"""Pluggable scheduling-policy layer (DESIGN.md §Policy layer): the four
policies on the shared WorkerPool substrate, cross-plane conformance between
the threaded runtime and the discrete-event simulator, open-arrival parity
for the baselines, and policy-parametric serving."""

import threading
import time

import numpy as np
import pytest

from repro.core.a2ws import WorkerPool
from repro.core.baselines import CTWSRuntime, LWRuntime
from repro.core.policy import (
    POLICIES,
    CTWSPolicy,
    LWPolicy,
    PolicyView,
    RandomWSPolicy,
    make_policy,
)
from repro.core.simulator import SimConfig, simulate
from repro.serve.engine import Replica, ServePool


def _busy(seconds: float) -> None:
    end = time.perf_counter() + seconds
    while time.perf_counter() < end:
        pass


# --------------------------------------------------------------- unit layer
def test_make_policy_registry():
    for name in POLICIES:
        assert make_policy(name, 4).name == name
    with pytest.raises(ValueError):
        make_policy("fifo", 4)
    pol = RandomWSPolicy()
    assert make_policy(pol, 4) is pol
    with pytest.raises(ValueError):  # kwargs make no sense for an instance
        make_policy(pol, 4, hop_time=1.0)


def test_lw_partition_routes_everything_to_leader():
    parts = LWPolicy().partition(list(range(7)), 3)
    assert parts == [[0, 1, 2, 3, 4, 5, 6], [], []]
    assert LWPolicy.central == 0


def _view(worker, num_workers, depths, now=0.0, idle=True, inflight=0):
    return PolicyView(
        worker=worker, now=now, idle=idle, ran_any=True, open_arrival=False,
        radius=1, num_workers=num_workers, rng=np.random.default_rng(0),
        window=list(range(num_workers)), depth=lambda j: depths[j],
        alive=lambda j: True, pending=lambda: sum(depths),
        inflight=lambda: inflight,
    )


def test_random_policy_steals_half_uniform():
    pol = RandomWSPolicy()
    plan = pol.on_boundary(_view(0, 3, [0, 9, 0]))
    assert plan is not None and plan.victim == 1 and plan.amount == 4
    # busy thieves and loot-in-transit never probe
    assert pol.on_boundary(_view(0, 3, [2, 9, 0], idle=False)) is None
    assert pol.on_boundary(_view(0, 3, [0, 9, 0], inflight=1)) is None
    # nothing anywhere -> no churn
    assert pol.on_boundary(_view(0, 3, [0, 0, 0])) is None


def test_ctws_only_token_holder_steals():
    pol = CTWSPolicy(3)
    pol.on_start([0, 6, 2], now=0.0)
    # worker 1 does not hold the token: no plan, token does not move
    assert pol.on_boundary(_view(1, 3, [0, 6, 2])) is None
    assert pol.token_at == 0
    # the holder is empty: steals HALF the most-loaded victim, passes token
    plan = pol.on_boundary(_view(0, 3, [0, 6, 2]))
    assert plan is not None and plan.victim == 1 and plan.amount == 3
    assert pol.token_at == 1


def test_ctws_hop_time_gates_token_reuse():
    pol = CTWSPolicy(2, hop_time=1.0)
    pol.on_start([0, 8], now=0.0)
    assert pol.on_boundary(_view(0, 2, [0, 8], now=0.5)) is None  # in transit
    assert pol.on_boundary(_view(0, 2, [0, 8], now=1.5)) is not None


def test_simulate_rejects_unknown_policy():
    cfg = SimConfig(speeds=np.ones(3), num_tasks=6)
    with pytest.raises(ValueError):
        simulate("fifo", cfg)


# ------------------------------------------------------ threaded substrate
@pytest.mark.parametrize("policy", ["ctws", "lw", "random"])
def test_baselines_every_task_once_on_substrate(policy):
    n, done, lock = 40, [], threading.Lock()

    def task_fn(wid, task):
        _busy(0.0005)
        with lock:
            done.append(task)

    stats = WorkerPool(list(range(n)), 4, task_fn, policy=policy).run()
    assert sorted(done) == list(range(n))
    assert sum(stats.per_worker_tasks) == n
    # non-ring policies pay zero info-cell traffic
    assert stats.info_cells_sent == 0


@pytest.mark.parametrize("cls", [LWRuntime, CTWSRuntime])
def test_baseline_open_arrival_latency_parity(cls):
    """PR 2 satellite: on the shared substrate LW/CTWS gain submit()/drain()
    and arrival-stamped records, so latency_percentiles() is non-empty for
    them too (it used to silently return {})."""
    done, lock = [], threading.Lock()

    def task_fn(wid, task):
        _busy(0.0005)
        with lock:
            done.append(task)

    rt = cls([], 3, task_fn, open_arrival=True)
    rt.start()
    rt.submit_many(range(8))
    time.sleep(0.01)  # a second wave, mid-flight
    rt.submit_many(range(8, 18))
    rt.drain()
    stats = rt.join()
    assert sorted(done) == list(range(18))
    pct = stats.latency_percentiles()
    assert pct and 0.0 < pct[50.0] <= pct[95.0] <= pct[99.0]


def test_random_policy_balances_heterogeneous_pool():
    """Classical random stealing must still drain a slow worker's queue."""
    n, slow = 30, {1}

    def task_fn(wid, task):
        _busy(0.012 if wid in slow else 0.002)

    stats = WorkerPool(list(range(n)), 2, task_fn, policy="random", seed=3).run()
    assert sum(stats.per_worker_tasks) == n
    assert len(stats.steals) > 0
    assert stats.per_worker_tasks[0] > stats.per_worker_tasks[1]


# --------------------------------------------------- cross-plane conformance
_SPEEDS = [4.0, 1.0, 1.0, 1.0]
_N, _BASE = 48, 0.012


def _threaded_stats(policy: str, seed: int):
    def task_fn(wid, task):
        _busy(_BASE / _SPEEDS[wid])

    pool = WorkerPool(
        list(range(_N)), len(_SPEEDS), task_fn, policy=policy, seed=seed
    )
    return pool.run()


def _sim_stats(policy: str):
    cfg = SimConfig(
        speeds=np.asarray(_SPEEDS), num_tasks=_N, task_cost=_BASE, noise=0.0,
        seed=0, hop_latency=1e-4, info_poll=1e-3, comm_cell_cost=0.0,
        steal_latency=5e-4, steal_per_task=1e-5, retry_interval=1e-3,
        token_base=1e-4, token_per_node=0.0, request_rtt=2e-4,
        leader_service=1e-4, leader_overhead=0.0,
    )
    return simulate(policy, cfg)


@pytest.mark.parametrize("policy", list(POLICIES))
def test_cross_plane_conformance(policy):
    """The same SchedPolicy semantics through BOTH planes: a threaded run
    (real clock, one 4x-fast worker) and a simulated run of the same seeded
    workload must agree on who dominates and how much work moved.

    The threaded plane is wall-clock noisy (GIL, CI machines), so it is
    sampled three times and compared by medians with a generous band — the
    assertion catches plane divergence (a policy that steals in one plane
    and not the other, or by an order of magnitude differently), not exact
    schedules.
    """
    sim = _sim_stats(policy)
    assert sum(sim.per_node_tasks) == _N
    assert int(np.argmax(sim.per_node_tasks)) == 0
    assert sim.steals > 0

    runs = [_threaded_stats(policy, seed) for seed in range(3)]
    for st in runs:
        assert sum(st.per_worker_tasks) == _N
    med_w0 = float(np.median([st.per_worker_tasks[0] for st in runs]))
    others = float(
        np.median([max(st.per_worker_tasks[1:]) for st in runs])
    )
    assert med_w0 > others, "fast worker must dominate in the threaded plane"
    med_moved = float(
        np.median([sum(s[3] for s in st.steals) for st in runs])
    )
    assert med_moved > 0, "threaded plane never stole"
    hi = max(med_moved, float(sim.moved_tasks))
    assert abs(med_moved - sim.moved_tasks) <= max(8.0, 0.8 * hi), (
        f"steal volume diverged across planes: threaded~{med_moved} "
        f"vs simulated {sim.moved_tasks}"
    )


# ------------------------------------------------------ policy-parametric serving
@pytest.mark.parametrize("policy", ["ctws", "lw", "random"])
def test_servepool_serves_open_arrival_with_baseline_policy(policy):
    """Acceptance: ServePool(policy="ctws") serves an open-arrival Poisson
    run end-to-end and reports latency percentiles (likewise lw/random)."""
    rng = np.random.default_rng(0)

    def gen(request):
        _busy(0.002)
        return {"echo": request["x"]}

    replicas = [
        Replica("fast", gen),
        Replica("slow", gen, slow_factor=6.0),
        Replica("slow2", gen, slow_factor=6.0),
    ]
    pool = ServePool(replicas, policy=policy, seed=0)
    pool.start()
    futs = []
    for k in range(24):
        time.sleep(float(rng.exponential(1.0 / 400.0)))
        futs.append(pool.submit({"x": k}))
    for k, f in enumerate(futs):
        assert f.result(timeout=30.0) == {"echo": k}
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 24
    pct = stats.latency_percentiles()
    assert pct and 0.0 < pct[50.0] <= pct[99.0]

"""Threaded-plane transport realism (ROADMAP item, DESIGN.md §Topology
plane) and the chaos properties of the fault fabric.

PR-7 priced steals in the threaded plane but paid the fare BEFORE the
claim; now a priced plan claims first and sleeps the fare while the loot
is in flight (overlapped with victim compute), mirroring the simulator's
claim-now/land-later event.  These tests pin the fare accounting, the
retiring-thief-with-loot-in-flight regression, and — with hypothesis —
task conservation under arbitrary kill/join/drop/partition interleavings
in both planes."""

import time

import numpy as np
import pytest

from _hypo import given, settings, st  # skips properties w/o hypothesis
from repro.core.a2ws import WorkerPool
from repro.core.netfault import (
    LinkFault,
    NetFaultSchedule,
    PartitionEvent,
)
from repro.core.simulator import SimConfig, simulate, table2_speeds
from repro.core.topology import Topology


# ---------------------------------------------------- fare paid after claim
def test_threaded_fare_accounting_matches_steal_log():
    """Every landed priced steal pays topo.cost(victim, thief, got) as a
    sleep-before-land; the summed fare telemetry must reconcile exactly
    against the steal log."""
    topo = Topology.uniform(0.01, 0.002)
    pool = WorkerPool(
        list(range(60)), 4, lambda w, t: time.sleep(0.002 * (1 + w % 3)),
        policy="a2ws", seed=3, topology=topo,
    )
    stats = pool.run()
    assert len(stats.records) == 60
    assert stats.steals, "no steals fired; fare accounting untested"
    expect = sum(topo.cost(v, i, k) for _t, i, v, k in stats.steals)
    assert stats.fare_paid == pytest.approx(expect)
    assert stats.fare_paid > 0.0


def test_threaded_zero_cost_links_pay_no_fare():
    pool = WorkerPool(
        list(range(60)), 4, lambda w, t: time.sleep(0.002 * (1 + w % 3)),
        policy="a2ws", seed=3, topology=Topology.uniform(),
    )
    stats = pool.run()
    assert len(stats.records) == 60
    assert stats.fare_paid == 0.0


def test_threaded_fare_overlaps_victim_compute():
    """The fare is the THIEF's stall, not the victim's: with one fast thief
    and a loaded victim behind an expensive link, the victim keeps
    executing while the thief's loot is in flight — total makespan stays
    far below the serialized claim-then-wait-then-run bound."""
    topo = Topology.uniform(0.05, 0.0)
    pool = WorkerPool(
        [], 2, lambda w, t: time.sleep(0.004), policy="a2ws", seed=0,
        open_arrival=True, topology=topo,
    )
    pool.start()
    for i in range(30):
        pool.submit(i, worker=0)  # all work lands on the victim
    pool.drain()
    stats = pool.join()
    assert len(stats.records) == 30
    # the victim alone would take 30*4ms = 120ms; the old PRE-claim fare
    # blocked the victim's tasks from being claimed during each 50ms stall
    # but the victim still drained itself — the pinned property is that
    # thief stalls did not SERIALIZE: makespan < victim-solo + one fare.
    assert stats.makespan < 0.120 + 0.05 + 0.10  # generous CI slack


def test_retiring_thief_with_loot_in_flight_resprays_and_terminates():
    """Satellite regression: the thief claims loot, the fare is in flight,
    and the thief is RETIRED before landing.  The loot lands on its deque,
    the retire drain re-sprays it to survivors, and quiescence counters
    still terminate the pool with every task executed exactly once."""
    topo = Topology.uniform(0.25, 0.0)  # long fare: a wide retire window
    pool = WorkerPool(
        [], 2, lambda w, t: time.sleep(0.02), policy="a2ws", seed=1,
        open_arrival=True, topology=topo,
    )
    pool.start()
    for i in range(20):
        pool.submit(i, worker=0)
    # wait until the thief has CLAIMED (victim deque shrank by more than
    # worker 0 could have executed) — the fare (0.25 s) is then in flight
    deadline = time.time() + 5.0
    while time.time() < deadline:
        if pool.workers[1].deque.mutations > 0 or len(
            pool.workers[1].deque
        ) > 0:
            break  # loot already landed (fast machine): still a valid run
        claimed = 20 - len(pool.workers[0].deque) - pool.workers[0].executed
        if claimed > 1:
            break
        time.sleep(0.002)
    pool.retire_worker(1)  # retire the thief mid-flight
    pool.drain()
    stats = pool.join()
    assert len(stats.records) == 20, "tasks lost with loot in flight"
    assert pool.done_counter.load() == pool.submitted.load() == 20
    assert any(kind == "retire" and w == 1
               for _t, kind, w in pool.membership_log)
    # the retiree handed everything back: worker 0 ran what 1 didn't
    assert stats.per_worker_tasks[0] + stats.per_worker_tasks[1] == 20


# ----------------------------------------------------------- chaos property
def _sim_chaos_run(seed, drop, cut_start, cut_len, cut_k, n_joins, n_retires):
    """One chaos cell: arbitrary join/retire/drop/partition/heal scripts on
    the hardened virtual-time plane must conserve every task and terminate.
    Shared by the hypothesis property and the seeded CI sweep."""
    rng = np.random.default_rng(seed)
    joins = tuple(
        (float(rng.uniform(1.0, 30.0)), float(rng.uniform(0.5, 2.0)))
        for _ in range(n_joins)
    )
    retires = tuple(
        (float(rng.uniform(5.0, 50.0)), int(rng.integers(0, 8)))
        for _ in range(n_retires)
    )
    retires = tuple({node: t for t, node in retires}.items())
    retires = tuple((t, node) for node, t in retires)
    nf = NetFaultSchedule(
        faults=(LinkFault(drop_prob=drop),) if drop > 0.0 else (),
        partitions=(
            PartitionEvent(side=tuple(range(cut_k)), start=cut_start,
                           duration=cut_len),
        ),
    )
    cfg = SimConfig(
        speeds=table2_speeds("C4")[:8], num_tasks=120, seed=seed,
        task_cost=1.0, joins=joins, retires=retires, netfaults=nf,
    )
    res = simulate("a2ws", cfg)
    assert sum(res.per_node_tasks) == cfg.num_tasks
    assert len(res.records) == cfg.num_tasks
    assert res.lost_tasks == 0


@given(
    seed=st.integers(0, 2**16),
    drop=st.floats(0.0, 0.6),
    cut_start=st.floats(1.0, 40.0),
    cut_len=st.floats(1.0, 60.0),
    cut_k=st.integers(1, 7),
    n_joins=st.integers(0, 2),
    n_retires=st.integers(0, 2),
)
@settings(max_examples=10, deadline=None)
def test_property_sim_chaos_conserves_tasks(
    seed, drop, cut_start, cut_len, cut_k, n_joins, n_retires
):
    """Arbitrary interleavings of join/retire/drop/partition/heal: every
    submitted task still runs exactly once (hardened plane), and the run
    terminates."""
    _sim_chaos_run(seed, drop, cut_start, cut_len, cut_k, n_joins, n_retires)


@pytest.mark.slow
@pytest.mark.parametrize("drop", [0.0, 0.25, 0.5])
@pytest.mark.parametrize("cut_k", [1, 4, 7])
def test_chaos_matrix_sim_sweep(drop, cut_k):
    """The seeded CI chaos job: a deterministic fault-matrix sweep (the
    hypothesis property's body on a fixed grid, so CI failures reproduce
    bit-for-bit from the cell id alone).  Every cell of
    drop x partition-size x churn conserves tasks on the hardened plane."""
    for seed in (0, 1, 2):
        _sim_chaos_run(
            seed=seed * 7919 + cut_k, drop=drop,
            cut_start=5.0 + 3.0 * seed, cut_len=10.0 + 8.0 * seed,
            cut_k=cut_k, n_joins=seed % 3, n_retires=(seed + 1) % 3,
        )


@given(
    seed=st.integers(0, 2**16),
    drop=st.floats(0.0, 0.5),
    cut_start=st.floats(0.0, 0.08),
    cut_len=st.floats(0.02, 0.1),
    kill=st.booleans(),
    join=st.booleans(),
)
@settings(max_examples=6, deadline=None)
def test_property_threaded_chaos_conserves_tasks(
    seed, drop, cut_start, cut_len, kill, join
):
    """Real threads under kill/join/drop/partition/heal interleavings:
    done == submitted at join(), join() terminates, ring versions stay
    monotone."""
    killed = []

    def task_fn(w, t):
        if t == "die" and not killed:  # one-shot: the re-served copy runs
            killed.append(w)
            raise RuntimeError("injected death")
        time.sleep(0.002)

    nf = NetFaultSchedule(
        faults=(LinkFault(drop_prob=drop),) if drop > 0.0 else (),
        partitions=(
            PartitionEvent(side=(0,), start=cut_start, duration=cut_len),
        ),
        attempt_timeout=0.001, lease_timeout=0.01, stale_after=0.02,
    )
    pool = WorkerPool(
        [], 3, task_fn, policy="a2ws", seed=seed, open_arrival=True,
        netfaults=nf,
    )
    pool.start()
    for i in range(24):
        pool.submit(i)
    mid = pool.info.version.copy()
    if kill:
        pool.submit("die", worker=2)  # worker 2 dies; its queue re-sprays
    if join:
        pool.add_worker()
    for i in range(24, 36):
        pool.submit(i)
    pool.drain()
    stats = pool.join()
    expect = 36 + (1 if kill else 0)
    assert pool.submitted.load() == expect
    # the "die" task never completes (its worker died mid-task and pushed
    # it back; a survivor re-serves it — conservation through death)
    assert pool.done_counter.load() == expect
    assert len(stats.records) == expect
    v = pool.info.version
    assert np.all(v[: mid.shape[0], : mid.shape[1]] >= mid)

"""Straggler/limplock plane (DESIGN.md §Straggler plane): slowdown fault
injection, adaptive limp detection/re-pricing, and cross-plane conformance —
a scripted mid-run slowdown must produce the same qualitative steal-volume
shift in the threaded WorkerPool and the discrete-event simulator, for every
policy.  Plus the serve-plane integration (limp-aware autoscaler) and the
acceptance scenario (adaptive vs count-based A2WS under a 16x limplock)."""

import math
import threading
import time

import numpy as np
import pytest

from _hypo import given, settings, st
from repro.core.a2ws import WorkerPool
from repro.core.info_ring import RingInfo
from repro.core.limp import (
    LimpConfig,
    LimpState,
    SlowdownEvent,
    SlowdownSchedule,
    normalize_duration,
)
from repro.core.policy import POLICIES
from repro.core.simulator import SimConfig, simulate
from repro.core.steal import weighted_overlay
from repro.serve.engine import AutoscaleConfig, Replica, ServePool

#: calibrated virtual costs (tests/test_policy.py): scheduling overheads
#: small vs the 12 ms task grain, so sim makespans mirror the threaded pool
SIM_COSTS = dict(
    hop_latency=1e-4, info_poll=1e-3, comm_cell_cost=0.0, steal_latency=5e-4,
    steal_per_task=1e-5, retry_interval=1e-3, token_base=1e-4,
    token_per_node=0.0, request_rtt=2e-4, leader_service=1e-4,
    leader_overhead=0.0,
)


# ------------------------------------------------------------ fault primitives
def test_slowdown_event_step_transient_ramp():
    step = SlowdownEvent(0, 10.0, 16.0)
    assert step.factor_at(9.999) == 1.0
    assert step.factor_at(10.0) == 16.0
    assert step.factor_at(1e9) == 16.0  # step faults never recover
    trans = SlowdownEvent(0, 10.0, 16.0, duration=5.0)
    assert trans.factor_at(12.0) == 16.0
    assert trans.factor_at(15.0) == 1.0  # end is exclusive
    ramp = SlowdownEvent(0, 10.0, 16.0, ramp=10.0)
    assert ramp.factor_at(10.0) == 1.0
    assert ramp.factor_at(15.0) == pytest.approx(8.5)  # halfway up
    assert ramp.factor_at(20.0) == 16.0


def test_slowdown_schedule_compounds_overlapping_events():
    sched = SlowdownSchedule((
        SlowdownEvent(1, 0.0, 4.0),
        SlowdownEvent(1, 5.0, 2.0, duration=5.0),
        SlowdownEvent(2, 0.0, 3.0),
    ))
    assert sched.factor_at(1, 2.0) == 4.0
    assert sched.factor_at(1, 6.0) == 8.0  # overlapping faults multiply
    assert sched.factor_at(1, 11.0) == 4.0
    assert sched.factor_at(0, 6.0) == 1.0
    assert sched.workers() == {1, 2}


def test_slowdown_event_validation():
    with pytest.raises(ValueError):
        SlowdownEvent(-1, 0.0, 2.0)
    with pytest.raises(ValueError):
        SlowdownEvent(0, -1.0, 2.0)
    with pytest.raises(ValueError):
        SlowdownEvent(0, 0.0, 0.0)  # factor must be positive
    with pytest.raises(ValueError):
        SlowdownEvent(0, 0.0, 2.0, duration=0.0)
    with pytest.raises(ValueError):
        SlowdownEvent(0, 0.0, 2.0, ramp=-1.0)


# ------------------------------------------------------------- limp detector
def test_limp_state_flags_and_recovers_with_hysteresis():
    st_ = LimpState(LimpConfig(limp_factor=4.0, recover_factor=2.0,
                               recent_alpha=0.5, min_samples=1))
    for _ in range(8):
        st_.observe(1.0)
    assert not st_.evaluate()
    baseline = st_.baseline
    # one 16x completion pushes recent to ~8.5x baseline -> flag
    st_.observe(16.0)
    assert st_.evaluate()
    # the baseline FREEZES while limping: the fault must not become normal
    st_.observe(16.0)
    assert st_.evaluate()
    assert st_.baseline == baseline
    # recovery: fast completions pull recent back under recover_factor
    for _ in range(4):
        st_.observe(1.0)
    assert not st_.evaluate()


def test_limp_state_peer_fallback_before_min_samples():
    """A worker that is limped from its very first completion has no healthy
    baseline of its own — the peer median stands in until min_samples."""
    st_ = LimpState(LimpConfig(min_samples=3))
    st_.observe(16.0)
    assert st_.evaluate(peer_ref=1.0), "boot-limped worker must flag via peers"
    assert not LimpState(LimpConfig(min_samples=3)).evaluate(), \
        "no samples + no peers -> verdict unchanged (healthy)"


def test_recovery_half_life_pinned():
    """Regression pin (DESIGN.md §Straggler plane): at recent_alpha=0.5 the
    recent EWMA sheds half the fault's excess per completion — a transient
    slowdown is forgiven in O(1) completions, never blacklisted forever."""
    assert LimpConfig(recent_alpha=0.5).recovery_half_life() == pytest.approx(1.0)
    assert LimpConfig(recent_alpha=0.25).recovery_half_life() == pytest.approx(
        math.log(0.5) / math.log(0.75)
    )
    assert LimpConfig(recent_alpha=1.0).recovery_half_life() == 1.0


def test_normalize_duration_rescales_classes():
    class_t = np.array([1.0, 8.0])
    mean = float(np.nanmean(class_t))
    # a heavy-class completion is scaled DOWN so it cannot false-flag
    assert normalize_duration(8.0, 1, class_t) == pytest.approx(8.0 * mean / 8.0)
    assert normalize_duration(1.0, 0, class_t) == pytest.approx(1.0 * mean)
    # degenerate cases: no class info -> identity
    assert normalize_duration(3.0, 0, None) == 3.0
    assert normalize_duration(3.0, 1, np.array([float("nan"), float("nan")])) == 3.0


def test_limp_config_validation():
    with pytest.raises(ValueError):
        LimpConfig(limp_factor=1.0)
    with pytest.raises(ValueError):
        LimpConfig(recover_factor=5.0)  # must stay below limp_factor
    with pytest.raises(ValueError):
        LimpConfig(recent_alpha=0.0)
    with pytest.raises(ValueError):
        LimpConfig(min_samples=0)


# ----------------------------------------------- scenario validators (with_())
def test_sim_slowdown_target_never_joins_rejected():
    cfg = SimConfig(speeds=np.ones(2), num_tasks=10)
    with pytest.raises(ValueError, match="never joins"):
        cfg.with_(slowdowns=(SlowdownEvent(5, 1.0, 4.0),))


def test_sim_slowdown_before_join_rejected():
    cfg = SimConfig(speeds=np.ones(2), num_tasks=10, joins=((10.0, 1.0),))
    with pytest.raises(ValueError, match="precedes its join"):
        cfg.with_(slowdowns=(SlowdownEvent(2, 5.0, 4.0),))
    # starting AFTER the join is fine
    cfg.with_(slowdowns=(SlowdownEvent(2, 11.0, 4.0),))


def test_sim_slowdown_after_retire_rejected():
    cfg = SimConfig(speeds=np.ones(3), num_tasks=10, retires=((5.0, 1),))
    with pytest.raises(ValueError, match="already retired"):
        cfg.with_(slowdowns=(SlowdownEvent(1, 6.0, 4.0),))
    # the same mis-script straight through the constructor is caught at
    # simulate() time (with_() is bypassable by construction)
    bad = SimConfig(speeds=np.ones(3), num_tasks=10, retires=((5.0, 1),),
                    slowdowns=(SlowdownEvent(1, 6.0, 4.0),))
    with pytest.raises(ValueError, match="already retired"):
        simulate("a2ws", bad)


def test_threaded_set_worker_slowdown_validates():
    pool = WorkerPool([], 2, lambda w, t: None, open_arrival=True)
    with pytest.raises(ValueError):
        pool.set_worker_slowdown(7, 2.0)
    with pytest.raises(ValueError):
        pool.set_worker_slowdown(0, 0.0)
    with pytest.raises(ValueError):
        pool.set_worker_slowdown(0, float("inf"))
    pool.set_worker_slowdown(0, 2.0)
    pool.set_worker_slowdown(0, 1.0)


# ------------------------------------------- cross-plane conformance, per policy
@pytest.mark.parametrize("policy", list(POLICIES))
def test_cross_plane_slowdown_conformance(policy):
    """One seeded workload shape through BOTH planes with the same scripted
    fault: worker 1 limps to 16x early in a closed run.  In each plane the
    limper must end up executing clearly fewer tasks than the healthy mean
    (the steal plane routes around it) while every task still executes
    exactly once."""
    n, base = 48, 0.012

    # -- simulated (virtual time, calibrated costs)
    cfg = SimConfig(
        speeds=np.ones(4), num_tasks=n, task_cost=base, noise=0.0, seed=0,
        slowdowns=(SlowdownEvent(1, base, 16.0),), limp=LimpConfig(),
        **SIM_COSTS,
    )
    sim = simulate(policy, cfg)
    assert sum(sim.per_node_tasks) == n
    healthy = [sim.per_node_tasks[j] for j in (0, 2, 3)]
    assert sim.per_node_tasks[1] < np.mean(healthy), (
        f"sim limper kept its share: {sim.per_node_tasks}"
    )
    assert sim.moved_tasks > 0, "sim plane never moved work off the limper"

    # -- threaded (same shape; sleep-based tasks keep the GIL fair — a
    # busy-wait straggler would starve the very threads that should
    # out-run it)
    done, lock = [], threading.Lock()

    def task_fn(wid, task):
        time.sleep(base)
        with lock:
            done.append(task)

    pool = WorkerPool(
        list(range(n)), 4, task_fn, policy=policy, seed=0,
        slowdown=SlowdownSchedule((SlowdownEvent(1, base, 16.0),)),
        limp=LimpConfig(),
    )
    stats = pool.run()
    assert sorted(done) == list(range(n))
    assert sum(stats.per_worker_tasks) == n
    healthy = [stats.per_worker_tasks[j] for j in (0, 2, 3)]
    assert stats.per_worker_tasks[1] < np.mean(healthy), (
        f"threaded limper kept its share: {stats.per_worker_tasks}"
    )
    assert sum(s[3] for s in stats.steals) > 0, "threaded plane never stole"


def test_sim_limp_detection_fires_and_reroutes_open_arrival():
    """Open arrival + detection: the detector flags the limper (one slow
    completion is enough at the defaults), routing skips it from then on,
    and thieves strip its queue — it serves almost nothing post-fault."""
    cfg = SimConfig(
        speeds=np.ones(4), num_tasks=200, task_cost=1.0, seed=0,
        arrival="poisson", arrival_rate=1.4,
        slowdowns=(SlowdownEvent(1, 20.0, 16.0),), limp=LimpConfig(),
    )
    res = simulate("a2ws", cfg)
    flags = [(t, w) for t, w, f in res.limp_events if f]
    assert flags and flags[0][1] == 1
    t_flag = flags[0][0]
    # detection needs one slow completion: ~16x one task's service time
    assert 20.0 < t_flag < 20.0 + 16.0 * 1.5 + 3.0
    # post-flag the limper serves only the rate-limited probation canaries
    # (exponential backoff: O(log T) of them), never a routed share
    post = [1 for nd, s, _e in res.records if nd == 1 and s > t_flag]
    assert len(post) <= 6, f"flagged limper kept serving: {len(post)} tasks"
    healthy = [res.per_node_tasks[j] for j in (0, 2, 3)]
    assert res.per_node_tasks[1] < np.mean(healthy) / 2


# ------------------------------------------------- hypothesis property (ring)
@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=2),   # 0=publish 1=comm 2=limp
            st.integers(min_value=0, max_value=5),   # worker
            st.floats(min_value=0.0, max_value=64.0, allow_nan=False),
            st.floats(min_value=1e-6, max_value=64.0, allow_nan=False),
        ),
        max_size=60,
    )
)
def test_ring_versions_monotone_and_overlay_finite_under_limp(ops):
    """Property (DESIGN.md §Straggler plane): under ARBITRARY interleavings
    of publishes, ring communication and limp-flag flips — including the
    collapsed-t re-pricing a flagged owner publishes — every per-cell
    version stays monotonically non-decreasing and the work-weighted
    overlay keeps producing finite, non-negative prices."""
    P, C = 6, 2
    ri = RingInfo(P, radius=2, num_classes=C)
    limping = [False] * P
    for kind, w, a, b in ops:
        prev = ri.version.copy()
        if kind == 0:
            nc = np.array([a % 7, b % 7])
            tc = np.array([b, float("nan") if a < 1.0 else a * b])
            ri.update_local(w, a + nc.sum(), b, nc, tc, limp_i=limping[w])
        elif kind == 1:
            ri.communicate(w)
        else:
            limping[w] = not limping[w]
            # a flagged owner re-prices: publish collapsed t (recent EWMA)
            t_pub = max(b, 16.0 * b) if limping[w] else b
            ri.update_local(w, a, t_pub, limp_i=limping[w])
        assert (ri.version >= prev).all(), "a cell version went backwards"
        n, t, _raw, _win, nc_v, tc_v, limp_v = ri.view_window_all(w)
        assert limp_v.dtype == np.bool_
        queued = np.maximum(n, 0.0)
        n_w, t_w, queued_w, unit, qtasks, rel = weighted_overlay(
            np.maximum(n, 0.0), np.maximum(t, 0.0), queued, nc_v, tc_v
        )
        for arr in (n_w, t_w, queued_w, unit, qtasks, rel):
            assert np.isfinite(arr).all(), "overlay produced non-finite price"
        assert (n_w >= 0).all() and (queued_w >= 0).all()
        assert (unit > 0).all() and (rel > 0).all()


# ------------------------------------------------- transient-recovery regression
def test_sim_transient_slowdown_recovers_and_unflags():
    """Regression: a TRANSIENT fault (recovers after `duration`) must not
    blacklist the worker forever — the detector unflags it within a few
    healthy completions (recovery half-life is ~1 completion at the default
    recent_alpha=0.5) and it serves real work again."""
    cfg = SimConfig(
        speeds=np.ones(4), num_tasks=300, task_cost=1.0, seed=0,
        arrival="poisson", arrival_rate=1.4,
        slowdowns=(SlowdownEvent(1, 20.0, 16.0, duration=30.0),),
        limp=LimpConfig(),
    )
    res = simulate("a2ws", cfg)
    ev = [(t, f) for t, w, f in res.limp_events if w == 1]
    assert [f for _, f in ev][:2] == [True, False], f"no flag/unflag cycle: {ev}"
    t_recover = [t for t, f in ev if not f][0]
    post = [1 for nd, s, _e in res.records if nd == 1 and s > t_recover]
    assert len(post) >= 5, (
        f"recovered worker permanently blacklisted: served {len(post)} after "
        f"unflagging at t={t_recover:.1f}"
    )


def test_threaded_transient_slowdown_recovers():
    """The same forgiveness on real threads: flag under an injected live
    slowdown, unflag after it is lifted, and the worker serves again."""
    pool = WorkerPool([], 2, lambda w, t: time.sleep(0.004),
                      policy="a2ws", open_arrival=True, seed=0,
                      limp=LimpConfig())
    pool.start()
    pool.submit_many(range(20))
    deadline = time.time() + 5.0
    while pool.pending() and time.time() < deadline:
        time.sleep(0.002)
    pool.set_worker_slowdown(1, 12.0)
    pool.submit_many(range(20, 40))
    deadline = time.time() + 10.0
    while not pool.limping(1) and time.time() < deadline:
        time.sleep(0.002)
    assert pool.limping(1), "injected slowdown never flagged"
    pool.set_worker_slowdown(1, 1.0)
    # flagged workers still pop their OWN queue, so healthy completions keep
    # arriving and the recent EWMA forgives within a few of them
    deadline = time.time() + 10.0
    while pool.limping(1) and time.time() < deadline:
        pool.submit_many(range(40, 44), worker=1)
        time.sleep(0.01)
    assert not pool.limping(1), "recovered worker stayed blacklisted"
    flips = [f for _t, w, f in pool.limp_log if w == 1]
    assert flips[:2] == [True, False]
    pool.drain()
    stats = pool.join()
    assert sum(stats.per_worker_tasks) == pool.done_counter.load()
    pool_tasks = stats.per_worker_tasks
    assert pool_tasks[1] > 0


# ----------------------------------------------------------------- serve plane
def test_servepool_limp_detection_and_autoscaler_drain():
    """Tentpole serve integration: a replica limping mid-serve is flagged
    and drained out like retire_replica(drain=True) once the scheduler has
    stripped its queue — recorded as a 'limp' scale event.  (Scale-out and
    idle-retire are disabled via unreachable bounds so the ONLY membership
    change is the limp-drain under test — recycling/idle races would make
    the accounting below ambiguous.)"""
    def gen(req):
        time.sleep(0.01)
        return {"ok": True}

    pool = ServePool(
        [Replica(f"r{i}", gen) for i in range(3)],
        seed=0,
        slowdown=SlowdownSchedule((SlowdownEvent(1, 0.25, 16.0),)),
        limp=LimpConfig(),
        autoscale=AutoscaleConfig(
            factory=lambda wid: Replica(f"s{wid}", gen),
            min_replicas=2, max_replicas=3,
            high_pending_per_replica=1e9, idle_ticks_to_retire=10**9,
            drain_limping_ticks=3, interval=0.01,
        ),
    )
    pool.start()
    rng = np.random.default_rng(0)
    futs = []
    for _ in range(120):
        time.sleep(float(rng.exponential(1.0 / 80.0)))
        futs.append(pool.submit({"x": 1}))
    for f in futs:
        f.result(timeout=60)
    deadline = time.time() + 5.0
    while 1 in pool.live_replicas() and time.time() < deadline:
        time.sleep(0.01)
    assert any(w == 1 and f for _t, w, f in pool.limp_log), "never flagged"
    assert any(e[1] == "limp" and e[2] == 1 for e in pool.scale_events), (
        f"limping replica never limp-drained: {pool.scale_events}"
    )
    assert 1 not in pool.live_replicas()
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 120
    assert stats.per_worker_tasks[1] < 120 // 3, "limper kept its full share"


def test_servepool_set_replica_slowdown_and_accessors():
    pool = ServePool([Replica("r0", lambda r: {"ok": True}),
                      Replica("r1", lambda r: {"ok": True})], limp=LimpConfig())
    with pytest.raises(RuntimeError):
        pool.set_replica_slowdown(0, 2.0)
    assert pool.limping_replicas() == []
    pool.start()
    pool.set_replica_slowdown(1, 4.0)
    with pytest.raises(ValueError):
        pool.set_replica_slowdown(1, -1.0)
    assert pool.limping_replicas() == []  # injected but not yet detected
    pool.shutdown()


# ------------------------------------------------------------ acceptance (slow)
@pytest.mark.slow
def test_limplock_acceptance_adaptive_vs_count():
    """ISSUE acceptance: one worker of four limps to 16x mid-run under open
    arrivals.  Over >= 5 seeds, adaptive re-pricing keeps the median p99
    within ~1.5x of the no-fault baseline while the count-based ablation
    (limp=None — bit-for-bit the pre-straggler-plane scheduler) degrades by
    >= 3x.  The same grid is archived by benchmarks/limplock.py as
    BENCH_limplock.json."""
    ratios = {"adaptive": [], "count": []}
    for seed in range(5):
        base = SimConfig(
            speeds=np.ones(4), num_tasks=3600, task_cost=1.0, seed=seed,
            arrival="poisson", arrival_rate=1.4,
            slowdowns=(SlowdownEvent(1, 60.0, 16.0),),
        )
        p99 = {}
        for name, cfg in (
            ("no_fault", base.with_(slowdowns=())),
            ("adaptive", base.with_(limp=LimpConfig())),
            ("count", base),
        ):
            res = simulate("a2ws", cfg)
            assert sum(res.per_node_tasks) == 3600
            p99[name] = res.latency_percentiles((99.0,))[99.0]
        ratios["adaptive"].append(p99["adaptive"] / p99["no_fault"])
        ratios["count"].append(p99["count"] / p99["no_fault"])
    med_a = float(np.median(ratios["adaptive"]))
    med_c = float(np.median(ratios["count"]))
    assert med_a <= 1.5, f"adaptive p99 ratio {med_a:.2f} (per-seed {ratios})"
    assert med_c >= 3.0, f"count-based p99 ratio {med_c:.2f} — limplock gone?"

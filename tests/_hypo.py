"""Optional-hypothesis shim: property tests SKIP (not error) when the
``hypothesis`` package is absent, so tier-1 collection succeeds everywhere.

Import from tests as ``from _hypo import given, settings, st`` — with
hypothesis installed these are the real objects; without it ``@given``
replaces the test with a skip marker and the strategy/settings calls become
inert placeholders.  CI installs hypothesis, so the properties always run
there (.github/workflows/ci.yml).
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(_fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():  # pragma: no cover - placeholder body
                pass

            _skipped.__name__ = _fn.__name__
            _skipped.__doc__ = _fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Placeholder: strategy expressions evaluate to inert objects."""

        def __getattr__(self, _name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):  # strategies are sometimes called
            return self

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

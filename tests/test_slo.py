"""SLO plane (DESIGN.md §SLO serving): the first-class Task record, SLO-
ordered owner pops (latency jumps batch, EDF within class, batch aging),
the no-SLO degenerate bit-for-bit conformance in both planes (flat +
hierarchical), the diurnal trace generator, the `_arrival_times` fast
path, p99.9 telemetry, the sim autoscale plane, and the serve-plane SLO
submit path."""

import math

import numpy as np
import pytest

from _hypo import given, settings, st  # skips properties w/o hypothesis
from repro.core.a2ws import (
    DEFAULT_QS,
    WorkerPool,
    latency_percentiles,
)
from repro.core.deque import (
    SLO_BATCH,
    SLO_LATENCY,
    SLO_NAMES,
    Task,
    TaskDeque,
    slo_key,
    slo_of,
)
from repro.core.policy import HierarchicalA2WSPolicy
from repro.core.simulator import (
    SimAutoscale,
    SimConfig,
    _arrival_times,
    simulate,
    table2_speeds,
)
from repro.core.trace import diurnal_trace, load_trace, save_trace
from repro.serve.engine import Replica, ServePool


# ------------------------------------------------------------ the Task record
def test_task_record_defaults_and_slo_of():
    t = Task()
    assert t.id == -1 and t.cls == 0 and t.slo == SLO_BATCH
    assert t.arrival != t.arrival and t.deadline == math.inf
    t2 = Task(id=3, arrival=1.5, cls=2, slo=SLO_LATENCY, deadline=2.0,
              payload={"x": 1})
    assert slo_of(t2) == (SLO_LATENCY, 2.0, 1.5)
    assert "latency" in repr(t2)
    # duck-typed face: anything with slo_class/deadline/submit_t (the
    # ServeFuture shape) reads identically
    class Fut:
        slo_class = SLO_LATENCY
        deadline = 9.0
        submit_t = 4.0
    assert slo_of(Fut()) == (SLO_LATENCY, 9.0, 4.0)
    # plain payloads are batch-class, no deadline
    s, d, a = slo_of({"prompt": "hi"})
    assert s == SLO_BATCH and d == math.inf and a != a


def test_slo_key_ordering_rule():
    key = slo_key(now=100.0, aging=10.0)
    lat_tight = Task(slo=SLO_LATENCY, deadline=101.0)
    lat_loose = Task(slo=SLO_LATENCY, deadline=105.0)
    fresh_batch = Task(slo=SLO_BATCH, arrival=95.0)
    aged_batch = Task(slo=SLO_BATCH, arrival=85.0)  # age 15 > 10
    ranks = sorted(
        [lat_loose, fresh_batch, aged_batch, lat_tight], key=key
    )
    # EDF among latency; the aged batch task is promoted to (0, 85+10=95),
    # ahead of BOTH deadlines; the fresh batch task stays last.
    assert ranks == [aged_batch, lat_tight, lat_loose, fresh_batch]
    # aging=inf never promotes
    key_inf = slo_key(now=1e9, aging=math.inf)
    assert key_inf(aged_batch) > key_inf(lat_loose)


def test_taskdeque_slo_ordered_owner_pops_and_thief_asymmetry():
    d = TaskDeque()
    tasks = [
        Task(id=0, arrival=0.0, slo=SLO_BATCH),
        Task(id=1, arrival=0.1, slo=SLO_BATCH),
        Task(id=2, arrival=0.2, slo=SLO_LATENCY, deadline=5.0),
        Task(id=3, arrival=0.3, slo=SLO_LATENCY, deadline=2.0),
    ]
    for t in tasks:
        d.push([t])  # one push per submit, as the runtime does
    # owner: EDF latency first (id 3 then 2), batch only afterwards
    key = slo_key(1.0)
    assert d.get_task(key).id == 3
    assert d.get_task(key).id == 2
    assert {d.get_task(key).id, d.get_task(key).id} == {0, 1}
    assert d.get_task(key) is None
    # thief end is UNCHANGED: steals strip the oldest tail slots, i.e.
    # batch work preferentially (the batch tasks were submitted first)
    for t in tasks:
        d.push([t])
    loot = d.steal(2).tasks
    assert [t.id for t in loot] == [1, 0]
    assert d.get_task(slo_key(1.0)).id == 3


def test_taskdeque_keyed_pop_degenerates_on_plain_payloads():
    a, b = TaskDeque(), TaskDeque()
    a.push(list(range(8)))
    b.push(list(range(8)))
    got_a = [a.get_task() for _ in range(8)]
    got_b = [b.get_task(slo_key(0.0)) for _ in range(8)]
    assert got_a == got_b  # plain payloads: SLO pops == LIFO pops


@given(
    seed=st.integers(0, 2**16),
    n=st.integers(1, 24),
    aging=st.sampled_from([0.5, 2.0, math.inf]),
)
@settings(max_examples=25, deadline=None)
def test_property_keyed_pop_returns_min_rank_and_never_starves(seed, n, aging):
    """get_task(key) always returns a minimum-rank task; with finite aging,
    a batch task older than `aging` whose promoted key beats every latency
    deadline is never passed over (the no-starvation bound)."""
    rng = np.random.default_rng(seed)
    d = TaskDeque()
    shadow = []
    for i in range(n):
        if rng.random() < 0.5:
            t = Task(id=i, arrival=float(rng.uniform(0, 5)), slo=SLO_BATCH)
        else:
            t = Task(id=i, arrival=float(rng.uniform(0, 5)),
                     slo=SLO_LATENCY,
                     deadline=float(rng.uniform(0, 10)))
        shadow.append(t)
        d.push([t])
    now = 5.0
    while shadow:
        key = slo_key(now, aging)
        got = d.get_task(key)
        best = min(key(t) for t in shadow)
        assert key(got) == best
        shadow.remove(got)
        now += 0.25
    assert d.get_task(slo_key(now, aging)) is None


# --------------------------------------------- satellite: _arrival_times perf
def test_arrival_times_accepts_arrays_sorts_and_validates():
    rng = np.random.default_rng(0)
    unsorted = np.asarray([3.0, 1.0, 2.0])
    cfg = SimConfig(speeds=(1.0,), num_tasks=3, arrival="trace",
                    arrival_trace=unsorted)
    out = _arrival_times(cfg, rng)
    assert out.tolist() == [1.0, 2.0, 3.0]
    assert unsorted.tolist() == [3.0, 1.0, 2.0]  # input never mutated
    # list input works; already-sorted ndarray input is copied, not aliased
    cfg2 = SimConfig(speeds=(1.0,), num_tasks=3, arrival="trace",
                     arrival_trace=[1.0, 2.0, 3.0])
    assert _arrival_times(cfg2, rng).dtype == np.float64
    sorted_arr = np.asarray([1.0, 2.0])
    cfg3 = SimConfig(speeds=(1.0,), num_tasks=2, arrival="trace",
                     arrival_trace=sorted_arr)
    out3 = _arrival_times(cfg3, rng)
    out3[0] = -1.0
    assert sorted_arr[0] == 1.0
    for bad in ((), (1.0, math.nan), (math.inf,)):
        with pytest.raises(ValueError):
            _arrival_times(
                SimConfig(speeds=(1.0,), num_tasks=1, arrival="trace",
                          arrival_trace=bad),
                rng,
            )


# ---------------------------------------------------- satellite: p99.9 quants
def test_default_percentiles_include_p999():
    assert 99.9 in DEFAULT_QS
    pct = latency_percentiles([float(i) for i in range(1000)])
    assert 99.9 in pct and pct[99.9] > pct[99.0]
    res = simulate("a2ws", SimConfig(
        speeds=(1.0, 1.0), num_tasks=50, task_cost=0.01,
        arrival="poisson", arrival_rate=100.0,
    ))
    assert "p99.9" in res.summary()
    assert 99.9 in res.latency_percentiles()


# ------------------------------------------- no-SLO degenerate: bit-for-bit
def _sim_equal(a, b):
    assert b.makespan == a.makespan
    assert b.per_node_tasks == a.per_node_tasks
    assert b.per_node_busy == a.per_node_busy
    assert b.records == a.records
    assert b.latencies == a.latencies
    assert b.steal_log == a.steal_log
    assert (b.steals, b.failed_steals, b.moved_tasks, b.boundaries) == (
        a.steals, a.failed_steals, a.moved_tasks, a.boundaries
    )


def _slo_off_variants(cfg: SimConfig, n: int):
    """Configs that must be indistinguishable from the bare scheduler: SLO
    telemetry without ordering, and ordering over an all-batch trace with
    no aging (every pop degenerates to the plain LIFO choice)."""
    zeros = np.zeros(n, np.int8)
    return (
        cfg.with_(slo_trace=zeros, slo_order=False),
        cfg.with_(slo_trace=zeros, slo_order=True, slo_aging=math.inf),
    )


@pytest.mark.parametrize("conf,seed", [("C1", 0), ("C4", 3)])
def test_sim_no_slo_degenerate_bit_for_bit_flat(conf, seed):
    cfg = SimConfig(
        speeds=table2_speeds(conf), num_tasks=140, seed=seed,
        arrival="poisson", arrival_rate=40.0, task_cost=1.0,
    )
    bare = simulate("a2ws", cfg)
    for variant in _slo_off_variants(cfg, 140):
        res = simulate("a2ws", variant)
        _sim_equal(bare, res)
        assert res.slo_violations == {"batch": 0, "latency": 0}
    assert bare.slo_latencies == {} and bare.scale_log == []


@pytest.mark.parametrize("seed", [0, 37])
def test_sim_no_slo_degenerate_bit_for_bit_hierarchical(seed):
    p = 16
    cfg = SimConfig(
        speeds=table2_speeds("C4")[:p], num_tasks=160, seed=seed,
        arrival="poisson", arrival_rate=30.0, task_cost=1.0,
    )
    bare = simulate(HierarchicalA2WSPolicy(p), cfg)
    for variant in _slo_off_variants(cfg, 160):
        _sim_equal(bare, simulate(HierarchicalA2WSPolicy(p), variant))


@given(seed=st.integers(0, 2**16), tasks=st.integers(40, 160))
@settings(max_examples=12, deadline=None)
def test_property_sim_no_slo_degenerate_is_identity(seed, tasks):
    """Property-tested conformance over arbitrary seeds/sizes: an all-batch
    SLO trace with no deadlines hit and no aging can NEVER perturb the
    scheduler — plans, rng streams and whole-run telemetry included."""
    cfg = SimConfig(
        speeds=table2_speeds("C4")[:16], num_tasks=tasks, seed=seed,
        arrival="poisson", arrival_rate=50.0, task_cost=1.0,
    )
    bare = simulate("a2ws", cfg)
    for variant in _slo_off_variants(cfg, tasks):
        _sim_equal(bare, simulate("a2ws", variant))


def _crafted_plans(policy, p, seed, slo):
    """Deterministic boundary plans from a constructed (never started) pool
    with crafted imbalance (mirrors tests/test_netfault.py)."""
    pool = WorkerPool(
        list(range(p * 5)), p, lambda w, t: None, policy=policy, seed=seed,
        slo=slo,
    )
    for i in (0, p // 2):
        w = pool.workers[i]
        while w.deque.get_task() is not None:
            pass
    now = pool.clock()
    for i, w in enumerate(pool.workers):
        w.executed, w.runtime_sum, w.ran_any = 5, 5 * 0.05, True
        w.start_time = now - 1e-3
        pool._update_info(i)
    for i in range(p):
        pool.info.communicate(i)
    plans = []
    for i in range(p):
        plan = pool.policy.on_boundary(pool._make_view(i))
        plans.append(
            None if plan is None else
            (plan.victim, plan.amount, plan.criterion, plan.delay, plan.work)
        )
    return plans


@pytest.mark.parametrize("policy", ["a2ws", "ha2ws"])
@pytest.mark.parametrize("p,seed", [(5, 7), (24, 1234)])
def test_threaded_plans_bit_for_bit_under_slo_pops(policy, p, seed):
    """Conformance, threaded plane: enabling SLO-ordered pops over plain
    payloads produces IDENTICAL boundary plans — same victims, amounts,
    criteria, delays, work targets, same rng stream."""
    assert _crafted_plans(policy, p, seed, False) == \
        _crafted_plans(policy, p, seed, True)


# ------------------------------------------------- SLO ordering improves tail
def test_sim_slo_ordering_improves_latency_tail_batch_within_noise():
    """Cross-plane conformance, sim side: under an overloaded bursty trace,
    SLO ordering improves the latency-class p99 while total makespan (the
    batch-class completion bound) stays within noise."""
    arr, slo = diurnal_trace(
        8000, mean_rate=120.0, period=120.0, depth=0.6, spikes=2,
        spike_amp=1.5, spike_width=6.0, latency_frac=0.25, seed=3,
    )
    base = dict(
        speeds=(1.0,) * 4, num_tasks=len(arr), task_cost=0.03,
        arrival="trace", arrival_trace=arr, slo_trace=slo,
        slo_deadlines=(30.0, 0.5), seed=1,
    )
    off = simulate("a2ws", SimConfig(**base, slo_order=False))
    on = simulate("a2ws", SimConfig(**base, slo_order=True, slo_aging=10.0))
    p99_off = float(np.percentile(off.slo_latencies["latency"], 99.0))
    p99_on = float(np.percentile(on.slo_latencies["latency"], 99.0))
    assert p99_on < p99_off
    assert on.makespan == pytest.approx(off.makespan, rel=0.05)
    assert sum(on.per_node_tasks) == len(arr)
    vr = on.slo_violation_rate()
    assert vr["latency"] <= off.slo_violation_rate()["latency"]
    assert "slo[" in on.summary()


def test_threaded_slo_ordering_latency_jumps_batch_edf_within_class():
    """Cross-plane conformance, threaded side: with the worker held busy,
    queued latency-class Tasks are served before earlier-queued batch
    Tasks, EDF within the latency class."""
    import threading

    order: list[int] = []
    gate = threading.Event()
    started = threading.Event()

    def task_fn(wid: int, task: Task) -> None:
        if task.id == -100:
            started.set()
            assert gate.wait(5.0)
            return
        order.append(task.id)

    pool = WorkerPool(
        [], 1, task_fn, open_arrival=True, slo=True, seed=0,
    )
    pool.start()
    try:
        pool.submit(Task(id=-100), worker=0)
        assert started.wait(5.0)
        # queued while the worker is busy: two latency (EDF inverted vs
        # submit order) between batch tasks
        pool.submit(Task(id=1, slo=SLO_BATCH), worker=0)
        pool.submit(Task(id=2, slo=SLO_LATENCY, deadline=50.0), worker=0)
        pool.submit(Task(id=3, slo=SLO_LATENCY, deadline=10.0), worker=0)
        pool.submit(Task(id=4, slo=SLO_BATCH), worker=0)
        gate.set()
        pool.drain()
        stats = pool.join()
    finally:
        gate.set()
    assert order == [3, 2, 4, 1]  # EDF latency first; batch LIFO after
    slo_stats = stats.slo_stats()
    assert slo_stats["latency"]["count"] == 2.0


# ------------------------------------------------------------- sim autoscale
def test_sim_autoscale_validations():
    ok = SimConfig(speeds=(1.0,), num_tasks=10, arrival="poisson",
                   arrival_rate=5.0)
    with pytest.raises(ValueError):
        simulate("a2ws", ok.with_(
            arrival="closed",
            autoscale=SimAutoscale(reserve=(1.0,)),
        ))
    with pytest.raises(ValueError):
        simulate("a2ws", ok.with_(
            joins=((1.0, 1.0),), autoscale=SimAutoscale(reserve=(1.0,)),
        ))
    with pytest.raises(ValueError):
        simulate("a2ws", ok.with_(autoscale=SimAutoscale(reserve=())))
    with pytest.raises(ValueError):
        simulate("a2ws", ok.with_(
            autoscale=SimAutoscale(reserve=(1.0,), mode="psychic"),
        ))
    with pytest.raises(ValueError):
        simulate("a2ws", ok.with_(slo_trace=(0,) * 3))  # length mismatch
    with pytest.raises(ValueError):
        simulate("a2ws", ok.with_(slo_trace=(0,) * 10, slo_deadlines=(0.0, 1.0)))
    with pytest.raises(ValueError):
        simulate("a2ws", ok.with_(slo_trace=(2,) * 10))
    with pytest.raises(ValueError):
        simulate("a2ws", ok.with_(slo_aging=0.0))


def _burst_trace(n: int, rate: float) -> np.ndarray:
    rng = np.random.default_rng(5)
    return np.cumsum(rng.exponential(1.0 / rate, n))


@pytest.mark.parametrize("mode", ["threshold", "predictive"])
def test_sim_autoscale_scales_out_under_overload_and_completes(mode):
    n = 3000
    arr = _burst_trace(n, 60.0)  # 2 nodes x 20/s: 3x overloaded
    res = simulate("a2ws", SimConfig(
        speeds=(1.0, 1.0), num_tasks=n, task_cost=0.05,
        arrival="trace", arrival_trace=arr, seed=0,
        autoscale=SimAutoscale(reserve=(1.0, 1.0, 1.0), interval=0.5,
                               mode=mode),
    ))
    assert sum(res.per_node_tasks) == n
    outs = [e for e in res.scale_log if e[1] == "out"]
    assert outs, f"{mode} scaler never activated a reserve under overload"
    assert "scale[" in res.summary()
    # reserves actually served work
    assert sum(res.per_node_tasks[2:]) > 0


def test_sim_autoscale_none_is_bit_for_bit_off():
    n = 400
    arr = _burst_trace(n, 30.0)
    cfg = SimConfig(speeds=(1.0, 1.0), num_tasks=n, task_cost=0.02,
                    arrival="trace", arrival_trace=arr, seed=2)
    _sim_equal(simulate("a2ws", cfg), simulate("a2ws", cfg))  # determinism
    assert simulate("a2ws", cfg).scale_log == []


# ------------------------------------------------------------- trace generator
def test_diurnal_trace_deterministic_sorted_exact_n():
    a1, s1 = diurnal_trace(5000, mean_rate=80.0, period=120.0, seed=11)
    a2, s2 = diurnal_trace(5000, mean_rate=80.0, period=120.0, seed=11)
    assert np.array_equal(a1, a2) and np.array_equal(s1, s2)
    assert a1.shape == s1.shape == (5000,)
    assert bool((np.diff(a1) >= 0.0).all())
    assert a1.dtype == np.float64 and s1.dtype == np.int8
    assert set(np.unique(s1)) <= {0, 1}
    frac = float(s1.mean())
    assert 0.15 < frac < 0.35  # latency_frac default 0.25
    a3, _ = diurnal_trace(5000, mean_rate=80.0, period=120.0, seed=12)
    assert not np.array_equal(a1, a3)


def test_diurnal_trace_validation_and_roundtrip(tmp_path):
    for bad in (
        dict(n=0), dict(mean_rate=-1.0), dict(depth=1.0),
        dict(latency_frac=2.0), dict(spike_width=0.0),
    ):
        with pytest.raises(ValueError):
            diurnal_trace(**{"n": 100, **bad})
    arr, slo = diurnal_trace(300, mean_rate=50.0, period=60.0, seed=0)
    path = str(tmp_path / "t.npz")
    save_trace(path, arr, slo)
    a2, s2 = load_trace(path)
    assert np.array_equal(arr, a2) and np.array_equal(slo, s2)
    with pytest.raises(ValueError):
        save_trace(path, arr, slo[:-1])


def test_diurnal_trace_feeds_simulator_directly():
    arr, slo = diurnal_trace(2000, mean_rate=100.0, period=60.0, seed=4)
    res = simulate("a2ws", SimConfig(
        speeds=(1.0,) * 4, num_tasks=len(arr), task_cost=0.02,
        arrival="trace", arrival_trace=arr, slo_trace=slo,
        slo_order=True, slo_deadlines=(30.0, 0.5), seed=0,
    ))
    assert sum(res.per_node_tasks) == 2000
    counts = {k: len(v) for k, v in res.slo_latencies.items()}
    assert counts["latency"] == int(slo.sum())
    assert counts["batch"] == 2000 - int(slo.sum())


# ------------------------------------------------------------------ serve SLO
def _echo_replicas(k: int) -> list[Replica]:
    return [
        Replica(name=f"r{i}", generate=lambda req: {"ok": True})
        for i in range(k)
    ]


def test_serve_submit_slo_kwargs_and_stats():
    pool = ServePool(_echo_replicas(2), slo_order=True, slo_aging=5.0)
    pool.start()
    futs = []
    for i in range(6):
        futs.append(pool.submit(
            {"i": i},
            slo_class="latency" if i % 3 == 0 else "batch",
            deadline=30.0 if i % 3 == 0 else None,
        ))
    for f in futs:
        assert f.result(10.0) == {"ok": True}
    lat = [f for f in futs if f.slo_class == SLO_LATENCY]
    assert len(lat) == 2
    assert all(math.isfinite(f.deadline) for f in lat)
    assert all(f.deadline > f.submit_t for f in lat)
    stats = pool.shutdown()
    slo = stats.slo_stats()
    assert slo["latency"]["count"] == 2.0
    assert slo["batch"]["count"] == 4.0
    assert slo["latency"]["violations"] == 0.0
    assert "slo[" in stats.summary() and "p99.9" in stats.summary()


def test_serve_submit_slo_validation():
    pool = ServePool(_echo_replicas(1))
    pool.start()
    try:
        with pytest.raises(ValueError):
            pool.submit({}, slo_class="gold")
        with pytest.raises(ValueError):
            pool.submit({}, slo_class=7)
        with pytest.raises(ValueError):
            pool.submit({}, deadline=0.0)
        with pytest.raises(ValueError):
            ServePool(_echo_replicas(1), slo_aging=-1.0)
        assert SLO_NAMES[pool.submit({}).slo_class] == "batch"
    finally:
        pool.shutdown()

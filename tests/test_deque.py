"""Asynchronous-theft deque (paper §2.3): packed word, Fig. 3b protocol,
no-loss/no-duplication under concurrency."""

import threading

import pytest

from _hypo import given, settings, st  # skips properties w/o hypothesis

from repro.core.deque import AtomicInt64, TaskDeque, pack, unpack


@given(st.integers(-1000, 10_000), st.integers(-1000, 10_000))
@settings(max_examples=200, deadline=None)
def test_pack_unpack_roundtrip(h, t):
    assert unpack(pack(h, t)) == (h, t)


def test_get_accumulate_semantics():
    a = AtomicInt64(pack(0, 10))
    old = a.get_accumulate(-3)  # claim 3 tail slots in ONE atomic op
    assert unpack(old) == (0, 10)
    assert unpack(a.load()) == (0, 7)


def test_owner_pops_head_in_order():
    d = TaskDeque(["a", "b", "c"])
    assert [d.get_task(), d.get_task(), d.get_task()] == ["a", "b", "c"]
    assert d.get_task() is None


def test_steal_takes_tail():
    d = TaskDeque([0, 1, 2, 3, 4])
    res = d.steal(2)
    assert res.tasks == [3, 4]  # tail end
    assert res.adjusted == 2 and not res.corrected
    assert len(d) == 3
    assert d.get_task() == 0


def test_steal_overdraft_occasional_correction():
    # Fig. 3b dashed arrow: thief asked for more than available.
    d = TaskDeque([0, 1, 2])
    res = d.steal(5)
    assert res.tasks == [0, 1, 2]
    assert res.corrected and res.adjusted == 3
    assert len(d) == 0
    assert d.get_task() is None  # victim sees empty (tail<head fixed up)


def test_steal_empty_full_correction():
    d = TaskDeque([])
    res = d.steal(4)
    assert not res and res.corrected
    assert len(d) == 0


def test_push_head_side():
    d = TaskDeque([1, 2])
    d.push([10, 11])
    assert d.get_task() == 10  # new tasks come off the head first
    assert d.get_task() == 11
    assert d.get_task() == 1


def test_snapshot_telemetry():
    d = TaskDeque([0, 1, 2, 3])
    res = d.steal(1)
    assert (res.observed_head, res.observed_tail) == (0, 4)


@pytest.mark.parametrize("thieves", [1, 2, 4])
def test_concurrent_no_loss_no_dup(thieves):
    """Owner pops while thieves steal: every task runs exactly once."""
    n = 400
    d = TaskDeque(range(n))
    got: list[list] = [[] for _ in range(thieves + 1)]
    stop = threading.Event()

    def owner():
        while True:
            t = d.get_task()
            if t is None:
                if stop.is_set():
                    return
                continue
            got[0].append(t)

    def thief(k):
        while not stop.is_set():
            res = d.steal(3)
            got[k].append(res.tasks)

    th = [threading.Thread(target=owner)]
    th += [threading.Thread(target=thief, args=(k,)) for k in range(1, thieves + 1)]
    for t in th:
        t.start()
    while len(d):
        pass
    stop.set()
    for t in th:
        t.join()
    all_tasks = list(got[0])
    for k in range(1, thieves + 1):
        for chunk in got[k]:
            all_tasks.extend(chunk)
    assert sorted(all_tasks) == list(range(n))  # no loss, no duplication


@given(
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("get")),
            st.tuples(st.just("steal"), st.integers(1, 5)),
            st.tuples(st.just("push"), st.integers(1, 3)),
        ),
        max_size=60,
    )
)
@settings(max_examples=60, deadline=None)
def test_sequential_op_sequences_conserve(ops):
    """Any interleaving of get/steal/push keeps the task multiset intact."""
    d = TaskDeque(range(10))
    seen = []
    nxt = 100
    expected = set(range(10))
    for op in ops:
        if op[0] == "get":
            t = d.get_task()
            if t is not None:
                seen.append(t)
        elif op[0] == "steal":
            seen.extend(d.steal(op[1]).tasks)
        else:
            new = list(range(nxt, nxt + op[1]))
            nxt += op[1]
            expected.update(new)
            d.push(new)
    while True:
        t = d.get_task()
        if t is None:
            break
        seen.append(t)
    assert sorted(seen) == sorted(expected)
    assert len(d) == 0

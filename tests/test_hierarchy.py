"""Two-level hierarchical A2WS (DESIGN.md §Hierarchy): cell topology units,
the K=1 bit-for-bit degenerate guarantee (plans AND whole-sim telemetry),
sub-board remapping properties under join/migrate churn, hierarchical runs
in both planes (conservation, elasticity, weighted overlay), cross-plane
inter-cell steal conformance, and the slow P=512 acceptance sweep."""

import threading
import time

import numpy as np
import pytest

from _hypo import given, settings, st
from repro.core.a2ws import WorkerPool
from repro.core.info_ring import CellBoard, CellDigest, CellMap, DigestBoard
from repro.core.policy import HierarchicalA2WSPolicy, make_policy
from repro.core.simulator import SimConfig, simulate, table2_speeds


# ------------------------------------------------------------- CellMap units
def test_cellmap_default_topology_is_sqrt_p():
    cm = CellMap(64)
    assert cm.num_cells == 8
    assert CellMap(1).num_cells == 1
    assert CellMap(4, num_cells=9).num_cells == 4  # clamped to P


def test_cellmap_contiguous_split_covers_every_worker_once():
    cm = CellMap(13, num_cells=4)
    seen = []
    for c in range(cm.num_cells):
        mem = cm.members(c)
        for loc, g in enumerate(mem):
            assert cm.locate(g) == (c, loc)
            seen.append(g)
    assert sorted(seen) == list(range(13))
    # contiguous block split: each cell's ids are consecutive
    for c in range(cm.num_cells):
        mem = cm.members(c)
        assert mem == list(range(mem[0], mem[0] + len(mem)))


def test_cellmap_radius_override_and_full_cell_default():
    cm = CellMap(30, num_cells=3)  # cells of 10
    assert cm.radius_of(0) == 5  # full-cell window: slots // 2
    cm2 = CellMap(30, num_cells=3, radius=2)
    assert cm2.radius_of(0) == 2
    cm3 = CellMap(30, num_cells=3, radius=99)
    assert cm3.radius_of(0) == 5  # clamped to slots // 2


def test_cellmap_assign_dense_and_idempotent():
    cm = CellMap(6, num_cells=3)
    v0 = cm.version
    assert cm.assign(3) == cm.cell_of(3)  # already mapped: no-op
    assert cm.version == v0
    c = cm.assign(6)  # new id lands in a smallest live cell
    assert cm.cell_of(6) == c and cm.version == v0 + 1
    with pytest.raises(ValueError):
        cm.assign(99)  # joins must be dense


def test_cellmap_migrate_leaves_hole_and_appends():
    cm = CellMap(8, num_cells=2)
    old_cell, old_loc = cm.locate(1)
    assert old_cell == 0
    oc, nl = cm.migrate(1, 1)
    assert oc == 0 and cm.locate(1) == (1, nl)
    assert cm.members(0)[old_loc] == -1  # hole, slots stable
    assert cm.members(1)[-1] == 1
    assert cm.live_size(0) == 3 and cm.live_size(1) == 5
    # same-cell migrate is a no-op
    v = cm.version
    cm.migrate(1, 1)
    assert cm.version == v


# ---------------------------------------------------- CellBoard / DigestBoard
def test_cellboard_drops_cross_cell_records():
    cm = CellMap(8, num_cells=2)
    board = CellBoard(cm, num_classes=1)
    board.update_local(0, 3.0, 0.5, 2.0)
    board.record_remote(0, 1, 1.0, 0.5)  # same cell: lands
    assert board.dropped_remote == 0
    board.record_remote(0, 5, 1.0, 0.5)  # cross cell: dropped
    assert board.dropped_remote == 1
    assert np.isnan(board.belief_t(0, 5))
    assert board.belief_nc(0, 5) is None
    assert all(g < 4 for g in board.window(0))  # window stays intra-cell


def test_cellboard_window_and_peer_raw_t_return_global_ids():
    cm = CellMap(12, num_cells=3)  # cell 1 = ids 4..7
    board = CellBoard(cm, num_classes=1)
    win = board.window(5)
    assert 5 not in [g for g in win if g != 5] or True
    assert set(win) <= {4, 5, 6, 7}
    peers = board.peer_raw_t(5)
    assert all(g in {4, 6, 7} for g, _t in peers)


def test_digestboard_publish_seq_and_peers():
    db = DigestBoard(3)
    assert db.get(0) is None and db.peers(0) == []
    db.publish(CellDigest(0, 1.0, 5.0, 5.0, 4, 2, 3))
    db.publish(CellDigest(0, 2.0, 4.0, 4.0, 4, 2, 2))
    db.publish(CellDigest(2, 2.0, 9.0, 9.0, 4, 9, 4))
    assert db.get(0).seq == 2 and db.get(0).work == 4.0
    assert [d.cell for d in db.peers(0)] == [2]
    assert db.publishes == 3


# ------------------------------------------- remapping property (sub-boards)
@settings(max_examples=40, deadline=None)
@given(
    p0=st.integers(2, 10),
    k=st.integers(1, 4),
    ops=st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 63), st.integers(0, 3)),
        max_size=12,
    ),
    num_classes=st.integers(1, 3),
)
def test_cell_remapping_preserves_versions_and_epochs(p0, k, ops, num_classes):
    """Join/migrate/report churn on a CellBoard: the worker->slot mapping
    stays a bijection, per-cell RingInfo versions stay monotone across
    sub-board growth, and every live worker's view stays consistent with its
    cell's board epoch (rows == sub-board size, no index races)."""
    cm = CellMap(p0, num_cells=k)
    board = CellBoard(cm, num_classes=num_classes)
    next_id = p0

    def snapshot():
        return [b.version.copy() for b in board.boards]

    def check(before):
        # mapping is a bijection over live ids
        seen = []
        for c in range(cm.num_cells):
            for loc, g in enumerate(cm.members(c)):
                if g >= 0:
                    assert cm.locate(g) == (c, loc)
                    seen.append(g)
        assert len(seen) == len(set(seen))
        for c in range(cm.num_cells):
            b = board.boards[c]
            assert b.P >= cm.slots(c) or cm.slots(c) == 0
            # version monotonicity across growth: the carried-over block
            # never moves backwards
            old = before[c]
            assert (b.version[: old.shape[0], : old.shape[1]] >= old).all()
        for g in seen:
            n, t, *_rest = board.view_window_all(g)
            c, _loc = cm.locate(g)
            assert len(n) == board.boards[c].P == len(t)

    for op, a, b_ in ops:
        before = snapshot()
        ver = cm.version
        if op == 0:  # elastic join (dense ids), substrate grows the board
            cm.assign(next_id)
            board.ensure(next_id)
            next_id += 1
            assert cm.version == ver + 1
        elif op == 1:  # leader-level member migration
            w = a % next_id
            board.migrate(w, b_ % cm.num_cells)
        else:  # ordinary report traffic
            w = a % next_id
            board.update_local(w, float(b_), 0.5, float(b_))
            board.communicate(w)
        check(before)


# --------------------------------------------- K=1 degenerate: plan equality
def _crafted_plans(policy, p, seed, num_classes):
    """Deterministic boundary plans from a constructed (never started) pool
    with crafted imbalance: workers seed//? drained, everyone else queued."""
    kw = {}
    if num_classes > 1:
        kw = dict(cost_class_fn=lambda t: t % num_classes,
                  num_classes=num_classes)
    pool = WorkerPool(
        list(range(p * 5)), p, lambda w, t: None, policy=policy, seed=seed,
        **kw,
    )
    for i in (0, p // 2):
        w = pool.workers[i]
        while w.deque.get_task() is not None:
            pass
    now = pool.clock()
    for i, w in enumerate(pool.workers):
        w.executed, w.runtime_sum, w.ran_any = 5, 5 * 0.05, True
        if num_classes > 1:
            w.class_t[:] = 0.04 + 0.01 * np.arange(num_classes)
        w.start_time = now - 1e-3
        pool._update_info(i)
    for i in range(p):
        pool.info.communicate(i)
    plans = []
    for i in range(p):
        plan = pool.policy.on_boundary(pool._make_view(i))
        plans.append(
            None if plan is None else
            (plan.victim, plan.amount, plan.criterion, plan.delay, plan.work)
        )
    return plans, pool.radius


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(2, 24),
    seed=st.integers(0, 10_000),
    num_classes=st.sampled_from([1, 3]),
)
def test_k1_threaded_plans_bit_for_bit_flat(p, seed, num_classes):
    """With num_cells=1 and the cell radius pinned to the flat Eq. 5 radius,
    the hierarchical policy's boundary plans are IDENTICAL to flat A2WS —
    same victims, amounts, criteria, work targets, same rng stream."""
    flat_plans, radius = _crafted_plans("a2ws", p, seed, num_classes)
    hier = HierarchicalA2WSPolicy(p, num_cells=1, cell_radius=radius)
    hier_plans, _ = _crafted_plans(hier, p, seed, num_classes)
    assert hier_plans == flat_plans
    assert any(x is not None for x in flat_plans) or p <= 3


# --------------------------------------- K=1 degenerate: whole-sim telemetry
def _k1_policy_for(cfg, p):
    r = cfg.radius if cfg.radius is not None else max(1, round(0.2 * p))
    return HierarchicalA2WSPolicy(p, num_cells=1, cell_radius=min(r, p // 2))


@settings(max_examples=12, deadline=None)
@given(
    conf=st.sampled_from(["C1", "C4"]),
    seed=st.integers(0, 50),
    tasks=st.integers(60, 200),
    weighted=st.booleans(),
)
def test_k1_sim_telemetry_bit_for_bit_flat(conf, seed, tasks, weighted):
    """Whole-run virtual-time telemetry — makespan, per-node task counts and
    busy time, every (node, start, end) record, steal counters — is
    bit-for-bit identical between flat a2ws and the K=1 hierarchy."""
    kw = {}
    if weighted:
        kw = dict(class_cost=(1.0, 3.0), class_probs=(0.7, 0.3))
    speeds = table2_speeds(conf)
    cfg = SimConfig(speeds=speeds, num_tasks=tasks, seed=seed, **kw)
    flat = simulate("a2ws", cfg)
    k1 = simulate(_k1_policy_for(cfg, len(speeds)), cfg)
    assert k1.makespan == flat.makespan
    assert k1.per_node_tasks == flat.per_node_tasks
    assert k1.per_node_busy == flat.per_node_busy
    assert k1.records == flat.records
    assert (k1.steals, k1.failed_steals, k1.moved_tasks, k1.boundaries) == (
        flat.steals, flat.failed_steals, flat.moved_tasks, flat.boundaries
    )


def test_k1_sim_telemetry_equal_under_churn_and_limp():
    """The degenerate guarantee holds with the OTHER overlays live too:
    elastic join/retire and a scripted slowdown with limp detection."""
    from repro.core import LimpConfig, SlowdownEvent

    speeds = table2_speeds("C1")
    p = len(speeds)
    cfg = SimConfig(
        speeds=speeds, num_tasks=300, seed=4,
        joins=((5.0, 1.0),), retires=((9.0, 1),),
        slowdowns=(SlowdownEvent(0, 2.0, 8.0),), limp=LimpConfig(),
    )
    flat = simulate("a2ws", cfg)
    k1 = simulate(_k1_policy_for(cfg, p), cfg)  # joiner homed at join time
    assert k1.makespan == flat.makespan
    assert k1.records == flat.records
    assert k1.limp_events == flat.limp_events


# ------------------------------------------------- hierarchical runs, threaded
def test_threaded_hierarchical_conservation():
    done, lock = [], threading.Lock()

    def fn(w, t):
        time.sleep(0.0005)
        with lock:
            done.append(t)

    pol = HierarchicalA2WSPolicy(6, num_cells=3)
    pool = WorkerPool(list(range(300)), 6, fn, policy=pol, seed=0)
    stats = pool.run()
    assert sorted(done) == list(range(300))
    assert sum(stats.per_worker_tasks) == 300


def test_threaded_hierarchical_weighted_conservation():
    pol = HierarchicalA2WSPolicy(6, num_cells=2)
    pool = WorkerPool(
        list(range(240)), 6, lambda w, t: time.sleep(0.0005), policy=pol,
        seed=2, cost_class_fn=lambda t: t % 3, num_classes=3,
    )
    stats = pool.run()
    assert sum(stats.per_worker_tasks) == 240


def test_threaded_hierarchical_elastic_join_retire():
    """Elastic membership under the hierarchy: a joiner is homed to the
    smallest live cell and serves tasks; a retiree's queue survives via
    drain.  Every task runs exactly once."""
    done, lock = [], threading.Lock()

    def fn(w, t):
        time.sleep(0.002)
        with lock:
            done.append(t)

    pol = HierarchicalA2WSPolicy(4, num_cells=2)
    pool = WorkerPool([], 4, fn, policy=pol, open_arrival=True, seed=0)
    pool.start()
    pool.submit_many(range(40), worker=0)
    wid = pool.add_worker()
    assert wid == 4
    assert pol.cells.cell_of(wid) in (0, 1)
    assert pol.cells.live_size(pol.cells.cell_of(wid)) == 3
    pool.submit_many(range(40, 80))
    time.sleep(0.05)
    pool.retire_worker(1, drain=True)
    pool.submit_many(range(80, 100))
    pool.drain()
    stats = pool.join()
    assert sorted(done) == list(range(100))
    assert stats.per_worker_tasks[wid] > 0


def test_servepool_runs_hierarchical_policy():
    """The third plane: ServePool's continuous batching balances replica
    deques through the hierarchical policy unchanged."""
    from repro.serve.engine import Replica, ServePool

    pol = HierarchicalA2WSPolicy(4, num_cells=2)
    reps = [Replica(f"r{i}", lambda r: {"ok": True}) for i in range(4)]
    pool = ServePool(reps, policy=pol, seed=0)
    futs = [pool.submit({"i": i}) for i in range(40)]
    outs = [f.result(timeout=30) for f in futs]
    pool.shutdown()
    assert len(outs) == 40 and all(o["ok"] for o in outs)


def test_make_policy_spec():
    pol = make_policy("ha2ws", 16)
    assert isinstance(pol, HierarchicalA2WSPolicy)
    assert pol.cells.num_workers == 16


# ------------------------------------------------- hierarchical runs, sim
def test_sim_hierarchical_conservation_and_planes():
    speeds = table2_speeds("C4")
    p = len(speeds)
    cfg = SimConfig(speeds=speeds, num_tasks=960, seed=0)
    h = HierarchicalA2WSPolicy(p, num_cells=8)
    res = simulate(h, cfg)
    assert sum(res.per_node_tasks) == 960
    assert res.boundaries > 0


def test_sim_hierarchical_elastic_churn():
    speeds = table2_speeds("C1")
    cfg = SimConfig(
        speeds=speeds, num_tasks=600, seed=9,
        joins=((10.0, 1.0), (20.0, 0.5)), retires=((30.0, 2),),
    )
    h = HierarchicalA2WSPolicy(len(speeds), num_cells=4)
    res = simulate(h, cfg)
    assert sum(res.per_node_tasks) == 600
    # the joiners were homed (version bumps) and appear in the map
    assert h.cells.num_workers == len(speeds) + 2


# ------------------------------------- cross-plane inter-cell steal conformance
def test_cross_plane_xcell_steal_conformance():
    """Both planes agree on WHEN the leader plane engages: a half-fast /
    half-slow pool (cell 1 surplus in work-seconds) fires inter-cell steals
    in the simulator AND the threaded pool; a homogeneous balanced pool
    fires (essentially) none.  Exact volumes differ across planes — thread
    timing is real — so the conformance bound is an order-of-engagement,
    not an equality."""
    p = 16
    skew = tuple([8.0] * 8 + [0.5] * 8)
    cfg = SimConfig(speeds=skew, num_tasks=p * 30, seed=0, task_cost=1.0)
    hs = HierarchicalA2WSPolicy(p, num_cells=2)
    rs = simulate(hs, cfg)
    assert sum(rs.per_node_tasks) == p * 30
    assert hs.xcell_steals >= 3, "sim skew must engage the leader plane"

    hb = HierarchicalA2WSPolicy(p, num_cells=2)
    simulate(hb, SimConfig(speeds=(1.0,) * p, num_tasks=p * 30, seed=0,
                           task_cost=1.0))
    assert hb.xcell_steals == 0, "sim balanced pool must not ping-pong loot"

    def run_threaded(slow_half):
        pol = HierarchicalA2WSPolicy(8, num_cells=2)
        def fn(w, t):
            time.sleep(0.004 if (slow_half and w >= 4) else 0.0005)
        stats = WorkerPool(
            list(range(240)), 8, fn, policy=pol, seed=1
        ).run()
        assert sum(stats.per_worker_tasks) == 240
        return pol.xcell_steals

    skew_steals = run_threaded(True)
    bal_steals = run_threaded(False)
    assert skew_steals >= 3, "threaded skew must engage the leader plane"
    assert bal_steals <= skew_steals // 2, (
        f"balanced ({bal_steals}) should engage far less than skew "
        f"({skew_steals})"
    )


# ---------------------------------------------------- P=512 acceptance (slow)
@pytest.mark.slow
def test_p512_hierarchy_beats_flat_makespan_and_overhead():
    """The ISSUE acceptance run: at P=512 in the short-task regime the
    hierarchy wins BOTH the makespan and the mean per-boundary view/steal
    overhead (wall time per boundary — the O(cell) vs O(P) hot path)."""
    p = 512
    speeds = tuple(np.tile(table2_speeds("C4"), p // 64))
    cfg = SimConfig(speeds=speeds, num_tasks=p * 3, seed=0, task_cost=2.0)
    t0 = time.perf_counter()
    flat = simulate("a2ws", cfg)
    flat_wall = time.perf_counter() - t0
    h = HierarchicalA2WSPolicy(p)
    t0 = time.perf_counter()
    hier = simulate(h, cfg)
    hier_wall = time.perf_counter() - t0
    assert sum(hier.per_node_tasks) == p * 3
    assert hier.makespan < flat.makespan
    assert (hier_wall / hier.boundaries) < 0.5 * (flat_wall / flat.boundaries)


@pytest.mark.slow
def test_p512_k_rho_sweep_conserves_and_stays_cheap():
    """K×ρ sweep at P=512: every cell shape conserves tasks and keeps the
    per-boundary hot path an order of magnitude under the flat O(P) cost
    (~15 ms/boundary measured for flat at this size)."""
    p = 512
    speeds = tuple(np.tile(table2_speeds("C4"), p // 64))
    cfg = SimConfig(speeds=speeds, num_tasks=p * 2, seed=1, task_cost=2.0)
    for k in (8, 23, 64):
        h = HierarchicalA2WSPolicy(p, num_cells=k)
        t0 = time.perf_counter()
        res = simulate(h, cfg)
        wall = time.perf_counter() - t0
        assert sum(res.per_node_tasks) == p * 2, f"K={k} lost tasks"
        assert wall / res.boundaries < 5e-3, f"K={k} hot path regressed"

"""Elastic membership (DESIGN.md §Elasticity): runtime worker join/retire on
the live pool, for every policy, in BOTH planes (threaded WorkerPool and the
discrete-event simulator), plus the ServePool elastic API and autoscaler."""

import threading
import time

import numpy as np
import pytest

from repro.core.a2ws import WorkerPool
from repro.core.policy import POLICIES
from repro.core.simulator import SimConfig, simulate
from repro.serve.engine import AutoscaleConfig, Replica, ServePool


# -------------------------------------------------- threaded plane, per policy
@pytest.mark.parametrize("policy", list(POLICIES))
def test_threaded_join_and_retire_open_arrival(policy):
    """A worker joins the live open-arrival pool mid-run and serves part of
    the workload through the ordinary steal path; a worker retires with
    drain=True and its queue survives.  Every task executes exactly once."""
    done, lock = [], threading.Lock()

    def task_fn(wid, task):
        time.sleep(0.002)
        with lock:
            done.append(task)

    pool = WorkerPool([], 2, task_fn, policy=policy, open_arrival=True, seed=0)
    pool.start()
    pool.submit_many(range(30), worker=0)  # backlog on worker 0
    wid = pool.add_worker()
    assert wid == 2
    pool.submit_many(range(30, 60))
    time.sleep(0.05)
    pool.retire_worker(1, drain=True)
    pool.submit_many(range(60, 80))
    pool.drain()
    stats = pool.join()
    assert sorted(done) == list(range(80))
    assert sum(stats.per_worker_tasks) == 80
    assert stats.per_worker_tasks[wid] > 0, "joiner never served a task"
    assert pool.dead[1] and not pool.dead[0] and not pool.dead[wid]
    kinds = [(k, w) for _, k, w in pool.membership_log]
    assert ("join", 2) in kinds and ("retire", 1) in kinds


@pytest.mark.parametrize("policy", list(POLICIES))
def test_threaded_join_closed_workload(policy):
    """Elasticity is not open-arrival-only: a joiner entering a CLOSED run
    steals from the static partition and shortens the tail.  (Sleep-based
    tasks: GIL-free, so thread scheduling stays fair on small CI boxes.)"""
    n = 48

    def task_fn(wid, task):
        time.sleep(0.004)

    pool = WorkerPool(list(range(n)), 2, task_fn, policy=policy, seed=1)
    pool.start()
    time.sleep(0.02)
    wid = pool.add_worker()
    stats = pool.join()
    assert sum(stats.per_worker_tasks) == n
    assert stats.per_worker_tasks[wid] > 0, "closed-mode joiner never served"


def test_retire_without_drain_leaves_tasks_stealable():
    """drain=False is the fault path minus the crash: the queue stays on the
    tombstoned deque and thieves reclaim it."""
    done, lock = [], threading.Lock()

    def task_fn(wid, task):
        time.sleep(0.001)
        with lock:
            done.append((wid, task))

    pool = WorkerPool([], 2, task_fn, policy="a2ws", open_arrival=True, seed=0)
    pool.start()
    pool.retire_worker(1, drain=False)
    deadline = time.time() + 5.0
    while not pool.dead[1] and time.time() < deadline:
        time.sleep(0.001)
    assert pool.dead[1]
    pool.submit_many(range(12), worker=1)  # pinned onto the tombstone
    pool.drain()
    stats = pool.join()
    assert sorted(t for _, t in done) == list(range(12))
    assert all(w == 0 for w, _ in done), "only the survivor may serve"
    assert sum(stats.per_worker_tasks) == 12


def test_collapse_sweep_reconciles_quiescence_for_resurrection():
    """Review fix: sweeping stranded tasks at total collapse must count them
    as resolved — otherwise pending() stays positive forever and a pool
    resurrected with add_worker() can never reach quiescence (join hangs)."""
    stranded, done, lock = [], [], threading.Lock()

    def task_fn(wid, task):
        if task == "die":
            raise RuntimeError("boom")
        time.sleep(0.001)
        with lock:
            done.append(task)

    pool = WorkerPool([], 2, task_fn, policy="random", open_arrival=True)
    pool.on_collapse = stranded.extend
    pool.start()
    pool.submit_many(["die", "die"])  # both workers crash; tasks re-queued
    deadline = time.time() + 5.0
    while pool.alive.load() > 0 and time.time() < deadline:
        time.sleep(0.001)
    assert pool.alive.load() == 0
    assert len(stranded) == 2  # the re-queued crashers were swept
    assert pool.pending() == 0, "swept tasks must reconcile the counters"
    # Resurrection: a replacement worker joins the collapsed pool and the
    # pool serves new work and terminates cleanly.
    wid = pool.add_worker()
    pool.submit_many(range(4))
    pool.drain()
    stats = pool.join()  # pre-fix: hangs forever (done can never catch up)
    assert sorted(done) == list(range(4))
    assert stats.per_worker_tasks[wid] == 4


def test_retiring_last_worker_collapses_pool():
    stranded_seen = []

    pool = WorkerPool([], 2, lambda w, t: time.sleep(0.001),
                      policy="random", open_arrival=True, seed=0)
    pool.on_collapse = stranded_seen.extend
    pool.start()
    pool.retire_worker(0)
    pool.retire_worker(1)
    deadline = time.time() + 5.0
    while pool.alive.load() > 0 and time.time() < deadline:
        time.sleep(0.001)
    assert pool.alive.load() == 0
    with pytest.raises(RuntimeError):
        pool.submit("x")
    pool.drain()
    pool.join()


def test_add_worker_recycles_tombstoned_slot():
    """Review fix (bounded elastic state): a replacement reuses the lowest
    fully-exited tombstone — inheriting its deque — instead of growing the
    ring forever; per-worker counters restart but records keep history."""
    done, lock = [], threading.Lock()

    def task_fn(wid, task):
        time.sleep(0.001)
        with lock:
            done.append((wid, task))

    pool = WorkerPool([], 3, task_fn, policy="a2ws", open_arrival=True, seed=0)
    pool.start()
    pool.retire_worker(1, drain=True)
    deadline = time.time() + 5.0
    while pool._slot_threads[1].is_alive() and time.time() < deadline:
        time.sleep(0.001)
    assert pool.dead[1]
    wid = pool.add_worker()
    assert wid == 1, "tombstoned slot must be recycled, not appended past"
    assert pool.num_workers == 3 and not pool.dead[1]
    assert pool.info.P == 3  # the ring did NOT grow
    # (the info-column reset to the unreported state is unit-tested in
    # test_info_ring.py — here live propagation re-fills it immediately)
    pool.submit_many(range(20), worker=1)
    pool.drain()
    pool.join()
    assert sorted(t for _, t in done) == list(range(20))
    assert any(w == 1 for w, _ in done), "replacement never served"
    joins = [(k, w) for _, k, w in pool.membership_log if k == "join"]
    assert ("join", 1) in [(k, w) for k, w in joins]


def test_autoscaler_surge_cycles_keep_ring_bounded():
    """Scale out -> drain back -> scale out again: the second surge recycles
    the drained slots, so the ring never outgrows max_replicas."""
    def gen(req):
        time.sleep(0.003)
        return {"ok": True}

    pool = ServePool(
        [Replica("r0", gen)],
        autoscale=AutoscaleConfig(
            factory=lambda wid: Replica(f"s{wid}", gen),
            min_replicas=1, max_replicas=3,
            high_pending_per_replica=3.0, idle_ticks_to_retire=2,
            interval=0.005,
        ),
    )
    pool.start()
    for _burst in range(2):
        futs = pool.submit_wave([{"x": k} for k in range(40)])
        for f in futs:
            f.result(timeout=30)
        deadline = time.time() + 5.0
        while len(pool.live_replicas()) > 1 and time.time() < deadline:
            time.sleep(0.01)
        assert pool.live_replicas() == [0]
    assert pool._runtime.num_workers <= 3, (
        f"ring grew to {pool._runtime.num_workers}: drained slots "
        "were not recycled across surges"
    )
    assert len(pool.replicas) <= 3
    pool.shutdown()


def test_add_worker_requires_started_pool_and_retire_validates():
    pool = WorkerPool([], 2, lambda w, t: None, open_arrival=True)
    with pytest.raises(RuntimeError):
        pool.add_worker()
    pool.start()
    with pytest.raises(ValueError):
        pool.retire_worker(7)
    pool.retire_worker(1)
    pool.retire_worker(1)  # idempotent
    pool.drain()
    pool.join()


def test_joiner_ring_radius_recomputed():
    """The paper's 20% radius operating point tracks the ELASTIC pool size
    unless the caller pinned a radius explicitly."""
    pool = WorkerPool([], 5, lambda w, t: None, policy="a2ws",
                      open_arrival=True)
    assert pool.radius == 1
    pool.start()
    for _ in range(6):
        pool.add_worker()
    assert pool.num_workers == 11
    assert pool.radius == 2
    assert pool.info.P == 11 and pool.info.R == 2
    pool.drain()
    pool.join()
    pinned = WorkerPool([], 5, lambda w, t: None, policy="a2ws", radius=1,
                        open_arrival=True)
    pinned.start()
    pinned.add_worker()
    assert pinned.radius == 1
    pinned.drain()
    pinned.join()


# --------------------------------------------------- simulated plane, per policy
@pytest.mark.parametrize("policy", list(POLICIES))
def test_sim_join_retire_closed(policy):
    """The same policy objects under virtual time: a joiner picks up a share
    of the closed workload, a retiree's remaining queue is drained, and the
    task count is conserved."""
    cfg = SimConfig(
        speeds=np.array([4.0, 1.0, 1.0]), num_tasks=60, task_cost=1.0,
        noise=0.0, seed=0, joins=((1.0, 4.0),), retires=((3.0, 1),),
    )
    res = simulate(policy, cfg)
    assert sum(res.per_node_tasks) == 60
    joiner = 3
    assert res.per_node_tasks[joiner] > 0, "simulated joiner never served"
    # the retiree freezes at whatever it finished by t=3 (its share of a
    # 10s-scale run) — the drained queue went to the survivors
    assert res.per_node_tasks[1] < 60 // 3


def test_sim_retire_before_join_rejected():
    """Review fix: a churn script retiring a node before it joins would be
    silently dropped by the tombstone guard — reject it up front."""
    cfg = SimConfig(
        speeds=np.array([1.0, 1.0]), num_tasks=10,
        joins=((10.0, 4.0),), retires=((5.0, 2),),
    )
    with pytest.raises(ValueError, match="precedes its join"):
        simulate("random", cfg)


@pytest.mark.parametrize("policy", list(POLICIES))
def test_sim_join_retire_poisson(policy):
    cfg = SimConfig(
        speeds=np.array([4.0, 1.0, 1.0]), num_tasks=80, task_cost=1.0,
        noise=0.0, seed=1, arrival="poisson", arrival_rate=0.6 * 6.0,
        joins=((2.0, 4.0),), retires=((6.0, 1),),
    )
    res = simulate(policy, cfg)
    assert sum(res.per_node_tasks) == 80
    assert len(res.latencies) == 80
    assert res.per_node_tasks[3] > 0


@pytest.mark.parametrize("policy", list(POLICIES))
def test_cross_plane_elastic_conformance(policy):
    """Join/retire through BOTH planes on one seeded workload shape: in each
    plane the joiner must take real work, the retiree must stop early, and
    work must still move (steal accounting stays live under churn)."""
    # -- simulated
    cfg = SimConfig(
        speeds=np.array([4.0, 1.0, 1.0, 1.0]), num_tasks=48, task_cost=0.012,
        noise=0.0, seed=0, hop_latency=1e-4, info_poll=1e-3,
        comm_cell_cost=0.0, steal_latency=5e-4, steal_per_task=1e-5,
        retry_interval=1e-3, token_base=1e-4, token_per_node=0.0,
        request_rtt=2e-4, leader_service=1e-4, leader_overhead=0.0,
        joins=((0.02, 4.0),), retires=((0.06, 1),),
    )
    sim = simulate(policy, cfg)
    assert sum(sim.per_node_tasks) == 48
    assert sim.per_node_tasks[4] > 0
    assert sim.moved_tasks > 0

    # -- threaded (same speeds: worker 0 fast, joiner fast).  Sleep-based
    # tasks keep the GIL out of the scheduling; the joiner enters with ~2/3
    # of the run left, so it must serve part of the workload in any fair
    # interleaving.
    speeds = [4.0, 1.0, 1.0, 1.0, 4.0]
    done, lock = [], threading.Lock()

    def task_fn(wid, task):
        time.sleep(0.012 / speeds[wid])
        with lock:
            done.append(task)

    pool = WorkerPool(list(range(48)), 4, task_fn, policy=policy, seed=0)
    pool.start()
    time.sleep(0.02)
    wid = pool.add_worker()
    pool.retire_worker(1, drain=True)
    stats = pool.join()
    assert sorted(done) == list(range(48))
    assert stats.per_worker_tasks[wid] > 0
    assert sum(s[3] for s in stats.steals) > 0, "threaded plane never stole"


# ----------------------------------------------------------------- ServePool
def test_servepool_add_and_retire_replica():
    served_by = {}
    lock = threading.Lock()

    def gen(req):
        time.sleep(0.002)
        with lock:
            served_by.setdefault(req["x"], []).append(True)
        return {"y": req["x"]}

    pool = ServePool([Replica("r0", gen), Replica("r1", gen)], seed=0)
    pool.start()
    futs = pool.submit_wave([{"x": k} for k in range(10)])
    wid = pool.add_replica(Replica("r2", gen))
    assert wid == 2 and len(pool.replicas) == 3
    futs += pool.submit_wave([{"x": k} for k in range(10, 30)])
    for f in futs:
        f.result(timeout=30)
    assert any(f.worker == wid for f in futs), "new replica never served"
    pool.retire_replica(1)
    # Retirement is asynchronous (the replica finishes its in-flight work
    # first) — wait for the tombstone before asserting exclusivity.
    deadline = time.time() + 5.0
    while not pool._runtime.dead[1] and time.time() < deadline:
        time.sleep(0.002)
    assert pool.live_replicas() == [0, 2]
    futs2 = pool.submit_wave([{"x": k} for k in range(30, 40)])
    for f in futs2:
        f.result(timeout=30)
    assert all(f.worker in (0, 2) for f in futs2)
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 40


def test_servepool_autoscaler_scales_out_and_back():
    """A burst on a 1-replica pool scales out to max_replicas, then the
    idle pool drains back to min_replicas."""
    def gen(req):
        time.sleep(0.004)
        return {"ok": True}

    pool = ServePool(
        [Replica("r0", gen)],
        autoscale=AutoscaleConfig(
            factory=lambda wid: Replica(f"s{wid}", gen),
            min_replicas=1, max_replicas=3,
            high_pending_per_replica=3.0, idle_ticks_to_retire=2,
            interval=0.005,
        ),
    )
    pool.start()
    futs = pool.submit_wave([{"x": k} for k in range(60)])
    for f in futs:
        f.result(timeout=30)
    assert pool.peak_live == 3, f"peak {pool.peak_live}, wanted full scale-out"
    assert sum(1 for e in pool.scale_events if e[1] == "out") >= 2
    deadline = time.time() + 5.0
    while len(pool.live_replicas()) > 1 and time.time() < deadline:
        time.sleep(0.01)
    assert pool.live_replicas() == [0], "idle pool never drained back"
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 60

"""MoE layer: expert-parallel dispatch vs the all-experts-dense oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import moe as moe_mod
from repro.models.layers import split


def _setup(cf=8.0, dtype=jnp.float32):
    cfg = get_smoke("moonshot-v1-16b-a3b")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=cf))
    leafs = moe_mod.moe_params(jax.random.key(0), cfg)
    params, _ = split(leafs)
    params = jax.tree.map(lambda x: x.astype(dtype) if x.dtype == jnp.bfloat16 else x, params)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), dtype) * 0.5
    return cfg, params, x


def test_dispatch_matches_dense_oracle():
    cfg, params, x = _setup()
    top_i, top_w, _ = moe_mod.route(params["router"], x, cfg.moe)
    got = moe_mod.moe_apply(params, x, top_i, top_w, cfg, ctx=None)
    want = moe_mod.moe_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_route_topk_properties():
    cfg, params, x = _setup()
    top_i, top_w, probs = moe_mod.route(params["router"], x, cfg.moe)
    assert top_i.shape == (2, 16, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(top_w.sum(-1)), 1.0, atol=1e-3)
    # indices are the true argmax set of the probs
    best = np.argsort(-np.asarray(probs), axis=-1)[..., : cfg.moe.top_k]
    assert set(np.asarray(top_i)[0, 0]) == set(best[0, 0])


def test_capacity_drops_under_tight_factor():
    """With a tiny capacity factor (cap -> 1 slot/expert) most tokens are
    dropped: the output departs from the dense oracle but stays finite, and
    some token rows are exactly zero (fully dropped)."""
    cfg, params, x = _setup(cf=1e-6)
    top_i, top_w, _ = moe_mod.route(params["router"], x, cfg.moe)
    got = np.asarray(moe_mod.moe_apply(params, x, top_i, top_w, cfg, ctx=None))
    want = np.asarray(moe_mod.moe_dense_ref(params, x, cfg))
    assert np.isfinite(got).all()
    assert not np.allclose(got, want, atol=1e-5)  # drops happened
    row_norms = np.abs(got).reshape(-1, got.shape[-1]).max(-1)
    assert (row_norms < 1e-7).sum() > 0  # some tokens fully dropped


def test_shared_expert_added():
    cfg = get_smoke("deepseek-v3-671b")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    leafs = moe_mod.moe_params(jax.random.key(0), cfg)
    params, _ = split(leafs)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    assert "ws1" in params  # deepseek smoke has 1 shared expert
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model)) * 0.5
    top_i, top_w, _ = moe_mod.route(params["router"], x, cfg.moe)
    got = moe_mod.moe_apply(params, x, top_i, top_w, cfg, ctx=None)
    want = moe_mod.moe_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_aux_loss_balanced_vs_collapsed():
    cfg, params, x = _setup()
    e = cfg.moe.num_experts
    # perfectly uniform router
    probs = jnp.ones((2, 16, e)) / e
    top_i = jnp.tile(jnp.arange(cfg.moe.top_k)[None, None], (2, 16, 1))
    balanced = moe_mod.aux_load_balance_loss(probs, top_i, cfg.moe)
    # collapsed: everything to expert 0
    probs_c = jnp.zeros((2, 16, e)).at[..., 0].set(1.0)
    top_c = jnp.zeros_like(top_i)
    collapsed = moe_mod.aux_load_balance_loss(probs_c, top_c, cfg.moe)
    assert float(collapsed) > float(balanced)

"""Topology plane (DESIGN.md §Topology plane): the network-cost model, the
``topology=None`` / zero-cost conformance property in both planes, the
pricing behaviours (distance-penalized victims, net-negative refusal, the
hierarchical cross-cell gate, link contention), the serve-plane migration
fold, and the wedged-worker staleness regressions (LimpConfig.stale_after)."""

import threading
import time

import numpy as np
import pytest

from repro.core.a2ws import WorkerPool
from repro.core.limp import LimpConfig, SlowdownEvent
from repro.core.policy import HierarchicalA2WSPolicy
from repro.core.simulator import SimConfig, simulate, table2_speeds
from repro.core.steal import victim_weights
from repro.core.topology import Topology, parse_topology
from repro.serve.engine import Replica, ServePool


# ------------------------------------------------------------------ the model
def test_cost_zero_diagonal_and_uniform():
    topo = Topology.uniform(0.5, 0.1)
    assert topo.cost(3, 3, 100) == 0.0
    assert topo.cost(0, 1) == pytest.approx(0.6)
    assert topo.cost(0, 1, 5) == pytest.approx(0.5 + 5 * 0.1)
    # any worker id is valid (elastic growth)
    assert topo.cost(10_000, 3, 2) == pytest.approx(0.7)
    assert topo.cost(0, 1, -3) == pytest.approx(0.5)  # clamped, not negative


def test_two_level_tiers_from_callable_sequence_and_cellmap():
    for cells in (lambda g: g // 4, [0, 0, 0, 0, 1, 1, 1, 1],
                  HierarchicalA2WSPolicy(8, num_cells=2).cells):
        topo = Topology.two_level(
            cells, intra_latency=0.01, intra_per_task=0.001,
            cross_latency=0.1, cross_per_task=0.02,
        )
        assert topo.cost(0, 1, 1) == pytest.approx(0.011)
        assert topo.cost(0, 5, 1) == pytest.approx(0.12)
        # the acceptance skew: cross >= 10x intra on both terms
        assert topo.cost(0, 5, 1) >= 10 * topo.cost(0, 1, 1)
        # unknown workers (beyond the description) price as CROSS
        assert topo.cost(0, 9_999, 1) == pytest.approx(0.12)


def test_fat_tree_hop_tiers_and_modulo_wrap():
    topo = Topology.fat_tree(4, hop_latency=1.0, hop_per_task=0.5)
    # k=4: 16 hosts, edge groups of 2, pods of 4
    assert topo.cost(0, 1, 0) == 2.0  # same edge switch
    assert topo.cost(0, 2, 0) == 4.0  # same pod, via aggregation
    assert topo.cost(0, 8, 0) == 6.0  # across pods, via core
    assert topo.cost(0, 8, 2) == pytest.approx(6.0 + 2 * 6 * 0.5)
    # ids wrap modulo k^3/4 (elastic joiners reuse physical slots)
    assert topo.cost(16, 0, 0) == 0.0
    assert topo.cost(17, 0, 0) == 2.0
    with pytest.raises(ValueError):
        Topology.fat_tree(3)
    with pytest.raises(ValueError):
        Topology.fat_tree(0)


def test_from_matrix_and_out_of_range_prices_far():
    lat = [[0.0, 1.0], [2.0, 0.0]]
    per = [[0.0, 0.1], [0.2, 0.0]]
    topo = Topology.from_matrix(lat, per)
    assert topo.cost(0, 1, 1) == pytest.approx(1.1)
    assert topo.cost(1, 0, 2) == pytest.approx(2.4)
    # beyond the matrix: the matrix MAXIMUM (unmodelled joiner is far)
    assert topo.cost(0, 7, 1) == pytest.approx(2.0 + 0.2)
    with pytest.raises(ValueError):
        Topology.from_matrix([[0.0, 1.0]])  # not square
    with pytest.raises(ValueError):
        Topology.from_matrix(lat, [[0.0]])  # shape mismatch


def test_add_per_task_folds_migration_into_remote_links():
    topo = Topology.uniform(0.5, 0.1).add_per_task(0.05)
    assert topo.cost(0, 1, 2) == pytest.approx(0.5 + 2 * 0.15)
    assert topo.cost(1, 1, 2) == 0.0  # local stays free
    with pytest.raises(ValueError):
        Topology.uniform().add_per_task(-0.1)
    with pytest.raises(ValueError):
        Topology.uniform().add_per_task(float("nan"))


def test_contention_validation():
    with pytest.raises(ValueError):
        Topology.uniform(contention=-1.0)
    with pytest.raises(ValueError):
        Topology.uniform(contention=float("inf"))


def test_parse_topology_specs_and_errors():
    assert parse_topology(None, 8) is None
    assert parse_topology("none", 8) is None
    assert parse_topology("", 8) is None
    uni = parse_topology("uniform:0.5:0.1", 8)
    assert uni.cost(0, 1, 1) == pytest.approx(0.6)
    two = parse_topology("two-level:2:0.01:0.1", 8)
    assert two.cost(0, 1) == pytest.approx(0.01)  # same contiguous cell of 4
    assert two.cost(0, 4) == pytest.approx(0.1)
    # cross defaults to 10x intra
    assert parse_topology("two-level:2:0.01", 8).cost(0, 4) == pytest.approx(0.1)
    ft = parse_topology("fat-tree:4:0.5", 8)
    assert ft.cost(0, 8) == pytest.approx(3.0)
    with pytest.raises(ValueError):
        parse_topology("mesh:1", 8)
    with pytest.raises(ValueError):
        parse_topology("uniform:abc", 8)


# --------------------------------------- zero-cost conformance (plan level)
def test_victim_weights_zero_cost_hook_is_identity():
    n = [10.0, 2.0, 8.0, 1.0, 9.0]
    t = [0.1, 0.1, 0.2, 0.1, 0.15]
    queued = [8.0, 0.0, 6.0, 0.0, 7.0]
    base = victim_weights(1, n, t, queued, 2)
    hook = victim_weights(1, n, t, queued, 2, tcost=lambda j, k: 0.0)
    assert base[2] == hook[2]
    assert np.array_equal(base[0], hook[0])
    assert np.array_equal(base[1], hook[1])


def _crafted_plans(policy, p, seed, topology):
    """Deterministic boundary plans from a constructed (never started) pool
    with crafted imbalance (mirrors tests/test_hierarchy.py)."""
    pool = WorkerPool(
        list(range(p * 5)), p, lambda w, t: None, policy=policy, seed=seed,
        topology=topology,
    )
    for i in (0, p // 2):
        w = pool.workers[i]
        while w.deque.get_task() is not None:
            pass
    now = pool.clock()
    for i, w in enumerate(pool.workers):
        w.executed, w.runtime_sum, w.ran_any = 5, 5 * 0.05, True
        w.start_time = now - 1e-3
        pool._update_info(i)
    for i in range(p):
        pool.info.communicate(i)
    plans = []
    for i in range(p):
        plan = pool.policy.on_boundary(pool._make_view(i))
        plans.append(
            None if plan is None else
            (plan.victim, plan.amount, plan.criterion, plan.delay, plan.work)
        )
    return plans


@pytest.mark.parametrize("p,seed", [(2, 0), (5, 7), (11, 23), (24, 1234)])
def test_threaded_plans_bit_for_bit_under_zero_cost_topology(p, seed):
    """The conformance property, threaded plane: an all-zero topology model
    produces IDENTICAL boundary plans to topology=None — same victims,
    amounts, criteria, delays, work targets, same rng stream."""
    bare = _crafted_plans("a2ws", p, seed, None)
    zero = _crafted_plans("a2ws", p, seed, Topology.uniform())
    assert bare == zero


@pytest.mark.parametrize(
    "conf,seed,tasks",
    [("C1", 0, 80), ("C4", 3, 120), ("C4", 17, 160), ("C1", 42, 100)],
)
def test_sim_telemetry_bit_for_bit_under_zero_cost_topology(conf, seed, tasks):
    """The conformance property, sim plane, flat scheduler: whole-run
    virtual-time telemetry is bit-for-bit identical between topology=None
    and the all-zero uniform topology."""
    cfg = SimConfig(speeds=table2_speeds(conf), num_tasks=tasks, seed=seed)
    bare = simulate("a2ws", cfg)
    zero = simulate("a2ws", cfg.with_(topology=Topology.uniform()))
    assert zero.makespan == bare.makespan
    assert zero.per_node_tasks == bare.per_node_tasks
    assert zero.per_node_busy == bare.per_node_busy
    assert zero.records == bare.records
    assert (zero.steals, zero.failed_steals, zero.moved_tasks,
            zero.boundaries) == (bare.steals, bare.failed_steals,
                                 bare.moved_tasks, bare.boundaries)


@pytest.mark.parametrize("seed", [0, 11, 37])
def test_sim_telemetry_bit_for_bit_zero_cost_hierarchical(seed):
    """The conformance property for the hierarchical scheduler: the leader
    balancer's cross-cell gate must not perturb anything at zero cost."""
    p = 64
    cfg = SimConfig(speeds=table2_speeds("C4"), num_tasks=220, seed=seed)
    bare = simulate(HierarchicalA2WSPolicy(p), cfg)
    zero = simulate(
        HierarchicalA2WSPolicy(p),
        cfg.with_(topology=Topology.uniform()),
    )
    assert zero.makespan == bare.makespan
    assert zero.per_node_tasks == bare.per_node_tasks
    assert zero.records == bare.records
    assert (zero.steals, zero.moved_tasks) == (bare.steals, bare.moved_tasks)


# ------------------------------------------------------- pricing behaviours
def test_sim_expensive_uniform_topology_suppresses_stealing():
    """When every link costs more than the work it could move, the priced
    scheduler refuses steals the blind scheduler happily fires."""
    speeds = table2_speeds("C4")[:16]
    cfg = SimConfig(speeds=speeds, num_tasks=64, seed=0, task_cost=0.05)
    topo = Topology.uniform(50.0, 10.0)  # any steal costs >> total work
    free = simulate("a2ws", cfg)
    priced = simulate("a2ws", cfg.with_(topology=topo))
    blind = simulate("a2ws", cfg.with_(topology=topo, topology_aware=False))
    assert free.steals > 0
    assert blind.steals > 0  # blind plans as if the network were free
    assert priced.steals < blind.steals
    assert priced.moved_tasks < blind.moved_tasks


def test_hierarchical_balancer_refuses_net_negative_cross_cell_batches():
    p = 128
    speeds = tuple(np.tile(table2_speeds("C4", order="blocked"), p // 64))
    cfg = SimConfig(speeds=speeds, num_tasks=p * 4, seed=0, task_cost=2.0)
    pol = HierarchicalA2WSPolicy(p)
    topo = Topology.two_level(
        pol.cells, cross_latency=1e4, cross_per_task=1e3,
    )
    res = simulate(pol, cfg.with_(topology=topo))
    cell_of = pol.cells.cell_of
    xmoved = sum(t for _t, i, v, t in res.steal_log
                 if cell_of(i) != cell_of(v))
    assert pol.xcell_refused > 0, "balancer never priced a batch out"
    assert xmoved == 0, "net-negative cross-cell batches still moved loot"


def test_sim_link_contention_changes_transfer_timing():
    """contention=1 queues repeated transfers on one directed link behind
    each other.  Delayed arrivals feed back into scheduling decisions, so
    whole-run makespan is NOT monotone — the pinned property is that the
    knob is actually exercised (a directed link is reused) and that it
    perturbs the trajectory while conserving every task."""
    speeds = (4.0, 1.0, 1.0, 1.0)
    cfg = SimConfig(speeds=speeds, num_tasks=64, seed=0, task_cost=0.2)
    fluid = simulate("a2ws", cfg.with_(topology=Topology.uniform(0.05, 0.01)))
    jammed = simulate(
        "a2ws",
        cfg.with_(topology=Topology.uniform(0.05, 0.01, contention=1.0)),
    )
    links = [(v, i) for _t, i, v, _k in jammed.steal_log]
    assert len(links) > len(set(links)), "no directed link ever reused"
    assert jammed.steals > 0
    assert sum(jammed.per_node_tasks) == cfg.num_tasks
    assert (jammed.makespan, jammed.steal_log) != (
        fluid.makespan, fluid.steal_log
    ), "contention knob had no effect on the trajectory"


def test_steal_log_records_every_transfer():
    cfg = SimConfig(speeds=table2_speeds("C4")[:8], num_tasks=64, seed=0)
    res = simulate("a2ws", cfg)
    assert len(res.steal_log) == res.steals
    assert sum(take for *_x, take in res.steal_log) == res.moved_tasks
    for t, thief, victim, take in res.steal_log:
        assert 0.0 <= t <= res.makespan
        assert thief != victim
        assert take >= 1


# ------------------------------------------------------------- serve plane
def test_servepool_migration_cost_folds_into_topology():
    pool = ServePool(
        [Replica(f"r{i}", lambda req: {"ok": True}) for i in range(2)],
        seed=0, migration_cost=0.25,
    )
    assert pool.topology is not None
    assert pool.topology.cost(0, 1, 2) == pytest.approx(0.5)
    assert pool.topology.cost(0, 0, 2) == 0.0
    base = Topology.uniform(0.1, 0.05)
    pool2 = ServePool(
        [Replica(f"q{i}", lambda req: {"ok": True}) for i in range(2)],
        seed=0, topology=base, migration_cost=0.25,
    )
    assert pool2.topology.cost(0, 1, 1) == pytest.approx(0.1 + 0.3)
    with pytest.raises(ValueError):
        ServePool([Replica("z", lambda r: r)], migration_cost=-1.0)


def test_servepool_serves_with_priced_topology():
    def gen(req):
        time.sleep(0.002)
        return {"ok": True}

    pool = ServePool(
        [Replica(f"r{i}", gen) for i in range(2)],
        seed=0, topology=Topology.uniform(0.001, 0.0005),
        migration_cost=0.001,
    )
    pool.start()
    futs = [pool.submit({"x": i}) for i in range(16)]
    for f in futs:
        assert f.result(timeout=30)["ok"]
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 16


# ------------------------------------- wedged-worker staleness (satellite 1)
def test_limp_config_stale_after_validation():
    assert not np.isfinite(LimpConfig().stale_after)  # default: disabled
    assert LimpConfig(stale_after=2.0).stale_after == 2.0
    with pytest.raises(ValueError):
        LimpConfig(stale_after=0.0)
    with pytest.raises(ValueError):
        LimpConfig(stale_after=-1.0)


def test_sim_wedge_staleness_closes_factor_inf_blind_spot():
    """The PR-5 detector only observes COMPLETED tasks, so a worker wedged
    inside one task — a 200x slowdown that outlives the whole healthy run,
    indistinguishable from factor-infinity while it lasts — never flags:
    the owner-side EWMA is silent until the stuck task itself completes,
    ~200 s too late.  stale_after closes the blind spot from the PEER
    side: the heartbeat goes stale, peers flag the wedge within seconds,
    hold the flag for the whole silence, and hand the verdict back to the
    owner EWMA once the heartbeat returns."""
    base = SimConfig(
        speeds=np.ones(4), num_tasks=600, task_cost=1.0, seed=0,
        arrival="poisson", arrival_rate=2.0,
        slowdowns=(SlowdownEvent(1, 20.0, 200.0, duration=200.0),),
    )
    blind = simulate("a2ws", base.with_(limp=LimpConfig()))
    wedge = simulate("a2ws", base.with_(limp=LimpConfig(stale_after=2.0)))
    # The owner-side EWMA alone is silent for the whole wedge — its first
    # chance to flag is the stuck task's own completion, ~200 s too late.
    early_blind = [t for t, w, f in blind.limp_events if w == 1 and f]
    assert not early_blind or early_blind[0] > 200.0
    # The peer-side heartbeat check fires within seconds of the wedge...
    flags = [t for t, w, f in wedge.limp_events if w == 1 and f]
    assert flags and 20.0 < flags[0] < 30.0
    # ...holds the flag for the entire silence (no flapping mid-wedge)...
    unflags = [t for t, w, f in wedge.limp_events if w == 1 and not f]
    assert all(t > 200.0 for t in unflags)
    # ...and releases it once the heartbeat returns (the wedged task
    # completes at ~220 s), handing the verdict back to the owner EWMA.
    assert unflags and unflags[0] < base.num_tasks  # well before drain-end
    # Healthy workers are never dragged in by the staleness check: an idle
    # poll IS a heartbeat, so only the worker stuck INSIDE a task flags.
    assert not [t for t, w, f in wedge.limp_events if w != 1 and f]
    # Both legs still run every task to completion.
    assert sum(blind.per_node_tasks) == base.num_tasks
    assert sum(wedge.per_node_tasks) == base.num_tasks
    # The wedge-aware grid stays bounded for the 95% of tasks that peers
    # can rescue (the in-flight victim itself is unsaveable in both legs).
    assert wedge.latency_percentiles((95.0,))[95.0] < 60.0


def test_threaded_wedge_staleness_flags_blocked_worker():
    """Real threads: a worker wedged inside a task (never reaching a
    boundary, so its own ring version stands still) is flagged by peers via
    the heartbeat check, and the pool drains cleanly after release."""
    gate = threading.Event()

    def task_fn(wid, task):
        if task == "wedge":
            gate.wait(timeout=30.0)
        else:
            time.sleep(0.002)

    pool = WorkerPool(
        [], 3, task_fn, policy="a2ws", open_arrival=True, seed=0,
        limp=LimpConfig(stale_after=0.3),
    )
    pool.start()
    pool.submit_many(["t%d" % i for i in range(30)])
    deadline = time.time() + 5.0
    while pool.pending() and time.time() < deadline:
        time.sleep(0.002)
    pool.submit("wedge", worker=1)
    deadline = time.time() + 10.0
    while not pool.limping(1) and time.time() < deadline:
        pool.submit_many(["u%d" % i for i in range(4)])
        time.sleep(0.05)
    assert pool.limping(1), "wedged worker never flagged by peers"
    assert any(w == 1 and f for _t, w, f in pool.limp_log)
    gate.set()
    pool.drain()
    stats = pool.join()
    assert sum(stats.per_worker_tasks) == pool.done_counter.load()

"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def _batch_for(cfg, b=2, s=32):
    if cfg.frontend == "vision":
        return {
            "embeds": jax.random.normal(
                jax.random.key(1), (b, s, cfg.d_model), jnp.bfloat16) * 0.1,
            "positions": jnp.broadcast_to(
                jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32),
            "labels": jnp.ones((b, s), jnp.int32),
        }
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab),
    }
    if cfg.enc_layers:
        batch["enc_embeds"] = jax.random.normal(
            jax.random.key(3), (b, s, cfg.d_model), jnp.bfloat16) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke(arch)
    params, _ = lm.init(cfg, jax.random.key(0))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(lambda p, b: lm.forward(p, b, cfg))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab]).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_no_nan(arch):
    cfg = get_smoke(arch)
    params, _ = lm.init(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    batch = _batch_for(cfg)

    @jax.jit
    def step(p, o, b):
        (loss, m), g = jax.value_and_grad(lm.loss_fn, has_aux=True)(p, b, cfg)
        p2, o2, om = adamw_update(g, o, p, opt_cfg)
        return p2, o2, loss, om["grad_norm"]

    p2, o2, loss, gnorm = step(params, opt, batch)
    assert np.isfinite(float(loss)), arch
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch
    # parameters actually moved
    delta = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.abs(a - b.astype(a.dtype)).max()),
                     params, p2)
    )
    assert max(delta) > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_abstract_init(arch):
    """The FULL configs are exercised only abstractly (no allocation)."""
    cfg = get_config(arch)
    shapes, specs = lm.init_shapes(cfg)
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    assert n > 0.5e9  # every assigned arch is at least ~1B params
    # logical axes tree matches the shape tree structure
    assert len(jax.tree.leaves(shapes)) == len(
        jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, tuple)
        )
    )


def test_loss_decreases_tiny_overfit():
    """End-to-end sanity: 30 steps on one repeated batch reduces loss."""
    cfg = get_smoke("phi4-mini-3.8b")
    params, _ = lm.init(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)
    batch = _batch_for(cfg, b=2, s=16)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(lm.loss_fn, has_aux=True)(p, b, cfg)
        p2, o2, _ = adamw_update(g, o, p, opt_cfg)
        return p2, o2, loss

    first = None
    for i in range(30):
        params, opt, loss = step(params, opt, batch)
        if first is None:
            first = float(loss)
    assert float(loss) < first - 1.0, (first, float(loss))

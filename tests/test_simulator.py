"""Discrete-event simulator: reproduces the paper's §4 qualitative claims."""

import numpy as np
import pytest

from repro.core.simulator import SimConfig, simulate, table2_speeds


def test_table2_configurations():
    assert len(table2_speeds("C1")) == 8
    assert len(table2_speeds("C2")) == 16
    assert len(table2_speeds("C3")) == 32
    assert len(table2_speeds("C4")) == 64
    assert len(table2_speeds("C5")) == 128
    # fastest first (Fig. 5 ordering), speeds = core counts
    s = table2_speeds("C1")
    assert s[0] == 24.0 and s[-1] == 1.0


@pytest.mark.parametrize("policy", ["a2ws", "ctws", "lw"])
def test_all_tasks_complete(policy):
    cfg = SimConfig(speeds=table2_speeds("C1"), num_tasks=480, seed=0)
    res = simulate(policy, cfg)
    assert sum(res.per_node_tasks) == 480
    assert res.makespan > 0


def test_a2ws_fast_nodes_run_more_tasks():
    """Fig. 5a: task counts ~ proportional to node speed."""
    cfg = SimConfig(speeds=table2_speeds("C1"), num_tasks=480, seed=0)
    res = simulate("a2ws", cfg)
    counts = np.asarray(res.per_node_tasks, dtype=float)
    speeds = table2_speeds("C1")
    # 24-core nodes should execute >10x the tasks of 1-core nodes
    fast = counts[speeds == 24.0].mean()
    slow = counts[speeds == 1.0].mean()
    assert fast / max(slow, 1) > 8


def test_a2ws_beats_static_partition():
    """Work-stealing must beat no-stealing on a heterogeneous cluster."""
    speeds = table2_speeds("C1")
    cfg = SimConfig(speeds=speeds, num_tasks=480, seed=0)
    res = simulate("a2ws", cfg)
    # static partition: slowest node runs its block at its own speed
    per = 480 / len(speeds)
    static_makespan = per * cfg.task_cost / speeds.min()
    assert res.makespan < 0.35 * static_makespan


def test_a2ws_beats_lw_and_ctws_at_scale():
    """Tables 3-4 headline: positive gain at C4/3840 (the paper's sweet
    spot; exact percentages are calibration-dependent, signs are not)."""
    cfg = SimConfig(speeds=table2_speeds("C4"), num_tasks=3840, seed=0)
    a = simulate("a2ws", cfg).makespan
    lw = simulate("lw", cfg).makespan
    ct = simulate("ctws", cfg).makespan
    assert a < lw, f"a2ws {a:.1f} vs lw {lw:.1f}"
    assert a < ct, f"a2ws {a:.1f} vs ctws {ct:.1f}"


def test_radius_tradeoff_shape():
    """Fig. 4: tiny radius hurts; intermediate radius ~ as good as global."""
    speeds = table2_speeds("C2")
    mks = {}
    for r in (1, 3, 8):
        cfg = SimConfig(speeds=speeds, num_tasks=960, seed=1, radius=r)
        mks[r] = simulate("a2ws", cfg).makespan
    assert mks[3] <= mks[1] * 1.02  # growing the radius should not hurt much
    assert min(mks[3], mks[8]) < mks[1]  # and should help vs R=1


def test_task_conservation_with_noise():
    cfg = SimConfig(speeds=table2_speeds("C2"), num_tasks=961, noise=0.15, seed=7)
    res = simulate("a2ws", cfg)
    assert sum(res.per_node_tasks) == 961


def test_records_cover_all_tasks():
    cfg = SimConfig(speeds=table2_speeds("C1"), num_tasks=100, seed=2)
    res = simulate("a2ws", cfg)
    assert len(res.records) >= 100  # includes queued starts

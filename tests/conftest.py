import os
import sys

# Make src/ importable without installation.  NOTE: no XLA_FLAGS here — smoke
# tests and benches must see the single real CPU device; only the dry-run
# subprocesses force 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

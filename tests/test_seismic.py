"""Seismic modeling substrate (paper §3): physics sanity + A2WS shot driver."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.a2ws import A2WSRuntime
from repro.seismic.model import (
    SeismicModel,
    make_demo_model,
    make_shot_grid,
    ricker,
    run_shot,
)


def test_ricker_wavelet_properties():
    w = np.asarray(ricker(10.0, 1e-3, 400))
    assert w.max() == pytest.approx(1.0, abs=1e-3)  # unit peak at t=1/f
    assert abs(w[0]) < 1e-2 and abs(w[-1]) < 1e-2  # compact support


def test_demo_model_cfl():
    m = make_demo_model(n=24)
    assert m.cfl_ok()


def test_shot_produces_signal_and_stays_finite():
    m = make_demo_model(n=24)
    shots = make_shot_grid(m, 1)
    seis = run_shot(m, jnp.asarray(shots[0].src), jnp.asarray(shots[0].rec_array()),
                    nt=120)
    s = np.asarray(seis)
    assert s.shape == (120, 8)
    assert np.isfinite(s).all()
    assert np.abs(s).max() > 1e-8  # the wave reached the receivers
    # energy arrives later at farther receivers (finite propagation speed)
    src_x = shots[0].src[2]
    rec_x = shots[0].rec_array()[:, 2]
    arrival = np.argmax(np.abs(s) > 1e-4 * np.abs(s).max(), axis=0)
    near = arrival[np.argmin(np.abs(rec_x - src_x))]
    far = arrival[np.argmax(np.abs(rec_x - src_x))]
    assert near <= far


def test_sponge_damps_boundary_energy():
    m = make_demo_model(n=24)
    shots = make_shot_grid(m, 1)
    seis = run_shot(m, jnp.asarray(shots[0].src),
                    jnp.asarray(shots[0].rec_array()), nt=400)
    s = np.asarray(seis)
    # late-time energy must not exceed the first-arrival energy (no
    # reflection blow-up from the absorbing boundaries)
    early = np.abs(s[:200]).max()
    late = np.abs(s[350:]).max()
    assert late < early


def test_a2ws_schedules_real_shots():
    """End-to-end §4-style mini-run: shots as A2WS tasks on 2 workers."""
    import threading

    m = make_demo_model(n=16)
    shots = make_shot_grid(m, 6)
    results = []
    lock = threading.Lock()

    def task_fn(wid, shot):
        seis = run_shot(m, jnp.asarray(shot.src), jnp.asarray(shot.rec_array()),
                        nt=40)
        with lock:
            results.append(np.asarray(seis))

    rt = A2WSRuntime(shots, 2, task_fn, seed=0)
    stats = rt.run()
    assert len(results) == 6
    assert sum(stats.per_worker_tasks) == 6
    assert all(np.isfinite(s).all() for s in results)

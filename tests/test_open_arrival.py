"""Open-arrival scheduling (DESIGN.md §Open-arrival): dynamic task injection
into the live A2WS runtime, quiescence termination, mid-flight steals of
injected tasks, the continuous-batching ServePool, and the simulator's
Poisson/trace arrival modes with latency-percentile reporting."""

import threading
import time

import numpy as np
import pytest

from repro.core.a2ws import A2WSRuntime, PoolCollapsed, WorkerPool
from repro.core.policy import SchedPolicy
from repro.core.simulator import SimConfig, simulate, table2_speeds
from repro.core.steal import tail_steal_amount
from repro.serve.engine import Replica, ServePool


# ------------------------------------------------------------ threaded runtime
def test_open_arrival_quiescence_no_deadlock():
    """Queues go transiently empty between submit waves; the run must only
    terminate after drain(), and must terminate promptly then."""
    done = []
    lock = threading.Lock()

    def task_fn(wid, task):
        with lock:
            done.append(task)

    rt = A2WSRuntime([], 3, task_fn, open_arrival=True, seed=0)
    rt.start()
    rt.submit_many(range(10))
    deadline = time.time() + 5.0
    while rt.pending() and time.time() < deadline:
        time.sleep(0.001)
    assert rt.pending() == 0  # wave 1 fully executed...
    assert not rt._finished()  # ...but NOT finished: more work may arrive
    time.sleep(0.01)  # workers idle on empty deques — must not exit
    rt.submit_many(range(10, 25))
    rt.drain()
    stats = rt.join()  # must not deadlock
    assert sorted(done) == list(range(25))
    assert sum(stats.per_worker_tasks) == 25


def test_open_arrival_empty_drain():
    """drain() with zero submitted tasks terminates immediately."""
    rt = A2WSRuntime([], 2, lambda w, t: None, open_arrival=True)
    rt.start()
    rt.drain()
    stats = rt.join()
    assert sum(stats.per_worker_tasks) == 0


def test_submit_requires_open_mode_and_predrain():
    rt = A2WSRuntime([1, 2], 2, lambda w, t: None)
    with pytest.raises(RuntimeError):
        rt.submit(3)
    rt2 = A2WSRuntime([], 2, lambda w, t: None, open_arrival=True)
    rt2.drain()
    with pytest.raises(RuntimeError):
        rt2.submit(3)
    rt2.start()
    rt2.join()


def test_midflight_steal_of_injected_task():
    """Tasks injected onto a busy worker's deque AFTER the run started must
    be stolen and executed by another worker.

    Deterministic setup: both workers block on a "blocker" task, 8 requests
    are injected onto worker 1's deque while it is still blocked, then only
    worker 0 is released — everything it serves was stolen mid-flight
    (worker 1 cannot even publish its queue depth while blocked; the probe
    steal path is what discovers the backlog)."""
    releases = [threading.Event(), threading.Event()]
    served_by = {}
    lock = threading.Lock()

    def task_fn(wid, task):
        if isinstance(task, str) and task.startswith("blocker"):
            releases[wid].wait(10.0)
            return
        with lock:
            served_by[task] = wid

    rt = A2WSRuntime([], 2, task_fn, open_arrival=True, seed=1)
    rt.start()
    rt.submit("blocker0", worker=0)
    rt.submit("blocker1", worker=1)
    deadline = time.time() + 5.0
    while time.time() < deadline and (
        len(rt.workers[0].deque) or len(rt.workers[1].deque)
    ):
        time.sleep(0.001)  # both blockers picked up -> both workers stuck
    rt.submit_many(list(range(8)), worker=1)
    releases[0].set()  # only worker 0 wakes; worker 1 still holds blocker1
    deadline = time.time() + 10.0
    while rt.pending() > 1 and time.time() < deadline:
        time.sleep(0.001)
    releases[1].set()
    rt.drain()
    stats = rt.join()
    assert len(served_by) == 8
    stolen_and_served = [t for t, w in served_by.items() if w == 0]
    assert len(stolen_and_served) == 8, served_by
    assert [s for s in stats.steals if s[1] == 0], "no recorded steal by w0"


def test_submit_invalid_worker_rejected_before_counting():
    """An out-of-range pin must raise ValueError WITHOUT bumping the
    quiescence counter — otherwise join() hangs forever."""
    rt = A2WSRuntime([], 2, lambda w, t: None, open_arrival=True)
    rt.start()
    with pytest.raises(ValueError):
        rt.submit("x", worker=5)
    assert rt.pending() == 0
    rt.drain()
    rt.join()  # must terminate promptly


def test_duplicate_payload_objects_keep_latency_stats_consistent():
    """Submitting the same (interned) object N times must yield N stamped
    records with non-negative latencies (arrival stamps are a per-id stack,
    not a single slot)."""
    rt = A2WSRuntime([], 2, lambda w, t: time.sleep(0.001),
                     open_arrival=True, seed=0)
    rt.start()
    rt.submit_many(["retry"] * 6)  # one interned str, six submissions
    rt.drain()
    stats = rt.join()
    assert len(stats.latencies) == 6
    assert all(x >= 0.0 for x in stats.latencies)


def test_open_arrival_latency_stats():
    """Records carry arrival stamps; percentiles are monotone."""
    rt = A2WSRuntime([], 2, lambda w, t: time.sleep(0.001),
                     open_arrival=True, seed=0)
    rt.start()
    rt.submit_many(range(12))
    rt.drain()
    stats = rt.join()
    lat = stats.latencies
    assert len(lat) == 12
    assert all(x >= 0.0 for x in lat)
    pct = stats.latency_percentiles((50.0, 95.0, 99.0))
    assert pct[50.0] <= pct[95.0] <= pct[99.0]


def test_closed_mode_has_no_latency_stats():
    rt = A2WSRuntime(list(range(8)), 2, lambda w, t: None)
    stats = rt.run()
    assert stats.latency_percentiles() == {}


class _IdleGatePolicy(SchedPolicy):
    """Worker 1's post-get_task idle boundaries sleep ``hold`` seconds so the
    test can land a submit() inside the window between its empty-deque check
    and its backoff wait; nobody ever steals."""

    name = "idle-gate"

    def __init__(self, hold: float = 0.15) -> None:
        self.hold = hold
        self.calls = 0
        self.in_idle_boundary = threading.Event()

    def on_boundary(self, view):
        if view.worker == 1 and view.idle:
            self.calls += 1
            if self.calls % 2 == 0:  # the idle-branch call AFTER get_task
                self.in_idle_boundary.set()
                time.sleep(self.hold)
                self.in_idle_boundary.clear()
        return None


def test_submit_wakes_backoff_sleeper_promptly():
    """Bugfix regression (lost submit wakeup): with ONE shared wake event, a
    busy worker's event-clear at its loop top could erase a submit()'s set()
    aimed at an idle sleeper that had already checked its deque — costing a
    full idle_backoff_max of tail latency.  With per-worker events the
    submitted task must complete far sooner than the 0.5 s backoff cap."""
    pol = _IdleGatePolicy(hold=0.15)
    exec_t = {}

    def task_fn(wid, task):
        if task == "probe":
            exec_t["probe"] = time.perf_counter()
        else:
            time.sleep(0.001)

    pool = WorkerPool([], 2, task_fn, policy=pol, open_arrival=True,
                      idle_backoff=0.5, idle_backoff_max=0.5)
    pool.start()
    # 300 ms of backlog pinned to worker 0: it cycles its loop top (where
    # the shared event used to be cleared) every millisecond with NO further
    # submits to re-set the event.
    pool.submit_many(["w0"] * 300, worker=0)
    assert pol.in_idle_boundary.wait(5.0), "worker 1 never reached idle gate"
    t0 = time.perf_counter()
    pool.submit("probe", worker=1)  # lands AFTER worker 1's deque check
    deadline = time.time() + 5.0
    while "probe" not in exec_t and time.time() < deadline:
        time.sleep(0.005)
    pool.drain()
    pool.join()
    assert "probe" in exec_t, "probe task never executed"
    latency = exec_t["probe"] - t0
    assert latency < 0.35, (
        f"sleeper woke after {latency:.3f}s — submit wakeup was lost "
        f"(idle backoff cap is 0.5s)"
    )


def test_submit_into_collapsed_pool_raises():
    """Bugfix regression (submit-vs-collapse race): once every worker has
    died, submit() must raise PoolCollapsed instead of round-robining onto a
    dead deque nobody will ever drain (the silent strand of the old code)."""

    def die(wid, task):
        raise RuntimeError("boom")

    pool = WorkerPool([], 2, die, policy="random", open_arrival=True)
    pool.start()
    pool.submit_many(["a", "b"])  # both workers pick one up and die
    deadline = time.time() + 5.0
    while pool.alive.load() > 0 and time.time() < deadline:
        time.sleep(0.001)
    assert pool.alive.load() == 0
    with pytest.raises(PoolCollapsed):
        pool.submit("stranded")
    pool.drain()
    pool.join()  # must return promptly, nothing hangs


def test_servepool_kill_all_replicas_while_submitting():
    """Bugfix regression: hammer submits while every replica dies — each
    future must resolve (with an error), whether it was accepted before the
    collapse, swept by the collapse hook, or rejected after it."""

    def bad(req):
        raise RuntimeError("replica crashed")

    pool = ServePool([Replica("b0", bad), Replica("b1", bad)])
    pool.start()
    futs = [pool.submit({"x": k}) for k in range(40)]
    for f in futs:
        with pytest.raises(RuntimeError):
            f.result(timeout=10)
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 0


# ------------------------------------------------------------------ tail rule
def test_tail_steal_open_arrival_accepts_tie():
    """Closed: equal-speed single-task tie -> no steal.  Open: the idle
    thief takes it (the victim is busy with an in-flight task; leaving the
    queued task behind it is a pure latency loss)."""
    assert tail_steal_amount(0, 1.0, 1, 1.0) == 0
    assert tail_steal_amount(0, 1.0, 1, 1.0, open_arrival=True) == 1
    # but a strictly-worsening move is still refused even when open
    assert tail_steal_amount(0, 60.0, 1, 1.0, open_arrival=True) == 0
    # and a busy thief gets no tie-break exemption
    assert tail_steal_amount(3, 1.0, 1, 1.0, open_arrival=True) == 0


# ------------------------------------------------------------------ ServePool
def test_servepool_streams_across_waves_without_teardown():
    def gen(req):
        time.sleep(0.001)
        return {"y": req["x"] * 2}

    pool = ServePool(
        [Replica("fast", gen), Replica("slow", gen, slow_factor=10.0)],
        seed=3,
    )
    pool.start()
    runtime = pool._runtime
    # wave 1: everything pinned to the SLOW replica post-start; the fast
    # replica can only serve via mid-flight steals.
    futs = pool.submit_wave([{"x": k} for k in range(16)], replica=1)
    resp = [f.result(timeout=30) for f in futs]
    assert [r["y"] for r in resp] == [2 * k for k in range(16)]
    served_by_fast = sum(1 for f in futs if f.worker == 0)
    assert served_by_fast > 0, "no injected request was stolen cross-replica"
    s1 = pool.stats()
    assert len(s1.steals) > 0

    # wave 2 reuses the same runtime: no teardown/re-partition between waves
    resp2, s2 = pool.submit_all([{"x": 100 + k} for k in range(8)])
    assert pool._runtime is runtime
    assert [r["y"] for r in resp2] == [2 * (100 + k) for k in range(8)]
    assert sum(s2.per_worker_tasks) == 24

    final = pool.shutdown()
    assert sum(final.per_worker_tasks) == 24
    assert len(final.latencies) == 24


def test_servepool_total_collapse_fails_futures_instead_of_hanging():
    """When EVERY replica dies, queued requests can never be served — their
    futures must fail promptly (collapse hook) rather than hang forever."""

    def bad(req):
        raise RuntimeError("boom")

    pool = ServePool([Replica("b0", bad), Replica("b1", bad)])
    pool.start()
    futs = pool.submit_wave([{"x": k} for k in range(6)])
    for f in futs:
        with pytest.raises(RuntimeError):
            f.result(timeout=10)
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 0


def test_submit_drain_race_never_strands_tasks():
    """Hammer submit() against drain() from another thread: every submit
    must either raise (after drain) or have its task executed."""
    done = []
    lock = threading.Lock()

    def task_fn(wid, task):
        with lock:
            done.append(task)

    for trial in range(5):
        rt = A2WSRuntime([], 2, task_fn, open_arrival=True, seed=trial)
        rt.start()
        accepted = []

        def submitter():
            for k in range(200):
                try:
                    rt.submit(("t", trial, k))
                except RuntimeError:
                    return
                accepted.append(k)

        th = threading.Thread(target=submitter)
        th.start()
        time.sleep(0.002)
        rt.drain()
        th.join()
        rt.join()
        ran = [t for t in done if t[1] == trial]
        assert len(ran) == len(accepted), (trial, len(ran), len(accepted))


def test_servepool_replica_failure_transparent_retry():
    calls = []

    def bad_gen(req):
        raise RuntimeError("replica crashed")

    def good_gen(req):
        calls.append(req["x"])
        return {"ok": req["x"]}

    pool = ServePool([Replica("good", good_gen), Replica("bad", bad_gen)])
    pool.start()
    futs = [pool.submit({"x": k}, replica=1) for k in range(4)]
    resp = [f.result(timeout=30) for f in futs]
    assert sorted(r["ok"] for r in resp) == [0, 1, 2, 3]
    assert all(f.worker == 0 for f in futs)  # survivor served everything
    pool.shutdown()


# ------------------------------------------------------------------ simulator
def test_sim_poisson_latency_reporting():
    speeds = table2_speeds("C1")
    capacity = float(speeds.sum()) / 60.0
    cfg = SimConfig(speeds=speeds, num_tasks=300, seed=0,
                    arrival="poisson", arrival_rate=0.6 * capacity)
    res = simulate("a2ws", cfg)
    assert sum(res.per_node_tasks) == 300
    assert len(res.latencies) == 300
    pct = res.latency_percentiles((50.0, 95.0, 99.0))
    assert 0.0 < pct[50.0] <= pct[95.0] <= pct[99.0]
    assert res.makespan > 0


def test_sim_trace_arrivals():
    speeds = table2_speeds("C1")
    trace = tuple(np.linspace(0.0, 50.0, 40))
    cfg = SimConfig(speeds=speeds, num_tasks=0, seed=1,
                    arrival="trace", arrival_trace=trace)
    res = simulate("a2ws", cfg)
    assert sum(res.per_node_tasks) == 40
    assert len(res.latencies) == 40


def test_sim_open_stealing_beats_static_routing_tail():
    """Round-robin arrivals overload slow nodes; adaptive stealing must
    rescue the tail (radius=0 disables stealing entirely)."""
    speeds = table2_speeds("C1")
    capacity = float(speeds.sum()) / 60.0
    base = dict(speeds=speeds, num_tasks=400, seed=0,
                arrival="poisson", arrival_rate=0.7 * capacity)
    steal = simulate("a2ws", SimConfig(**base))
    nosteal = simulate("a2ws", SimConfig(**base, radius=0))
    assert steal.steals > 0 and nosteal.steals == 0
    p99_s = steal.latency_percentiles((99.0,))[99.0]
    p99_n = nosteal.latency_percentiles((99.0,))[99.0]
    assert p99_s < 0.5 * p99_n
    assert steal.makespan < nosteal.makespan


@pytest.mark.parametrize("policy", ["ctws", "lw", "random"])
def test_sim_open_arrival_baseline_parity(policy):
    """PR 2 (policy layer): open-arrival simulation is no longer A2WS-only —
    every policy runs on the same event loop and reports latencies."""
    speeds = table2_speeds("C1")
    cfg = SimConfig(speeds=speeds, num_tasks=60, seed=3,
                    arrival="poisson", arrival_rate=0.5 * float(speeds.sum()) / 60.0)
    res = simulate(policy, cfg)
    assert sum(res.per_node_tasks) == 60
    assert len(res.latencies) == 60
    assert res.latency_percentiles()[99.0] > 0.0

"""Mamba-2 SSD chunked scan vs token-by-token recurrence oracle; RG-LRU
associative scan vs step oracle."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import split


def _f32(tree):
    return jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, tree
    )


def test_ssd_chunked_matches_naive():
    cfg = get_smoke("mamba2-2.7b").with_(dtype="float32")
    params, _ = split(ssm_mod.ssm_params(jax.random.key(0), cfg))
    params = _f32(params)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model)) * 0.3
    got = ssm_mod.ssm_apply(params, x, cfg)  # chunk=16 -> 2 chunks
    want = ssm_mod.ssd_naive(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-3)


def test_ssd_cache_continuation():
    """apply(x) cache == state after running decode over all of x."""
    cfg = get_smoke("mamba2-2.7b").with_(dtype="float32")
    params, _ = split(ssm_mod.ssm_params(jax.random.key(0), cfg))
    params = _f32(params)
    x = jax.random.normal(jax.random.key(2), (1, 16, cfg.d_model)) * 0.3
    _, (state_a, conv_a) = ssm_mod.ssm_apply(params, x, cfg, return_cache=True)
    cache = ssm_mod.ssm_init_cache(cfg, 1, dtype=x.dtype)
    for t in range(16):
        _, cache = ssm_mod.ssm_decode(params, x[:, t : t + 1], cfg, cache)
    np.testing.assert_allclose(np.asarray(state_a), np.asarray(cache[0]),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(conv_a), np.asarray(cache[1]),
                               atol=1e-5)


def test_rglru_scan_matches_naive():
    cfg = get_smoke("recurrentgemma-2b").with_(dtype="float32")
    params, _ = split(rglru_mod.rglru_params(jax.random.key(0), cfg))
    params = _f32(params)
    x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model)) * 0.3
    got = rglru_mod.rglru_apply(params, x, cfg)
    want = rglru_mod.rglru_naive(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-3)


def test_rglru_decay_bounded():
    """a_t in (0, 1): the recurrence can never blow up."""
    cfg = get_smoke("recurrentgemma-2b")
    params, _ = split(rglru_mod.rglru_params(jax.random.key(0), cfg))
    params = _f32(params)
    x = jax.random.normal(jax.random.key(3), (1, 8, cfg.d_model)) * 5.0
    a, b = rglru_mod._gates(params, jnp.asarray(x, jnp.float32),
                            cfg.rglru.c_exponent)
    assert float(a.min()) > 0.0 and float(a.max()) < 1.0


def test_ssd_long_sequence_stability():
    cfg = get_smoke("mamba2-2.7b").with_(dtype="float32")
    params, _ = split(ssm_mod.ssm_params(jax.random.key(0), cfg))
    params = _f32(params)
    x = jax.random.normal(jax.random.key(4), (1, 128, cfg.d_model)) * 0.3
    y = ssm_mod.ssm_apply(params, x, cfg)
    assert bool(jnp.isfinite(y).all())

"""Flash attention vs naive softmax oracle; RoPE / M-RoPE properties."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention
from repro.models.layers import mrope, rope


def naive_attention(q, k, v, *, causal, window=0, q_offset=0):
    b, sq, h, d = q.shape
    _, sk, hkv, dv = v.shape
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qf, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckv->bqkgv", a, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dv).astype(q.dtype)


@pytest.mark.parametrize("sq,sk,h,hkv,d,causal,window,chunk", [
    (8, 8, 4, 4, 16, True, 0, 4),       # MHA causal
    (8, 8, 4, 1, 16, True, 0, 8),       # MQA
    (16, 16, 8, 2, 8, True, 0, 4),      # GQA, several chunks
    (8, 8, 4, 2, 16, False, 0, 4),      # bidirectional (encoder)
    (16, 16, 4, 2, 8, True, 6, 4),      # sliding window
    (12, 12, 2, 2, 8, True, 0, 5),      # chunk doesn't divide seq
    (1, 16, 4, 2, 8, True, 0, 16),      # single query vs long keys
])
def test_flash_matches_naive(sq, sk, h, hkv, d, causal, window, chunk):
    key = jax.random.key(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, sq, h, d), jnp.float32)
    k = jax.random.normal(kk, (2, sk, hkv, d), jnp.float32)
    v = jax.random.normal(kv, (2, sk, hkv, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    want = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_q_offset_matches_suffix():
    """Cached prefill: q covers positions [off, off+sq) of the key range."""
    key = jax.random.key(1)
    kq, kk, kv = jax.random.split(key, 3)
    sk, off, sq = 12, 8, 4
    qfull = jax.random.normal(kq, (1, sk, 2, 8), jnp.float32)
    k = jax.random.normal(kk, (1, sk, 2, 8), jnp.float32)
    v = jax.random.normal(kv, (1, sk, 2, 8), jnp.float32)
    full = flash_attention(qfull, k, v, causal=True, chunk=4)
    part = flash_attention(qfull[:, off:], k, v, causal=True, chunk=4,
                           q_offset=off)
    np.testing.assert_allclose(
        np.asarray(full[:, off:]), np.asarray(part), atol=2e-5
    )


def test_rope_relative_property():
    """RoPE: <q_m, k_n> depends only on m-n (shift positions together)."""
    key = jax.random.key(2)
    q = jax.random.normal(key, (1, 4, 1, 16), jnp.float32)
    k = jax.random.normal(jax.random.key(3), (1, 4, 1, 16), jnp.float32)
    p0 = jnp.arange(4)[None, :]
    p1 = p0 + 7
    s0 = jnp.einsum(
        "bshd,bthd->bst", rope(q, p0, 1e4), rope(k, p0, 1e4)
    )
    s1 = jnp.einsum(
        "bshd,bthd->bst", rope(q, p1, 1e4), rope(k, p1, 1e4)
    )
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-4)


def test_mrope_degenerates_to_rope_for_text():
    """Qwen2-VL M-RoPE with t==h==w position ids == standard RoPE."""
    key = jax.random.key(4)
    x = jax.random.normal(key, (2, 6, 3, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 6))
    a = rope(x, pos, 1e4)
    b = mrope(x, pos3, 1e4, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mrope_distinguishes_spatial_ids():
    key = jax.random.key(5)
    x = jax.random.normal(key, (1, 4, 1, 16), jnp.float32)
    pos_t = jnp.zeros((1, 4), jnp.int32)
    same = jnp.stack([pos_t, pos_t, pos_t])
    spatial = jnp.stack([pos_t, pos_t + 3, pos_t + 5])
    a = mrope(x, same, 1e4, (2, 3, 3))
    b = mrope(x, spatial, 1e4, (2, 3, 3))
    assert not np.allclose(np.asarray(a), np.asarray(b))

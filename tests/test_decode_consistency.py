"""Decode/prefill vs full-forward consistency — the serving correctness
contract, per architecture family (GQA, MLA+MoE, M-RoPE, local+RG-LRU, SSD,
enc-dec)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import lm

S = 24  # full sequence length for the comparisons


def _f32_cfg(arch):
    cfg = get_smoke(arch).with_(dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return cfg


def _f32_params(cfg):
    params, _ = lm.init(cfg, jax.random.key(0))
    return jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params,
    )


def _token_batch(cfg, b=2, s=S):
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}


FAMILIES = [
    "mistral-nemo-12b",       # dense GQA
    "qwen1.5-32b",            # MHA + qkv bias
    "deepseek-v3-671b",       # MLA + MoE (+MTP params unused at serve)
    "moonshot-v1-16b-a3b",    # GQA + MoE
    "recurrentgemma-2b",      # RG-LRU + local attention cycle
    "mamba2-2.7b",            # SSD
]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(arch):
    """Feeding tokens one-by-one through decode_step reproduces the full
    forward logits at every position."""
    cfg = _f32_cfg(arch)
    if cfg.ssm is not None:
        cfg = cfg.with_(ssm=dataclasses.replace(cfg.ssm, chunk=8))
    params = _f32_params(cfg)
    batch = _token_batch(cfg)
    full_logits, _ = lm.forward(params, batch, cfg)

    caches = lm.init_caches(cfg, 2, S, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c, i: lm.decode_step(p, t, c, i, cfg))
    outs = []
    for i in range(S):
        logits, caches = step(params, batch["tokens"][:, i : i + 1], caches,
                              jnp.int32(i))
        outs.append(logits)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full_logits), atol=2e-3, rtol=2e-3
    )


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "deepseek-v3-671b",
                                  "recurrentgemma-2b", "mamba2-2.7b"])
def test_prefill_matches_forward_last(arch):
    cfg = _f32_cfg(arch)
    if cfg.ssm is not None:
        cfg = cfg.with_(ssm=dataclasses.replace(cfg.ssm, chunk=8))
    params = _f32_params(cfg)
    batch = _token_batch(cfg)
    full_logits, _ = lm.forward(params, batch, cfg)
    pre_logits, _ = lm.prefill(params, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]), np.asarray(full_logits[:, -1]),
        atol=2e-3, rtol=2e-3,
    )


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "recurrentgemma-2b",
                                  "mamba2-2.7b", "deepseek-v3-671b"])
def test_prefill_then_decode_continues(arch):
    """prefill(prompt) + decode(rest) == forward(full) on the suffix."""
    cfg = _f32_cfg(arch)
    if cfg.ssm is not None:
        cfg = cfg.with_(ssm=dataclasses.replace(cfg.ssm, chunk=8))
    params = _f32_params(cfg)
    batch = _token_batch(cfg)
    s0 = 16
    full_logits, _ = lm.forward(params, batch, cfg)
    _, caches = lm.prefill(
        params, {"tokens": batch["tokens"][:, :s0]}, cfg
    )
    caches = lm.pad_caches(caches, cfg, S)
    outs = []
    for i in range(s0, S):
        logits, caches = lm.decode_step(
            params, batch["tokens"][:, i : i + 1], caches, jnp.int32(i), cfg
        )
        outs.append(logits)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full_logits[:, s0:]),
        atol=2e-3, rtol=2e-3,
    )


def test_encdec_prefill_matches_forward():
    cfg = _f32_cfg("seamless-m4t-medium")
    params = _f32_params(cfg)
    b = 2
    toks = jax.random.randint(jax.random.key(1), (b, S), 0, cfg.vocab)
    enc = jax.random.normal(jax.random.key(2), (b, S, cfg.d_model)) * 0.2
    batch = {"tokens": toks, "enc_embeds": enc}
    full_logits, _ = lm.forward(
        params, {**batch, "labels": toks}, cfg
    )
    pre_logits, caches = lm.prefill(params, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]), np.asarray(full_logits[:, -1]),
        atol=2e-3, rtol=2e-3,
    )
    # one decode step continues coherently (cross-attn memory kv reused)
    caches = lm.pad_caches(caches, cfg, S + 4)
    nxt = jnp.argmax(pre_logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits, _ = lm.decode_step(params, nxt, caches, jnp.int32(S), cfg)
    assert bool(jnp.isfinite(logits).all())


def test_vlm_forward_with_embeds():
    cfg = _f32_cfg("qwen2-vl-2b")
    params = _f32_params(cfg)
    b, s = 2, 16
    embeds = jax.random.normal(jax.random.key(3), (b, s, cfg.d_model)) * 0.2
    pos = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    logits, _ = lm.forward(
        params,
        {"embeds": embeds, "positions": pos, "labels": jnp.zeros((b, s), jnp.int32)},
        cfg,
    )
    assert logits.shape == (b, s, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab]).all())

"""Serve engine structure: abstract caches match prefill's real cache tree,
partition specs mirror the cache structure, pad_caches grows the right dims,
serve_context layout rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st  # skips properties w/o hypothesis

from repro.configs import get_smoke
from repro.core.steal import tail_steal_amount
from repro.models import lm
from repro.serve.engine import abstract_caches


@pytest.mark.parametrize("arch", [
    "mistral-nemo-12b", "deepseek-v3-671b", "recurrentgemma-2b",
    "mamba2-2.7b", "seamless-m4t-medium", "moonshot-v1-16b-a3b",
])
def test_abstract_caches_match_prefill(arch):
    """The dry-run's ShapeDtypeStruct caches must agree exactly (structure,
    shapes, dtypes) with what lm.prefill actually returns — otherwise
    decode_32k cells lower against the wrong tree."""
    cfg = get_smoke(arch)
    b, s = 2, 16
    batch = {"tokens": jnp.zeros((b, s), jnp.int32)}
    if cfg.enc_layers:
        batch["enc_embeds"] = jnp.zeros((b, s, cfg.d_model), jnp.bfloat16)

    real = jax.eval_shape(
        lambda p, bt: lm.prefill(p, bt, cfg)[1],
        lm.init_shapes(cfg)[0], batch,
    )
    sds = abstract_caches(cfg, b, s, enc_len=s)
    real_flat = jax.tree.leaves(real)
    sds_flat = jax.tree.leaves(sds)
    assert len(real_flat) == len(sds_flat), arch
    for r, a in zip(real_flat, sds_flat):
        assert r.shape == a.shape, (arch, r.shape, a.shape)
        assert r.dtype == a.dtype, (arch, r.dtype, a.dtype)


def test_pad_caches_grows_attention_only():
    cfg = get_smoke("recurrentgemma-2b")  # rglru + local mix
    caches = jax.eval_shape(lambda: lm.init_caches(cfg, 2, 16))
    padded = jax.eval_shape(lambda c: lm.pad_caches(c, cfg, 64),
                            caches)
    for before, after in zip(jax.tree.leaves(caches), jax.tree.leaves(padded)):
        # ring-buffer (window 32 > 16 -> cache built at 16) / state caches
        # keep their shape; nothing shrinks
        assert after.shape >= before.shape

    cfg2 = get_smoke("mistral-nemo-12b")
    c2 = jax.eval_shape(lambda: lm.init_caches(cfg2, 2, 16))
    p2 = jax.eval_shape(lambda c: lm.pad_caches(c, cfg2, 64), c2)
    for before, after in zip(jax.tree.leaves(c2), jax.tree.leaves(p2)):
        assert after.shape[2] == 64 and before.shape[2] == 16


def test_pad_caches_decode_still_correct():
    """Padding mid-generation must not change logits (padded keys are
    position-masked)."""
    cfg = get_smoke("mistral-nemo-12b").with_(dtype="float32")
    params, _ = lm.init(cfg, jax.random.key(0))
    params = jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)
    toks = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab)
    _, caches = lm.prefill(params, {"tokens": toks}, cfg)
    small = lm.pad_caches(caches, cfg, 9)
    big = lm.pad_caches(caches, cfg, 32)
    nxt = jnp.ones((1, 1), jnp.int32)
    l_small, _ = lm.decode_step(params, nxt, small, jnp.int32(8), cfg)
    l_big, _ = lm.decode_step(params, nxt, big, jnp.int32(8), cfg)
    np.testing.assert_allclose(np.asarray(l_small), np.asarray(l_big),
                               atol=1e-5)


# ---------------------------------------------------------------- tail rule
@given(
    q_t=st.integers(0, 50), q_v=st.integers(0, 50),
    t_t=st.floats(0.1, 60.0), t_v=st.floats(0.1, 60.0),
)
@settings(max_examples=150, deadline=None)
def test_tail_steal_never_worsens_pair_makespan(q_t, q_v, t_t, t_v):
    k = tail_steal_amount(q_t, t_t, q_v, t_v)
    before = max(q_v * t_v, q_t * t_t)
    after = max((q_v - k) * t_v, (q_t + k) * t_t)
    assert 0 <= k <= q_v
    assert after <= before + 1e-9
    if k > 0:
        assert after < before - 1e-12  # strictly improving or it stays home


def test_tail_steal_slow_thief_declines_single_task():
    # victim holds exactly 1 task and is faster or equal: tie -> no steal
    assert tail_steal_amount(0, 60.0, 1, 60.0) == 0
    assert tail_steal_amount(0, 60.0, 1, 2.5) == 0
    # fast idle thief takes the slow victim's last task
    assert tail_steal_amount(0, 2.5, 1, 60.0) == 1

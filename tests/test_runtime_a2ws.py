"""Threaded A2WS runtime (Algorithm 1) + LW/CTWS baselines: correctness of
the distributed execution, stealing behaviour, fault tolerance."""

import threading
import time

import pytest

from repro.core.a2ws import A2WSRuntime, WorkerPool, partition_tasks
from repro.core.baselines import CTWSRuntime, LWRuntime
from repro.core.policy import StealPlan


def _busy(seconds: float) -> None:
    end = time.perf_counter() + seconds
    while time.perf_counter() < end:
        pass


def test_partition_tasks_block():
    parts = partition_tasks(list(range(10)), 3)
    assert parts == [[0, 1, 2, 3], [4, 5, 6], [7, 8, 9]]
    assert partition_tasks([], 2) == [[], []]


@pytest.mark.parametrize("runtime_cls", [A2WSRuntime, CTWSRuntime])
def test_every_task_exactly_once(runtime_cls):
    n = 60
    done = []
    lock = threading.Lock()

    def task_fn(wid, task):
        _busy(0.0005)
        with lock:
            done.append(task)

    rt = runtime_cls(list(range(n)), 4, task_fn)
    stats = rt.run()
    assert sorted(done) == list(range(n))
    assert sum(stats.per_worker_tasks) == n


def test_lw_every_task_exactly_once():
    n = 40
    done = []
    lock = threading.Lock()

    def task_fn(wid, task):
        with lock:
            done.append(task)

    stats = LWRuntime(list(range(n)), 3, task_fn).run()
    assert sorted(done) == list(range(n))
    assert sum(stats.per_worker_tasks) == n


def test_a2ws_fast_worker_executes_more():
    """2 workers, one 8x slower: the fast one must end up with more tasks
    (stealing happened) and the slow one with fewer than the static half."""
    n = 30
    slow = {1}

    def task_fn(wid, task):
        _busy(0.016 if wid in slow else 0.002)

    rt = A2WSRuntime(list(range(n)), 2, task_fn, seed=3)
    stats = rt.run()
    assert sum(stats.per_worker_tasks) == n
    assert len(stats.steals) > 0, "no steals happened"
    assert stats.per_worker_tasks[0] > stats.per_worker_tasks[1]
    assert stats.per_worker_tasks[1] < n // 2


def test_a2ws_worker_failure_tasks_survive():
    """A dying worker re-queues its task; survivors finish everything."""
    n = 24
    done = []
    lock = threading.Lock()

    def task_fn(wid, task):
        if wid == 2:
            raise RuntimeError("injected node failure")
        _busy(0.001)
        with lock:
            done.append(task)

    rt = A2WSRuntime(list(range(n)), 3, task_fn, seed=0)
    stats = rt.run()
    assert sorted(done) == list(range(n))
    assert len(rt.errors) >= 1
    assert stats.per_worker_tasks[2] == 0


def _correction_pool(clock_now):
    """3-worker closed-mode pool with a hand-set info state.

    The thief (worker 0) believes the victim (worker 1) has n=8 TOTAL tasks
    at t=2.0 s each; the thief's elapsed wall clock is 10 s, so the §2.2.1
    estimate says the victim has executed min(10/2, 8) = 5 of them — a
    queued estimate of 3.  ``done_est`` for the Table 1 reconciliation is
    therefore 5.
    """
    pool = WorkerPool([], 3, lambda w, t: None, policy="a2ws", radius=1,
                      clock=lambda: clock_now[0])
    pool.info.record_remote(0, 1, 8.0, 2.0)
    return pool


def test_closed_failed_steal_correction_keeps_done_estimate():
    """Bugfix regression: a failed steal on a DRAINED victim must reconcile
    its total to done_est + observed queue (5 + 0), not leave the stale full
    n=8 in place (the old ``n_view - observed_left`` rule)."""
    clock_now = [10.0]
    pool = _correction_pool(clock_now)
    pool.policy.on_boundary = lambda view: StealPlan(1, 1)
    assert not pool._policy_boundary(0)  # victim deque is empty -> failure
    assert pool.info.n[0, 1] == pytest.approx(5.0)


def test_closed_successful_steal_reconciles_total_from_snapshot():
    """Bugfix regression: after a successful steal the victim's total is
    done_est + observed remaining queue (5 + 1), not the stale-view
    ``n_view - got`` (7) — the get-accumulate snapshot is ground truth for
    the queued part."""
    clock_now = [10.0]
    pool = _correction_pool(clock_now)
    pool.workers[1].deque.push(["a", "b"])  # ground truth: 2 queued
    pool.policy.on_boundary = lambda view: StealPlan(1, 1)
    assert pool._policy_boundary(0)
    assert pool.info.n[0, 1] == pytest.approx(6.0)


def test_a2ws_single_worker_degenerates():
    done = []
    rt = A2WSRuntime(list(range(5)), 1, lambda w, t: done.append(t))
    stats = rt.run()
    assert sorted(done) == list(range(5))
    assert stats.steals == []


def test_ctws_token_steals_only_when_empty():
    n = 40
    slow = {1}

    def task_fn(wid, task):
        _busy(0.008 if wid in slow else 0.001)

    rt = CTWSRuntime(list(range(n)), 2, task_fn)
    stats = rt.run()
    assert sum(stats.per_worker_tasks) == n
    # fast worker should have taken over some of the slow one's tasks
    assert stats.per_worker_tasks[0] > stats.per_worker_tasks[1]


def test_lw_leader_overhead_slows_worker0():
    """Fig. 5b structure: the co-located leader thread slows worker 0.

    Tasks sleep (GIL-free) so thread scheduling reflects the modelled rates;
    the robust, deterministic signal is the recorded per-task mean time —
    worker 0's includes the leader_overhead busy-wait, so it must be ~2x the
    others'.  Task counts are a noisy proxy (leader round-trips quantise
    them), so they only get a loose monotonicity check.
    """
    n = 30

    def task_fn(wid, task):
        time.sleep(0.008)

    stats = LWRuntime(
        list(range(n)), 3, task_fn, leader_overhead=1.0
    ).run()
    mean_t = stats.per_worker_mean_t
    assert mean_t[0] > 1.15 * max(mean_t[1:])
    # worker 0 runs each task ~2x as long -> it cannot execute the most
    assert stats.per_worker_tasks[0] <= min(stats.per_worker_tasks[1:]) + 2

"""Pallas FD3D kernel vs the pure-jnp oracle: shape/dtype/block sweeps in
interpret mode (the container is CPU; TPU is the target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fd3d import fd3d_step
from repro.kernels.fd3d.fd3d import fd3d_pallas
from repro.kernels.fd3d.ref import fd3d_step as ref_step, laplacian, HALO


def _fields(shape, dtype=jnp.float32, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    u = jax.random.normal(k1, shape, dtype)
    up = jax.random.normal(k2, shape, dtype)
    c2 = jnp.full(shape, 0.1, dtype)
    return u, up, c2


@pytest.mark.parametrize("shape,bz", [
    ((8, 16, 16), 8),
    ((16, 16, 16), 8),
    ((16, 24, 16), 4),     # bz smaller than a block row
    ((32, 16, 32), 16),    # multiple blocks, wide x
    ((8, 8, 8), 4),
])
def test_pallas_matches_ref_shapes(shape, bz):
    u, up, c2 = _fields(shape)
    got = fd3d_pallas(u, up, c2, dx=10.0, bz=bz, interpret=True)
    want = ref_step(u, up, c2, 10.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_dtypes(dtype):
    u, up, c2 = _fields((8, 16, 16), dtype)
    got = fd3d_pallas(u, up, c2, dx=5.0, bz=4, interpret=True)
    want = ref_step(u, up, c2, 5.0)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_laplacian_of_quadratic_is_constant():
    """lap(x^2 + y^2 + z^2) == 6 exactly for an 8th-order stencil."""
    n = 24
    ax = jnp.arange(n, dtype=jnp.float32)
    x, y, z = jnp.meshgrid(ax, ax, ax, indexing="ij")
    u = x * x + y * y + z * z
    lap = laplacian(u, dx=1.0)
    core = lap[HALO + 1 : -HALO - 1, HALO + 1 : -HALO - 1, HALO + 1 : -HALO - 1]
    np.testing.assert_allclose(np.asarray(core), 6.0, rtol=1e-3, atol=1e-3)


def test_invalid_blocks_raise():
    u, up, c2 = _fields((12, 16, 16))
    with pytest.raises(ValueError):
        fd3d_pallas(u, up, c2, dx=1.0, bz=8, interpret=True)  # 12 % 8 != 0
    with pytest.raises(ValueError):
        fd3d_pallas(u, up, c2, dx=1.0, bz=2, interpret=True)  # bz < HALO


def test_ops_backend_dispatch():
    u, up, c2 = _fields((8, 16, 16))
    a = fd3d_step(u, up, c2, dx=10.0, backend="ref")
    b = fd3d_step(u, up, c2, dx=10.0, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)

"""A2WS-scheduled heterogeneous data parallelism: gradient exactness under
stealing, straggler mitigation, fault tolerance, elasticity."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import ResilientDriver
from repro.runtime.het_dp import HetDPTrainer, WorkerSpec


def _toy():
    """Tiny least-squares problem; loss_fn(params, batch) -> (loss, aux)."""
    w_true = jnp.asarray([1.0, -2.0, 0.5])

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        err = pred - batch["y"]
        return jnp.mean(err**2), {"n": err.shape[0]}

    def make_microbatches(step, t=8, n=4):
        rng = np.random.default_rng(step)
        out = []
        for _ in range(t):
            x = rng.normal(size=(n, 3)).astype(np.float32)
            y = x @ np.asarray(w_true)
            out.append({"x": jnp.asarray(x), "y": jnp.asarray(y)})
        return out

    params = {"w": jnp.zeros(3)}
    return loss_fn, params, make_microbatches


def _full_batch_grad(loss_fn, params, mbs):
    g_total = None
    for mb in mbs:
        _, g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_total = g if g_total is None else jax.tree.map(jnp.add, g_total, g)
    return jax.tree.map(lambda x: x / len(mbs), g_total)


def test_gradient_exact_regardless_of_stealing():
    """The combined A2WS gradient == the single-worker full-batch gradient,
    no matter who computed which microbatch."""
    loss_fn, params, make_mbs = _toy()
    mbs = make_mbs(0)
    want = _full_batch_grad(loss_fn, params, mbs)

    # reference update on one worker
    ref = HetDPTrainer(loss_fn, params, [WorkerSpec("solo")],
                       AdamWConfig(lr=0.1, weight_decay=0.0))
    ref.step(mbs)

    # heterogeneous pair with forced stealing
    het = HetDPTrainer(
        loss_fn, {"w": jnp.zeros(3)},
        [WorkerSpec("fast"), WorkerSpec("slow", slow_factor=6.0)],
        AdamWConfig(lr=0.1, weight_decay=0.0), base_task_time=0.003,
    )
    het.step(make_mbs(0))
    np.testing.assert_allclose(
        np.asarray(ref.params["w"]), np.asarray(het.params["w"]), atol=1e-5
    )
    del want


def test_straggler_mitigation_fast_does_more():
    loss_fn, params, make_mbs = _toy()
    tr = HetDPTrainer(
        loss_fn, params,
        [WorkerSpec("fast"), WorkerSpec("slow", slow_factor=8.0)],
        base_task_time=0.004,
    )
    m = tr.step(make_mbs(0, t=12))
    assert sum(m["tasks_per_worker"]) == 12
    assert m["tasks_per_worker"][0] > m["tasks_per_worker"][1]


def test_worker_failure_step_still_completes():
    loss_fn, params, make_mbs = _toy()
    tr = HetDPTrainer(
        loss_fn, params,
        [WorkerSpec("ok"), WorkerSpec("dies", fail_at_step=0)],
    )
    m = tr.step(make_mbs(0))
    assert m["failed_workers"] == [1]
    assert sum(m["tasks_per_worker"]) == 8  # survivors finished everything


def test_elastic_add_remove():
    loss_fn, params, make_mbs = _toy()
    tr = HetDPTrainer(loss_fn, params, [WorkerSpec("a"), WorkerSpec("b")])
    tr.step(make_mbs(0))
    tr.remove_worker(1)
    m = tr.step(make_mbs(1))
    assert len(m["tasks_per_worker"]) == 1
    tr.add_worker(WorkerSpec("c"))
    m = tr.step(make_mbs(2))
    assert len(m["tasks_per_worker"]) == 2
    assert sum(m["tasks_per_worker"]) == 8


def test_compression_path_still_converges():
    """int8+EF compression adds quantisation noise but must keep converging
    (error feedback prevents bias accumulation)."""
    loss_fn, params, make_mbs = _toy()
    tr = HetDPTrainer(
        loss_fn, params, [WorkerSpec("a"), WorkerSpec("b")],
        AdamWConfig(lr=0.05, weight_decay=0.0), compress=True,
    )
    first = None
    for step in range(60):
        m = tr.step(make_mbs(step))
        if first is None:
            first = m["loss"]
    assert m["loss"] < min(1.0, first / 4), (first, m["loss"])


def test_resilient_driver_restart(tmp_path):
    loss_fn, params, make_mbs = _toy()
    tr = HetDPTrainer(
        loss_fn, params,
        [WorkerSpec("a"), WorkerSpec("dies", fail_at_step=3)],
        AdamWConfig(lr=0.05, weight_decay=0.0),
    )
    drv = ResilientDriver(tr, make_mbs, str(tmp_path), ckpt_every=2)
    report = drv.run(8)
    assert report.steps_run == 8
    assert "dies" in report.removed_workers
    assert len(tr.workers) == 1
    assert np.isfinite(report.final_loss)

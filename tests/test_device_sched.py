"""Device data-plane scheduler: jnp formulas == host formulas; the jitted
shard_map/ppermute cluster balances and conserves tasks.

The multi-worker parts run in a SUBPROCESS with forced host devices so this
process keeps the single real CPU device (smoke tests must see 1 device).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import steal
from repro.core.device_sched import gamma_round, steal_rate_window

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_steal_rate_window_matches_host():
    rng = np.random.default_rng(0)
    for _ in range(30):
        p, r = 9, 2
        n = rng.integers(0, 30, p).astype(float)
        t = rng.uniform(0.1, 5.0, p)
        for i in range(p):
            idx = steal.neighborhood(i, p, r)
            win_n = jnp.asarray([n[j] for j in idx], jnp.float32)
            win_t = jnp.asarray([t[j] for j in idx], jnp.float32)
            got = float(steal_rate_window(win_n, win_t, r))
            want = steal.steal_rate_radius(i, n, t, r)
            assert got == pytest.approx(want, rel=2e-4, abs=2e-3)


def test_gamma_round_matches_host():
    rng = np.random.default_rng(1)
    for _ in range(60):
        s = rng.uniform(0, 10)
        n_i, n_j = rng.uniform(0, 20, 2)
        t_i, t_j = rng.uniform(0.1, 3.0, 2)
        got = int(gamma_round(jnp.float32(s), n_i, t_i, n_j, t_j))
        want = steal.round_steal_rate(s, n_i, t_i, n_j, t_j)
        assert got == want, (s, n_i, t_i, n_j, t_j)


_SUBPROC = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    from repro.core.device_sched import virtual_run
    mesh = jax.make_mesh((8,), ("workers",))
    speeds = [24, 16, 8, 8, 4, 2, 1, 1]
    state, rounds, makespan = virtual_run(
        mesh, "workers", speeds, num_tasks=192, radius=2, max_steal=8
    )
    executed = [int(x) for x in state.executed]
    remaining = int((state.tail - state.head).sum())
    print(json.dumps({{"executed": executed, "rounds": rounds,
                       "makespan": makespan, "remaining": remaining}}))
    """
)


@pytest.mark.slow
def test_virtual_cluster_balances():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC.format(src=SRC)],
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    executed = np.asarray(res["executed"])
    assert res["remaining"] == 0
    assert executed.sum() == 192  # conservation inside the jitted program
    # fast workers executed more (speeds 24..1)
    assert executed[0] > executed[-1]
    # virtual makespan beats the static partition bound (24 tasks at speed 1)
    assert res["makespan"] < 24.0 * 0.8

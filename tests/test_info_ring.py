"""Information ring (paper §2.1): write partition, hop-by-hop propagation,
dirty-flag suppression (Table 1)."""

import numpy as np

from repro.core.info_ring import RingInfo


def test_local_update_and_view():
    r = RingInfo(4, 1)
    r.update_local(0, 10.0, 2.0)
    n, t = r.view(0)
    assert n[0] == 10.0 and t[0] == 2.0


def test_propagation_one_hop_per_round():
    """Process 0's info reaches distance d after d communicate() rounds."""
    p, rad = 8, 3
    r = RingInfo(p, rad)
    r.update_local(0, 42.0, 1.5)
    for d in range(1, rad + 1):
        # a full round: everyone communicates once
        for i in range(p):
            r.communicate(i)
        assert r.n[d % p, 0] == 42.0, f"right neighbour at distance {d}"
        assert r.n[(-d) % p, 0] == 42.0, f"left neighbour at distance {d}"
    # beyond the radius: never arrives
    for i in range(p):
        r.communicate(i)
    assert r.n[rad + 1, 0] == 0.0
    assert r.n[p - rad - 1, 0] == 0.0


def test_dirty_flag_suppression():
    """Unchanged cells are not re-sent (Table 1: only new information)."""
    r = RingInfo(6, 2)
    r.update_local(0, 5.0, 1.0)
    for i in range(6):
        r.communicate(i)
    puts_after_first = r.puts
    for i in range(6):
        r.communicate(i)
    second_round = r.puts - puts_after_first
    for i in range(6):
        r.communicate(i)
    third_round = r.puts - puts_after_first - second_round
    assert third_round == 0  # everything stale by round 3 -> silence


def test_write_partition_no_overlap():
    """For each destination vector cell there is exactly ONE writer —
    the §2.1 partition that makes lock-free Puts safe."""
    p, rad = 8, 2
    writers: dict[tuple[int, int], set[int]] = {}
    r = RingInfo(p, rad)

    orig_put = r._put

    def tracking_put(src, dst, j, direction):
        writers.setdefault((dst, j), set()).add(src)
        return orig_put(src, dst, j, direction)

    r._put = tracking_put
    rng = np.random.default_rng(0)
    for step in range(60):
        i = int(rng.integers(0, p))
        r.update_local(i, float(rng.integers(0, 20)), float(rng.random() + 0.1))
        r.communicate(i)
    for (dst, j), srcs in writers.items():
        assert len(srcs) == 1, f"cell ({dst},{j}) written by {srcs}"
        assert dst != j  # own cell is written locally, never remotely


def test_record_remote_propagates_thief_news():
    """Table 1 rows 2-3: a thief's first-hand knowledge of the victim
    propagates outward from the THIEF."""
    r = RingInfo(6, 2)
    for i in range(6):
        r.update_local(i, 10.0, 1.0)
        r.communicate(i)
    # thief 0 stole 4 tasks from victim 1
    r.record_remote(0, 1, 6.0, 1.0)
    r.communicate(0)
    assert r.n[5, 1] == 6.0  # left neighbour of 0 heard the news from 0


def test_radius_zero_or_single_process_noop():
    r = RingInfo(1, 2)
    assert r.communicate(0) == 0
    r2 = RingInfo(4, 0)
    assert r2.communicate(1) == 0

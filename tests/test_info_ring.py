"""Information ring (paper §2.1): write partition, hop-by-hop propagation,
dirty-flag suppression (Table 1)."""

import numpy as np
import pytest

from repro.core.info_ring import RingInfo


def test_local_update_and_view():
    r = RingInfo(4, 1)
    r.update_local(0, 10.0, 2.0)
    n, t = r.view(0)
    assert n[0] == 10.0 and t[0] == 2.0


def test_propagation_one_hop_per_round():
    """Process 0's info reaches distance d after d communicate() rounds."""
    p, rad = 8, 3
    r = RingInfo(p, rad)
    r.update_local(0, 42.0, 1.5)
    for d in range(1, rad + 1):
        # a full round: everyone communicates once
        for i in range(p):
            r.communicate(i)
        assert r.n[d % p, 0] == 42.0, f"right neighbour at distance {d}"
        assert r.n[(-d) % p, 0] == 42.0, f"left neighbour at distance {d}"
    # beyond the radius: never arrives
    for i in range(p):
        r.communicate(i)
    assert r.n[rad + 1, 0] == 0.0
    assert r.n[p - rad - 1, 0] == 0.0


def test_dirty_flag_suppression():
    """Unchanged cells are not re-sent (Table 1: only new information)."""
    r = RingInfo(6, 2)
    r.update_local(0, 5.0, 1.0)
    for i in range(6):
        r.communicate(i)
    puts_after_first = r.puts
    for i in range(6):
        r.communicate(i)
    second_round = r.puts - puts_after_first
    for i in range(6):
        r.communicate(i)
    third_round = r.puts - puts_after_first - second_round
    assert third_round == 0  # everything stale by round 3 -> silence


def test_write_partition_no_overlap():
    """For each destination vector cell there is exactly ONE writer —
    the §2.1 partition that makes lock-free Puts safe."""
    p, rad = 8, 2
    writers: dict[tuple[int, int], set[int]] = {}
    r = RingInfo(p, rad)

    orig_put = r._put

    def tracking_put(src, dst, j, direction):
        writers.setdefault((dst, j), set()).add(src)
        return orig_put(src, dst, j, direction)

    r._put = tracking_put
    rng = np.random.default_rng(0)
    for step in range(60):
        i = int(rng.integers(0, p))
        r.update_local(i, float(rng.integers(0, 20)), float(rng.random() + 0.1))
        r.communicate(i)
    for (dst, j), srcs in writers.items():
        assert len(srcs) == 1, f"cell ({dst},{j}) written by {srcs}"
        assert dst != j  # own cell is written locally, never remotely


def test_record_remote_propagates_thief_news():
    """Table 1 rows 2-3: a thief's first-hand knowledge of the victim
    propagates outward from the THIEF."""
    r = RingInfo(6, 2)
    for i in range(6):
        r.update_local(i, 10.0, 1.0)
        r.communicate(i)
    # thief 0 stole 4 tasks from victim 1
    r.record_remote(0, 1, 6.0, 1.0)
    r.communicate(0)
    assert r.n[5, 1] == 6.0  # left neighbour of 0 heard the news from 0


def test_radius_zero_or_single_process_noop():
    r = RingInfo(1, 2)
    assert r.communicate(0) == 0
    r2 = RingInfo(4, 0)
    assert r2.communicate(1) == 0


def test_view_unknown_t_falls_back_to_subsystem_mean():
    """PR 2 fix: a NaN t cell fills with the MEAN of the known t's (not a
    flat 1.0 s guess, which poisons Eq. 5 for sub-millisecond tasks)."""
    r = RingInfo(6, 2)
    # Own cell still NaN, but two neighbours reported 2ms tasks.
    r.record_remote(0, 1, 5.0, 2e-3)
    r.record_remote(0, 2, 7.0, 2e-3)
    _, t = r.view(0)
    np.testing.assert_allclose(t, 2e-3)  # every unknown = subsystem mean
    # Explicit default wins over the mean.
    _, t = r.view(0, default_t=5e-4)
    assert t[0] == 5e-4 and t[1] == 2e-3 and t[2] == 2e-3
    # Nothing known at all: a flat constant (cancels out of Eq. 5).
    _, t_blank = RingInfo(4, 1).view(0)
    np.testing.assert_allclose(t_blank, 1.0)


# ----------------------------------------------------------------- elasticity
def test_grow_preserves_state_and_marks_joiners_unreported():
    """DESIGN.md §Elasticity: growth carries every existing cell over
    verbatim and the new positions look exactly like boot members (n=0,
    t=NaN, version=0) so preemptive estimates cover them."""
    r = RingInfo(4, 1)
    r.update_local(0, 7.0, 1.5)
    r.update_local(2, 3.0, 0.5)
    for i in range(4):
        r.communicate(i)
    old_version = r.version.copy()
    r.grow(6, 2)
    assert r.P == 6 and r.R == 2
    assert r.n[0, 0] == 7.0 and r.t[0, 0] == 1.5
    assert (r.version[:4, :4] == old_version).all()
    assert np.isnan(r.t[:, 4:]).all() and (r.n[:, 4:] == 0.0).all()
    assert (r.version[:, 4:] == 0).all()
    # new members participate immediately
    r.update_local(5, 2.0, 0.25)
    r.communicate(5)
    assert r.n[4, 5] == 2.0  # 5's right neighbour (4... ring: 5+1=0; left=4)
    with pytest.raises(ValueError):
        r.grow(3)


def test_reset_member_returns_column_to_unreported_state():
    """Slot reuse (DESIGN.md §Elasticity): a replacement in a tombstoned
    ring position resets everyone's cell about it to the boot state (n=0,
    t=NaN) with a version BUMP, so preemptive estimates price the newcomer
    and observers stay monotone."""
    r = RingInfo(4, 1)
    for i in range(4):
        r.update_local(i, 5.0, 2.0)
        r.communicate(i)
    before = r.version[:, 1].copy()
    r.reset_member(1)
    assert (r.n[:, 1] == 0.0).all() and np.isnan(r.t[:, 1]).all()
    assert (r.version[:, 1] == before + 1).all()
    # the replacement's FIRST report propagates normally from the bumped base
    r.update_local(1, 3.0, 0.5)
    r.communicate(1)
    assert r.n[0, 1] == 3.0 and r.t[0, 1] == 0.5  # left neighbour heard it


def test_grow_same_size_only_updates_radius():
    r = RingInfo(6, 1)
    r.update_local(1, 9.0, 1.0)
    r.grow(6, 2)
    assert r.P == 6 and r.R == 2 and r.n[1, 1] == 9.0


# --------------------------------------------------- concurrency properties
from _hypo import given, settings, st  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(
    p=st.integers(min_value=3, max_value=9),
    radius=st.integers(min_value=1, max_value=4),
    rounds=st.integers(min_value=5, max_value=25),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_version_monotonic_under_concurrent_communicate(p, radius, rounds, seed):
    """Per-cell version counters only ever move FORWARD, even with every
    process communicating concurrently (the §2.1 single-writer partition is
    what makes the lock-free Puts safe), and no view ever runs ahead of the
    owner's own version (staleness >= 0)."""
    import threading

    r = RingInfo(p, radius)
    snapshots: list[np.ndarray] = []
    snap_lock = threading.Lock()
    rng = np.random.default_rng(seed)
    plans = [
        [(float(rng.integers(0, 50)), float(rng.random() + 1e-3))
         for _ in range(rounds)]
        for _ in range(p)
    ]

    def worker(i: int) -> None:
        for n_i, t_i in plans[i]:
            r.update_local(i, n_i, t_i)
            r.communicate(i)
            with snap_lock:
                snapshots.append(r.version.copy())

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(p)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    # 1) per-cell monotonicity across the snapshot sequence
    for prev, cur in zip(snapshots, snapshots[1:]):
        assert (cur >= prev).all(), "a version counter moved backwards"
    # 2) with only owner writes + ring propagation, nobody's view of j can
    #    be newer than j's own cell: staleness is non-negative everywhere
    truth = r.version.diagonal().copy()
    assert (r.staleness(truth) >= 0).all()
    # 3) and the owner's own cell saw every local update exactly once
    for i in range(p):
        expected = sum(
            1 for k, (n_i, t_i) in enumerate(plans[i])
            if k == 0 or plans[i][k - 1] != (n_i, t_i)
        )
        assert r.version[i, i] == expected


@settings(max_examples=25, deadline=None)
@given(
    p0=st.integers(min_value=2, max_value=6),
    radius=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    script=st.lists(
        st.sampled_from(["update", "communicate", "record", "grow"]),
        min_size=5, max_size=40,
    ),
)
def test_grow_preserves_version_monotonicity(p0, radius, seed, script):
    """Elasticity property (ISSUE 3): interleaving ``grow`` with local
    updates, thief records and ring propagation NEVER moves a version
    counter backwards for any pre-existing cell, never lets a view run
    ahead of the owner (staleness >= 0 with owner-only writes), and every
    growth leaves the old board block intact."""
    rng = np.random.default_rng(seed)
    r = RingInfo(p0, radius)
    prev = r.version.copy()
    for op in script:
        if op == "update":
            i = int(rng.integers(0, r.P))
            r.update_local(i, float(rng.integers(0, 30)), float(rng.random() + 0.1))
        elif op == "communicate":
            r.communicate(int(rng.integers(0, r.P)))
        elif op == "record":
            i, j = rng.integers(0, r.P, size=2)
            r.record_remote(int(i), int(j), float(rng.integers(0, 30)), 1.0)
        else:
            r.grow(r.P + int(rng.integers(1, 3)))
        common = prev.shape[0]
        assert (r.version[:common, :common] >= prev).all(), (
            "a version counter moved backwards across " + op
        )
        prev = r.version.copy()
    truth = r.version.diagonal().copy()
    # record_remote legitimately advances a thief's cell past the owner's
    # (first-hand knowledge); owner-only scripts keep staleness >= 0.
    if "record" not in script:
        assert (r.staleness(truth) >= 0).all()

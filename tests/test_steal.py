"""Unit + property tests for the smart-stealing math (paper §2.2, Eqs. 2-10)."""

import math

import numpy as np
import pytest

from _hypo import given, settings, st  # skips properties w/o hypothesis

from repro.core import steal

pos_floats = st.floats(min_value=0.05, max_value=50.0, allow_nan=False)


def test_ideal_runtime_homogeneous():
    # Eq. 2: t_ideal = N/T; 20 tasks, system speed 2*(1/2)=1 -> 20 s
    # (each of the 2 workers runs 10 tasks x 2 s).
    assert steal.ideal_runtime([10, 10], [2.0, 2.0]) == pytest.approx(20.0)


def test_steal_rate_balanced_system_is_zero():
    # Equal speeds, equal loads: nobody needs to steal (Eq. 4).
    n = [5, 5, 5, 5]
    t = [1.0, 1.0, 1.0, 1.0]
    for i in range(4):
        assert steal.steal_rate(i, n, t) == pytest.approx(0.0)


def test_steal_rate_fast_process_steals():
    # 2x faster process with the same load must have S_i > 0 (Eq. 4).
    n = [6, 6]
    t = [0.5, 1.0]
    assert steal.steal_rate(0, n, t) > 0
    assert steal.steal_rate(1, n, t) < 0


def test_steal_rate_matches_closed_form():
    # Worked example: S_i = N/(t_i T) - n_i.
    n = [4.0, 8.0, 2.0]
    t = [1.0, 2.0, 4.0]
    big_t = 1 / 1.0 + 1 / 2.0 + 1 / 4.0
    expected = 14.0 / (1.0 * big_t) - 4.0
    assert steal.steal_rate(0, n, t) == pytest.approx(expected)


@given(
    n=st.lists(st.integers(0, 40).map(float), min_size=2, max_size=9),
    t=st.lists(pos_floats, min_size=9, max_size=9),
)
@settings(max_examples=80, deadline=None)
def test_full_radius_equals_global(n, t):
    # Eq. 5 with R covering the ring == Eq. 4.
    p = len(n)
    t = t[:p]
    for i in range(p):
        assert steal.steal_rate_radius(i, n, t, radius=p) == pytest.approx(
            steal.steal_rate(i, n, t), rel=1e-9, abs=1e-9
        )


@given(
    n=st.lists(st.integers(0, 40).map(float), min_size=2, max_size=8),
    t=st.lists(pos_floats, min_size=8, max_size=8),
)
@settings(max_examples=80, deadline=None)
def test_weighted_steal_rates_conserve(n, t):
    # Σ_i S_i / t_i-weighted identity: Σ (S_i + n_i) = N when every process
    # uses global info (task conservation under the ideal redistribution).
    p = len(n)
    t = t[:p]
    tot = sum(steal.steal_rate(i, n, t) + n[i] for i in range(p))
    assert tot == pytest.approx(sum(n), rel=1e-6, abs=1e-6)


def test_pair_rate_simplification():
    # Eq. 9 == Eq. 10 after simplification.
    n_i, t_i, n_j, t_j = 3.0, 0.5, 9.0, 1.5
    eq9 = (n_i + n_j) / (t_i * (1 / t_i + 1 / t_j)) - n_i
    assert steal.pair_steal_rate(n_i, t_i, n_j, t_j) == pytest.approx(eq9)


def test_pair_rate_balanced_pair_zero():
    # i twice as fast with twice the tasks: already balanced.
    assert steal.pair_steal_rate(8.0, 0.5, 4.0, 1.0) == pytest.approx(0.0)


@given(
    s=st.floats(min_value=0.0, max_value=20.0),
    n_i=st.floats(min_value=0, max_value=50),
    t_i=pos_floats,
    n_j=st.floats(min_value=0, max_value=50),
    t_j=pos_floats,
)
@settings(max_examples=120, deadline=None)
def test_gamma_rounding_optimal(s, n_i, t_i, n_j, t_j):
    # Eq. 7: the chosen integer minimises γ over {floor, ceil}.
    d = steal.round_steal_rate(s, n_i, t_i, n_j, t_j)
    g_d = steal.gamma(d, n_i, t_i, n_j, t_j)
    for cand in (math.floor(s), math.ceil(s)):
        assert g_d <= steal.gamma(cand, n_i, t_i, n_j, t_j) + 1e-9


def test_gamma_is_pair_makespan():
    # γ(S) = max of victim/thief runtimes after moving S tasks (Eq. 8, with
    # the dimensionally-consistent product form of Eq. 6 — see steal.py).
    g = steal.gamma(2.0, n_thief=4, t_thief=1.0, n_victim=10, t_victim=2.0)
    assert g == pytest.approx(max((10 - 2) * 2.0, (4 + 2) * 1.0))


def test_neighborhood_ring_wraps():
    assert steal.neighborhood(0, 8, 2) == [6, 7, 0, 1, 2]
    assert steal.neighborhood(7, 8, 1) == [6, 7, 0]
    # radius covering everything -> every process once
    assert steal.neighborhood(3, 5, 4) == [0, 1, 2, 3, 4]


def test_victim_selection_prefers_surplus():
    rng = np.random.default_rng(0)
    # worker 0 fast & starving, worker 2 slow & loaded
    n = [2.0, 4.0, 12.0]
    t = [0.5, 1.0, 2.0]
    queued = [0.0, 2.0, 10.0]
    cand, w, crit = steal.victim_weights(0, n, t, queued, radius=1)
    assert crit == "closest-rate"
    assert 2 in list(cand)
    picks = [steal.select_victim(rng, 0, n, t, queued, 1)[0] for _ in range(50)]
    assert picks.count(2) > picks.count(1)


def test_victim_selection_empty_queues_gives_none():
    rng = np.random.default_rng(0)
    v, _ = steal.select_victim(rng, 0, [5, 5], [1.0, 1.0], [0.0, 0.0], 1)
    assert v is None


def test_in_pair_fallback_when_balanced():
    # All S_j >= 0 (system looks balanced) but queues non-empty -> criterion 2.
    n = [1.0, 1.0]
    t = [0.5, 1.0]  # process 0 faster; in-pair says steal from 1
    queued = [0.0, 1.0]
    cand, w, crit = steal.victim_weights(0, n, t, queued, radius=1)
    assert crit in ("closest-rate", "in-pair")
    if crit == "in-pair":
        assert list(cand) == [1]


def test_plan_steal_clamps_to_queue():
    rng = np.random.default_rng(1)
    n = [0.0, 100.0]
    t = [0.1, 1.0]
    queued = [0.0, 3.0]  # victim only has 3 left
    d = steal.plan_steal(rng, 0, n, t, queued, radius=1)
    assert d is not None and d.amount <= 3


def test_plan_steal_surplus_process_declines():
    rng = np.random.default_rng(1)
    n = [100.0, 1.0]
    t = [1.0, 1.0]
    assert steal.plan_steal(rng, 0, n, t, [99.0, 1.0], radius=1) is None

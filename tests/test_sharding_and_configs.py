"""Sharding rules, batch/cache partition specs, config registry + shapes."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, get_smoke, input_specs
from repro.configs.base import shape_applicable
from repro.parallel.sharding import (
    DEFAULT_RULES,
    ParallelContext,
    make_context,
    spec_for,
)


def _fake_ctx():
    """Mesh-free context but with rules (spec_for works without a mesh)."""
    return ParallelContext(mesh=None)


# ------------------------------------------------------------------ rules
def test_spec_for_basic_rules():
    ctx = _fake_ctx()
    assert spec_for(("embed", "ffn"), ctx) == P("data", "model")
    assert spec_for(("vocab", "embed"), ctx) == P("model", "data")
    assert spec_for(("layers", "experts", "embed", "ffn"), ctx) == P(
        None, "model", "data", None  # ffn loses: 'model' already used
    )


def test_spec_for_duplicate_axis_guard():
    ctx = _fake_ctx()
    assert spec_for(("ffn", "ffn"), ctx) == P("model", None)


def test_config_registry_complete():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.name == a
        smoke = get_smoke(a)
        assert smoke.family == cfg.family
        assert smoke.param_count() < 0.05e9


@pytest.mark.parametrize(
    "arch,lo,hi",
    [
        ("moonshot-v1-16b-a3b", 25e9, 30e9),
        ("deepseek-v3-671b", 660e9, 700e9),
        ("qwen2-vl-2b", 1.5e9, 2.2e9),
        ("mistral-nemo-12b", 11e9, 13.5e9),
        ("minitron-4b", 4e9, 6e9),
        ("qwen1.5-32b", 32e9, 37e9),
        ("phi4-mini-3.8b", 3.8e9, 5e9),
        ("recurrentgemma-2b", 2e9, 3.2e9),
        ("mamba2-2.7b", 2.4e9, 3e9),
        ("seamless-m4t-medium", 0.7e9, 1.1e9),
    ],
)
def test_param_counts_plausible(arch, lo, hi):
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B"


def test_active_params_moe():
    cfg = get_config("deepseek-v3-671b")
    act = cfg.active_param_count()
    assert 30e9 <= act <= 45e9  # ~37B active
    dense = get_config("mistral-nemo-12b")
    assert dense.active_param_count() == dense.param_count()


def test_cells_and_applicability():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2]]
    assert len(runnable) == 32
    skipped = [c for c in all_cells if not c[2]]
    assert all(s[1] == "long_500k" for s in skipped)
    ok, _ = shape_applicable(get_config("mamba2-2.7b"), SHAPES["long_500k"])
    assert ok
    ok, reason = shape_applicable(get_config("qwen1.5-32b"), SHAPES["long_500k"])
    assert not ok and "quadratic" in reason


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_shapes(arch):
    cfg = get_config(arch)
    for sh in SHAPES.values():
        specs = input_specs(cfg, sh)
        assert specs, (arch, sh.name)
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)
        if sh.kind == "train":
            assert "labels" in specs
        if sh.kind == "decode":
            assert specs["tokens"].shape == (sh.global_batch, 1)
        if cfg.frontend == "vision" and sh.kind != "decode":
            assert "embeds" in specs and "positions" in specs
        if cfg.enc_layers and sh.kind != "decode":
            assert "enc_embeds" in specs


def test_vocab_padding():
    seam = get_config("seamless-m4t-medium")
    assert seam.vocab_padded % 16 == 0 and seam.vocab_padded >= seam.vocab
    mamba = get_config("mamba2-2.7b")
    assert mamba.vocab_padded % 16 == 0
    nemo = get_config("mistral-nemo-12b")
    assert nemo.vocab_padded == nemo.vocab  # already divisible


def test_scan_groups_cover_all_layers():
    for a in ARCH_IDS:
        cfg = get_config(a)
        groups = cfg.scan_groups()
        total = 0
        for kind, count in groups:
            k = len(kind.split("|")) if kind.startswith("cycle:") else 1
            total += k * count
        assert total == cfg.n_layers, a

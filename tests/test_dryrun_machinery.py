"""Dry-run machinery validated end-to-end on a small forced-device mesh in a
subprocess (the real 512-device sweep runs via launch/dryrun.py), plus the
trip-count-aware HLO analyzer against hand-checkable programs."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ----------------------------------------------------------- HLO analyzer
def test_hlo_flops_single_matmul():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    hlo = jax.jit(lambda x, y: x @ y).lower(a, b).compile().as_text()
    costs = analyze_hlo(hlo)
    assert costs.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_hlo_scan_scales_by_trip_count():
    """XLA cost_analysis counts the while body once; our walk multiplies by
    the known trip count."""
    a = jnp.zeros((32, 32), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    compiled = jax.jit(f).lower(a).compile()
    costs = analyze_hlo(compiled.as_text())
    one_matmul = 2 * 32 * 32 * 32
    assert costs.flops == pytest.approx(10 * one_matmul, rel=0.05)
    xla = compiled.cost_analysis()
    xla = xla[0] if isinstance(xla, list) else xla
    assert float(xla["flops"]) <= costs.flops / 5  # the undercount we fix


def test_hlo_bytes_positive_and_bounded():
    a = jnp.zeros((256, 256), jnp.float32)
    hlo = jax.jit(lambda x: x + 1.0).lower(a).compile().as_text()
    costs = analyze_hlo(hlo)
    assert 2 * a.size * 4 * 0.5 <= costs.bytes <= 10 * a.size * 4


_SUBPROC = textwrap.dedent(
    """
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax
    from repro.configs.base import Shape, get_smoke, input_specs
    from repro.launch.cells import analyze, lower_cell
    from repro.launch.mesh import make_debug_mesh
    from repro.parallel.sharding import make_context

    cfg = get_smoke({arch!r})
    shape = Shape("t", {kind!r}, 32, 4)
    mesh = make_debug_mesh(2, 4) if not {pod} else make_debug_mesh(2, 2, pod=2)
    ctx = make_context(mesh)
    with mesh:
        lowered, meta = lower_cell(cfg, shape, ctx)
        compiled = lowered.compile()
        rec = analyze(lowered, compiled, cfg, shape, mesh.devices.size)
    print(json.dumps({{"flops": rec["flops_per_device"],
                       "coll": rec["collective_bytes_per_device"],
                       "dom": rec["dominant"],
                       "mem": rec["memory"],
                       "useful": rec["useful_flops_ratio"]}}))
    """
)


def _run_cell(arch, kind, pod=False):
    code = _SUBPROC.format(src=SRC, arch=arch, kind=kind, pod=pod)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("arch,kind", [
    ("phi4-mini-3.8b", "train"),
    ("moonshot-v1-16b-a3b", "train"),
    ("mistral-nemo-12b", "decode"),
    ("mamba2-2.7b", "prefill"),
    ("seamless-m4t-medium", "train"),
])
def test_cell_lowers_on_debug_mesh(arch, kind):
    rec = _run_cell(arch, kind)
    assert rec["flops"] > 0
    assert rec["dom"] in ("t_compute", "t_memory", "t_collective")


@pytest.mark.slow
def test_cell_lowers_multipod_debug_mesh():
    rec = _run_cell("phi4-mini-3.8b", "train", pod=True)
    assert rec["flops"] > 0
    assert rec["coll"] > 0  # pod axis forces cross-pod gradient reduction

"""Substrate layers: optimizer, checkpoint store, data pipeline, gradient
compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.runtime.compression import (
    ErrorFeedback,
    dequantize,
    quantize,
)


# ------------------------------------------------------------------ optimizer
def test_adamw_quadratic_convergence():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_matches_reference_formula():
    """One step against a hand-rolled Adam update."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9)
    w0 = jnp.asarray([[1.0, 2.0]])
    params = {"w": w0}
    opt = adamw_init(params, cfg)
    g = {"w": jnp.asarray([[0.5, -1.0]])}
    new, opt, _ = adamw_update(g, opt, params, cfg)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    step = (m / 0.1) / (np.sqrt(v / 0.001) + 1e-8)
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(w0) - 1e-2 * step,
                               rtol=1e-5)


def test_grad_clipping_applied():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params, cfg)
    g = {"w": jnp.asarray([30.0, 40.0, 0.0])}  # norm 50
    _, _, metrics = adamw_update(g, opt, params, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(50.0)
    assert float(metrics["clip_scale"]) == pytest.approx(1 / 50.0)


def test_cosine_lr_schedule_shape():
    assert float(cosine_lr(0, warmup=10, total=100)) == 0.0
    assert float(cosine_lr(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(cosine_lr(100, warmup=10, total=100)) == pytest.approx(0.1)


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    store.save(str(tmp_path), 3, tree)
    restored, step = store.restore(str(tmp_path), tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_latest_step(tmp_path):
    assert store.latest_step(str(tmp_path)) is None
    tree = {"x": jnp.zeros(2)}
    store.save(str(tmp_path), 1, tree)
    store.save(str(tmp_path), 7, tree)
    assert store.latest_step(str(tmp_path)) == 7


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(str(tmp_path))
    tree = {"x": jnp.arange(3)}
    ck.save(5, tree)
    ck.wait()
    assert store.latest_step(str(tmp_path)) == 5
    restored, _ = store.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(3))


# ----------------------------------------------------------------------- data
def test_data_deterministic_per_step():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4, seed=3)
    a = SyntheticLM(cfg).batch_at(7)
    b = SyntheticLM(cfg).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert (a["tokens"] >= 0).all() and (a["tokens"] < 1000).all()
    # labels are next-token shifted views of the same stream
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_shard_invariance():
    """Two shards concatenated == the single-shard global batch (the exact
    elastic-resharding property)."""
    base = DataConfig(vocab=1000, seq_len=8, global_batch=4, seed=0)
    full = SyntheticLM(base).batch_at(5)["tokens"]
    s0 = SyntheticLM(DataConfig(1000, 8, 4, 0, num_shards=2, shard=0)).batch_at(5)
    s1 = SyntheticLM(DataConfig(1000, 8, 4, 0, num_shards=2, shard=1)).batch_at(5)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), full
    )


def test_prefetcher_yields_in_order():
    cfg = DataConfig(vocab=100, seq_len=4, global_batch=2, seed=1)
    data = SyntheticLM(cfg)
    pf = Prefetcher(iter(data), depth=2)
    first = next(pf)
    np.testing.assert_array_equal(first["tokens"], data.batch_at(0)["tokens"])
    second = next(pf)
    np.testing.assert_array_equal(second["tokens"], data.batch_at(1)["tokens"])
    pf.close()


# ---------------------------------------------------------------- compression
def test_quantize_roundtrip_error_bounded():
    x = np.random.default_rng(0).normal(size=(64,)).astype(np.float32)
    q, scale = quantize(x)
    err = np.abs(dequantize(q, scale) - x).max()
    assert err <= scale / 2 + 1e-7


def test_error_feedback_bias_vanishes():
    """With EF, the ACCUMULATED compressed sum tracks the true sum — the
    compression bias does not accumulate (Karimireddy et al.)."""
    rng = np.random.default_rng(1)
    ef = ErrorFeedback()
    true_sum = np.zeros(32, np.float32)
    got_sum = np.zeros(32, np.float32)
    for _ in range(60):
        g = {"w": rng.normal(size=32).astype(np.float32)}
        true_sum += g["w"]
        packed = ef.compress(g)
        got_sum += ErrorFeedback.decompress(packed)["w"]
    # residual is bounded by one quantisation step, not 60 of them
    assert np.abs(true_sum - got_sum).max() < 0.2 * np.abs(true_sum).max() + 0.5

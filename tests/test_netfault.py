"""Fault fabric (DESIGN.md §Fault fabric): the NetFaultSchedule model, the
``netfaults=None`` / empty-schedule conformance property in both planes,
leased two-phase transfers (exactly-once under drops), the no-retry
ablation's honest at-least-... at-most-once loss accounting, partition
degradation + heal reconciliation, link-health victim weighting, and the
serve-plane partition routing."""

import math
import time

import numpy as np
import pytest

from _hypo import given, settings, st  # skips properties w/o hypothesis
from repro.core.a2ws import WorkerPool
from repro.core.info_ring import RingInfo
from repro.core.limp import effective_heartbeat
from repro.core.netfault import (
    LinkFault,
    LinkHealth,
    NetFaultSchedule,
    PartitionEvent,
    parse_netfaults,
    validate_netfaults,
)
from repro.core.policy import HierarchicalA2WSPolicy
from repro.core.simulator import SimConfig, simulate, table2_speeds
from repro.core.steal import victim_weights
from repro.serve.engine import Replica, ServePool


# ------------------------------------------------------------------ the model
def test_link_fault_matching_and_validation():
    f = LinkFault(src=0, dst=1, start=1.0, duration=2.0, drop_prob=0.5)
    assert f.matches(0, 1, 1.0) and f.matches(0, 1, 2.9)
    assert not f.matches(0, 1, 3.0)  # half-open window
    assert not f.matches(1, 0, 2.0)  # directed
    wild = LinkFault(drop_prob=0.1)  # src/dst None = every link, forever
    assert wild.matches(7, 3, 1e9)
    with pytest.raises(ValueError):
        LinkFault(drop_prob=1.5)
    with pytest.raises(ValueError):
        LinkFault(drop_prob=-0.1)
    with pytest.raises(ValueError):
        LinkFault(extra_delay=-1.0)
    with pytest.raises(ValueError):
        LinkFault(duration=-1.0)


def test_drop_prob_composes_complementarily():
    nf = NetFaultSchedule(faults=(
        LinkFault(drop_prob=0.5), LinkFault(drop_prob=0.5),
    ))
    assert nf.drop_prob(0, 1, 0.0) == pytest.approx(0.75)
    assert nf.drop_prob(3, 3, 0.0) == 0.0  # self-link is always clean
    nf2 = NetFaultSchedule(faults=(LinkFault(src=0, dst=1, drop_prob=0.3),))
    assert nf2.drop_prob(0, 1, 0.0) == pytest.approx(0.3)
    assert nf2.drop_prob(1, 0, 0.0) == 0.0


def test_partition_event_separates_and_heals():
    p = PartitionEvent(side=(0, 1), start=10.0, duration=5.0)
    assert p.separates(0, 2, 10.0)
    assert p.separates(2, 1, 14.9)  # symmetric (XOR membership)
    assert not p.separates(0, 1, 12.0)  # same side
    assert not p.separates(2, 3, 12.0)  # same side
    assert not p.separates(0, 2, 15.0)  # healed
    assert not p.separates(0, 2, 9.9)  # not yet
    with pytest.raises(ValueError):
        PartitionEvent(side=(0,), start=0.0, duration=-1.0)


def test_schedule_reachability_and_heal_times():
    nf = NetFaultSchedule(partitions=(
        PartitionEvent(side=(0,), start=1.0, duration=2.0),
        PartitionEvent(side=(1,), start=10.0, duration=math.inf),
    ))
    assert nf.reachable(0, 2, 0.5)
    assert not nf.reachable(0, 2, 1.5)
    assert nf.unreachable_since(0, 2, 1.5) == 1.0
    assert nf.unreachable_since(0, 2, 0.5) == math.inf
    assert list(nf.heal_times()) == [3.0]  # the infinite cut never heals
    assert not nf.reachable(1, 2, 1e9)


def test_parse_netfaults_specs_and_errors():
    assert parse_netfaults(None, 8) is None
    assert parse_netfaults("none", 8) is None
    assert parse_netfaults("", 8) is None
    nf = parse_netfaults("drop:0.25", 8)
    assert nf.drop_prob(0, 1, 0.0) == pytest.approx(0.25)
    nf = parse_netfaults("delay:0.05", 8)
    assert nf.extra_delay(0, 1, 0.0) == pytest.approx(0.05)
    nf = parse_netfaults("partition:5:30:2", 8)
    (p,) = nf.partitions
    assert p.side == (0, 1) and p.start == 5.0 and p.duration == 30.0
    # K defaults to half the pool
    (p,) = parse_netfaults("partition:5:30", 8).partitions
    assert p.side == tuple(range(4))
    combo = parse_netfaults("drop:0.1+partition:10:30:2", 8)
    assert combo.lossy() and combo.partitions
    with pytest.raises(ValueError):
        parse_netfaults("drop:2.0", 8)
    with pytest.raises(ValueError):
        parse_netfaults("flood:1", 8)
    with pytest.raises(ValueError):
        validate_netfaults(parse_netfaults("partition:0:1:9", 8), 8)


def test_link_health_ewma_backoff_and_clear():
    nf = NetFaultSchedule(
        faults=(LinkFault(drop_prob=0.5),),
        backoff_base=0.1, backoff_cap=1.0, health_alpha=0.5,
        health_floor=0.05,
    )
    h = LinkHealth(nf)
    assert h.factor(0, 1, 0.0) == 1.0  # never observed
    h.record(0, 1, False, 0.0)
    assert h.blocked(0, 1, 0.05)  # first failure: backoff_base
    assert h.factor(0, 1, 0.05) == 0.0
    assert not h.blocked(0, 1, 0.11)
    assert 0.05 <= h.factor(0, 1, 0.11) < 1.0  # EWMA discounted, floored
    h.record(0, 1, False, 0.2)  # consecutive: doubled backoff
    assert h.blocked(0, 1, 0.35) and not h.blocked(0, 1, 0.45)
    h.record(0, 1, True, 0.5)  # success resets the streak
    assert not h.blocked(0, 1, 0.5)
    for _ in range(8):
        h.record(0, 1, True, 1.0)
    assert h.factor(0, 1, 1.0) > 0.9
    h.record(2, 3, False, 0.0)
    h.clear_backoff(2)  # heal reconciliation: worker 2's links reopen
    assert not h.blocked(2, 3, 0.0)


def test_effective_heartbeat_caps_at_cut():
    assert effective_heartbeat(5.0, 3.0) == 3.0
    assert effective_heartbeat(2.0, 3.0) == 2.0
    assert effective_heartbeat(5.0, math.inf) == 5.0
    nan = effective_heartbeat(float("nan"), 3.0)
    assert nan != nan


# --------------------------------------- conformance (plan + telemetry level)
def test_victim_weights_all_one_health_hook_is_identity():
    n = [10.0, 2.0, 8.0, 1.0, 9.0]
    t = [0.1, 0.1, 0.2, 0.1, 0.15]
    queued = [8.0, 0.0, 6.0, 0.0, 7.0]
    base = victim_weights(1, n, t, queued, 2)
    hook = victim_weights(1, n, t, queued, 2, link_health=lambda j: 1.0)
    assert base[2] == hook[2]
    assert np.array_equal(base[0], hook[0])
    assert np.array_equal(base[1], hook[1])
    # a zero-health link is excised entirely
    cut = victim_weights(1, n, t, queued, 2,
                         link_health=lambda j: 0.0 if j == 2 else 1.0)
    w, loaded, crit = cut
    assert all(w[k] == 0.0 for k, j in enumerate(loaded) if j == 2)


def _crafted_plans(policy, p, seed, netfaults):
    """Deterministic boundary plans from a constructed (never started) pool
    with crafted imbalance (mirrors tests/test_topology.py)."""
    pool = WorkerPool(
        list(range(p * 5)), p, lambda w, t: None, policy=policy, seed=seed,
        netfaults=netfaults,
    )
    for i in (0, p // 2):
        w = pool.workers[i]
        while w.deque.get_task() is not None:
            pass
    now = pool.clock()
    for i, w in enumerate(pool.workers):
        w.executed, w.runtime_sum, w.ran_any = 5, 5 * 0.05, True
        w.start_time = now - 1e-3
        pool._update_info(i)
    for i in range(p):
        pool.info.communicate(i)
    plans = []
    for i in range(p):
        plan = pool.policy.on_boundary(pool._make_view(i))
        plans.append(
            None if plan is None else
            (plan.victim, plan.amount, plan.criterion, plan.delay, plan.work)
        )
    return plans


@pytest.mark.parametrize("policy", ["a2ws", "ha2ws"])
@pytest.mark.parametrize("p,seed", [(5, 7), (11, 23), (24, 1234)])
def test_threaded_plans_bit_for_bit_under_empty_schedule(policy, p, seed):
    """The conformance property, threaded plane: an EMPTY fault schedule
    produces IDENTICAL boundary plans to netfaults=None — same victims,
    amounts, criteria, delays, work targets, same rng stream."""
    bare = _crafted_plans(policy, p, seed, None)
    empty = _crafted_plans(policy, p, seed, NetFaultSchedule())
    assert bare == empty


def _sim_equal(a, b):
    assert b.makespan == a.makespan
    assert b.per_node_tasks == a.per_node_tasks
    assert b.per_node_busy == a.per_node_busy
    assert b.records == a.records
    assert b.steal_log == a.steal_log
    assert (b.steals, b.failed_steals, b.moved_tasks, b.boundaries) == (
        a.steals, a.failed_steals, a.moved_tasks, a.boundaries
    )


@pytest.mark.parametrize(
    "conf,seed,tasks",
    [("C1", 0, 80), ("C4", 3, 120), ("C4", 17, 160)],
)
def test_sim_telemetry_bit_for_bit_under_empty_schedule(conf, seed, tasks):
    """The conformance property, sim plane, flat scheduler: whole-run
    virtual-time telemetry is bit-for-bit identical between netfaults=None
    and the empty schedule — the off-switch is exact."""
    cfg = SimConfig(speeds=table2_speeds(conf), num_tasks=tasks, seed=seed)
    bare = simulate("a2ws", cfg)
    empty = simulate("a2ws", cfg.with_(netfaults=NetFaultSchedule()))
    _sim_equal(bare, empty)
    assert empty.net_failed == empty.lease_expired == empty.lost_tasks == 0


@pytest.mark.parametrize("seed", [0, 37])
def test_sim_telemetry_bit_for_bit_empty_schedule_hierarchical(seed):
    p = 64
    cfg = SimConfig(speeds=table2_speeds("C4"), num_tasks=220, seed=seed)
    bare = simulate(HierarchicalA2WSPolicy(p), cfg)
    empty = simulate(
        HierarchicalA2WSPolicy(p), cfg.with_(netfaults=NetFaultSchedule()),
    )
    _sim_equal(bare, empty)


@given(seed=st.integers(0, 2**16), tasks=st.integers(40, 160))
@settings(max_examples=12, deadline=None)
def test_property_sim_empty_schedule_is_identity(seed, tasks):
    """Property-tested conformance over arbitrary seeds/sizes: the empty
    schedule can NEVER perturb the fault-free scheduler (open arrivals,
    the harder path — depth semantics + quiescence)."""
    cfg = SimConfig(
        speeds=table2_speeds("C4")[:16], num_tasks=tasks, seed=seed,
        arrival="poisson", arrival_rate=50.0, task_cost=1.0,
    )
    bare = simulate("a2ws", cfg)
    empty = simulate("a2ws", cfg.with_(netfaults=NetFaultSchedule()))
    _sim_equal(bare, empty)


# ------------------------------------------------- leases: exactly-once moves
def test_sim_leased_transfers_conserve_every_task_under_heavy_drops():
    """40% of steal messages drop, yet every submitted task completes
    exactly once: dropped requests are failed attempts, dropped transfers
    expire their lease and RETURN the stamps to the victim."""
    cfg = SimConfig(
        speeds=table2_speeds("C4")[:16], num_tasks=200, seed=2,
        task_cost=1.0,
        netfaults=NetFaultSchedule(faults=(LinkFault(drop_prob=0.4),)),
    )
    res = simulate("a2ws", cfg)
    assert sum(res.per_node_tasks) == cfg.num_tasks
    assert len(res.records) == cfg.num_tasks
    assert res.lost_tasks == 0
    assert res.net_failed > 0  # the plane actually fired
    assert res.lease_expired > 0


def test_sim_no_retry_ablation_strands_or_loses_tasks():
    """hardened=False: no leases, no backoff — a dropped transfer's loot is
    GONE.  The run still terminates (lost tasks are accounted), and the
    loss is visible in the telemetry: done + lost == submitted."""
    cfg = SimConfig(
        speeds=table2_speeds("C4")[:16], num_tasks=200, seed=2,
        task_cost=1.0,
        netfaults=NetFaultSchedule(
            faults=(LinkFault(drop_prob=0.4),), hardened=False,
        ),
    )
    res = simulate("a2ws", cfg)
    assert res.lost_tasks > 0, "ablation never lost loot at 40% drop"
    assert sum(res.per_node_tasks) + res.lost_tasks == cfg.num_tasks


def test_threaded_leased_transfers_conserve_under_heavy_drops():
    nf = NetFaultSchedule(
        faults=(LinkFault(drop_prob=0.4),),
        attempt_timeout=0.001, lease_timeout=0.01,
    )
    pool = WorkerPool(
        list(range(80)), 4, lambda w, t: time.sleep(0.002 * (1 + w % 3)),
        policy="a2ws", seed=5, netfaults=nf,
    )
    stats = pool.run()
    assert len(stats.records) == 80
    assert sum(stats.per_worker_tasks) == 80
    assert stats.net_failed > 0


def test_threaded_unhardened_still_conserves_payloads():
    """The threaded plane carries REAL task payloads: even the un-hardened
    ablation returns dropped loot to the victim (immediately, no lease
    wait) instead of destroying work — the documented divergence from the
    simulator's loss accounting (DESIGN.md §Fault fabric)."""
    nf = NetFaultSchedule(faults=(LinkFault(drop_prob=0.5),), hardened=False)
    pool = WorkerPool(
        list(range(60)), 4, lambda w, t: time.sleep(0.002),
        policy="a2ws", seed=3, netfaults=nf,
    )
    stats = pool.run()
    assert len(stats.records) == 60


# ----------------------------------------------- partitions: degrade and heal
def test_sim_partition_both_sides_keep_scheduling_and_heal():
    """A mid-run cut: each side keeps executing within its component (no
    cross-cut steals while active), completes every task, and the ring
    reconciles on heal."""
    nf = NetFaultSchedule(
        partitions=(PartitionEvent(side=(0, 1, 2, 3), start=10.0,
                                   duration=60.0),),
    )
    cfg = SimConfig(
        speeds=table2_speeds("C4")[:16], num_tasks=300, seed=1,
        task_cost=1.0, netfaults=nf,
    )
    res = simulate("a2ws", cfg)
    assert sum(res.per_node_tasks) == cfg.num_tasks
    # both components executed work (graceful degradation, not a stall)
    assert sum(res.per_node_tasks[:4]) > 0
    assert sum(res.per_node_tasks[4:]) > 0
    # no loot ever crossed the active cut
    side = {0, 1, 2, 3}
    for t, thief, victim, _take in res.steal_log:
        if 10.0 <= t < 70.0:
            assert (thief in side) == (victim in side), (
                f"steal crossed the active cut at t={t}"
            )


def test_threaded_partition_run_completes_and_ring_versions_monotone():
    nf = NetFaultSchedule(
        partitions=(PartitionEvent(side=(0, 1), start=0.02, duration=0.15),),
        stale_after=0.02,
    )
    pool = WorkerPool(
        list(range(80)), 4, lambda w, t: time.sleep(0.002),
        policy="a2ws", seed=9, netfaults=nf,
    )
    pool.start()
    time.sleep(0.05)  # mid-partition snapshot
    mid = pool.info.version.copy()
    stats = pool.join()
    assert len(stats.records) == 80
    assert np.all(pool.info.version >= mid), "ring versions went backwards"


def test_ring_resync_reoffers_cells_after_gated_communicate():
    """Unit-level heal reconciliation: a direction gated off keeps its
    watermark, resync() re-offers the full window, and receivers stay
    monotone (a re-Put of a known version is a no-op)."""
    ring = RingInfo(4, 1)
    ring.update_local(0, 5.0, 0.5)
    sent = ring.communicate(0, can_send=lambda j: False)  # total cut
    assert sent == 0
    assert ring.n[1, 0] == 0.0 and ring.n[3, 0] == 0.0
    sent = ring.communicate(0)  # heal: ungated
    assert sent > 0
    assert ring.n[3, 0] == 5.0  # left neighbour of 0 is 3
    v_before = ring.version.copy()
    ring.resync(0)
    sent = ring.communicate(0)  # re-offer after resync
    assert sent > 0  # watermarks forgot the delivery...
    assert np.array_equal(ring.version, v_before)  # ...receivers monotone


def test_partition_staleness_excludes_far_side_from_victim_selection():
    """Sim: while the cut is active, thieves never burn attempts on
    unreachable victims (the link-health hook zeroes their weights), so
    net_failed stays 0 in a pure-partition run."""
    nf = NetFaultSchedule(
        partitions=(PartitionEvent(side=(0, 1), start=2.0, duration=30.0),),
    )
    cfg = SimConfig(
        speeds=(4.0, 1.0, 1.0, 1.0), num_tasks=60, seed=0,
        task_cost=1.0, netfaults=nf,
    )
    res = simulate("a2ws", cfg)
    assert sum(res.per_node_tasks) == cfg.num_tasks
    assert res.net_failed == 0, (
        "victim selection still picked unreachable peers"
    )


# ------------------------------------------------------------- serve plane
def test_servepool_partition_routing_avoids_minority_side():
    def gen(req):
        time.sleep(0.003)
        return {"ok": True}

    nf = NetFaultSchedule(
        partitions=(PartitionEvent(side=(0,), start=0.0, duration=0.3),),
        stale_after=0.02,
    )
    pool = ServePool(
        [Replica(f"r{i}", gen) for i in range(4)], seed=1, netfaults=nf,
    )
    pool.start()
    futs = [pool.submit({"i": i}) for i in range(24)]
    for f in futs:
        assert f.result(timeout=30)["ok"]
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 24
    # the cut-off replica got no fresh submits while partitioned, and the
    # cut lasted past the last submit — so it served (at most) strays that
    # landed via post-heal stealing: the majority did the work.
    assert stats.per_worker_tasks[0] < max(stats.per_worker_tasks)

"""Work-weighted stealing (DESIGN.md §Work-weighted stealing): the class
pricing math, the degenerate single-class guarantee, the NaN-boot guards,
work-greedy loot, churn regressions in both planes, cross-plane conformance
and the acceptance makespan ratio on the clustered bimodal scenario."""

import threading
import time

import numpy as np
import pytest

from _hypo import given, settings, st

from repro.core.a2ws import WorkerPool
from repro.core.deque import TaskDeque
from repro.core.info_ring import RingInfo
from repro.core.simulator import SimConfig, simulate
from repro.core.steal import (
    class_relatives,
    ideal_runtime,
    plan_steal,
    queue_units,
    steal_rate_radius,
    tail_steal_amount,
)
from repro.serve.engine import (
    Replica,
    ServePool,
    request_size,
    shape_cost_classifier,
)


# ---------------------------------------------------------- class pricing math
def test_class_relatives_from_own_worker_ratios():
    # Within one worker the speed cancels: every worker reports 8x between
    # its classes, so rel must be [1, 8] regardless of absolute speeds.
    tc = np.array([[1.0, 8.0], [4.0, 32.0], [np.nan, np.nan]])
    assert np.allclose(class_relatives(tc), [1.0, 8.0])


def test_class_relatives_unreported_class_prices_at_one():
    tc = np.array([[2.0, np.nan], [3.0, np.nan]])
    assert np.allclose(class_relatives(tc), [1.0, 1.0])
    # nobody reported anything: all ones (count-based degenerate values)
    assert np.allclose(class_relatives(np.full((3, 2), np.nan)), [1.0, 1.0])


def test_class_relatives_pool_mean_fallback():
    # No worker reported BOTH classes: fall back to the ratio of pool means.
    tc = np.array([[2.0, np.nan], [np.nan, 10.0]])
    assert np.allclose(class_relatives(tc), [1.0, 5.0])


def test_queue_units_mean_work_per_task():
    nc = np.array([[4.0, 0.0], [0.0, 2.0], [2.0, 2.0], [0.0, 0.0]])
    units = queue_units(nc, np.array([1.0, 8.0]))
    assert np.allclose(units, [1.0, 8.0, 4.5, 1.0])


# ----------------------------------------------------- NaN-boot guards (Eq. 2/5)
def test_ideal_runtime_unreported_neighbour_is_nan_not_garbage():
    assert np.isnan(ideal_runtime([3.0, 2.0], [1.0, float("nan")]))
    assert ideal_runtime([3.0, 3.0], [1.0, 1.0]) == pytest.approx(3.0)


def test_steal_rate_radius_nan_window():
    t = np.array([1.0, np.nan, 1.0, 1.0])
    assert np.isnan(steal_rate_radius(0, np.ones(4), t, radius=1))
    # NaN outside the window must NOT poison the subsystem computation
    t2 = np.array([1.0, 1.0, np.nan, 1.0])
    assert np.isfinite(steal_rate_radius(0, np.ones(4), t2, radius=1))


def test_tail_steal_amount_nonfinite_inputs_mean_no_steal():
    assert tail_steal_amount(0.0, float("nan"), 5.0, 1.0) == 0
    assert tail_steal_amount(0.0, 1.0, 5.0, float("inf")) == 0


def test_plan_steal_all_unreported_boot_returns_no_plan():
    """Regression (fails pre-fix): at open-arrival boot every in-window t̂
    is NaN while depths are already positive.  The old code propagated NaN
    into the victim weights and ``rng.choice`` raised ``ValueError``; the
    fix must translate "no information" into "no steal"."""
    rng = np.random.default_rng(0)
    n = np.array([0.0, 5.0, 4.0, 3.0, 2.0, 1.0])
    t = np.full(6, np.nan)
    plan = plan_steal(
        rng, 0, n, t, n.copy(), radius=2, idle=True, open_arrival=True
    )
    assert plan is None


def test_plan_steal_partial_reports_only_targets_reported_victims():
    rng = np.random.default_rng(1)
    n = np.array([0.0, 6.0, 6.0, 0.0])
    t = np.array([1.0, np.nan, 1.0, 1.0])
    for _ in range(20):
        plan = plan_steal(
            rng, 0, n, t, n.copy(), radius=2, idle=True, open_arrival=True
        )
        assert plan is None or plan.victim == 2  # never the NaN victim


# --------------------------------------------- degenerate single-class guarantee
def test_single_class_weighted_plan_equals_count_plan():
    """The work-weighted identities (unit ≡ 1, qtasks ≡ queued) must leave
    the count-based plan untouched bit-for-bit, rng stream included."""
    for seed in range(40):
        rng = np.random.default_rng(seed)
        p = int(rng.integers(2, 9))
        n = rng.integers(0, 30, p).astype(float)
        t = rng.uniform(0.5, 4.0, p)
        queued = np.minimum(rng.integers(0, 20, p).astype(float), n)
        i = int(rng.integers(0, p))
        radius = int(rng.integers(1, max(p // 2, 2)))
        idle = bool(rng.integers(0, 2))
        open_arr = bool(rng.integers(0, 2))
        a = plan_steal(
            np.random.default_rng(seed), i, n, t, queued, radius,
            idle=idle, open_arrival=open_arr,
        )
        b = plan_steal(
            np.random.default_rng(seed), i, n, t, queued, radius,
            idle=idle, open_arrival=open_arr,
            unit=np.ones(p), qtasks=queued,
        )
        if a is None:
            assert b is None
        else:
            assert b is not None
            assert (a.victim, a.amount, a.criterion) == (
                b.victim, b.amount, b.criterion
            )


@settings(max_examples=60, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
)
def test_single_class_plan_property(seed):
    """Hypothesis-driven variant of the bit-for-bit degenerate guarantee."""
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 12))
    n = rng.integers(0, 40, p).astype(float)
    t = rng.uniform(0.1, 8.0, p)
    queued = np.minimum(rng.integers(0, 25, p).astype(float), n)
    i = int(rng.integers(0, p))
    radius = int(rng.integers(1, max(p // 2, 2)))
    a = plan_steal(np.random.default_rng(seed), i, n, t, queued, radius)
    b = plan_steal(
        np.random.default_rng(seed), i, n, t, queued, radius,
        unit=np.ones(p), qtasks=queued,
    )
    assert (a is None) == (b is None)
    if a is not None:
        assert (a.victim, a.amount) == (b.victim, b.amount)


def test_sim_single_class_weighted_equals_count_exactly():
    """One cost class through the whole simulator: the weighted info plane
    must reproduce the count-based run bit-for-bit (same rng stream, same
    makespan, same steal telemetry)."""
    cfg = SimConfig(
        speeds=np.array([4.0, 2.0, 1.0, 1.0]), num_tasks=80, seed=5,
        class_cost=(3.0,),
    )
    rw = simulate("a2ws", cfg)
    rc = simulate("a2ws", cfg.with_(weighted=False))
    assert rw.makespan == rc.makespan
    assert (rw.steals, rw.failed_steals, rw.moved_tasks) == (
        rc.steals, rc.failed_steals, rc.moved_tasks
    )
    assert rw.per_node_tasks == rc.per_node_tasks


# ------------------------------------------------------------ work-greedy loot
def test_steal_by_work_homogeneous_takes_exact_count():
    dq = TaskDeque(list(range(10)))
    r = dq.steal_by_work(3.0, lambda _t: 1.0, max_tasks=8)
    assert len(r.tasks) == 3
    # synthesized pre-image: observed span - got == what is left behind
    assert r.observed_tail - r.observed_head - len(r.tasks) == len(dq)


def test_steal_by_work_refuses_overshooting_heavy_task():
    """A thief planning one light-task's worth must never ingest a heavy
    task 8x its target — the count-based failure mode under tail skew."""
    dq = TaskDeque(["heavy"])
    r = dq.steal_by_work(1.0, lambda _t: 8.0, max_tasks=4)
    assert not r and len(dq) == 1  # refused, nothing claimed


def test_steal_by_work_nearest_to_target():
    dq = TaskDeque(["l", "l", "h"])  # thief side = tail: h first
    work = {"l": 1.0, "h": 4.0}
    # target 5: take h (cum 4), then l (cum 5 == target), stop
    r = dq.steal_by_work(5.0, lambda t: work[t], max_tasks=8)
    assert sorted(r.tasks) == ["h", "l"]
    # target 4.4: after h (cum 4), +l overshoots by 0.6 > deficit 0.4: stop
    dq2 = TaskDeque(["l", "l", "h"])
    r2 = dq2.steal_by_work(4.4, lambda t: work[t], max_tasks=8)
    assert r2.tasks == ["h"]


def test_peek_tail_and_snapshot_tasks():
    dq = TaskDeque([1, 2, 3])
    assert dq.peek_tail() == 3
    assert dq.snapshot_tasks() == [1, 2, 3]
    assert len(dq) == 3  # both are pure reads
    assert TaskDeque([]).peek_tail() is None


# ------------------------------------------------------- info-ring class plane
def test_ring_info_class_payload_roundtrip_and_versioning():
    ri = RingInfo(3, radius=1, num_classes=2)
    ri.update_local(0, 4.0, 1.0, nc_i=np.array([3.0, 1.0]),
                    tc_i=np.array([1.0, 8.0]))
    v0 = ri.version[0, 0]
    # class-profile-only change must dirty the cell (scalars unchanged)
    ri.update_local(0, 4.0, 1.0, nc_i=np.array([2.0, 2.0]),
                    tc_i=np.array([1.0, 8.0]))
    assert ri.version[0, 0] == v0 + 1
    ri.communicate(0)
    ri.communicate(1)
    *_, nc, tc = ri.view_window_classes(1)
    assert np.allclose(nc[0], [2.0, 2.0]) and np.allclose(tc[0], [1.0, 8.0])


def test_ring_info_grow_preserves_class_cells():
    ri = RingInfo(2, radius=1, num_classes=2)
    ri.update_local(1, 2.0, 0.5, nc_i=np.array([0.0, 2.0]),
                    tc_i=np.array([np.nan, 4.0]))
    ri.grow(4)
    assert np.allclose(ri.nc[1, 1], [0.0, 2.0])
    assert np.isnan(ri.tc[1, 1, 0]) and ri.tc[1, 1, 1] == 4.0
    assert np.all(ri.nc[:, 2:, :] == 0.0) and np.all(np.isnan(ri.tc[:, 2:, :]))


# ---------------------------------------------------------- threaded substrate
def _busy(seconds: float) -> None:
    end = time.perf_counter() + seconds
    while time.perf_counter() < end:
        pass


def test_threaded_weighted_pool_runs_and_observes_classes():
    n = 40
    rng = np.random.default_rng(2)
    tasks = [int(c) for c in (rng.random(n) < 0.2)]
    done, lock = [], threading.Lock()

    def task_fn(wid, task):
        time.sleep(0.004 * (4.0 if task else 1.0))
        with lock:
            done.append(task)

    pool = WorkerPool(
        tasks, 3, task_fn, policy="a2ws", seed=0,
        cost_class_fn=lambda t: t, num_classes=2,
    )
    stats = pool.run()
    assert sorted(done) == sorted(tasks)
    assert sum(stats.per_worker_tasks) == n
    # every worker that ran a task has a finite EWMA for some class
    for w, st_ in zip(pool.workers, stats.per_worker_tasks):
        if st_ > 0:
            assert np.isfinite(w.class_t).any()


def test_threaded_closed_reconciliation_keeps_board_counts():
    """Regression (code review): in weighted CLOSED mode the Fig. 3b
    reconciliation must derive its executed estimate from the pre-overlay
    COUNT vectors — the work-repriced ``n_view - queued`` is executed work
    in reference units, and writing it into the board's count-denominated
    ``n`` double-scales on the next view (a victim with heavy history got
    its n inflated ~rel[c]-fold, attracting oversized plans forever).

    Crafted state, driven on the main thread: worker 1 executed 10 heavy
    tasks (8 ms each) and still queues 3; worker 0 ran 3 light (1 ms) and
    is idle.  rel resolves to ~8, so the pre-fix code recorded worker 1's
    n as ~50 work units instead of <= 13 tasks."""
    tasks = [0, 0, 0] + [1, 1, 1]  # block split: w0 light, w1 heavy queue
    pool = WorkerPool(
        tasks, 2, lambda wid, t: None, policy="a2ws", seed=0,
        cost_class_fn=lambda t: t, num_classes=2,
    )
    w0, w1 = pool.workers
    while w0.deque.get_task() is not None:
        pass  # w0 idle: its boundary must plan a steal
    now = pool.clock()
    w0.executed, w0.runtime_sum, w0.ran_any = 3, 3e-3, True
    w0.class_t[0] = 1e-3
    w0.start_time = now - 0.05
    w1.executed, w1.runtime_sum, w1.ran_any = 10, 8e-2, True
    w1.class_t[1] = 8e-3
    w1.start_time = now - 0.05
    pool._update_info(0)
    pool._update_info(1)
    pool.info.communicate(1)  # w1's self-cell reaches w0's vector
    assert pool._policy_boundary(0), "crafted state must trigger a steal"
    # Closed-mode n is a TASK count (executed + queued): worker 1's cell
    # can never exceed its 10 executed + 3 queued.
    assert float(pool.info.n[0, 1]) <= 13.5, pool.info.n[0, 1]


def test_threaded_raising_classifier_never_kills_a_worker():
    def bad_classifier(_task):
        raise RuntimeError("shape probe exploded")

    pool = WorkerPool(
        list(range(20)), 2, lambda wid, t: time.sleep(0.001),
        policy="a2ws", cost_class_fn=bad_classifier, num_classes=3,
    )
    stats = pool.run()
    assert sum(stats.per_worker_tasks) == 20  # all classified to class 0


# ------------------------------------------------- churn regression (both planes)
def test_threaded_weighted_churn_probes_skip_retired_members():
    """Join/retire churn under open arrivals with the weighted info plane:
    a retired member's stale ring row (depth > 0 at tombstone time) must
    not attract probe steals forever, and every submitted task is served."""
    done, lock = [], threading.Lock()

    def task_fn(wid, task):
        time.sleep(0.002 * (3.0 if task % 7 == 0 else 1.0))
        with lock:
            done.append(task)

    pool = WorkerPool(
        [], 3, task_fn, policy="a2ws", open_arrival=True, seed=0,
        cost_class_fn=lambda t: int(t % 7 == 0), num_classes=2,
    )
    pool.start()
    pool.submit_many(range(30), worker=1)  # backlog on the future retiree
    time.sleep(0.01)
    pool.retire_worker(1, drain=True)  # ring rows elsewhere still show depth
    wid = pool.add_worker()
    pool.submit_many(range(30, 60))
    pool.drain()
    stats = pool.join()
    assert sorted(done) == list(range(60))
    assert sum(stats.per_worker_tasks) == 60
    # no SUCCESSFUL steal may have a tombstoned victim after its retirement
    retire_t = [t for t, k, w in pool.membership_log if k == "retire"][0]
    for t, _thief, victim, got in stats.steals:
        if victim == 1 and t > retire_t:
            assert got == 0
    assert pool.dead[1] and not pool.dead[wid]


def test_sim_weighted_churn_conserves_tasks():
    speeds = np.array([4.0, 2.0, 1.0, 1.0])
    cfg = SimConfig(
        speeds=speeds, num_tasks=120, seed=3,
        arrival="poisson", arrival_rate=0.6 * float(speeds.sum()) / 60.0,
        class_cost=(1.0, 6.0), class_probs=(0.85, 0.15),
        retires=((90.0, 2),), joins=((90.0, 4.0),),
    )
    res = simulate("a2ws", cfg)
    assert sum(res.per_node_tasks) == 120
    assert len(res.latencies) == 120
    assert res.per_node_tasks[4] > 0  # the joiner pulled work


# --------------------------------------------------- cross-plane conformance
_SPEEDS = [4.0, 1.0, 1.0, 1.0]
_N, _BASE, _MULT = 48, 0.012, 4.0


def _bimodal_classes(seed: int = 7) -> list[int]:
    rng = np.random.default_rng(seed)
    return [int(c) for c in (rng.random(_N) < 0.15)]


def _threaded_weighted(seed: int):
    cls = _bimodal_classes()

    def task_fn(wid, task):
        _busy(_BASE * (_MULT if task else 1.0) / _SPEEDS[wid])

    pool = WorkerPool(
        cls, len(_SPEEDS), task_fn, policy="a2ws", seed=seed,
        cost_class_fn=lambda t: t, num_classes=2,
    )
    return pool.run()


def test_cross_plane_conformance_weighted_a2ws():
    """Weighted A2WS through BOTH planes on the same seeded bimodal
    workload: the fast worker dominates everywhere and steal volumes agree
    within the (generous) cross-plane band of tests/test_policy.py."""
    cls = _bimodal_classes()
    cfg = SimConfig(
        speeds=np.asarray(_SPEEDS), num_tasks=_N, task_cost=_BASE, noise=0.0,
        seed=0, hop_latency=1e-4, info_poll=1e-3, comm_cell_cost=0.0,
        steal_latency=5e-4, steal_per_task=1e-5, retry_interval=1e-3,
        class_cost=(1.0, _MULT), class_trace=tuple(cls),
    )
    sim = simulate("a2ws", cfg)
    assert sum(sim.per_node_tasks) == _N
    assert int(np.argmax(sim.per_node_tasks)) == 0
    assert sim.steals > 0

    runs = [_threaded_weighted(seed) for seed in range(3)]
    for st_ in runs:
        assert sum(st_.per_worker_tasks) == _N
    med_w0 = float(np.median([st_.per_worker_tasks[0] for st_ in runs]))
    others = float(np.median([max(st_.per_worker_tasks[1:]) for st_ in runs]))
    assert med_w0 > others, "fast worker must dominate in the threaded plane"
    med_moved = float(
        np.median([sum(s[3] for s in st_.steals) for st_ in runs])
    )
    assert med_moved > 0, "threaded plane never stole"
    hi = max(med_moved, float(sim.moved_tasks))
    assert abs(med_moved - sim.moved_tasks) <= max(8.0, 0.8 * hi), (
        f"steal volume diverged across planes: threaded~{med_moved} "
        f"vs simulated {sim.moved_tasks}"
    )


# ------------------------------------------------------- acceptance criterion
def test_acceptance_weighted_beats_count_on_clustered_bimodal():
    """The PR's acceptance scenario (mirrored in benchmarks/weighted.py):
    heavy shots at every partition block's tail, 16x cost, moderate speed
    spread.  Deterministic virtual time: the median work-weighted makespan
    over six seeds must be ≤ 0.9x the count-based one, and weighted must
    never lose by more than the modelling noise on any seed."""
    speeds = np.asarray((4.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0))
    n, blk, heavy = 240, 30, 6
    cls: list[int] = []
    for _ in range(len(speeds)):
        cls += [0] * (blk - heavy) + [1] * heavy
    ratios = []
    for seed in range(6):
        cfg = SimConfig(
            speeds=speeds, num_tasks=n, seed=seed, task_cost=6.0,
            class_cost=(1.0, 16.0), class_trace=tuple(cls),
        )
        rw = simulate("a2ws", cfg)
        rc = simulate("a2ws", cfg.with_(weighted=False))
        assert sum(rw.per_node_tasks) == n and sum(rc.per_node_tasks) == n
        ratios.append(rw.makespan / rc.makespan)
    assert float(np.median(ratios)) <= 0.9, f"ratios={ratios}"
    assert max(ratios) <= 1.05, f"weighted lost a seed outright: {ratios}"


# ------------------------------------------------------------- serving layer
def test_request_size_shape_inference():
    assert request_size({"nt": 480}) == 480.0
    assert request_size({"max_new_tokens": 64}) == 64.0
    assert request_size({"prompt": "abcd"}) == 4.0
    assert request_size({"tokens": list(range(7))}) == 7.0
    assert request_size({"mystery": object()}) == 1.0  # lowest class, no error
    clf = shape_cost_classifier((100.0,))
    assert clf({"nt": 60}) == 0 and clf({"nt": 480}) == 1
    clf3 = shape_cost_classifier((10.0, 100.0))
    assert clf3({"nt": 5}) == 0 and clf3({"nt": 50}) == 1 and clf3({"nt": 500}) == 2


def test_servepool_rejects_conflicting_classifier_config():
    with pytest.raises(ValueError):
        ServePool([], cost_class_bounds=(1.0,), cost_class_fn=lambda r: 0)
    with pytest.raises(ValueError):
        ServePool([], cost_class_fn=lambda r: 0)  # num_classes missing


def test_servepool_infers_classes_from_request_shape():
    def gen(request):
        _busy(0.001 * (4.0 if request["nt"] > 100 else 1.0))
        return {"ok": request["nt"]}

    pool = ServePool(
        [Replica("a", gen), Replica("b", gen), Replica("c", gen)],
        seed=0, cost_class_bounds=(100.0,),
    )
    pool.start()
    assert pool._runtime is not None and pool._runtime.weighted
    assert pool._runtime.num_classes == 2
    rng = np.random.default_rng(0)
    futs = []
    for k in range(24):
        time.sleep(float(rng.exponential(1.0 / 500.0)))
        nt = 480 if k % 6 == 0 else 60
        futs.append(pool.submit({"nt": nt}))
    for f in futs:
        assert "ok" in f.result(timeout=30.0)
    stats = pool.shutdown()
    assert sum(stats.per_worker_tasks) == 24

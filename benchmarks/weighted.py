"""Work-weighted stealing benchmark (DESIGN.md §Work-weighted stealing).

Bimodal seismic-shot scenario: ~10% of shots cost ~8x the rest (deep shots —
larger ``nt``), which is exactly the cost skew that breaks the paper's
count-based Eq. 5.  Three experiments, all A2WS-vs-A2WS so the only variable
is whether queues are priced in task counts or estimated work-seconds:

1. **Simulated** (C1, closed batch + Poisson arrivals): work-weighted vs
   count-based makespan and latency percentiles under virtual time.
2. **Threaded**: the same bimodal mix as real sleep-calibrated payloads on a
   heterogeneous 4-worker pool (one 4x-fast worker), wall-clock makespan.
3. **--real-shots**: ``repro.seismic.run_shot`` as the ACTUAL payload — the
   first benchmark where the Pallas FD3D path and the scheduler meet.  Light
   shots run ``nt`` time steps, heavy shots ``8*nt``; the classifier reads
   the class off the shot's ``nt`` (the request-shape inference ServePool
   uses).  Opt-in because it compiles and runs real XLA programs.

Emits ``BENCH_weighted.json`` via ``benchmarks.run`` (the returned dict).
"""

from __future__ import annotations

import time

import numpy as np

from .common import timed  # noqa: F401  (harness convention)

import sys

sys.path.insert(0, "src")
from repro.core.a2ws import WorkerPool  # noqa: E402
from repro.core.simulator import SimConfig, simulate, table2_speeds  # noqa: E402

#: fraction of heavy shots and their cost multiple (bimodal mix)
HEAVY_FRAC = 0.10
HEAVY_MULT = 8.0
#: threaded plane: worker speeds and light-task service time
SPEEDS = (4.0, 2.0, 1.0, 1.0)
BASE = 0.010


# ------------------------------------------------------------ simulated plane
def _sim_pair(seeds: int, arrival: str) -> dict:
    """Weighted vs count-based, PAIRED per seed: the iid closed scenario has
    a heavy-task-on-slow-owner lottery that hits both modes identically per
    seed, so the honest estimator is the median of per-seed ratios, not a
    ratio of independent medians."""
    speeds = table2_speeds("C1")
    w_ms, c_ms, ratios, w_p99, c_p99 = [], [], [], [], []
    for seed in range(seeds):
        cfg = SimConfig(
            speeds=speeds, num_tasks=300, seed=seed,
            class_cost=(1.0, HEAVY_MULT),
            class_probs=(1.0 - HEAVY_FRAC, HEAVY_FRAC),
        )
        if arrival == "poisson":
            # ~85% utilisation of the mean-cost-adjusted capacity.
            mean_cost = (1.0 - HEAVY_FRAC) + HEAVY_FRAC * HEAVY_MULT
            rate = 0.85 * float(speeds.sum()) / (60.0 * mean_cost)
            cfg = cfg.with_(arrival="poisson", arrival_rate=rate)
        rw = simulate("a2ws", cfg)
        rc = simulate("a2ws", cfg.with_(weighted=False))
        assert sum(rw.per_node_tasks) == 300 and sum(rc.per_node_tasks) == 300
        w_ms.append(rw.makespan)
        c_ms.append(rc.makespan)
        ratios.append(rw.makespan / rc.makespan)
        if arrival == "poisson":
            w_p99.append(rw.latency_percentiles((99.0,))[99.0])
            c_p99.append(rc.latency_percentiles((99.0,))[99.0])
    return {
        "weighted_makespan_s": float(np.median(w_ms)),
        "count_makespan_s": float(np.median(c_ms)),
        "ratio": float(np.median(ratios)),
        "weighted_p99_s": float(np.median(w_p99)) if w_p99 else float("nan"),
        "count_p99_s": float(np.median(c_p99)) if c_p99 else float("nan"),
    }


def _sim_clustered(weighted: bool, seeds: int) -> float:
    """The acceptance scenario (tests/test_weighted.py): heavy shots sit at
    every partition block's TAIL — the stolen region — so each node's
    executed history (light, fast t̂) diverges from its queue composition
    (heavy).  Count-based pricing extrapolates depth from the history mean
    and systematically under-sizes its steals; work-weighted pricing reads
    the published class profile instead."""
    speeds = np.asarray((4.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0))
    n, blk, heavy_per_blk = 240, 30, 6
    cls: list[int] = []
    for _ in range(len(speeds)):
        cls += [0] * (blk - heavy_per_blk) + [1] * heavy_per_blk
    makespans = []
    for seed in range(seeds):
        cfg = SimConfig(
            speeds=speeds, num_tasks=n, seed=seed, task_cost=6.0,
            class_cost=(1.0, 16.0), class_trace=tuple(cls),
            weighted=weighted,
        )
        res = simulate("a2ws", cfg)
        assert sum(res.per_node_tasks) == n
        makespans.append(res.makespan)
    return float(np.median(makespans))


# ------------------------------------------------------------- threaded plane
def _threaded(weighted: bool, seed: int, n_tasks: int = 48) -> float:
    """Clustered motif on real threads.  Caveat recorded with the numbers:
    at this scale (4 workers, ~200 ms runs) wall-clock noise is comparable
    to the scheduling effect, so expect parity-ish ratios — the virtual-time
    plane is where the effect is measured cleanly."""
    blk = n_tasks // len(SPEEDS)
    h = max(1, blk // 5)
    tasks: list[int] = []
    for _ in range(len(SPEEDS)):
        tasks += [0] * (blk - h) + [1] * h

    def task_fn(wid: int, task: int) -> None:
        # sleep = worker blocked on its accelerator; GIL-fair, so the
        # wall-clock makespan reflects BALANCE, not bytecode contention
        time.sleep(BASE * (HEAVY_MULT if task else 1.0) / SPEEDS[wid])

    pool = WorkerPool(
        tasks, len(SPEEDS), task_fn, policy="a2ws", seed=seed,
        cost_class_fn=(lambda t: t) if weighted else None, num_classes=2,
    )
    stats = pool.run()
    assert sum(stats.per_worker_tasks) == len(tasks)
    return stats.makespan


# ----------------------------------------------------------- real FD3D shots
def _real_shots(
    weighted: bool, seed: int, num_shots: int = 12, n: int = 32,
    nt_light: int = 24,
) -> float:
    """``run_shot`` as the scheduled payload: bimodal ``nt`` mix, classes
    inferred from the shot's shape (nt), 4 host workers sharing the device."""
    import jax.numpy as jnp

    from repro.seismic.model import make_demo_model, make_shot_grid, run_shot

    nt_heavy = int(nt_light * HEAVY_MULT)
    model = make_demo_model(n)
    rng = np.random.default_rng(seed)
    shots = make_shot_grid(model, num_shots)
    tasks = [
        (s, nt_heavy if rng.random() < HEAVY_FRAC else nt_light)
        for s in shots
    ]

    def run_one(shot, nt: int) -> None:
        run_shot(
            model,
            jnp.asarray(shot.src, jnp.int32),
            jnp.asarray(shot.rec_array()),
            nt,
        ).block_until_ready()

    # Warm both jit cache entries (one per static nt) outside the makespan.
    run_one(shots[0], nt_light)
    run_one(shots[0], nt_heavy)

    def task_fn(wid: int, task) -> None:
        run_one(task[0], task[1])

    pool = WorkerPool(
        tasks, 4, task_fn, policy="a2ws", seed=seed,
        cost_class_fn=(lambda t: int(t[1] > nt_light)) if weighted else None,
        num_classes=2,
    )
    stats = pool.run()
    assert sum(stats.per_worker_tasks) == len(tasks)
    return stats.makespan


def run(
    seeds: int = 3, fast: bool = False, real_shots: bool = False,
    csv: bool = True,
):
    # Virtual-time scenarios are cheap: always median over >= 3 seeds (the
    # iid closed scenario has a heavy-task-on-slow-owner lottery that makes
    # any single seed misleading in either direction).
    sim_seeds = max(seeds, 3)
    closed = _sim_pair(sim_seeds, "closed")
    poisson = _sim_pair(sim_seeds, "poisson")
    clu_w = _sim_clustered(True, sim_seeds)
    clu_c = _sim_clustered(False, sim_seeds)
    thr_w = float(np.median([_threaded(True, s) for s in range(seeds)]))
    thr_c = float(np.median([_threaded(False, s) for s in range(seeds)]))
    out = {
        "heavy_frac": HEAVY_FRAC,
        "heavy_mult": HEAVY_MULT,
        "sim_clustered_weighted_makespan_s": clu_w,
        "sim_clustered_count_makespan_s": clu_c,
        "sim_clustered_ratio": clu_w / clu_c,
        "sim_closed_weighted_makespan_s": closed["weighted_makespan_s"],
        "sim_closed_count_makespan_s": closed["count_makespan_s"],
        "sim_closed_ratio": closed["ratio"],
        "sim_open_weighted_makespan_s": poisson["weighted_makespan_s"],
        "sim_open_count_makespan_s": poisson["count_makespan_s"],
        "sim_open_weighted_p99_s": poisson["weighted_p99_s"],
        "sim_open_count_p99_s": poisson["count_p99_s"],
        "threaded_weighted_makespan_s": thr_w,
        "threaded_count_makespan_s": thr_c,
        "threaded_ratio": thr_w / thr_c,
    }
    if real_shots and not fast:
        rs_w = _real_shots(True, seed=0)
        rs_c = _real_shots(False, seed=0)
        out.update(
            real_shots_weighted_makespan_s=rs_w,
            real_shots_count_makespan_s=rs_c,
            real_shots_ratio=rs_w / rs_c,
        )
    if csv:
        print(
            f"weighted_sim_clustered,{clu_w*1e6:.0f},"
            f"ratio_vs_count={out['sim_clustered_ratio']:.3f}"
        )
        print(
            f"weighted_sim_closed,{closed['weighted_makespan_s']*1e6:.0f},"
            f"ratio_vs_count={out['sim_closed_ratio']:.3f}"
        )
        print(
            f"weighted_sim_open_p99,{poisson['weighted_p99_s']*1e6:.0f},"
            f"count_p99_us={poisson['count_p99_s']*1e6:.0f}"
        )
        print(
            f"weighted_threaded,{thr_w*1e6:.0f},"
            f"ratio_vs_count={out['threaded_ratio']:.3f}"
        )
        if "real_shots_ratio" in out:
            print(
                f"weighted_real_shots,{out['real_shots_weighted_makespan_s']*1e6:.0f},"
                f"ratio_vs_count={out['real_shots_ratio']:.3f}"
            )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument(
        "--real-shots", action="store_true",
        help="schedule real FD3D shots (compiles XLA programs; slower)",
    )
    args = ap.parse_args()
    run(
        seeds=1 if args.fast else args.seeds, fast=args.fast,
        real_shots=args.real_shots,
    )

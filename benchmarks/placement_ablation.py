"""Beyond-paper ablation: rank placement policy x steal policy.

Radius-limited work-stealing wants every radius window to contain a
representative speed mix.  Under the paper's PURE preemptive rules (Eq. 4-8
only), blocked placement (SLURM-component order) strands surplus inside
slow blocks and forfeits the entire gain; our final policy adds the
remaining-work tail/relay rule, which restores robustness — blocked and
interleaved then perform within noise of each other.  This quantifies both.
"""

from __future__ import annotations

from .common import gain, median_makespan


def run(seeds: int = 3, csv: bool = True):
    conf, tasks = "C4", 3840
    rows = {}
    for order in ("interleaved", "blocked"):
        a = median_makespan("a2ws", conf, tasks, seeds=seeds, order=order)
        c = median_makespan("ctws", conf, tasks, seeds=seeds, order=order)
        rows[order] = (a, gain(a, c))
        if csv:
            print(
                f"placement_{order},{a*1e6:.0f},gain_vs_ctws={gain(a, c):.1f}"
            )
    derived = {
        "blocked_penalty_pct": round(
            (rows["blocked"][0] / rows["interleaved"][0] - 1) * 100, 1
        ),
        "placement_robust_within_5pct": abs(
            rows["blocked"][0] / rows["interleaved"][0] - 1
        ) < 0.05,
        "positive_gain_both_orders": min(
            rows["interleaved"][1], rows["blocked"][1]
        ) > 0,
    }
    if csv:
        print(f"placement_summary,0,{derived}")
    return rows, derived


if __name__ == "__main__":
    run()

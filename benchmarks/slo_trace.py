"""SLO serving benchmark at diurnal-trace scale (DESIGN.md §SLO serving).

A 10^6-request bursty diurnal trace (sinusoidal base rate + flash-crowd
spikes, 25% latency-class with a 0.5 s budget, batch with 30 s) replayed on
the virtual-time plane over an 8-node pool with 8 autoscaler reserves.
Three legs, identical trace per seed, the only variables being queue
ordering and the scaler:

* **threshold_noslo** — FIFO owner pops + the PR-3 reactive threshold
  autoscaler: the pre-SLO baseline.
* **threshold_slo**   — SLO-ordered owner pops (latency jumps batch, EDF
  within class, 10 s batch aging) on the same threshold scaler: isolates
  the ordering win.
* **predictive_slo**  — SLO ordering + the predictive autoscaler (Holt's
  level+trend forecast of arrival rate, provisioned at 75% target
  utilisation): reserves come up BEFORE the backlog a threshold scaler
  needs as evidence.

Acceptance (the ISSUE headline): predictive_slo must beat threshold_noslo
STRICTLY on latency-class p99.9 and on latency-class SLO-violation rate,
on the same trace.  Emits ``BENCH_slo_trace.json`` via ``benchmarks.run``.
"""

from __future__ import annotations

import numpy as np

from .common import timed  # noqa: F401  (harness convention)

import sys

sys.path.insert(0, "src")
from repro.core.simulator import SimAutoscale, SimConfig, simulate  # noqa: E402
from repro.core.trace import diurnal_trace  # noqa: E402

P = 8
RESERVE = (1.0,) * 8
TASK_COST = 0.05  # seconds/task at speed 1.0 -> 160 tasks/s base capacity
MEAN_RATE = 100.0  # diurnal peak ~160/s + spikes ~310/s: reserves required
PERIOD = 1200.0
DEPTH = 0.6
SPIKES = 3
SPIKE_AMP = 1.5
SPIKE_WIDTH = 15.0
LATENCY_FRAC = 0.25
DEADLINES = (30.0, 0.5)  # (batch, latency) budgets, seconds
AGING = 10.0
N_FULL = 1_000_000
N_FAST = 60_000


def _legs(arr: np.ndarray, slo: np.ndarray, seed: int) -> dict[str, SimConfig]:
    base = dict(
        speeds=(1.0,) * P,
        num_tasks=len(arr),
        task_cost=TASK_COST,
        seed=seed,
        arrival="trace",
        arrival_trace=arr,
        slo_trace=slo,
        slo_deadlines=DEADLINES,
        slo_aging=AGING,
        record_tasks=False,  # 10^6 task records would dominate memory
    )
    thresh = SimAutoscale(reserve=RESERVE, interval=1.0, mode="threshold")
    pred = SimAutoscale(reserve=RESERVE, interval=1.0, mode="predictive")
    return {
        "threshold_noslo": SimConfig(
            **base, slo_order=False, autoscale=thresh
        ),
        "threshold_slo": SimConfig(**base, slo_order=True, autoscale=thresh),
        "predictive_slo": SimConfig(**base, slo_order=True, autoscale=pred),
    }


def run(seeds: int = 1, fast: bool = False, csv: bool = True):
    n = N_FAST if fast else N_FULL

    names = ("threshold_noslo", "threshold_slo", "predictive_slo")
    per = {
        name: {
            "lat_p99": [], "lat_p999": [], "lat_viol_rate": [],
            "batch_p50": [], "makespan": [], "scale_out": [],
        }
        for name in names
    }
    for seed in range(seeds):
        arr, slo = diurnal_trace(
            n,
            mean_rate=MEAN_RATE,
            period=PERIOD,
            depth=DEPTH,
            spikes=SPIKES,
            spike_amp=SPIKE_AMP,
            spike_width=SPIKE_WIDTH,
            latency_frac=LATENCY_FRAC,
            seed=seed,
        )
        for name, cfg in _legs(arr, slo, seed).items():
            res = simulate("a2ws", cfg)
            assert sum(res.per_node_tasks) == n and res.lost_tasks == 0
            lats = np.asarray(res.slo_latencies["latency"])
            per[name]["lat_p99"].append(float(np.percentile(lats, 99.0)))
            per[name]["lat_p999"].append(float(np.percentile(lats, 99.9)))
            per[name]["lat_viol_rate"].append(
                res.slo_violation_rate()["latency"]
            )
            per[name]["batch_p50"].append(
                float(np.percentile(res.slo_latencies["batch"], 50.0))
            )
            per[name]["makespan"].append(res.makespan)
            per[name]["scale_out"].append(
                sum(1 for _, k, _n, _p in res.scale_log if k == "out")
            )

    med = {
        f"{name}_{k}": float(np.median(v))
        for name, m in per.items() for k, v in m.items()
    }
    base999 = med["threshold_noslo_lat_p999"]
    base_viol = med["threshold_noslo_lat_viol_rate"]
    out = {
        "P": P,
        "reserves": len(RESERVE),
        "num_requests": n,
        "seeds": seeds,
        "mean_rate": MEAN_RATE,
        "period_s": PERIOD,
        "latency_frac": LATENCY_FRAC,
        "latency_budget_s": DEADLINES[1],
        "batch_budget_s": DEADLINES[0],
        **med,
        "slo_p999_ratio": med["threshold_slo_lat_p999"] / base999,
        "predictive_p999_ratio": med["predictive_slo_lat_p999"] / base999,
        # the ISSUE's acceptance booleans: strictly better p99.9 AND
        # violation rate under SLO ordering + predictive autoscaling
        "predictive_p999_better": bool(
            med["predictive_slo_lat_p999"] < base999
        ),
        "predictive_viol_better": bool(
            med["predictive_slo_lat_viol_rate"] < base_viol
        ),
    }
    if csv:
        for name in names:
            print(
                f"slo_trace_{name},{med[f'{name}_lat_p999']*1e6:.0f},"
                f"lat_p99={med[f'{name}_lat_p99']:.3f}s"
                f"_viol={med[f'{name}_lat_viol_rate']:.4f}"
                f"_batch_p50={med[f'{name}_batch_p50']:.2f}s"
                f"_scale_out={med[f'{name}_scale_out']:.0f}"
            )
        print(
            f"slo_trace_headline,{n},"
            f"p999_better={out['predictive_p999_better']}"
            f"_viol_better={out['predictive_viol_better']}"
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()
    run(seeds=args.seeds, fast=args.fast)

"""Two-level hierarchy headline (DESIGN.md §Hierarchy): flat vs hierarchical
A2WS at P = 512 in the virtual-time plane, plus the K×ρ cell-shape sweep.

The regime is short tasks (task_cost = 2 s) on the tiled Table-2 C4
heterogeneous mix: with tasks this short the info plane dominates — a flat
ring pays O(P)-radius per-boundary communication and multi-second relay
staleness, while cells pay O(ρ) and stay fresh, so the hierarchy wins BOTH
makespan and per-boundary overhead.  ``headline`` records the flat-vs-hier
pair; ``sweep`` walks K (number of cells, ρ = P/K members each) to show the
cost bathtub — K too small re-creates the flat ring, K too large starves
intra-cell stealing and leans on the (batched, slower) leader plane.

The flat baseline is the expensive leg (its Python view loop is O(radius²)
per boundary), so it runs once at seed 0; hierarchical legs are cheap and
sweep K at the same config.
"""

from __future__ import annotations

import time

import numpy as np

import sys
sys.path.insert(0, "src")
from repro.core.policy import HierarchicalA2WSPolicy  # noqa: E402
from repro.core.simulator import SimConfig, simulate, table2_speeds  # noqa: E402

SIZE = 512
TASK_COST = 2.0
SWEEP_K = (8, 16, 23, 32, 64)


def _leg(policy, cfg) -> dict:
    t0 = time.perf_counter()
    res = simulate(policy, cfg)
    wall = time.perf_counter() - t0
    out = {
        "makespan": res.makespan,
        "steals": res.steals,
        "moved": res.moved_tasks,
        "boundaries": res.boundaries,
        "wall_s": wall,
        "us_per_boundary": wall / max(res.boundaries, 1) * 1e6,
    }
    if isinstance(policy, HierarchicalA2WSPolicy):
        out["num_cells"] = policy.cells.num_cells
        out["xcell_steals"] = policy.xcell_steals
        out["xcell_moved"] = policy.xcell_moved
    return out


def run(seeds: int = 1, fast: bool = False, csv: bool = True):
    p = SIZE
    speeds = tuple(np.tile(table2_speeds("C4"), p // 64))
    cfg = SimConfig(
        speeds=speeds, num_tasks=p * (4 if fast else 6), seed=0,
        task_cost=TASK_COST,
    )

    flat = _leg("a2ws", cfg)
    hier = _leg(HierarchicalA2WSPolicy(p), cfg)
    headline = {
        "P": p,
        "task_cost": TASK_COST,
        "num_tasks": cfg.num_tasks,
        "flat": flat,
        "hier": hier,
        "makespan_gain_pct": (1.0 - hier["makespan"] / flat["makespan"]) * 100,
        "overhead_ratio": flat["us_per_boundary"] / hier["us_per_boundary"],
    }
    if csv:
        print(
            f"hier_flat_p{p},{flat['us_per_boundary']:.1f},"
            f"makespan={flat['makespan']:.3f}"
        )
        print(
            f"hier_cells_p{p},{hier['us_per_boundary']:.1f},"
            f"makespan={hier['makespan']:.3f}_K={hier['num_cells']}"
        )
        print(
            f"hier_gain,{headline['makespan_gain_pct']:.2f},"
            f"overhead_ratio={headline['overhead_ratio']:.1f}x"
        )

    sweep = {}
    for k in SWEEP_K:
        leg = _leg(HierarchicalA2WSPolicy(p, num_cells=k), cfg)
        sweep[f"K{k}"] = leg
        if csv:
            print(
                f"hier_sweep_k{k},{leg['us_per_boundary']:.1f},"
                f"makespan={leg['makespan']:.3f}_rho={p // k}"
            )
    return {"headline": headline, "sweep": sweep}


if __name__ == "__main__":
    run()

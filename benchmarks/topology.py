"""Topology-priced stealing headline (DESIGN.md §Topology plane): net-priced
vs network-blind stealing at P = 512 on a skewed two-level fabric, plus a
topology-model sweep (flat-uniform vs two-level vs fat-tree).

The regime is the hierarchy benchmark's interleaved short-task C4 mix under
the FLAT weighted scheduler (PR-4): every thief probes a ring window of
~0.2·P neighbours, and with ~22-worker cells that window is almost entirely
cross-cell — a thief happily strips a victim three hops away over an
equally-loaded neighbour, which is exactly the traffic a two-level fabric
punishes (cross-cell link ≥ 10× the intra-cell link: the intra tier here is
free, the cross tier costs a latency + per-task fare).  Both legs run the
SAME cost model — the simulator charges every transfer's fare on the actual
take either way — the only difference is whether the scheduler gets to see
the price sheet:

* ``blind``  (``topology_aware=False``): the PR-4 scheduler exactly as it
  was — victim selection plans as if loot moved for free, then pays the
  link fare anyway.
* ``priced`` (``topology_aware=True``): victim weights are
  distance-penalized, net-negative steals are refused (work gained must
  beat the transfer cost), and priced loot moves as one batched claim per
  hop.

The acceptance claim recorded in ``headline``: priced beats blind on
makespan while moving STRICTLY fewer cross-cell tasks.  ``sweep`` runs the
priced hierarchical scheduler (cheap legs) under the three built-in cost
models at comparable price scales — flat-uniform (everything equally far)
shows the refusal rule alone, fat-tree grades 2/4/6 hops between the
two-level extremes.
"""

from __future__ import annotations

import time

import numpy as np

import sys
sys.path.insert(0, "src")
from repro.core.policy import HierarchicalA2WSPolicy  # noqa: E402
from repro.core.simulator import SimConfig, simulate, table2_speeds  # noqa: E402
from repro.core.topology import Topology  # noqa: E402

SIZE = 512
FAST_SIZE = 128
TASK_COST = 2.0
# Two-level fabric: the intra-cell tier is free (a steal inside a cell is
# bit-for-bit the unpriced scheduler), the cross-cell tier charges a
# latency + per-task fare — trivially ≥ 10× intra on both terms.
CROSS_LAT, CROSS_PER = 1e-1, 2e-2
FAT_TREE_K = 16  # k³/4 = 1024 hosts ≥ P, core distance = 6 hops


def _xcell_moved(res, cell_of) -> int:
    return sum(
        take
        for _t, thief, victim, take in res.steal_log
        if cell_of(thief) != cell_of(victim)
    )


def _flat_leg(cfg, topo, cell_of, aware: bool) -> dict:
    """One headline leg: the FLAT weighted scheduler, priced or blind."""
    t0 = time.perf_counter()
    res = simulate("a2ws", cfg.with_(topology=topo, topology_aware=aware))
    wall = time.perf_counter() - t0
    return {
        "makespan": res.makespan,
        "steals": res.steals,
        "moved": res.moved_tasks,
        "xcell_moved": _xcell_moved(res, cell_of),
        "boundaries": res.boundaries,
        "wall_s": wall,
    }


def _hier_leg(cfg, topo, p: int, aware: bool) -> dict:
    """One sweep leg: the hierarchical scheduler (O(cell) hot path)."""
    pol = HierarchicalA2WSPolicy(p)  # fresh per leg: stateful
    t0 = time.perf_counter()
    res = simulate(pol, cfg.with_(topology=topo, topology_aware=aware))
    wall = time.perf_counter() - t0
    return {
        "makespan": res.makespan,
        "steals": res.steals,
        "moved": res.moved_tasks,
        "xcell_moved": _xcell_moved(res, pol.cells.cell_of),
        "xcell_refused": pol.xcell_refused,
        "boundaries": res.boundaries,
        "wall_s": wall,
    }


def run(seeds: int = 1, fast: bool = False, csv: bool = True):
    p = FAST_SIZE if fast else SIZE
    speeds = tuple(np.tile(table2_speeds("C4"), p // 64))  # interleaved mix
    cfg = SimConfig(
        speeds=speeds, num_tasks=p * 4, seed=0, task_cost=TASK_COST,
    )
    cells = HierarchicalA2WSPolicy(p).cells  # the deterministic cell split
    two_level = Topology.two_level(
        cells,
        cross_latency=CROSS_LAT, cross_per_task=CROSS_PER,
    )

    blind = _flat_leg(cfg, two_level, cells.cell_of, aware=False)
    priced = _flat_leg(cfg, two_level, cells.cell_of, aware=True)
    headline = {
        "P": p,
        "task_cost": TASK_COST,
        "num_tasks": cfg.num_tasks,
        "num_cells": cells.num_cells,
        "cross_latency": CROSS_LAT,
        "cross_per_task": CROSS_PER,
        "blind": blind,
        "priced": priced,
        "makespan_gain_pct": (
            (1.0 - priced["makespan"] / blind["makespan"]) * 100
        ),
        "xcell_moved_ratio": (
            priced["xcell_moved"] / max(blind["xcell_moved"], 1)
        ),
    }
    if csv:
        print(
            f"topo_blind_p{p},{blind['makespan']:.3f},"
            f"xcell_moved={blind['xcell_moved']}"
        )
        print(
            f"topo_priced_p{p},{priced['makespan']:.3f},"
            f"xcell_moved={priced['xcell_moved']}"
        )
        print(
            f"topo_gain,{headline['makespan_gain_pct']:.2f},"
            f"xcell_ratio={headline['xcell_moved_ratio']:.3f}"
        )

    # Topology-model sweep at comparable price scales, on the hierarchical
    # scheduler (legs are ~40× cheaper than flat): uniform charges every
    # pair the cross tier (everything equally far — only the refusal rule
    # and batching act); fat-tree grades 2/4/6 hops so the core distance
    # matches the two-level cross tier.
    models = {
        "uniform": Topology.uniform(CROSS_LAT, CROSS_PER),
        "two_level": two_level,
        "fat_tree": Topology.fat_tree(
            FAT_TREE_K,
            hop_latency=CROSS_LAT / 6.0, hop_per_task=CROSS_PER / 6.0,
        ),
    }
    sweep = {}
    for name, topo in models.items():
        leg = _hier_leg(cfg, topo, p, aware=True)
        sweep[name] = leg
        if csv:
            print(
                f"topo_sweep_{name},{leg['makespan']:.3f},"
                f"xcell_moved={leg['xcell_moved']}"
                f"_refused={leg['xcell_refused']}"
            )
    return {"headline": headline, "sweep": sweep}


if __name__ == "__main__":
    run()

"""Roofline collation: reads experiments/dryrun/*.json into the §Roofline
table (compute/memory/collective terms, dominant bottleneck, 6ND ratio)."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(mesh: str | None = "16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(path))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_table(recs) -> str:
    hdr = (
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
        "6ND/HLO | HBM fit |\n|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |"
            )
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — |"
            )
            continue
        dom = r["dominant"].replace("t_", "")
        fit = "yes" if r.get("fits_hbm16g") else "NO"
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3f} | "
            f"{r['t_memory']:.3f} | {r['t_collective']:.3f} | {dom} | "
            f"{'—' if ratio is None else format(ratio, '.2f')} | {fit} |"
        )
    return "\n".join(lines)


def run(csv: bool = True):
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    if csv:
        for r in ok:
            dom = r["dominant"]
            ratio = r.get("useful_flops_ratio")
            print(
                f"roofline_{r['arch']}_{r['shape']},"
                f"{max(r['t_compute'], r['t_memory'], r['t_collective'])*1e6:.0f},"
                f"dom={dom};ratio="
                + ("-" if ratio is None else format(ratio, ".2f"))
            )
        n_dom = {}
        for r in ok:
            n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
        print(f"roofline_summary,0,cells={len(ok)};dominants={n_dom}")
    return recs


if __name__ == "__main__":
    print(fmt_table(load_records()))

"""Open-arrival latency benchmark (DESIGN.md §Open-arrival).

Measures what the closed-batch tables cannot: per-request latency
percentiles under a continuous Poisson arrival stream.

1. Virtual (discrete-event, C2 = 16 heterogeneous nodes): requests arrive
   round-robin at ~75% of aggregate capacity.  Adaptive stealing (paper
   radius) vs no stealing (radius=0 — static round-robin routing).  The
   slow 1-core nodes receive the same arrival share as the 24-core nodes,
   so without stealing their queues diverge and the tail explodes; the
   steal-rate math (Eq. 5 on instantaneous depths) is what rescues p99.

2. Threaded (real concurrency): a live ``ServePool`` of 4 replicas (one
   8x slower) serving ~2 ms no-op requests streamed at ~80% capacity —
   scheduling overhead and steal latency are real, the "model" is a sleep.
"""

from __future__ import annotations

import time

import numpy as np

from .common import timed  # noqa: F401  (harness convention)

import sys
sys.path.insert(0, "src")
from repro.core.simulator import SimConfig, simulate, table2_speeds  # noqa: E402
from repro.serve.engine import Replica, ServePool  # noqa: E402


def _sim_latency(radius, seeds: int):
    speeds = table2_speeds("C2")
    capacity = float(speeds.sum()) / 60.0  # tasks/sec at task_cost=60
    p50s, p99s, mks = [], [], []
    for seed in range(seeds):
        cfg = SimConfig(
            speeds=speeds, num_tasks=960, seed=seed,
            arrival="poisson", arrival_rate=0.75 * capacity, radius=radius,
        )
        res = simulate("a2ws", cfg)
        pct = res.latency_percentiles((50.0, 99.0))
        p50s.append(pct[50.0])
        p99s.append(pct[99.0])
        mks.append(res.makespan)
    return (
        float(np.median(p50s)), float(np.median(p99s)), float(np.median(mks))
    )


def _pool_latency():
    rng = np.random.default_rng(0)
    n_req, work = 200, 0.002

    def gen(request):
        time.sleep(work)
        return {"ok": True}

    replicas = [Replica(f"r{k}", gen) for k in range(3)]
    replicas.append(Replica("r3-slow", gen, slow_factor=8.0))
    # capacity: 3 fast (1/2ms) + 1 slow (1/16ms) ≈ 1562 req/s; drive at ~80%
    rate = 0.8 * (3 / work + 1 / (8 * work))
    pool = ServePool(replicas, seed=0)
    pool.start()
    futs = []
    for _ in range(n_req):
        time.sleep(float(rng.exponential(1.0 / rate)))
        futs.append(pool.submit({"x": 0}))
    for f in futs:
        f.result(timeout=60)
    stats = pool.shutdown()
    pct = stats.latency_percentiles((50.0, 99.0))
    return pct[50.0], pct[99.0], len(stats.steals), stats.per_worker_tasks


def run(seeds: int = 3, csv: bool = True):
    paper_r = max(1, round(0.2 * 16))  # the paper's 20% operating point
    p50_r, p99_r, mk_r = _sim_latency(paper_r, seeds)
    p50_0, p99_0, mk_0 = _sim_latency(0, seeds)
    if csv:
        print(f"open_arrival_sim_C2_p50_steal,{p50_r*1e6:.0f},seconds={p50_r:.2f}")
        print(f"open_arrival_sim_C2_p99_steal,{p99_r*1e6:.0f},seconds={p99_r:.2f}")
        print(f"open_arrival_sim_C2_p99_nosteal,{p99_0*1e6:.0f},seconds={p99_0:.2f}")
        print(
            f"open_arrival_sim_C2_p99_gain,"
            f"{(1 - p99_r / p99_0) * 100:.1f},percent_vs_no_steal"
        )
    p50, p99, steals, per_rep = _pool_latency()
    if csv:
        print(f"open_arrival_pool_p50,{p50*1e6:.0f},us")
        print(f"open_arrival_pool_p99,{p99*1e6:.0f},us")
        print(
            f"open_arrival_pool_steals,{steals},"
            f"tasks_per_replica={'/'.join(str(c) for c in per_rep)}"
        )
    return {
        "sim_p99_steal_s": p99_r,
        "sim_p99_nosteal_s": p99_0,
        "pool_p99_us": p99 * 1e6,
        "pool_steals": steals,
    }


if __name__ == "__main__":
    run()

"""Elastic-membership benchmark (DESIGN.md §Elasticity).

Measures the scenario a fixed-size pool cannot express: a traffic surge
hitting a small serving pool that is allowed to SCALE OUT at runtime.

1. Threaded surge (the acceptance scenario): Poisson requests at ~1.8x the
   2-replica service capacity.  The elastic pool starts at 2 replicas and a
   threshold autoscaler (backlog > 3 requests/replica) grows it up to 6;
   the fixed pool serves the identical trace with 2 replicas forever.  The
   autoscaler must reach 6 replicas and cut p99 latency vs the fixed pool.

2. Virtual maintenance churn: C1 under open arrivals at ~85% utilisation
   with two slow nodes retiring mid-run; the elastic run replaces them with
   two fast joiners (spot-preemption-with-replacement), the degraded run
   does not.  Same policy objects, virtual time (`SimConfig.joins/retires`).

Emits ``BENCH_elastic.json`` via ``benchmarks.run`` (the returned dict).
"""

from __future__ import annotations

import time

import numpy as np

from .common import timed  # noqa: F401  (harness convention)

import sys

sys.path.insert(0, "src")
from repro.core.simulator import SimConfig, simulate, table2_speeds  # noqa: E402
from repro.serve.engine import AutoscaleConfig, Replica, ServePool  # noqa: E402

#: per-request service time of the no-op model (seconds)
WORK = 0.004
#: surge arrival rate vs the 2-replica capacity (2/WORK requests/sec) —
#: 3x keeps even the fully scaled-out 6-replica pool at saturation, so the
#: autoscaler must ride all the way to max_replicas
SURGE = 3.0


def _surge_pool(elastic: bool, n_req: int, seed: int):
    rng = np.random.default_rng(seed)

    def gen(request):
        time.sleep(WORK)
        return {"ok": True}

    def factory(wid: int) -> Replica:
        return Replica(f"surge{wid}", gen)

    autoscale = (
        AutoscaleConfig(
            factory=factory, min_replicas=2, max_replicas=6,
            high_pending_per_replica=3.0, interval=0.01,
        )
        if elastic
        else None
    )
    pool = ServePool(
        [Replica("r0", gen), Replica("r1", gen)], seed=seed,
        autoscale=autoscale,
    )
    pool.start()
    rate = SURGE * 2.0 / WORK
    # Pace against the wall clock, not with per-gap sleeps: sub-millisecond
    # time.sleep overshoots ~2x, which would quietly halve the surge.
    offsets = np.cumsum(rng.exponential(1.0 / rate, n_req))
    futs = []
    t0 = time.perf_counter()
    for t_arr in offsets:
        while time.perf_counter() - t0 < t_arr:
            time.sleep(2e-4)
        futs.append(pool.submit({"x": 0}))
    for f in futs:
        f.result(timeout=60)
    peak = pool.peak_live
    scale_outs = sum(1 for e in pool.scale_events if e[1] == "out")
    stats = pool.shutdown()
    pct = stats.latency_percentiles((50.0, 99.0))
    return pct[50.0], pct[99.0], peak, scale_outs


def _sim_churn(replace: bool, seeds: int):
    """C1 open arrivals; two 1-core nodes retire at t=120/180.  ``replace``
    adds two 24-core joiners at the same instants."""
    speeds = table2_speeds("C1")
    capacity = float(speeds.sum()) / 60.0
    slow = [int(i) for i in np.argsort(speeds)[:2]]
    p99s = []
    for seed in range(seeds):
        cfg = SimConfig(
            speeds=speeds, num_tasks=600, seed=seed,
            arrival="poisson", arrival_rate=0.85 * capacity,
            retires=((120.0, slow[0]), (180.0, slow[1])),
            joins=((120.0, 24.0), (180.0, 24.0)) if replace else (),
        )
        res = simulate("a2ws", cfg)
        assert sum(res.per_node_tasks) == 600
        p99s.append(res.latency_percentiles((99.0,))[99.0])
    return float(np.median(p99s))


def run(seeds: int = 3, fast: bool = False, csv: bool = True):
    n_req = 150 if fast else 300
    fixed_p50, fixed_p99, fixed_peak, _ = _surge_pool(False, n_req, seed=0)
    el_p50, el_p99, el_peak, outs = _surge_pool(True, n_req, seed=0)
    sim_degraded = _sim_churn(False, seeds)
    sim_replaced = _sim_churn(True, seeds)
    out = {
        "surge_requests": n_req,
        "surge_fixed_p99_s": fixed_p99,
        "surge_elastic_p99_s": el_p99,
        "surge_fixed_p50_s": fixed_p50,
        "surge_elastic_p50_s": el_p50,
        "surge_fixed_replicas": fixed_peak,
        "surge_elastic_peak_replicas": el_peak,
        "surge_scale_outs": outs,
        "surge_p99_gain_pct": (1.0 - el_p99 / fixed_p99) * 100.0,
        "sim_churn_degraded_p99_s": sim_degraded,
        "sim_churn_replaced_p99_s": sim_replaced,
        "sim_churn_p99_gain_pct": (1.0 - sim_replaced / sim_degraded) * 100.0,
    }
    if csv:
        print(f"elastic_surge_fixed_p99,{fixed_p99*1e6:.0f},replicas=2")
        print(
            f"elastic_surge_elastic_p99,{el_p99*1e6:.0f},"
            f"peak_replicas={el_peak}|scale_outs={outs}"
        )
        print(
            f"elastic_surge_p99_gain,{out['surge_p99_gain_pct']:.1f},"
            f"percent_vs_fixed_pool"
        )
        print(f"elastic_sim_churn_degraded_p99,{sim_degraded*1e6:.0f},seconds={sim_degraded:.2f}")
        print(f"elastic_sim_churn_replaced_p99,{sim_replaced*1e6:.0f},seconds={sim_replaced:.2f}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()
    run(seeds=1 if args.fast else args.seeds, fast=args.fast)

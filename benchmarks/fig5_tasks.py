"""Fig. 5 — per-process task counts and runtimes, C1 / 480 tasks.

Paper claims: (a) A2WS and CTWS give similar per-task runtimes, (b) the
slowest processes run FEWER tasks under A2WS than under CTWS/LW (A2WS
prioritises fast processes), (c) LW slows process 0 (leader co-location).
"""

from __future__ import annotations

import numpy as np

from .common import SimConfig, simulate, table2_speeds


def run(seed: int = 0, csv: bool = True):
    speeds = table2_speeds("C1", order="blocked")  # paper Fig. 5 ordering
    cfg = SimConfig(speeds=speeds, num_tasks=480, seed=seed)
    out = {}
    for policy in ("a2ws", "ctws", "lw"):
        res = simulate(policy, cfg)
        out[policy] = res
        if csv:
            counts = "/".join(str(c) for c in res.per_node_tasks)
            print(f"fig5_{policy},{res.makespan*1e6:.0f},tasks={counts}")
    slow = speeds == 1.0
    a_slow = np.asarray(out["a2ws"].per_node_tasks)[slow].sum()
    c_slow = np.asarray(out["ctws"].per_node_tasks)[slow].sum()
    l_slow = np.asarray(out["lw"].per_node_tasks)[slow].sum()
    derived = {
        "a2ws_slow_tasks": int(a_slow),
        "ctws_slow_tasks": int(c_slow),
        "lw_slow_tasks": int(l_slow),
        "a2ws_gives_slow_fewer": bool(a_slow <= min(c_slow, l_slow)),
    }
    if csv:
        print(f"fig5_summary,0,{derived}")
    return out, derived


if __name__ == "__main__":
    run()

"""Scheduler micro-benchmarks: decision latency of the smart-stealing math
and throughput of the threaded A2WS runtime on no-op tasks (scheduling
overhead per task)."""

from __future__ import annotations

import numpy as np

from .common import timed

import sys
sys.path.insert(0, "src")
from repro.core.a2ws import A2WSRuntime  # noqa: E402
from repro.core.steal import plan_steal  # noqa: E402


def run(csv: bool = True):
    rng = np.random.default_rng(0)
    p = 128
    n = rng.integers(1, 100, p).astype(float)
    t = rng.uniform(0.5, 10.0, p)
    q = rng.integers(0, 50, p).astype(float)
    _, t_plan = timed(
        lambda: plan_steal(rng, 0, n, t, q, radius=26), iters=200
    )

    def tiny_run():
        rt = A2WSRuntime(list(range(200)), 4, lambda w, task: None, seed=1)
        return rt.run()

    stats, t_run = timed(tiny_run, warmup=1, iters=2)
    per_task = t_run / 200
    if csv:
        print(f"sched_plan_steal_128p,{t_plan*1e6:.1f},radius=26")
        print(
            f"sched_runtime_overhead,{per_task*1e6:.0f},"
            f"per_task_us_4workers_200tasks"
        )
    return {"plan_steal_us": t_plan * 1e6, "per_task_us": per_task * 1e6}


if __name__ == "__main__":
    run()

"""Scheduler micro-benchmarks: per-boundary VIEW and STEAL-PLAN cost of the
threaded substrate, flat vs two-level hierarchical, across ring sizes.

The headline scaling question (DESIGN.md §Hierarchy): a flat A2WS boundary
builds an O(P)-row view and walks an O(P)-radius window, so its cost grows
with the ring; a hierarchical boundary is scoped to the worker's CELL
(ρ ≈ √P members), so at fixed ρ its cost is flat in P.  This module measures
both sides at P ∈ {32, 128, 512, 1024} on the real ``WorkerPool`` view
builder (weighted mode, 3 task classes — the expensive path), plus the
legacy ``plan_steal`` decision-latency and end-to-end no-op-task overhead
metrics.
"""

from __future__ import annotations

import numpy as np

from .common import timed

import sys
sys.path.insert(0, "src")
from repro.core.a2ws import A2WSRuntime, WorkerPool  # noqa: E402
from repro.core.policy import HierarchicalA2WSPolicy  # noqa: E402
from repro.core.steal import plan_steal  # noqa: E402

SIZES = (32, 128, 512, 1024)
NUM_CLASSES = 3
RHO = 16  # fixed cell size for the scaling sweep: cost should be flat in P


def _pool(p: int, policy) -> WorkerPool:
    """A constructed-but-not-started pool: ``_make_view``/``on_boundary``
    are callable without threads (the boundary hot path, isolated)."""
    tasks = list(range(p * 4))
    return WorkerPool(
        tasks, p, lambda w, t: None, policy=policy, seed=0,
        cost_class_fn=lambda t: t % NUM_CLASSES, num_classes=NUM_CLASSES,
    )


def _boundary_cost(pool: WorkerPool, worker: int, iters: int) -> tuple:
    """(view_us, plan_us) for one worker's task boundary."""
    _, t_view = timed(lambda: pool._make_view(worker), warmup=2, iters=iters)
    view = pool._make_view(worker)
    _, t_plan = timed(
        lambda: pool.policy.on_boundary(view), warmup=2, iters=iters
    )
    return t_view * 1e6, t_plan * 1e6


def run(csv: bool = True):
    result: dict = {"view_us": {}, "plan_us": {}, "rho": RHO}
    for p in SIZES:
        iters = max(20, 2000 // p)
        flat = _pool(p, "a2ws")
        fv, fp = _boundary_cost(flat, p // 2, iters)
        hier = _pool(p, HierarchicalA2WSPolicy(p, cell_size=RHO))
        hv, hp = _boundary_cost(hier, p // 2, iters)
        result["view_us"][f"P{p}"] = {"flat": fv, "hier": hv}
        result["plan_us"][f"P{p}"] = {"flat": fp, "hier": hp}
        if csv:
            print(f"sched_view_flat_p{p},{fv:.1f},weighted_c{NUM_CLASSES}")
            print(f"sched_view_hier_p{p},{hv:.1f},rho={RHO}")
            print(f"sched_plan_flat_p{p},{fp:.1f},on_boundary")
            print(f"sched_plan_hier_p{p},{hp:.1f},on_boundary")

    rng = np.random.default_rng(0)
    p = 128
    n = rng.integers(1, 100, p).astype(float)
    t = rng.uniform(0.5, 10.0, p)
    q = rng.integers(0, 50, p).astype(float)
    _, t_plan = timed(
        lambda: plan_steal(rng, 0, n, t, q, radius=26), iters=200
    )

    def tiny_run():
        rt = A2WSRuntime(list(range(200)), 4, lambda w, task: None, seed=1)
        return rt.run()

    stats, t_run = timed(tiny_run, warmup=1, iters=2)
    per_task = t_run / 200
    if csv:
        print(f"sched_plan_steal_128p,{t_plan*1e6:.1f},radius=26")
        print(
            f"sched_runtime_overhead,{per_task*1e6:.0f},"
            f"per_task_us_4workers_200tasks"
        )
    result["plan_steal_us"] = t_plan * 1e6
    result["per_task_us"] = per_task * 1e6
    return result


if __name__ == "__main__":
    run()

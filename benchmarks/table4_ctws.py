"""Table 4 — gain of A2WS over CTWS (cyclic token WS, Assis et al. 2019)
across C1..C5 x task counts (median of N seeds, paper Eq. 13)."""

from __future__ import annotations

from .common import CONFIGS, TASKS, gain, median_makespan


def run(seeds: int = 3, csv: bool = True, order: str = "interleaved"):
    grid = {}
    for tasks in TASKS:
        for conf in CONFIGS:
            a = median_makespan("a2ws", conf, tasks, seeds=seeds, order=order)
            c = median_makespan("ctws", conf, tasks, seeds=seeds, order=order)
            g = gain(a, c)
            grid[(tasks, conf)] = g
            if csv:
                print(f"table4_ctws_{conf}_{tasks},{a*1e6:.0f},gain_pct={g:.1f}")
    derived = {
        "C5_3840_gain": round(grid[(3840, "C5")], 1),
        "C1_480_gain": round(grid[(480, "C1")], 1),
        "gain_grows_with_nodes_3840": grid[(3840, "C5")] > grid[(3840, "C1")],
        "corner_C4_480_negative": grid[(480, "C4")] < 0,
    }
    if csv:
        print(f"table4_summary,0,{derived}")
    return grid, derived


if __name__ == "__main__":
    run()

"""Table 3 — gain of A2WS over LW (leader–workers) across C1..C5 x task
counts (median of N seeds, paper Eq. 13)."""

from __future__ import annotations

from .common import CONFIGS, TASKS, gain, median_makespan


def run(seeds: int = 3, csv: bool = True, order: str = "interleaved"):
    grid = {}
    for tasks in TASKS:
        for conf in CONFIGS:
            a = median_makespan("a2ws", conf, tasks, seeds=seeds, order=order)
            l = median_makespan("lw", conf, tasks, seeds=seeds, order=order)
            g = gain(a, l)
            grid[(tasks, conf)] = g
            if csv:
                print(f"table3_lw_{conf}_{tasks},{a*1e6:.0f},gain_pct={g:.1f}")
    # headline cells (paper: ~10.1% at C5/3840; negative corners)
    derived = {
        "C5_3840_gain": round(grid[(3840, "C5")], 1),
        "C1_480_gain": round(grid[(480, "C1")], 1),
        "corner_C4_480_negative": grid[(480, "C4")] < 0,
        "corner_C5_960_negative": grid[(960, "C5")] < 0,
    }
    if csv:
        print(f"table3_summary,0,{derived}")
    return grid, derived


if __name__ == "__main__":
    run()

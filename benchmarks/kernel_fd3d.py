"""FD3D stencil kernel micro-benchmark: fused Pallas (interpret on CPU; the
TPU target) vs the unfused jnp oracle.  On CPU the oracle is the fast path —
the interesting derived number is HBM traffic per step (the fusion motive):
the fused kernel reads u, u_prev, c2dt2 and writes u_next once (4 passes),
the unfused oracle issues ~7 passes over the wavefield."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import timed

import sys
sys.path.insert(0, "src")
from repro.kernels.fd3d import fd3d_step  # noqa: E402
from repro.kernels.fd3d.fd3d import fd3d_pallas  # noqa: E402


def run(n: int = 64, csv: bool = True):
    shape = (n, n, n)
    k1, k2 = jax.random.split(jax.random.key(0))
    u = jax.random.normal(k1, shape, jnp.float32)
    up = jax.random.normal(k2, shape, jnp.float32)
    c2 = jnp.full(shape, 0.1, jnp.float32)

    ref = jax.jit(lambda a, b, c: fd3d_step(a, b, c, dx=10.0, backend="ref"))
    _, t_ref = timed(lambda: jax.block_until_ready(ref(u, up, c2)), iters=5)
    _, t_pal = timed(
        lambda: jax.block_until_ready(
            fd3d_pallas(u, up, c2, dx=10.0, bz=8, interpret=True)
        ),
        iters=1,
    )
    cells = n ** 3
    bytes_fused = 4 * cells * 4  # 3 reads + 1 write, f32
    bytes_unfused = 7 * cells * 4
    if csv:
        print(f"fd3d_ref_jnp,{t_ref*1e6:.0f},cells={cells}")
        print(f"fd3d_pallas_interpret,{t_pal*1e6:.0f},cells={cells}")
        print(
            f"fd3d_traffic_model,0,fused_bytes={bytes_fused}"
            f";unfused_bytes={bytes_unfused};hbm_reduction="
            f"{bytes_unfused/bytes_fused:.2f}x"
        )
    return {"t_ref": t_ref, "t_pallas_interpret": t_pal}


if __name__ == "__main__":
    run()

"""Shared helpers for the benchmark harness (one module per paper table)."""

from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.simulator import SimConfig, simulate, table2_speeds  # noqa: E402

CONFIGS = ("C1", "C2", "C3", "C4", "C5")
TASKS = (480, 960, 1920, 3840, 7680)


def median_makespan(policy, conf, tasks, seeds=5, order="interleaved", **kw):
    ms = []
    for seed in range(seeds):
        cfg = SimConfig(
            speeds=table2_speeds(conf, order=order), num_tasks=tasks,
            seed=seed, **kw,
        )
        ms.append(simulate(policy, cfg).makespan)
    return float(np.median(ms))


def gain(a2ws: float, other: float) -> float:
    """Paper Eq. 13 (percent)."""
    return (1.0 - a2ws / other) * 100.0


def timed(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / iters
    return out, dt

"""Limplock benchmark (DESIGN.md §Straggler plane).

The limplock scenario from the fault-injection literature: one worker of a
homogeneous pool degrades to a fraction of its speed mid-run (a throttled
NIC, a failing disk, a thermally limited device) but keeps completing tasks,
so nothing crashes and fail-stop tolerance never triggers.  Under open
arrivals the degraded worker's queue grows without bound while the healthy
workers idle between steals — the tail latency of the WHOLE pool collapses
to the straggler's service rate.

Grid, all on the virtual-time plane (identical Poisson trace per seed, the
only variable is the response policy):

* **no_fault**   — the healthy baseline the others are normalised against.
* **adaptive**   — A2WS + limp detection (``limp=LimpConfig()``): the owner
  detects its own slowdown, re-prices its queue so thieves strip it, and
  open-arrival routing skips it.
* **count**      — plain A2WS, blind to the fault (``limp=None``): steals
  still happen, but Eq. 5 keeps pricing the limping queue by task count and
  routing keeps feeding it.  The ablation the paper's Eq. 5 cannot fix.
* **no_steal**   — ``radius=0``: no balancing at all, the textbook limplock
  upper bound.

Emits ``BENCH_limplock.json`` via ``benchmarks.run``: per-variant latency
percentiles, p99 ratios vs no_fault, and the detector's flag time.
"""

from __future__ import annotations

import numpy as np

from .common import timed  # noqa: F401  (harness convention)

import sys

sys.path.insert(0, "src")
from repro.core.limp import LimpConfig, SlowdownEvent  # noqa: E402
from repro.core.simulator import SimConfig, simulate  # noqa: E402

#: the fault: one worker limps to 16x its service time, mid-run, forever
LIMP_FACTOR = 16.0
LIMP_WORKER = 1
#: homogeneous pool — heterogeneity is the work-weighted axis, not this one's
P = 4
#: ~35% utilisation at full health: comfortably stable before AND after the
#: fault (3 + 1/16 healthy-equivalent workers >> rate), so every bit of tail
#: degradation is a SCHEDULING failure — the blind scheduler keeps feeding
#: the limper — not an overload artefact.  (At higher utilisation the
#: adaptive p99 drifts up too, honestly: flagging the limper removes a
#: quarter of the capacity, and a 3-worker pool at util ~0.9 queues.  The
#: detection window also strands ``rate/P * limp_factor * task_cost``
#: casualty tasks on the limper — num_tasks is sized so they sit beyond
#: the p99.)
RATE = 1.4
TASK_COST = 1.0


def _cfg(seed: int, num_tasks: int, fault_at: float) -> SimConfig:
    return SimConfig(
        speeds=np.ones(P),
        num_tasks=num_tasks,
        task_cost=TASK_COST,
        seed=seed,
        arrival="poisson",
        arrival_rate=RATE,
        slowdowns=(SlowdownEvent(LIMP_WORKER, fault_at, LIMP_FACTOR),),
    )


def _variants(cfg: SimConfig) -> dict[str, SimConfig]:
    return {
        "no_fault": cfg.with_(slowdowns=()),
        "adaptive": cfg.with_(limp=LimpConfig()),
        "count": cfg,
        "no_steal": cfg.with_(radius=0),
    }


def run(seeds: int = 5, fast: bool = False, csv: bool = True):
    # The p99 under a mid-run fault is seed-noisy (it depends on how many
    # requests are already queued on the limper when it flags): keep >= 5
    # seeds even when the caller asks for fewer, except in --fast CI mode.
    seeds = max(seeds, 1 if fast else 5)
    num_tasks = 400 if fast else 3600
    fault_at = 25.0 if fast else 60.0

    per = {name: {"p50": [], "p99": [], "makespan": []}
           for name in ("no_fault", "adaptive", "count", "no_steal")}
    detect_delays = []
    limper_tasks = {"adaptive": [], "count": []}
    for seed in range(seeds):
        for name, cfg in _variants(_cfg(seed, num_tasks, fault_at)).items():
            res = simulate("a2ws", cfg)
            assert sum(res.per_node_tasks) == num_tasks
            pct = res.latency_percentiles((50.0, 99.0))
            per[name]["p50"].append(pct[50.0])
            per[name]["p99"].append(pct[99.0])
            per[name]["makespan"].append(res.makespan)
            if name in limper_tasks:
                limper_tasks[name].append(res.per_node_tasks[LIMP_WORKER])
            if name == "adaptive":
                flags = [t for t, w, f in res.limp_events
                         if w == LIMP_WORKER and f]
                detect_delays.append(
                    flags[0] - fault_at if flags else float("nan")
                )

    med = {
        f"{name}_{k}_s": float(np.median(v))
        for name, m in per.items() for k, v in m.items()
    }
    base_p99 = med["no_fault_p99_s"]
    out = {
        "limp_factor": LIMP_FACTOR,
        "arrival_rate": RATE,
        "num_tasks": num_tasks,
        "fault_at_s": fault_at,
        "seeds": seeds,
        **med,
        # the acceptance ratios: adaptive should hug 1.0, count should blow up
        "adaptive_p99_ratio": med["adaptive_p99_s"] / base_p99,
        "count_p99_ratio": med["count_p99_s"] / base_p99,
        "no_steal_p99_ratio": med["no_steal_p99_s"] / base_p99,
        "detect_delay_s": float(np.median(detect_delays)),
        "adaptive_limper_tasks": float(np.median(limper_tasks["adaptive"])),
        "count_limper_tasks": float(np.median(limper_tasks["count"])),
    }
    if csv:
        for name in ("no_fault", "adaptive", "count", "no_steal"):
            ratio = out.get(f"{name}_p99_ratio", 1.0)
            print(
                f"limplock_{name},{med[f'{name}_p99_s']*1e6:.0f},"
                f"p99_ratio_vs_no_fault={ratio:.2f}"
            )
        print(
            f"limplock_detect,{out['detect_delay_s']*1e6:.0f},"
            f"limper_tasks_adaptive={out['adaptive_limper_tasks']:.0f}"
            f"_vs_count={out['count_limper_tasks']:.0f}"
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--seeds", type=int, default=5)
    args = ap.parse_args()
    run(seeds=1 if args.fast else args.seeds, fast=args.fast)

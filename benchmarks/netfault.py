"""Fault-fabric benchmark (DESIGN.md §Fault fabric).

The headline robustness claim: a P = 64 open-arrival two-level fabric under
a hostile network — every steal message crosses links that drop 10% of
traffic, and a 30-second partition cuts the pool along its cell boundary
mid-run.  Three legs on the virtual-time plane, identical Poisson trace per
seed, the only variable being the fault response:

* **no_fault** — the clean PR-7 scheduler (``netfaults=None``), the
  baseline the others are normalised against.
* **leased**   — the hardened fabric: leased two-phase transfers return
  dropped loot to the victim at lease expiry, failed requests back off per
  (thief, victim) with a link-health EWMA discounting flaky links, and each
  partition side degrades gracefully (staleness-excluded victims, gated
  gossip, heal-time resync).  Acceptance: completes ALL tasks with zero
  losses and a p99 within 2x the no-fault baseline.
* **no_retry** — the ablation (``hardened=False``): same drops, no leases,
  no backoff, no health discounting.  Dropped transfers lose their tasks
  outright — the leg either strands work (``lost_tasks > 0``) or its tail
  degrades >= 3x.

Emits ``BENCH_netfault.json`` via ``benchmarks.run``: per-leg latency
percentiles, p99 ratios vs no_fault, loss/lease telemetry, and the two
acceptance booleans.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

from .common import timed  # noqa: F401  (harness convention)

import sys

sys.path.insert(0, "src")
from repro.core.netfault import (  # noqa: E402
    LinkFault,
    NetFaultSchedule,
    PartitionEvent,
)
from repro.core.policy import HierarchicalA2WSPolicy  # noqa: E402
from repro.core.simulator import SimConfig, simulate, table2_speeds  # noqa: E402
from repro.core.topology import Topology  # noqa: E402

P = 64
#: every link drops 10% of steal messages for the whole run (ISSUE headline)
DROP = 0.10
#: one partition cuts the pool in half along its CELL boundary: each side
#: keeps a working two-level fabric (whole cells + their leaders), so the
#: degradation under test is the steal/gossip fabric, not a beheaded cell
PARTITION_AT = 10.0
PARTITION_LEN = 30.0
#: ~35% utilisation (capacity = sum(speeds)/task_cost = 80 tasks/s): stable
#: before, during and after the faults, so tail degradation is a FABRIC
#: failure (lost loot, unpaced retries into dead links), not overload
TASK_COST = 8.0
RATE = 28.0
#: two-level fare: intra-cell free, cross-cell latency + per-task (the
#: topology benchmark's skewed fabric at the same price scale)
CROSS_LAT, CROSS_PER = 1e-1, 2e-2


def _cfg(seed: int, num_tasks: int, part_at: float, part_len: float,
         rate: float) -> SimConfig:
    cells = HierarchicalA2WSPolicy(P).cells  # the deterministic cell split
    half = tuple(
        w for c in range(cells.num_cells // 2) for w in cells.members(c)
    )
    nf = NetFaultSchedule(
        faults=(LinkFault(drop_prob=DROP),),
        partitions=(
            PartitionEvent(side=half, start=part_at, duration=part_len),
        ),
    )
    return SimConfig(
        speeds=table2_speeds("C4"),
        num_tasks=num_tasks,
        task_cost=TASK_COST,
        seed=seed,
        arrival="poisson",
        arrival_rate=rate,
        topology=Topology.two_level(
            cells, cross_latency=CROSS_LAT, cross_per_task=CROSS_PER,
        ),
        netfaults=nf,
    )


def _variants(cfg: SimConfig) -> dict[str, SimConfig]:
    return {
        "no_fault": cfg.with_(netfaults=None),
        "leased": cfg,
        "no_retry": cfg.with_(
            netfaults=replace(cfg.netfaults, hardened=False)
        ),
    }


def run(seeds: int = 3, fast: bool = False, csv: bool = True):
    num_tasks = 240 if fast else 1600
    part_at = 2.0 if fast else PARTITION_AT
    part_len = 5.0 if fast else PARTITION_LEN
    rate = 20.0 if fast else RATE

    names = ("no_fault", "leased", "no_retry")
    per = {name: {"p50": [], "p99": [], "makespan": []} for name in names}
    telemetry = {
        "leased_net_failed": [], "leased_lease_expired": [],
        "no_retry_lost": [],
    }
    for seed in range(seeds):
        grid = _variants(_cfg(seed, num_tasks, part_at, part_len, rate))
        for name, cfg in grid.items():
            res = simulate(HierarchicalA2WSPolicy(P), cfg)
            done = sum(res.per_node_tasks)
            if name == "no_retry":
                # at-most-once: losses are ACCOUNTED, never silently dropped
                assert done + res.lost_tasks == num_tasks
            else:
                assert done == num_tasks and res.lost_tasks == 0
            pct = res.latency_percentiles((50.0, 99.0))
            per[name]["p50"].append(pct[50.0])
            per[name]["p99"].append(pct[99.0])
            per[name]["makespan"].append(res.makespan)
            if name == "leased":
                telemetry["leased_net_failed"].append(res.net_failed)
                telemetry["leased_lease_expired"].append(res.lease_expired)
            elif name == "no_retry":
                telemetry["no_retry_lost"].append(res.lost_tasks)

    med = {
        f"{name}_{k}_s": float(np.median(v))
        for name, m in per.items() for k, v in m.items()
    }
    base_p99 = med["no_fault_p99_s"]
    leased_ratio = med["leased_p99_s"] / base_p99
    no_retry_ratio = med["no_retry_p99_s"] / base_p99
    no_retry_lost = float(np.median(telemetry["no_retry_lost"]))
    out = {
        "P": P,
        "drop_prob": DROP,
        "partition_at_s": part_at,
        "partition_len_s": part_len,
        "arrival_rate": rate,
        "num_tasks": num_tasks,
        "seeds": seeds,
        **med,
        "leased_p99_ratio": leased_ratio,
        "no_retry_p99_ratio": no_retry_ratio,
        "leased_net_failed": float(np.median(
            telemetry["leased_net_failed"])),
        "leased_lease_expired": float(np.median(
            telemetry["leased_lease_expired"])),
        "no_retry_lost_tasks": no_retry_lost,
        # the two acceptance booleans the ISSUE pins
        "leased_within_2x": bool(leased_ratio <= 2.0),
        "no_retry_degraded": bool(
            no_retry_lost > 0 or no_retry_ratio >= 3.0
        ),
    }
    if csv:
        print(f"netfault_no_fault,{base_p99*1e6:.0f},p99_ratio=1.00")
        print(
            f"netfault_leased,{med['leased_p99_s']*1e6:.0f},"
            f"p99_ratio_vs_no_fault={leased_ratio:.2f}"
            f"_lost=0_leases={out['leased_lease_expired']:.0f}"
        )
        print(
            f"netfault_no_retry,{med['no_retry_p99_s']*1e6:.0f},"
            f"p99_ratio_vs_no_fault={no_retry_ratio:.2f}"
            f"_lost={no_retry_lost:.0f}"
        )
        print(
            f"netfault_headline,{out['leased_net_failed']:.0f},"
            f"leased_within_2x={out['leased_within_2x']}"
            f"_no_retry_degraded={out['no_retry_degraded']}"
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()
    run(seeds=1 if args.fast else args.seeds, fast=args.fast)

"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--seeds N] [--fast] [--out-dir D]

Prints ``name,us_per_call,derived`` CSV lines per benchmark, and records
each benchmark's returned result object to ``BENCH_<name>.json`` under
``--out-dir`` (default: the working directory) — the machine-readable perf
trajectory CI archives per commit.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _jsonable(obj):
    """Best-effort conversion to JSON-serialisable structures (tuple-keyed
    dicts become "a|b|c" keys; numpy scalars become floats)."""
    if isinstance(obj, dict):
        return {
            "|".join(str(p) for p in k) if isinstance(k, tuple) else str(k):
                _jsonable(v)
            for k, v in obj.items()
        }
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item") and not isinstance(obj, (str, bytes)):
        try:
            return obj.item()
        except (TypeError, ValueError):
            pass
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--fast", action="store_true", help="seeds=1, smaller kernels")
    ap.add_argument(
        "--only", default="", help="comma-separated benchmark names"
    )
    ap.add_argument(
        "--out-dir", default=".",
        help="directory for the BENCH_<name>.json result records",
    )
    args = ap.parse_args()
    seeds = 1 if args.fast else args.seeds

    from . import (
        elastic,
        fig4_radius,
        fig5_tasks,
        hierarchy,
        kernel_fd3d,
        limplock,
        netfault,
        open_arrival,
        placement_ablation,
        policy_matrix,
        roofline,
        sched_micro,
        slo_trace,
        table3_lw,
        table4_ctws,
        topology,
        weighted,
    )
    # (benchmarks/common.py is the only unregistered module — shared
    # helpers, not a benchmark.)

    benches = {
        "fig4": lambda: fig4_radius.run(seeds=seeds),
        "table3": lambda: table3_lw.run(seeds=seeds),
        "table4": lambda: table4_ctws.run(seeds=seeds),
        "fig5": lambda: fig5_tasks.run(),
        "placement": lambda: placement_ablation.run(seeds=seeds),
        "kernel_fd3d": lambda: kernel_fd3d.run(n=32 if args.fast else 64),
        "sched_micro": lambda: sched_micro.run(),
        "open_arrival": lambda: open_arrival.run(seeds=seeds),
        "policy_matrix": lambda: policy_matrix.run(seeds=seeds, fast=args.fast),
        "elastic": lambda: elastic.run(seeds=seeds, fast=args.fast),
        "weighted": lambda: weighted.run(seeds=seeds, fast=args.fast),
        "limplock": lambda: limplock.run(seeds=seeds, fast=args.fast),
        "netfault": lambda: netfault.run(seeds=seeds, fast=args.fast),
        "slo_trace": lambda: slo_trace.run(seeds=1, fast=args.fast),
        "hierarchy": lambda: hierarchy.run(seeds=seeds, fast=args.fast),
        "topology": lambda: topology.run(seeds=seeds, fast=args.fast),
        "roofline": lambda: roofline.run(),
    }
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            result = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{type(e).__name__}: {e}", file=sys.stderr)
            raise
        if result is not None:
            path = os.path.join(args.out_dir, f"BENCH_{name}.json")
            with open(path, "w") as fh:
                json.dump(_jsonable(result), fh, indent=2, sort_keys=True)
            print(f"# wrote {path}", flush=True)
    print(f"# done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

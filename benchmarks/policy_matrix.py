"""Policy matrix — the head-to-head grid the policy layer exists for.

Sweeps every scheduling policy ({a2ws, ctws, lw, random}) over the paper's
Table 2 cluster configurations (C1..C5) under BOTH workload planes:

* ``closed``  — the paper's batch workload (60·P shots at t=0): makespan,
  the Tables 3/4 metric, plus the Eq. 13 gain of a2ws over each baseline.
* ``poisson`` — open-arrival serving traffic at ~75% of aggregate capacity:
  per-request p50/p95/p99 sojourn times, the serving metric the baselines
  could not even report before the shared substrate (PR 2).

One CSV line per (policy, config, arrival) cell:

    policy_matrix_<conf>_<arrival>_<policy>,<makespan_us>,p50=..|p95=..|p99=..

Run directly or through the harness:

    PYTHONPATH=src python -m benchmarks.policy_matrix [--fast]
    PYTHONPATH=src python -m benchmarks.run --only policy_matrix
"""

from __future__ import annotations

import numpy as np

from .common import gain  # noqa: F401  (re-exported harness convention)

import sys

sys.path.insert(0, "src")
from repro.core.policy import POLICIES  # noqa: E402
from repro.core.simulator import SimConfig, simulate, table2_speeds  # noqa: E402

#: tasks per node in the closed plane (C1 = 8 nodes -> 480 tasks, as in §4)
TASKS_PER_NODE = 60
#: open-arrival utilisation (fraction of aggregate service capacity)
RHO = 0.75


def _cell(policy: str, conf: str, arrival: str, seeds: int):
    """Median makespan + latency percentiles over ``seeds`` runs."""
    speeds = table2_speeds(conf)
    num_tasks = TASKS_PER_NODE * len(speeds)
    mks, p50, p95, p99 = [], [], [], []
    for seed in range(seeds):
        kw = {}
        if arrival == "poisson":
            kw = dict(
                arrival="poisson",
                arrival_rate=RHO * float(speeds.sum()) / 60.0,
            )
        cfg = SimConfig(speeds=speeds, num_tasks=num_tasks, seed=seed, **kw)
        res = simulate(policy, cfg)
        mks.append(res.makespan)
        pct = res.latency_percentiles((50.0, 95.0, 99.0))
        if pct:
            p50.append(pct[50.0])
            p95.append(pct[95.0])
            p99.append(pct[99.0])
    med = lambda xs: float(np.median(xs)) if xs else float("nan")  # noqa: E731
    return med(mks), med(p50), med(p95), med(p99)


def run(seeds: int = 3, fast: bool = False, csv: bool = True):
    configs = ("C1", "C2") if fast else ("C1", "C2", "C3", "C4", "C5")
    grid: dict[tuple[str, str, str], dict[str, float]] = {}
    for conf in configs:
        for arrival in ("closed", "poisson"):
            for policy in POLICIES:
                mk, p50, p95, p99 = _cell(policy, conf, arrival, seeds)
                grid[(conf, arrival, policy)] = {
                    "makespan": mk, "p50": p50, "p95": p95, "p99": p99,
                }
                if csv:
                    lat = (
                        f"p50={p50:.2f}|p95={p95:.2f}|p99={p99:.2f}"
                        if arrival == "poisson" else "closed"
                    )
                    print(
                        f"policy_matrix_{conf}_{arrival}_{policy},"
                        f"{mk*1e6:.0f},{lat}"
                    )
    # Headline: a2ws's Eq. 13 gain over each baseline on the biggest closed
    # config of the sweep, and its p99 edge under serving traffic.
    top = configs[-1]
    a_mk = grid[(top, "closed", "a2ws")]["makespan"]
    a_p99 = grid[(top, "poisson", "a2ws")]["p99"]
    derived = {}
    for other in POLICIES:
        if other == "a2ws":
            continue
        derived[f"{top}_gain_vs_{other}"] = round(
            gain(a_mk, grid[(top, "closed", other)]["makespan"]), 1
        )
        derived[f"{top}_p99_ratio_vs_{other}"] = round(
            grid[(top, "poisson", other)]["p99"] / a_p99, 2
        )
    if csv:
        print(f"policy_matrix_summary,0,{derived}")
    return grid, derived


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="C1-C2 only, 1 seed")
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()
    run(seeds=1 if args.fast else args.seeds, fast=args.fast)

"""Fig. 4 — runtime vs information radius R (C4, 3840 tasks).

Paper claim: runtime falls from R=1 up to an interior optimum (R=16) which
beats full/global information (R=32); the fixed operating point is R = 20%
of the node count.
"""

from __future__ import annotations

from .common import gain, median_makespan


def run(seeds: int = 3, csv: bool = True):
    conf, tasks = "C4", 3840
    radii = (1, 2, 4, 8, 16, 32)
    rows = []
    for r in radii:
        mk = median_makespan("a2ws", conf, tasks, seeds=seeds, radius=r)
        rows.append((r, mk))
        if csv:
            print(f"fig4_radius_R{r},{mk*1e6:.0f},makespan_s={mk:.1f}")
    best_r = min(rows, key=lambda x: x[1])[0]
    r1 = rows[0][1]
    interior = dict(rows)
    derived = {
        "optimum_R": best_r,
        "R1_vs_R16_gain_pct": round(gain(interior[16], r1), 2),
        "R16_beats_R32": interior[16] <= interior[32] * 1.02,
    }
    if csv:
        print(f"fig4_radius_summary,0,{derived}")
    return rows, derived


if __name__ == "__main__":
    run()

from .step import (
    abstract_train_state,
    batch_pspecs,
    make_train_step,
    train_shardings,
)

__all__ = [
    "abstract_train_state",
    "batch_pspecs",
    "make_train_step",
    "train_shardings",
]

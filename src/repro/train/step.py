"""The pjit training step: loss -> grads -> AdamW, with explicit shardings.

``make_train_step(cfg, ctx, opt_cfg)`` builds the pure step function;
``train_shardings``/``abstract_train_state`` build the matching NamedSharding
and ShapeDtypeStruct trees so the SAME code path serves (a) real training on
whatever mesh exists and (b) the multi-pod dry-run (lower + compile against
abstract inputs, no allocation).

Sharding layout (see ``repro.parallel.sharding``):
  params/opt : TP over 'model', FSDP over 'data', replicated over 'pod'
               (m/v moments inherit the param sharding -> ZeRO with no
               replicated optimizer state)
  batch      : leading batch dim over ('pod', 'data')
  metrics    : replicated scalars
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.parallel.sharding import ParallelContext, shardings_for

__all__ = [
    "abstract_train_state",
    "batch_pspecs",
    "make_train_step",
    "train_shardings",
]


def abstract_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """(params_sds, opt_sds, logical_specs) — nothing allocated."""
    params_sds, specs = lm.init_shapes(cfg)
    opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
    return params_sds, opt_sds, specs


def train_shardings(cfg: ModelConfig, ctx: ParallelContext, opt_cfg: AdamWConfig):
    """(param_shardings, opt_shardings) NamedSharding trees."""
    params_sds, opt_sds, specs = abstract_train_state(cfg, opt_cfg)
    param_sh = shardings_for(specs, ctx, params_sds)
    if ctx.mesh is None:
        return None, None
    # moments share the param layout; count is a replicated scalar
    opt_sh = {
        "m": param_sh,
        "v": param_sh,
        "count": NamedSharding(ctx.mesh, P()),
    }
    return param_sh, opt_sh


def batch_pspecs(batch: dict, ctx: ParallelContext) -> dict:
    """PartitionSpec per batch entry: batch dim over the DP axes.

    Handles [B,S] token/label arrays, [B,S,d] embeddings, [3,B,S] M-RoPE
    position ids, and scalar entries (e.g. decode ``pos``).
    """
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    dp_size = 1
    if ctx.mesh is not None:
        for a in ctx.dp_axes:
            dp_size *= ctx.mesh.shape[a]

    def one(name: str, leaf) -> P:
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        if name == "positions" and len(shape) == 3 and shape[0] == 3:
            return P(None, dp if shape[1] % dp_size == 0 else None)
        bdim = dp if shape[0] % dp_size == 0 else None
        return P(bdim, *([None] * (len(shape) - 1)))

    return {k: one(k, v) for k, v in batch.items()}


def batch_shardings(batch: dict, ctx: ParallelContext):
    if ctx.mesh is None:
        return {k: None for k in batch}
    specs = batch_pspecs(batch, ctx)
    return {k: NamedSharding(ctx.mesh, s) for k, s in specs.items()}


def make_train_step(
    cfg: ModelConfig,
    ctx: ParallelContext,
    opt_cfg: AdamWConfig,
    *,
    schedule: dict | None = None,
):
    """Pure (params, opt_state, batch) -> (params', opt_state', metrics).

    ``schedule``: optional {"warmup": int, "total": int} enabling the cosine
    LR schedule keyed off opt_state['count'].
    """

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True
        )(params, batch, cfg, ctx)
        lr_scale = (
            cosine_lr(opt_state["count"], **schedule) if schedule else 1.0
        )
        new_params, new_opt, om = adamw_update(
            grads, opt_state, params, opt_cfg, lr_scale
        )
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "ce": metrics["ce"],
            "grad_norm": om["grad_norm"],
        }
        return new_params, new_opt, out_metrics

    return train_step


def jit_train_step(
    cfg: ModelConfig,
    ctx: ParallelContext,
    opt_cfg: AdamWConfig,
    batch_sds: dict,
    *,
    schedule: dict | None = None,
    donate: bool = True,
):
    """jit-wrapped train step with explicit in/out shardings (dry-run entry)."""
    step = make_train_step(cfg, ctx, opt_cfg, schedule=schedule)
    if ctx.mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())
    param_sh, opt_sh = train_shardings(cfg, ctx, opt_cfg)
    b_sh = batch_shardings(batch_sds, ctx)
    metric_sh = {
        k: NamedSharding(ctx.mesh, P()) for k in ("loss", "ce", "grad_norm")
    }
    return jax.jit(
        step,
        in_shardings=(param_sh, opt_sh, b_sh),
        out_shardings=(param_sh, opt_sh, metric_sh),
        donate_argnums=(0, 1) if donate else (),
    )

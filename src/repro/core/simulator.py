"""Discrete-event simulator of A2WS / CTWS / LW on a heterogeneous cluster.

Reproduces the paper's experimental setup (§4) deterministically and fast:
SDumont nodes throttled to {1,2,4,8,16,24} cores via SLURM heterogeneous jobs
(Table 2 configurations C1-C5), tasks = seismic shots of equal work, node
speed proportional to core count (the shot solver scales over cores; Fig. 5's
task-count ratios ~24x between 24-core and 1-core nodes confirm this model).

The simulator advances *virtual time* through an event heap.  It exercises the
exact same decision code as the threaded runtime (``repro.core.steal``) so the
paper's mathematics is tested once and measured twice.

Modelled costs (all configurable):

* task duration         = task_cost / speed_i * lognormal(noise)
* info propagation      : process i's view of process j lags by the ring
                          distance d(i,j): each relay forwards at its own task
                          boundaries, so per-hop delay = hop_latency + half the
                          relay's current mean task time.  Radius R caps the
                          window (Eq. 5) — beyond R there is NO information.
* info send overhead    : comm_cell_cost * cells per boundary (grows with R —
                          the Fig. 4 tradeoff).
* steal                 : round-trip steal_latency + per-task payload cost;
                          claimed tasks leave the victim at decision time and
                          reach the thief after the transfer delay.
* CTWS token            : hop time = token_base + token_per_node * P; only the
                          holder steals (half of the most-loaded victim).
* LW                    : serialized leader (service time per request +
                          request round-trip); worker 0 runs slower by
                          leader_overhead (the co-located distributor thread).
"""

from __future__ import annotations

import heapq
from bisect import bisect_right
from collections import deque as _deque
from dataclasses import dataclass, field, replace
from typing import Literal

import numpy as np

from .a2ws import latency_percentiles
from .steal import plan_steal

__all__ = [
    "SimConfig",
    "SimResult",
    "table2_speeds",
    "simulate",
    "CORE_STEPS",
]

CORE_STEPS = (24, 16, 8, 4, 2, 1)  # descending, process 0 = fastest (Fig. 5)

# Table 2: how many nodes of each core count per configuration.
_TABLE2 = {
    # cores:      1   2   4   8  16  24
    "C1": {1: 2, 2: 1, 4: 1, 8: 1, 16: 1, 24: 2},  # 8 nodes
    "C2": {1: 4, 2: 2, 4: 2, 8: 2, 16: 2, 24: 4},  # 16 nodes
    "C3": {1: 8, 2: 4, 4: 4, 8: 4, 16: 4, 24: 8},  # 32 nodes
    "C4": {1: 16, 2: 8, 4: 8, 8: 8, 16: 8, 24: 16},  # 64 nodes
    "C5": {1: 32, 2: 16, 4: 16, 8: 16, 16: 16, 24: 32},  # 128 nodes
}


def table2_speeds(config: str, order: str = "interleaved") -> np.ndarray:
    """Node speed vector for configuration C1..C5.

    ``order`` is the launcher's RANK PLACEMENT policy — a knob the paper
    never discusses but which dominates radius-limited work-stealing:

    * ``"interleaved"`` (default): round-robin across core classes, so every
      radius-R window contains a representative speed mix and the local
      fair-share (Eq. 5) approximates the global one.  This is what our
      launcher does on a real cluster and what reproduces the paper's gains.
    * ``"blocked"``: SLURM-het-job-style blocks of equal nodes (fastest
      first, process 0 = fastest as in Fig. 5).  Adversarial for small R:
      windows deep inside a slow block see no fast nodes — kept as the
      placement ablation in ``benchmarks/``.
    """
    counts = dict(_TABLE2[config])
    speeds: list[float] = []
    if order == "blocked":
        for cores in CORE_STEPS:
            speeds.extend([float(cores)] * counts[cores])
    elif order == "interleaved":
        while any(v > 0 for v in counts.values()):
            for cores in CORE_STEPS:
                if counts[cores] > 0:
                    speeds.append(float(cores))
                    counts[cores] -= 1
    else:
        raise ValueError(f"unknown placement order {order!r}")
    return np.asarray(speeds, dtype=np.float64)


@dataclass(frozen=True)
class SimConfig:
    speeds: np.ndarray
    num_tasks: int
    task_cost: float = 60.0  # seconds of work per task at speed 1.0
    noise: float = 0.03
    seed: int = 0
    # --- A2WS ---
    radius: int | None = None  # None -> 20% of P (paper's operating point)
    hop_latency: float = 2e-3
    # §2.1: info is forwarded "during the task execution if the application
    # allows it" (the seismic app does) — relays poll every ``info_poll``
    # virtual seconds, so per-hop delay is NOT bound to task boundaries.
    info_poll: float = 0.25
    comm_cell_cost: float = 3e-4
    steal_latency: float = 2e-2
    steal_per_task: float = 2e-3
    retry_interval: float = 5e-2
    # --- open arrivals (DESIGN.md §Open-arrival; A2WS policy only) ---
    # "closed": the paper's workload — all tasks present at t=0 (§2.2.1).
    # "poisson": num_tasks tasks arrive with Exp(1/arrival_rate) gaps and are
    #            round-robined across nodes (the front-end sprays; adaptive
    #            stealing balances).
    # "trace":   arrival_trace gives the absolute arrival times verbatim.
    arrival: Literal["closed", "poisson", "trace"] = "closed"
    arrival_rate: float = 0.0  # tasks/second entering the system (poisson)
    arrival_trace: tuple[float, ...] = ()  # absolute times (trace mode)
    # --- CTWS ---
    token_base: float = 2e-3
    token_per_node: float = 2.5e-4
    # --- LW ---
    request_rtt: float = 8e-3
    leader_service: float = 4e-3
    leader_overhead: float = 0.18

    @property
    def P(self) -> int:
        return len(self.speeds)

    def with_(self, **kw) -> "SimConfig":
        return replace(self, **kw)


@dataclass
class SimResult:
    makespan: float
    per_node_tasks: list[int]
    per_node_busy: list[float]
    steals: int
    failed_steals: int
    moved_tasks: int
    records: list[tuple[int, float, float]] = field(default_factory=list)
    # records: (node, start, end) per task, for Fig. 5 style plots
    latencies: list[float] = field(default_factory=list)
    # per-task arrival-to-completion sojourn times (open-arrival modes only)

    def latency_percentiles(
        self, qs: tuple[float, ...] = (50.0, 95.0, 99.0)
    ) -> dict[float, float]:
        """Per-task latency percentiles (open-arrival serving metric)."""
        return latency_percentiles(self.latencies, qs)

    def summary(self) -> str:
        out = (
            f"makespan={self.makespan:.2f}s steals={self.steals} "
            f"failed={self.failed_steals} moved={self.moved_tasks}"
        )
        pct = self.latency_percentiles()
        if pct:
            out += " lat[p50/p95/p99]=" + "/".join(
                f"{pct[q]:.2f}s" for q in (50.0, 95.0, 99.0)
            )
        return out


# --------------------------------------------------------------------------- #
#                                   A2WS                                       #
# --------------------------------------------------------------------------- #


class _History:
    """Append-only (time, n, t) history per node for delayed views."""

    __slots__ = ("times", "ns", "ts")

    def __init__(self) -> None:
        self.times: list[float] = [0.0]
        self.ns: list[float] = [0.0]
        self.ts: list[float] = [float("nan")]

    def append(self, time: float, n: float, t: float) -> None:
        self.times.append(time)
        self.ns.append(n)
        self.ts.append(t)

    def at(self, time: float) -> tuple[float, float]:
        k = bisect_right(self.times, time) - 1
        return self.ns[k], self.ts[k]


def _ring_dist(i: int, j: int, p: int) -> int:
    d = abs(i - j)
    return min(d, p - d)


def _arrival_times(cfg: SimConfig, rng: np.random.Generator) -> np.ndarray:
    """Absolute arrival times for the open-arrival modes."""
    if cfg.arrival == "poisson":
        if cfg.arrival_rate <= 0.0:
            raise ValueError("poisson arrivals need arrival_rate > 0")
        gaps = rng.exponential(1.0 / cfg.arrival_rate, cfg.num_tasks)
        return np.cumsum(gaps)
    if cfg.arrival == "trace":
        if not cfg.arrival_trace:
            raise ValueError("trace arrivals need a non-empty arrival_trace")
        return np.asarray(sorted(cfg.arrival_trace), dtype=np.float64)
    raise ValueError(f"not an open-arrival mode: {cfg.arrival!r}")


def _simulate_a2ws(cfg: SimConfig) -> SimResult:
    p = cfg.P
    rng = np.random.default_rng(cfg.seed)
    radius = cfg.radius if cfg.radius is not None else max(1, round(0.2 * p))
    radius = min(radius, p // 2)
    open_mode = cfg.arrival != "closed"

    # Per-node queues hold ARRIVAL STAMPS (the simulator's task identity —
    # enough for latency accounting).  Head = left (owner pops, new arrivals
    # land), tail = right (thieves claim the oldest waiters), matching the
    # TaskDeque discipline of the threaded runtime.
    queues: list[_deque] = [_deque() for _ in range(p)]
    if open_mode:
        arrivals = _arrival_times(cfg, rng)
        total_tasks = len(arrivals)
    else:
        # Static block partition (paper §2.2.1): everything arrives at t=0.
        base, rem = divmod(cfg.num_tasks, p)
        for i in range(p):
            queues[i].extend([0.0] * (base + (1 if i < rem else 0)))
        arrivals = np.empty(0)
        total_tasks = cfg.num_tasks

    def depth(i: int) -> int:
        return len(queues[i])

    executed = np.zeros(p, np.int64)
    runtime_sum = np.zeros(p, np.float64)
    busy = np.zeros(p, np.float64)
    hist = [_History() for _ in range(p)]
    for i in range(p):
        hist[i].append(0.0, float(depth(i)), float("nan"))
    cur_t = np.full(p, np.nan)  # latest own estimate (for relay pacing)
    pending_dur = np.zeros(p, np.float64)  # duration of the task in flight
    pending_arr = np.zeros(p, np.float64)  # arrival stamp of that task
    idle_since = np.full(p, -1.0)
    records: list[tuple[int, float, float]] = []
    latencies: list[float] = []
    steals = failed = moved = 0

    # Event heap: (time, seq, kind, node, payload)
    heap: list[tuple[float, int, str, int, object]] = []
    seq = 0

    def push_event(time: float, kind: str, node: int, payload: object = 0) -> None:
        nonlocal seq
        heapq.heappush(heap, (time, seq, kind, node, payload))
        seq += 1

    def reported_n(i: int) -> float:
        """What node i publishes as n_i: cumulative total in the paper's
        closed workload, instantaneous depth under open arrivals (DESIGN.md
        §Open-arrival — totals are meaningless while tasks keep arriving)."""
        if open_mode:
            return float(depth(i))
        return float(executed[i] + depth(i))

    def start_task(i: int, now: float) -> None:
        if not queues[i]:
            idle_since[i] = now
            push_event(now + cfg.retry_interval, "retry", i, 0)
            return
        pending_arr[i] = queues[i].popleft()
        dur = cfg.task_cost / cfg.speeds[i]
        if cfg.noise:
            dur *= float(rng.lognormal(0.0, cfg.noise))
        # Sender-side info-communication overhead at the task boundary: the
        # dirty part of the window goes to both neighbours (≤ R cells each).
        overhead = cfg.comm_cell_cost * 2 * radius
        pending_dur[i] = dur
        push_event(now + overhead + dur, "finish", i)
        busy[i] += dur
        records.append((i, now + overhead, now + overhead + dur))

    def view_for(i: int, now: float) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Delayed (n, t, queued-estimate) views of the window around i."""
        n_view = np.zeros(p)
        t_view = np.ones(p)
        queued = np.zeros(p)
        # Relay pacing: per-hop delay = link latency + half the relay's poll
        # interval (relays forward mid-task, §2.1 — capped by poll period,
        # never by the 60 s task duration).
        t_relay = np.where(np.isnan(cur_t), cfg.task_cost / cfg.speeds, cur_t)
        for off in range(-radius, radius + 1):
            j = (i + off) % p
            if j == i:
                n_view[j] = reported_n(i)
                t_view[j] = _own_t(i, now)
                queued[j] = depth(i)
                continue
            d = _ring_dist(i, j, p)
            step = 1 if off > 0 else -1
            delay = 0.0
            for h in range(1, d + 1):
                relay = (i + step * h) % p
                delay += cfg.hop_latency + 0.5 * min(
                    t_relay[relay], cfg.info_poll
                )
            n_j, t_j = hist[j].at(max(now - delay, 0.0))
            if t_j != t_j:  # no report yet: preemptive wall-time estimate
                t_j = max(now, 1e-9)
            n_view[j] = n_j
            t_view[j] = t_j
            if open_mode:
                # n_j IS the reported depth; no elapsed-time extrapolation —
                # depth drains AND refills under arrivals, so decaying it
                # would systematically under-count busy victims.
                queued[j] = max(n_j, 0.0)
            else:
                done_est = min(now / max(t_j, 1e-9), n_j)
                queued[j] = max(n_j - done_est, 0.0)
        return n_view, t_view, queued

    def _own_t(i: int, now: float) -> float:
        if executed[i] > 0:
            return runtime_sum[i] / executed[i]
        return max(now, 1e-9)

    def try_steal(i: int, now: float) -> bool:
        nonlocal steals, failed, moved
        n_view, t_view, queued = view_for(i, now)
        decision = plan_steal(
            rng, i, n_view, t_view, queued, radius,
            idle=depth(i) <= 1, open_arrival=open_mode,
        )
        if decision is None:
            return False
        v = decision.victim
        avail = depth(v)  # get-accumulate ground truth at the victim
        take = min(decision.amount, avail)
        if take <= 0:
            failed += 1
            return False
        stamps = [queues[v].pop() for _ in range(take)]  # tail: oldest waiters
        hist[v].append(now, reported_n(v), _own_t(v, now))
        arrive = now + cfg.steal_latency + cfg.steal_per_task * take
        push_event(arrive, "receive", i, stamps)
        steals += 1
        moved += take
        return True

    # Boot: all nodes start their first task at t=0; open-arrival tasks
    # enter through "arrive" events (round-robin routed — the front-end
    # sprays, adaptive stealing balances).
    for k, t_arr in enumerate(arrivals):
        push_event(float(t_arr), "arrive", k % p, float(t_arr))
    for i in range(p):
        start_task(i, 0.0)

    makespan = 0.0
    total_done = 0
    while heap and total_done < total_tasks:
        now, _, kind, i, payload = heapq.heappop(heap)
        if kind == "finish":
            executed[i] += 1
            total_done += 1
            runtime_sum[i] += pending_dur[i]
            if open_mode:
                latencies.append(now - pending_arr[i])
            makespan = max(makespan, now)
            # Update own info + history (Alg. 1 line 11 + communicate).
            cur_t[i] = runtime_sum[i] / executed[i]
            hist[i].append(now, reported_n(i), cur_t[i])
            # Smart stealing right after finishing a task (preemptive).
            try_steal(i, now)
            start_task(i, now)
        elif kind == "arrive":
            queues[i].appendleft(float(payload))  # head side, like submit()
            hist[i].append(now, reported_n(i), _own_t(i, now))
            if idle_since[i] >= 0.0:
                idle_since[i] = -1.0
                start_task(i, now)
        elif kind == "receive":
            queues[i].extendleft(payload)  # stolen goods land head-side
            hist[i].append(now, reported_n(i), _own_t(i, now))
            if idle_since[i] >= 0.0:
                idle_since[i] = -1.0
                start_task(i, now)
        elif kind == "retry":
            if queues[i] or idle_since[i] < 0.0:
                continue  # no longer idle
            if total_done >= total_tasks:
                continue
            if not try_steal(i, now):
                # mild exponential backoff so long idle tails stay cheap
                delay = cfg.retry_interval * (1.3 ** min(payload, 12))
                push_event(now + delay, "retry", i, payload + 1)
            # on success the stolen tasks arrive via a "receive" event

    return SimResult(
        makespan=makespan,
        per_node_tasks=[int(x) for x in executed],
        per_node_busy=[float(b) for b in busy],
        steals=steals,
        failed_steals=failed,
        moved_tasks=moved,
        records=records,
        latencies=latencies,
    )


# --------------------------------------------------------------------------- #
#                                   CTWS                                       #
# --------------------------------------------------------------------------- #


def _simulate_ctws(cfg: SimConfig) -> SimResult:
    p = cfg.P
    rng = np.random.default_rng(cfg.seed)
    base, rem = divmod(cfg.num_tasks, p)
    queue = np.array([base + (1 if i < rem else 0) for i in range(p)], np.int64)
    executed = np.zeros(p, np.int64)
    busy = np.zeros(p, np.float64)
    idle = np.zeros(p, bool)
    records: list[tuple[int, float, float]] = []
    steals = moved = 0
    hop = cfg.token_base + cfg.token_per_node * p

    heap: list[tuple[float, int, str, int, int]] = []
    seq = 0

    def push_event(time: float, kind: str, node: int, payload: int = 0) -> None:
        nonlocal seq
        heapq.heappush(heap, (time, seq, kind, node, payload))
        seq += 1

    def start_task(i: int, now: float) -> None:
        if queue[i] <= 0:
            idle[i] = True
            return
        idle[i] = False
        queue[i] -= 1
        dur = cfg.task_cost / cfg.speeds[i]
        if cfg.noise:
            dur *= float(rng.lognormal(0.0, cfg.noise))
        push_event(now + dur, "finish", i)
        busy[i] += dur
        records.append((i, now, now + dur))

    for i in range(p):
        start_task(i, 0.0)
    push_event(hop, "token", 0)

    makespan = 0.0
    total_done = 0
    while heap and total_done < cfg.num_tasks:
        now, _, kind, i, payload = heapq.heappop(heap)
        if kind == "finish":
            executed[i] += 1
            total_done += 1
            makespan = max(makespan, now)
            start_task(i, now)
        elif kind == "receive":
            queue[i] += payload
            if idle[i]:
                start_task(i, now)
        elif kind == "token":
            # Holder steals only if its queue is empty (CTWS rule).
            if queue[i] == 0 and idle[i]:
                victim = int(np.argmax(queue))
                if victim != i and queue[victim] > 0:
                    take = max(1, int(queue[victim]) // 2)
                    queue[victim] -= take
                    arrive = now + cfg.steal_latency + cfg.steal_per_task * take
                    push_event(arrive, "receive", i, take)
                    steals += 1
                    moved += take
            if total_done < cfg.num_tasks:
                push_event(now + hop, "token", (i + 1) % p)

    return SimResult(
        makespan=makespan,
        per_node_tasks=[int(x) for x in executed],
        per_node_busy=[float(b) for b in busy],
        steals=steals,
        failed_steals=0,
        moved_tasks=moved,
        records=records,
    )


# --------------------------------------------------------------------------- #
#                                    LW                                        #
# --------------------------------------------------------------------------- #


def _simulate_lw(cfg: SimConfig) -> SimResult:
    p = cfg.P
    rng = np.random.default_rng(cfg.seed)
    speeds = cfg.speeds.copy()
    speeds[0] *= 1.0 - cfg.leader_overhead  # co-located distributor thread
    executed = np.zeros(p, np.int64)
    busy = np.zeros(p, np.float64)
    records: list[tuple[int, float, float]] = []
    remaining = cfg.num_tasks
    leader_free = 0.0

    heap: list[tuple[float, int, str, int]] = []
    seq = 0

    def push_event(time: float, kind: str, node: int) -> None:
        nonlocal seq
        heapq.heappush(heap, (time, seq, kind, node))
        seq += 1

    def request(i: int, now: float) -> None:
        """Worker i asks the leader for a task; leader is a serial server."""
        nonlocal leader_free, remaining
        if remaining <= 0:
            return
        arrive_leader = now + cfg.request_rtt / 2
        service_start = max(arrive_leader, leader_free)
        leader_free = service_start + cfg.leader_service
        remaining -= 1
        push_event(leader_free + cfg.request_rtt / 2, "task", i)

    for i in range(p):
        request(i, 0.0)

    makespan = 0.0
    total_done = 0
    while heap and total_done < cfg.num_tasks:
        now, _, kind, i = heapq.heappop(heap)
        if kind == "task":
            dur = cfg.task_cost / speeds[i]
            if cfg.noise:
                dur *= float(rng.lognormal(0.0, cfg.noise))
            push_event(now + dur, "finish", i)
            busy[i] += dur
            records.append((i, now, now + dur))
        elif kind == "finish":
            executed[i] += 1
            total_done += 1
            makespan = max(makespan, now)
            request(i, now)

    return SimResult(
        makespan=makespan,
        per_node_tasks=[int(x) for x in executed],
        per_node_busy=[float(b) for b in busy],
        steals=0,
        failed_steals=0,
        moved_tasks=0,
        records=records,
    )


# --------------------------------------------------------------------------- #


def simulate(policy: Literal["a2ws", "ctws", "lw"], cfg: SimConfig) -> SimResult:
    if policy == "a2ws":
        return _simulate_a2ws(cfg)
    if cfg.arrival != "closed":
        raise NotImplementedError(
            f"open-arrival simulation is A2WS-only for now (got {policy!r}); "
            "compare against no-stealing by setting radius=0 instead"
        )
    if policy == "ctws":
        return _simulate_ctws(cfg)
    if policy == "lw":
        return _simulate_lw(cfg)
    raise ValueError(f"unknown policy {policy!r}")

"""Discrete-event simulator: the virtual-time plane of the policy substrate.

Reproduces the paper's experimental setup (§4) deterministically and fast:
SDumont nodes throttled to {1,2,4,8,16,24} cores via SLURM heterogeneous jobs
(Table 2 configurations C1-C5), tasks = seismic shots of equal work, node
speed proportional to core count (the shot solver scales over cores; Fig. 5's
task-count ratios ~24x between 24-core and 1-core nodes confirm this model).

The simulator advances *virtual time* through an event heap, and it drives
the exact same ``SchedPolicy`` objects (``repro.core.policy``) as the
threaded ``WorkerPool`` — A2WS, CTWS, LW and random work-stealing all run on
one event loop, so the paper's mathematics is tested once and measured twice
and every policy is available in both the real-time and virtual-time planes
with identical telemetry (DESIGN.md §Policy layer).  Open-arrival modes
(``poisson``/``trace``) work for every policy.

Modelled costs (all configurable):

* task duration         = task_cost / speed_i * lognormal(noise)
                          * policy.task_multiplier(i)  (LW leader co-location)
* info propagation      : ring policies only.  Process i's view of process j
                          lags by the ring distance d(i,j): each relay
                          forwards at its own task boundaries, so per-hop
                          delay = hop_latency + half the relay's current mean
                          task time.  Radius R caps the window (Eq. 5) —
                          beyond R there is NO information.
* info send overhead    : comm_cell_cost * cells per boundary (grows with R —
                          the Fig. 4 tradeoff; ring policies only).
* steal                 : round-trip steal_latency + per-task payload cost;
                          claimed tasks leave the victim at decision time and
                          reach the thief after the transfer delay.  A policy
                          may price the dispatch itself (``StealPlan.delay``,
                          LW's leader round-trip), which then replaces the
                          default transport cost.
* CTWS token            : hop gate = token_base + token_per_node * P; only
                          the holder steals (half of the most-loaded victim),
                          and busy holders forward the token at task
                          boundaries — exactly like the threaded plane.
* LW                    : serialized leader (service time per request +
                          request round-trip); worker 0 runs slower by
                          leader_overhead (the co-located distributor
                          thread) and co-hosts the central queue.
"""

from __future__ import annotations

import heapq
import math
from bisect import bisect_right
from collections import deque as _deque
from dataclasses import dataclass, field, replace

import numpy as np

from .a2ws import DEFAULT_QS, latency_percentiles
from .deque import SLO_NAMES
from .limp import (
    LimpConfig,
    LimpState,
    SlowdownSchedule,
    effective_heartbeat,
    normalize_duration,
)
from .netfault import (
    NF_SEED_SALT,
    LinkHealth,
    NetFaultSchedule,
)
from .netfault import validate_netfaults as _check_netfaults
from .policy import PolicyView, SchedPolicy, make_policy
from .steal import OverlayBuffers, neighborhood, weighted_overlay
from .topology import Topology

__all__ = [
    "SimAutoscale",
    "SimConfig",
    "SimResult",
    "table2_speeds",
    "simulate",
    "sim_policy",
    "CORE_STEPS",
]

CORE_STEPS = (24, 16, 8, 4, 2, 1)  # descending, process 0 = fastest (Fig. 5)

# Table 2: how many nodes of each core count per configuration.
_TABLE2 = {
    # cores:      1   2   4   8  16  24
    "C1": {1: 2, 2: 1, 4: 1, 8: 1, 16: 1, 24: 2},  # 8 nodes
    "C2": {1: 4, 2: 2, 4: 2, 8: 2, 16: 2, 24: 4},  # 16 nodes
    "C3": {1: 8, 2: 4, 4: 4, 8: 4, 16: 4, 24: 8},  # 32 nodes
    "C4": {1: 16, 2: 8, 4: 8, 8: 8, 16: 8, 24: 16},  # 64 nodes
    "C5": {1: 32, 2: 16, 4: 16, 8: 16, 16: 16, 24: 32},  # 128 nodes
}


def table2_speeds(config: str, order: str = "interleaved") -> np.ndarray:
    """Node speed vector for configuration C1..C5.

    ``order`` is the launcher's RANK PLACEMENT policy — a knob the paper
    never discusses but which dominates radius-limited work-stealing:

    * ``"interleaved"`` (default): round-robin across core classes, so every
      radius-R window contains a representative speed mix and the local
      fair-share (Eq. 5) approximates the global one.  This is what our
      launcher does on a real cluster and what reproduces the paper's gains.
    * ``"blocked"``: SLURM-het-job-style blocks of equal nodes (fastest
      first, process 0 = fastest as in Fig. 5).  Adversarial for small R:
      windows deep inside a slow block see no fast nodes — kept as the
      placement ablation in ``benchmarks/``.
    """
    counts = dict(_TABLE2[config])
    speeds: list[float] = []
    if order == "blocked":
        for cores in CORE_STEPS:
            speeds.extend([float(cores)] * counts[cores])
    elif order == "interleaved":
        while any(v > 0 for v in counts.values()):
            for cores in CORE_STEPS:
                if counts[cores] > 0:
                    speeds.append(float(cores))
                    counts[cores] -= 1
    else:
        raise ValueError(f"unknown placement order {order!r}")
    return np.asarray(speeds, dtype=np.float64)


@dataclass(frozen=True)
class SimAutoscale:
    """Virtual-time replica autoscaling (DESIGN.md §SLO serving).

    ``reserve`` names dormant nodes (by speed) appended after the base ring;
    the scaler activates them in order and deactivates them LIFO.  Every
    ``interval`` virtual seconds a "scale" event evaluates one of two modes:

    * ``"threshold"`` — the PR-3 rule, ported from the threaded
      ``AutoscaleConfig``: scale OUT one reserve when the pending backlog
      (arrived − done, queued + in flight) exceeds
      ``high_pending_per_replica ×`` live nodes; scale IN one reserve after
      ``idle_ticks_to_retire`` consecutive zero-backlog ticks.  Purely
      reactive: it waits for the queue to already be deep.
    * ``"predictive"`` — Holt's EWMA level+trend forecast of the ARRIVAL
      RATE: per tick, the observed rate ``(arrived − prev)/interval``
      updates ``level`` (smoothing ``rate_alpha``) and ``trend``
      (smoothing ``trend_beta``); the forecast ``λ̂ = level + trend·horizon``
      is converted to a node count by requiring aggregate service capacity
      ``Σ_j speed_j / E[task seconds] ≥ λ̂ / target_util``.  Reserves
      activate as soon as the FORECAST crosses capacity — ahead of the
      backlog a threshold scaler waits for — and deactivate (one per tick,
      only while the backlog is small) when the forecast recedes.

    The scaler consumes NO scheduler rng and pushes no events when unset —
    ``autoscale=None`` is bit-for-bit the PR-9 event stream.
    """

    reserve: tuple[float, ...]
    interval: float = 1.0
    mode: str = "threshold"
    high_pending_per_replica: float = 4.0
    idle_ticks_to_retire: int = 3
    rate_alpha: float = 0.3
    trend_beta: float = 0.2
    horizon: float = 5.0
    target_util: float = 0.75


@dataclass(frozen=True)
class SimConfig:
    speeds: np.ndarray
    num_tasks: int
    task_cost: float = 60.0  # seconds of work per task at speed 1.0
    noise: float = 0.03
    seed: int = 0
    # --- ring policies (A2WS) ---
    radius: int | None = None  # None -> 20% of P (paper's operating point)
    hop_latency: float = 2e-3
    # §2.1: info is forwarded "during the task execution if the application
    # allows it" (the seismic app does) — relays poll every ``info_poll``
    # virtual seconds, so per-hop delay is NOT bound to task boundaries.
    info_poll: float = 0.25
    comm_cell_cost: float = 3e-4
    steal_latency: float = 2e-2
    steal_per_task: float = 2e-3
    retry_interval: float = 5e-2
    # --- open arrivals (DESIGN.md §Open-arrival; all policies) ---
    # "closed": the paper's workload — all tasks present at t=0 (§2.2.1).
    # "poisson": num_tasks tasks arrive with Exp(1/arrival_rate) gaps and are
    #            routed by the policy (round-robin spray by default, the
    #            central queue for LW).
    # "trace":   arrival_trace gives the absolute arrival times verbatim.
    arrival: str = "closed"
    arrival_rate: float = 0.0  # tasks/second entering the system (poisson)
    # Absolute times (trace mode).  Array-likes welcome — a 10^6-request
    # diurnal trace streams straight from the generator/npz as a float64
    # array, never materialising a Python tuple.
    arrival_trace: "tuple[float, ...] | np.ndarray" = ()
    # --- elastic membership (DESIGN.md §Elasticity) ---
    # joins:   (time, speed) scale-out events — each activates ONE new node
    #          appended to the ring at that virtual time; it starts with an
    #          empty queue and pulls work through the policy's own steal
    #          path (preemptive estimates cover it exactly like boot).
    # retires: (time, node) graceful drains — the node finishes its
    #          in-flight task, its queued tasks are re-sprayed over the live
    #          nodes, and its ring position is tombstoned.
    joins: tuple[tuple[float, float], ...] = ()
    retires: tuple[tuple[float, int], ...] = ()
    # --- work-weighted cost classes (DESIGN.md §Work-weighted stealing) ---
    # class_cost:  per-class task-duration multipliers (variable-cost
    #              workloads, e.g. bimodal seismic shots: (1.0, 8.0)).
    #              () = the paper's homogeneous tasks — nothing changes,
    #              not even the rng stream.
    # class_probs: workload mix (must sum to 1; () = uniform over classes).
    # class_trace: explicit per-task class assignment (len == num_tasks;
    #              overrides class_probs) — clustered-cost workloads, e.g. a
    #              deep-shot survey line landing in one partition block.
    # weighted:    publish per-class queue counts + EWMA t̂[c] through the
    #              info plane so ring policies price queues in work-seconds;
    #              False keeps the info plane count-based while tasks still
    #              COST class_cost — the ablation baseline.
    # ewma_alpha:  smoothing of the per-class runtime estimates.
    class_cost: tuple[float, ...] = ()
    class_probs: tuple[float, ...] = ()
    class_trace: tuple[int, ...] = ()
    weighted: bool = True
    ewma_alpha: float = 0.25
    # --- straggler/limplock plane (DESIGN.md §Straggler plane) ---
    # slowdowns: scripted degraded-but-alive faults — a SlowdownSchedule (or
    #            a bare tuple of SlowdownEvent) multiplying task durations on
    #            the targeted nodes, the straggler analogue of joins/retires.
    # limp:      adaptive limp DETECTION + response (LimpConfig); None keeps
    #            the scheduler blind to stragglers — the count-based
    #            ablation baseline, and bit-for-bit the pre-PR behaviour.
    slowdowns: SlowdownSchedule | tuple = ()
    limp: LimpConfig | None = None
    # --- topology plane (DESIGN.md §Topology plane) ---
    # topology:       network-cost model.  When set, a steal's loot travels
    #                 cost(victim, thief, take) virtual seconds on the link
    #                 (overlapped with thief compute) instead of the flat
    #                 steal_latency/steal_per_task default; a link priced at
    #                 0.0 falls back to the default transport, so the
    #                 all-zero topology is bit-for-bit topology=None.  With
    #                 contention > 0 a started transfer keeps its directed
    #                 link busy for cost·contention seconds and later
    #                 transfers on the same link queue behind it.
    # topology_aware: False = BLIND ablation — transport is still charged by
    #                 the model, but the policy never sees transfer_cost, so
    #                 it plans exactly as if the network were free.
    topology: Topology | None = None
    topology_aware: bool = True
    # --- network-fault plane (DESIGN.md §Fault fabric) ---
    # netfaults: scriptable lossy-link/partition schedule (NetFaultSchedule).
    #            Steal requests and loot transfers roll against per-link
    #            drop_prob (a DEDICATED rng stream — the scheduler stream is
    #            untouched), pay extra_delay, and cannot cross an active
    #            partition.  Hardening (leases, backoff, link-health
    #            weighting) rides on the schedule's own knobs; None — or an
    #            empty schedule — is bit-for-bit the fault-free scheduler.
    netfaults: NetFaultSchedule | None = None
    # --- SLO serving (DESIGN.md §SLO serving; open-arrival modes only) ---
    # slo_trace:     per-task SLO class (0 = batch, 1 = latency; array-likes
    #                welcome), aligned with the arrival order.  () disables
    #                the whole plane — bit-for-bit the PR-9 scheduler.
    # slo_deadlines: per-class latency BUDGET seconds (batch, latency); a
    #                task's absolute deadline is arrival + budget, so EDF
    #                within a class coincides with FIFO (budgets are
    #                per-class constants).  inf = no deadline (telemetry
    #                still splits per class).
    # slo_order:     owners pop SLO-ordered (latency jumps batch, EDF within
    #                class); False records per-class telemetry but keeps
    #                PR-9 LIFO pops — the ordering ablation.
    # slo_aging:     batch no-starvation bound: a batch task older than this
    #                is promoted into the EDF order at effective deadline
    #                arrival + slo_aging.  inf = never promote.
    # record_tasks:  False skips the per-task (node, start, end) records —
    #                at 10^6 requests they dominate memory and no benchmark
    #                reads them.
    slo_trace: "tuple[int, ...] | np.ndarray" = ()
    slo_deadlines: tuple[float, float] = (math.inf, math.inf)
    slo_order: bool = True
    slo_aging: float = math.inf
    record_tasks: bool = True
    # --- autoscaling (DESIGN.md §SLO serving; open-arrival modes only) ---
    autoscale: "SimAutoscale | None" = None
    # --- CTWS ---
    token_base: float = 2e-3
    token_per_node: float = 2.5e-4
    # --- LW ---
    request_rtt: float = 8e-3
    leader_service: float = 4e-3
    leader_overhead: float = 0.18

    @property
    def P(self) -> int:
        return len(self.speeds)

    def with_(self, **kw) -> "SimConfig":
        new = replace(self, **kw)
        # Fail fast on a mis-scripted fault plan (mirrors the simulate()-time
        # retire-before-join rejection): with_() is how benchmark grids and
        # tests derive scenario configs, so a bad slowdown script should blow
        # up where it is WRITTEN, not runs later inside the event loop.
        validate_slowdowns(new)
        validate_netfaults(new)
        return new


def slowdown_schedule(cfg: "SimConfig") -> SlowdownSchedule:
    """Normalise ``cfg.slowdowns`` (schedule or bare event tuple)."""
    s = cfg.slowdowns
    if isinstance(s, SlowdownSchedule):
        return s
    return SlowdownSchedule(tuple(s))


def validate_slowdowns(cfg: "SimConfig") -> SlowdownSchedule:
    """Reject slowdown events that target never-joined or already-retired
    workers — a fault script slowing a ghost would be silently inert (the
    tombstone guard drops its effect), exactly the failure mode PR 3's
    retire-before-join rejection closed for churn scripts."""
    sched = slowdown_schedule(cfg)
    if not sched.events:
        return sched
    p0 = cfg.P
    joins = sorted(cfg.joins)
    pmax = p0 + len(joins)
    first_retire: dict[int, float] = {}
    for t_ret, node in cfg.retires:
        t_prev = first_retire.get(node)
        if t_prev is None or t_ret < t_prev:
            first_retire[node] = t_ret
    for ev in sched.events:
        if ev.worker >= pmax:
            raise ValueError(
                f"slowdown target {ev.worker} outside the ring "
                f"0..{pmax - 1}: that worker never joins"
            )
        if ev.worker >= p0 and ev.start < joins[ev.worker - p0][0]:
            raise ValueError(
                f"slowdown of node {ev.worker} at t={ev.start} precedes "
                f"its join at t={joins[ev.worker - p0][0]}"
            )
        t_ret = first_retire.get(ev.worker)
        if t_ret is not None and ev.start >= t_ret:
            raise ValueError(
                f"slowdown of node {ev.worker} at t={ev.start} targets a "
                f"worker already retired at t={t_ret}"
            )
    return sched


def validate_netfaults(cfg: "SimConfig") -> None:
    """Reject fault scripts naming workers outside the final ring — a
    partition isolating a ghost would be silently inert (same failure mode
    the slowdown validation closes)."""
    if cfg.netfaults is not None:
        _check_netfaults(cfg.netfaults, cfg.P + len(cfg.joins))


@dataclass
class SimResult:
    makespan: float
    per_node_tasks: list[int]
    per_node_busy: list[float]
    steals: int
    failed_steals: int
    moved_tasks: int
    records: list[tuple[int, float, float]] = field(default_factory=list)
    # records: (node, start, end) per task, for Fig. 5 style plots
    latencies: list[float] = field(default_factory=list)
    # per-task arrival-to-completion sojourn times (open-arrival modes only)
    limp_events: list[tuple[float, int, bool]] = field(default_factory=list)
    # (time, node, flagged) limp-detector transitions (cfg.limp runs only)
    steal_log: list[tuple[float, int, int, int]] = field(default_factory=list)
    # (time, thief, victim, take) per successful steal — lets a caller
    # attribute moved tasks to links/cells (topology benchmarks)
    boundaries: int = 0
    # total policy consultations (view builds) — overhead denominator
    net_failed: int = 0
    # steal requests lost to link drops / partitions (netfaults runs only)
    lease_expired: int = 0
    # dropped loot transfers whose lease expired and returned to the victim
    lost_tasks: int = 0
    # tasks lost in flight — ONLY possible under netfaults.hardened=False
    # (the no-lease ablation); the hardened path conserves every task
    slo_latencies: dict[str, list[float]] = field(default_factory=dict)
    # per-SLO-class sojourn times keyed by class name; {} when cfg.slo_trace
    # is unset (the telemetry split rides the SLO plane, not the ordering)
    slo_violations: dict[str, int] = field(default_factory=dict)
    # per-SLO-class deadline violations (latency > class budget)
    scale_log: list[tuple[float, str, int, int]] = field(default_factory=list)
    # (time, "out" | "in", node, pending) autoscaler actions (cfg.autoscale)

    def latency_percentiles(
        self, qs: tuple[float, ...] = DEFAULT_QS
    ) -> dict[float, float]:
        """Per-task latency percentiles (open-arrival serving metric)."""
        return latency_percentiles(self.latencies, qs)

    def slo_violation_rate(self) -> dict[str, float]:
        """Per-SLO-class violation rate; {} when the SLO plane is off."""
        return {
            name: self.slo_violations.get(name, 0) / max(len(lats), 1)
            for name, lats in self.slo_latencies.items()
        }

    def summary(self) -> str:
        out = (
            f"makespan={self.makespan:.2f}s steals={self.steals} "
            f"failed={self.failed_steals} moved={self.moved_tasks}"
        )
        pct = self.latency_percentiles()
        if pct:
            out += " lat[p50/p95/p99/p99.9]=" + "/".join(
                f"{pct[q]:.2f}s" for q in DEFAULT_QS
            )
        if self.slo_latencies:
            out += " slo[" + " ".join(
                f"{name}={self.slo_violations.get(name, 0)}"
                f"/{len(lats)}viol"
                for name, lats in sorted(self.slo_latencies.items())
            ) + "]"
        if self.scale_log:
            outs = sum(1 for _, k, _n, _p in self.scale_log if k == "out")
            out += f" scale[{outs}out/{len(self.scale_log) - outs}in]"
        return out


# --------------------------------------------------------------------------- #
#                       generic policy-driven event loop                       #
# --------------------------------------------------------------------------- #


class _History:
    """Append-only (time, n, t[, nc, tc]) history per node for delayed views.

    ``num_classes > 0`` additionally records the per-class queue counts and
    EWMA runtime estimates published at each report (work-weighted mode) —
    a remote reader sees the class profile from the SAME report as the
    scalars, i.e. one consistent ring cell."""

    __slots__ = ("times", "ns", "ts", "ncs", "tcs", "limps")

    def __init__(self, num_classes: int = 0) -> None:
        self.times: list[float] = [0.0]
        self.ns: list[float] = [0.0]
        self.ts: list[float] = [float("nan")]
        self.limps: list[bool] = [False]
        if num_classes > 0:
            self.ncs: list[np.ndarray] | None = [np.zeros(num_classes)]
            self.tcs: list[np.ndarray] | None = [
                np.full(num_classes, float("nan"))
            ]
        else:
            self.ncs = self.tcs = None

    def append(
        self,
        time: float,
        n: float,
        t: float,
        nc: np.ndarray | None = None,
        tc: np.ndarray | None = None,
        limp: bool = False,
    ) -> None:
        self.times.append(time)
        self.ns.append(n)
        self.ts.append(t)
        self.limps.append(limp)
        if self.ncs is not None:
            self.ncs.append(self.ncs[-1] if nc is None else nc)
            self.tcs.append(self.tcs[-1] if tc is None else tc)

    def at(self, time: float) -> tuple[float, float]:
        k = bisect_right(self.times, time) - 1
        return self.ns[k], self.ts[k]

    def at_classes(
        self, time: float
    ) -> tuple[float, float, np.ndarray, np.ndarray]:
        k = bisect_right(self.times, time) - 1
        return self.ns[k], self.ts[k], self.ncs[k], self.tcs[k]

    def limp_at(self, time: float) -> bool:
        """Delayed limp flag — rides the same report stream as (n, t)."""
        k = bisect_right(self.times, time) - 1
        return self.limps[k]


def _ring_dist(i: int, j: int, p: int) -> int:
    d = abs(i - j)
    return min(d, p - d)


def _arrival_times(cfg: SimConfig, rng: np.random.Generator) -> np.ndarray:
    """Absolute arrival times for the open-arrival modes."""
    if cfg.arrival == "poisson":
        if cfg.arrival_rate <= 0.0:
            raise ValueError("poisson arrivals need arrival_rate > 0")
        gaps = rng.exponential(1.0 / cfg.arrival_rate, cfg.num_tasks)
        return np.cumsum(gaps)
    if cfg.arrival == "trace":
        # Accept array-likes and avoid the Python-object sort that dominated
        # ingestion at 10^6 events: one vectorised monotonicity check IS the
        # validation, and np.sort runs only when the trace is out of order.
        arr = np.asarray(cfg.arrival_trace, dtype=np.float64)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError("trace arrivals need a non-empty 1-D arrival_trace")
        if not np.isfinite(arr).all():
            raise ValueError("arrival_trace times must all be finite")
        if arr.size > 1 and bool((arr[1:] < arr[:-1]).any()):
            arr = np.sort(arr)
        elif arr is cfg.arrival_trace:
            arr = arr.copy()  # never alias caller memory into the event loop
        return arr
    raise ValueError(f"not an open-arrival mode: {cfg.arrival!r}")


def sim_policy(spec: str | SchedPolicy, cfg: SimConfig) -> SchedPolicy:
    """Resolve a policy spec against the simulator's cost model (the plane
    owns the policy *parameters* — hop gates, leader service — because they
    are measured quantities of the modelled cluster, not of the policy).

    Name dispatch itself lives in ``policy.make_policy`` (the single
    registry); this only translates SimConfig costs into the named policy's
    constructor kwargs, so a new registered policy without sim-specific
    costs is simulatable with no change here.
    """
    if isinstance(spec, SchedPolicy):
        return spec
    kw: dict = {}
    if spec == "ctws":
        kw = {"hop_time": cfg.token_base + cfg.token_per_node * cfg.P}
    elif spec == "lw":
        kw = {
            "leader_overhead": cfg.leader_overhead,
            "service_time": cfg.leader_service,
            "request_rtt": cfg.request_rtt,
        }
    return make_policy(spec, cfg.P, **kw)


class _SloQueue:
    """Two-class task-id queue: the simulator's O(1) mirror of the threaded
    ``TaskDeque.get_task(key)`` scan (DESIGN.md §SLO serving).

    The threaded owner scans ``[head, tail)`` for the minimum SLO key; at
    trace scale that scan is O(depth) per pop, so the simulator keeps the
    two classes in separate deques and the SLO choice becomes a two-way
    comparison.  Orientation matches the plain deque it replaces: left =
    newest (arrivals land, owner's LIFO end), right = oldest (thief end).

    * ``popleft(now)`` — the OWNER pop: latency first in EDF order (per-class
      constant budgets make EDF ≡ oldest-first), with a batch task older
      than ``aging`` promoted at effective deadline ``arrival + aging``;
      batch-only pops stay newest-first (LIFO), exactly the plain pop.
    * ``pop()`` — the THIEF end: oldest BATCH first, then oldest latency —
      steals strip batch work preferentially (owner-vs-thief asymmetry).
    * ``[-1]`` — what ``pop()`` would take next (work-greedy loot pricing).
    """

    __slots__ = ("lat", "bat", "slo", "arrival", "deadline", "aging")

    def __init__(
        self,
        slo: np.ndarray,
        arrival: np.ndarray,
        deadline: np.ndarray,
        aging: float,
    ) -> None:
        self.lat: _deque = _deque()
        self.bat: _deque = _deque()
        self.slo = slo
        self.arrival = arrival
        self.deadline = deadline
        self.aging = aging

    def __len__(self) -> int:
        return len(self.lat) + len(self.bat)

    def __bool__(self) -> bool:
        return bool(self.lat) or bool(self.bat)

    def __iter__(self):
        yield from self.lat
        yield from self.bat

    def __getitem__(self, idx: int):
        if idx != -1:
            raise IndexError("_SloQueue exposes only the thief end [-1]")
        if self.bat:
            return self.bat[-1]
        return self.lat[-1]

    def extendleft(self, tids) -> None:
        lat_l, bat_l, slo = self.lat.appendleft, self.bat.appendleft, self.slo
        for tid in tids:
            (lat_l if slo[tid] else bat_l)(tid)

    def extend(self, tids) -> None:
        lat_a, bat_a, slo = self.lat.append, self.bat.append, self.slo
        for tid in tids:
            (lat_a if slo[tid] else bat_a)(tid)

    def popleft(self, now: float) -> int:
        lat, bat = self.lat, self.bat
        if bat:
            b = bat[-1]  # oldest batch task
            aged = (
                self.aging < math.inf
                and (now - float(self.arrival[b])) > self.aging
            )
            if not lat:
                return bat.pop() if aged else bat.popleft()
            if aged and (
                float(self.arrival[b]) + self.aging
                <= float(self.deadline[lat[-1]])
            ):
                return bat.pop()  # the promoted batch task wins the EDF race
        return self.lat.pop()  # EDF: oldest latency = earliest deadline

    def pop(self) -> int:
        if self.bat:
            return self.bat.pop()
        return self.lat.pop()

    def clear(self) -> None:
        self.lat.clear()
        self.bat.clear()


def simulate(policy: str | SchedPolicy, cfg: SimConfig) -> SimResult:
    """Run ``cfg`` under ``policy`` ("a2ws" | "ctws" | "lw" | "random", or a
    ready ``SchedPolicy`` instance) on the virtual-time substrate."""
    pol = sim_policy(policy, cfg)
    p0 = cfg.P
    rng = np.random.default_rng(cfg.seed)

    # Straggler plane: scripted slowdown faults (always honoured) and the
    # adaptive limp detector (opt-in via cfg.limp; when None the `limping`
    # mask stays all-False and every downstream branch is inert — the
    # count-based ablation is bit-for-bit the pre-straggler behaviour).
    sched = validate_slowdowns(cfg)
    has_slow = bool(sched.events)
    detect = cfg.limp is not None

    # Network-fault plane (DESIGN.md §Fault fabric): drop/delay/partition
    # rolls come from a DEDICATED rng stream — the scheduler stream is never
    # consulted, and every roll is gated on drop_prob > 0, so an empty
    # schedule is bit-for-bit netfaults=None (tests/test_netfault.py).
    nf = cfg.netfaults
    validate_netfaults(cfg)
    nf_rng = (
        np.random.default_rng(cfg.seed + NF_SEED_SALT) if nf is not None else None
    )
    health = LinkHealth(nf) if nf is not None else None
    nf_lossy = nf is not None and nf.lossy()

    # Topology plane (DESIGN.md §Topology plane): the network-cost model and
    # the per-directed-link busy-until horizon (contention serialization).
    topo = cfg.topology
    if topo is not None and cfg.topology_aware:
        # The blind ablation must NOT bind: the policy (including the
        # hierarchical leader balancer) plans as if loot moved for free.
        pol.bind_topology(topo)
    link_busy: dict[tuple[int, int], float] = {}

    # Autoscale plane (DESIGN.md §SLO serving): reserve nodes occupy the
    # ring positions a scripted join would, so combining both would make
    # slot ownership ambiguous — rejected.  The scaler is the churn driver.
    scaler = cfg.autoscale
    if scaler is not None:
        if cfg.arrival == "closed":
            raise ValueError("autoscale needs an open-arrival mode")
        if cfg.joins:
            raise ValueError(
                "autoscale and scripted joins are mutually exclusive"
            )
        if not scaler.reserve:
            raise ValueError("autoscale needs at least one reserve node")
        if scaler.mode not in ("threshold", "predictive"):
            raise ValueError(f"unknown autoscale mode {scaler.mode!r}")
        if scaler.interval <= 0.0:
            raise ValueError("autoscale interval must be > 0")
        if getattr(pol, "cells", None) is not None:
            raise NotImplementedError(
                "autoscale under a hierarchical policy: reserve homing is "
                "not implemented (flat policies only)"
            )

    # Elastic membership: every join appends one ring position, so all
    # per-node state is sized for the FINAL ring up front; `p` is the
    # currently-materialised prefix and `alive_sim` masks live members.
    joins = sorted(cfg.joins)
    reserve = tuple(scaler.reserve) if scaler is not None else ()
    pmax = p0 + len(joins) + len(reserve)
    speeds = np.concatenate(
        [np.asarray(cfg.speeds, np.float64),
         np.asarray([s for _, s in joins], np.float64),
         np.asarray(reserve, np.float64)]
    )
    p = p0
    alive_sim = np.zeros(pmax, bool)
    alive_sim[:p0] = True
    born = np.zeros(pmax, np.float64)  # preemptive-estimate baseline per node

    def _radius_for(active: int) -> int:
        r = cfg.radius if cfg.radius is not None else max(1, round(0.2 * active))
        return min(r, active // 2)

    radius = _radius_for(p0)
    open_mode = cfg.arrival != "closed"
    uses_ring = pol.uses_ring
    # Hierarchy scoping (DESIGN.md §Hierarchy): a cell-mapped policy gets
    # CELL-scoped views — O(ρ) arrays over the cell's local slots instead of
    # O(P) over the whole ring.  The simulator has no board objects (views
    # are rebuilt from report histories), so the CellMap alone carries the
    # topology; joins are homed by the policy's own on_worker_join.
    cells = getattr(pol, "cells", None) if uses_ring else None
    if cells is not None and cells.num_workers != p0:
        raise ValueError(
            f"policy cell map covers {cells.num_workers} workers, "
            f"sim boots {p0}"
        )
    overlay_bufs: dict[int, OverlayBuffers] = {}

    # Work-weighted cost classes: every task is a ``(arrival, class)`` tuple
    # (class 0 when the workload is homogeneous — the legacy float stamp
    # generalised, same rng stream when class_cost is unset).  ``winfo``
    # gates the per-class INFO plane: tasks cost class_cost either way, the
    # flag only decides whether ring policies get to see the classes.
    costs = np.asarray(cfg.class_cost or (1.0,), np.float64)
    ncls = len(costs)
    has_classes = bool(cfg.class_cost)
    # (ncls > 1: a single class carries no composition information and must
    # stay bit-for-bit count-based — the degenerate-case guarantee.)
    winfo = cfg.weighted and has_classes and ncls > 1 and uses_ring

    if open_mode:
        arrivals = _arrival_times(cfg, rng)
        total_tasks = len(arrivals)
    else:
        arrivals = np.empty(0)
        total_tasks = cfg.num_tasks
    if has_classes:
        if cfg.class_trace:
            if len(cfg.class_trace) != total_tasks:
                raise ValueError("class_trace must assign every task a class")
            task_cls = np.asarray(cfg.class_trace, np.int64)
            if task_cls.min() < 0 or task_cls.max() >= ncls:
                raise ValueError("class_trace entries outside [0, num_classes)")
        else:
            if cfg.class_probs:
                if len(cfg.class_probs) != ncls:
                    raise ValueError("class_probs must match class_cost length")
                probs = np.asarray(cfg.class_probs, np.float64)
            else:
                probs = np.full(ncls, 1.0 / ncls)
            task_cls = rng.choice(ncls, size=total_tasks, p=probs)
    else:
        task_cls = np.zeros(total_tasks, np.int64)

    # First-class Task records, column-wise: the simulator's task identity
    # is its integer id; (arrival, cls, slo, deadline) live in parallel
    # arrays so a 10^6-request trace never materialises per-task Python
    # objects (the threaded plane carries the same fields on core.deque.Task
    # instances).  `task_arrival` aliases the arrival trace under open mode.
    task_arrival = arrivals if open_mode else np.zeros(total_tasks, np.float64)
    slo_tele = len(cfg.slo_trace) > 0
    budgets = np.asarray(cfg.slo_deadlines, np.float64)
    if slo_tele:
        if not open_mode:
            raise ValueError("slo_trace needs an open-arrival mode")
        task_slo = np.asarray(cfg.slo_trace, np.int8)
        if task_slo.shape != (total_tasks,):
            raise ValueError("slo_trace must assign every task an SLO class")
        if int(task_slo.min()) < 0 or int(task_slo.max()) > 1:
            raise ValueError(
                "slo_trace entries must be 0 (batch) or 1 (latency)"
            )
        if budgets.shape != (2,) or not bool((budgets > 0.0).all()):
            raise ValueError(
                "slo_deadlines must be two positive budgets (batch, latency)"
            )
        task_deadline = task_arrival + budgets[task_slo]
    else:
        task_slo = np.zeros(total_tasks, np.int8)
        task_deadline = np.empty(0)
    if not cfg.slo_aging > 0.0:  # also rejects NaN
        raise ValueError("slo_aging must be > 0 (math.inf disables aging)")
    slo_on = slo_tele and cfg.slo_order
    record_tasks = cfg.record_tasks

    # Per-node queues hold task IDS.  Head = left (owner pops, new arrivals
    # land), tail = right (thieves claim the oldest waiters), matching the
    # TaskDeque discipline of the threaded runtime; with SLO ordering on,
    # the owner end consults the two-lane _SloQueue instead.  Initial
    # placement is the policy's (static block split by default, the central
    # queue for LW).
    queues: list = [
        _SloQueue(task_slo, task_arrival, task_deadline, cfg.slo_aging)
        if slo_on
        else _deque()
        for _ in range(pmax)
    ]
    if not open_mode:
        for i, part in enumerate(pol.partition(list(range(total_tasks)), p0)):
            queues[i].extend(part)

    def depth(i: int) -> int:
        return len(queues[i])

    # Per-queue class counts, maintained INCREMENTALLY at every queue
    # mutation (the O(depth) rescan per published report would make weighted
    # open-arrival runs quadratic in backlog — the threaded plane caches the
    # same scan behind a deque-mutation key).
    qcls = np.zeros((pmax, ncls), np.float64)
    for i, q in enumerate(queues):
        for task in q:
            qcls[i, task_cls[task]] += 1.0

    def q_pop(i: int, left: bool = False, now: float = 0.0):
        if left:
            task = queues[i].popleft(now) if slo_on else queues[i].popleft()
        else:
            task = queues[i].pop()
        qcls[i, task_cls[task]] -= 1.0
        return task

    def q_classes(i: int) -> np.ndarray:
        return qcls[i].copy()

    executed = np.zeros(pmax, np.int64)
    runtime_sum = np.zeros(pmax, np.float64)
    busy = np.zeros(pmax, np.float64)
    class_t = np.full((pmax, ncls), np.nan)  # per-class EWMA runtimes
    hist = [_History(ncls if winfo else 0) for _ in range(pmax)]
    limping = np.zeros(pmax, bool)
    limp_states = [LimpState(cfg.limp) for _ in range(pmax)] if detect else None
    limp_events: list[tuple[float, int, bool]] = []
    # Wedge detector (LimpConfig.stale_after): the OWNER-driven heartbeat —
    # last time each node reported its own cell at a boundary it reached
    # itself.  Thief-side victim publishes (the record_remote analogue) do
    # NOT count: in the threaded plane a steal never bumps the victim's own
    # version, and a wedged node being stolen from must stay flagged.
    wedge = detect and math.isfinite(cfg.limp.stale_after)
    own_report = np.zeros(pmax, np.float64)
    stale_flagged = np.zeros(pmax, bool)

    def cls_payload(i: int) -> dict:
        """Per-class cell payload published alongside every (n, t) report."""
        if not winfo:
            return {}
        return {"nc": q_classes(i), "tc": class_t[i].copy()}

    if uses_ring:
        for i in range(p0):
            hist[i].append(0.0, float(depth(i)), float("nan"), **cls_payload(i))
    cur_t = np.full(pmax, np.nan)  # latest own estimate (for relay pacing)
    pending_dur = np.zeros(pmax, np.float64)  # duration of the task in flight
    pending_task: list = [None] * pmax  # the task id in flight (None: idle)
    idle_since = np.full(pmax, -1.0)
    in_transit = np.zeros(pmax, np.int64)  # loot scheduled but not yet received
    arrived = 0 if open_mode else total_tasks
    records: list[tuple[int, float, float]] = []
    latencies: list[float] = []
    steal_log: list[tuple[float, int, int, int]] = []
    # Per-SLO-class telemetry ({} when the SLO plane is off, so SimResult
    # summaries of plain runs are unchanged).
    slo_lat: dict[str, list[float]] = (
        {name: [] for name in SLO_NAMES} if slo_tele else {}
    )
    slo_viol: dict[str, int] = (
        {name: 0 for name in SLO_NAMES} if slo_tele else {}
    )
    scale_log: list[tuple[float, str, int, int]] = []
    stats = {
        "steals": 0, "failed": 0, "moved": 0, "done": 0, "boundaries": 0,
        "net_failed": 0, "lease": 0, "lost": 0,
    }
    rr_state = [0]  # round-robin router for arrivals / drain re-sprays

    def route(prefer_central: bool = True) -> int:
        """Pick a LIVE landing node (arrival spray / retirement drain) —
        membership changes mean targets must resolve at event time, not at
        trace-generation time.  Flagged-limping nodes are skipped (routing a
        fresh submit onto a collapsed node bakes its slowdown straight into
        that task's latency) unless every live node is limping, in which
        case degrade gracefully rather than drop the task — EXCEPT for the
        probation canaries: every Nth diverted task still lands on the
        flagged node, the only completions that can ever clear its flag
        (LimpConfig.probation_every)."""
        central = pol.central if prefer_central else None
        if central is not None and alive_sim[central]:
            return central
        fallback = -1
        for _ in range(p):
            rr_state[0] = (rr_state[0] + 1) % p
            j = rr_state[0]
            if alive_sim[j]:
                if not limping[j]:
                    return j
                if limp_states is not None and limp_states[j].should_probe():
                    return j  # probation canary
                if fallback < 0:
                    fallback = j
        return fallback  # only limping nodes left (or nobody at all: -1)

    # Event heap: (time, seq, kind, node, payload).  Sequence numbers
    # 0..N-1 are RESERVED for the N open-mode arrival events (arrival k
    # carries seq k), so the lazily-streamed arrival pushes below reproduce
    # exactly the heap order of an eager up-front push of the whole trace;
    # every other event numbers from N.
    heap: list[tuple[float, int, str, int, object]] = []
    seq = total_tasks if open_mode else 0

    arr_cursor = [0]

    def push_arrival() -> None:
        # Stream the arrival trace one event at a time: the heap holds at
        # most ONE pending arrival instead of all 10^6, and the payload is
        # just the task id.
        k = arr_cursor[0]
        if k < total_tasks:
            arr_cursor[0] = k + 1
            heapq.heappush(heap, (float(arrivals[k]), k, "arrive", -1, k))

    def push_event(time: float, kind: str, node: int, payload: object = 0) -> None:
        nonlocal seq
        heapq.heappush(heap, (time, seq, kind, node, payload))
        seq += 1

    def reported_n(i: int) -> float:
        """What node i publishes as n_i: cumulative total in the paper's
        closed workload, instantaneous depth under open arrivals (DESIGN.md
        §Open-arrival — totals are meaningless while tasks keep arriving)."""
        if open_mode:
            return float(depth(i))
        return float(executed[i] + depth(i))

    def start_task(i: int, now: float) -> None:
        if not alive_sim[i]:
            return  # tombstoned/retired: never picks up work again
        if not queues[i]:
            idle_since[i] = now
            push_event(now + cfg.retry_interval, "retry", i, 0)
            return
        task = q_pop(i, left=True, now=now)
        pending_task[i] = task
        dur = cfg.task_cost * float(costs[task_cls[task]]) / speeds[i]
        if cfg.noise:
            dur *= float(rng.lognormal(0.0, cfg.noise))
        dur *= pol.task_multiplier(i)  # LW: co-located leader slows worker 0
        if has_slow:
            # Straggler fault injection: the scripted multiplier, sampled at
            # task START (the threaded plane stalls the same wall-clock way).
            dur *= sched.factor_at(i, now)
        # Sender-side info-communication overhead at the task boundary: the
        # dirty part of the window goes to both neighbours (≤ R cells each).
        # Under a hierarchy the window is the CELL radius — the whole point:
        # per-boundary info cost scales with ρ, not P.
        if uses_ring:
            r_i = radius if cells is None else cells.radius_of(cells.cell_of(i))
            overhead = cfg.comm_cell_cost * 2 * r_i
        else:
            overhead = 0.0
        pending_dur[i] = dur
        push_event(now + overhead + dur, "finish", i)
        busy[i] += dur
        if record_tasks:
            records.append((i, now + overhead, now + overhead + dur))

    def _own_t(i: int, now: float) -> float:
        if executed[i] > 0:
            return runtime_sum[i] / executed[i]
        return max(now - born[i], 1e-9)  # elapsed since the node joined

    def _pub_t(i: int, now: float) -> float:
        """What node i PUBLISHES as its mean task time: the cumulative mean,
        except that a flagged-limping node publishes its collapsed fast-EWMA
        instead — the adaptive RE-PRICING.  Pushing the honest (slow) t_i
        through the ring makes the existing fair-share mathematics (Eq. 5)
        mark the limper as massively surplus, so thieves strip it through
        the ordinary steal path; no new steal rule is needed."""
        t = _own_t(i, now)
        if limping[i]:
            recent = limp_states[i].recent
            if recent == recent:
                t = max(t, recent)
        return t

    def publish(j: int, now: float) -> None:
        """Append node j's current cell to its report history."""
        hist[j].append(
            now, reported_n(j), _pub_t(j, now),
            limp=bool(limping[j]), **cls_payload(j)
        )

    def _peer_ref(i: int, now: float) -> float:
        """Median published t among i's live window peers — the detector's
        reference of last resort for a node limping before it has its own
        baseline (min_samples).  NaN when no peer has reported.  Under a
        hierarchy the peers are i's CELL window — a limper is judged against
        its cell, mirroring the threaded plane's peer_raw_t scoping."""
        if cells is None:
            peers = [j for j in neighborhood(i, p, radius) if j != i]
        else:
            cell, iloc = cells.locate(i)
            mem = cells.members(cell)
            m = len(mem)
            rad = min(cells.radius_of(cell), m // 2)
            peers = [
                mem[jl]
                for jl in neighborhood(iloc, m, rad)
                if jl != iloc and mem[jl] >= 0
            ]
        vals = [
            float(cur_t[j])
            for j in peers
            if alive_sim[j] and cur_t[j] == cur_t[j]
        ]
        if not vals:
            return float("nan")
        return float(np.median(vals))

    def ring_view(i: int, now: float) -> tuple:
        """Delayed (n, t, queued-estimate) views of the window around i,
        plus the ``(unit, qtasks, rel)`` work-weighted overlay (None in
        count mode) and the delayed limp-flag plane — the simulator's
        mirror of ``WorkerPool._ring_view``.

        Under a hierarchy the board is i's CELL: rows are the cell's
        member slots (LOCAL indices, -1 holes from migration/retirement),
        and the relay path walks the cell ring — the O(cell)-not-O(P) hot
        path.  Flat runs take the identical loop with the identity member
        mapping (``g = jl``), so the arithmetic is bit-for-bit the old
        flat builder's."""
        if cells is None:
            mem = None
            m, iloc, rad = p, i, radius
        else:
            cell, iloc = cells.locate(i)
            mem = cells.members(cell)
            m = len(mem)
            rad = min(cells.radius_of(cell), m // 2)
        n_view = np.zeros(m)
        t_view = np.ones(m)
        queued = np.zeros(m)
        limp_view = np.zeros(m, bool) if detect else None
        nc_view = np.zeros((m, ncls)) if winfo else None
        tc_view = np.full((m, ncls), np.nan) if winfo else None
        frozen = np.zeros(m, bool) if winfo else None

        def relay_half_t(g: int) -> float:
            # Relay pacing: per-hop delay = link latency + half the relay's
            # poll interval (relays forward mid-task, §2.1 — capped by poll
            # period, never by the 60 s task duration).  A hole slot has no
            # relay estimate: charge the poll-period cap.
            if g < 0:
                return 0.5 * cfg.info_poll
            t_r = cur_t[g]
            if t_r != t_r:
                t_r = cfg.task_cost / speeds[g]
            return 0.5 * min(float(t_r), cfg.info_poll)

        for off in range(-rad, rad + 1):
            jl = (iloc + off) % m
            g = jl if mem is None else mem[jl]
            if g < 0:
                # Hole slot (migrated-away / compacted member): empty row,
                # speed ~0 so no planner ever targets it.
                t_view[jl] = 1e12
                continue
            if jl == iloc:
                n_view[jl] = reported_n(i)
                t_view[jl] = _pub_t(i, now)  # own row: re-priced when limping
                queued[jl] = depth(i)
                if detect:
                    limp_view[jl] = bool(limping[i])
                if winfo:
                    # Own row is ground truth: actual queue composition +
                    # own EWMA estimates (mirrors the threaded plane).
                    nc_view[jl] = q_classes(i)
                    tc_view[jl] = class_t[i]
                continue
            if not alive_sim[g]:
                # Tombstoned member: frozen cells; count the orphaned queue
                # directly and report speed ~0 (mirrors the threaded plane).
                queued[jl] = depth(g)
                t_view[jl] = 1e12
                n_view[jl] = (
                    queued[jl] if open_mode else executed[g] + queued[jl]
                )
                if winfo:
                    nc_view[jl] = q_classes(g)  # orphans: ground-truth scan
                continue
            d = _ring_dist(iloc, jl, m)
            step = 1 if off > 0 else -1
            delay = 0.0
            for h in range(1, d + 1):
                rl = (iloc + step * h) % m
                rg = rl if mem is None else mem[rl]
                delay += cfg.hop_latency + relay_half_t(rg)
            read_at = max(now - delay, 0.0)
            cut = math.inf
            if nf is not None:
                # Partition gating (DESIGN.md §Fault fabric): no report g
                # published after the cut can have crossed the fabric, so
                # the observer's view of g FREEZES at the cut instant and
                # thaws automatically when the partition heals (reads catch
                # back up to now - delay on their own).
                cut = nf.unreachable_since(g, i, now)
                if cut < read_at:
                    read_at = cut
            if winfo:
                n_j, t_j, nc_j, tc_j = hist[g].at_classes(read_at)
                nc_view[jl] = nc_j
                tc_view[jl] = tc_j
            else:
                n_j, t_j = hist[g].at(read_at)
            if detect:
                limp_view[jl] = hist[g].limp_at(read_at)
            if t_j != t_j:  # no report yet: preemptive wall-time estimate
                t_j = max(now - born[i], 1e-9)  # the THIEF's elapsed time
            if wedge:
                # Heartbeat staleness (LimpConfig.stale_after): g has not
                # reported its own cell for the whole window — it is wedged
                # (slowdown → ∞) and its owner-side EWMA will never flag it.
                # The PEER raises the limp flag and re-prices g's believed
                # speed to the silence itself, so closed-mode done_est → 0
                # and thieves see g's full queue as surplus.
                hb = float(own_report[g])
                if now - hb > cfg.limp.stale_after:
                    if not stale_flagged[g]:
                        stale_flagged[g] = True
                        if not limping[g]:
                            limping[g] = True
                            limp_events.append((now, g, True))
                    t_j = max(t_j, now - hb)
                    limp_view[jl] = True
                elif stale_flagged[g]:
                    # Heartbeat is back: hand the verdict back to the
                    # owner-side EWMA hysteresis.
                    stale_flagged[g] = False
                    verdict = bool(limp_states[g].limping)
                    if bool(limping[g]) != verdict:
                        limping[g] = verdict
                        limp_events.append((now, g, verdict))
            if cut < math.inf:
                # Partition staleness (observer-LOCAL): across a cut the
                # heartbeat the observer can actually see stops at the cut
                # instant, so after nf.stale_after of silence the peer is
                # re-priced to the silence in THIS view row only — thieves
                # on this side stop targeting it while its own component
                # keeps scheduling it (no write to the global limping /
                # stale_flagged state, unlike the wedge path above).
                hb_eff = effective_heartbeat(float(own_report[g]), cut)
                if now - hb_eff > nf.stale_after:
                    t_j = max(t_j, now - hb_eff)
                    if detect:
                        limp_view[jl] = True
            n_view[jl] = n_j
            t_view[jl] = t_j
            if open_mode:
                # n_j IS the reported depth; no elapsed-time extrapolation —
                # depth drains AND refills under arrivals, so decaying it
                # would systematically under-count busy victims.
                queued[jl] = max(n_j, 0.0)
            else:
                done_est = min(now / max(t_j, 1e-9), n_j)
                queued[jl] = max(n_j - done_est, 0.0)
        members = None if mem is None else np.asarray(mem, np.int64)
        if not winfo:
            return (n_view, t_view, queued, None, None, None, limp_view,
                    members, None, iloc, rad)
        # ---- work-weighted overlay (DESIGN.md §Work-weighted stealing) ----
        # steal.weighted_overlay is the ONE shared re-pricing for both
        # planes; tombstones are frozen at their ~0-speed price.  A limping
        # node's collapsed t feeds the overlay like any other estimate, so
        # its queue prices in (slow) work-seconds automatically.
        if mem is None:
            np.logical_not(alive_sim[:p], out=frozen)
        else:
            for jl2, g2 in enumerate(mem):
                frozen[jl2] = g2 < 0 or not alive_sim[g2]
        buf = OverlayBuffers.ensure(overlay_bufs.get(m), m, ncls)
        overlay_bufs[m] = buf
        n_w, t_w, queued_w, unit, qtasks, rel = weighted_overlay(
            n_view, t_view, queued, nc_view, tc_view, frozen=frozen, buf=buf
        )
        return (n_w, t_w, queued_w, unit, qtasks, rel, limp_view,
                members, nc_view, iloc, rad)

    def make_view(i: int, now: float) -> PolicyView:
        unit = qtasks = rel = limp_view = None
        members = nc_view = None
        iview, m, rad = i, p, radius
        if uses_ring:
            (n_view, t_view, queued, unit, qtasks, rel, limp_view,
             members, nc_view, iview, rad) = ring_view(i, now)
            m = p if members is None else len(members)
            window = neighborhood(iview, m, rad)
        else:
            n_view = t_view = queued = None
            window = list(range(p))
        if members is None:
            depth_f = depth
            alive_f = lambda j: bool(alive_sim[j])
        else:
            mem = members
            depth_f = lambda jl: depth(int(mem[jl])) if mem[jl] >= 0 else 0
            alive_f = lambda jl: bool(mem[jl] >= 0 and alive_sim[mem[jl]])
        tcost = None
        if topo is not None and cfg.topology_aware:
            if members is None:
                # transfer_cost(j, k) = seconds to move k tasks FROM j TO i.
                tcost = lambda j, k, _i=i: topo.cost(  # noqa: E731
                    int(j), _i, int(k)
                )
            else:
                # Scoped view: j is a LOCAL slot — translate through the
                # member map; a migration hole is unreachable (inf).
                def tcost(jl, k, _i=i, _mem=members):
                    g = int(_mem[jl]) if 0 <= jl < len(_mem) else -1
                    if g < 0:
                        return float("inf")
                    return topo.cost(g, _i, int(k))
        lh = None
        if nf is not None:
            # link_health(j) ∈ [0, 1]: victim-weight multiplier for thief i
            # stealing from j — 0.0 across an active partition or a
            # backed-off link, the health EWMA (floor-clamped) otherwise.
            # All-1.0 on a healthy fabric, so weights are untouched
            # (steal.victim_weights skips the multiply entirely then).
            if members is None:
                def lh(j, _i=i, _now=now):
                    g = int(j)
                    if not nf.reachable(g, _i, _now):
                        return 0.0
                    return health.factor(_i, g, _now)
            else:
                def lh(jl, _i=i, _now=now, _mem=members):
                    g = int(_mem[jl]) if 0 <= jl < len(_mem) else -1
                    if g < 0 or not nf.reachable(g, _i, _now):
                        return 0.0
                    return health.factor(_i, g, _now)
        return PolicyView(
            worker=iview,
            now=now,
            idle=depth(i) == 0,
            near_idle=depth(i) <= 1,
            ran_any=bool(executed[i] > 0),
            open_arrival=open_mode,
            radius=rad,
            num_workers=m,
            rng=rng,
            window=window,
            depth=depth_f,
            alive=alive_f,
            pending=lambda: arrived - stats["done"],
            n_view=n_view,
            t_view=t_view,
            queued=queued,
            unit=unit,
            qtasks=qtasks,
            rel=rel,
            limp=limp_view,
            inflight=lambda: int(in_transit[i]),
            members=members,
            nc_view=nc_view,
            transfer_cost=tcost,
            link_health=lh,
        )

    def boundary(i: int, now: float) -> bool:
        """Task-boundary policy consultation + steal execution (the
        simulator's analogue of WorkerPool._policy_boundary)."""
        if not alive_sim[i]:
            return False  # tombstoned members take no more boundaries
        stats["boundaries"] += 1
        view = make_view(i, now)
        plan = pol.on_boundary(view)
        if plan is None:
            return False
        v = plan.victim
        if nf is not None:
            # Request leg (thief → victim): a partition loses the probe with
            # certainty — deterministically, NO rng draw, so the scheduler
            # stream is untouched — and a lossy link with drop_prob.  Either
            # way the thief learns nothing about the victim (result 0/0),
            # records the failure in the link-health EWMA (capped
            # exponential backoff zeroes the link's weight for a while) and
            # falls back to the ordinary retry path.
            req_lost = not nf.reachable(i, v, now)
            if not req_lost:
                pd = nf.drop_prob(i, v, now)
                if pd > 0.0 and float(nf_rng.random()) < pd:
                    req_lost = True
            if req_lost:
                stats["failed"] += 1
                stats["net_failed"] += 1
                if nf.hardened:
                    health.record(i, v, False, now)
                pol.on_steal_result(view, plan, 0, 0)
                return False
            if nf.hardened and nf_lossy:
                health.record(i, v, True, now)
        avail = depth(v)  # get-accumulate ground truth at the victim
        if plan.work > 0.0 and view.rel is not None and plan.delay <= 0.0:
            # Work-greedy loot: pop tail tasks until the plan's work target
            # is covered, refusing a candidate whose work would overshoot
            # the target by more than the remaining deficit (mirrors
            # TaskDeque.steal_by_work in the threaded plane).  The cap
            # bounds tasks by ~2x the work target, NOT by the count
            # estimate: a lighter-than-expected tail may take more than
            # plan.amount tasks to fill the planned work.  A PRICED plan
            # (delay > 0, §Topology plane) skips this: its loot moves as
            # ONE batched transfer of exactly the tasks it paid for.
            rel_v = view.rel
            cap = max(plan.amount, int(np.ceil(2.0 * plan.work)))
            stamps = []
            cum = 0.0
            while queues[v] and len(stamps) < cap:
                w_next = float(rel_v[task_cls[queues[v][-1]]])
                if cum + w_next - plan.work > plan.work - cum + 1e-12 and not (
                    view.idle and not stamps  # idle: stay work-conserving
                ):
                    break
                stamps.append(q_pop(v))
                cum += w_next
            take = len(stamps)
        else:
            take = min(plan.amount, avail)
            stamps = [q_pop(v) for _ in range(take)]  # tail: oldest waiters
        if take <= 0:
            stats["failed"] += 1
            pol.on_steal_result(view, plan, 0, avail)
            return False
        if uses_ring:
            publish(v, now)
        # Transport: the topology model's link cost on the ACTUAL take
        # (charged identically whether the policy planned blind or priced —
        # the ablation difference must live in the decisions, not the
        # fare), else the policy-priced dispatch delay (LW round-trip),
        # else the plane's default steal cost.  A zero-priced link falls
        # back to the default transport — the all-zero topology is
        # bit-for-bit topology=None.
        if topo is not None:
            cost = topo.cost(v, i, take)
        elif plan.delay > 0.0:
            cost = plan.delay
        else:
            cost = 0.0
        if cost > 0.0:
            start_tx = now
            if topo is not None and topo.contention > 0.0:
                # Per-directed-link serialization: a started transfer holds
                # the link for cost·contention seconds; later transfers on
                # the same link queue behind it.
                key = (v, i)
                start_tx = max(now, link_busy.get(key, 0.0))
                link_busy[key] = start_tx + cost * topo.contention
            arrive = start_tx + cost
        else:
            arrive = now + cfg.steal_latency + cfg.steal_per_task * take
        if nf is not None:
            # Transfer leg (victim → thief): the loot is claimed — it has
            # LEFT the victim's queue — and now rides a lossy link.
            arrive += nf.extra_delay(v, i, now)
            pd = nf.drop_prob(v, i, now)
            if pd > 0.0 and float(nf_rng.random()) < pd:
                if nf.hardened:
                    # Leased two-phase transfer: the drop expires the lease
                    # lease_timeout later and the tasks return to the victim
                    # (or a live survivor) — exactly-once delivery at the
                    # price of one lease_timeout of queueing latency.
                    stats["lease"] += 1
                    in_transit[i] += take
                    push_event(
                        now + nf.lease_timeout, "lease", i, (v, stamps)
                    )
                else:
                    # Ablation (hardened=False): fire-and-forget transfer —
                    # the loot is gone.  Counted so the run can terminate
                    # and the benchmark can report the damage.
                    stats["lost"] += take
                pol.on_steal_result(view, plan, take, depth(v))
                return True
        in_transit[i] += take
        push_event(arrive, "receive", i, stamps)
        stats["steals"] += 1
        stats["moved"] += take
        steal_log.append((now, i, v, take))
        pol.on_steal_result(view, plan, take, depth(v))
        return True

    def land(node: int, stamps, now: float) -> None:
        """Queue stamps head-side on ``node`` and wake it if idle."""
        queues[node].extendleft(stamps)
        for s in stamps:
            qcls[node, task_cls[s]] += 1.0
        if uses_ring:
            publish(node, now)
        if idle_since[node] >= 0.0:
            idle_since[node] = -1.0
            start_task(node, now)

    # ---- Autoscale plane (cfg.autoscale): reserve slots res0.. activate in
    # order and deactivate LIFO, reusing the join/retire machinery so the
    # policy sees ordinary membership churn.  Holt's level+trend state
    # drives the predictive mode; neither mode touches scheduler rng.
    res0 = p0 + len(joins)  # == p0 whenever scaler is set (joins rejected)
    res_active: list[int] = []
    scale_state = {"level": 0.0, "trend": 0.0, "prev": 0, "init": False,
                   "idle": 0}
    if scaler is not None and total_tasks:
        mean_task_s = cfg.task_cost * float(np.mean(costs[task_cls]))
    else:
        mean_task_s = cfg.task_cost

    def scale_out(i: int, now: float, pending: int) -> None:
        nonlocal p, radius
        if i >= p:
            p = i + 1
        alive_sim[i] = True
        born[i] = now
        own_report[i] = now
        radius = _radius_for(p)
        if uses_ring:
            hist[i].append(now, 0.0, float("nan"))
        res_active.append(i)
        scale_log.append((now, "out", i, pending))
        pol.on_worker_join(i, now)
        start_task(i, now)

    def scale_in(now: float, pending: int) -> None:
        i = res_active.pop()
        alive_sim[i] = False
        stamps = list(queues[i])
        queues[i].clear()
        qcls[i, :] = 0.0
        if uses_ring:
            publish(i, now)
        for s in stamps:
            land(route(prefer_central=False), [s], now)
        scale_log.append((now, "in", i, pending))
        pol.on_worker_death(i, now)

    # Boot: all initial nodes start their first task at t=0.  Open-arrival
    # tasks enter through "arrive" events whose landing node is resolved at
    # ARRIVAL time (policy central queue, else live round-robin) — the ring
    # may have grown or shrunk since the trace was generated.  Membership
    # events are scheduled alongside.
    if open_mode:
        push_arrival()
    if scaler is not None:
        push_event(scaler.interval, "scale", -1)
    for k, (t_join, _speed) in enumerate(joins):
        push_event(float(t_join), "join", p0 + k)
    for t_ret, node in cfg.retires:
        if not 0 <= node < pmax:
            raise ValueError(f"retire target {node} outside the ring 0..{pmax - 1}")
        if node >= p0 and t_ret < joins[node - p0][0]:
            # Would hit the not-yet-joined node's tombstone guard and be
            # silently dropped — surface the mis-ordered churn script.
            raise ValueError(
                f"retire of node {node} at t={t_ret} precedes its join "
                f"at t={joins[node - p0][0]}"
            )
        push_event(float(t_ret), "retire", int(node))
    pol.on_start([depth(i) for i in range(p)], 0.0)
    for i in range(p0):
        start_task(i, 0.0)

    makespan = 0.0
    # lost > 0 is only reachable under the hardened=False ablation: those
    # tasks will never finish, so the run quiesces at done + lost == total.
    while heap and stats["done"] + stats["lost"] < total_tasks:
        now, _, kind, i, payload = heapq.heappop(heap)
        if kind == "finish":
            executed[i] += 1
            stats["done"] += 1
            runtime_sum[i] += pending_dur[i]
            task = pending_task[i]
            if has_classes:
                # Owner-side EWMA t̂[c] on completion — same update rule as
                # WorkerPool._observe_class_time, in virtual time.
                c = int(task_cls[task])
                prev = class_t[i, c]
                if prev != prev:  # first observation of this class
                    class_t[i, c] = pending_dur[i]
                else:
                    class_t[i, c] = (
                        cfg.ewma_alpha * pending_dur[i]
                        + (1.0 - cfg.ewma_alpha) * prev
                    )
            if open_mode:
                lat_v = now - float(task_arrival[task])
                latencies.append(lat_v)
                if slo_tele:
                    s = int(task_slo[task])
                    slo_lat[SLO_NAMES[s]].append(lat_v)
                    if lat_v > float(budgets[s]):
                        slo_viol[SLO_NAMES[s]] += 1
            makespan = max(makespan, now)
            if detect:
                # Owner-side limp detection on the completed duration (the
                # only thing the owner can actually observe — DESIGN.md
                # §Straggler plane caveat), normalised to average-class
                # terms so heavy tasks don't read as a slowdown.
                st = limp_states[i]
                st.observe(
                    normalize_duration(
                        pending_dur[i], int(task_cls[task]),
                        class_t[i] if has_classes else None,
                    )
                )
                flagged = st.evaluate(
                    peer_ref=(
                        _peer_ref(i, now)
                        if st.samples < cfg.limp.min_samples
                        else float("nan")
                    )
                )
                if flagged != bool(limping[i]):
                    limping[i] = flagged
                    limp_events.append((now, i, flagged))
            if uses_ring:
                # Update own info + history (Alg. 1 line 11 + communicate).
                cur_t[i] = runtime_sum[i] / executed[i]
                publish(i, now)
                own_report[i] = now  # owner-driven heartbeat (wedge detector)
            # Smart stealing right after finishing a task (preemptive);
            # a node retired mid-task completes it, then leaves the loop.
            boundary(i, now)
            start_task(i, now)
        elif kind == "arrive":
            push_arrival()  # stream the next trace entry onto the heap
            arrived += 1
            target = route()
            if target < 0:
                # Unlike the threaded plane (which raises PoolCollapsed at
                # submit), silently parking the stamp would truncate the
                # latency/task counts the caller is measuring — fail loud.
                raise RuntimeError(
                    f"arrival at t={now:.3f} but every node has retired; "
                    "fix the churn script (cfg.retires/joins)"
                )
            land(target, [payload], now)
        elif kind == "receive":
            in_transit[i] -= len(payload)
            if not alive_sim[i]:
                # Loot landed on a node that retired while it was in
                # transit: forward it to a live member immediately.
                tgt = route(prefer_central=False)
                if tgt < 0:
                    raise RuntimeError(
                        f"steal loot arrived at t={now:.3f} but every node "
                        "has retired; fix the churn script"
                    )
                land(tgt, payload, now)
                continue
            land(i, payload, now)
        elif kind == "lease":
            # Lease expiry: the dropped transfer's tasks return to their
            # victim (or a live survivor if it retired meanwhile) — the
            # second phase of the leased move, closing the exactly-once
            # guarantee.  The thief learns of the loss HERE (it waited out
            # the lease), so the health failure is recorded at expiry time.
            v, stamps = payload
            in_transit[i] -= len(stamps)
            health.record(i, v, False, now)
            tgt = v if alive_sim[v] else route(prefer_central=False)
            if tgt < 0:
                raise RuntimeError(
                    f"lease expired at t={now:.3f} but every node has "
                    "retired; fix the churn script"
                )
            land(tgt, stamps, now)
        elif kind == "retry":
            if not alive_sim[i]:
                continue  # tombstoned while idle: drop the poll loop
            if queues[i] or idle_since[i] < 0.0:
                continue  # no longer idle
            if stats["done"] + stats["lost"] >= total_tasks:
                continue
            if uses_ring:
                # An idle poll IS a heartbeat: the threaded idle loop keeps
                # reaching boundaries and bumping its own ring row, so only
                # a worker stuck INSIDE a task goes silent (the wedge).
                own_report[i] = now
            if not boundary(i, now):
                # mild exponential backoff so long idle tails stay cheap
                delay = cfg.retry_interval * (1.3 ** min(payload, 12))
                push_event(now + delay, "retry", i, payload + 1)
            # on success the stolen tasks arrive via a "receive" event
        elif kind == "scale":
            live = int(alive_sim.sum())
            pending = arrived - stats["done"] - stats["lost"]
            if scaler.mode == "threshold":
                # PR-3 serve-plane port: one action per tick on the
                # instantaneous backlog, scale-in after a full idle streak.
                if (
                    pending > scaler.high_pending_per_replica * max(live, 1)
                    and len(res_active) < len(reserve)
                ):
                    scale_out(res0 + len(res_active), now, pending)
                    scale_state["idle"] = 0
                elif pending == 0:
                    scale_state["idle"] += 1
                    if (
                        scale_state["idle"] >= scaler.idle_ticks_to_retire
                        and res_active
                    ):
                        scale_in(now, pending)
                        scale_state["idle"] = 0
                else:
                    scale_state["idle"] = 0
            else:
                # Predictive: Holt's level+trend on the observed arrival
                # rate, capacity provisioned against the HORIZON forecast —
                # reserves come up before the backlog a threshold scaler
                # waits for ever forms.
                inst = (arrived - scale_state["prev"]) / scaler.interval
                scale_state["prev"] = arrived
                if not scale_state["init"]:
                    scale_state["init"] = True
                    scale_state["level"] = inst
                else:
                    lvl_prev = scale_state["level"]
                    a = scaler.rate_alpha
                    scale_state["level"] = a * inst + (1.0 - a) * lvl_prev
                    b = scaler.trend_beta
                    scale_state["trend"] = (
                        b * (scale_state["level"] - lvl_prev)
                        + (1.0 - b) * scale_state["trend"]
                    )
                lam = max(
                    scale_state["level"]
                    + scale_state["trend"] * scaler.horizon,
                    0.0,
                )
                need = lam / scaler.target_util  # tasks/s of capacity wanted
                cap = sum(
                    float(speeds[j]) / mean_task_s
                    for j in range(p0)
                    if alive_sim[j]
                )
                want = 0
                for r in range(len(reserve)):
                    if cap >= need:
                        break
                    cap += float(speeds[res0 + r]) / mean_task_s
                    want += 1
                while len(res_active) < want:
                    scale_out(res0 + len(res_active), now, pending)
                if len(res_active) > want and pending <= live:
                    # Recede one per tick, and only once the backlog is
                    # small — draining a reserve re-sprays its queue.
                    scale_in(now, pending)
            if arrived < total_tasks or pending > 0:
                push_event(now + scaler.interval, "scale", -1)
        elif kind == "join":
            # Scale-out: node i materialises NOW — empty queue, no history,
            # preemptive estimates date from `born[i]`, and the policy grows
            # any member-count state before the joiner's first boundary.
            p = i + 1
            alive_sim[i] = True
            born[i] = now
            own_report[i] = now  # heartbeat baseline starts at the join
            radius = _radius_for(p)
            if uses_ring:
                hist[i].append(now, 0.0, float("nan"))
            pol.on_worker_join(i, now)
            start_task(i, now)  # empty queue -> the retry/steal loop
        elif kind == "retire":
            if not alive_sim[i]:
                continue  # already tombstoned (double retire / dead)
            alive_sim[i] = False
            # Graceful drain: re-spray the queued stamps over live members
            # (the threaded plane's retire_worker(drain=True) semantics).
            stamps = list(queues[i])
            queues[i].clear()
            qcls[i, :] = 0.0
            if uses_ring:
                publish(i, now)
            if stamps and not alive_sim[:p].any():
                raise RuntimeError(
                    f"retiring the last live node at t={now:.3f} with "
                    f"{len(stamps)} task(s) queued would silently drop "
                    "them; fix the churn script"
                )
            for s in stamps:
                land(route(prefer_central=False), [s], now)
            pol.on_worker_death(i, now)

    pol.termination(makespan)
    return SimResult(
        makespan=makespan,
        per_node_tasks=[int(x) for x in executed],
        per_node_busy=[float(b) for b in busy],
        steals=stats["steals"],
        failed_steals=stats["failed"],
        moved_tasks=stats["moved"],
        records=records,
        latencies=latencies,
        limp_events=limp_events,
        steal_log=steal_log,
        boundaries=stats["boundaries"],
        net_failed=stats["net_failed"],
        lease_expired=stats["lease"],
        lost_tasks=stats["lost"],
        slo_latencies=slo_lat,
        slo_violations=slo_viol,
        scale_log=scale_log,
    )

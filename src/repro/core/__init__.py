"""A2WS — Adaptive Asynchronous Work-Stealing (the paper's contribution).

Layers:
  steal        Eqs. 2-10 (steal rate, γ-rounding, victim selection)
  info_ring    radius-R bidirectional ring information vector (§2.1)
  deque        packed head/tail asynchronous-theft deque (§2.3, Fig. 2/3b)
  policy       pluggable SchedPolicy layer (A2WS, CTWS, LW, random-WS)
  limp         straggler plane: slowdown fault injection + limp detection
  a2ws         policy-parametric threaded WorkerPool substrate (Algorithm 1)
  baselines    LW (leader-workers) and CTWS (cyclic token) policy shims
  simulator    discrete-event virtual-time plane driving the same policies
  device_sched jitted shard_map/ppermute SPMD scheduler (TPU data plane)
"""

from .a2ws import A2WSRuntime, RunStats, WorkerPool, partition_tasks
from .baselines import CTWSRuntime, LWRuntime
from .deque import AtomicInt64, StealResult, TaskDeque
from .info_ring import CellBoard, CellDigest, CellMap, DigestBoard, RingInfo
from .limp import LimpConfig, LimpState, SlowdownEvent, SlowdownSchedule
from .policy import (
    POLICIES,
    A2WSPolicy,
    CTWSPolicy,
    HierarchicalA2WSPolicy,
    LWPolicy,
    PolicyView,
    RandomWSPolicy,
    SchedPolicy,
    StealPlan,
    make_policy,
)
from .simulator import SimConfig, SimResult, simulate, table2_speeds
from .steal import (
    StealDecision,
    gamma,
    ideal_runtime,
    neighborhood,
    pair_steal_rate,
    plan_steal,
    round_steal_rate,
    select_victim,
    steal_rate,
    steal_rate_radius,
    victim_weights,
)

__all__ = [
    "A2WSRuntime",
    "WorkerPool",
    "RunStats",
    "partition_tasks",
    "CTWSRuntime",
    "LWRuntime",
    "SchedPolicy",
    "StealPlan",
    "PolicyView",
    "A2WSPolicy",
    "CTWSPolicy",
    "HierarchicalA2WSPolicy",
    "LWPolicy",
    "RandomWSPolicy",
    "POLICIES",
    "make_policy",
    "AtomicInt64",
    "StealResult",
    "TaskDeque",
    "RingInfo",
    "CellMap",
    "CellBoard",
    "CellDigest",
    "DigestBoard",
    "LimpConfig",
    "LimpState",
    "SlowdownEvent",
    "SlowdownSchedule",
    "SimConfig",
    "SimResult",
    "simulate",
    "table2_speeds",
    "StealDecision",
    "gamma",
    "ideal_runtime",
    "neighborhood",
    "pair_steal_rate",
    "plan_steal",
    "round_steal_rate",
    "select_victim",
    "steal_rate",
    "steal_rate_radius",
    "victim_weights",
]

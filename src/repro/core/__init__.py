"""A2WS — Adaptive Asynchronous Work-Stealing (the paper's contribution).

Layers:
  steal        Eqs. 2-10 (steal rate, γ-rounding, victim selection)
  info_ring    radius-R bidirectional ring information vector (§2.1)
  deque        packed head/tail asynchronous-theft deque (§2.3, Fig. 2/3b)
  a2ws         Algorithm 1 threaded host runtime
  baselines    LW (leader-workers) and CTWS (cyclic token) comparisons
  simulator    discrete-event heterogeneous-cluster simulator (paper §4 setup)
  device_sched jitted shard_map/ppermute SPMD scheduler (TPU data plane)
"""

from .a2ws import A2WSRuntime, RunStats, partition_tasks
from .baselines import CTWSRuntime, LWRuntime
from .deque import AtomicInt64, StealResult, TaskDeque
from .info_ring import RingInfo
from .simulator import SimConfig, SimResult, simulate, table2_speeds
from .steal import (
    StealDecision,
    gamma,
    ideal_runtime,
    neighborhood,
    pair_steal_rate,
    plan_steal,
    round_steal_rate,
    select_victim,
    steal_rate,
    steal_rate_radius,
    victim_weights,
)

__all__ = [
    "A2WSRuntime",
    "RunStats",
    "partition_tasks",
    "CTWSRuntime",
    "LWRuntime",
    "AtomicInt64",
    "StealResult",
    "TaskDeque",
    "RingInfo",
    "SimConfig",
    "SimResult",
    "simulate",
    "table2_speeds",
    "StealDecision",
    "gamma",
    "ideal_runtime",
    "neighborhood",
    "pair_steal_rate",
    "plan_steal",
    "round_steal_rate",
    "select_victim",
    "steal_rate",
    "steal_rate_radius",
    "victim_weights",
]

"""Smart-stealing mathematics of A2WS (paper §2.2, Eqs. 2-10).

Host-side (scalar / numpy) implementation used by the threaded runtime and the
discrete-event simulator.  ``repro.core.device_sched`` re-implements the same
formulas in jnp for the jitted shard_map scheduler; ``tests/test_steal.py``
asserts the two agree.

Conventions
-----------
* ``n[j]``  -- TOTAL number of tasks of process j: already executed + queued
               (paper: "including the already executed and available").
* ``t[j]``  -- average runtime per task of process j (seconds).  Processes that
               have not yet finished a task report their *elapsed wall time*
               (preemptive stealing, §2.2.1) so they look progressively slower.
* ``S_i``   -- ideal steal rate of process i (Eq. 4/5).  S_i > 0: i must steal
               S_i tasks; S_i < 0: others should steal -S_i tasks from i.

Note on Eq. 6: the paper prints ``U(S) = (n_k + S)/t_k`` but defines speed as
``1/t_k`` (Eq. 2), so the expected *runtime* of ``n_k + S`` tasks is
``(n_k + S) * t_k``.  We implement the dimensionally-consistent product and
flag the discrepancy here; every downstream property (γ-rounding minimises the
pairwise makespan) only makes sense with the product form.

Work-weighted generalisation (DESIGN.md §Work-weighted stealing)
----------------------------------------------------------------
Eqs. 2-10 assume homogeneous tasks, so "queue depth" and "queued work" are
the same number.  Under variable task cost (seismic shots with different
``nt``/model sizes) every formula here generalises by measuring queues in
**equivalent reference-class tasks** instead of head counts:

* ``rel[c]``   — relative cost of class c vs the reference class
                 (:func:`class_relatives`; within one worker the speed
                 cancels, so its own per-class EWMA ratios estimate it).
* ``w_j``      — queued work ``Σ_c n_j[c]·rel[c]`` replaces the count.
* ``unit_j``   — mean work per queued task at j (:func:`queue_units`);
                 converts an Eq. 5/7 work amount back to an integer TASK
                 count for the Fig. 3b protocol.

``plan_steal`` takes the work vectors through the SAME ``(n, t, queued)``
parameters plus ``unit``/``qtasks`` keywords; with one class ``rel ≡ 1``,
``unit ≡ 1`` and ``qtasks ≡ queued``, every operation multiplies or divides
by exactly 1.0 — the count-based plan falls out bit-for-bit (property-tested
in ``tests/test_weighted.py``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "class_counts",
    "slo_split",
    "ideal_runtime",
    "tail_steal_amount",
    "steal_rate",
    "steal_rate_radius",
    "pair_steal_rate",
    "expected_runtime",
    "gamma",
    "round_steal_rate",
    "victim_weights",
    "select_victim",
    "neighborhood",
    "class_relatives",
    "queue_units",
    "weighted_overlay",
    "OverlayBuffers",
]

_EPS = 1e-12


def class_counts(
    tasks: Sequence,
    classifier: Callable[[object], int] | None,
    num_classes: int,
) -> list[int]:
    """Per-cost-class histogram of a task batch — THE loot/queue accounting
    both planes share (DESIGN.md §Work-weighted stealing).

    :class:`repro.core.deque.Task` records carry their class in ``.cls`` and
    are counted directly; bare payloads go through ``classifier`` (clamped
    to ``[0, num_classes)``; a raising classifier falls back to class 0 —
    accounting must never kill a worker).  ``classifier=None`` counts
    everything, Task or not, in class 0 — the count-based degenerate case.
    """
    from .deque import Task  # local: steal.py must stay import-light

    counts = [0] * max(num_classes, 1)
    hi = len(counts) - 1
    for task in tasks:
        if type(task) is Task:
            c = task.cls
        elif classifier is None:
            c = 0
        else:
            try:
                c = int(classifier(task))
            except Exception:
                c = 0
        counts[min(max(c, 0), hi)] += 1
    return counts


def slo_split(tasks: Sequence) -> tuple[int, int]:
    """``(batch, latency)`` counts of a loot batch (DESIGN.md §SLO serving).

    Telemetry for the owner-vs-thief asymmetry claim: thief-end steals strip
    the tail, so their loot should skew batch even when the victim's queue
    holds latency work.  Uses :func:`repro.core.deque.slo_of`, so it accepts
    Task records, future-likes and bare payloads alike.
    """
    from .deque import SLO_LATENCY, slo_of

    lat = sum(1 for task in tasks if slo_of(task)[0] == SLO_LATENCY)
    return len(tasks) - lat, lat


def ideal_runtime(n: Sequence[float], t: Sequence[float]) -> float:
    """Eq. 2: t_ideal = N / T with N = sum(n_j) and T = sum(1/t_j).

    Non-finite runtimes (``t̂ = NaN``: a neighbour that has never reported,
    e.g. at open-arrival boot) poison the harmonic sum ``T``; the guard
    returns NaN explicitly so callers treat it as "no information" rather
    than receiving an arbitrary NaN/inf arithmetic artefact.
    """
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    if not np.isfinite(t).all():
        return float("nan")
    big_n = float(n.sum())
    big_t = float((1.0 / np.maximum(t, _EPS)).sum())
    return big_n / max(big_t, _EPS)


def steal_rate(i: int, n: Sequence[float], t: Sequence[float]) -> float:
    """Eq. 4: S_i = N / (t_i * T) - n_i over the FULL system."""
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    big_n = float(n.sum())
    big_t = float((1.0 / np.maximum(t, _EPS)).sum())
    return big_n / (max(float(t[i]), _EPS) * max(big_t, _EPS)) - float(n[i])


def neighborhood(i: int, num_procs: int, radius: int) -> list[int]:
    """Indices of the radius-R subsystem around i on the ring (Eq. 1).

    ``P_sub = 2R + 1`` positions, wrapping around the ring; if the radius
    covers the whole ring the neighborhood is simply every process once.
    """
    if 2 * radius + 1 >= num_procs:
        return list(range(num_procs))
    return [(i + d) % num_procs for d in range(-radius, radius + 1)]


def steal_rate_radius(
    i: int, n: Sequence[float], t: Sequence[float], radius: int
) -> float:
    """Eq. 5: the steal rate computed only over the radius-R subsystem.

    Returns NaN when any in-window runtime is non-finite (unreported
    neighbours at boot) — there is no basis for a fair share, and callers
    (``plan_steal``) must translate NaN into "no steal" instead of letting
    it corrupt victim probabilities downstream.
    """
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    idx = neighborhood(i, len(n), radius)
    if not np.isfinite(t[idx]).all():
        return float("nan")
    sub_n = float(n[idx].sum())
    sub_t = float((1.0 / np.maximum(t[idx], _EPS)).sum())
    return sub_n / (max(float(t[i]), _EPS) * max(sub_t, _EPS)) - float(n[i])


def pair_steal_rate(n_i: float, t_i: float, n_j: float, t_j: float) -> float:
    """Eq. 10 (simplified Eq. 9): in-pair steal rate of thief i vs victim j.

    S_j = (n_i + n_j) * t_j / (t_i + t_j) - n_i
    Positive => thief i should take S_j tasks from j when only the pair is
    considered (used when the subsystem looks balanced, §2.2.2).
    """
    return (n_i + n_j) * t_j / max(t_i + t_j, _EPS) - n_i


def expected_runtime(s: float, n_k: float, t_k: float) -> float:
    """Eq. 6: runtime of process k after its queue changes by ``s`` tasks."""
    return max(n_k + s, 0.0) * t_k


def gamma(
    s: float, n_thief: float, t_thief: float, n_victim: float, t_victim: float
) -> float:
    """Eq. 8: pairwise makespan if the thief steals ``s`` tasks."""
    return max(
        expected_runtime(-s, n_victim, t_victim),
        expected_runtime(+s, n_thief, t_thief),
    )


def round_steal_rate(
    s: float,
    n_thief: float,
    t_thief: float,
    n_victim: float,
    t_victim: float,
    unit: float = 1.0,
) -> int:
    """Eq. 7: round fractional S to the integer minimising γ (pair makespan).

    ``unit``: mean work per victim task (work-weighted mode).  ``s`` and the
    ``n`` arguments are then in work units while the returned amount stays an
    integer TASK count — γ is evaluated at ``k·unit`` work moved.  The
    default ``unit=1.0`` multiplies by exactly 1.0 everywhere, so the
    homogeneous path is unchanged bit-for-bit.
    """
    s_tasks = s / max(unit, _EPS)
    lo, hi = math.floor(s_tasks), math.ceil(s_tasks)
    if lo == hi:
        return int(lo)
    g_lo = gamma(lo * unit, n_thief, t_thief, n_victim, t_victim)
    g_hi = gamma(hi * unit, n_thief, t_thief, n_victim, t_victim)
    return int(lo) if g_lo < g_hi else int(hi)


def _distance_penalty(
    cand: np.ndarray,
    w: np.ndarray,
    tcost: "Callable[[int, int], float] | None",
    ref: float,
) -> np.ndarray:
    """Divide victim weights by ``1 + cost(j, 1)/ref`` (DESIGN.md §Topology
    plane): between equally-attractive victims, prefer the one whose loot is
    cheap to move.  ``ref`` is the thief's own per-task seconds, so the
    penalty is the per-task transfer cost measured in thief task-times.
    With ``tcost=None`` — or a model pricing every candidate at 0.0, where
    each weight divides by exactly 1.0 — the weights are bit-for-bit the
    unpriced ones."""
    if tcost is None:
        return w
    pen = np.array(
        [1.0 + max(float(tcost(int(j), 1)), 0.0) / ref for j in cand],
        dtype=np.float64,
    )
    return w / pen


def _health_factor(
    cand: np.ndarray,
    w: np.ndarray,
    health: "Callable[[int], float] | None",
) -> np.ndarray:
    """Multiply victim weights by the link-health factor (DESIGN.md §Fault
    fabric): 0.0 for a victim behind an active partition or a backed-off
    flaky link (excluded outright — the request cannot or should not be
    sent), the floor-clamped success EWMA for the rest.  ``health=None`` —
    or an all-healthy hook, where every factor is exactly 1.0 — leaves the
    weights bit-for-bit untouched (the multiply is skipped entirely)."""
    if health is None:
        return w
    f = np.array(
        [min(max(float(health(int(j))), 0.0), 1.0) for j in cand],
        dtype=np.float64,
    )
    if np.all(f >= 1.0):
        return w
    return w * f


def victim_weights(
    i: int,
    n: Sequence[float],
    t: Sequence[float],
    queued: Sequence[float],
    radius: int,
    tcost: "Callable[[int, int], float] | None" = None,
    link_health: "Callable[[int], float] | None" = None,
) -> tuple[np.ndarray, np.ndarray, str]:
    """Victim-selection probabilities (§2.2.2) for thief ``i``.

    Returns ``(candidates, weights, criterion)`` where ``criterion`` is
    ``"closest-rate"`` or ``"in-pair"``.

    Criterion 1 — *closest rate*: candidates are subsystem members with
    S_j < 0 (surplus) and a non-empty queue.  The best victim is the one whose
    surplus ``-S_j`` most closely matches the thief's deficit ``S_i`` (one
    steal balances both).  Weights scale with the surplus volume and decay
    with the mismatch, so concurrent thieves favour victims that can actually
    satisfy them while still spreading probabilistically (the paper specifies
    the criterion but not the exact weight; this is our realisation).

    Criterion 2 — *in-pair comparison* (Eq. 9/10): used when no candidate has
    S_j < 0 but queued tasks remain.  Each pair is evaluated in isolation and
    weighted by the pairwise steal volume.

    ``tcost``: optional ``(victim, ntasks) -> seconds`` transfer-cost hook
    (DESIGN.md §Topology plane).  Weights in BOTH criteria are divided by
    ``1 + cost/ref`` so nearby victims win ties; ``None`` (or an all-zero
    model) reproduces the unpriced weights bit-for-bit.

    ``link_health``: optional ``victim -> [0, 1]`` fault-plane hook
    (DESIGN.md §Fault fabric).  Weights in BOTH criteria are multiplied by
    the factor — 0.0 (partitioned / backed-off link) excludes the victim
    outright; ``None`` (or an all-healthy model) reproduces the weights
    bit-for-bit.
    """
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    queued = np.asarray(queued, dtype=np.float64)
    idx = [j for j in neighborhood(i, len(n), radius) if j != i]
    if not idx:
        return np.array([], dtype=np.int64), np.array([]), "closest-rate"

    ref = max(float(t[i]), _EPS) if math.isfinite(t[i]) else 1.0
    s_i = steal_rate_radius(i, n, t, radius)
    s_j = np.array([steal_rate_radius(j, n, t, radius) for j in idx])
    has_tasks = queued[idx] > 0.0

    surplus = (s_j < 0.0) & has_tasks
    if surplus.any():
        cand = np.asarray(idx, dtype=np.int64)[surplus]
        volume = -s_j[surplus]
        mismatch = np.abs(volume - max(s_i, 0.0))
        w = _distance_penalty(cand, volume / (1.0 + mismatch), tcost, ref)
        w = _health_factor(cand, w, link_health)
        w_sum = float(w.sum())
        if not math.isfinite(w_sum) or w_sum <= 0.0:
            # Every candidate priced unreachable (infinite-cost links).
            return np.array([], dtype=np.int64), np.array([]), "closest-rate"
        return cand, w / w_sum, "closest-rate"

    # In-pair fallback: the subsystem looks balanced yet queues are non-empty.
    pair = np.array(
        [pair_steal_rate(n[i], t[i], n[j], t[j]) for j in idx], dtype=np.float64
    )
    good = (pair > 0.0) & has_tasks
    if not good.any():
        return np.array([], dtype=np.int64), np.array([]), "in-pair"
    cand = np.asarray(idx, dtype=np.int64)[good]
    w = _distance_penalty(cand, pair[good], tcost, ref)
    w = _health_factor(cand, w, link_health)
    w_sum = float(w.sum())
    if not math.isfinite(w_sum) or w_sum <= 0.0:
        return np.array([], dtype=np.int64), np.array([]), "in-pair"
    return cand, w / w_sum, "in-pair"


def select_victim(
    rng: np.random.Generator,
    i: int,
    n: Sequence[float],
    t: Sequence[float],
    queued: Sequence[float],
    radius: int,
    tcost: "Callable[[int, int], float] | None" = None,
    link_health: "Callable[[int], float] | None" = None,
) -> tuple[int | None, str]:
    """Sample a victim for thief ``i`` (§2.2.2); None if no viable victim."""
    cand, w, crit = victim_weights(i, n, t, queued, radius, tcost, link_health)
    if len(cand) == 0:
        return None, crit
    return int(rng.choice(cand, p=w)), crit


class OverlayBuffers:
    """Preallocated scratch for :func:`weighted_overlay`, keyed on (P, C).

    The overlay sits on the per-boundary hot path and otherwise rebuilds a
    dozen (P,)- and (P, C)-sized temporaries per view.  A caller that runs
    many boundaries (one buffer per worker in the threaded pool, one per
    ring size in the simulator) passes the same buffer object back in; the
    overlay then writes into these arrays instead of allocating.

    The arrays RETURNED by a buffered ``weighted_overlay`` call alias this
    scratch — they are valid until the next overlay call with the same
    buffer, which is exactly one task boundary.  Never share one buffer
    across concurrently-deciding workers.
    """

    __slots__ = (
        "p", "c", "ratios", "both", "known", "ref_t", "finite", "mtmp",
        "t_w", "queued_w", "n_w", "exec_est", "unit", "vtmp",
    )

    def __init__(self, p: int, c: int) -> None:
        self.p, self.c = p, c
        self.ratios = np.empty((p, c), dtype=np.float64)
        self.both = np.empty((p, c), dtype=bool)
        self.known = np.empty((p, c), dtype=bool)
        self.ref_t = np.empty((p, c), dtype=np.float64)
        self.finite = np.empty((p, c), dtype=bool)
        self.mtmp = np.empty((p, c), dtype=np.float64)
        self.t_w = np.empty(p, dtype=np.float64)
        self.queued_w = np.empty(p, dtype=np.float64)
        self.n_w = np.empty(p, dtype=np.float64)
        self.exec_est = np.empty(p, dtype=np.float64)
        self.unit = np.empty(p, dtype=np.float64)
        self.vtmp = np.empty(p, dtype=np.float64)

    @classmethod
    def ensure(
        cls, buf: "OverlayBuffers | None", p: int, c: int
    ) -> "OverlayBuffers":
        """Reuse ``buf`` when it matches (P, C), else allocate a fresh one —
        elastic growth and cell migration change a worker's view size."""
        if buf is not None and buf.p == p and buf.c == c:
            return buf
        return cls(p, c)


def class_relatives(
    tc: np.ndarray, buf: OverlayBuffers | None = None
) -> np.ndarray:
    """Relative per-class costs ``rel[c]`` from a (P, C) matrix of per-worker
    per-class EWMA runtimes (NaN = that worker never ran that class).

    Within ONE worker the speed cancels: ``t̂_j[c]/t̂_j[a] = κ[c]/κ[a]``
    exactly under the separable cost model (duration = class cost / worker
    speed), so the primary estimator is the mean of own-worker ratios
    against the anchor class ``a`` (the lowest class anyone reported).
    Fallback when no worker reported both ``c`` and ``a``: the ratio of
    pool means (biased by which speeds saw which class, but better than
    nothing); a class nobody reported prices at 1.0 — the count-based
    degenerate value, so unknown classes never poison the plan.
    """
    tc = np.asarray(tc, dtype=np.float64)
    if tc.ndim != 2:
        raise ValueError("tc must be (num_workers, num_classes)")
    p, c = tc.shape
    rel = np.ones(c, dtype=np.float64)
    if buf is not None and (buf.p != p or buf.c != c):
        buf = None  # mismatched scratch: fall back to fresh temporaries
    known = (
        np.isfinite(tc) if buf is None else np.isfinite(tc, out=buf.known)
    )
    reported = known.any(axis=0)
    if not reported.any():
        return rel
    # Fully vectorised — this runs on every weighted ring view, a hot path.
    anchor = int(np.argmax(reported))  # lowest class with any report
    base = tc[:, anchor]
    known_a = known[:, anchor]
    # (P, C): worker knows anchor AND class
    if buf is None:
        both = known_a[:, None] & known
        ratios = np.divide(tc, base[:, None], out=np.ones_like(tc), where=both)
        masked = np.where(both, ratios, 0.0)
    else:
        both = np.logical_and(known_a[:, None], known, out=buf.both)
        buf.ratios.fill(1.0)
        ratios = np.divide(tc, base[:, None], out=buf.ratios, where=both)
        buf.mtmp.fill(0.0)
        np.copyto(buf.mtmp, ratios, where=both)
        masked = buf.mtmp
    n_both = both.sum(axis=0)
    with np.errstate(invalid="ignore"):
        rel_ratio = masked.sum(axis=0) / n_both
    # Pool-mean fallback for classes no worker reported alongside the anchor.
    col_cnt = known.sum(axis=0)
    if buf is None:
        col_masked = np.where(known, tc, 0.0)
    else:
        buf.mtmp.fill(0.0)
        np.copyto(buf.mtmp, tc, where=known)
        col_masked = buf.mtmp
    with np.errstate(invalid="ignore"):
        col_mean = col_masked.sum(axis=0) / col_cnt
    anchor_mean = col_mean[anchor]
    use_ratio = n_both > 0
    use_pool = (~use_ratio) & reported & (anchor_mean > 0.0)
    rel = np.where(use_ratio, rel_ratio, rel)
    rel = np.where(use_pool, col_mean / max(anchor_mean, _EPS), rel)
    rel[~reported] = 1.0
    rel[anchor] = 1.0
    return np.maximum(rel, _EPS)


def queue_units(
    nc: np.ndarray, rel: np.ndarray, buf: OverlayBuffers | None = None
) -> np.ndarray:
    """Mean work per queued task, per worker: ``unit_j = Σ_c nc_j[c]·rel[c]
    / Σ_c nc_j[c]`` from a (P, C) matrix of per-class queue counts.  Workers
    with no class information (empty or unreported queue) price at 1.0 —
    the count-based degenerate value."""
    nc = np.asarray(nc, dtype=np.float64)
    rel = np.asarray(rel, dtype=np.float64)
    if buf is not None and buf.p == nc.shape[0] and buf.c == nc.shape[1]:
        tot = nc.sum(axis=1, out=buf.vtmp)
        work = np.matmul(nc, rel, out=buf.unit)
        np.divide(work, np.maximum(tot, _EPS), out=work)
        work[tot <= 0.0] = 1.0
        return work
    tot = nc.sum(axis=1)
    work = nc @ rel
    return np.where(tot > 0.0, work / np.maximum(tot, _EPS), 1.0)


def weighted_overlay(
    n: np.ndarray,
    t: np.ndarray,
    queued: np.ndarray,
    nc: np.ndarray,
    tc: np.ndarray,
    frozen: np.ndarray | None = None,
    buf: OverlayBuffers | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """The work-weighted re-pricing shared by BOTH planes (DESIGN.md
    §Work-weighted stealing): from count-denominated view rows ``(n, t,
    queued)`` and the per-class board rows ``(nc, tc)``, derive

    * ``rel``/``unit`` — class relatives and mean work per queued task,
    * ``t_w``        — seconds per REFERENCE task (``t̂[c]/rel[c]`` mean;
      the per-task mean conflates speed with queue mix).  Rows with no
      class report, and rows masked by ``frozen`` (tombstones priced at
      ~0 speed), keep their scalar estimate,
    * ``queued_w``/``n_w`` — queue and total in equivalent reference-class
      tasks (executed history converts by ``t/t_w``),
    * ``qtasks``     — the original count estimates (integrality guards and
      the Fig. 3b clamp).

    Returns ``(n_w, t_w, queued_w, unit, qtasks, rel)``.  One
    implementation on purpose: the threaded runtime and the simulator must
    price identically or cross-plane conformance is meaningless.

    ``buf``: optional :class:`OverlayBuffers` scratch keyed on (P, C).  The
    returned arrays then alias the buffer (valid for one task boundary) —
    results are numerically identical with or without it.
    """
    if buf is not None and (buf.p != tc.shape[0] or buf.c != tc.shape[1]):
        buf = None  # mismatched scratch (elastic growth): allocate fresh
    rel = class_relatives(tc, buf)
    unit = queue_units(nc, rel, buf)
    with np.errstate(invalid="ignore"):
        ref_t = (
            tc / rel if buf is None else np.divide(tc, rel, out=buf.ref_t)
        )
    finite = (
        np.isfinite(ref_t) if buf is None
        else np.isfinite(ref_t, out=buf.finite)
    )
    rows = finite.any(axis=1)
    if frozen is not None:
        rows &= ~np.asarray(frozen, dtype=bool)
    t_w = t.copy() if buf is None else np.copyto(buf.t_w, t) or buf.t_w
    # Per-row mean of the finite reference-priced estimates, vectorised:
    # summing masked zeros is exact (adding 0.0 never changes a float), so
    # this is the same value as the per-row compress-and-mean it replaced.
    if rows.any():
        if buf is None:
            msum = np.where(finite, ref_t, 0.0).sum(axis=1)
        else:
            buf.mtmp.fill(0.0)
            np.copyto(buf.mtmp, ref_t, where=finite)
            msum = buf.mtmp.sum(axis=1)
        cnt = finite.sum(axis=1)
        np.copyto(t_w, msum / np.maximum(cnt, 1), where=rows)
    qtasks = queued
    if buf is None:
        queued_w = queued * unit
        exec_est = np.maximum(n - queued, 0.0)
        n_w = exec_est * (t / np.maximum(t_w, 1e-12)) + queued_w
    else:
        queued_w = np.multiply(queued, unit, out=buf.queued_w)
        exec_est = np.subtract(n, queued, out=buf.exec_est)
        np.maximum(exec_est, 0.0, out=exec_est)
        ratio = np.divide(t, np.maximum(t_w, 1e-12), out=buf.vtmp)
        n_w = np.multiply(exec_est, ratio, out=buf.n_w)
        n_w += queued_w
    return n_w, t_w, queued_w, unit, qtasks, rel


@dataclass(frozen=True)
class StealDecision:
    """A fully-resolved steal: victim and integer task count.

    ``work``: the plan's loot target in equivalent reference-class tasks
    (``amount × unit_victim``) — 0.0 in count mode.  A weighted substrate
    executes the steal greedily by work (``TaskDeque.steal_by_work``), so
    the amount actually moved tracks the planned work-seconds even when the
    victim's tail composition differs from its mean unit."""

    victim: int
    amount: int
    criterion: str
    work: float = 0.0


def tail_steal_amount(
    q_thief: float,
    t_thief: float,
    q_victim: float,
    t_victim: float,
    *,
    open_arrival: bool = False,
    unit_victim: float = 1.0,
    thief_tasks: float | None = None,
) -> int:
    """γ-optimal steal count on REMAINING work (the §2.2 'final stages' rule).

    Minimises ``max((q_v - k)·t_v, (q_i + k)·t_i)`` over integer k — the pair
    makespan from *now* — and returns k only if it strictly beats k = 0.
    Used when the thief is (nearly) idle: it prevents a fast process from
    idling while a slow one still holds queued tasks, and conversely returns
    0 when a slow thief would only stretch the pair makespan.

    ``open_arrival``: under open arrivals (tasks injected while the system
    runs) the closed-workload tie-break inverts for an EMPTY thief.  In a
    closed run a tie steal is pointless churn ("slow processes cannot steal
    at the end"); in an open run the victim's queue depth q_v excludes the
    task it is currently executing, so a tied γ still leaves the stolen task
    waiting behind the victim's in-flight work while the thief idles — a pure
    per-task latency loss.  An idle (q_i = 0) thief therefore accepts ties
    (k ≥ 1 whenever γ(k) ≤ γ(0)), which is what keeps freshly injected tasks
    from being stranded on a busy worker's deque.

    Work-weighted mode: ``q_victim`` stays the victim's TASK count while
    ``q_thief`` is the thief's queued WORK and ``unit_victim`` the mean work
    per victim task, so γ compares drain times of heterogeneous queues but
    ``k`` remains an integer task count for the Fig. 3b protocol.
    ``thief_tasks`` is the thief's task count for the idle tie rule
    (defaults to ``q_thief`` — identical in the homogeneous case).  Any
    non-finite input means "no information": return 0 (no steal) instead of
    propagating NaN into ``int()``.
    """
    if not all(
        math.isfinite(v) for v in (q_thief, t_thief, q_victim, t_victim)
    ):
        return 0
    if q_victim < 1.0:
        return 0
    u = max(unit_victim, _EPS)
    if thief_tasks is None:
        thief_tasks = q_thief
    w_victim = q_victim * u
    k_star = (w_victim * t_victim - q_thief * t_thief) / max(
        u * (t_thief + t_victim), _EPS
    )
    best_k, best_g = 0, max(w_victim * t_victim, q_thief * t_thief)
    for k in {math.floor(k_star), math.ceil(k_star), 1}:
        k = int(min(max(k, 0), q_victim))
        g = max((w_victim - k * u) * t_victim, (q_thief + k * u) * t_thief)
        if g < best_g - 1e-12 or (g == best_g and k < best_k):
            best_k, best_g = k, g
    if open_arrival and best_k == 0 and thief_tasks < 1.0:
        # Accept a tie: one task moves to the idle thief if that does not
        # strictly worsen the pair bound (it starts immediately instead of
        # queueing behind the victim's in-flight task).
        g1 = max((w_victim - u) * t_victim, (q_thief + u) * t_thief)
        if g1 <= best_g + 1e-12:
            return 1
    return best_k


def plan_steal(
    rng: np.random.Generator,
    i: int,
    n: Sequence[float],
    t: Sequence[float],
    queued: Sequence[float],
    radius: int,
    idle: bool = False,
    open_arrival: bool = False,
    *,
    unit: Sequence[float] | None = None,
    qtasks: Sequence[float] | None = None,
    transfer_cost: Callable[[int, int], float] | None = None,
    link_health: Callable[[int], float] | None = None,
) -> StealDecision | None:
    """End-to-end smart-stealing decision for thief ``i`` (Alg. 1 lines 4-6).

    Computes S_i (Eq. 5), selects a victim (§2.2.2), rounds with γ (Eq. 7) and
    clamps to the victim's queued tasks.  Returns None when i should not steal.

    ``idle``: the thief's deque is (nearly) empty.  Preemptive stealing
    (S_i > 0 on TOTAL task counts, Eqs. 4-8) is the primary mechanism; a
    (nearly) idle thief additionally applies the remaining-work γ tail rule
    (``tail_steal_amount``) so that (a) fast processes never idle while slow
    ones hold queued tasks (the paper's "final stages" behaviour) and (b)
    the §2.1 relay works — an intermediary with S_i <= 0 still pulls tasks
    across the ring when that strictly reduces the pair makespan, letting a
    distant fast process re-steal them.

    ``open_arrival``: the workload is open (tasks keep arriving while the
    scheduler runs, DESIGN.md §Open-arrival).  The paper's cumulative totals
    ``n_j`` (executed + available, §2.2) are meaningless as a balance target
    when the ground keeps shifting, so Eq. 5 is evaluated on the
    INSTANTANEOUS queue depths instead: ``S_i = Q_sub/(t_i·T_sub) − q_i`` is
    the fair share of the *remaining* work in the radius-R window.  Callers
    must then pass reported depths via ``queued`` (no elapsed-time
    extrapolation — depth both drains and refills under arrivals) and the
    tail rule runs in its latency-oriented tie-accepting form.

    ``transfer_cost``: optional ``(victim, ntasks) -> seconds`` network
    pricing hook (DESIGN.md §Topology plane).  Victim weights are
    distance-penalized, and a sized plan is priced as *work-gained minus
    transfer-cost*: the γ improvement of moving the loot (seconds) must
    exceed the cost of moving it, else the steal is REFUSED — a refused
    preemptive plan falls through to the tail rule (which may find a
    nearer victim), a refused tail plan returns None.  ``None``, or a
    model pricing every link at 0.0, reproduces the unpriced plan
    bit-for-bit, rng stream included.

    ``link_health``: optional ``victim -> [0, 1]`` fault-plane hook
    (DESIGN.md §Fault fabric): victim weights in the preemptive AND tail
    draws are multiplied by the factor, so partitioned or backed-off
    victims (factor 0) are never targeted and flaky links are discounted.
    ``None``, or an all-healthy model, reproduces the plan bit-for-bit,
    rng stream included.

    ``unit``/``qtasks``: work-weighted mode (DESIGN.md §Work-weighted
    stealing).  ``n``/``queued`` are then measured in equivalent
    reference-class tasks (``w_j = Σ_c n_j[c]·rel[c]``), ``unit[j]`` is the
    mean work per queued task at j (converts Eq. 5/7 work amounts back to
    integer task counts) and ``qtasks[j]`` the actual queued task count
    (integrality guards and the Fig. 3b clamp).  Defaults (``None``) are the
    homogeneous identities — every operation multiplies by exactly 1.0, so
    the count-based plan is reproduced bit-for-bit, rng stream included.
    """
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    queued = np.asarray(queued, dtype=np.float64)
    weighted = unit is not None
    unit = (
        np.ones_like(queued)
        if unit is None
        else np.maximum(np.asarray(unit, dtype=np.float64), _EPS)
    )
    qtasks = queued if qtasks is None else np.asarray(qtasks, dtype=np.float64)
    if open_arrival:
        # Fair-share balance on remaining work: depths replace totals in
        # Eqs. 4-8; the γ-rounding already operates on "work after the
        # steal", which is exactly the depth semantics.
        n = queued
    s_i = steal_rate_radius(i, n, t, radius)
    # NaN guard: an all-unreported window (open-arrival boot, every t̂ NaN)
    # yields a NaN steal rate — no basis for Eq. 5, so no preemptive plan
    # (the tail rule below still works against reported victims).
    if math.isfinite(s_i) and s_i > 0.0:
        victim, crit = select_victim(
            rng, i, n, t, queued, radius, transfer_cost, link_health
        )
        if victim is not None:
            if crit == "in-pair":
                s = pair_steal_rate(
                    float(n[i]), float(t[i]), float(n[victim]), float(t[victim])
                )
            else:
                s = min(s_i, -steal_rate_radius(victim, n, t, radius))
            if s > 0.0:
                amount = round_steal_rate(
                    s, float(n[i]), float(t[i]), float(n[victim]), float(t[victim]),
                    unit=float(unit[victim]),
                )
                amount = int(min(amount, qtasks[victim]))
                if amount >= 1:
                    # Net pricing (§Topology plane): the γ improvement of
                    # moving the loot must beat the cost of moving it.  A
                    # refused plan falls through to the tail rule, which
                    # distance-penalizes toward nearer victims.
                    refused = False
                    if transfer_cost is not None:
                        cost = max(
                            float(transfer_cost(int(victim), int(amount))), 0.0
                        )
                        if cost > 0.0:
                            u = float(unit[victim])
                            args = (
                                float(n[i]), float(t[i]),
                                float(n[victim]), float(t[victim]),
                            )
                            gain = gamma(0.0, *args) - gamma(amount * u, *args)
                            refused = not (gain > cost)
                    if not refused:
                        return StealDecision(
                            victim=victim, amount=amount, criterion=crit,
                            work=(
                                amount * float(unit[victim]) if weighted else 0.0
                            ),
                        )

    # Tail rule: γ on remaining (queued) work against a probabilistically
    # chosen loaded victim.  This is the "final stages" behaviour of §2.2 —
    # a fast process must not idle while a slower one holds queued tasks.
    #
    # Guards: (a) victim queue estimates are FLOORED — tasks are integral,
    # and a fractional estimate (q=1.04) must not let a thief see a strict
    # γ-improvement where the true comparison is a tie (this enforces the
    # paper's "slow processes cannot steal at the end"); (b) a BUSY thief may
    # only tail-steal from victims at most as fast as itself — a pairwise
    # improvement that parks work on a slow node is a global regression
    # (other fast thieves would have drained that victim).  Idle thieves are
    # exempt from (b): that is the §2.1 relay (γ still protects the pair).
    window = [j for j in neighborhood(i, len(n), radius) if j != i]
    loaded = [
        j for j in window
        if math.floor(qtasks[j]) >= 1
        and (idle or t[i] <= t[j])
        and math.isfinite(t[j])
        and math.isfinite(queued[j])
    ]
    if not loaded:
        return None
    w = np.array([queued[j] * t[j] for j in loaded], dtype=np.float64)
    if transfer_cost is not None:
        ref = max(float(t[i]), _EPS) if math.isfinite(t[i]) else 1.0
        w = _distance_penalty(
            np.asarray(loaded, dtype=np.int64), w, transfer_cost, ref
        )
    w = _health_factor(np.asarray(loaded, dtype=np.int64), w, link_health)
    w_sum = float(w.sum())
    if not math.isfinite(w_sum) or w_sum <= 0.0:
        return None  # degenerate weights (NaN boot state / zero work)
    victim = int(rng.choice(loaded, p=w / w_sum))
    amount = tail_steal_amount(
        float(queued[i]), float(t[i]),
        float(math.floor(qtasks[victim])), float(t[victim]),
        open_arrival=open_arrival,
        unit_victim=float(unit[victim]),
        thief_tasks=float(qtasks[i]),
    )
    if amount < 1:
        return None
    if transfer_cost is not None:
        cost = max(float(transfer_cost(int(victim), int(amount))), 0.0)
        if cost > 0.0:
            # Net pricing on REMAINING work, mirroring tail_steal_amount's
            # γ: refuse when the pair-makespan improvement (plus, for an
            # idle open-arrival thief, the per-task wait the rescue saves)
            # does not beat the transfer cost.
            u = max(float(unit[victim]), _EPS)
            w_v = float(math.floor(qtasks[victim])) * u
            q_i, t_i_s, t_v = float(queued[i]), float(t[i]), float(t[victim])
            g0 = max(w_v * t_v, q_i * t_i_s)
            g1 = max((w_v - amount * u) * t_v, (q_i + amount * u) * t_i_s)
            rescue = (
                u * t_v
                if open_arrival and float(qtasks[i]) < 1.0
                else 0.0
            )
            if not (g0 - g1 + rescue > cost):
                return None
    return StealDecision(
        victim=victim, amount=amount, criterion="tail",
        work=amount * float(unit[victim]) if weighted else 0.0,
    )

"""Smart-stealing mathematics of A2WS (paper §2.2, Eqs. 2-10).

Host-side (scalar / numpy) implementation used by the threaded runtime and the
discrete-event simulator.  ``repro.core.device_sched`` re-implements the same
formulas in jnp for the jitted shard_map scheduler; ``tests/test_steal.py``
asserts the two agree.

Conventions
-----------
* ``n[j]``  -- TOTAL number of tasks of process j: already executed + queued
               (paper: "including the already executed and available").
* ``t[j]``  -- average runtime per task of process j (seconds).  Processes that
               have not yet finished a task report their *elapsed wall time*
               (preemptive stealing, §2.2.1) so they look progressively slower.
* ``S_i``   -- ideal steal rate of process i (Eq. 4/5).  S_i > 0: i must steal
               S_i tasks; S_i < 0: others should steal -S_i tasks from i.

Note on Eq. 6: the paper prints ``U(S) = (n_k + S)/t_k`` but defines speed as
``1/t_k`` (Eq. 2), so the expected *runtime* of ``n_k + S`` tasks is
``(n_k + S) * t_k``.  We implement the dimensionally-consistent product and
flag the discrepancy here; every downstream property (γ-rounding minimises the
pairwise makespan) only makes sense with the product form.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

__all__ = [
    "ideal_runtime",
    "tail_steal_amount",
    "steal_rate",
    "steal_rate_radius",
    "pair_steal_rate",
    "expected_runtime",
    "gamma",
    "round_steal_rate",
    "victim_weights",
    "select_victim",
    "neighborhood",
]

_EPS = 1e-12


def ideal_runtime(n: Sequence[float], t: Sequence[float]) -> float:
    """Eq. 2: t_ideal = N / T with N = sum(n_j) and T = sum(1/t_j)."""
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    big_n = float(n.sum())
    big_t = float((1.0 / np.maximum(t, _EPS)).sum())
    return big_n / max(big_t, _EPS)


def steal_rate(i: int, n: Sequence[float], t: Sequence[float]) -> float:
    """Eq. 4: S_i = N / (t_i * T) - n_i over the FULL system."""
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    big_n = float(n.sum())
    big_t = float((1.0 / np.maximum(t, _EPS)).sum())
    return big_n / (max(float(t[i]), _EPS) * max(big_t, _EPS)) - float(n[i])


def neighborhood(i: int, num_procs: int, radius: int) -> list[int]:
    """Indices of the radius-R subsystem around i on the ring (Eq. 1).

    ``P_sub = 2R + 1`` positions, wrapping around the ring; if the radius
    covers the whole ring the neighborhood is simply every process once.
    """
    if 2 * radius + 1 >= num_procs:
        return list(range(num_procs))
    return [(i + d) % num_procs for d in range(-radius, radius + 1)]


def steal_rate_radius(
    i: int, n: Sequence[float], t: Sequence[float], radius: int
) -> float:
    """Eq. 5: the steal rate computed only over the radius-R subsystem."""
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    idx = neighborhood(i, len(n), radius)
    sub_n = float(n[idx].sum())
    sub_t = float((1.0 / np.maximum(t[idx], _EPS)).sum())
    return sub_n / (max(float(t[i]), _EPS) * max(sub_t, _EPS)) - float(n[i])


def pair_steal_rate(n_i: float, t_i: float, n_j: float, t_j: float) -> float:
    """Eq. 10 (simplified Eq. 9): in-pair steal rate of thief i vs victim j.

    S_j = (n_i + n_j) * t_j / (t_i + t_j) - n_i
    Positive => thief i should take S_j tasks from j when only the pair is
    considered (used when the subsystem looks balanced, §2.2.2).
    """
    return (n_i + n_j) * t_j / max(t_i + t_j, _EPS) - n_i


def expected_runtime(s: float, n_k: float, t_k: float) -> float:
    """Eq. 6: runtime of process k after its queue changes by ``s`` tasks."""
    return max(n_k + s, 0.0) * t_k


def gamma(
    s: float, n_thief: float, t_thief: float, n_victim: float, t_victim: float
) -> float:
    """Eq. 8: pairwise makespan if the thief steals ``s`` tasks."""
    return max(
        expected_runtime(-s, n_victim, t_victim),
        expected_runtime(+s, n_thief, t_thief),
    )


def round_steal_rate(
    s: float, n_thief: float, t_thief: float, n_victim: float, t_victim: float
) -> int:
    """Eq. 7: round fractional S to the integer minimising γ (pair makespan)."""
    lo, hi = math.floor(s), math.ceil(s)
    if lo == hi:
        return int(lo)
    g_lo = gamma(lo, n_thief, t_thief, n_victim, t_victim)
    g_hi = gamma(hi, n_thief, t_thief, n_victim, t_victim)
    return int(lo) if g_lo < g_hi else int(hi)


def victim_weights(
    i: int,
    n: Sequence[float],
    t: Sequence[float],
    queued: Sequence[float],
    radius: int,
) -> tuple[np.ndarray, np.ndarray, str]:
    """Victim-selection probabilities (§2.2.2) for thief ``i``.

    Returns ``(candidates, weights, criterion)`` where ``criterion`` is
    ``"closest-rate"`` or ``"in-pair"``.

    Criterion 1 — *closest rate*: candidates are subsystem members with
    S_j < 0 (surplus) and a non-empty queue.  The best victim is the one whose
    surplus ``-S_j`` most closely matches the thief's deficit ``S_i`` (one
    steal balances both).  Weights scale with the surplus volume and decay
    with the mismatch, so concurrent thieves favour victims that can actually
    satisfy them while still spreading probabilistically (the paper specifies
    the criterion but not the exact weight; this is our realisation).

    Criterion 2 — *in-pair comparison* (Eq. 9/10): used when no candidate has
    S_j < 0 but queued tasks remain.  Each pair is evaluated in isolation and
    weighted by the pairwise steal volume.
    """
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    queued = np.asarray(queued, dtype=np.float64)
    idx = [j for j in neighborhood(i, len(n), radius) if j != i]
    if not idx:
        return np.array([], dtype=np.int64), np.array([]), "closest-rate"

    s_i = steal_rate_radius(i, n, t, radius)
    s_j = np.array([steal_rate_radius(j, n, t, radius) for j in idx])
    has_tasks = queued[idx] > 0.0

    surplus = (s_j < 0.0) & has_tasks
    if surplus.any():
        cand = np.asarray(idx, dtype=np.int64)[surplus]
        volume = -s_j[surplus]
        mismatch = np.abs(volume - max(s_i, 0.0))
        w = volume / (1.0 + mismatch)
        return cand, w / w.sum(), "closest-rate"

    # In-pair fallback: the subsystem looks balanced yet queues are non-empty.
    pair = np.array(
        [pair_steal_rate(n[i], t[i], n[j], t[j]) for j in idx], dtype=np.float64
    )
    good = (pair > 0.0) & has_tasks
    if not good.any():
        return np.array([], dtype=np.int64), np.array([]), "in-pair"
    cand = np.asarray(idx, dtype=np.int64)[good]
    w = pair[good]
    return cand, w / w.sum(), "in-pair"


def select_victim(
    rng: np.random.Generator,
    i: int,
    n: Sequence[float],
    t: Sequence[float],
    queued: Sequence[float],
    radius: int,
) -> tuple[int | None, str]:
    """Sample a victim for thief ``i`` (§2.2.2); None if no viable victim."""
    cand, w, crit = victim_weights(i, n, t, queued, radius)
    if len(cand) == 0:
        return None, crit
    return int(rng.choice(cand, p=w)), crit


@dataclass(frozen=True)
class StealDecision:
    """A fully-resolved steal: victim and integer task count."""

    victim: int
    amount: int
    criterion: str


def tail_steal_amount(
    q_thief: float,
    t_thief: float,
    q_victim: float,
    t_victim: float,
    *,
    open_arrival: bool = False,
) -> int:
    """γ-optimal steal count on REMAINING work (the §2.2 'final stages' rule).

    Minimises ``max((q_v - k)·t_v, (q_i + k)·t_i)`` over integer k — the pair
    makespan from *now* — and returns k only if it strictly beats k = 0.
    Used when the thief is (nearly) idle: it prevents a fast process from
    idling while a slow one still holds queued tasks, and conversely returns
    0 when a slow thief would only stretch the pair makespan.

    ``open_arrival``: under open arrivals (tasks injected while the system
    runs) the closed-workload tie-break inverts for an EMPTY thief.  In a
    closed run a tie steal is pointless churn ("slow processes cannot steal
    at the end"); in an open run the victim's queue depth q_v excludes the
    task it is currently executing, so a tied γ still leaves the stolen task
    waiting behind the victim's in-flight work while the thief idles — a pure
    per-task latency loss.  An idle (q_i = 0) thief therefore accepts ties
    (k ≥ 1 whenever γ(k) ≤ γ(0)), which is what keeps freshly injected tasks
    from being stranded on a busy worker's deque.
    """
    if q_victim < 1.0:
        return 0
    k_star = (q_victim * t_victim - q_thief * t_thief) / max(
        t_thief + t_victim, _EPS
    )
    best_k, best_g = 0, max(q_victim * t_victim, q_thief * t_thief)
    for k in {math.floor(k_star), math.ceil(k_star), 1}:
        k = int(min(max(k, 0), q_victim))
        g = max((q_victim - k) * t_victim, (q_thief + k) * t_thief)
        if g < best_g - 1e-12 or (g == best_g and k < best_k):
            best_k, best_g = k, g
    if open_arrival and best_k == 0 and q_thief < 1.0:
        # Accept a tie: one task moves to the idle thief if that does not
        # strictly worsen the pair bound (it starts immediately instead of
        # queueing behind the victim's in-flight task).
        g1 = max((q_victim - 1.0) * t_victim, (q_thief + 1.0) * t_thief)
        if g1 <= best_g + 1e-12:
            return 1
    return best_k


def plan_steal(
    rng: np.random.Generator,
    i: int,
    n: Sequence[float],
    t: Sequence[float],
    queued: Sequence[float],
    radius: int,
    idle: bool = False,
    open_arrival: bool = False,
) -> StealDecision | None:
    """End-to-end smart-stealing decision for thief ``i`` (Alg. 1 lines 4-6).

    Computes S_i (Eq. 5), selects a victim (§2.2.2), rounds with γ (Eq. 7) and
    clamps to the victim's queued tasks.  Returns None when i should not steal.

    ``idle``: the thief's deque is (nearly) empty.  Preemptive stealing
    (S_i > 0 on TOTAL task counts, Eqs. 4-8) is the primary mechanism; a
    (nearly) idle thief additionally applies the remaining-work γ tail rule
    (``tail_steal_amount``) so that (a) fast processes never idle while slow
    ones hold queued tasks (the paper's "final stages" behaviour) and (b)
    the §2.1 relay works — an intermediary with S_i <= 0 still pulls tasks
    across the ring when that strictly reduces the pair makespan, letting a
    distant fast process re-steal them.

    ``open_arrival``: the workload is open (tasks keep arriving while the
    scheduler runs, DESIGN.md §Open-arrival).  The paper's cumulative totals
    ``n_j`` (executed + available, §2.2) are meaningless as a balance target
    when the ground keeps shifting, so Eq. 5 is evaluated on the
    INSTANTANEOUS queue depths instead: ``S_i = Q_sub/(t_i·T_sub) − q_i`` is
    the fair share of the *remaining* work in the radius-R window.  Callers
    must then pass reported depths via ``queued`` (no elapsed-time
    extrapolation — depth both drains and refills under arrivals) and the
    tail rule runs in its latency-oriented tie-accepting form.
    """
    n = np.asarray(n, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    queued = np.asarray(queued, dtype=np.float64)
    if open_arrival:
        # Fair-share balance on remaining work: depths replace totals in
        # Eqs. 4-8; the γ-rounding already operates on "work after the
        # steal", which is exactly the depth semantics.
        n = queued
    s_i = steal_rate_radius(i, n, t, radius)
    if s_i > 0.0:
        victim, crit = select_victim(rng, i, n, t, queued, radius)
        if victim is not None:
            if crit == "in-pair":
                s = pair_steal_rate(
                    float(n[i]), float(t[i]), float(n[victim]), float(t[victim])
                )
            else:
                s = min(s_i, -steal_rate_radius(victim, n, t, radius))
            if s > 0.0:
                amount = round_steal_rate(
                    s, float(n[i]), float(t[i]), float(n[victim]), float(t[victim])
                )
                amount = int(min(amount, queued[victim]))
                if amount >= 1:
                    return StealDecision(victim=victim, amount=amount, criterion=crit)

    # Tail rule: γ on remaining (queued) work against a probabilistically
    # chosen loaded victim.  This is the "final stages" behaviour of §2.2 —
    # a fast process must not idle while a slower one holds queued tasks.
    #
    # Guards: (a) victim queue estimates are FLOORED — tasks are integral,
    # and a fractional estimate (q=1.04) must not let a thief see a strict
    # γ-improvement where the true comparison is a tie (this enforces the
    # paper's "slow processes cannot steal at the end"); (b) a BUSY thief may
    # only tail-steal from victims at most as fast as itself — a pairwise
    # improvement that parks work on a slow node is a global regression
    # (other fast thieves would have drained that victim).  Idle thieves are
    # exempt from (b): that is the §2.1 relay (γ still protects the pair).
    window = [j for j in neighborhood(i, len(n), radius) if j != i]
    loaded = [
        j for j in window
        if math.floor(queued[j]) >= 1 and (idle or t[i] <= t[j])
    ]
    if not loaded:
        return None
    w = np.array([queued[j] * t[j] for j in loaded], dtype=np.float64)
    victim = int(rng.choice(loaded, p=w / w.sum()))
    amount = tail_steal_amount(
        float(queued[i]), float(t[i]),
        float(math.floor(queued[victim])), float(t[victim]),
        open_arrival=open_arrival,
    )
    if amount < 1:
        return None
    return StealDecision(victim=victim, amount=amount, criterion="tail")

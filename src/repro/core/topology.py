"""Network topology: transfer-cost model for pricing steals (DESIGN.md
§Topology plane).

Both planes priced a steal as if moving loot were free — victim selection
(Eq. 5, and the PR-4 work-weighted overlay) maximizes work-gained with
zero transfer cost, so at scale a thief happily strips a victim three
hops away over an equally-loaded neighbour.  A :class:`Topology` maps a
directed worker pair to the cost, in seconds, of moving ``ntasks`` tasks
across the link:

    cost(src, dst, ntasks) = latency(src, dst) + ntasks · per_task(src, dst)

with ``cost(i, i, ·) = 0`` (loot never leaves the node).  The scheduler
consumes this through one hook — ``PolicyView.transfer_cost(j, ntasks)``
— threaded from here through victim selection (distance-penalized
weights), plan pricing (net-negative steals refused), and the loot path
(the whole batch moves as ONE priced transfer; the plan's ``delay``
carries the price, so the threaded pool clock-paces it and the simulator
lands the loot ``cost`` virtual seconds later, overlapped with thief
compute).

``contention`` is a simple scalar knob consumed by the SIMULATOR only:
after a transfer starts on a directed link, the link stays busy for
``cost · contention`` seconds and later transfers on the same link queue
behind it (0 = infinite parallel capacity, 1 = full serialization).
The threaded plane and plan-time pricing always use the uncontended
cost — see the honest caveat in DESIGN.md §Topology plane.

``topology=None`` everywhere means "no network model": the scheduler is
bit-for-bit the zero-cost scheduler.  A link the model prices at 0.0 is
likewise charged the plane's DEFAULT transport cost (the simulator's
``steal_latency``/``steal_per_task``), not zero — so the all-zero
topology is also bit-for-bit the no-model scheduler, which is what the
conformance property in ``tests/test_topology.py`` pins.
"""

from __future__ import annotations

import math
from typing import Callable, Sequence

import numpy as np

__all__ = ["Topology", "parse_topology"]


def _as_cell_fn(cells) -> Callable[[int], int]:
    """Normalize a cell description into ``worker -> cell id`` (-1 = unknown).

    Accepts a ``CellMap`` (anything with ``cell_of``), a callable, or an
    explicit per-worker sequence of cell ids.  Unknown workers (elastic
    joiners beyond what the description covers) map to -1, which the
    two-level cost model prices as CROSS-cell — the conservative default
    for a worker whose placement the model hasn't been told about.
    """
    if hasattr(cells, "cell_of"):
        cmap = cells

        def fn(g: int) -> int:
            try:
                return int(cmap.cell_of(int(g)))
            except (KeyError, IndexError, ValueError):
                return -1

        return fn
    if callable(cells):
        inner = cells

        def fn(g: int) -> int:
            try:
                return int(inner(int(g)))
            except (KeyError, IndexError, ValueError):
                return -1

        return fn
    table = [int(c) for c in cells]

    def fn(g: int) -> int:
        return table[g] if 0 <= g < len(table) else -1

    return fn


class Topology:
    """Directed transfer-cost model over worker pairs.

    ``latency``/``per_task`` are ``(src, dst) -> seconds`` callables; use
    the builders (:meth:`uniform`, :meth:`two_level`, :meth:`fat_tree`,
    :meth:`from_matrix`) rather than constructing directly.  The model
    must accept ANY non-negative worker id — elastic pools grow past the
    boot membership, and each builder documents its out-of-range rule.
    """

    __slots__ = ("_latency", "_per_task", "contention", "name")

    def __init__(
        self,
        latency: Callable[[int, int], float],
        per_task: Callable[[int, int], float],
        *,
        contention: float = 0.0,
        name: str = "custom",
    ) -> None:
        if not (contention >= 0.0 and math.isfinite(contention)):
            raise ValueError("contention must be finite and >= 0")
        self._latency = latency
        self._per_task = per_task
        self.contention = float(contention)
        self.name = name

    # ------------------------------------------------------------------ cost
    def cost(self, src: int, dst: int, ntasks: int = 1) -> float:
        """Seconds to move ``ntasks`` tasks from ``src`` to ``dst``
        (uncontended).  Zero for a local move."""
        if src == dst:
            return 0.0
        lat = float(self._latency(src, dst))
        per = float(self._per_task(src, dst))
        return max(lat, 0.0) + max(int(ntasks), 0) * max(per, 0.0)

    def add_per_task(self, extra: float, name: str | None = None) -> "Topology":
        """A new topology with ``extra`` seconds folded into every remote
        per-task cost — how ``ServePool`` prices per-request migration
        (warm-state loss rides the same hook as the network)."""
        if not (extra >= 0.0 and math.isfinite(extra)):
            raise ValueError("extra per-task cost must be finite and >= 0")
        base = self._per_task
        return Topology(
            self._latency,
            lambda s, d: float(base(s, d)) + extra,
            contention=self.contention,
            name=name or f"{self.name}+migration",
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Topology({self.name}, contention={self.contention})"

    # -------------------------------------------------------------- builders
    @classmethod
    def uniform(
        cls,
        latency: float = 0.0,
        per_task: float = 0.0,
        *,
        contention: float = 0.0,
    ) -> "Topology":
        """Every distinct pair costs the same — a flat switch.  Any worker
        id is valid, so elastic growth needs no special casing."""
        return cls(
            lambda s, d: latency,
            lambda s, d: per_task,
            contention=contention,
            name="uniform",
        )

    @classmethod
    def two_level(
        cls,
        cells,
        *,
        intra_latency: float = 0.0,
        intra_per_task: float = 0.0,
        cross_latency: float = 0.0,
        cross_per_task: float = 0.0,
        contention: float = 0.0,
    ) -> "Topology":
        """Two tiers matching the PR-6 hierarchy: cheap intra-cell links,
        expensive cross-cell links.  ``cells`` is a ``CellMap``, a
        ``worker -> cell`` callable, or an explicit per-worker cell-id
        sequence; workers the description doesn't cover price as
        CROSS-cell (conservative for elastic joiners)."""
        cell_of = _as_cell_fn(cells)

        def same(s: int, d: int) -> bool:
            cs, cd = cell_of(s), cell_of(d)
            return cs >= 0 and cs == cd

        return cls(
            lambda s, d: intra_latency if same(s, d) else cross_latency,
            lambda s, d: intra_per_task if same(s, d) else cross_per_task,
            contention=contention,
            name="two_level",
        )

    @classmethod
    def fat_tree(
        cls,
        k: int,
        *,
        hop_latency: float = 0.0,
        hop_per_task: float = 0.0,
        contention: float = 0.0,
    ) -> "Topology":
        """k-ary fat-tree (k³/4 hosts): cost scales with the standard hop
        count — 2 hops within an edge group (k/2 hosts), 4 within a pod
        (k²/4 hosts), 6 across pods.  Worker ids beyond k³/4 wrap modulo
        the host count (elastic joiners reuse physical slots)."""
        if k < 2 or k % 2:
            raise ValueError("fat_tree needs an even k >= 2")
        half = k // 2
        per_pod = half * half
        hosts = per_pod * k

        def hops(s: int, d: int) -> int:
            s, d = s % hosts, d % hosts
            if s == d:
                return 0
            if s // half == d // half:
                return 2  # same edge switch
            if s // per_pod == d // per_pod:
                return 4  # same pod, via aggregation
            return 6  # via core

        return cls(
            lambda s, d: hops(s, d) * hop_latency,
            lambda s, d: hops(s, d) * hop_per_task,
            contention=contention,
            name=f"fat_tree(k={k})",
        )

    @classmethod
    def from_matrix(
        cls,
        latency: Sequence[Sequence[float]] | np.ndarray,
        per_task: Sequence[Sequence[float]] | np.ndarray | None = None,
        *,
        contention: float = 0.0,
    ) -> "Topology":
        """Explicit (P, P) cost matrices — measured or synthesized.  A
        worker beyond the matrix prices at the matrix MAXIMUM (an
        unmodelled joiner is assumed far)."""
        lat = np.asarray(latency, dtype=np.float64)
        if lat.ndim != 2 or lat.shape[0] != lat.shape[1]:
            raise ValueError("latency must be a square (P, P) matrix")
        per = (
            np.zeros_like(lat)
            if per_task is None
            else np.asarray(per_task, dtype=np.float64)
        )
        if per.shape != lat.shape:
            raise ValueError("per_task must match the latency matrix shape")
        p = lat.shape[0]
        lat_far = float(lat.max()) if p else 0.0
        per_far = float(per.max()) if p else 0.0

        def pick(m: np.ndarray, far: float, s: int, d: int) -> float:
            if 0 <= s < p and 0 <= d < p:
                return float(m[s, d])
            return far

        return cls(
            lambda s, d: pick(lat, lat_far, s, d),
            lambda s, d: pick(per, per_far, s, d),
            contention=contention,
            name="matrix",
        )


def parse_topology(spec: str | None, num_workers: int) -> Topology | None:
    """CLI string -> Topology (``launch.serve --topology``).

    Forms (all costs in seconds): ``none``; ``uniform:LAT:PER_TASK``;
    ``two-level:K:INTRA:CROSS`` (K equal contiguous cells, latency-only
    tiers); ``fat-tree:K:HOP`` (per-hop latency).
    """
    if spec is None or spec in ("", "none"):
        return None
    parts = spec.split(":")
    kind = parts[0]
    try:
        if kind == "uniform":
            lat = float(parts[1]) if len(parts) > 1 else 0.0
            per = float(parts[2]) if len(parts) > 2 else 0.0
            return Topology.uniform(lat, per)
        if kind in ("two-level", "two_level"):
            k = int(parts[1]) if len(parts) > 1 else max(1, round(math.sqrt(num_workers)))
            intra = float(parts[2]) if len(parts) > 2 else 0.0
            cross = float(parts[3]) if len(parts) > 3 else 10 * intra
            size = max(1, -(-num_workers // max(k, 1)))  # ceil
            return Topology.two_level(
                lambda g: g // size, intra_latency=intra, cross_latency=cross
            )
        if kind in ("fat-tree", "fat_tree"):
            k = int(parts[1]) if len(parts) > 1 else 4
            hop = float(parts[2]) if len(parts) > 2 else 0.0
            return Topology.fat_tree(k, hop_latency=hop)
    except (ValueError, IndexError) as e:
        raise ValueError(f"bad --topology spec {spec!r}") from e
    raise ValueError(f"unknown --topology kind {kind!r}")

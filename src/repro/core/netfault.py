"""Network-fault plane: lossy links, partitions, leases (DESIGN.md §Fault fabric).

The topology plane (PR 7) made the fabric *slow* — every steal pays a
modeled fare — but never *lossy*: each probe and each loot transfer was
assumed to arrive.  This module drops that assumption.  A
:class:`NetFaultSchedule` is a scriptable description of network faults,
injected identically into both execution planes exactly like
``SlowdownSchedule`` (the straggler plane, DESIGN.md §Straggler plane):

* :class:`LinkFault` — a timed window during which a directed link (or a
  wildcard set of links) drops each message with probability ``drop_prob``
  and/or delays it by ``extra_delay`` seconds.

* :class:`PartitionEvent` — a timed split of the worker set: every link
  crossing the cut is *down* (deterministically unreachable, not merely
  lossy) until the partition heals.

The schedule is a pure function of plane time — ``drop_prob(src, dst, t)``,
``extra_delay(src, dst, t)``, ``reachable(src, dst, t)`` — so the
discrete-event simulator evaluates it at virtual time and the threaded
pool at ``clock() - t0``, with no hidden state.

Hardening state lives in :class:`LinkHealth`: a per-(thief, victim)
success EWMA (the link analogue of PR 5's per-worker limp detector) plus
a consecutive-failure capped exponential backoff.  Victim weights are
multiplied by the health factor, a blocked link weighs 0, and the
``health_floor`` keeps flaky links sampled occasionally (the probation
canary analogue) so they can recover.

RNG discipline (DESIGN.md §Conformance): fault rolls come from a
DEDICATED generator seeded off the main seed, and every roll is gated on
``drop_prob > 0`` — an empty schedule consumes no randomness and every
health factor stays 1.0, so ``NetFaultSchedule()`` reproduces the
fault-free scheduler bit for bit, rng stream included.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable

__all__ = [
    "LinkFault",
    "PartitionEvent",
    "NetFaultSchedule",
    "LinkHealth",
    "parse_netfaults",
]

# Seed perturbation for the dedicated fault rng (golden-ratio constant —
# any fixed odd-ish constant works; it only has to decorrelate the fault
# stream from the scheduler stream for every base seed).
NF_SEED_SALT = 0x9E3779B9


@dataclass(frozen=True)
class LinkFault:
    """One timed lossy-link window.

    ``src``/``dst`` of ``None`` are wildcards (any sender / any
    receiver); links are DIRECTED, so a symmetric fault needs two
    entries or double wildcards.  ``drop_prob`` is the per-message drop
    probability while the window is active; ``extra_delay`` is added to
    the transport time of messages that do get through.
    """

    src: int | None = None
    dst: int | None = None
    start: float = 0.0
    duration: float = math.inf
    drop_prob: float = 0.0
    extra_delay: float = 0.0

    def __post_init__(self) -> None:
        if not 0.0 <= self.drop_prob <= 1.0:
            raise ValueError(f"drop_prob must be in [0,1], got {self.drop_prob}")
        if self.extra_delay < 0.0 or self.duration < 0.0:
            raise ValueError("extra_delay and duration must be >= 0")

    @property
    def end(self) -> float:
        return self.start + self.duration

    def matches(self, src: int, dst: int, t: float) -> bool:
        return (
            (self.src is None or self.src == src)
            and (self.dst is None or self.dst == dst)
            and self.start <= t < self.end
        )


@dataclass(frozen=True)
class PartitionEvent:
    """A timed network partition: ``side`` vs everyone else.

    While active, every directed link with exactly one endpoint in
    ``side`` is down — messages across the cut are lost with certainty
    and both components must degrade gracefully.  Links within either
    component are untouched.  The partition heals at ``start +
    duration`` and both sides reconcile (ring resync, backoff reset).
    """

    side: tuple[int, ...]
    start: float
    duration: float = math.inf
    # Cached frozenset view of ``side`` for O(1) membership.
    _side_set: frozenset = field(init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        if self.duration < 0.0:
            raise ValueError("duration must be >= 0")
        object.__setattr__(self, "side", tuple(int(w) for w in self.side))
        object.__setattr__(self, "_side_set", frozenset(self.side))

    @property
    def end(self) -> float:
        return self.start + self.duration

    def separates(self, src: int, dst: int, t: float) -> bool:
        if not self.start <= t < self.end:
            return False
        return (src in self._side_set) != (dst in self._side_set)


@dataclass(frozen=True)
class NetFaultSchedule:
    """Scriptable network-fault plane + hardening knobs.

    An EMPTY schedule (no faults, no partitions) is the identity: it is
    property-tested bit-for-bit equal to ``netfaults=None`` in both
    planes (tests/test_netfault.py), mirroring ``SlowdownSchedule()``
    and ``Topology.uniform(0, 0)``.

    Hardening knobs (all consumed by the schedulers, not the schedule):

    * ``lease_timeout`` — a loot transfer is a LEASED two-phase move:
      the thief claims tasks under a lease; if the transfer is dropped
      (or the thief dies mid-flight), the lease expires after this many
      seconds and the tasks return to the victim.  No task is ever
      lost; the cost of an expiry is one lease_timeout of added latency
      for the leased tasks.
    * ``attempt_timeout`` — how long a threaded thief stalls on a
      request that went unanswered (the sim charges its retry path).
    * ``backoff_base`` / ``backoff_cap`` — consecutive failures on a
      (thief, victim) link block it for ``base·2^(k-1)`` seconds,
      capped.
    * ``health_alpha`` / ``health_floor`` — link-health EWMA step and
      the minimum sampling weight for an unblocked flaky link (the
      probation-canary analogue: a floored link still gets the odd
      probe, so a healed link recovers its weight).
    * ``stale_after`` — seconds of heartbeat silence over a CUT link
      before the observer treats the peer as unreachable in its own
      view row (t̂ inflation + limp flag, PR 7's staleness path).  This
      is observer-local: the peer's own side never flags it.
    * ``hardened`` — the ablation switch.  ``False`` turns leases,
      backoff and health-weighting OFF: a dropped transfer loses its
      loot (simulator counts it in ``lost``), a dropped request is just
      a failed steal.  Exists to measure what the hardening buys
      (benchmarks/netfault.py).
    """

    faults: tuple[LinkFault, ...] = ()
    partitions: tuple[PartitionEvent, ...] = ()
    lease_timeout: float = 0.25
    attempt_timeout: float = 0.01
    backoff_base: float = 0.05
    backoff_cap: float = 2.0
    health_alpha: float = 0.4
    health_floor: float = 0.05
    stale_after: float = 1.0
    hardened: bool = True

    def __post_init__(self) -> None:
        object.__setattr__(self, "faults", tuple(self.faults))
        object.__setattr__(self, "partitions", tuple(self.partitions))
        for name in ("lease_timeout", "attempt_timeout", "backoff_base",
                     "backoff_cap", "health_floor", "stale_after"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0")
        if not 0.0 < self.health_alpha <= 1.0:
            raise ValueError("health_alpha must be in (0,1]")

    # -- pure queries (plane-time functions) ---------------------------------

    def drop_prob(self, src: int, dst: int, t: float) -> float:
        """Per-message drop probability on src→dst at plane time t.

        Multiple overlapping faults compose complementarily (the message
        must survive every active fault): ``1 - Π(1 - p_k)``.  A self-link
        is always clean — local hand-offs never touch the network.
        """
        if src == dst:
            return 0.0
        keep = 1.0
        for f in self.faults:
            if f.drop_prob > 0.0 and f.matches(src, dst, t):
                keep *= 1.0 - f.drop_prob
        return 1.0 - keep

    def extra_delay(self, src: int, dst: int, t: float) -> float:
        """Added transport delay (seconds) on src→dst at plane time t
        (0.0 on a self-link — local hand-offs never touch the network)."""
        if src == dst:
            return 0.0
        d = 0.0
        for f in self.faults:
            if f.extra_delay > 0.0 and f.matches(src, dst, t):
                d += f.extra_delay
        return d

    def reachable(self, src: int, dst: int, t: float) -> bool:
        """False iff some active partition separates src from dst."""
        if src == dst:
            return True
        return not any(p.separates(src, dst, t) for p in self.partitions)

    def unreachable_since(self, src: int, dst: int, t: float) -> float:
        """Start time of the earliest active partition cutting src→dst.

        ``math.inf`` when the pair is reachable — so
        ``min(heartbeat, unreachable_since(...))`` is the identity on a
        healthy link (the PR-7 staleness path needs no special case).
        """
        cut = math.inf
        if src == dst:
            return cut
        for p in self.partitions:
            if p.separates(src, dst, t):
                cut = min(cut, p.start)
        return cut

    def heal_times(self) -> tuple[float, ...]:
        """Sorted finite partition-heal instants (for reconciliation)."""
        return tuple(sorted({p.end for p in self.partitions if math.isfinite(p.end)}))

    def lossy(self) -> bool:
        """True if the schedule can ever drop/delay/cut anything."""
        return bool(self.partitions) or any(
            f.drop_prob > 0.0 or f.extra_delay > 0.0 for f in self.faults
        )

    def workers(self) -> set[int]:
        """Every worker index the schedule names (for validation)."""
        out: set[int] = set()
        for f in self.faults:
            for w in (f.src, f.dst):
                if w is not None:
                    out.add(int(w))
        for p in self.partitions:
            out.update(p.side)
        return out


class LinkHealth:
    """Per-(thief, victim) link-health EWMA + capped exponential backoff.

    The link analogue of PR 5's :class:`~repro.core.limp.LimpState`: a
    success EWMA tracks how often attempts over a link come back, k
    consecutive failures block the link for ``base·2^(k-1)`` seconds
    (capped), and the health factor multiplies the victim weight so the
    scheduler organically routes around flaky links.  An unblocked link
    never weighs less than ``health_floor`` — the canary: it still gets
    sampled occasionally, and one success resets the backoff, so a
    healed link earns its weight back instead of being blacklisted.

    Thread-safety: in the threaded plane each worker ``i`` only ever
    touches its own ``(i, ·)`` rows (single writer per key under the
    GIL); the simulator is single-threaded.
    """

    def __init__(self, cfg: NetFaultSchedule) -> None:
        self.cfg = cfg
        self._ewma: dict[tuple[int, int], float] = {}
        self._fails: dict[tuple[int, int], int] = {}
        self._blocked_until: dict[tuple[int, int], float] = {}

    def record(self, i: int, j: int, ok: bool, now: float) -> None:
        """Fold one attempt outcome over link i→j observed at ``now``."""
        a = self.cfg.health_alpha
        key = (i, j)
        h = self._ewma.get(key, 1.0)
        self._ewma[key] = (1.0 - a) * h + (a if ok else 0.0)
        if ok:
            self._fails[key] = 0
            self._blocked_until.pop(key, None)
        else:
            k = self._fails.get(key, 0) + 1
            self._fails[key] = k
            hold = min(self.cfg.backoff_base * (2.0 ** (k - 1)), self.cfg.backoff_cap)
            self._blocked_until[key] = now + hold

    def blocked(self, i: int, j: int, now: float) -> bool:
        return self._blocked_until.get((i, j), -math.inf) > now

    def factor(self, i: int, j: int, now: float) -> float:
        """Victim-weight multiplier in [0, 1] for thief i stealing from j.

        0.0 while the link is backed off; otherwise the success EWMA
        clamped up to ``health_floor``.  A never-observed link is 1.0,
        so an all-healthy fabric changes no weight (bit-for-bit
        conformance with the fault-free scheduler).
        """
        if self.blocked(i, j, now):
            return 0.0
        h = self._ewma.get((i, j))
        if h is None or h >= 1.0:
            return 1.0
        return max(h, self.cfg.health_floor)

    def clear_backoff(self, i: int | None = None) -> None:
        """Drop backoff blocks (all links, or thief ``i``'s links) on heal.

        The EWMA is kept — a healed partition says the CUT is gone, not
        that the link was never flaky; the floor + one success restore
        full weight quickly if it is in fact healthy.
        """
        if i is None:
            self._blocked_until.clear()
            self._fails.clear()
            return
        for key in [k for k in self._blocked_until if k[0] == i]:
            del self._blocked_until[key]
        for key in [k for k in self._fails if k[0] == i]:
            del self._fails[key]


def _parse_side(tok: str, num_workers: int) -> tuple[int, ...]:
    k = int(tok) if tok else max(num_workers // 2, 1)
    if not 0 < k < num_workers:
        raise ValueError(
            f"partition side size {k} must be in (0, {num_workers})"
        )
    return tuple(range(k))


def parse_netfaults(
    spec: str | None, num_workers: int
) -> NetFaultSchedule | None:
    """Parse a CLI ``--net-faults`` spec into a schedule.

    Forms (combinable with ``+``), mirroring ``parse_topology``:

    - ``none`` / empty  — no fault plane (returns None)
    - ``drop:PROB``  — every link drops each steal message w.p. PROB
    - ``delay:SEC``  — every message pays SEC extra transport seconds
    - ``partition:START:DUR[:K]`` — workers [0, K) cut off from the rest
      for DUR seconds starting at START (K defaults to half the pool)

    Example: ``drop:0.1+partition:10:30:8``.
    """
    if spec is None:
        return None
    spec = spec.strip().lower()
    if spec in ("", "none"):
        return None
    faults: list[LinkFault] = []
    partitions: list[PartitionEvent] = []
    for part in spec.split("+"):
        toks = part.strip().split(":")
        kind = toks[0]
        try:
            if kind == "drop":
                faults.append(LinkFault(drop_prob=float(toks[1])))
            elif kind == "delay":
                faults.append(LinkFault(extra_delay=float(toks[1])))
            elif kind == "partition":
                start, dur = float(toks[1]), float(toks[2])
                side = _parse_side(toks[3] if len(toks) > 3 else "", num_workers)
                partitions.append(
                    PartitionEvent(side=side, start=start, duration=dur)
                )
            else:
                raise ValueError(f"unknown net-fault kind {kind!r}")
        except (IndexError, ValueError) as e:
            raise ValueError(f"bad net-fault spec {part!r}: {e}") from None
    return NetFaultSchedule(faults=tuple(faults), partitions=tuple(partitions))


def validate_netfaults(
    sched: NetFaultSchedule | None, num_workers: int
) -> None:
    """Reject schedules naming workers outside [0, num_workers)."""
    if sched is None:
        return
    bad = [w for w in sched.workers() if not 0 <= w < num_workers]
    if bad:
        raise ValueError(
            f"net-fault schedule names workers {sorted(bad)} outside "
            f"[0, {num_workers})"
        )

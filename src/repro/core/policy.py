"""Pluggable scheduling policies — one worker-loop substrate, many schedulers.

The paper's contribution is a *policy* (adaptive victim selection + Eq. 4-7
steal sizing over the §2.1 info ring), not the plumbing around it.  This
module separates the two: a ``SchedPolicy`` decides *whether, whom and how
much to steal* at every task boundary, while the execution substrates — the
threaded ``repro.core.a2ws.WorkerPool`` (real time) and the discrete-event
``repro.core.simulator`` (virtual time) — own deques, clocks, termination and
telemetry.  The SAME policy objects drive both planes, so every policy is
measurable under closed batches and open arrivals, threaded and simulated,
with identical semantics (DESIGN.md §Policy layer).

Hook contract
-------------
``on_boundary(view) -> StealPlan | None`` is called at every task boundary
and idle tick with a :class:`PolicyView` of what the worker may legally know:
its info-ring estimates (ring policies), one-sided ground-truth depth reads
(``view.depth`` — an RMA head/tail snapshot costs one atomic in the paper's
protocol, so classical random stealing and token counts may use it), the
plane clock and the worker's rng.  Returning a plan asks the substrate to
execute the Fig. 3b steal; the substrate then reports the outcome through
``on_steal_result`` (the get-accumulate snapshot is knowledge the policy may
fold into its own state — Table 1 rows 2-3).

Policies must be thread-safe across workers in the threaded plane: any
cross-worker state (CTWS token, LW leader gate) takes an internal lock.
Policies must NOT keep per-plane state keyed on wall time — ``view.now`` is
the only clock, so the same object works under both real and virtual time.

Policies never touch task payloads.  Both substrates carry first-class
:class:`repro.core.deque.Task` records (or, in the simulator, the same
fields column-wise), but everything a policy sees is already aggregated
into the view — queue depths, per-class counts, work-second estimates.
SLO ordering (DESIGN.md §SLO serving) lives entirely at the OWNER end of
the deque: plans, victim selection and loot sizing are SLO-blind, which is
what keeps the no-SLO degenerate configuration bit-for-bit identical and
lets thief-end steals drain batch work preferentially with no policy
change.

Implementations
---------------
* :class:`A2WSPolicy`   — the paper: Eq. 5 steal rate over the radius-R info
  ring, §2.2.2 victim selection, γ-rounding, probe steals under open arrivals.
* :class:`CTWSPolicy`   — Assis et al. 2019: one token circulates the ring
  carrying the global count vector; only the holder steals (half of the most
  loaded victim), hop cost grows with P.
* :class:`LWPolicy`     — leader–workers: worker 0 co-hosts the central queue
  (its deque); everyone else requests one task at a time through a serialized
  leader gate (service time + request RTT); worker 0 runs slower by
  ``leader_overhead`` (the co-located distributor thread).
* :class:`RandomWSPolicy` — classical receiver-initiated random stealing
  (uniform victim, steal-half), the baseline of arXiv:2211.00838 /
  arXiv:1911.06714.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from .info_ring import CellDigest, CellMap, DigestBoard
from .steal import plan_steal

__all__ = [
    "StealPlan",
    "PolicyView",
    "SchedPolicy",
    "A2WSPolicy",
    "HierarchicalA2WSPolicy",
    "CTWSPolicy",
    "LWPolicy",
    "RandomWSPolicy",
    "POLICIES",
    "make_policy",
]


@dataclass(frozen=True)
class StealPlan:
    """A resolved transfer request: take ``amount`` tasks from ``victim``.

    ``delay``: dispatch latency in seconds charged before the loot lands on
    the thief's deque (LW's leader round-trip).  0.0 means "use the plane's
    default transport cost" (none in the threaded plane, ``steal_latency`` in
    the simulator).

    ``work``: loot target in equivalent reference-class tasks (work-weighted
    mode, DESIGN.md §Work-weighted stealing) — a weighted substrate then
    executes the steal greedily by work, ``amount`` acting as the count
    estimate.  0.0 = count mode: take exactly ``amount`` tasks.
    """

    victim: int
    amount: int
    criterion: str = ""
    delay: float = 0.0
    work: float = 0.0


@dataclass
class PolicyView:
    """What one worker may legally know at a task boundary.

    Built by the substrate, consumed by the policy.  Ring estimates
    (``n_view``/``t_view``/``queued``) are the plane's *information model* —
    delayed, radius-limited, preemptively extrapolated — and are ``None`` for
    policies that declared ``uses_ring = False``.  ``depth``/``alive`` are
    one-sided ground-truth reads (one RMA atomic each in the paper's
    protocol).  ``rng`` is the plane's generator (per-worker when threaded,
    global when simulated) so decision sampling stays reproducible per plane.
    """

    worker: int
    now: float
    #: the worker's own deque is EMPTY — same strict meaning in both planes,
    #: so strict-idle policies (LW requests, random/probe steals) behave
    #: identically threaded and simulated
    idle: bool
    ran_any: bool
    open_arrival: bool
    radius: int
    num_workers: int
    rng: np.random.Generator
    window: list[int]
    depth: Callable[[int], int]
    alive: Callable[[int], bool]
    pending: Callable[[], int]
    n_view: np.ndarray | None = None
    t_view: np.ndarray | None = None
    queued: np.ndarray | None = None
    #: work-weighted overlay (DESIGN.md §Work-weighted stealing): when the
    #: substrate runs with cost classes, ``n_view``/``queued`` are measured
    #: in equivalent reference-class tasks, ``unit[j]`` is the mean work per
    #: queued task at j and ``qtasks[j]`` the actual task-count estimate
    #: (γ-rounding integrality + the Fig. 3b clamp).  None = count mode —
    #: the degenerate single-class case, bit-for-bit the old behaviour.
    unit: np.ndarray | None = None
    qtasks: np.ndarray | None = None
    #: pre-overlay n estimates in TASK COUNTS (weighted mode only) — the
    #: info board's n field is count-denominated, so Fig. 3b reconciliation
    #: must derive its executed estimate from these, never from the
    #: work-repriced ``n_view``
    ntasks: np.ndarray | None = None
    #: per-class relative costs ``rel[c]`` behind the overlay (weighted mode
    #: only) — the substrate prices individual loot with it when executing a
    #: plan greedily by work
    rel: np.ndarray | None = None
    #: delayed limp-flag plane (DESIGN.md §Straggler plane): ``limp[j]`` is
    #: True when worker j has FLAGGED ITSELF as limping (owner-side detector)
    #: and the flag has propagated to this worker's view — same delay model
    #: as the (n, t) cells it rides with.  None = detection disabled (the
    #: count-based ablation) — every policy then behaves bit-for-bit as
    #: before this plane existed.
    limp: np.ndarray | None = None
    #: tasks already stolen/granted but still in transit to THIS worker —
    #: nonzero only under the simulator (threaded transfers are synchronous);
    #: one-request-at-a-time policies gate on it to avoid duplicate requests
    inflight: Callable[[], int] = lambda: 0
    #: the plane's "(nearly) idle" signal for the A2WS tail rule
    #: (``plan_steal(idle=...)``): the threaded plane reports empty-deque,
    #: the simulator reports depth<=1 (at a finish event the next pop is
    #: imminent).  Plane-calibrated by design — A2WS's own semantics predate
    #: the policy layer and are preserved exactly.  None = same as ``idle``.
    near_idle: bool | None = None
    #: hierarchy scoping (DESIGN.md §Hierarchy): when the substrate scopes
    #: this view to one CELL, ``members[local_slot]`` is the GLOBAL worker id
    #: behind each local slot (``-1`` = migration hole) and every other field
    #: — ``worker``, ``radius``, ``num_workers``, ``window``, the ring arrays,
    #: the ``depth``/``alive`` wrappers — speaks LOCAL slot indices.  The
    #: policy must translate a plan's victim back to a global id before
    #: returning it.  None = an unscoped flat view (global ids throughout).
    members: np.ndarray | None = None
    #: per-class queue-count rows behind the weighted overlay (weighted mode
    #: only) — the leader's cell digest aggregates its per-class mix from it
    nc_view: np.ndarray | None = None
    #: network pricing (DESIGN.md §Topology plane): ``transfer_cost(j,
    #: ntasks)`` is the seconds it takes to move ``ntasks`` tasks from
    #: worker ``j`` to THIS worker.  ``j`` speaks the view's index space —
    #: LOCAL slots when the view is cell-scoped (the substrate's closure
    #: translates through ``members``).  None = no network model — every
    #: policy then behaves bit-for-bit as before this plane existed.
    transfer_cost: Callable[[int, int], float] | None = None
    #: link health (DESIGN.md §Fault fabric): ``link_health(j)`` ∈ [0, 1] is
    #: the victim-weight multiplier for stealing from ``j`` over the current
    #: fabric — 0.0 across an active partition or a backed-off flaky link,
    #: the per-link success EWMA (floor-clamped) otherwise.  ``j`` speaks the
    #: view's index space, like ``transfer_cost``.  None = no fault plane —
    #: every policy then behaves bit-for-bit as before this plane existed;
    #: an all-1.0 hook is equally bit-for-bit (the multiply is skipped).
    link_health: Callable[[int], float] | None = None


class SchedPolicy:
    """Base scheduling policy: hook defaults shared by all implementations."""

    name: str = "base"
    #: substrate builds the RingInfo board / delayed-view histories iff True
    uses_ring: bool = False
    #: open-arrival ``submit()`` routes here when set (LW's central queue);
    #: None = the substrate's default round-robin spray
    central: int | None = None
    #: hierarchy topology (DESIGN.md §Hierarchy): a :class:`CellMap` when the
    #: policy wants per-cell scoping — the substrate then builds one sub-board
    #: per cell and hands the policy CELL-scoped views (``view.members``
    #: non-None).  None = flat: one board, global views, exactly as before.
    cells: CellMap | None = None

    # ------------------------------------------------------------- lifecycle
    def partition(self, tasks: Sequence, num_workers: int) -> list[list]:
        """Initial task placement (§2.2.1 static block split by default)."""
        from .a2ws import partition_tasks

        return partition_tasks(tasks, num_workers)

    def on_start(self, depths: Sequence[int], now: float) -> None:
        """Substrate booted: initial per-worker queue depths."""

    def termination(self, now: float) -> None:
        """Quiescence reached: release any policy-held state (token waits,
        leader gates).  Purely a notification — the substrate's counters
        decide termination, the policy cannot veto it."""

    def bind_board(self, board) -> None:
        """The threaded substrate hands over its information board (a
        :class:`~repro.core.info_ring.CellBoard` when ``cells`` is set) so
        hierarchy policies can drive board-side membership changes (member
        migration).  The simulator never calls this — it has no board, so
        migrations there touch only the :class:`CellMap`."""

    def bind_topology(self, topology) -> None:
        """The substrate hands over its :class:`~repro.core.topology.Topology`
        when one is configured (DESIGN.md §Topology plane).  Per-boundary
        pricing flows through ``view.transfer_cost`` regardless; this hook
        exists for policy state that prices GLOBAL worker pairs outside any
        scoped view — the hierarchical leader balancer.  Default: ignore."""

    # -------------------------------------------------------------- stealing
    def on_boundary(self, view: PolicyView) -> StealPlan | None:
        raise NotImplementedError

    def on_steal_result(
        self, view: PolicyView, plan: StealPlan, got: int, left: int
    ) -> None:
        """Outcome of an executed plan: ``got`` tasks transferred, ``left``
        tasks observed remaining on the victim (get-accumulate snapshot)."""

    # ---------------------------------------------------------------- faults
    def on_worker_death(self, worker: int, now: float) -> None:
        """A worker tombstoned itself (its re-queued tasks stay stealable).
        Graceful retirement (``WorkerPool.retire_worker``) reports through
        the same hook — from the policy's perspective a drained leaver and a
        crashed member differ only in who rescued the queued tasks."""

    # ------------------------------------------------------------- elasticity
    def on_worker_join(self, worker: int, now: float) -> None:
        """A worker joined the LIVE pool (elastic scale-out, DESIGN.md
        §Elasticity).  ``worker`` is its ring position — either a brand-new
        index one past the previous ring size, or a previously tombstoned
        slot being replaced.  Called by both substrates BEFORE the joiner
        takes its first boundary, so any policy state sized on the worker
        count must be grown here."""

    # --------------------------------------------------------------- costing
    def task_multiplier(self, worker: int) -> float:
        """Execution-time inflation for ``worker`` (LW's co-located leader
        slows worker 0).  1.0 = run at native speed."""
        return 1.0


class A2WSPolicy(SchedPolicy):
    """The paper's adaptive smart stealing (§2.2) over the §2.1 info ring.

    Decision state lives entirely in the information plane the substrate
    provides (``n_view``/``t_view``/``queued``), so the object itself is
    stateless and trivially thread-safe.  ``probe``: under open arrivals an
    idle thief whose view went stale fires one speculative single-task steal
    per idle tick (DESIGN.md §Open-arrival); the get-accumulate doubles as a
    ground-truth depth read either way.

    Work-weighted when the substrate provides the overlay (``view.unit`` /
    ``view.qtasks`` non-None): Eq. 5, victim selection and γ-rounding then
    price queues in estimated work-seconds rather than task counts
    (DESIGN.md §Work-weighted stealing).  CTWS/LW/random deliberately stay
    count-based — they are the paper's baselines, and none of them consults
    the information ring the class estimates travel on.
    """

    name = "a2ws"
    uses_ring = True

    def __init__(self, probe: bool = True) -> None:
        self.probe = probe

    def on_boundary(self, view: PolicyView) -> StealPlan | None:
        near_idle = view.near_idle if view.near_idle is not None else view.idle
        if not near_idle and not view.ran_any:
            # Preemptive stealing starts at the first completed task
            # (Alg. 1 lines 3-9 gate); idle workers always try.
            return None
        if view.limp is not None and view.limp[view.worker]:
            # A flagged-limping worker never INITIATES steals: its collapsed
            # published t already blocks the loaded-victim tail rule, but
            # idle thieves are exempt from that rule (§2.1 relay) and the
            # probe path ignores t entirely — loot it pulled would execute
            # at the collapsed speed, the exact inversion of what the
            # re-pricing is draining.  Stolen-FROM it stays fully legal.
            return None
        decision = plan_steal(
            view.rng, view.worker, view.n_view, view.t_view, view.queued,
            view.radius, idle=near_idle, open_arrival=view.open_arrival,
            unit=view.unit, qtasks=view.qtasks,
            transfer_cost=view.transfer_cost,
            link_health=view.link_health,
        )
        if decision is None:
            return self._probe(view)
        # Topology pricing (DESIGN.md §Topology plane): the plan's ``delay``
        # carries the transfer cost of the whole batch — ONE priced transfer
        # of k tasks.  The threaded substrate clock-paces it, the simulator
        # lands the loot that many virtual seconds later (overlapped with
        # thief compute).  A free link leaves delay at 0.0, which both
        # planes read as "use the default transport cost".
        delay = 0.0
        if view.transfer_cost is not None:
            delay = max(
                float(view.transfer_cost(decision.victim, decision.amount)),
                0.0,
            )
        return StealPlan(
            decision.victim, decision.amount, decision.criterion,
            delay=delay, work=decision.work,
        )

    def on_worker_join(self, worker: int, now: float) -> None:
        """Nothing to grow: A2WS decision state lives in the information
        plane, and the substrate already recomputed the radius window and
        remapped the ring (``RingInfo.grow``).  The joiner's cells are NaN
        everywhere, so thieves price it by the §2.2.1 preemptive wall-time
        estimate until its first report propagates — exactly like boot."""

    def _probe(self, view: PolicyView) -> StealPlan | None:
        if not (self.probe and view.open_arrival):
            return None
        if view.depth(view.worker) > 0 or view.inflight() > 0:
            return None
        if view.pending() == 0:
            # Nothing queued or in flight anywhere — probing would only
            # churn atomics while the pool sits quiescent between waves.
            return None
        candidates = [
            j for j in view.window if j != view.worker and view.alive(j)
        ]
        if not candidates:
            return None
        if view.limp is not None:
            # Victim of choice: a limping peer's backlog is the worst-priced
            # work in the window — strip it first.  (The probe's uniform
            # draw is otherwise blind to t, so without this preference the
            # limper is probed no more often than a healthy node.)
            limping = [j for j in candidates if view.limp[j]]
            if limping:
                candidates = limping
        health = view.link_health
        hw = None
        if health is not None:
            # Link-health gating (DESIGN.md §Fault fabric): a probe over a
            # cut or backed-off link is a guaranteed miss — drop factor-0
            # candidates outright, bias the draw by the health EWMA of the
            # rest.  All-healthy factors (1.0) leave ``hw`` unset so the
            # draw below stays bit-for-bit the fault-free one.
            hf = [min(max(float(health(j)), 0.0), 1.0) for j in candidates]
            if any(f < 1.0 for f in hf):
                live = [(j, f) for j, f in zip(candidates, hf) if f > 0.0]
                if not live:
                    return None
                candidates = [j for j, _ in live]
                hw = np.array([f for _, f in live])
        tcost = view.transfer_cost
        costs = None
        if tcost is not None:
            costs = [max(float(tcost(j, 1)), 0.0) for j in candidates]
            if not any(c > 0.0 for c in costs):
                costs = None
        if costs is not None or hw is not None:
            # Distance/health-biased probe draw: a probe is speculative, so
            # spend it where the (single-task) transfer is cheap and the
            # link answers.  The all-zero-cost all-healthy case keeps the
            # unweighted rng.choice call — numpy's weighted draw consumes
            # the stream differently, and the identity model must stay
            # bit-for-bit unpriced.
            w = np.ones(len(candidates))
            if costs is not None:
                w *= np.array([1.0 / (1.0 + c) for c in costs])
            if hw is not None:
                w *= hw
            victim = int(view.rng.choice(candidates, p=w / w.sum()))
            delay = 0.0
            if costs is not None:
                delay = costs[candidates.index(victim)]
            return StealPlan(victim, 1, "probe", delay=delay)
        return StealPlan(int(view.rng.choice(candidates)), 1, "probe")


class HierarchicalA2WSPolicy(SchedPolicy):
    """Two-level A2WS (DESIGN.md §Hierarchy): K cells of ~ρ members, each
    running ordinary intra-cell A2WS on its own sub-board, plus a leader-level
    balancer over a K-wide digest plane.

    The substrate sees ``cells`` non-None and scopes every view to the
    worker's cell (``view.members`` carries the local→global mapping), so the
    per-boundary cost is O(ρ), not O(P).  Inside a cell the delegate
    :class:`A2WSPolicy` runs UNCHANGED — Eq. 5 radius, victim selection,
    γ-rounding, weighted overlay and limp re-pricing all scoped to ρ members.
    With ``num_cells=1`` the scoped view IS the flat view (identity mapping,
    same radius), the delegate consumes the rng identically, and the leader
    plane has no peers to balance against — K=1 is bit-for-bit the flat
    scheduler (property-tested).

    Leader plane: the first LIVE slot of each cell is its leader (leadership
    fails over automatically when that member dies).  At its own boundaries
    the leader (a) publishes a :class:`CellDigest` — aggregate queued
    work-seconds, task count, live membership, per-class mix, richest member
    — computed from its ordinary delayed intra-cell view, and (b) runs the
    balancer: when the richest peer cell's digest exceeds this cell's by more
    than ``band_hi`` × mean cell work (and this cell sits below the mean),
    the leader fires a batched inter-cell steal against that cell's richest
    member (half its queue, the get-accumulate clamp handles staleness).
    ``cooldown`` leader boundaries must pass between fires (loot needs time
    to land before re-judging), and the pressure counter resets once the gap
    falls under ``band_lo`` × mean — a hysteresis band, so digest noise
    cannot make leaders ping-pong loot.  When the gap persists for
    ``patience`` consecutive fires, the leader re-homes its last live
    follower INTO the rich cell (member migration — capacity moves to the
    work when loot-moving alone cannot keep up).

    Inter-cell loot lands on the leader's deque and is redistributed by
    ordinary intra-cell stealing; cross-cell ``record_remote`` is dropped by
    the :class:`~repro.core.info_ring.CellBoard` (digests, not cells, carry
    inter-cell knowledge).
    """

    name = "ha2ws"
    uses_ring = True

    def __init__(
        self,
        num_workers: int,
        num_cells: int | None = None,
        cell_size: int | None = None,
        cell_radius: int | None = None,
        probe: bool = True,
        band_hi: float = 0.5,
        band_lo: float = 0.15,
        cooldown: int = 3,
        patience: int = 12,
    ) -> None:
        self.cells = CellMap(
            num_workers, num_cells=num_cells, cell_size=cell_size,
            radius=cell_radius,
        )
        self.inner = A2WSPolicy(probe=probe)
        self.digests = DigestBoard(self.cells.num_cells)
        self.band_hi = float(band_hi)
        self.band_lo = float(band_lo)
        self.cooldown = int(cooldown)
        self.patience = int(patience)
        k = self.cells.num_cells
        self._cool = [0] * k   # leader boundaries left before the next fire
        self._lag = [0] * k    # consecutive fires with the gap still open
        self._lock = threading.Lock()
        self._board = None     # threaded CellBoard (bind_board); None in sim
        self._topology = None  # network pricing (bind_topology); None = free
        self.xcell_steals = 0  # telemetry: inter-cell steal plans fired
        self.xcell_moved = 0   # telemetry: member migrations executed
        self.xcell_refused = 0  # telemetry: fires refused as net-negative
        self.migrations: list[tuple[float, int, int, int]] = []

    # ------------------------------------------------------------- lifecycle
    def bind_board(self, board) -> None:
        self._board = board

    def bind_topology(self, topology) -> None:
        # The balancer prices GLOBAL pairs (leader <- rich cell's top
        # worker), which no cell-scoped view.transfer_cost can express.
        self._topology = topology

    def on_start(self, depths: Sequence[int], now: float) -> None:
        with self._lock:
            self.digests.reset()
            k = self.cells.num_cells
            self._cool = [0] * k
            self._lag = [0] * k
            self.xcell_steals = 0
            self.xcell_moved = 0
            self.xcell_refused = 0
            self.migrations = []

    def on_worker_join(self, worker: int, now: float) -> None:
        # Home the joiner (smallest live cell); idempotent for a recycled
        # tombstone slot, which keeps its cell.  The substrate grows the
        # cell's sub-board AFTER this hook returns.
        self.cells.assign(worker)

    # -------------------------------------------------------------- stealing
    def on_boundary(self, view: PolicyView) -> StealPlan | None:
        members = view.members
        if members is None:
            # Unscoped substrate (defensive): degrade to flat A2WS.
            return self.inner.on_boundary(view)
        cell = self.cells.cell_of(int(members[view.worker]))
        if self._leader_slot(view, members) == view.worker:
            # Leader duties consume NO rng — K=1 stays bit-for-bit flat.
            self._publish(view, cell, members)
            plan = self._balance(view, cell)
            if plan is not None:
                return plan
        plan = self.inner.on_boundary(view)
        if plan is None:
            return None
        victim = int(members[plan.victim])
        if victim < 0:
            return None  # raced a migration hole: skip this boundary
        return StealPlan(
            victim, plan.amount, plan.criterion, plan.delay, plan.work
        )

    @staticmethod
    def _leader_slot(view: PolicyView, members: np.ndarray) -> int:
        for jl in range(len(members)):
            if members[jl] >= 0 and view.alive(jl):
                return jl
        return -1

    def _publish(
        self, view: PolicyView, cell: int, members: np.ndarray
    ) -> None:
        m = len(members)
        n, t, q = view.n_view[:m], view.t_view[:m], view.queued[:m]
        live = np.fromiter(
            (members[jl] >= 0 and view.alive(jl) for jl in range(m)),
            dtype=bool, count=m,
        )
        # Tombstone/limp sentinels (t >= ~1e12) would explode the aggregate;
        # price unknown/dead cells at the median known rate instead.
        tt = np.where(np.isfinite(t) & (t < 1e11), t, np.nan)
        known = np.isfinite(tt)
        med = float(np.nanmedian(tt)) if known.any() else 1.0
        tt = np.where(known, tt, med)
        qq = np.where(live, np.maximum(q, 0.0), 0.0)
        work_j = qq * tt
        qt = view.qtasks[:m] if view.qtasks is not None else q
        tasks = float(np.where(live, np.maximum(qt, 0.0), 0.0).sum())
        top_worker, top_queued, top_work = -1, 0, 0.0
        cand = np.nonzero(live & (np.floor(qt) >= 1.0))[0]
        if cand.size:
            jl = int(cand[np.argmax(work_j[cand])])
            top_worker = int(members[jl])
            top_queued = int(qt[jl])
            top_work = float(qq[jl])
        mix = None
        if view.nc_view is not None:
            mix = view.nc_view[:m][live].sum(axis=0)
        self.digests.publish(CellDigest(
            cell, view.now, float(work_j.sum()), tasks, int(live.sum()),
            top_worker, top_queued, top_work, mix,
            leader=int(members[view.worker]),
        ))

    @staticmethod
    def _aged_work(d: CellDigest, now: float) -> float:
        """A digest's work estimate decayed to ``now``: each live member
        retires ~one second of (its own re-priced) work per second, so a
        stale digest is discounted by ``live × age`` — without this, a peer
        that published EARLIER always looks richer than a fresh self-digest
        and balanced pools ping-pong loot at boot."""
        return max(d.work - max(now - d.time, 0.0) * d.live, 0.0)

    def _balance(self, view: PolicyView, cell: int) -> StealPlan | None:
        own = self.digests.get(cell)
        peers = self.digests.peers(cell)
        if own is None or not peers:
            return None  # K=1, or no peer has published yet
        aged = [self._aged_work(d, view.now) for d in peers]
        vals = [own.work] + aged
        mean = sum(vals) / len(vals)
        if mean <= 0.0:
            return None
        ri = max(range(len(peers)), key=lambda k: aged[k])
        rich = peers[ri]
        gap = aged[ri] - own.work
        amount = max(1, rich.top_queued // 2)
        delay = 0.0
        with self._lock:
            if self._cool[cell] > 0:
                self._cool[cell] -= 1
            if gap <= self.band_lo * mean:
                self._lag[cell] = 0  # gap closed: release migration pressure
                return None
            if gap <= self.band_hi * mean or own.work >= mean:
                return None
            if rich.top_worker < 0 or rich.top_queued < 1:
                return None
            if self._topology is not None:
                # Cross-cell pricing (DESIGN.md §Topology plane): the batch
                # is net-negative when the work-seconds it moves don't beat
                # the link cost — the hysteresis band must not fire on a
                # steal the network would eat.  Refusal consumes no
                # cooldown: the band re-judges at the next leader boundary.
                delay = max(
                    float(self._topology.cost(
                        int(rich.top_worker), int(view.members[view.worker]),
                        amount,
                    )),
                    0.0,
                )
                if delay > 0.0:
                    per = rich.work / rich.tasks if rich.tasks >= 1.0 else 0.0
                    moved = (
                        rich.top_work / 2.0
                        if rich.top_work > 0.0
                        else amount * per
                    )
                    if not (moved > delay):
                        self.xcell_refused += 1
                        return None
            if self._cool[cell] > 0:
                return None
            self._cool[cell] = self.cooldown
            self._lag[cell] += 1
            self.xcell_steals += 1
            if self._lag[cell] >= self.patience:
                self._lag[cell] = 0
                mover = self._pick_migrant(view)
                if mover >= 0:
                    if self._board is not None:
                        self._board.migrate(mover, rich.cell)
                    else:
                        self.cells.migrate(mover, rich.cell)
                    self.xcell_moved += 1
                    self.migrations.append((view.now, mover, cell, rich.cell))
        work = rich.top_work / 2.0 if view.unit is not None else 0.0
        return StealPlan(
            rich.top_worker, amount, "x-cell", delay=delay, work=work
        )

    def _pick_migrant(self, view: PolicyView) -> int:
        """Last live follower of the leader's cell (never the leader itself
        — the cell keeps its digest publisher), or -1 when the leader is
        alone."""
        members = view.members
        for jl in range(len(members) - 1, -1, -1):
            if jl == view.worker:
                continue
            if members[jl] >= 0 and view.alive(jl):
                return int(members[jl])
        return -1


class CTWSPolicy(SchedPolicy):
    """Cyclic token-based work-stealing (Assis et al., 2019).

    One token circulates the ring carrying the global task-count vector;
    only the holder may steal (race/deadlock freedom by exclusivity), it
    steals HALF the most-loaded victim's tasks, and it steals only when its
    own deque is empty.  Busy holders forward the token at task boundaries.
    ``hop_time`` models the token transfer cost (it carries a P-sized
    vector, so real deployments scale it with P): the token is usable only
    ``hop_time`` seconds after the previous holder released it — a virtual
    gate that works identically under wall and simulated time.
    """

    name = "ctws"

    def __init__(self, num_workers: int, hop_time: float = 0.0) -> None:
        self.num_workers = num_workers
        self.hop_time = hop_time
        self.counts = np.zeros(num_workers, dtype=np.int64)
        self.token_at = 0
        self.token_ready = 0.0
        self._dead: set[int] = set()
        self._lock = threading.Lock()

    def on_start(self, depths: Sequence[int], now: float) -> None:
        with self._lock:
            # Reset circulation state so the same policy object can drive a
            # fresh run (HetDPTrainer builds one runtime per optimizer step).
            self.counts[: len(depths)] = depths
            self.token_at = 0
            self.token_ready = now + self.hop_time
            self._dead.clear()

    def _advance(self, now: float) -> None:
        # Pass the token, skipping tombstoned workers (a dead holder would
        # freeze the ring forever — the liveness hole of token schemes).
        for _ in range(self.num_workers):
            self.token_at = (self.token_at + 1) % self.num_workers
            if self.token_at not in self._dead:
                break
        self.token_ready = now + self.hop_time

    def on_boundary(self, view: PolicyView) -> StealPlan | None:
        i = view.worker
        with self._lock:
            if self.token_at != i or view.now < self.token_ready:
                return None
            my_depth = view.depth(i)
            self.counts[i] = my_depth
            # Tombstoned workers stop publishing, but their deques (holding
            # their re-queued tasks) stay readable — fold in ground truth so
            # orphaned work is rescued instead of stranded.
            for j in self._dead:
                self.counts[j] = view.depth(j)
            plan = None
            if my_depth == 0 and view.inflight() == 0:
                victim = int(np.argmax(self.counts))
                if victim != i and self.counts[victim] > 0:
                    plan = StealPlan(
                        victim, max(1, int(self.counts[victim]) // 2), "token"
                    )
            self._advance(view.now)
            return plan

    def on_steal_result(
        self, view: PolicyView, plan: StealPlan, got: int, left: int
    ) -> None:
        with self._lock:
            # The holder refreshes the vector entries it just learned
            # first-hand (the token carries them to everyone downstream).
            self.counts[plan.victim] = left
            self.counts[view.worker] = view.depth(view.worker)

    def on_worker_death(self, worker: int, now: float) -> None:
        with self._lock:
            self._dead.add(worker)
            self.counts[worker] = 0
            if self.token_at == worker:
                self._advance(now)

    def on_worker_join(self, worker: int, now: float) -> None:
        with self._lock:
            if worker >= self.num_workers:
                grown = np.zeros(worker + 1, dtype=np.int64)
                grown[: self.num_workers] = self.counts
                self.counts = grown
                self.num_workers = worker + 1
            # Un-skip: the slot re-enters the token rotation (``_advance``
            # hops over ``_dead`` members, so without this a replacement in
            # a tombstoned slot would never receive the token).
            self._dead.discard(worker)
            self.counts[worker] = 0


class LWPolicy(SchedPolicy):
    """Centralized leader–workers dynamic scheduling (paper §4 baseline).

    The central queue is worker 0's deque (the leader is co-located with
    worker 0, as in the paper): worker 0 pops it directly, every other worker
    requests ONE task at a time through the leader.  The leader is a serial
    server — each request waits for ``leader_free``, holds it for
    ``service_time`` and pays ``request_rtt`` on the wire — which reproduces
    the paper's congestion pathology as the worker count grows.  Worker 0
    additionally runs ``1 + leader_overhead`` slower (the co-located
    distributor thread steals its cycles, Fig. 5b).
    """

    name = "lw"
    central = 0

    def __init__(
        self,
        leader_overhead: float = 0.0,
        service_time: float = 0.0,
        request_rtt: float = 0.0,
    ) -> None:
        self.leader_overhead = leader_overhead
        self.service_time = service_time
        self.request_rtt = request_rtt
        self.leader_free = 0.0
        self._lock = threading.Lock()

    def partition(self, tasks: Sequence, num_workers: int) -> list[list]:
        # Everything starts on the central queue (worker 0's deque).
        out: list[list] = [[] for _ in range(num_workers)]
        out[0] = list(tasks)
        return out

    def on_start(self, depths: Sequence[int], now: float) -> None:
        with self._lock:
            self.leader_free = now  # fresh run: the leader starts idle

    def task_multiplier(self, worker: int) -> float:
        return 1.0 + self.leader_overhead if worker == 0 else 1.0

    def on_boundary(self, view: PolicyView) -> StealPlan | None:
        i = view.worker
        if not view.idle or view.inflight() > 0:
            # One outstanding request at a time (classical on-demand
            # dispatch — a worker never queues ahead at the leader).
            return None
        # Fault recovery: a tombstoned worker's re-queued tasks sit on its
        # own (still readable) deque — reclaim them before they strand.
        for j in range(view.num_workers):
            if j != i and not view.alive(j) and view.depth(j) > 0:
                return StealPlan(j, view.depth(j), "reclaim")
        if i == 0:
            # The leader's co-located worker has direct queue access; other
            # workers only request when they have nothing to run (classical
            # on-demand dispatch).
            return None
        if view.depth(0) == 0 or not view.alive(0):
            return None
        with self._lock:
            start = max(view.now + self.request_rtt / 2.0, self.leader_free)
            self.leader_free = start + self.service_time
            grant = self.leader_free + self.request_rtt / 2.0
        return StealPlan(0, 1, "leader", delay=max(grant - view.now, 0.0))

    def on_worker_join(self, worker: int, now: float) -> None:
        """Joiners become requesters: the central queue stays on worker 0
        and the new worker's first idle boundary sends it through the same
        serialized leader gate as everyone else — no policy state to grow."""


class RandomWSPolicy(SchedPolicy):
    """Classical receiver-initiated random work-stealing: an idle thief
    probes a uniformly random victim and steals HALF its queue (the baseline
    both arXiv:2211.00838 and arXiv:1911.06714 compare against).

    No information ring: the victim's depth comes from the one-sided
    head/tail snapshot (``view.depth`` — one RMA atomic), and victims are
    drawn over the WHOLE system, not a radius window.
    """

    name = "random"

    def on_boundary(self, view: PolicyView) -> StealPlan | None:
        if not view.idle or view.inflight() > 0:
            return None
        i = view.worker
        # Any non-empty deque is fair game — including a tombstoned worker's
        # (still readable, holding its re-queued tasks).
        loaded = [
            j for j in range(view.num_workers)
            if j != i and view.depth(j) > 0
        ]
        if not loaded:
            return None
        victim = int(view.rng.choice(loaded))
        return StealPlan(victim, max(1, view.depth(victim) // 2), "random-half")

    def on_worker_join(self, worker: int, now: float) -> None:
        """The victim set grows implicitly: every boundary draws uniformly
        over ``view.num_workers``, which the substrate already bumped."""


POLICIES = ("a2ws", "ha2ws", "ctws", "lw", "random")


def make_policy(spec: str | SchedPolicy, num_workers: int, **kw) -> SchedPolicy:
    """Resolve a policy spec (name or ready instance) to a policy object.

    Keyword arguments are forwarded to the named policy's constructor
    (``hop_time`` for ctws; ``leader_overhead``/``service_time``/
    ``request_rtt`` for lw) and must be empty for an instance spec.
    """
    if isinstance(spec, SchedPolicy):
        if kw:
            raise ValueError(
                f"policy kwargs {sorted(kw)} conflict with an instance spec"
            )
        return spec
    if spec == "a2ws":
        return A2WSPolicy(**kw)
    if spec == "ha2ws":
        return HierarchicalA2WSPolicy(num_workers, **kw)
    if spec == "ctws":
        return CTWSPolicy(num_workers, **kw)
    if spec == "lw":
        return LWPolicy(**kw)
    if spec == "random":
        return RandomWSPolicy(**kw)
    raise ValueError(f"unknown policy {spec!r}; known: {', '.join(POLICIES)}")

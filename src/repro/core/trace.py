"""Bursty diurnal arrival traces (DESIGN.md §SLO serving).

Serving workloads are neither the paper's closed batch nor a flat Poisson
stream: request rate swings sinusoidally over the day and flash crowds spike
it several-fold for minutes at a time.  :func:`diurnal_trace` generates a
seeded trace of exactly ``n`` arrival times from that non-homogeneous
Poisson process — sinusoidal base rate, Gaussian flash-crowd bumps — by
thinning (Lewis & Shedler): candidates stream from a homogeneous process at
the rate envelope's maximum and are accepted with probability
``rate(t)/rate_max``.  Everything is vectorised numpy; no per-request
Python objects are ever built, which is what lets the simulator replay
10^6+ requests (the arrays feed ``SimConfig.arrival_trace``/``slo_trace``
directly and the event loop streams them lazily).

Each request also gets an SLO class — latency (1) with probability
``latency_frac``, else batch (0) — matching ``core.deque``'s SLO_LATENCY /
SLO_BATCH encoding.

The on-disk trace format is a compressed ``.npz`` with two aligned arrays,
``arrival`` (float64 seconds, non-decreasing) and ``slo`` (int8 ∈ {0, 1});
``scripts/make_trace.py`` is the CLI front-end and ``benchmarks/slo_trace``
generates its workload through the same function.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["diurnal_trace", "load_trace", "save_trace"]


def _rate(
    t: np.ndarray,
    mean_rate: float,
    period: float,
    depth: float,
    spike_t: np.ndarray,
    spike_amp: float,
    spike_width: float,
) -> np.ndarray:
    """Instantaneous arrival rate: sinusoidal diurnal base + Gaussian
    flash-crowd bumps (additive, so overlapping crowds stack)."""
    r = mean_rate * (1.0 + depth * np.sin(2.0 * math.pi * t / period))
    for ts in spike_t:
        z = (t - ts) / spike_width
        r = r + mean_rate * spike_amp * np.exp(-0.5 * z * z)
    return r


def diurnal_trace(
    n: int,
    *,
    mean_rate: float = 100.0,
    period: float = 600.0,
    depth: float = 0.8,
    spikes: int = 3,
    spike_amp: float = 4.0,
    spike_width: float | None = None,
    latency_frac: float = 0.25,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Generate exactly ``n`` seeded arrivals of a bursty diurnal process.

    ``mean_rate`` requests/s around which the diurnal sinusoid of period
    ``period`` seconds swings by ``±depth``; ``spikes`` flash crowds of
    amplitude ``spike_amp × mean_rate`` and width ``spike_width`` (default
    period/40) land at seeded uniform times inside the trace's nominal
    span.  Returns ``(arrival, slo)``: float64 non-decreasing times and
    int8 SLO classes (latency with probability ``latency_frac``).
    """
    if n <= 0:
        raise ValueError("n must be > 0")
    if mean_rate <= 0.0 or period <= 0.0:
        raise ValueError("mean_rate and period must be > 0")
    if not 0.0 <= depth < 1.0:
        raise ValueError("depth must be in [0, 1) — the rate must stay > 0")
    if spikes < 0 or spike_amp < 0.0:
        raise ValueError("spikes and spike_amp must be >= 0")
    if not 0.0 <= latency_frac <= 1.0:
        raise ValueError("latency_frac must be in [0, 1]")
    w = period / 40.0 if spike_width is None else float(spike_width)
    if w <= 0.0:
        raise ValueError("spike_width must be > 0")

    rng = np.random.default_rng(seed)
    # Nominal span: solve ∫rate ≈ n (each Gaussian bump integrates to
    # amp·mean_rate·w·√(2π); the sinusoid integrates to ~mean_rate·T).
    bump_mass = spikes * spike_amp * mean_rate * w * math.sqrt(2.0 * math.pi)
    # Floor at half the no-spike span: flash crowds may steepen the trace
    # but must not collapse it into one long spike when n is small relative
    # to the bump mass.
    horizon = max((n - bump_mass) / mean_rate, 0.5 * n / mean_rate)
    spike_t = np.sort(rng.uniform(0.0, horizon, size=spikes))

    # Thinning envelope: exact maximum of the rate on a dense grid (bumps
    # can overlap, so no closed form), padded 0.1% — thinning only needs an
    # UPPER bound, a slack one just wastes candidates.
    grid = np.arange(0.0, horizon + period, w / 4.0)
    rate_max = float(
        _rate(grid, mean_rate, period, depth, spike_t, spike_amp, w).max()
    ) * 1.001

    out: list[np.ndarray] = []
    got = 0
    t = 0.0
    accept_est = max(mean_rate / rate_max, 0.05)
    while got < n:
        m = int((n - got) / accept_est * 1.2) + 64
        cand = t + np.cumsum(rng.exponential(1.0 / rate_max, size=m))
        t = float(cand[-1])
        keep = rng.random(m) * rate_max < _rate(
            cand, mean_rate, period, depth, spike_t, spike_amp, w
        )
        acc = cand[keep]
        out.append(acc)
        got += acc.size
    arrival = np.concatenate(out)[:n]
    slo = (rng.random(n) < latency_frac).astype(np.int8)
    return arrival, slo


def save_trace(path: str, arrival: np.ndarray, slo: np.ndarray) -> None:
    """Write a trace as compressed ``.npz`` (arrays ``arrival``, ``slo``)."""
    arrival = np.asarray(arrival, np.float64)
    slo = np.asarray(slo, np.int8)
    if arrival.shape != slo.shape or arrival.ndim != 1:
        raise ValueError("arrival and slo must be aligned 1-D arrays")
    np.savez_compressed(path, arrival=arrival, slo=slo)


def load_trace(path: str) -> tuple[np.ndarray, np.ndarray]:
    """Load a trace written by :func:`save_trace`; returns (arrival, slo)."""
    with np.load(path) as z:
        return (
            np.asarray(z["arrival"], np.float64),
            np.asarray(z["slo"], np.int8),
        )

"""Limited information communication over a bidirectional ring (paper §2.1).

Every process ``i`` owns an *information vector* holding, for each process
``j`` in its radius-R subsystem (Eq. 1: ``P_sub = 2R+1``), the pair
``(n_j, t_j)`` — total task count and mean task runtime — plus a freshness
flag (Table 1).

The paper's key trick is a **write partition** that makes one-sided ``Put``s
race-free without locks: in p_i's vector, positions ``i-R..i-1`` are written
only by the left neighbour p_{i-1}, position ``i`` only by p_i itself, and
positions ``i+1..i+R`` only by the right neighbour p_{i+1}.  Information about
process j therefore flows hop-by-hop away from j in both ring directions,
with exactly one writer per (vector, cell).

TPU/JAX adaptation: this module is the *host control plane* version — numpy
arrays in shared memory stand in for MPI RMA windows, and the single-writer
partition carries over verbatim (so no locks are needed here either, exactly
as in the paper).  The *device data plane* version — two ``lax.ppermute``s per
round — lives in ``repro.core.device_sched``.

Freshness flags are realised as **per-cell version counters** plus a private
``last_sent`` watermark per direction: ``dirty(cell, dir) == version[cell] >
last_sent[dir][cell]``.  This is equivalent to Table 1's boolean flags but
immune to the set/clear race a boolean would have with two writers, and it
gives staleness telemetry for free.
"""

from __future__ import annotations

import threading

import numpy as np

from .steal import neighborhood

__all__ = ["RingInfo", "CellMap", "CellDigest", "DigestBoard", "CellBoard"]


class RingInfo:
    """Shared information board for P processes with propagation radius R.

    Elastic membership (DESIGN.md §Elasticity): ``grow`` remaps the board to
    a larger ring.  New members start with ``n = 0, t = NaN, version = 0`` —
    exactly the boot state — so every thief's §2.2.1 preemptive wall-time
    estimate covers them until their first report propagates.  ``_epoch``
    serialises the whole-board swap against cell writes; it is NOT a cell
    lock (the §2.1 single-writer partition still makes individual Puts
    race-free) but an epoch guard so a writer never lands on a half-swapped
    board and per-cell versions stay monotone across growth.
    """

    def __init__(
        self, num_procs: int, radius: int, num_classes: int = 1
    ) -> None:
        if num_procs < 1:
            raise ValueError("num_procs must be >= 1")
        if num_classes < 1:
            raise ValueError("num_classes must be >= 1")
        self.P = num_procs
        self.R = int(max(0, min(radius, num_procs // 2)))
        self.C = num_classes
        # board[i, j] = what process i currently believes about process j.
        self.n = np.zeros((self.P, self.P), dtype=np.float64)
        self.t = np.full((self.P, self.P), np.nan, dtype=np.float64)
        # Work-weighted extension (DESIGN.md §Work-weighted stealing): each
        # cell also carries the per-class queue counts nc[c] and per-class
        # EWMA runtime estimates t̂[c] of the subject process.  The payload
        # rides the SAME per-cell version counters — one Put moves the whole
        # cell, so (n, t, nc, tc) stay mutually consistent per §2.1 writer.
        self.nc = np.zeros((self.P, self.P, self.C), dtype=np.float64)
        self.tc = np.full((self.P, self.P, self.C), np.nan, dtype=np.float64)
        # Straggler plane (DESIGN.md §Straggler plane): the subject's
        # self-reported limping flag.  One boolean riding the SAME per-cell
        # version counters — it moves with (n, t) in one Put, so a thief
        # never sees a re-priced t without the flag that explains it.
        self.limp = np.zeros((self.P, self.P), dtype=bool)
        self.version = np.zeros((self.P, self.P), dtype=np.int64)
        # last_sent[d][i, j]: newest version of cell j that i pushed toward
        # direction d (0 = to left neighbour i-1, 1 = to right neighbour i+1).
        self.last_sent = np.zeros((2, self.P, self.P), dtype=np.int64)
        self.puts = 0  # telemetry: number of cell-level Put operations
        self.rounds = 0
        # Reentrant so communicate() can hold it ONCE around its whole send
        # round (up to 2R cell Puts) instead of paying an acquire per cell.
        self._epoch = threading.RLock()

    # -------------------------------------------------------------- elasticity
    def grow(self, num_procs: int, radius: int | None = None) -> None:
        """Remap the board to ``num_procs`` ring positions (scale-out).

        Existing cells (values, versions, send watermarks) carry over
        verbatim; the new positions join as unreported members (n=0, t=NaN,
        version=0).  Shrinking is not supported — leavers are tombstoned by
        the substrate, never removed, so ring indices stay stable.
        """
        if num_procs < self.P:
            raise ValueError(
                f"cannot shrink the ring ({self.P} -> {num_procs}); "
                "retired members keep their positions as tombstones"
            )
        with self._epoch:
            new_r = self.R if radius is None else radius
            new_r = int(max(0, min(new_r, num_procs // 2)))
            if num_procs == self.P:
                self.R = new_r
                return
            old = self.P
            n = np.zeros((num_procs, num_procs), dtype=np.float64)
            t = np.full((num_procs, num_procs), np.nan, dtype=np.float64)
            nc = np.zeros((num_procs, num_procs, self.C), dtype=np.float64)
            tc = np.full((num_procs, num_procs, self.C), np.nan, dtype=np.float64)
            limp = np.zeros((num_procs, num_procs), dtype=bool)
            version = np.zeros((num_procs, num_procs), dtype=np.int64)
            last_sent = np.zeros((2, num_procs, num_procs), dtype=np.int64)
            n[:old, :old] = self.n
            t[:old, :old] = self.t
            nc[:old, :old] = self.nc
            tc[:old, :old] = self.tc
            limp[:old, :old] = self.limp
            version[:old, :old] = self.version
            last_sent[:, :old, :old] = self.last_sent
            self.n, self.t = n, t
            self.nc, self.tc = nc, tc
            self.limp = limp
            self.version, self.last_sent = version, last_sent
            self.P, self.R = num_procs, new_r

    def reset_member(self, k: int) -> None:
        """A replacement took over tombstoned ring position ``k``: every
        process's cell about k returns to the unreported boot state (n=0,
        t=NaN) so §2.2.1 preemptive estimates price the newcomer, not the
        ghost it replaced.  Versions BUMP (never reset) — observers stay
        monotone and the reset propagates like any other news."""
        with self._epoch:
            self.n[:, k] = 0.0
            self.t[:, k] = np.nan
            self.nc[:, k, :] = 0.0
            self.tc[:, k, :] = np.nan
            self.limp[:, k] = False
            self.version[:, k] += 1

    # ------------------------------------------------------------ local write
    def update_local(
        self,
        i: int,
        n_i: float,
        t_i: float,
        nc_i: np.ndarray | None = None,
        tc_i: np.ndarray | None = None,
        limp_i: bool = False,
    ) -> None:
        """Alg. 1 lines 2/11: p_i refreshes its own cell (Table 1 row 1).

        ``nc_i``/``tc_i``: optional per-class queue counts and EWMA runtime
        estimates (work-weighted mode); they share the cell's version, so a
        class-profile change alone is enough to mark the cell dirty.
        ``limp_i``: the owner-side limp-detector verdict (DESIGN.md
        §Straggler plane) — a flag flip alone also dirties the cell.
        """
        with self._epoch:
            changed = (self.n[i, i] != n_i) or not _feq(self.t[i, i], t_i)
            if bool(self.limp[i, i]) != limp_i:
                self.limp[i, i] = limp_i
                changed = True
            if nc_i is not None and not np.array_equal(self.nc[i, i], nc_i):
                self.nc[i, i] = nc_i
                changed = True
            if tc_i is not None and not np.array_equal(
                self.tc[i, i], tc_i, equal_nan=True
            ):
                self.tc[i, i] = tc_i
                changed = True
            if changed:
                self.n[i, i] = n_i
                self.t[i, i] = t_i
                self.version[i, i] += 1

    def record_remote(
        self,
        i: int,
        j: int,
        n_j: float,
        t_j: float,
        nc_j: np.ndarray | None = None,
    ) -> None:
        """Thief-side knowledge injection (Table 1 rows 2-3).

        After (attempting) a steal, the thief p_i learned the victim's new
        queue state first-hand (it moved the tail itself), so it writes the
        victim's cell in its OWN vector and bumps the version so the news
        propagates outward from the thief.  ``nc_j``: the victim's corrected
        per-class queue profile (the thief saw the classes of the loot it
        took); the victim's t̂[c] estimates are NOT the thief's to correct.
        """
        with self._epoch:
            self.n[i, j] = n_j
            if t_j == t_j:  # not NaN
                self.t[i, j] = t_j
            if nc_j is not None:
                self.nc[i, j] = nc_j
            self.version[i, j] += 1

    # ------------------------------------------------------- ring propagation
    def communicate(self, i: int, can_send=None) -> int:
        """Alg. 1 line 13: push dirty cells to both ring neighbours.

        p_i sends cells about indices ``j >= i`` to its LEFT neighbour (which
        stores them in its upper window) and cells about ``j <= i`` to its
        RIGHT neighbour — the write partition of §2.1.  Only cells whose
        version advanced since the previous send to that direction move
        (Table 1: "Only new information is exchanged").

        ``can_send(neighbour) -> bool`` (fault plane, DESIGN.md §Fault
        fabric): when given, a whole direction is skipped if the neighbour
        is unreachable — the watermark does NOT advance, so the cells are
        re-offered once the link heals.  ``can_send=None`` is exactly the
        ungated round.

        Returns the number of cells transmitted (0 = nothing dirty).
        """
        if self.P == 1 or self.R == 0:
            return 0
        sent = 0
        with self._epoch:  # one hold per round; inner Puts re-enter cheaply
            left = (i - 1) % self.P
            right = (i + 1) % self.P
            # Cells the LEFT neighbour may receive: positions j in left's
            # upper window, i.e. ring-distance(left -> j) in [1, R] going
            # right; those are exactly j = i .. i+R-1 (distance from i:
            # 0..R-1).
            if can_send is None or can_send(left):
                for off in range(0, self.R):
                    j = (i + off) % self.P
                    sent += self._put(i, left, j, direction=0)
            # Cells the RIGHT neighbour may receive: j = i-R+1 .. i.
            if can_send is None or can_send(right):
                for off in range(0, self.R):
                    j = (i - off) % self.P
                    sent += self._put(i, right, j, direction=1)
            self.rounds += 1
        return sent

    def resync(self, i: int) -> None:
        """Partition heal (DESIGN.md §Fault fabric): forget everything ``i``
        believes it already delivered.  A neighbour on the far side of a cut
        may hold copies frozen at the cut instant, yet ``last_sent`` says
        "already sent" — without this reset the stale cells would never be
        re-offered.  Versions are untouched, so receivers stay monotone: a
        re-Put of a version they already hold is a no-op."""
        with self._epoch:
            self.last_sent[:, i, :] = 0

    def _put(self, src: int, dst: int, j: int, direction: int) -> int:
        with self._epoch:  # epoch guard only — see class docstring
            ver = self.version[src, j]
            if ver <= self.last_sent[direction, src, j]:
                return 0  # flag is false: nothing new to send
            self.last_sent[direction, src, j] = ver
            # One-sided Put into dst's window.  Single-writer per (dst, j)
            # cell by the §2.1 partition, hence no cell lock.  Keep
            # monotonicity: a cell only moves forward in version (defensive;
            # partition already ensures it).
            if ver > self.version[dst, j]:
                self.n[dst, j] = self.n[src, j]
                self.t[dst, j] = self.t[src, j]
                self.nc[dst, j] = self.nc[src, j]
                self.tc[dst, j] = self.tc[src, j]
                self.limp[dst, j] = self.limp[src, j]
                self.version[dst, j] = ver
            self.puts += 1
            return 1

    # -------------------------------------------------------------- inspection
    def view(
        self, i: int, default_t: float | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """(n, t) rows as seen by process i, with unknown ``t`` cells filled.

        Fallback order for a NaN cell: ``default_t`` when the caller passes
        one (e.g. the preemptive wall-time estimate of §2.2.1), else the
        MEAN of the t's process i actually knows — the subsystem-mean prior
        says "an unreported neighbour is probably an average one", which
        keeps Eq. 5's harmonic sum on the right scale.  Only when process i
        knows NOTHING at all does 1.0 remain: with every cell equal, the
        fair share degenerates to a pure task-count split, so the actual
        constant cancels out.  (The old fallback of a flat 1.0 s whenever
        the own cell was still NaN poisoned Eq. 5 for sub-millisecond
        tasks: one fake 1 s neighbour dwarfs the real harmonic sum.)
        """
        n, t, _raw, _window = self.view_window(i, default_t)
        return n, t

    def view_window(
        self, i: int, default_t: float | None = None
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, list[int]]:
        """``view(i)`` plus the raw-t row and radius window, all from ONE
        board epoch — a concurrent ``grow`` can never hand a caller a window
        sized for a bigger ring than the rows it just copied."""
        n, t, raw_t, window, _nc, _tc = self.view_window_classes(i, default_t)
        return n, t, raw_t, window

    def view_window_classes(
        self, i: int, default_t: float | None = None
    ) -> tuple[
        np.ndarray, np.ndarray, np.ndarray, list[int], np.ndarray, np.ndarray
    ]:
        """``view_window(i)`` plus the (P, C) per-class rows — queue counts
        ``nc`` and EWMA runtime estimates ``tc`` (NaN = unreported) — all
        copied under the same board epoch so the work-weighted overlay can
        never mix ring sizes with the scalar rows."""
        n, t, raw_t, window, nc, tc, _limp = self.view_window_all(i, default_t)
        return n, t, raw_t, window, nc, tc

    def view_window_all(
        self, i: int, default_t: float | None = None
    ) -> tuple[
        np.ndarray, np.ndarray, np.ndarray, list[int],
        np.ndarray, np.ndarray, np.ndarray,
    ]:
        """``view_window_classes(i)`` plus the delayed limp-flag row
        (DESIGN.md §Straggler plane), under the same board epoch."""
        with self._epoch:
            n = self.n[i].copy()
            raw_t = self.t[i].copy()
            nc = self.nc[i].copy()
            tc = self.tc[i].copy()
            limp = self.limp[i].copy()
            window = neighborhood(i, self.P, self.R)
        t = raw_t.copy()
        mask = np.isnan(t)
        if mask.any():
            if default_t is not None:
                fill = default_t
            else:
                known = t[~mask]
                fill = float(known.mean()) if known.size else 1.0
            t[mask] = fill
        return n, t, raw_t, window, nc, tc, limp

    def window(self, i: int) -> list[int]:
        return neighborhood(i, self.P, self.R)

    def belief_t(self, i: int, j: int) -> float:
        """What i currently believes about j's mean task time (raw cell)."""
        return float(self.t[i, j])

    def belief_nc(self, i: int, j: int) -> np.ndarray | None:
        """i's current belief about j's per-class queue profile (the row the
        Fig. 3b loot correction subtracts from)."""
        return self.nc[i, j]

    def peer_raw_t(self, i: int) -> list[tuple[int, float]]:
        """(peer id, raw believed t) over i's window, excluding i — the
        limp detector's boot-time peer-median reference (NaN = unreported).
        On a :class:`CellBoard` the same call returns GLOBAL ids scoped to
        i's cell, so callers need not know which board they hold."""
        row = self.t[i]
        return [(j, float(row[j])) for j in self.window(i) if j != i]

    def staleness(self, truth_version: np.ndarray) -> np.ndarray:
        """How many versions behind each process's view is (telemetry)."""
        return truth_version[None, :] - self.version


def _feq(a: float, b: float) -> bool:
    if a != a and b != b:  # both NaN
        return True
    return a == b


# --------------------------------------------------------------------------- #
#                      two-level hierarchy (DESIGN.md §Hierarchy)              #
# --------------------------------------------------------------------------- #


class CellMap:
    """Global worker ids grouped into K cells, each cell a small local ring.

    The flat ring's O(P) per-boundary view is what tops out at production
    pool sizes; the hierarchy replaces it with K cells of ~ρ members, each
    running ordinary intra-cell A2WS on its own sub-board (O(ρ) views), plus
    a K-wide leader plane (:class:`DigestBoard`) for inter-cell balancing.

    Mapping invariants:

    * every global id maps to exactly one ``(cell, local slot)``;
    * local slots are APPEND-ONLY — a member that migrates away leaves a
      hole (``-1`` in ``members``) so every other member's slot, and hence
      its sub-board column, stays stable (the same tombstone-not-remove
      discipline the flat ring uses for dead workers);
    * cells are never added or removed after construction (K is the
      topology; joiners land in the smallest live cell).

    Readers are lock-free: ``members`` returns a copy taken under the lock,
    and ``cell_of``/``local_of`` are single atomic list reads.  Mutations
    (``assign``/``migrate``) serialise on the internal lock.
    """

    def __init__(
        self,
        num_workers: int,
        num_cells: int | None = None,
        cell_size: int | None = None,
        radius: int | None = None,
    ) -> None:
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if num_cells is None and cell_size is None:
            # Default topology: ~sqrt(P) cells — balances the O(ρ) intra-cell
            # view against the O(K) leader plane.
            num_cells = max(1, int(round(float(num_workers) ** 0.5)))
        if num_cells is None:
            num_cells = max(1, -(-num_workers // max(int(cell_size), 1)))
        num_cells = int(num_cells)
        if num_cells < 1:
            raise ValueError("num_cells must be >= 1")
        if num_cells > num_workers:
            num_cells = num_workers
        self.num_cells = num_cells
        #: explicit intra-cell Eq. 5 radius; None = full-cell window
        #: (ρ//2 — with ρ small the leader then digests its WHOLE cell)
        self.cell_radius = radius
        self._lock = threading.Lock()
        # Contiguous block split, like the flat static partition: cell k
        # gets ~P/K consecutive ids (locality-friendly when ids are ranks).
        self._members: list[list[int]] = [[] for _ in range(num_cells)]
        self._cell_of: list[int] = [0] * num_workers
        self._local_of: list[int] = [0] * num_workers
        base, rem = divmod(num_workers, num_cells)
        g = 0
        for c in range(num_cells):
            k = base + (1 if c < rem else 0)
            for _ in range(k):
                self._cell_of[g] = c
                self._local_of[g] = len(self._members[c])
                self._members[c].append(g)
                g += 1
        #: bumps on every assign/migrate — membership-change telemetry and
        #: the staleness hook for remapping property tests
        self.version = 0

    @property
    def num_workers(self) -> int:
        return len(self._cell_of)

    def cell_of(self, worker: int) -> int:
        return self._cell_of[worker]

    def local_of(self, worker: int) -> int:
        return self._local_of[worker]

    def locate(self, worker: int) -> tuple[int, int]:
        """Consistent ``(cell, local)`` pair under the lock — a concurrent
        ``migrate`` can never hand a caller the old cell with the new local
        slot (the torn read the two single-field getters would allow)."""
        with self._lock:
            return self._cell_of[worker], self._local_of[worker]

    def members(self, cell: int) -> list[int]:
        """Global ids by local slot (``-1`` = hole left by a migration)."""
        with self._lock:
            return list(self._members[cell])

    def slots(self, cell: int) -> int:
        return len(self._members[cell])

    def live_size(self, cell: int) -> int:
        with self._lock:
            return sum(1 for g in self._members[cell] if g >= 0)

    def radius_of(self, cell: int) -> int:
        """Intra-cell Eq. 5 radius: the explicit override, else the
        full-cell window (slots//2 — ``neighborhood`` then covers every
        slot, so the leader's digest aggregates its whole cell)."""
        m = max(len(self._members[cell]), 1)
        if self.cell_radius is not None:
            return int(max(0, min(self.cell_radius, m // 2)))
        return m // 2

    def assign(self, worker: int) -> int:
        """Home a NEW global id (elastic join) in the smallest live cell and
        return the cell.  Idempotent for already-mapped ids (a recycled
        tombstone slot keeps its cell — its sub-board column is reset by the
        substrate, not re-homed)."""
        with self._lock:
            if worker < len(self._cell_of):
                return self._cell_of[worker]
            if worker != len(self._cell_of):
                raise ValueError(
                    f"joins must be dense: expected id {len(self._cell_of)}, "
                    f"got {worker}"
                )
            sizes = [
                sum(1 for g in mem if g >= 0) for mem in self._members
            ]
            cell = int(min(range(self.num_cells), key=lambda c: sizes[c]))
            self._cell_of.append(cell)
            self._local_of.append(len(self._members[cell]))
            self._members[cell].append(worker)
            self.version += 1
            return cell

    def migrate(self, worker: int, new_cell: int) -> tuple[int, int]:
        """Re-home ``worker`` to ``new_cell`` (leader-level member
        migration).  The old slot becomes a hole; the worker gets a fresh
        slot appended to the new cell.  Returns ``(old_cell, new_local)``."""
        if not 0 <= new_cell < self.num_cells:
            raise ValueError(f"cell {new_cell} out of range 0..{self.num_cells - 1}")
        with self._lock:
            old_cell = self._cell_of[worker]
            if old_cell == new_cell:
                return old_cell, self._local_of[worker]
            self._members[old_cell][self._local_of[worker]] = -1
            new_local = len(self._members[new_cell])
            self._members[new_cell].append(worker)
            # Order matters for lock-free readers resolving (cell, local):
            # the new slot exists before the mapping flips to it.
            self._local_of[worker] = new_local
            self._cell_of[worker] = new_cell
            self.version += 1
            return old_cell, new_local


class CellDigest:
    """One cell's compact self-description on the leader plane: aggregate
    queued work-seconds, task count, live membership, optional per-class
    mix, and the richest member (the inter-cell steal target).

    ``leader`` is the publishing leader's GLOBAL worker id — the digest's
    cell-DISTANCE hint (DESIGN.md §Topology plane): a consuming leader can
    price the link to a cell through any :class:`~repro.core.topology.
    Topology` by measuring to its leader (or to ``top_worker`` when one is
    named), even before it knows anything else about that cell's layout.
    -1 = unpriced (digests published before the topology plane existed)."""

    __slots__ = (
        "cell", "time", "work", "tasks", "live", "top_worker", "top_queued",
        "top_work", "mix", "seq", "leader",
    )

    def __init__(
        self,
        cell: int,
        time: float,
        work: float,
        tasks: float,
        live: int,
        top_worker: int,
        top_queued: int,
        top_work: float = 0.0,
        mix: np.ndarray | None = None,
        seq: int = 0,
        leader: int = -1,
    ) -> None:
        self.cell = cell
        self.time = time
        self.work = work
        self.tasks = tasks
        self.live = live
        self.top_worker = top_worker
        self.top_queued = top_queued
        self.top_work = top_work
        self.mix = mix
        self.seq = seq
        self.leader = leader


class DigestBoard:
    """The K-wide leader ring: one digest slot per cell.

    Single writer per slot (only the cell's current leader publishes), so a
    publish is one atomic reference swap — the same §2.1 write-partition
    argument as the flat board, scaled down to K entries.  Readers see a
    consistent digest or an older one, never a torn write.  Transport delay
    on the leader plane is not modelled (K is small and digests are
    aggregates that age gracefully) — documented in DESIGN.md §Hierarchy.
    """

    def __init__(self, num_cells: int) -> None:
        self.slots: list[CellDigest | None] = [None] * num_cells
        self.publishes = 0  # telemetry (racy increment; indicative only)

    def publish(self, digest: CellDigest) -> None:
        prev = self.slots[digest.cell]
        digest.seq = (prev.seq + 1) if prev is not None else 1
        self.slots[digest.cell] = digest  # atomic reference swap
        self.publishes += 1

    def get(self, cell: int) -> CellDigest | None:
        return self.slots[cell]

    def peers(self, cell: int) -> list[CellDigest]:
        """Every other cell's latest digest (skips never-published slots)."""
        return [
            d for c, d in enumerate(self.slots) if c != cell and d is not None
        ]

    def reset(self) -> None:
        self.slots = [None] * len(self.slots)


class CellBoard:
    """K per-cell :class:`RingInfo` sub-boards behind GLOBAL-id addressing.

    The substrate keeps talking in global worker ids; this facade maps every
    call through the :class:`CellMap` to ``(cell, local)`` and the cell's
    own sub-board.  Each sub-board is an ordinary flat RingInfo over the
    cell's local slots — view, Eq. 5 radius, weighted overlay and limp
    re-pricing all run unchanged, just scoped to ρ members — which is the
    whole point: K=1 IS the flat scheduler (one sub-board of size P).

    Cross-cell writes (``record_remote`` after an inter-cell steal) are
    dropped: the victim's cell is not on the thief's board, and digests —
    not cells — carry inter-cell knowledge.
    """

    def __init__(self, cells: CellMap, num_classes: int = 1) -> None:
        self.cells = cells
        self.C = num_classes
        self.boards = [
            RingInfo(
                max(cells.slots(c), 1), cells.radius_of(c), num_classes
            )
            for c in range(cells.num_cells)
        ]
        self.digests = DigestBoard(cells.num_cells)
        self.dropped_remote = 0  # telemetry: cross-cell record_remote drops

    # ------------------------------------------------------------ delegation
    def _loc(self, worker: int) -> tuple["RingInfo", int]:
        c, loc = self.cells.locate(worker)
        return self.boards[c], loc

    @property
    def puts(self) -> int:
        return sum(b.puts for b in self.boards)

    @property
    def rounds(self) -> int:
        return sum(b.rounds for b in self.boards)

    def update_local(self, i: int, *a, **kw) -> None:
        board, loc = self._loc(i)
        board.update_local(loc, *a, **kw)

    def communicate(self, i: int, can_send=None) -> int:
        c, loc = self.cells.locate(i)
        board = self.boards[c]
        if can_send is None:
            return board.communicate(loc)
        # The gate speaks GLOBAL ids; the sub-board's neighbours are LOCAL
        # slots — translate through the member list (holes never receive).
        mem = self.cells.members(c)

        def _can(jl, _mem=mem, _cs=can_send):
            g = _mem[jl] if jl < len(_mem) else -1
            return g >= 0 and _cs(g)

        return board.communicate(loc, can_send=_can)

    def resync(self, i: int) -> None:
        """Partition heal: reset ``i``'s send watermarks on its sub-board
        (see :meth:`RingInfo.resync`).  Digests are NOT resynced — they are
        re-published wholesale every leader round anyway."""
        board, loc = self._loc(i)
        board.resync(loc)

    def record_remote(self, i: int, j: int, *a, **kw) -> None:
        ci, li = self.cells.locate(i)
        cj, lj = self.cells.locate(j)
        if ci != cj:
            self.dropped_remote += 1  # inter-cell: no shared board
            return
        self.boards[ci].record_remote(li, lj, *a, **kw)

    def view_window_all(self, i: int, default_t: float | None = None):
        board, loc = self._loc(i)
        return board.view_window_all(loc, default_t)

    def window(self, i: int) -> list[int]:
        """GLOBAL ids of i's intra-cell window (holes dropped)."""
        c, loc = self.cells.locate(i)
        board = self.boards[c]
        mem = self.cells.members(c)
        out = []
        for jl in board.window(loc):
            if jl < len(mem) and mem[jl] >= 0:
                out.append(mem[jl])
        return out

    def belief_t(self, i: int, j: int) -> float:
        """What i currently believes about j's mean task time (NaN when j is
        outside i's cell — inter-cell victims are priced by digest)."""
        ci, li = self.cells.locate(i)
        cj, lj = self.cells.locate(j)
        if ci != cj:
            return float("nan")
        return float(self.boards[ci].t[li, lj])

    def belief_nc(self, i: int, j: int) -> np.ndarray | None:
        """i's believed per-class queue profile of j (None when j lives in
        another cell — there is no shared board row to correct)."""
        ci, li = self.cells.locate(i)
        cj, lj = self.cells.locate(j)
        if ci != cj:
            return None
        return self.boards[ci].nc[li, lj]

    def peer_raw_t(self, i: int) -> list[tuple[int, float]]:
        """(GLOBAL peer id, raw believed t) over i's intra-cell window — the
        limp detector's peer-median reference, scoped to i's cell."""
        c, loc = self.cells.locate(i)
        board = self.boards[c]
        mem = self.cells.members(c)
        row = board.t[loc]
        out = []
        for jl in board.window(loc):
            if jl != loc and jl < len(mem) and mem[jl] >= 0:
                out.append((mem[jl], float(row[jl])))
        return out

    # ------------------------------------------------------------ elasticity
    def ensure(self, worker: int) -> None:
        """Grow ``worker``'s cell sub-board to cover its local slot (elastic
        join / migration landing).  The new column joins unreported —
        preemptive §2.2.1 estimates cover it exactly like boot."""
        c = self.cells.cell_of(worker)
        need = self.cells.slots(c)
        if self.boards[c].P < need:
            self.boards[c].grow(need, self.cells.radius_of(c))

    def reset_member(self, worker: int) -> None:
        board, loc = self._loc(worker)
        board.reset_member(loc)

    def migrate(self, worker: int, new_cell: int) -> None:
        """Board-side half of a member migration: re-home the mapping, then
        grow the receiving sub-board to cover the fresh slot.  The old
        cell's column stays as a hole (stable slots), masked out of views by
        the substrate exactly like a tombstone."""
        self.cells.migrate(worker, new_cell)
        self.ensure(worker)

"""Literature baselines the paper compares against (§4) — as policy shims.

* **LW** — "Leader and Workers": classical centralized dynamic scheduling.
  The central queue lives on worker 0 (the leader is co-located, Fig. 5b):
  worker 0 is slowed by ``leader_overhead`` and every other worker requests
  one task at a time through a serialized leader gate (``service_time`` per
  request), which congests as the node count grows (§4: "the primary node
  ... becomes increasingly overloaded").

* **CTWS** — Cyclic Token-based Work-Stealing (Assis et al., 2019).  A single
  token circulates the ring carrying the global task-count vector; only the
  token holder may steal, it steals **half** the most-loaded victim's
  available tasks, and deadlock/race freedom comes from the token's
  exclusivity.  The cost is waiting for the token, which grows with the node
  count — the effect the paper beats.

Since PR 2 both are thin wrappers over the shared ``WorkerPool`` substrate
(``repro.core.a2ws``) parameterised by ``LWPolicy``/``CTWSPolicy``
(``repro.core.policy``): the worker loops, deques, submit()/drain() open
arrivals and latency telemetry are the substrate's, so the comparison
isolates the scheduling policy — and the baselines gain everything the
substrate grows (open arrivals, fault tombstones, ServePool serving).
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

from .a2ws import WorkerPool
from .policy import CTWSPolicy, LWPolicy

__all__ = ["LWRuntime", "CTWSRuntime"]


class LWRuntime(WorkerPool):
    """Centralized leader–workers scheduler on the shared substrate."""

    def __init__(
        self,
        tasks: Sequence,
        num_workers: int,
        task_fn: Callable[[int, object], object],
        *,
        leader_overhead: float = 0.0,
        service_time: float = 0.0,
        request_rtt: float = 0.0,
        clock: Callable[[], float] = time.perf_counter,
        **kw,
    ) -> None:
        """``leader_overhead``: fractional slowdown applied to worker 0's task
        execution (the co-located leader thread steals cycles).
        ``service_time``: leader-side seconds consumed per request (models the
        serialization bottleneck at large worker counts).
        ``request_rtt``: request/grant wire round-trip per dispatch."""
        super().__init__(
            tasks,
            num_workers,
            task_fn,
            policy=LWPolicy(
                leader_overhead=leader_overhead,
                service_time=service_time,
                request_rtt=request_rtt,
            ),
            clock=clock,
            **kw,
        )


class CTWSRuntime(WorkerPool):
    """Cyclic token-based work-stealing on the shared substrate."""

    def __init__(
        self,
        tasks: Sequence,
        num_workers: int,
        task_fn: Callable[[int, object], object],
        *,
        token_hop_time: float = 0.0,
        clock: Callable[[], float] = time.perf_counter,
        **kw,
    ) -> None:
        """``token_hop_time``: per-node token transfer cost — the token
        carries the global P-sized count vector, so the effective hop gate is
        ``token_hop_time * num_workers`` (scales with the node count)."""
        super().__init__(
            tasks,
            num_workers,
            task_fn,
            policy=CTWSPolicy(
                num_workers, hop_time=token_hop_time * num_workers
            ),
            clock=clock,
            **kw,
        )

"""Literature baselines the paper compares against (§4).

* **LW** — "Leader and Workers": classical centralized dynamic scheduling.  An
  extra scheduler thread co-located with worker 0 hands out tasks on demand
  from a central queue.  The paper's observed pathologies are reproduced
  structurally: (a) worker 0 is slowed by the co-located leader thread
  (Fig. 5b), and (b) the leader serializes requests, so it congests as the
  node count grows (§4: "the primary node ... becomes increasingly
  overloaded").

* **CTWS** — Cyclic Token-based Work-Stealing (Assis et al., 2019).  A single
  token circulates the ring carrying the global task-count vector; only the
  token holder may steal, it steals **half** the most-loaded victim's
  available tasks, and deadlock/race freedom comes from the token's
  exclusivity.  The cost is waiting for the token, which grows with the node
  count — the effect the paper beats.

Both run on the same ``TaskDeque``/task_fn substrate as ``A2WSRuntime`` so the
comparison isolates the scheduling policy.
"""

from __future__ import annotations

import queue as _queue
import threading
import time
from typing import Callable, Sequence

import numpy as np

from .a2ws import RunStats, TaskRecord, partition_tasks
from .deque import AtomicInt64, TaskDeque

__all__ = ["LWRuntime", "CTWSRuntime"]


class LWRuntime:
    """Centralized leader–workers scheduler (threaded)."""

    def __init__(
        self,
        tasks: Sequence,
        num_workers: int,
        task_fn: Callable[[int, object], object],
        *,
        leader_overhead: float = 0.0,
        service_time: float = 0.0,
        clock: Callable[[], float] = time.perf_counter,
    ) -> None:
        """``leader_overhead``: fractional slowdown applied to worker 0's task
        execution (the co-located leader thread steals cycles).
        ``service_time``: leader-side seconds consumed per request (models the
        serialization bottleneck at large worker counts)."""
        self.tasks = list(tasks)
        self.num_workers = num_workers
        self.task_fn = task_fn
        self.leader_overhead = leader_overhead
        self.service_time = service_time
        self.clock = clock
        self._central: _queue.SimpleQueue = _queue.SimpleQueue()
        self._request_q: _queue.SimpleQueue = _queue.SimpleQueue()
        self._records: list[TaskRecord] = []
        self._log_lock = threading.Lock()

    def run(self) -> RunStats:
        for task in self.tasks:
            self._central.put(task)
        t0 = self.clock()
        per_worker = [0] * self.num_workers
        per_runtime = [0.0] * self.num_workers
        reply_qs = [_queue.SimpleQueue() for _ in range(self.num_workers)]
        stop = threading.Event()

        def leader() -> None:
            remaining = len(self.tasks)
            while remaining > 0:
                wid = self._request_q.get()
                if self.service_time:
                    _busy_wait(self.service_time, self.clock)
                try:
                    task = self._central.get_nowait()
                except _queue.Empty:
                    reply_qs[wid].put(None)
                    continue
                remaining -= 1
                reply_qs[wid].put(task)
            stop.set()
            for q in reply_qs:  # release any worker still waiting
                q.put(None)

        def worker(i: int) -> None:
            while not stop.is_set():
                self._request_q.put(i)
                task = reply_qs[i].get()
                if task is None:
                    return
                start = self.clock()
                self.task_fn(i, task)
                if i == 0 and self.leader_overhead:
                    _busy_wait((self.clock() - start) * self.leader_overhead, self.clock)
                end = self.clock()
                per_worker[i] += 1
                per_runtime[i] += end - start
                with self._log_lock:
                    self._records.append(TaskRecord(task, i, start, end))

        threads = [threading.Thread(target=leader, daemon=True)]
        threads += [
            threading.Thread(target=worker, args=(i,), daemon=True)
            for i in range(self.num_workers)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        t1 = self.clock()
        return RunStats(
            makespan=t1 - t0,
            records=sorted(self._records, key=lambda r: r.start),
            steals=[],
            failed_steals=0,
            info_cells_sent=0,
            corrections=0,
            per_worker_tasks=per_worker,
            per_worker_mean_t=[
                (rt / c) if c else float("nan")
                for rt, c in zip(per_runtime, per_worker)
            ],
        )


class CTWSRuntime:
    """Cyclic token-based work-stealing (threaded)."""

    def __init__(
        self,
        tasks: Sequence,
        num_workers: int,
        task_fn: Callable[[int, object], object],
        *,
        token_hop_time: float = 0.0,
        clock: Callable[[], float] = time.perf_counter,
    ) -> None:
        self.num_workers = num_workers
        self.task_fn = task_fn
        self.token_hop_time = token_hop_time
        self.clock = clock
        parts = partition_tasks(tasks, num_workers)
        self.total = len(tasks)
        self.deques = [TaskDeque(parts[i]) for i in range(num_workers)]
        self.done = AtomicInt64(0)
        # The token: a lock + the global remaining-task vector it carries.
        self._token_lock = threading.Lock()
        self._token_counts = np.array([len(d) for d in self.deques], dtype=np.int64)
        self._token_at = 0
        self._token_cond = threading.Condition()
        self._steals: list[tuple[float, int, int, int]] = []
        self._records: list[TaskRecord] = []
        self._log_lock = threading.Lock()

    def _handle_token(self, i: int, my: TaskDeque) -> None:
        """If the token is at i: use it (steal iff empty) and pass it on.

        The token circulates continuously — busy holders forward it at task
        boundaries, idle holders steal first.  Only the holder may steal,
        which is CTWS's race/deadlock-freedom argument.
        """
        with self._token_cond:
            if self._token_at != i:
                return
            if self.token_hop_time:
                # Token size grows with the node count (it carries the global
                # task vector): hop cost scales with P.
                _busy_wait(self.token_hop_time * self.num_workers, self.clock)
            counts = self._token_counts
            counts[i] = len(my)
            if len(my) == 0:
                victim = int(np.argmax(counts))
                if victim != i and counts[victim] > 0:
                    k = max(1, int(counts[victim]) // 2)
                    res = self.deques[victim].steal(k)
                    if res:
                        my.push(res.tasks)
                        with self._log_lock:
                            self._steals.append(
                                (self.clock(), i, victim, len(res.tasks))
                            )
                    counts[victim] = len(self.deques[victim])
                counts[i] = len(my)
            self._token_at = (self._token_at + 1) % self.num_workers
            self._token_cond.notify_all()

    def run(self) -> RunStats:
        t0 = self.clock()
        per_worker = [0] * self.num_workers
        per_runtime = [0.0] * self.num_workers

        def worker(i: int) -> None:
            my = self.deques[i]
            while self.done.load() < self.total:
                self._handle_token(i, my)
                task = my.get_task()
                if task is None:
                    # Empty deque: wait until the token comes around.
                    with self._token_cond:
                        if self._token_at != i:
                            self._token_cond.wait(timeout=1e-3)
                    continue
                start = self.clock()
                self.task_fn(i, task)
                end = self.clock()
                per_worker[i] += 1
                per_runtime[i] += end - start
                with self._log_lock:
                    self._records.append(TaskRecord(task, i, start, end))
                self.done.accumulate(1)

        threads = [
            threading.Thread(target=worker, args=(i,), daemon=True)
            for i in range(self.num_workers)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        t1 = self.clock()
        return RunStats(
            makespan=t1 - t0,
            records=sorted(self._records, key=lambda r: r.start),
            steals=list(self._steals),
            failed_steals=0,
            info_cells_sent=0,
            corrections=sum(d.corrections for d in self.deques),
            per_worker_tasks=per_worker,
            per_worker_mean_t=[
                (rt / c) if c else float("nan")
                for rt, c in zip(per_runtime, per_worker)
            ],
        )


def _busy_wait(duration: float, clock: Callable[[], float]) -> None:
    if duration <= 0:
        return
    end = clock() + duration
    while clock() < end:
        pass

"""Asynchronous-theft task deque (paper §2.3).

The paper controls deque access with MPI one-sided operations:

* Fig. 2 — a lock protocol: the owner takes from the *head* (exclusive lock on
  head+tail, shared lock on the deque body); the thief shifts the *tail*
  (exclusive head+tail), transfers task payloads, then exclusively locks its
  own deque to append.
* Fig. 3b — the optimisation this paper contributes: head and tail are packed
  into a **single word** so one atomic ``MPI_Get_accumulate`` both claims tail
  slots and returns a consistent (head, tail) snapshot — 7 communication ops
  collapse to 4.  When the snapshot reveals fewer available tasks than
  requested, an *occasional* ``MPI_Accumulate`` returns the overdraft (dashed
  arrow in Fig. 3b), and a victim observing ``tail < head`` classifies its
  deque as empty.

TPU/JAX adaptation: there is no remote atomic inside an XLA program, so this
structure lives in the **host control plane** (shared memory between worker
threads stands in for RDMA windows; on a real cluster the same protocol runs
between per-host scheduler agents).  ``AtomicInt64`` emulates a single
hardware fetch-and-add — its lock guards exactly one 64-bit read-modify-write
and is *never* held across a task transfer, preserving the paper's
no-lock-across-communication property.

Layout: slots live in a growable ring buffer addressed by absolute indices;
valid tasks occupy ``[head, tail)``.  The owner pops at ``head`` (head += 1);
a thief claims ``k`` slots at the tail (tail -= k) and receives ``[tail',
tail' + k)``.  New/stolen tasks are pushed at the head side (head -= 1), which
matches the paper: "new tasks are initially added to the head".
"""

from __future__ import annotations

import math
import threading
from typing import Callable, Iterable, Sequence

__all__ = [
    "AtomicInt64", "pack", "unpack", "TaskDeque", "StealResult",
    "Task", "SLO_BATCH", "SLO_LATENCY", "SLO_NAMES", "slo_of", "slo_key",
]

_HALF = 32
_MASK = (1 << _HALF) - 1
_BIAS = 1 << (_HALF - 1)  # biased encoding so head/tail may go "negative"


def pack(head: int, tail: int) -> int:
    """Pack (head, tail) into one 64-bit word: head in the high half."""
    return ((head + _BIAS) << _HALF) | ((tail + _BIAS) & _MASK)


def unpack(word: int) -> tuple[int, int]:
    head = (word >> _HALF) - _BIAS
    tail = (word & _MASK) - _BIAS
    return head, tail


#: SLO classes (DESIGN.md §SLO serving).  Two classes on purpose — the
#: ordering rule is "latency jumps batch, EDF within class"; finer tiers are
#: a deadline choice, not a new class.
SLO_BATCH = 0
SLO_LATENCY = 1
SLO_NAMES = ("batch", "latency")


class Task:
    """THE task record — the one encoding every layer shares.

    Before this record, per-task metadata accreted one parallel encoding per
    plane: the simulator carried ``(arrival, class)`` tuples, the threaded
    pool stamped arrivals in a side dict keyed by ``id(payload)``, ServePool
    wrapped requests in futures, and cost classes lived in a classifier
    closure.  ``Task`` is the superset, defined once:

    * ``id``       — stable integer identity (trace index / submission seq).
    * ``arrival``  — submission time in the owning plane's clock (virtual
      seconds in the simulator, ``perf_counter`` in the pool); NaN = closed
      workload, no latency accounting.
    * ``cls``      — cost class in ``[0, num_classes)`` (PR-4 weighted
      stealing); classifier-free substrates read it directly.
    * ``slo``      — :data:`SLO_LATENCY` or :data:`SLO_BATCH`.
    * ``deadline`` — absolute completion deadline (same clock as
      ``arrival``); ``inf`` = none.
    * ``payload``  — the actual work item, opaque to the scheduler.

    Plain payloads remain legal everywhere (:func:`slo_of` defaults them to
    batch/no-deadline), which is what keeps the degenerate no-SLO
    configuration bit-for-bit the PR-9 scheduler.
    """

    __slots__ = ("id", "arrival", "cls", "slo", "deadline", "payload")

    def __init__(
        self,
        id: int = -1,
        arrival: float = math.nan,
        cls: int = 0,
        slo: int = SLO_BATCH,
        deadline: float = math.inf,
        payload: object = None,
    ) -> None:
        self.id = id
        self.arrival = arrival
        self.cls = cls
        self.slo = slo
        self.deadline = deadline
        self.payload = payload

    def __repr__(self) -> str:  # telemetry/debug only
        return (
            f"Task(id={self.id}, arrival={self.arrival:.6g}, cls={self.cls},"
            f" slo={SLO_NAMES[self.slo]}, deadline={self.deadline:.6g})"
        )


def slo_of(task) -> tuple[int, float, float]:
    """``(slo, deadline, arrival)`` of ANY payload the runtime may carry.

    :class:`Task` records answer from their fields; future-like payloads
    (``ServeFuture``) answer from ``slo_class``/``deadline``/``submit_t``
    attributes; every other payload is batch-class with no deadline and an
    unknown arrival — the degenerate values under which SLO ordering is a
    no-op.  ``deadline`` is normalised to ``inf`` when absent/NaN, arrival
    to NaN when unknown.
    """
    if type(task) is Task:
        d = task.deadline
        return task.slo, (math.inf if d != d else d), task.arrival
    s = getattr(task, "slo_class", None)
    if s is None:
        return SLO_BATCH, math.inf, math.nan
    d = getattr(task, "deadline", None)
    a = getattr(task, "submit_t", None)
    d = math.inf if d is None or d != d else float(d)
    a = math.nan if a is None else float(a)
    return int(s), d, a


def slo_key(now: float, aging: float = math.inf) -> Callable:
    """Owner-pop ordering key for :meth:`TaskDeque.get_task` (DESIGN.md
    §SLO serving).  Smaller ranks pop first; exact ties resolve head-most
    (newest), which preserves batch LIFO under the hood.

    Rank layout: latency-class tasks rank ``(0, deadline)`` — EDF, with
    deadline-free latency tasks at ``(0, inf)``.  A batch task older than
    ``aging`` seconds is PROMOTED to rank ``(0, arrival + aging)`` — it
    competes in the same EDF order as latency work, which is the
    no-starvation bound: a latency flood can delay a batch task by at most
    ``aging`` plus the latency backlog ahead of its effective deadline.
    Fresh batch tasks rank ``(1, 0.0)`` — always behind latency, tie-broken
    newest-first (LIFO).
    """
    def key(task) -> tuple[int, float]:
        s, d, a = slo_of(task)
        if s == SLO_LATENCY:
            return (0, d)
        if aging < math.inf and a == a and (now - a) > aging:
            return (0, a + aging)
        return (1, 0.0)
    return key


class AtomicInt64:
    """A single 64-bit cell with fetch-and-add — the RDMA-atomic stand-in.

    ``get_accumulate(delta)`` is the MPI_Get_accumulate of Fig. 3b: atomically
    adds ``delta`` and returns the PREVIOUS value.  ``accumulate(delta)`` is
    the occasional correction op.  The internal lock covers one integer
    read-modify-write only.
    """

    __slots__ = ("_value", "_lock")

    def __init__(self, value: int = 0) -> None:
        self._value = value
        self._lock = threading.Lock()

    def load(self) -> int:
        with self._lock:
            return self._value

    def store(self, value: int) -> None:
        with self._lock:
            self._value = value

    def get_accumulate(self, delta: int) -> int:
        with self._lock:
            old = self._value
            self._value = old + delta
            return old

    def accumulate(self, delta: int) -> None:
        with self._lock:
            self._value += delta

    def compare_exchange(self, expected: int, desired: int) -> bool:
        with self._lock:
            if self._value != expected:
                return False
            self._value = desired
            return True


class _RWLock:
    """Shared/exclusive lock mirroring MPI_Win_lock(SHARED|EXCLUSIVE)."""

    def __init__(self) -> None:
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False

    def acquire_shared(self) -> None:
        with self._cond:
            while self._writer:
                self._cond.wait()
            self._readers += 1

    def release_shared(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_exclusive(self) -> None:
        with self._cond:
            while self._writer or self._readers:
                self._cond.wait()
            self._writer = True

    def release_exclusive(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()


class StealResult:
    """Outcome of ``TaskDeque.steal``: the tasks plus protocol telemetry.

    ``observed_head``/``observed_tail`` are the pre-image snapshot returned by
    the single Get-accumulate — the thief learns the victim's exact queue
    state for free, which feeds its information vector (Table 1 rows 2-3).
    """

    __slots__ = (
        "tasks", "requested", "adjusted", "corrected",
        "observed_head", "observed_tail",
    )

    def __init__(
        self,
        tasks: list,
        requested: int,
        adjusted: int,
        corrected: bool,
        observed_head: int = 0,
        observed_tail: int = 0,
    ):
        self.tasks = tasks
        self.requested = requested
        self.adjusted = adjusted
        self.corrected = corrected
        self.observed_head = observed_head
        self.observed_tail = observed_tail

    def __bool__(self) -> bool:  # truthy iff anything was stolen
        return bool(self.tasks)


class TaskDeque:
    """Owner-head / thief-tail deque with packed-word asynchronous theft."""

    def __init__(self, tasks: Iterable | None = None) -> None:
        items = list(tasks) if tasks is not None else []
        self._slots: dict[int, object] = {k: v for k, v in enumerate(items)}
        self.headtail = AtomicInt64(pack(0, len(items)))
        self.body = _RWLock()  # the "deque" window of Fig. 2
        # Telemetry (read by the info vector / tests; not part of the protocol)
        self.steals_suffered = 0
        self.corrections = 0
        self._telemetry_lock = threading.Lock()
        # Content-change hint for accounting caches (work-weighted queue
        # composition): bumped on every successful pop/push/steal.  Plain
        # int — a racy lost increment merely risks one stale accounting
        # read, which the next publish corrects.
        self.mutations = 0

    # ------------------------------------------------------------------ owner
    def get_task(self, key: Callable | None = None):
        """Fig. 2a: owner pops from the head.  Returns task or None if empty.

        (I) exclusive lock head+tail -> our single-word CAS loop: a CAS on the
        packed word is the degenerate exclusive lock over exactly that word;
        (II) shared lock on the body while reading the slot; (III) move head;
        (IV) unlock.

        ``key``: optional SLO-ordering key (:func:`slo_key`).  ``None`` —
        the default, and the only path any no-SLO substrate takes — is the
        plain head pop above, bit-for-bit the PR-9 protocol.  With a key the
        owner pops the MINIMUM-key task from anywhere in ``[head, tail)``
        (ties resolve head-most, i.e. newest).  Protocol: the owner takes an
        EXCLUSIVE body lock — thieves may still CLAIM tail slots (the
        packed-word get-accumulate is not body-locked) but cannot TRANSFER
        payloads (Fig. 2b step III needs the shared body lock) — scans the
        live range, CASes ``head + 1`` exactly as the plain pop does, then
        swaps the head payload into the popped task's slot so the range
        ``[head+1, tail)`` stays fully populated for any thief whose claim
        serialised after our CAS.  A claim that serialises before our CAS
        fails it and we rescan against the shrunken range.  Only the OWNER
        end reorders: the thief end still strips the oldest/cheapest tail
        slots first, which is what makes steals drain batch work
        preferentially (DESIGN.md §SLO serving).  ``key`` runs under the
        exclusive lock — it must be cheap and must not touch this deque.
        """
        if key is None:
            while True:
                word = self.headtail.load()
                head, tail = unpack(word)
                if head >= tail:  # empty (incl. thief-overdraft tail < head)
                    if tail < head:
                        self._note_overdraft()
                    return None
                self.body.acquire_shared()
                try:
                    if not self.headtail.compare_exchange(word, pack(head + 1, tail)):
                        continue  # a thief moved the tail under us: retry
                    task = self._slots.pop(head)
                    self.mutations += 1
                finally:
                    self.body.release_shared()
                return task
        missing = object()
        self.body.acquire_exclusive()
        try:
            while True:
                word = self.headtail.load()
                head, tail = unpack(word)
                if head >= tail:
                    if tail < head:
                        self._note_overdraft()
                    return None
                best_rank, best_k = None, head
                for k in range(head, tail):
                    cand = self._slots.get(k, missing)
                    if cand is missing:  # defensively skip claimed slots
                        continue
                    rank = key(cand)
                    if best_rank is None or rank < best_rank:
                        best_rank, best_k = rank, k
                if not self.headtail.compare_exchange(word, pack(head + 1, tail)):
                    continue  # a thief moved the tail under us: rescan
                task = self._slots.pop(best_k)
                if best_k != head:
                    # Refill the hole with the head payload: thieves that
                    # claimed after our CAS transfer from [head+1, tail),
                    # which must stay gap-free.
                    self._slots[best_k] = self._slots.pop(head)
                self.mutations += 1
                return task
        finally:
            self.body.release_exclusive()

    def push(self, tasks: Sequence) -> None:
        """Owner (or thief landing stolen goods) pushes at the head side.

        Fig. 2b step (IV): exclusive lock on own deque body while appending.
        """
        if not tasks:
            return
        self.body.acquire_exclusive()
        try:
            while True:
                word = self.headtail.load()
                head, tail = unpack(word)
                new_head = head - len(tasks)
                if self.headtail.compare_exchange(word, pack(new_head, tail)):
                    break
            for off, task in enumerate(tasks):
                self._slots[new_head + off] = task
            self.mutations += 1
        finally:
            self.body.release_exclusive()

    # ------------------------------------------------------------------ thief
    def steal(self, k: int) -> StealResult:
        """Fig. 3b: claim ``k`` tail slots with ONE get-accumulate.

        Protocol: ``old = get_accumulate(-k)`` shifts the tail and returns the
        consistent pre-image.  With ``avail = old_tail - old_head``:

        * ``avail <= 0``  -> nothing to steal; full correction ``+k``.
        * ``avail <  k``  -> partial; occasional correction ``+(k - avail)``
                             (the dashed Atomic Accumulate of Fig. 3b).
        * ``avail >= k``  -> clean steal, no extra round-trip.
        """
        if k <= 0:
            return StealResult([], k, 0, False)
        old = self.headtail.get_accumulate(-k)  # single atomic: shift tail
        head, tail = unpack(old)
        avail = tail - head
        if avail <= 0:
            self.headtail.accumulate(+k)  # full correction
            with self._telemetry_lock:
                self.corrections += 1
            return StealResult([], k, 0, True, head, tail)
        take = min(k, avail)
        corrected = False
        if take < k:  # occasional correction: give back the overdraft
            self.headtail.accumulate(+(k - take))
            corrected = True
            with self._telemetry_lock:
                self.corrections += 1
        # Transfer the payload [tail - take, tail) under a shared body lock —
        # the victim may keep popping at the head concurrently (Fig. 2b III).
        self.body.acquire_shared()
        try:
            stolen = [self._slots.pop(tail - take + off) for off in range(take)]
            self.mutations += 1
        finally:
            self.body.release_shared()
        with self._telemetry_lock:
            self.steals_suffered += 1
        return StealResult(stolen, k, take, corrected, head, tail)

    def steal_by_work(
        self, work_target: float, work_of, max_tasks: int,
        take_first: bool = False,
    ) -> StealResult:
        """Work-greedy theft (DESIGN.md §Work-weighted stealing): claim tail
        slots ONE Fig. 3b get-accumulate at a time, pricing each candidate
        with ``work_of(task)``, until the cumulative stolen work is nearest
        ``work_target``.

        Each candidate is *peeked* (an extra one-sided Get under the shared
        body lock) before it is claimed: a task whose work would overshoot
        the target by more than the remaining deficit is refused — a slow
        thief planning to take one light-task's worth must never ingest a
        heavy task 8x its fair share, which is exactly the failure mode of
        counting loot by head-count.  With homogeneous work (``work_of`` ≡ 1
        and an integer target) this takes exactly ``work_target`` tasks —
        the count-based degenerate case.

        ``take_first``: accept the first candidate even when it overshoots —
        an IDLE thief executing an approved plan must stay work-conserving
        (the victim is loaded, the thief has nothing; leaving the task to
        rot because its class is heavier than the victim's stale mean unit
        is a latency disaster under open arrivals).  Refusal still applies
        from the second candidate on.

        The returned ``StealResult`` synthesizes a single-op pre-image so
        ``observed_tail - observed_head - len(tasks)`` is the queue actually
        left behind, matching the contract of :meth:`steal`.

        Topology contract (DESIGN.md §Topology plane): each claim here is a
        separate protocol hop, so a PRICED plan (``StealPlan.delay`` > 0 —
        the thief paid for ONE batched transfer of ``amount`` tasks) must
        not use this path; its call sites route priced loot through the
        single batched :meth:`steal` instead of k separately-priced hops.
        """
        taken: list = []
        cum = 0.0
        corrected = False
        claimed_any = False
        left_after = 0
        while len(taken) < max_tasks:
            nxt = self.peek_tail()
            if nxt is None:
                break
            w = max(float(work_of(nxt)), 0.0)
            if cum + w - work_target > work_target - cum + 1e-12 and not (
                take_first and not taken
            ):
                break  # overshoot beyond the deficit: worse than stopping
            r = self.steal(1)
            corrected |= r.corrected
            claimed_any = True
            left_after = max(r.observed_tail - r.observed_head, 0) - len(r.tasks)
            if not r.tasks:
                break
            task = r.tasks[0]  # may differ from the peek under thief races
            taken.append(task)
            cum += max(float(work_of(task)), 0.0)
        got = len(taken)
        if not claimed_any:
            head, tail = self.snapshot()
            return StealResult([], 0, 0, False, head, tail)
        left_after = max(left_after, 0)
        return StealResult(
            taken, max_tasks, got, corrected, 0, left_after + got
        )

    # ------------------------------------------------------------- inspection
    def peek_tail(self):
        """One-sided read of the task a thief would claim next (the slot at
        ``tail - 1``) WITHOUT claiming it — the pricing Get of
        :meth:`steal_by_work`.  Returns None when the deque is empty or the
        slot was concurrently claimed; purely advisory (a racing thief may
        take the peeked slot first)."""
        self.body.acquire_shared()
        try:
            head, tail = unpack(self.headtail.load())
            if tail <= head:
                return None
            missing = object()
            task = self._slots.get(tail - 1, missing)
            return None if task is missing else task
        finally:
            self.body.release_shared()

    def snapshot_tasks(self) -> list:
        """Best-effort copy of the queued payloads in ``[head, tail)``.

        Owner-side accounting read for the work-weighted information vector
        (the owner prices its own queue composition — DESIGN.md
        §Work-weighted stealing).  Taken under a shared body lock; a
        concurrent thief may have claimed tail slots already, so missing
        slots are skipped — the estimate self-corrects at the next publish.
        """
        self.body.acquire_shared()
        try:
            head, tail = unpack(self.headtail.load())
            missing = object()
            out = []
            for k in range(head, tail):
                task = self._slots.get(k, missing)
                if task is not missing:
                    out.append(task)
            return out
        finally:
            self.body.release_shared()

    def __len__(self) -> int:
        head, tail = unpack(self.headtail.load())
        return max(tail - head, 0)

    def snapshot(self) -> tuple[int, int]:
        return unpack(self.headtail.load())

    def _note_overdraft(self) -> None:
        # Paper: "the victim will detect the stolen tasks when checking the own
        # head and tail, verifying tail < head and ... classify its deque as
        # empty."  Nothing to fix — thief corrections restore the invariant.
        pass

"""Straggler/limplock plane: fault injection + adaptive limp detection.

The paper's fault model (and ours, through PR 3) is binary — a worker is
alive or tombstoned.  Production heterogeneity has a third shape, the
dominant one at scale (Liu et al., PAPERS.md): a *limping* node that stays
alive but runs 10-100x slow (thermal throttle, noisy neighbor, IO stall).
Count-based stealing strands work on it; even the paper's t-weighted fair
share reacts only as fast as the published estimate, and the cumulative
mean ``t_i = runtime_sum/executed`` takes O(history) completions to admit a
mid-life collapse.

This module holds the plane-independent primitives (DESIGN.md §Straggler
plane); the threaded ``WorkerPool`` and the discrete-event simulator wire
them in identically so fault-injection scripts are cross-plane portable:

* :class:`SlowdownEvent` / :class:`SlowdownSchedule` — scriptable per-worker
  slowdown fault injection (step, ramp and transient events), the straggler
  analogue of PR 3's ``joins``/``retires`` churn scripts.  A schedule is a
  pure function ``factor_at(worker, t) -> multiplier`` of plane time, so the
  same script drives wall-clock stalls in the threaded plane and duration
  multipliers in the simulator.
* :class:`LimpConfig` / :class:`LimpState` — the owner-side detector: a fast
  EWMA over the worker's own completed-task durations (``recent``) against a
  slow own-baseline EWMA (``baseline``), flagged limping when the ratio
  crosses ``limp_factor`` and forgiven (hysteresis) when it falls back under
  ``recover_factor``.  The baseline FREEZES while limping so the collapsed
  regime cannot erode the healthy reference; recovery is driven entirely by
  ``recent`` decaying back — its half-life is pinned by
  :meth:`LimpConfig.recovery_half_life` and regression-tested.

Why own-trajectory and not peer-relative?  Static heterogeneity is the
paper's premise — a 1-core node is legitimately ~24x slower than a 24-core
one, and flagging it would fight the very fair-share mathematics (Eq. 5)
that already prices it correctly.  A limp is a *collapse against the
worker's own history*.  The ring-published peer baseline is used only as
the reference of last resort, for a worker that collapses before it has
``min_samples`` healthy completions of its own (boot-limped): there the
own baseline does not exist yet and the window median is the only signal.

Honest caveat (DESIGN.md §Straggler plane): the OWNER-side detector
observes only COMPLETED tasks.  A fully wedged worker (slowdown ->
infinity) never completes, never updates its EWMA, and never flags
itself.  ``LimpConfig.stale_after`` closes that blind spot from the PEER
side: the worker's own ring-cell version is its heartbeat (every
``update_local`` state change bumps it), and a version that stands still
for ``stale_after`` seconds gets the worker flagged limping by its peers
— routing-skip, re-pricing and limp-drain then fire exactly as for a
measured limp.  ``inf`` (default) keeps the pre-wedge behavior.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = [
    "SlowdownEvent",
    "SlowdownSchedule",
    "LimpConfig",
    "LimpState",
    "normalize_duration",
    "effective_heartbeat",
]

_INF = float("inf")


def effective_heartbeat(hb: float, cut_start: float) -> float:
    """Observer-side heartbeat of a peer behind a cut link.

    The shared staleness primitive of the wedge detector
    (``LimpConfig.stale_after``) and the network-fault plane (DESIGN.md
    §Fault fabric): a heartbeat published after the link was cut cannot
    have crossed the fabric, so what the OBSERVER can actually see is the
    heartbeat capped at the cut instant.  ``cut_start = inf`` (a healthy
    link) is the identity; a NaN heartbeat (never reported) stays NaN.
    Both planes run their staleness comparison ``now - effective_hb >
    threshold`` on this value, so partition-caused silence flows through
    the exact same re-pricing path as a wedged worker — just scoped to
    the observer's own view row instead of the global limp flags.
    """
    if hb != hb:  # NaN: no heartbeat ever observed
        return hb
    return min(hb, cut_start)


@dataclass(frozen=True)
class SlowdownEvent:
    """One scripted slowdown of ``worker`` starting at plane time ``start``.

    ``factor`` multiplies the worker's task-execution time while the event
    is active (16.0 = 16x slower; values in (0, 1) model a speed-up and are
    allowed for completeness).  ``duration`` bounds the event — ``inf`` is a
    permanent *step*, finite gives a *transient* that fully recovers.
    ``ramp`` > 0 turns the onset into a linear *ramp*: the multiplier grows
    from 1 to ``factor`` over ``ramp`` seconds (thermal throttling rather
    than an instant stall).
    """

    worker: int
    start: float
    factor: float
    duration: float = _INF
    ramp: float = 0.0

    def __post_init__(self) -> None:
        if self.worker < 0:
            raise ValueError(f"slowdown worker {self.worker} must be >= 0")
        if not math.isfinite(self.start) or self.start < 0.0:
            raise ValueError(f"slowdown start {self.start} must be finite >= 0")
        if not math.isfinite(self.factor) or self.factor <= 0.0:
            raise ValueError(f"slowdown factor {self.factor} must be > 0")
        if self.duration <= 0.0:
            raise ValueError(f"slowdown duration {self.duration} must be > 0")
        if self.ramp < 0.0 or not math.isfinite(self.ramp):
            raise ValueError(f"slowdown ramp {self.ramp} must be finite >= 0")

    @property
    def end(self) -> float:
        """First instant the event no longer applies (inf for a step)."""
        if math.isinf(self.duration):
            return _INF
        return self.start + self.duration

    def factor_at(self, t: float) -> float:
        """Multiplier this event contributes at plane time ``t``."""
        if t < self.start or t >= self.end:
            return 1.0
        if self.ramp > 0.0:
            progress = min((t - self.start) / self.ramp, 1.0)
            return 1.0 + (self.factor - 1.0) * progress
        return self.factor


@dataclass(frozen=True)
class SlowdownSchedule:
    """A scriptable set of slowdown events (the straggler churn script).

    ``factor_at(worker, t)`` is the product of every active event's
    multiplier — overlapping faults compose multiplicatively, matching how
    independent interference sources behave on a real node.  Times are plane
    times: virtual seconds in the simulator, seconds since ``start()`` in
    the threaded pool.
    """

    events: tuple[SlowdownEvent, ...] = ()

    def __post_init__(self) -> None:
        # Accept any iterable of events but store a hashable tuple.
        object.__setattr__(self, "events", tuple(self.events))

    def factor_at(self, worker: int, t: float) -> float:
        f = 1.0
        for ev in self.events:
            if ev.worker == worker:
                f *= ev.factor_at(t)
        return f

    def workers(self) -> set[int]:
        return {ev.worker for ev in self.events}


@dataclass(frozen=True)
class LimpConfig:
    """Knobs of the owner-side limp detector (DESIGN.md §Straggler plane).

    * ``limp_factor``     — flag when ``recent / reference`` exceeds this.
    * ``recover_factor``  — unflag when the ratio falls back below this
      (hysteresis: must be < ``limp_factor`` or the flag would flap).
    * ``recent_alpha``    — fast EWMA over own completed-task durations; the
      collapse detector AND the forgiveness clock (see
      :meth:`recovery_half_life`).
    * ``baseline_alpha``  — slow EWMA forming the own healthy baseline;
      frozen while flagged so a long limp cannot erode the reference.
    * ``min_samples``     — completions before the own baseline is trusted;
      until then the ring-published peer median is the reference (covers a
      worker that collapses right after boot).
    * ``stale_after``     — the WEDGE detector (peer-side, satellite of the
      topology PR): seconds without the worker's own ring-cell version
      bumping before peers flag it limping anyway.  The owner-side EWMA
      only observes COMPLETED tasks, so a fully wedged worker
      (slowdown → ∞) never flags itself; ``update_local`` bumps the own
      version on every state change, so a version that stands still for
      ``stale_after`` seconds of communicate-windows is the heartbeat-loss
      signal.  ``inf`` (default) disables the check — bit-for-bit the
      pre-wedge detector.  Recovery is automatic: the next version bump
      clears the staleness flag (the EWMA hysteresis then owns the
      verdict again).
    * ``probation_every`` / ``probation_backoff_max`` — the canary path.
      The detector only observes COMPLETED tasks, and the response starves
      the flagged worker of exactly those: routing skips it and thieves
      strip its queue, so without a counter-measure a transient fault would
      blacklist it FOREVER.  Every ``probation_every``-th task that routing
      would have diverted away from a flagged worker is routed to it anyway
      as a probation canary; while canaries keep completing slow the gap
      doubles (exponential backoff, capped at ``probation_backoff_max``) so
      a permanently limping worker costs O(log T) canary latencies, and a
      healthy canary resets the gap so recovery is confirmed quickly.
    """

    limp_factor: float = 4.0
    recover_factor: float = 2.0
    recent_alpha: float = 0.5
    baseline_alpha: float = 0.05
    min_samples: int = 3
    probation_every: int = 4
    probation_backoff_max: int = 256
    stale_after: float = _INF

    def __post_init__(self) -> None:
        if self.limp_factor <= 1.0:
            raise ValueError("limp_factor must be > 1")
        if not 1.0 <= self.recover_factor < self.limp_factor:
            raise ValueError("need 1 <= recover_factor < limp_factor")
        for name in ("recent_alpha", "baseline_alpha"):
            a = getattr(self, name)
            if not 0.0 < a <= 1.0:
                raise ValueError(f"{name} must be in (0, 1]")
        if self.min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        if self.probation_every < 1:
            raise ValueError("probation_every must be >= 1")
        if self.probation_backoff_max < self.probation_every:
            raise ValueError("probation_backoff_max must be >= probation_every")
        if not self.stale_after > 0.0:
            raise ValueError("stale_after must be > 0 (inf disables)")

    def recovery_half_life(self) -> float:
        """Healthy completions for ``recent`` to decay half-way back toward
        the true task time after a transient ends — the pinned forgiveness
        rate of the detector (tests/test_limplock.py).  With
        ``recent_alpha = 0.5`` that is exactly one completion."""
        if self.recent_alpha >= 1.0:
            return 1.0
        return math.log(0.5) / math.log(1.0 - self.recent_alpha)


def normalize_duration(dt: float, cls: int, class_t) -> float:
    """Scale a completed-task duration to average-class terms before feeding
    the detector, using the worker's OWN per-class EWMA t̂[c] (PR 4).

    Without this, a variable-cost workload (bimodal shots, 8x class ratio)
    trips the limp detector on every run of heavy tasks: the worker is not
    slower, its *work* is bigger.  Both planes apply the identical rule so
    fault scripts stay cross-plane portable.  ``class_t`` is the worker's
    t̂ row (or None when the workload is single-class — no-op then).
    """
    if class_t is None or len(class_t) <= 1:
        return dt
    ref = float(class_t[cls])
    if ref != ref or ref <= 0.0:
        return dt
    total = 0.0
    count = 0
    for v in class_t:
        v = float(v)
        if v == v:
            total += v
            count += 1
    mean = total / count  # count >= 1: class_t[cls] itself is finite
    if mean <= 0.0:
        return dt
    return dt * (mean / ref)


class LimpState:
    """Per-worker detector state; owner-thread-only, one per live worker.

    ``observe(dt)`` feeds one completed-task duration; ``evaluate(peer_ref)``
    re-derives the flag with hysteresis.  All floats, no locks — in the
    threaded plane only the owner thread touches the EWMAs, in the simulator
    there are no threads at all.  (Exception: ``should_probe`` is called by
    the SUBMITTER thread; its two int counters are GIL-atomic and a lost
    increment merely delays one canary by one diverted task.)
    """

    __slots__ = (
        "cfg", "recent", "baseline", "samples", "limping",
        "probe_gap", "diverted",
    )

    def __init__(self, cfg: LimpConfig) -> None:
        self.cfg = cfg
        self.recent = float("nan")
        self.baseline = float("nan")
        self.samples = 0
        self.limping = False
        self.probe_gap = cfg.probation_every
        self.diverted = 0

    def observe(self, dt: float) -> None:
        """Fold one completed-task duration into the EWMAs."""
        if not math.isfinite(dt) or dt <= 0.0:
            return  # defensive: clock glitches must not poison the detector
        self.samples += 1
        if self.recent != self.recent:
            self.recent = dt
        else:
            a = self.cfg.recent_alpha
            self.recent = a * dt + (1.0 - a) * self.recent
        limped_obs = (
            self.baseline == self.baseline
            and dt >= self.cfg.limp_factor * self.baseline
        )
        if self.limping:
            # Probation backoff: a still-slow canary doubles the probe gap,
            # a healthy one resets it so recovery gets confirmed quickly.
            if limped_obs:
                self.probe_gap = min(
                    self.probe_gap * 2, self.cfg.probation_backoff_max
                )
            else:
                self.probe_gap = self.cfg.probation_every
        elif not limped_obs:
            # Baseline freezes under collapse — including the collapse's
            # FIRST completion, which arrives before evaluate() can raise
            # the flag: an observation that alone crosses limp_factor is
            # an outlier by definition, never baseline material.
            # Forgiveness comes from ``recent`` falling back, never from
            # the baseline inflating up.
            if self.baseline != self.baseline:
                self.baseline = dt
            else:
                b = self.cfg.baseline_alpha
                self.baseline = b * dt + (1.0 - b) * self.baseline

    def should_probe(self) -> bool:
        """Routing calls this each time it would DIVERT a task away from
        this flagged worker: every ``probe_gap``-th diverted task returns
        True — route that one to the worker anyway as a probation canary
        (the only way a recovered worker can ever prove itself; see
        ``probation_every``).  An idle flagged worker starts the canary
        immediately, so thieves cannot snatch it back off the queue."""
        self.diverted += 1
        if self.diverted >= self.probe_gap:
            self.diverted = 0
            return True
        return False

    def ratio(self, peer_ref: float = float("nan")) -> float:
        """Speed-collapse ratio against the trusted reference (NaN = no
        reference yet — neither own history nor a peer baseline)."""
        if self.recent != self.recent:
            return float("nan")
        ref = self.baseline
        if self.samples < self.cfg.min_samples or ref != ref:
            ref = peer_ref
        if ref != ref or ref <= 0.0:
            return float("nan")
        return self.recent / ref

    def evaluate(self, peer_ref: float = float("nan")) -> bool:
        """Re-derive the limping flag (with hysteresis) and return it."""
        r = self.ratio(peer_ref)
        if r != r:
            return self.limping  # no reference: keep the current verdict
        if not self.limping and r > self.cfg.limp_factor:
            self.limping = True
            self.probe_gap = self.cfg.probation_every
            self.diverted = 0
        elif self.limping and r < self.cfg.recover_factor:
            self.limping = False
            self.probe_gap = self.cfg.probation_every
            self.diverted = 0
        return self.limping

"""Device data-plane A2WS: the paper's scheduler as a jitted SPMD program.

XLA SPMD has no remote atomics, so the *asynchronous* theft of §2.3 cannot be
expressed verbatim inside one compiled step.  What CAN be expressed — and what
this module provides — is the paper's information/decision structure as a
**round-based, neighbour-only** rebalance:

* information ring (§2.1)  -> two ``lax.ppermute``s per round over the worker
  axis (bidirectional ring).  Each worker carries a (2R+1)-cell window of
  ``(n_j, t_j, q_j)``; one round shifts knowledge one hop outward, R rounds
  refresh the full radius.  No all-gather, no global barrier semantics beyond
  the compiled step — communication stays O(R) per worker, the paper's point.
* smart stealing (§2.2)    -> Eq. 5 steal rate, γ-rounding (Eq. 7) and victim
  selection as array ops; probabilistic victim choice via per-worker PRNG.
* asynchronous theft       -> a single request/grant exchange built from two
  ``lax.all_to_all``s.  The victim grants ``min(request, available)`` — the
  SPMD analogue of the Fig. 3b get-accumulate + occasional correction: the
  thief's optimistic claim is adjusted by the authoritative victim-side state,
  in one round trip, with no locks.

Used three ways:
  1. ``plan_rebalance`` — the training control plane (``runtime.het_dp``)
     calls it between steps to redistribute microbatch counts.
  2. ``virtual_run`` — a fully jitted virtual-time cluster: property tests and
     the technique's own roofline/dry-run cell run this.
  3. equivalence tests against ``repro.core.steal`` (same formulas, host vs
     device).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import shard_map_compat

__all__ = [
    "SchedState",
    "init_state",
    "a2ws_round",
    "make_round_fn",
    "virtual_run",
    "steal_rate_window",
    "gamma_round",
]

_EPS = 1e-9


class SchedState(NamedTuple):
    """Per-worker scheduler state; leading axis = worker (sharded)."""

    queue: jax.Array   # i32[P, cap]   task ids, valid in [head, tail)
    head: jax.Array    # i32[P]
    tail: jax.Array    # i32[P]
    executed: jax.Array  # i32[P]
    t_avg: jax.Array   # f32[P]      mean task runtime (virtual seconds)
    clock: jax.Array   # f32[P]      per-worker virtual time
    win_n: jax.Array   # f32[P, W]   window: total tasks n_j
    win_t: jax.Array   # f32[P, W]   window: mean runtime t_j
    win_q: jax.Array   # f32[P, W]   window: queued tasks q_j
    key: jax.Array     # u32[P, 2]
    credit: jax.Array  # f32[P]      accumulated virtual time not yet spent


def init_state(
    num_workers: int,
    tasks_per_worker: jax.Array,
    speeds: jax.Array,
    radius: int,
    capacity: int,
    seed: int = 0,
) -> SchedState:
    """Static block partition (§2.2.1) across ``num_workers`` deques."""
    p = num_workers
    w = 2 * radius + 1
    counts = jnp.asarray(tasks_per_worker, jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    # queue[i, s] = global task id offsets[i] + s  (valid while s < counts[i])
    slot = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    queue = jnp.where(slot < counts[:, None], offsets[:, None] + slot, -1)
    t0 = 1.0 / jnp.asarray(speeds, jnp.float32)  # virtual seconds per task
    win_n = jnp.zeros((p, w), jnp.float32)
    win_t = jnp.full((p, w), jnp.nan, jnp.float32)
    win_q = jnp.zeros((p, w), jnp.float32)
    win_n = win_n.at[:, radius].set(counts.astype(jnp.float32))
    win_q = win_q.at[:, radius].set(counts.astype(jnp.float32))
    keys = jax.vmap(lambda s: jax.random.key_data(jax.random.key(s)))(
        jnp.arange(seed, seed + p)
    ).astype(jnp.uint32)
    return SchedState(
        queue=queue,
        head=jnp.zeros((p,), jnp.int32),
        tail=counts.astype(jnp.int32),
        executed=jnp.zeros((p,), jnp.int32),
        t_avg=t0.astype(jnp.float32),
        clock=jnp.zeros((p,), jnp.float32),
        win_n=win_n,
        win_t=win_t,
        win_q=win_q,
        key=keys,
        credit=jnp.zeros((p,), jnp.float32),
    )


# ------------------------------------------------------------------ formulas
def steal_rate_window(win_n: jax.Array, win_t: jax.Array, radius: int) -> jax.Array:
    """Eq. 5 on a (2R+1)-cell window; index R = self.  Shape [...]->scalar."""
    t = jnp.where(jnp.isnan(win_t), jnp.inf, jnp.maximum(win_t, _EPS))
    inv = jnp.where(jnp.isfinite(t), 1.0 / t, 0.0)
    known = jnp.isfinite(t)
    n = jnp.where(known, win_n, 0.0)
    big_n = n.sum(-1)
    big_t = inv.sum(-1)
    t_self = jnp.maximum(win_t[..., radius], _EPS)
    return big_n / (t_self * jnp.maximum(big_t, _EPS)) - win_n[..., radius]


def gamma_round(s: jax.Array, n_i, t_i, n_j, t_j) -> jax.Array:
    """Eqs. 6-8: round fractional steal rate to the γ-minimising integer."""
    lo = jnp.floor(s)
    hi = jnp.ceil(s)

    def u(amount, n, t):  # Eq. 6 (dimensionally-consistent product form)
        return jnp.maximum(n + amount, 0.0) * t

    g_lo = jnp.maximum(u(-lo, n_j, t_j), u(lo, n_i, t_i))
    g_hi = jnp.maximum(u(-hi, n_j, t_j), u(hi, n_i, t_i))
    return jnp.where(g_lo < g_hi, lo, hi).astype(jnp.int32)


def _pair_rate(n_i, t_i, n_j, t_j):
    """Eq. 10."""
    return (n_i + n_j) * t_j / jnp.maximum(t_i + t_j, _EPS) - n_i


# ------------------------------------------------------------------- round
def a2ws_round(
    state: SchedState,
    *,
    axis: str,
    radius: int,
    max_steal: int,
    num_workers: int,
    execute: bool = True,
    max_exec: int = 64,
    packed: bool = True,
) -> SchedState:
    """One scheduler round, to be called inside shard_map over ``axis``.

    Per-shard shapes carry a leading local dim of 1 (we index [0]).
    Sequence: (a) virtual-execute tasks for one virtual-time quantum;
    (b) refresh own window cell; (c) two-ppermute ring exchange;
    (d) steal-rate + victim selection; (e) request/grant all_to_all theft.
    """
    p = num_workers
    w = 2 * radius + 1
    queue = state.queue[0]
    head, tail = state.head[0], state.tail[0]
    executed = state.executed[0]
    t_avg, clock = state.t_avg[0], state.clock[0]
    win_n, win_t, win_q = state.win_n[0], state.win_t[0], state.win_q[0]
    key = state.key[0]
    credit = state.credit[0]

    # ------------------------------------- (a) execute one virtual quantum
    # One round = the slowest worker's task time (pmax).  Each worker spends
    # its accumulated virtual-time credit on as many tasks as its own speed
    # affords (so consumption rate is proportional to 1/t_avg), capped by the
    # static ``max_exec`` unroll bound.  Idle workers do not hoard credit.
    if execute:
        dt = lax.pmax(t_avg, axis)
        credit = credit + dt
        avail_q = jnp.maximum(tail - head, 0)
        k = jnp.floor(credit / jnp.maximum(t_avg, _EPS)).astype(jnp.int32)
        k = jnp.minimum(jnp.minimum(k, avail_q), max_exec)
        head = head + k
        executed = executed + k
        clock = clock + k.astype(jnp.float32) * t_avg
        credit = credit - k.astype(jnp.float32) * t_avg
        credit = jnp.minimum(credit, dt)

    qlen = (tail - head).astype(jnp.float32)
    n_self = (executed).astype(jnp.float32) + qlen
    # Preemptive estimate (§2.2.1): before the first finished task, t is the
    # elapsed virtual wall time (clock may be 0 at boot -> use t_avg prior).
    t_self = jnp.where(executed > 0, t_avg, jnp.maximum(clock, t_avg))

    # ------------------------------------------- (b) refresh own window cell
    win_n = win_n.at[radius].set(n_self)
    win_t = win_t.at[radius].set(t_self)
    win_q = win_q.at[radius].set(qlen)

    # ------------------------------------------------ (c) ring info exchange
    # From RIGHT neighbour: its cells [R, 2R-1] -> my cells [R+1, 2R].
    # From LEFT  neighbour: its cells [1, R]    -> my cells [0, R-1].
    right_to_left = [((i + 1) % p, i) for i in range(p)]
    left_to_right = [((i - 1) % p, i) for i in range(p)]

    def shift(buf_slice, perm):
        return lax.ppermute(buf_slice, axis, perm)

    if radius > 0:
        upper = jnp.stack([win_n[radius:2 * radius],
                           win_t[radius:2 * radius],
                           win_q[radius:2 * radius]])
        lower = jnp.stack([win_n[1:radius + 1],
                           win_t[1:radius + 1],
                           win_q[1:radius + 1]])
        from_right = shift(upper, right_to_left)
        from_left = shift(lower, left_to_right)
        win_n = win_n.at[radius + 1:].set(from_right[0]).at[:radius].set(from_left[0])
        win_t = win_t.at[radius + 1:].set(from_right[1]).at[:radius].set(from_left[1])
        win_q = win_q.at[radius + 1:].set(from_right[2]).at[:radius].set(from_left[2])

    # ------------------------------------- (d) steal rate + victim selection
    s_i = steal_rate_window(win_n, win_t, radius)
    idx = lax.axis_index(axis)
    offs = jnp.arange(-radius, radius + 1, dtype=jnp.int32)
    owner = jnp.mod(idx + offs, p)  # window cell -> worker id
    known = ~jnp.isnan(win_t)
    is_self = offs == 0

    # S_j per window cell (each cell uses the SAME window — i's knowledge).
    def cell_rate(c):
        rolled_n = jnp.roll(win_n, radius - c)  # put cell c at centre
        rolled_t = jnp.roll(win_t, radius - c)
        return steal_rate_window(rolled_n, rolled_t, radius)

    s_cells = jax.vmap(cell_rate)(jnp.arange(w))
    has_q = win_q > 0.0
    surplus = (s_cells < 0.0) & has_q & known & (~is_self)

    # Criterion 1 — closest rate: surplus volume scaled by match closeness.
    w1 = jnp.maximum(-s_cells, 0.0) / (
        1.0 + jnp.abs(-s_cells - jnp.maximum(s_i, 0.0))
    )
    # Criterion 2 — in-pair (Eq. 10) when no surplus candidate exists.
    pair = _pair_rate(n_self, t_self, win_n, jnp.where(known, win_t, jnp.inf))
    w2_mask = (pair > 0.0) & has_q & known & (~is_self)
    use_pair = ~surplus.any()
    cand = jnp.where(use_pair, w2_mask, surplus)
    weights = jnp.where(use_pair, jnp.maximum(pair, 0.0), w1)
    weights = jnp.where(cand, weights, 0.0)

    key, sub = jax.random.split(jax.random.wrap_key_data(key))
    logits = jnp.where(weights > 0.0, jnp.log(weights), -jnp.inf)
    pick = jax.random.categorical(sub, logits)
    any_cand = cand.any()

    # Idle workers always steal (relay rule, see core.steal.plan_steal);
    # busy workers steal preemptively only when S_i > 0.
    idle = qlen <= 0.0
    use_pair_amt = use_pair | (s_i <= 0.0)
    want = jnp.where(use_pair_amt, pair[pick], jnp.minimum(s_i, -s_cells[pick]))
    amount = gamma_round(
        jnp.maximum(want, 0.0), n_self, t_self, win_n[pick], win_t[pick]
    )
    amount = jnp.clip(amount, 0, max_steal)
    do_steal = ((s_i > 0.0) | idle) & any_cand & (amount > 0)
    victim = owner[pick]

    # --------------------------------------- (e) request / grant (all_to_all)
    # Request vector: how many tasks I ask of each worker.  ``packed``
    # (§Perf): requests ride as u16 (amounts <= max_steal << 65535) —
    # halves the wire bytes of the request round.
    req = jnp.zeros((p,), jnp.int32).at[victim].set(
        jnp.where(do_steal, amount, 0)
    )
    if packed:
        req_in = lax.all_to_all(req.astype(jnp.uint16), axis, 0, 0).astype(
            jnp.int32
        )
    else:
        req_in = lax.all_to_all(req, axis, 0, 0)  # req_in[j] = j's ask of me
    # Grant greedily, largest request first, bounded by my queue.
    order = jnp.argsort(-req_in)
    sorted_req = req_in[order]
    avail = jnp.maximum(tail - head, 0)
    cum_before = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(sorted_req)[:-1]]
    )
    sorted_grant = jnp.clip(avail - cum_before, 0, sorted_req)
    grant = jnp.zeros((p,), jnp.int32).at[order].set(sorted_grant)
    grant_off = jnp.zeros((p,), jnp.int32).at[order].set(cum_before)
    total_grant = grant.sum()

    # Build payload [p, max_steal]: tasks popped from my tail.
    sslot = jnp.arange(max_steal, dtype=jnp.int32)[None, :]
    src = tail - 1 - (grant_off[:, None] + sslot)
    valid = sslot < grant[:, None]
    cap = queue.shape[0]
    use_u16 = packed and cap < 0xFFFF
    if use_u16:
        # Task ids < capacity fit u16: halves the payload exchange — the
        # dominant collective of the round (§Perf).
        payload = jnp.where(
            valid, queue[jnp.clip(src, 0, cap - 1)], 0xFFFF
        ).astype(jnp.uint16)
        recv = lax.all_to_all(payload, axis, 0, 0)  # [p, max_steal] u16
        got = recv != 0xFFFF
        recv_ids = recv.astype(jnp.int32)
    else:
        payload = jnp.where(valid, queue[jnp.clip(src, 0, cap - 1)], -1)
        recv = lax.all_to_all(payload, axis, 0, 0)  # [p, max_steal]
        got = recv >= 0
        recv_ids = recv
    tail = tail - total_grant
    incoming = got.sum().astype(jnp.int32)

    if packed:
        # Cumsum compaction (stable, two passes) instead of a full sort
        # (log^2 n bitonic passes) — received order is irrelevant.
        gotf = got.reshape(-1)
        pos = jnp.cumsum(gotf.astype(jnp.int32)) - 1
        dst = jnp.where(gotf, tail + pos, cap)
        queue = queue.at[dst].set(recv_ids.reshape(-1), mode="drop")
    else:
        flat = jnp.sort(
            jnp.where(got, recv_ids, jnp.iinfo(jnp.int32).max).reshape(-1)
        )  # valid ids first, sentinel-padded
        ok = jnp.arange(flat.shape[0], dtype=jnp.int32) < incoming
        dst = jnp.where(
            ok, tail + jnp.arange(flat.shape[0], dtype=jnp.int32), cap
        )
        queue = queue.at[dst].set(flat, mode="drop")  # index==cap -> dropped
    tail2 = tail + incoming

    qlen2 = (tail2 - head).astype(jnp.float32)
    win_q = win_q.at[radius].set(qlen2)
    win_n = win_n.at[radius].set(executed.astype(jnp.float32) + qlen2)

    return SchedState(
        queue=queue[None],
        head=head[None],
        tail=tail2[None],
        executed=executed[None],
        t_avg=t_avg[None],
        clock=clock[None],
        win_n=win_n[None],
        win_t=win_t[None],
        win_q=win_q[None],
        key=jax.random.key_data(key)[None],
        credit=credit[None],
    )


def make_round_fn(mesh: Mesh, axis: str, radius: int, max_steal: int,
                  execute: bool = True, packed: bool = True):
    """shard_map-wrapped jitted round function over ``axis`` of ``mesh``."""
    p = mesh.shape[axis]
    spec = SchedState(
        queue=P(axis, None), head=P(axis), tail=P(axis), executed=P(axis),
        t_avg=P(axis), clock=P(axis), win_n=P(axis, None),
        win_t=P(axis, None), win_q=P(axis, None), key=P(axis, None),
        credit=P(axis),
    )
    fn = functools.partial(
        a2ws_round, axis=axis, radius=radius, max_steal=max_steal,
        num_workers=p, execute=execute, packed=packed,
    )
    sharded = shard_map_compat(fn, mesh=mesh, in_specs=(spec,), out_specs=spec)
    return jax.jit(sharded)


def virtual_run(
    mesh: Mesh,
    axis: str,
    speeds,
    num_tasks: int,
    radius: int,
    max_steal: int = 8,
    max_rounds: int = 4096,
    seed: int = 0,
):
    """Run the jitted scheduler to completion in virtual time.

    Returns (final_state, rounds, makespan).  Fully compiled: a
    ``lax.while_loop`` around the shard_map round — this is the cell used for
    the technique's own dry-run/roofline entry.
    """
    p = mesh.shape[axis]
    speeds = jnp.asarray(speeds, jnp.float32)
    base, rem = divmod(num_tasks, p)
    counts = jnp.array([base + (1 if i < rem else 0) for i in range(p)], jnp.int32)
    state = init_state(p, counts, speeds, radius, capacity=num_tasks, seed=seed)
    round_fn = make_round_fn(mesh, axis, radius, max_steal)

    def cond(carry):
        state, rounds = carry
        remaining = (state.tail - state.head).sum()
        return (remaining > 0) & (rounds < max_rounds)

    def body(carry):
        state, rounds = carry
        return round_fn(state), rounds + 1

    state, rounds = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    makespan = state.clock.max()
    return state, int(rounds), float(makespan)

"""Policy-parametric threaded worker-pool substrate (+ A2WS Algorithm 1).

``WorkerPool`` is the **control plane** of the framework: worker threads (one
per heterogeneous worker group / node) execute opaque tasks and keep
per-worker deques (``repro.core.deque``); shared memory between threads
stands in for MPI RMA windows — the protocol (packed head/tail
get-accumulate, partitioned info Puts, preemptive wall-time speed estimates)
is the paper's, see DESIGN.md §2 for the adaptation argument.

WHICH tasks move, and when, is decided by a pluggable ``SchedPolicy``
(``repro.core.policy``): the paper's adaptive A2WS over the §2.1 info ring,
the CTWS token, the LW central leader, or classical random stealing — all on
this one substrate, so comparisons isolate the scheduling policy.  The
discrete-event simulator (``repro.core.simulator``) drives the SAME policy
objects under virtual time (DESIGN.md §Policy layer).

The pool is generic over the task payload: the seismic driver feeds shots,
the training runtime (``repro.runtime.het_dp``) feeds microbatches, the
server feeds request batches.

Two workload modes (DESIGN.md §Open-arrival), available to EVERY policy:

* **closed** (the paper's Algorithm 1): every task is known up front,
  statically partitioned (§2.2.1), and the run ends when the fixed task count
  has executed.
* **open-arrival** (``open_arrival=True``): tasks are injected with
  ``submit()`` while the run loop is live; ``drain()`` announces that no
  further tasks will arrive and termination is detected by quiescence —
  "my deque is empty" no longer means "the workload is finished".

Algorithm 1 mapping (line numbers from the paper; policy = A2WSPolicy):

    1  while the process has task do            -> _worker_loop
    2    update_process_info()                  -> _update_info
    3-8  if ran a task: S=steal_equation();     -> policy.on_boundary
         v=select_victim(S); steal_task(v,S)       + _policy_boundary
    10   T_id = get_task_id()                   -> deque.get_task
    11   update_process_info()                  -> _update_info
    12   execute(T_id)                          -> task_fn
    13   info_communication()                   -> RingInfo.communicate
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from .deque import AtomicInt64, Task, TaskDeque, slo_key
from .info_ring import CellBoard, RingInfo
from .limp import (
    LimpConfig,
    LimpState,
    SlowdownSchedule,
    effective_heartbeat,
    normalize_duration,
)
from .netfault import NF_SEED_SALT, LinkHealth, NetFaultSchedule
from .policy import PolicyView, SchedPolicy, make_policy
from .steal import OverlayBuffers, class_counts, weighted_overlay
from .topology import Topology

__all__ = [
    "WorkerPool",
    "A2WSRuntime",
    "PoolCollapsed",
    "RunStats",
    "TaskRecord",
    "latency_percentiles",
    "partition_tasks",
]


class PoolCollapsed(RuntimeError):
    """``submit()`` into a pool with no live worker: nothing can ever run
    the task (every worker died or retired).  Distinct from the plain
    ``RuntimeError`` of submit-after-drain so servers can fail the one
    request instead of treating the pool as cleanly shut down."""


#: Default latency quantiles.  p99.9 rides along since the SLO plane — at
#: trace scale (10^6 requests) p99 hides the tail the SLO targets.
DEFAULT_QS = (50.0, 95.0, 99.0, 99.9)


def latency_percentiles(
    latencies: Sequence[float], qs: Sequence[float] = DEFAULT_QS
) -> dict[float, float]:
    """Per-task latency percentiles ({} when there are no samples) — shared
    by the threaded runtime's RunStats and the simulator's SimResult."""
    if not latencies:
        return {}
    vals = np.percentile(np.asarray(latencies, dtype=np.float64), list(qs))
    return {float(q): float(v) for q, v in zip(qs, vals)}


@dataclass
class TaskRecord:
    task: object
    worker: int
    start: float
    end: float
    arrival: float = float("nan")  # submit time (open-arrival); NaN = at boot

    @property
    def latency(self) -> float:
        """Arrival-to-completion sojourn time (open-arrival telemetry)."""
        return self.end - self.arrival


@dataclass
class RunStats:
    makespan: float
    records: list[TaskRecord]
    steals: list[tuple[float, int, int, int]]  # (time, thief, victim, amount)
    failed_steals: int
    info_cells_sent: int
    corrections: int
    per_worker_tasks: list[int] = field(default_factory=list)
    per_worker_mean_t: list[float] = field(default_factory=list)
    # Fault-fabric telemetry (DESIGN.md §Fault fabric); all zero when the
    # pool runs with netfaults=None.
    net_failed: int = 0  # steal requests lost to drops / partitions
    lease_expired: int = 0  # transfers returned to the victim on expiry
    fare_paid: float = 0.0  # total transport fare slept before loot landed

    @property
    def latencies(self) -> list[float]:
        """Per-task sojourn times for records with a known arrival time."""
        return [r.latency for r in self.records if r.arrival == r.arrival]

    def latency_percentiles(
        self, qs: Sequence[float] = DEFAULT_QS
    ) -> dict[float, float]:
        """Latency percentiles of the open-arrival run (empty dict if the run
        was closed — no arrival stamps to measure against)."""
        return latency_percentiles(self.latencies, qs)

    def slo_stats(self) -> dict[str, dict[str, float]]:
        """Per-SLO-class telemetry (DESIGN.md §SLO serving): task count,
        deadline violations + rate, and latency percentiles, keyed by class
        name.  Classes with no tasks are omitted; a run whose payloads carry
        no SLO attributes reports everything under ``"batch"``."""
        from .deque import SLO_NAMES, slo_of

        per: dict[str, dict[str, object]] = {}
        for r in self.records:
            s, d, _ = slo_of(r.task)
            b = per.setdefault(
                SLO_NAMES[s], {"count": 0, "violations": 0, "lats": []}
            )
            b["count"] += 1
            if r.end > d:
                b["violations"] += 1
            if r.arrival == r.arrival:
                b["lats"].append(r.latency)
        out: dict[str, dict[str, float]] = {}
        for name, b in per.items():
            pct = latency_percentiles(b["lats"])
            out[name] = {
                "count": float(b["count"]),
                "violations": float(b["violations"]),
                "violation_rate": b["violations"] / max(b["count"], 1),
                **{f"p{q:g}": v for q, v in pct.items()},
            }
        return out

    def summary(self) -> str:
        counts = ",".join(str(c) for c in self.per_worker_tasks)
        out = (
            f"makespan={self.makespan:.4f}s steals={len(self.steals)} "
            f"failed={self.failed_steals} cells={self.info_cells_sent} "
            f"tasks/worker=[{counts}]"
        )
        pct = self.latency_percentiles()
        if pct:
            out += " lat[p50/p95/p99/p99.9]=" + "/".join(
                f"{pct[q]*1e3:.1f}ms" for q in DEFAULT_QS
            )
        slo = self.slo_stats()
        if len(slo) > 1 or "latency" in slo:
            out += " slo[" + " ".join(
                f"{name}={int(b['violations'])}/{int(b['count'])}viol"
                for name, b in sorted(slo.items())
            ) + "]"
        return out


def partition_tasks(tasks: Sequence, num_workers: int) -> list[list]:
    """Static block partition used before execution starts (§2.2.1: "A2WS
    distributes the tasks statically just before execution starts")."""
    out: list[list] = [[] for _ in range(num_workers)]
    base, rem = divmod(len(tasks), num_workers)
    pos = 0
    for w in range(num_workers):
        k = base + (1 if w < rem else 0)
        out[w] = list(tasks[pos : pos + k])
        pos += k
    return out


class _WorkerState:
    __slots__ = (
        "deque", "executed", "runtime_sum", "ran_any", "start_time", "rng",
        "wake", "retiring", "drain_on_retire", "class_t", "nc_cache",
        "limp_state", "slow_mult", "overlay_buf", "nf_rng", "heal_idx",
    )

    def __init__(
        self,
        deque: TaskDeque,
        seed: int,
        num_classes: int = 1,
        limp_cfg: LimpConfig | None = None,
    ) -> None:
        self.deque = deque
        self.executed = 0
        self.runtime_sum = 0.0
        self.ran_any = False
        self.start_time = 0.0
        self.rng = np.random.default_rng(seed)
        # Straggler plane (DESIGN.md §Straggler plane): owner-side limp
        # detector (None = detection off) and the manually injected live
        # slowdown multiplier (set_worker_slowdown — fault injection).
        self.limp_state = LimpState(limp_cfg) if limp_cfg is not None else None
        self.slow_mult = 1.0
        # Per-cost-class EWMA runtime estimates t̂[c] (NaN = never ran one);
        # written only by the owner thread, published via the info ring.
        self.class_t = np.full(num_classes, np.nan, dtype=np.float64)
        # (mutations, headtail word) -> cached queue-composition scan; the
        # scan is O(queue) under a lock and sits on the per-boundary hot
        # path, so it must only re-run when the deque actually changed.
        self.nc_cache: tuple[tuple[int, int], np.ndarray] | None = None
        # Preallocated weighted-overlay scratch (steal.OverlayBuffers),
        # lazily keyed on the (view size, num_classes) this worker last saw —
        # per-worker, so reuse never races another boundary's view.
        self.overlay_buf: OverlayBuffers | None = None
        # Per-worker wake event: a submit()/drain()/death sets EVERY event,
        # but each worker clears only its OWN — a busy worker's clear can
        # therefore never erase a wakeup meant for an idle sleeper (the
        # lost-wakeup bug a single shared Event had).
        self.wake = threading.Event()
        self.retiring = False
        self.drain_on_retire = True
        # Fault plane (DESIGN.md §Fault fabric): dedicated message-drop rng
        # (derived from the worker seed so the SCHEDULING rng stream stays
        # bit-for-bit untouched) and the per-worker heal cursor into
        # NetFaultSchedule.heal_times() — advanced at the first boundary
        # after each partition heals, triggering ring resync.
        self.nf_rng: np.random.Generator | None = None
        self.heal_idx = 0


class WorkerPool:
    """Threaded executor for ``num_workers`` heterogeneous workers, load
    balanced by a pluggable scheduling policy."""

    def __init__(
        self,
        tasks: Sequence,
        num_workers: int,
        task_fn: Callable[[int, object], object],
        *,
        policy: str | SchedPolicy = "a2ws",
        radius: int | None = None,
        seed: int = 0,
        idle_backoff: float = 1e-4,
        idle_backoff_max: float | None = None,
        clock: Callable[[], float] = time.perf_counter,
        open_arrival: bool = False,
        cost_class_fn: Callable[[object], int] | None = None,
        num_classes: int = 1,
        ewma_alpha: float = 0.25,
        slowdown: SlowdownSchedule | None = None,
        limp: LimpConfig | None = None,
        topology: Topology | None = None,
        netfaults: NetFaultSchedule | None = None,
        slo: bool = False,
        slo_aging: float = math.inf,
    ) -> None:
        """``task_fn(worker_id, task) -> result`` runs the task on a worker.

        ``policy``: a ``SchedPolicy`` instance or registry name ("a2ws",
        "ctws", "lw", "random").  The policy decides steals at every task
        boundary; the pool owns deques, threads, termination and telemetry.

        ``radius`` defaults to the paper's operating point: 20% of the number
        of workers (Fig. 4 discussion), at least 1.  Only ring policies
        (``policy.uses_ring``) build the info board.

        ``open_arrival``: accept ``submit()`` while running and terminate by
        quiescence (DESIGN.md §Open-arrival) instead of the closed-workload
        fixed task count.  ``tasks`` may then be empty — it seeds the deques
        exactly like the closed static partition would.

        ``idle_backoff`` / ``idle_backoff_max``: an idle worker that failed
        to steal sleeps ``idle_backoff`` seconds, doubling per consecutive
        miss up to the cap (default 50× the base) — long-lived open-arrival
        pools must not spin at full speed between request waves.  A
        ``submit()`` wakes sleepers immediately.

        ``cost_class_fn`` / ``num_classes`` / ``ewma_alpha``: work-weighted
        stealing (DESIGN.md §Work-weighted stealing).  ``cost_class_fn(task)
        -> int`` tags every payload with a cost class in ``[0, num_classes)``
        (clamped; a raising classifier falls back to class 0 — never let
        accounting kill a worker).  Workers then track per-class EWMA
        runtimes (smoothing ``ewma_alpha``), publish per-class queue counts
        through the info ring, and ring policies price queues in estimated
        work-seconds.  Without a classifier the pool runs the count-based
        degenerate case — bit-for-bit the old behaviour.

        ``slowdown`` / ``limp``: the straggler plane (DESIGN.md §Straggler
        plane).  ``slowdown`` is a scripted :class:`SlowdownSchedule` of
        degraded-but-alive faults — each worker's task execution stalls by
        the scheduled multiplier (wall-clock, sleep-paced so the GIL stays
        fair), times measured from ``start()``; ``set_worker_slowdown``
        injects a live multiplier on top.  ``limp`` enables the owner-side
        limp DETECTOR (:class:`LimpConfig`): a flagged worker re-prices its
        published t so thieves strip its queue, stops initiating steals,
        and ``submit()`` stops routing new work to it.  ``limp=None`` keeps
        every policy bit-for-bit blind to stragglers.

        ``topology``: the network-cost model (DESIGN.md §Topology plane).
        When set, every policy view carries ``transfer_cost(j, ntasks)`` =
        seconds to move loot from j to this worker, so victim selection is
        distance-penalized, net-negative steals are refused, and a priced
        plan moves its loot as ONE batched transfer whose cost the thief
        pays in clock time (``StealPlan.delay``) before the loot lands.
        ``topology=None`` (default) is bit-for-bit the unpriced scheduler.

        ``netfaults``: the network-fault plane (DESIGN.md §Fault fabric).
        A :class:`NetFaultSchedule` of lossy links and timed partitions is
        injected into the steal transaction: a dropped/partitioned request
        leg is a failed attempt (timeout stall + per-link backoff when
        ``hardened``); a dropped transfer leg holds the loot in flight for
        ``lease_timeout`` and then RETURNS it to the victim (the threaded
        plane carries real payloads, so loot is never destroyed — the
        delivery-semantics table in DESIGN.md records this deliberate
        divergence from the simulator's un-hardened ablation).  Partitioned
        peers go heartbeat-stale in the OBSERVER's view only, ring gossip
        is gated per-link, and the first boundary after a heal resyncs the
        worker's send watermarks.  ``netfaults=None`` (default) is
        bit-for-bit the fault-free scheduler, including every rng stream.

        ``slo`` / ``slo_aging``: SLO-ordered owner pops (DESIGN.md §SLO
        serving).  When enabled, each worker pops its OWN deque through
        :func:`repro.core.deque.slo_key` — latency-class tasks jump
        batch-class tasks, earliest deadline first within class, and a
        batch task older than ``slo_aging`` seconds is promoted so a
        latency flood can never starve it.  SLO attributes come from the
        payloads themselves (:class:`repro.core.deque.Task` records or
        future-likes with ``slo_class``/``deadline``); plain payloads are
        batch-class, so ``slo=True`` over plain payloads degenerates to
        ordinary LIFO pops.  Thief-end steals are UNCHANGED — they strip
        the oldest tail slots, i.e. batch work preferentially.
        ``slo=False`` (default) takes the PR-9 head-pop path bit-for-bit.
        """
        self.num_workers = num_workers
        self.task_fn = task_fn
        self.policy = make_policy(policy, num_workers)
        self.seed = seed
        # The paper's 20% operating point tracks an ELASTIC pool: unless the
        # caller pinned a radius, membership changes recompute it.
        self._radius_explicit = radius is not None
        self.radius = radius if radius is not None else max(1, round(0.2 * num_workers))
        self.idle_backoff = idle_backoff
        self.idle_backoff_max = (
            idle_backoff_max if idle_backoff_max is not None else idle_backoff * 50
        )
        self.clock = clock
        self.open_arrival = open_arrival
        if num_classes < 1:
            raise ValueError("num_classes must be >= 1")
        self.cost_class_fn = cost_class_fn
        self.num_classes = num_classes if cost_class_fn is not None else 1
        self.ewma_alpha = ewma_alpha
        self.slowdown = slowdown
        self.limp_cfg = limp
        self.topology = topology
        self.netfaults = netfaults
        if not slo_aging > 0.0:  # also rejects NaN
            raise ValueError(f"slo_aging {slo_aging} must be > 0 (or inf)")
        self.slo = slo
        self.slo_aging = slo_aging
        # Shared per-(thief, victim) link-health tracker; single writer per
        # key (the thief thread), so plain dict mutation is GIL-safe.
        self._link_health = LinkHealth(netfaults) if netfaults is not None else None
        self._nf_lossy = netfaults is not None and netfaults.lossy()
        self._heal_times = netfaults.heal_times() if netfaults is not None else []
        # Fault-plane telemetry (written under _log_lock on the steal path).
        self._net_failed = 0
        self._lease_expired = 0
        self._fare_paid = 0.0
        # Owner-written limp flags (one bool per ring slot; plain list —
        # CPython element writes are atomic, readers tolerate staleness).
        self._limping: list[bool] = [False] * num_workers
        #: (time, worker, flagged) limp-detector transition telemetry
        self.limp_log: list[tuple[float, int, bool]] = []
        # Wedge detector (DESIGN.md §Straggler plane, LimpConfig.stale_after):
        # per-ring-slot heartbeat — the last time the worker's OWN loop
        # reached a boundary (`_update_info`), NaN until its first one.  A
        # worker stuck inside a task stops beating; an idle-but-healthy
        # worker keeps beating through its poll loop.  `_stale_flagged`
        # records whether the STALENESS path (not the owner EWMA) holds the
        # limp flag.  Plain lists, benign races: a lost update delays one
        # staleness verdict by one boundary.
        self._hb_beat: list[float] = [float("nan")] * num_workers
        self._stale_flagged: list[bool] = [False] * num_workers
        parts = self.policy.partition(tasks, num_workers)
        self.workers = [
            _WorkerState(
                TaskDeque(parts[w]), seed * 1009 + w, self.num_classes,
                limp_cfg=limp,
            )
            for w in range(num_workers)
        ]
        if netfaults is not None:
            for w in range(num_workers):
                self.workers[w].nf_rng = np.random.default_rng(
                    (seed * 1009 + w) ^ NF_SEED_SALT
                )
        # Hierarchy scoping (DESIGN.md §Hierarchy): a policy that carries a
        # CellMap gets one sub-board per cell and CELL-scoped views; the
        # substrate keeps speaking global ids throughout.
        self.cells = getattr(self.policy, "cells", None)
        if self.cells is not None and self.cells.num_workers != num_workers:
            raise ValueError(
                f"policy cell map covers {self.cells.num_workers} workers, "
                f"pool has {num_workers}"
            )
        # The §2.1 information board exists only for ring policies; central
        # or probe-based policies (LW, CTWS, random) pay no cell traffic.
        if not self.policy.uses_ring:
            self.info = None
        elif self.cells is not None:
            self.info = CellBoard(self.cells, self.num_classes)
            # Hand the board to the policy so leader-level member migration
            # can re-home sub-board columns (threaded plane only).
            self.policy.bind_board(self.info)
        else:
            self.info = RingInfo(num_workers, self.radius, self.num_classes)
        if topology is not None:
            # Per-boundary pricing flows through the view hook; the policy
            # hook exists for state that prices GLOBAL pairs outside a view
            # (the hierarchical leader balancer's cross-cell gate).
            self.policy.bind_topology(topology)
        self.done_counter = AtomicInt64(0)
        # Tasks ever made visible to the runtime (seed partition + submits).
        # Quiescence: submitted is bumped BEFORE the task is pushed, so
        # ``done >= submitted`` can only hold when no task is seeded, queued,
        # in flight, or mid-injection — see _finished.
        self.submitted = AtomicInt64(len(tasks))
        self.alive = AtomicInt64(num_workers)
        # Failure tombstones (the heartbeat/failure-detector channel of a
        # real deployment): a dead worker's info-vector cells go stale, so
        # thieves must stop trusting them — see _ring_view.
        self.dead = [False] * num_workers
        self.errors: list[tuple[int, object, BaseException]] = []
        self._steal_log: list[tuple[float, int, int, int]] = []
        self._failed_steals = 0
        self._records: list[TaskRecord] = []
        self._log_lock = threading.Lock()
        self._arrivals: dict[int, float] = {}  # id(task) -> submit time
        self._drained = threading.Event()
        if not open_arrival:
            self._drained.set()  # closed workload: nothing will ever arrive
        # Serialises the drained-check against drain() so a concurrent
        # submit can never slip a task past an exiting run loop.
        self._submit_lock = threading.Lock()
        # Serialises membership changes (add_worker/retire_worker) against
        # each other; readers stay lock-free — every membership structure
        # only ever APPENDS (workers, dead) or swaps whole boards (RingInfo
        # epoch guard), so a racing reader sees a valid old or new state.
        self._membership_lock = threading.Lock()
        #: (time, "join" | "retire" | "death", worker) membership telemetry
        self.membership_log: list[tuple[float, str, int]] = []
        self._rr = AtomicInt64(0)  # round-robin router for submit()
        self._threads: list[threading.Thread] = []
        # Per-SLOT thread handle (reuse gate: a tombstoned slot may only be
        # recycled once its old thread has fully exited — two threads must
        # never run the same worker loop).
        self._slot_threads: list[threading.Thread | None] = [None] * num_workers
        self._t0: float | None = None
        # Total-collapse hook: called exactly once, by the last dying
        # worker, with every task left stranded in the deques — so a caller
        # (ServePool) can fail the corresponding waiters instead of hanging.
        self.on_collapse: Callable[[list], None] | None = None

    # --------------------------------------------------------- open arrivals
    def submit(self, task, worker: int | None = None) -> int:
        """Thread-safe task injection while the run loop is live.

        Routes to ``worker`` when given, else to the policy's central queue
        (LW) when it declares one, else round-robins across live workers
        (the front-end sprays; adaptive stealing balances, §2.2).  Returns
        the worker the task landed on.  Valid in open-arrival mode only, any
        time before ``drain()``.  Raises :class:`PoolCollapsed` when no live
        worker exists — a task pushed onto a dead pool's deques would strand
        forever (detected again AFTER the push, in case the last worker dies
        mid-injection; the stranded sweep then routes to ``on_collapse``).
        """
        if not self.open_arrival:
            raise RuntimeError("submit() requires open_arrival=True")
        if self.alive.load() == 0:
            raise PoolCollapsed("submit() into a collapsed pool (no live workers)")
        if worker is None:
            central = self.policy.central
            if central is not None and self._routable(central):
                worker = central
            else:
                num = self.num_workers
                fallback = None
                for _ in range(num):
                    cand = self._rr.get_accumulate(1) % num
                    if self._routable(cand):
                        # Straggler response: keep fresh submits OFF a
                        # flagged-limping worker (its collapsed speed would
                        # bake straight into the task's latency) — unless
                        # every routable worker is limping, where serving
                        # slowly beats not serving at all.  Exception: the
                        # probation canaries — every Nth diverted task still
                        # lands on the flagged worker, the only completions
                        # that can ever clear its flag.
                        if not self._limping[cand]:
                            worker = cand
                            break
                        st = self.workers[cand].limp_state
                        if st is not None and st.should_probe():
                            worker = cand  # probation canary
                            break
                        if fallback is None:
                            fallback = cand
                else:
                    if fallback is not None:
                        worker = fallback
                    else:
                        # Every worker died/retired between the alive check
                        # and the scan — never settle on a dead deque.
                        raise PoolCollapsed(
                            "submit() into a collapsed pool (no live workers)"
                        )
        elif not 0 <= worker < self.num_workers:
            # Validate BEFORE touching the quiescence counter: a failed push
            # after the accumulate would leave `submitted` permanently ahead
            # of `done` and hang every later join().
            raise ValueError(f"worker {worker} out of range 0..{self.num_workers - 1}")
        now = self.clock()
        if type(task) is Task and task.arrival != task.arrival:
            # First-class records carry their own arrival (read by SLO aging
            # and telemetry); the stamp stack below still pairs completions.
            task.arrival = now
        with self._log_lock:
            # A stamp STACK per id: the same (or interned) payload object may
            # be submitted several times; pairing completions with the oldest
            # stamp keeps counts conserved and latencies non-negative.
            self._arrivals.setdefault(id(task), []).append(now)
        # Order matters for quiescence: count it, then make it stealable —
        # and the drained-check must be atomic with the count (a drain()
        # racing in between could let every worker exit while this task is
        # still on its way into a deque).
        with self._submit_lock:
            if self._drained.is_set():
                with self._log_lock:
                    stamps = self._arrivals.get(id(task))
                    if stamps:
                        stamps.pop()
                        if not stamps:
                            del self._arrivals[id(task)]
                raise RuntimeError("submit() after drain()")
            self.submitted.accumulate(1)
        self.workers[worker].deque.push([task])
        self._wake_all()
        if self.alive.load() == 0:
            # Total collapse raced the push: the last worker's dying sweep
            # may have missed this task — nobody will ever pop it.  Sweep
            # again (the hook fails the corresponding waiters), or — with no
            # hook — leave the queue in place for a possible resurrection
            # and surface the strand to the caller.
            if self._collapse_sweep() == 0 and self.on_collapse is None:
                raise PoolCollapsed(
                    "pool collapsed mid-submit; the task stays queued and "
                    "runs only if the pool is resurrected via add_worker() "
                    "— do not blindly resubmit"
                )
        return worker

    def _collapse_sweep(self) -> int:
        """Total collapse with a registered hook: pop every stranded task,
        hand the batch to ``on_collapse`` (which fails the waiters), and
        RECONCILE the quiescence counters — a swept task is permanently
        resolved, so it must count as done or ``pending()`` stays positive
        forever and a later resurrection (``add_worker``) could never reach
        quiescence.  Without a hook the queues are left intact (a
        resurrected pool serves them) and nothing is counted.  Returns the
        number of swept tasks."""
        if self.on_collapse is None:
            return 0
        stranded = self.drain_leftover_tasks()
        if stranded:
            self.done_counter.accumulate(len(stranded))
            self.on_collapse(stranded)
        return len(stranded)

    def _routable(self, worker: int) -> bool:
        """May ``submit()`` place new work on this worker's deque?"""
        return not self.dead[worker] and not self.workers[worker].retiring

    def _wake_all(self) -> None:
        """Wake every idle sleeper (submit/drain/membership/death events).
        Sets each worker's PRIVATE event — only its owner clears it, so a
        busy worker cycling through its loop cannot eat another's wakeup."""
        for w in self.workers:
            w.wake.set()

    def submit_many(self, tasks: Sequence, worker: int | None = None) -> list[int]:
        return [self.submit(t, worker) for t in tasks]

    def drain(self) -> None:
        """Announce end-of-workload: no further ``submit()`` is coming.  The
        run loop then exits as soon as quiescence is reached."""
        with self._submit_lock:
            self._drained.set()
        self._wake_all()

    def drain_leftover_tasks(self) -> list:
        """Pop every task still sitting in any deque.  Only meaningful once
        no worker will serve them again (after ``join()``, or from the
        collapse hook) — used to fail the waiters of stranded tasks."""
        leftover: list = []
        for w in self.workers:
            while True:
                task = w.deque.get_task()
                if task is None:
                    break
                leftover.append(task)
        return leftover

    def pending(self) -> int:
        """Tasks submitted but not yet executed (queued + in flight)."""
        return self.submitted.load() - self.done_counter.load()

    # ------------------------------------------------- elastic membership
    def add_worker(
        self, on_assign: Callable[[int], None] | None = None
    ) -> int:
        """Boot ONE new worker thread into the live pool (elastic scale-out,
        DESIGN.md §Elasticity) and return its id.

        Slot policy: the lowest tombstoned slot whose old thread has fully
        exited is REUSED (spot-preemption-with-replacement; an autoscaled
        pool cycling out/in keeps a bounded ring instead of growing O(P²)
        board state per surge) — the replacement inherits the tombstone's
        deque, so any still-orphaned tasks come back to life with it, and
        its info column resets to the unreported state.  Only when no such
        slot exists does the ring grow by one appended position.

        Either way the joiner immediately participates as a thief, so
        existing work flows to it through the ordinary steal protocol — no
        re-partitioning — and every other member prices it by the §2.2.1
        preemptive wall-time estimate (NaN cells) exactly like an
        unreported boot member.  Joining a COLLAPSED pool resurrects it —
        but note any ``on_collapse`` sweep that already fired kept its word
        to the old waiters.

        ``on_assign(wid)`` runs under the membership lock after the id is
        fixed but BEFORE the worker thread starts — callers that index
        side tables by worker id (``ServePool.replicas``) install the entry
        there, never racing the first ``task_fn`` call.

        Telemetry note: a recycled slot's per-worker counters
        (``per_worker_tasks``/``per_worker_mean_t``) restart with the
        replacement; ``RunStats.records`` keeps every incarnation's tasks.
        """
        with self._membership_lock:
            if self._t0 is None:
                raise RuntimeError("add_worker() requires a started pool")
            wid = next(
                (
                    k for k in range(len(self.workers))
                    if self.dead[k]
                    and self._slot_threads[k] is not None
                    and not self._slot_threads[k].is_alive()
                ),
                len(self.workers),
            )
            now = self.clock()
            if wid < len(self.workers):
                # Replacement: fresh run state, inherited deque (orphans on
                # the tombstone become the joiner's backlog).
                w = _WorkerState(
                    self.workers[wid].deque, self.seed * 1009 + wid,
                    self.num_classes, limp_cfg=self.limp_cfg,
                )
                w.start_time = now
                self.workers[wid] = w
                if self.netfaults is not None:
                    w.nf_rng = np.random.default_rng(
                        (self.seed * 1009 + wid) ^ NF_SEED_SALT
                    )
                self._limping[wid] = False  # the ghost's flag dies with it
                self._hb_beat[wid] = float("nan")  # heartbeat restarts too
                self._stale_flagged[wid] = False
                if self.info is not None:
                    self.info.reset_member(wid)  # back to the unreported state
                self.dead[wid] = False
            else:
                w = _WorkerState(
                    TaskDeque([]), self.seed * 1009 + wid, self.num_classes,
                    limp_cfg=self.limp_cfg,
                )
                w.start_time = now  # preemptive-estimate baseline = NOW
                if self.netfaults is not None:
                    w.nf_rng = np.random.default_rng(
                        (self.seed * 1009 + wid) ^ NF_SEED_SALT
                    )
                # Append order matters for lock-free readers: the worker and
                # its tombstone slot exist BEFORE any count admits id wid.
                self.workers.append(w)
                self.dead.append(False)
                self._limping.append(False)
                self._hb_beat.append(float("nan"))
                self._stale_flagged.append(False)
                self._slot_threads.append(None)
                self.num_workers = len(self.workers)
                if not self._radius_explicit:
                    self.radius = max(1, round(0.2 * self.num_workers))
                if self.info is not None and self.cells is None:
                    self.info.grow(self.num_workers, self.radius)
            # (No own-cell publish here: the joiner's loop does it as its
            # first action — §2.2.1 elapsed-time self-report, as at boot —
            # and until then every thief prices the NaN cell preemptively.)
            if self.netfaults is not None:
                # A joiner is born past any already-healed partitions: start
                # its heal cursor beyond them so it never replays a resync.
                tj = now - self._t0 if self._t0 is not None else 0.0
                w.heal_idx = sum(1 for h in self._heal_times if h <= tj)
            self.alive.accumulate(1)
            self.policy.on_worker_join(wid, now)
            if self.info is not None and self.cells is not None:
                # Hierarchy ordering: the join hook HOMED the joiner (CellMap
                # assign), so only now can its cell's sub-board grow to cover
                # the new local slot.  Readers that race the gap clamp their
                # member list to the board rows they copied (_ring_view).
                self.info.ensure(wid)
            with self._log_lock:
                self.membership_log.append((now, "join", wid))
            if on_assign is not None:
                on_assign(wid)
            th = threading.Thread(
                target=self._worker_loop, args=(wid,), daemon=True
            )
            self._slot_threads[wid] = th
            self._threads.append(th)
            th.start()
        self._wake_all()  # sleepers re-derive windows over the new ring
        return wid

    def retire_worker(self, worker: int, drain: bool = True) -> None:
        """Gracefully remove ``worker`` from the live pool (scale-in /
        maintenance drain).  Asynchronous: the worker finishes its in-flight
        task, then — with ``drain=True`` — re-distributes its queued tasks
        over the surviving workers before tombstoning itself and exiting;
        ``drain=False`` tombstones immediately and leaves the queue on the
        (still readable) dead deque for thieves to reclaim, i.e. the fault
        path minus the crash.  Idempotent; retiring the last live worker
        collapses the pool (the ``on_collapse`` sweep runs as on death).
        """
        with self._membership_lock:
            if not 0 <= worker < self.num_workers:
                raise ValueError(
                    f"worker {worker} out of range 0..{self.num_workers - 1}"
                )
            w = self.workers[worker]
            if self.dead[worker] or w.retiring:
                return
            w.drain_on_retire = drain
            w.retiring = True
        self._wake_all()  # a sleeping retiree must wake to process the flag

    def _retire(self, i: int, w: _WorkerState) -> None:
        """Executed ON the retiring worker's thread at a task boundary — it
        never interrupts a task mid-flight."""
        self.dead[i] = True  # tombstone first: submit() stops routing here
        if w.drain_on_retire:
            targets = [
                j for j in range(self.num_workers)
                if j != i and not self.dead[j] and not self.workers[j].retiring
            ]
            leftover = []
            while True:
                task = w.deque.get_task()
                if task is None:
                    break
                leftover.append(task)
            if targets:
                for k, task in enumerate(leftover):
                    self.workers[targets[k % len(targets)]].deque.push([task])
            else:
                # Nobody left to hand them to; keep them visible on the dead
                # deque so the collapse sweep below can fail their waiters.
                w.deque.push(leftover)
        if self.info is not None:
            self._update_info(i)
            self._communicate(i)
        now = self.clock()
        self.policy.on_worker_death(i, now)
        with self._log_lock:
            self.membership_log.append((now, "retire", i))
        self.alive.accumulate(-1)
        self._wake_all()
        if self.alive.load() == 0:
            self._collapse_sweep()

    def _communicate(self, i: int) -> None:
        """Ring gossip for worker ``i``, gated by the fault plane.

        Partitions stop information flow: a cell cannot cross an active cut,
        so each neighbour push is filtered by reachability (``can_send``).
        The first boundary after a partition HEALS resyncs ``i``'s send
        watermarks (``RingInfo.resync``) — neighbours whose copies froze at
        the cut receive the full window again instead of nothing (the
        watermark says "already sent") — and clears ``i``'s steal backoffs,
        since the post-heal link is presumed healthy until re-observed.
        Plain message drops deliberately do NOT apply to gossip: the §2.1
        ring is modelled as eventually-consistent background traffic, and
        DESIGN.md §Fault fabric records the simplification.  With
        ``netfaults=None`` this is exactly ``info.communicate(i)``.
        """
        if self.info is None:
            return
        nf = self.netfaults
        if nf is None or self._t0 is None:
            self.info.communicate(i)
            return
        tnow = self.clock() - self._t0
        w = self.workers[i]
        if w.heal_idx < len(self._heal_times) and tnow >= self._heal_times[w.heal_idx]:
            while (
                w.heal_idx < len(self._heal_times)
                and tnow >= self._heal_times[w.heal_idx]
            ):
                w.heal_idx += 1
            self.info.resync(i)
            self._link_health.clear_backoff(i)
        if nf.partitions:
            self.info.communicate(
                i, can_send=lambda j, _i=i, _t=tnow: nf.reachable(_i, j, _t)
            )
        else:
            self.info.communicate(i)

    def _finished(self) -> bool:
        """Quiescence termination (DESIGN.md §Open-arrival).

        ``done == submitted`` means every task ever injected has finished
        executing; tasks never vanish (steals move them, worker failure
        re-queues them), so all deques are provably empty at that point.
        An empty deque alone proves nothing — the task may be in another
        worker's deque, in a thief's hands mid-transfer, or not arrived yet —
        hence the additional ``drain()`` gate before the loop may exit.
        """
        return self._drained.is_set() and (
            self.done_counter.load() >= self.submitted.load()
        )

    # ------------------------------------------------------------- Algorithm 1
    def start(self) -> None:
        """Boot the worker threads and return immediately (open-arrival
        servers feed ``submit()`` from here on; closed runs just ``join``)."""
        if self._threads:
            raise RuntimeError("runtime already started")
        t0 = self.clock()
        self._t0 = t0
        for w in self.workers:
            w.start_time = t0
        if self.info is not None:
            for i in range(self.num_workers):
                self._update_info(i)
        self.policy.on_start([len(w.deque) for w in self.workers], t0)
        self._threads = [
            threading.Thread(target=self._worker_loop, args=(i,), daemon=True)
            for i in range(self.num_workers)
        ]
        self._slot_threads = list(self._threads)
        for th in self._threads:
            th.start()

    def join(self) -> RunStats:
        """Wait for termination and return the final stats.  Open-arrival
        callers must ``drain()`` first or the workers wait forever for more
        work (by design — that is what keeps the pool alive between waves)."""
        k = 0
        while k < len(self._threads):  # add_worker may append mid-join
            self._threads[k].join()
            k += 1
        self.policy.termination(self.clock())
        return self.stats_snapshot()

    def run(self) -> RunStats:
        self.start()
        return self.join()

    def stats_snapshot(self) -> RunStats:
        """Consistent stats up to now — callable while the pool is live."""
        t1 = self.clock()
        per_tasks = [w.executed for w in self.workers]
        per_t = [
            (w.runtime_sum / w.executed) if w.executed else float("nan")
            for w in self.workers
        ]
        with self._log_lock:
            records = sorted(self._records, key=lambda r: r.start)
            steals = list(self._steal_log)
            failed = self._failed_steals
        return RunStats(
            makespan=t1 - (self._t0 if self._t0 is not None else t1),
            records=records,
            steals=steals,
            failed_steals=failed,
            info_cells_sent=self.info.puts if self.info is not None else 0,
            corrections=sum(w.deque.corrections for w in self.workers),
            per_worker_tasks=per_tasks,
            per_worker_mean_t=per_t,
            net_failed=self._net_failed,
            lease_expired=self._lease_expired,
            fare_paid=self._fare_paid,
        )

    def _worker_loop(self, i: int) -> None:
        w = self.workers[i]
        idle_misses = 0
        while not self._finished():
            if w.retiring:  # graceful leave, only ever at a task boundary
                self._retire(i, w)
                return
            if self.info is not None:
                self._update_info(i)  # line 2
            self._policy_boundary(i)  # lines 3-9 (policy gates preemption)
            w.wake.clear()  # own event only, before the deque check: a
            # concurrent submit() re-sets it and the wait below falls through
            task = w.deque.get_task(  # line 10
                slo_key(self.clock(), self.slo_aging) if self.slo else None
            )
            if task is None:
                # Empty deque: keep thieving until quiescence.
                if self.alive.load() == 0:
                    return  # every worker died; nothing left to wait for
                if self.info is not None:
                    self._communicate(i)
                if not self._policy_boundary(i):
                    idle_misses += 1
                    w.wake.wait(
                        min(
                            self.idle_backoff * (2.0 ** min(idle_misses, 30)),
                            self.idle_backoff_max,
                        )
                    )
                continue
            idle_misses = 0
            if self.info is not None:
                self._update_info(i)  # line 11
            start = self.clock()
            try:
                self.task_fn(i, task)  # line 12
            except BaseException as e:  # noqa: BLE001 — fault tolerance
                # Worker failure: return the task to the deque so survivors
                # can steal it, raise the tombstone, publish, and die.
                w.deque.push([task])
                with self._log_lock:
                    self.errors.append((i, task, e))
                self.dead[i] = True
                if self.info is not None:
                    self._update_info(i)
                    self._communicate(i)
                now = self.clock()
                self.policy.on_worker_death(i, now)
                with self._log_lock:
                    self.membership_log.append((now, "death", i))
                self.alive.accumulate(-1)
                self._wake_all()  # idle sleepers must re-check alive state
                if self.alive.load() == 0:
                    # Last worker standing just died: nobody will ever pop
                    # the remaining tasks — hand them to the caller so the
                    # corresponding waiters fail instead of hanging.
                    self._collapse_sweep()
                return
            mult = self.policy.task_multiplier(i)
            if mult > 1.0:
                _busy_wait((self.clock() - start) * (mult - 1.0), self.clock)
            slow = self._slow_factor(i, w, start)
            if slow > 1.0:
                # Degraded-but-alive fault injection: stretch the task's
                # wall time by the scripted/injected multiplier.  Sleep-
                # paced (not a busy wait) — a throttled or IO-stalled node
                # yields its cycles, and on a CI box a spinning straggler
                # would starve the very threads that should out-run it.
                _sleep_stall((self.clock() - start) * (slow - 1.0), self.clock)
            end = self.clock()
            w.executed += 1
            w.runtime_sum += end - start
            w.ran_any = True
            if self.weighted:
                self._observe_class_time(w, task, end - start)
            if w.limp_state is not None:
                self._observe_limp(i, w, task, end - start)
            with self._log_lock:
                stamps = self._arrivals.get(id(task))
                arrival = stamps.pop(0) if stamps else float("nan")
                if stamps is not None and not stamps:
                    del self._arrivals[id(task)]
                self._records.append(TaskRecord(task, i, start, end, arrival))
            self.done_counter.accumulate(1)
            if self._finished():
                self._wake_all()  # completion wakes idle sleepers to exit
            if self.info is not None:
                self._update_info(i)
                self._communicate(i)  # line 13

    # ------------------------------------------------------- straggler plane
    def set_worker_slowdown(self, worker: int, factor: float) -> None:
        """Live fault injection: multiply ``worker``'s task execution time
        by ``factor`` from its next task on (1.0 restores native speed).
        Composes multiplicatively with any scripted ``slowdown`` schedule.
        Thread-safe: a single float store, read once per task boundary."""
        if not 0 <= worker < self.num_workers:
            raise ValueError(
                f"worker {worker} out of range 0..{self.num_workers - 1}"
            )
        if not math.isfinite(factor) or factor <= 0.0:
            raise ValueError(f"slowdown factor {factor} must be finite > 0")
        self.workers[worker].slow_mult = float(factor)

    def limping(self, worker: int) -> bool:
        """Current limp verdict for ``worker`` — owner-side EWMA or the
        peer-side staleness flag (False when detection is disabled)."""
        return self._limping[worker]

    def _slow_factor(self, i: int, w: _WorkerState, now: float) -> float:
        """Combined slowdown multiplier for a task that started at ``now``
        (clock units): manual injection x the scripted schedule, evaluated
        at task start — mirroring the simulator's ``start_task``."""
        f = w.slow_mult
        if self.slowdown is not None and self._t0 is not None:
            f *= self.slowdown.factor_at(i, now - self._t0)
        return f

    def _observe_limp(self, i: int, w: _WorkerState, task, dt: float) -> None:
        """Owner-side limp detection on a completed task (the only signal
        the owner can actually observe — DESIGN.md §Straggler plane caveat:
        a fully wedged worker never reaches this line)."""
        st = w.limp_state
        cls = self._task_class(task) if self.weighted else 0
        st.observe(
            normalize_duration(dt, cls, w.class_t if self.weighted else None)
        )
        peer = float("nan")
        if st.samples < st.cfg.min_samples and self.info is not None:
            # Boot-limped fallback: the own baseline is not trusted yet, so
            # reference the median published t of the live window peers
            # (cell-scoped under a hierarchy board — a limper is judged
            # against ITS cell, not the whole pool).
            vals = [
                t
                for j, t in self.info.peer_raw_t(i)
                if not self.dead[j] and t == t
            ]
            if vals:
                peer = float(np.median(vals))
        flagged = st.evaluate(peer)
        if flagged != self._limping[i]:
            self._limping[i] = flagged
            with self._log_lock:
                self.limp_log.append((self.clock(), i, flagged))

    # ----------------------------------------------------------------- helpers
    @property
    def weighted(self) -> bool:
        """Work-weighted accounting active.  Requires a classifier AND at
        least two classes: a single class carries no composition information
        and its per-class EWMA would differ from the arithmetic-mean t the
        count plan prices with — the degenerate case must stay bit-for-bit
        count-based (tests/test_weighted.py)."""
        return self.cost_class_fn is not None and self.num_classes > 1

    def _task_class(self, task) -> int:
        """Clamped cost class of a payload: :class:`Task` records answer
        from their ``cls`` field directly; bare payloads go through the
        classifier, where a raising classifier maps to class 0 —
        accounting must never take a worker down."""
        if type(task) is Task:
            return min(max(task.cls, 0), self.num_classes - 1)
        try:
            c = int(self.cost_class_fn(task))  # type: ignore[misc]
        except Exception:  # noqa: BLE001 — user classifier, defensive
            return 0
        return min(max(c, 0), self.num_classes - 1)

    def _class_counts(self, tasks) -> np.ndarray:
        # Shared loot/queue accounting (steal.class_counts) — one Task-aware
        # histogram for both planes.
        return np.asarray(
            class_counts(tasks, self.cost_class_fn, self.num_classes),
            dtype=np.float64,
        )

    def _queue_classes(self, w: _WorkerState) -> np.ndarray:
        """Cached composition scan of a worker's own deque: re-scans only
        when the deque's mutation hint moved.  The returned array is never
        mutated in place (always replaced), so sharing it with the info
        board is safe."""
        key = (w.deque.mutations, w.deque.headtail.load())
        cached = w.nc_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        counts = self._class_counts(w.deque.snapshot_tasks())
        w.nc_cache = (key, counts)
        return counts

    def _observe_class_time(self, w: _WorkerState, task, dt: float) -> None:
        """Owner-side EWMA update t̂[c] ← α·dt + (1−α)·t̂[c] on completion."""
        c = self._task_class(task)
        prev = w.class_t[c]
        if prev != prev:  # first observation of this class
            w.class_t[c] = dt
        else:
            a = self.ewma_alpha
            w.class_t[c] = a * dt + (1.0 - a) * prev

    def _update_info(self, i: int) -> None:
        """Closed: n_i = executed + queued (paper §2.2).  Open-arrival:
        n_i = instantaneous queue depth — cumulative totals are meaningless
        as a balance target while tasks keep arriving (DESIGN.md
        §Open-arrival).  Either way t_i = mean runtime, or elapsed wall time
        before the first task finishes (preemptive stealing, §2.2.1)."""
        # Heartbeat for the wedge detector: the owner's loop reached a
        # boundary RIGHT NOW — a worker stuck inside a task never gets here.
        self._hb_beat[i] = self.clock()
        w = self.workers[i]
        if self.open_arrival:
            n_i = len(w.deque)
        else:
            n_i = w.executed + len(w.deque)
        if w.executed > 0:
            t_i = w.runtime_sum / w.executed
        else:
            t_i = max(self.clock() - w.start_time, 1e-9)
        limping = self._limping[i]
        if limping:
            # Adaptive RE-PRICING (DESIGN.md §Straggler plane): a flagged
            # limper publishes its collapsed fast-EWMA instead of the slow-
            # moving cumulative mean, so the existing fair-share mathematics
            # (Eq. 5) immediately marks it massively surplus and thieves
            # strip its queue through the ordinary steal path.
            recent = w.limp_state.recent
            if recent == recent:
                t_i = max(t_i, recent)
        if self.weighted:
            # Per-class payload: own queue composition (ground-truth scan of
            # the own deque) + per-class EWMA estimates, same cell version.
            self.info.update_local(
                i, float(n_i), float(t_i),
                nc_i=self._queue_classes(w),
                tc_i=w.class_t.copy(),
                limp_i=limping,
            )
        else:
            self.info.update_local(i, float(n_i), float(t_i), limp_i=limping)

    def _ring_view(self, i: int) -> tuple:
        """A2WS information model: what thief ``i`` may believe (§2.1/§2.2.1).

        Estimates use ONLY the thief's information vector (plus the elapsed
        wall time for preemptive estimates, §2.2.1) — never ground-truth
        reads of remote state.  Over/under-estimates are absorbed by the
        Fig. 3b atomic adjust-and-correct protocol, exactly as in the paper.

        Returns ``(n, t, queued, window, unit, qtasks, rel, ntasks, limp,
        members, nc, iview, rad)``; ``unit``/``qtasks``/``rel``/``ntasks``
        are the work-weighted overlay (None in count mode).  In weighted mode
        ``n``/``queued`` are measured in equivalent reference-class tasks
        (DESIGN.md §Work-weighted stealing) while ``qtasks`` keeps the task
        counts for integrality guards and the Fig. 3b clamp.  ``limp`` is the
        delayed limp-flag row (None when detection is off).

        Hierarchy scoping (DESIGN.md §Hierarchy): under a cell-mapped policy
        every returned array speaks LOCAL cell slots and ``members`` carries
        the local→global mapping (``-1`` = migration hole); flat boards
        return ``members=None`` with ``iview=i`` and the pool radius — the
        same loop runs either way, just over a different index set.
        """
        w = self.workers[i]
        # One board epoch for rows + window: a concurrent grow() can never
        # produce a window index outside the copied rows.
        n_view, t_view, raw_t, window, nc_view, tc_view, limp_row = (
            self.info.view_window_all(i)
        )
        m = len(n_view)
        if self.cells is not None:
            cell, iview = self.cells.locate(i)
            mem = self.cells.members(cell)
            # Clamp to the board rows copied above: a concurrent join may
            # have appended a member slot the sub-board has not grown to
            # cover yet (add_worker homes, then grows).
            if len(mem) < m:
                mem = mem + [-1] * (m - len(mem))
            members = np.asarray(mem[:m], dtype=np.int64)
            rad = self.cells.radius_of(cell)
        else:
            members = None
            iview = i
            rad = self.radius
        if self.limp_cfg is not None:
            limp_row[iview] = self._limping[i]  # own flag: ground truth, no lag
        else:
            limp_row = None
        wedge = self.limp_cfg is not None and math.isfinite(
            self.limp_cfg.stale_after
        )
        now = self.clock()
        elapsed = max(now - w.start_time, 1e-9)
        queued = np.zeros(m)
        for jl in window:
            g = jl if members is None else int(members[jl])
            if g < 0:
                # Migration hole: no member behind this slot any more —
                # empty, priced at speed ~0 so Eq. 5 never assigns it work.
                queued[jl] = 0.0
                t_view[jl] = 1e12
                n_view[jl] = 0.0
                continue
            if jl == iview:
                queued[jl] = len(w.deque)
                if self.open_arrival:
                    n_view[jl] = queued[jl]
                continue
            if self.dead[g]:
                # Tombstoned worker: its info cells are frozen garbage.  Its
                # RMA window (deque) is still readable — count the orphaned
                # tasks directly and report speed ~0 so the fair share never
                # assigns it anything.
                queued[jl] = len(self.workers[g].deque)
                t_view[jl] = 1e12
                n_view[jl] = (
                    queued[jl]
                    if self.open_arrival
                    else self.workers[g].executed + queued[jl]
                )
                continue
            if np.isnan(raw_t[jl]):
                # No report from j yet: preemptive wall-time estimate — j
                # looks like it has finished 0 tasks in `elapsed` seconds.
                t_view[jl] = elapsed
            if wedge:
                # Wedge detector (LimpConfig.stale_after): j's heartbeat is
                # the last boundary its OWN loop reached (`_update_info`) —
                # an idle worker keeps beating through its poll loop, so
                # only a worker stuck INSIDE a task goes silent.  Silence
                # past stale_after means j is wedged (slowdown → ∞): the
                # owner-side EWMA can never flag it because it only observes
                # COMPLETED tasks, so the PEER raises the limp flag —
                # routing skips it, and the §2.2.1-style re-pricing below
                # marks its whole queue surplus so thieves strip it.
                hb = self._hb_beat[g]
                if hb == hb and now - hb > self.limp_cfg.stale_after:
                    if not self._stale_flagged[g]:
                        self._stale_flagged[g] = True
                        if not self._limping[g]:
                            self._limping[g] = True
                            with self._log_lock:
                                self.limp_log.append((now, g, True))
                    # Progressive re-pricing: j has produced nothing for the
                    # whole stale window, so its believed speed can be no
                    # better than one task per silence — closed-mode
                    # done_est → 0 and thieves see the full queue.
                    t_view[jl] = max(t_view[jl], now - hb)
                    limp_row[jl] = True
                elif self._stale_flagged[g]:
                    # Heartbeat is back: hand the verdict back to the
                    # owner-side EWMA hysteresis.
                    self._stale_flagged[g] = False
                    st = self.workers[g].limp_state
                    verdict = bool(st.limping) if st is not None else False
                    if self._limping[g] != verdict:
                        self._limping[g] = verdict
                        with self._log_lock:
                            self.limp_log.append((now, g, verdict))
            if self.netfaults is not None and self._t0 is not None:
                # Partition staleness (DESIGN.md §Fault fabric): when a cut
                # separates i from g, g's heartbeat FREEZES from i's vantage
                # at the cut instant (no message crosses), so after
                # nf.stale_after of frozen silence i prices g as stale —
                # exactly the wedge detector's re-pricing, but OBSERVER-
                # LOCAL: no global _limping/_stale_flagged writes, because
                # g's own side of the cut still sees it healthy.  Heals undo
                # this automatically: unreachable_since returns inf again
                # and the real (still-beating) heartbeat shows through.
                cut = self.netfaults.unreachable_since(g, i, now - self._t0)
                if cut < math.inf:
                    hb_eff = effective_heartbeat(
                        self._hb_beat[g], self._t0 + cut
                    )
                    if hb_eff == hb_eff and (
                        now - hb_eff > self.netfaults.stale_after
                    ):
                        t_view[jl] = max(t_view[jl], now - hb_eff)
                        if limp_row is not None:
                            limp_row[jl] = True
            if self.open_arrival:
                # n_j IS the reported depth; no elapsed-time extrapolation —
                # depth both drains (execution) and refills (arrivals), so
                # decaying it would systematically under-count busy victims.
                queued[jl] = max(n_view[jl], 0.0)
            else:
                # Estimated executed count from speed; remaining = n_j - done.
                done_est = min(elapsed / max(t_view[jl], 1e-9), n_view[jl])
                queued[jl] = max(n_view[jl] - done_est, 0.0)
        if not self.weighted:
            return (
                n_view, t_view, queued, window, None, None, None, None,
                limp_row, members, None, iview, rad,
            )
        # ---- work-weighted overlay (DESIGN.md §Work-weighted stealing) ----
        # Ground-truth compositions where the thief may read them: its own
        # deque, and tombstoned deques (already ground-truth counted above).
        nc_view[iview] = self._queue_classes(w)
        tc_view[iview] = w.class_t
        for jl in window:
            g = jl if members is None else int(members[jl])
            if jl != iview and g >= 0 and self.dead[g]:
                nc_view[jl] = self._queue_classes(self.workers[g])
        # Shared re-pricing (steal.weighted_overlay — ONE implementation for
        # both planes): tombstones (and migration holes) are frozen at their
        # ~0-speed price.
        if members is None:
            frozen = np.fromiter(
                (self.dead[j] for j in range(m)), dtype=bool, count=m,
            )
        else:
            frozen = np.fromiter(
                (members[jl] < 0 or self.dead[members[jl]] for jl in range(m)),
                dtype=bool, count=m,
            )
        # Preallocated per-worker scratch: the overlay's temporaries dominate
        # the per-boundary hot path at scale, and a boundary fully consumes
        # its view before the next one starts, so reuse is safe.
        buf = OverlayBuffers.ensure(w.overlay_buf, m, self.num_classes)
        w.overlay_buf = buf
        n_w, t_w, queued_w, unit, qtasks, rel = weighted_overlay(
            n_view, t_view, queued, nc_view, tc_view, frozen=frozen, buf=buf
        )
        # n_view stays the COUNT estimate (n_w is a fresh array): the Fig. 3b
        # reconciliation writes the board's count-denominated n from it.
        return (
            n_w, t_w, queued_w, window, unit, qtasks, rel, n_view,
            limp_row, members, nc_view, iview, rad,
        )

    def _make_view(self, i: int) -> PolicyView:
        w = self.workers[i]
        unit = qtasks = rel = ntasks = limp_row = members = nc_view = None
        iview, rad = i, self.radius
        if self.info is not None:
            (
                n_view, t_view, queued, window, unit, qtasks, rel, ntasks,
                limp_row, members, nc_view, iview, rad,
            ) = self._ring_view(i)
            num_workers = len(n_view)  # the board epoch's ring size
        else:
            n_view = t_view = queued = None
            num_workers = self.num_workers
            window = list(range(num_workers))
        if members is None:
            depth = lambda j: len(self.workers[j].deque)  # noqa: E731
            alive = lambda j: not self.dead[j]  # noqa: E731
        else:
            # Scoped view: the policy speaks LOCAL slot indices; translate
            # through the member map (holes read as empty tombstones).
            mem = members
            depth = lambda jl: (  # noqa: E731
                len(self.workers[mem[jl]].deque) if mem[jl] >= 0 else 0
            )
            alive = lambda jl: (  # noqa: E731
                mem[jl] >= 0 and not self.dead[mem[jl]]
            )
        tcost = None
        if self.topology is not None:
            topo = self.topology
            if members is None:
                # transfer_cost(j, k) = seconds to move k tasks FROM j TO i.
                tcost = lambda j, k, _t=topo, _i=i: _t.cost(  # noqa: E731
                    int(j), _i, int(k)
                )
            else:
                # Scoped view: j is a LOCAL slot — translate through the
                # member map; a migration hole is unreachable (inf).
                def tcost(jl, k, _t=topo, _i=i, _mem=members):
                    g = int(_mem[jl]) if 0 <= jl < len(_mem) else -1
                    if g < 0:
                        return float("inf")
                    return _t.cost(g, _i, int(k))
        lh = None
        if self.netfaults is not None and self._t0 is not None:
            # link_health(j) in [0, 1]: 0 across an active partition or a
            # backed-off link, else the link's success EWMA (floor-clamped,
            # 1.0 until first observed) — victim weights multiply by it.
            nf, hlt, t0, clk = (
                self.netfaults, self._link_health, self._t0, self.clock,
            )
            if members is None:
                def lh(j, _i=i, _nf=nf, _h=hlt, _t0=t0, _c=clk):
                    tnow = _c() - _t0
                    g = int(j)
                    if not _nf.reachable(g, _i, tnow):
                        return 0.0
                    return _h.factor(_i, g, tnow)
            else:
                def lh(jl, _i=i, _nf=nf, _h=hlt, _t0=t0, _c=clk, _mem=members):
                    g = int(_mem[jl]) if 0 <= jl < len(_mem) else -1
                    if g < 0:
                        return 0.0
                    tnow = _c() - _t0
                    if not _nf.reachable(g, _i, tnow):
                        return 0.0
                    return _h.factor(_i, g, tnow)
        return PolicyView(
            worker=iview,
            now=self.clock(),
            idle=len(w.deque) == 0,
            ran_any=w.ran_any,
            open_arrival=self.open_arrival,
            radius=rad,
            num_workers=num_workers,
            rng=w.rng,
            window=window,
            depth=depth,
            alive=alive,
            pending=self.pending,
            n_view=n_view,
            t_view=t_view,
            queued=queued,
            unit=unit,
            qtasks=qtasks,
            rel=rel,
            ntasks=ntasks,
            limp=limp_row,
            members=members,
            nc_view=nc_view,
            transfer_cost=tcost,
            link_health=lh,
        )

    def _policy_boundary(self, i: int) -> bool:
        """Consult the policy at a task boundary; execute any steal it plans
        (Alg. 1 lines 4-8 for A2WS: steal_equation -> select_victim ->
        steal_task via the Fig. 3b protocol)."""
        view = self._make_view(i)
        plan = self.policy.on_boundary(view)
        if plan is None:
            return False
        # Plans name GLOBAL victims (hierarchy policies translate before
        # returning).  Under a scoped view, resolve the local row for the
        # reconciliation below; an inter-cell victim has none — its board
        # lives in another cell, so the steal executes but no cell is
        # reconciled (CellBoard drops cross-cell record_remote anyway).
        vloc = plan.victim
        xcell = False
        if view.members is not None:
            hits = np.nonzero(view.members == plan.victim)[0]
            if hits.size:
                vloc = int(hits[0])
            else:
                xcell = True
        nf = self.netfaults
        if nf is not None and self._t0 is not None:
            # ---- request leg (DESIGN.md §Fault fabric) ----
            # Deterministic reachability first (consumes no randomness), then
            # the drop roll on the DEDICATED nf rng — the scheduling stream
            # stays untouched.  A lost request teaches the thief nothing
            # about the victim (no snapshot, no reconciliation): it times
            # out, records the link failure, and backs off.
            tnow = self.clock() - self._t0
            req_lost = not nf.reachable(i, plan.victim, tnow)
            if not req_lost:
                pd = nf.drop_prob(i, plan.victim, tnow)
                if pd > 0.0 and float(self.workers[i].nf_rng.random()) < pd:
                    req_lost = True
            if req_lost:
                self._failed_steals += 1
                with self._log_lock:
                    self._net_failed += 1
                if nf.hardened:
                    self._link_health.record(i, plan.victim, False, tnow)
                    _sleep_stall(nf.attempt_timeout, self.clock)
                self.policy.on_steal_result(view, plan, 0, 0)
                return False
        if plan.delay > 0.0 and self.topology is None:
            # Policy-priced dispatch latency (LW's leader round-trip),
            # charged in CLOCK units: the policy booked its gate against
            # view.now from self.clock, so a scaled/virtual clock must see
            # the same delay it priced — a raw time.sleep would not.
            # (With a topology, plan.delay is the TRANSPORT fare instead,
            # and it is paid after the claim — loot in flight overlaps the
            # victim's compute; see the transport leg below.)
            deadline = self.clock() + plan.delay
            while True:
                remaining = deadline - self.clock()
                if remaining <= 0.0:
                    break
                time.sleep(min(remaining, 1e-3))
        victim = self.workers[plan.victim]
        if (
            self.weighted and plan.work > 0.0 and view.rel is not None
            and plan.delay <= 0.0
        ):
            # Work-greedy loot (DESIGN.md §Work-weighted stealing): claim
            # tail slots until the plan's work target is covered, pricing
            # each candidate by its class — the count `amount` is only the
            # mean-unit estimate and over/under-shoots under tail skew.
            # A PRICED plan (delay > 0, §Topology plane) is excluded: its
            # loot must move as ONE batched transfer — the per-task greedy
            # loop would be k separately-priced hops the plan never paid
            # for, so it takes the single batched claim below instead.
            rel = view.rel
            result = victim.deque.steal_by_work(
                plan.work,
                lambda task: float(rel[self._task_class(task)]),
                max_tasks=max(plan.amount, int(math.ceil(2.0 * plan.work))),
                take_first=view.idle,  # idle thieves stay work-conserving
            )
        else:
            result = victim.deque.steal(plan.amount)  # Fig. 3b protocol
        # The get-accumulate snapshot tells the thief the victim's exact
        # remaining queue; fold it into the information vector (Table 1).
        observed_left = max(result.observed_tail - result.observed_head, 0)
        got = len(result.tasks)
        left = max(observed_left - got, 0)
        # Closed-mode reconciliation: n_j is the victim's TOTAL (executed +
        # queued, §2.2).  The snapshot gives ground truth for the QUEUED
        # part only, so keep the executed estimate the thief already priced
        # (n_view − queued estimate) and replace the queued estimate with
        # the observation: corrected n = done_est + observed queue.
        # (Subtracting the remaining queue from the total — the old rule —
        # left a drained victim at its stale full n and under-counted a
        # loaded one.)
        if self.info is not None and not self.open_arrival:
            # COUNT units throughout: the board's n is count-denominated, so
            # in weighted mode the executed estimate must come from the
            # pre-overlay count vectors (n_w - queued_w is executed work in
            # reference units — writing that into n would double-scale on
            # the next view's re-pricing).
            if xcell:
                done_est = 0.0  # no local row; the record is dropped anyway
            else:
                base_n = view.ntasks if view.ntasks is not None else view.n_view
                base_q = view.qtasks if view.qtasks is not None else view.queued
                done_est = max(
                    float(base_n[vloc]) - float(base_q[vloc]), 0.0
                )
        if not result:
            self._failed_steals += 1
            # Table 1 row 3: thief marks the victim position dirty anyway —
            # with n_j corrected to what the snapshot implies.
            if self.info is not None:
                if self.open_arrival:
                    corrected_n = float(observed_left)
                else:
                    corrected_n = done_est + float(observed_left)
                nc_corr = None
                if self.weighted and observed_left == 0:
                    # The snapshot proved the queue empty: the stale class
                    # profile goes with it.
                    nc_corr = np.zeros(self.num_classes, dtype=np.float64)
                self.info.record_remote(
                    i, plan.victim, float(corrected_n),
                    self.info.belief_t(i, plan.victim),
                    nc_j=nc_corr,
                )
            self.policy.on_steal_result(view, plan, 0, left)
            return False
        # ---- transport leg (DESIGN.md §Fault fabric / §Topology plane) ----
        # A priced plan pays its fare AFTER the claim, overlapped with the
        # victim's compute: the loot is in flight while the thief sleeps the
        # modeled transfer time, then lands on its deque — mirroring the
        # simulator's claim-now/land-later event.  Zero-cost links skip the
        # stall entirely (bit-for-bit the instant-transfer scheduler).
        fare = 0.0
        if self.topology is not None and plan.delay > 0.0:
            # Fare on the ACTUAL take (the plan priced plan.amount).
            fare = max(float(self.topology.cost(plan.victim, i, got)), 0.0)
        if nf is not None and self._t0 is not None:
            tnow = self.clock() - self._t0
            fare += nf.extra_delay(plan.victim, i, tnow)
            pd = nf.drop_prob(plan.victim, i, tnow)
            if pd > 0.0 and float(self.workers[i].nf_rng.random()) < pd:
                # Transfer leg dropped: the loot never lands.  Hardened, the
                # thief waits out the LEASE and the tasks RETURN to the
                # victim — every task still executes exactly once, just
                # later.  The threaded plane carries real payloads, so even
                # the un-hardened ablation returns them (immediately, no
                # lease wait) instead of destroying work — the delivery-
                # semantics table records this divergence from the sim.
                with self._log_lock:
                    self._lease_expired += 1
                if nf.hardened:
                    _sleep_stall(nf.lease_timeout, self.clock)
                self.workers[plan.victim].deque.push(result.tasks)
                if nf.hardened:
                    self._link_health.record(
                        i, plan.victim, False, self.clock() - self._t0
                    )
                if self.info is not None:
                    # Belief restore: the victim has its queue back.
                    if self.open_arrival:
                        corrected_n = float(observed_left)
                    else:
                        corrected_n = done_est + float(observed_left)
                    self.info.record_remote(
                        i, plan.victim, float(corrected_n),
                        self.info.belief_t(i, plan.victim),
                    )
                self.policy.on_steal_result(view, plan, 0, observed_left)
                return False
            if nf.hardened and self._nf_lossy:
                self._link_health.record(i, plan.victim, True, tnow)
        if fare > 0.0:
            _sleep_stall(fare, self.clock)
            with self._log_lock:
                self._fare_paid += fare
        self.workers[i].deque.push(result.tasks)
        with self._log_lock:
            self._steal_log.append((self.clock(), i, plan.victim, got))
        if self.info is not None:
            if self.open_arrival:
                # Depth semantics: the snapshot IS the depth at steal time.
                victim_n_new = float(left)
            else:
                # Same reconciliation as above, post-transfer: the steal
                # moved queued tasks, the victim's executed count is
                # untouched, and `left` is the observed remaining queue.
                victim_n_new = done_est + float(left)
            nc_corr = None
            if self.weighted:
                # The thief saw the classes of the loot first-hand: subtract
                # them from the victim's published profile (clamped — the
                # profile may have been stale already).
                base_nc = self.info.belief_nc(i, plan.victim)
                if base_nc is not None:
                    nc_corr = np.maximum(
                        base_nc - self._class_counts(result.tasks), 0.0
                    )
            # Table 1 row 2: thief refreshes its own and the victim's cells.
            self._update_info(i)
            self.info.record_remote(
                i, plan.victim, float(victim_n_new),
                self.info.belief_t(i, plan.victim),
                nc_j=nc_corr,
            )
        self.policy.on_steal_result(view, plan, got, left)
        return True


def _sleep_stall(duration: float, clock: Callable[[], float]) -> None:
    """Stall for ``duration`` clock seconds while YIELDING the core (models
    throttled/IO-stalled stragglers; contrast ``_busy_wait``, which models a
    co-located compute thief).  Clock-deadline paced so virtual clocks see
    the same stall that was priced."""
    if duration <= 0:
        return
    deadline = clock() + duration
    while True:
        remaining = deadline - clock()
        if remaining <= 0.0:
            return
        time.sleep(min(remaining, 1e-3))


def _busy_wait(duration: float, clock: Callable[[], float]) -> None:
    """Burn CPU for ``duration`` seconds (models co-located thread
    interference — a sleep would free the core, a real leader does not)."""
    if duration <= 0:
        return
    end = clock() + duration
    while clock() < end:
        pass


# The paper's runtime is the pool under its own policy: ``A2WSRuntime(...)``
# constructs a ``WorkerPool`` with the default ``policy="a2ws"``.
A2WSRuntime = WorkerPool

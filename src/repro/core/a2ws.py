"""A2WS Algorithm 1 — the asynchronous host runtime.

This is the paper's scheduler running as the **control plane** of the
framework: worker threads (one per heterogeneous worker group / node) execute
opaque tasks, keep per-worker deques (``repro.core.deque``), exchange the
information vector over the bidirectional ring (``repro.core.info_ring``) and
steal adaptively (``repro.core.steal``).  Shared memory between threads stands
in for MPI RMA windows — the protocol (packed head/tail get-accumulate,
partitioned info Puts, preemptive wall-time speed estimates) is the paper's,
see DESIGN.md §2 for the adaptation argument.

The runtime is generic over the task payload: the seismic driver feeds shots,
the training runtime (``repro.runtime.het_dp``) feeds microbatches, the server
feeds request batches.

Algorithm 1 mapping (line numbers from the paper):

    1  while the process has task do            -> _worker_loop
    2    update_process_info()                  -> _update_info
    3-8  if ran a task: S=steal_equation();     -> plan_steal + _do_steal
         v=select_victim(S); steal_task(v,S)
    10   T_id = get_task_id()                   -> deque.get_task
    11   update_process_info()                  -> _update_info
    12   execute(T_id)                          -> task_fn
    13   info_communication()                   -> RingInfo.communicate
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from .deque import AtomicInt64, TaskDeque
from .info_ring import RingInfo
from .steal import plan_steal

__all__ = ["A2WSRuntime", "RunStats", "TaskRecord", "partition_tasks"]


@dataclass
class TaskRecord:
    task: object
    worker: int
    start: float
    end: float


@dataclass
class RunStats:
    makespan: float
    records: list[TaskRecord]
    steals: list[tuple[float, int, int, int]]  # (time, thief, victim, amount)
    failed_steals: int
    info_cells_sent: int
    corrections: int
    per_worker_tasks: list[int] = field(default_factory=list)
    per_worker_mean_t: list[float] = field(default_factory=list)

    def summary(self) -> str:
        counts = ",".join(str(c) for c in self.per_worker_tasks)
        return (
            f"makespan={self.makespan:.4f}s steals={len(self.steals)} "
            f"failed={self.failed_steals} cells={self.info_cells_sent} "
            f"tasks/worker=[{counts}]"
        )


def partition_tasks(tasks: Sequence, num_workers: int) -> list[list]:
    """Static block partition used before execution starts (§2.2.1: "A2WS
    distributes the tasks statically just before execution starts")."""
    out: list[list] = [[] for _ in range(num_workers)]
    base, rem = divmod(len(tasks), num_workers)
    pos = 0
    for w in range(num_workers):
        k = base + (1 if w < rem else 0)
        out[w] = list(tasks[pos : pos + k])
        pos += k
    return out


class _WorkerState:
    __slots__ = (
        "deque", "executed", "runtime_sum", "ran_any", "start_time", "rng",
    )

    def __init__(self, deque: TaskDeque, seed: int) -> None:
        self.deque = deque
        self.executed = 0
        self.runtime_sum = 0.0
        self.ran_any = False
        self.start_time = 0.0
        self.rng = np.random.default_rng(seed)


class A2WSRuntime:
    """Threaded A2WS executor for ``num_workers`` heterogeneous workers."""

    def __init__(
        self,
        tasks: Sequence,
        num_workers: int,
        task_fn: Callable[[int, object], object],
        *,
        radius: int | None = None,
        seed: int = 0,
        idle_backoff: float = 1e-4,
        clock: Callable[[], float] = time.perf_counter,
    ) -> None:
        """``task_fn(worker_id, task) -> result`` runs the task on a worker.

        ``radius`` defaults to the paper's operating point: 20% of the number
        of workers (Fig. 4 discussion), at least 1.
        """
        self.num_workers = num_workers
        self.task_fn = task_fn
        self.radius = radius if radius is not None else max(1, round(0.2 * num_workers))
        self.idle_backoff = idle_backoff
        self.clock = clock
        parts = partition_tasks(tasks, num_workers)
        self.total_tasks = len(tasks)
        self.workers = [
            _WorkerState(TaskDeque(parts[w]), seed * 1009 + w)
            for w in range(num_workers)
        ]
        self.info = RingInfo(num_workers, self.radius)
        self.done_counter = AtomicInt64(0)
        self.alive = AtomicInt64(num_workers)
        # Failure tombstones (the heartbeat/failure-detector channel of a
        # real deployment): a dead worker's info-vector cells go stale, so
        # thieves must stop trusting them — see _try_steal.
        self.dead = [False] * num_workers
        self.errors: list[tuple[int, object, BaseException]] = []
        self._steal_log: list[tuple[float, int, int, int]] = []
        self._failed_steals = 0
        self._records: list[TaskRecord] = []
        self._log_lock = threading.Lock()

    # ------------------------------------------------------------- Algorithm 1
    def run(self) -> RunStats:
        t0 = self.clock()
        for w in self.workers:
            w.start_time = t0
        for i in range(self.num_workers):
            self._update_info(i)
        threads = [
            threading.Thread(target=self._worker_loop, args=(i,), daemon=True)
            for i in range(self.num_workers)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        t1 = self.clock()
        per_tasks = [w.executed for w in self.workers]
        per_t = [
            (w.runtime_sum / w.executed) if w.executed else float("nan")
            for w in self.workers
        ]
        return RunStats(
            makespan=t1 - t0,
            records=sorted(self._records, key=lambda r: r.start),
            steals=list(self._steal_log),
            failed_steals=self._failed_steals,
            info_cells_sent=self.info.puts,
            corrections=sum(w.deque.corrections for w in self.workers),
            per_worker_tasks=per_tasks,
            per_worker_mean_t=per_t,
        )

    def _worker_loop(self, i: int) -> None:
        w = self.workers[i]
        ran_a_task = False
        while self.done_counter.load() < self.total_tasks:
            self._update_info(i)  # line 2
            if ran_a_task or w.ran_any:  # lines 3-9 (preemptive: any finished)
                self._try_steal(i)
            task = w.deque.get_task()  # line 10
            if task is None:
                # Empty deque: keep thieving until global completion.
                if self.alive.load() == 0:
                    return  # every worker died; nothing left to wait for
                ran_a_task = False
                self.info.communicate(i)
                if not self._try_steal(i):
                    time.sleep(self.idle_backoff)
                continue
            self._update_info(i)  # line 11
            start = self.clock()
            try:
                self.task_fn(i, task)  # line 12
            except BaseException as e:  # noqa: BLE001 — fault tolerance
                # Worker failure: return the task to the deque so survivors
                # can steal it, raise the tombstone, publish, and die.
                w.deque.push([task])
                with self._log_lock:
                    self.errors.append((i, task, e))
                self.dead[i] = True
                self._update_info(i)
                self.info.communicate(i)
                self.alive.accumulate(-1)
                return
            end = self.clock()
            w.executed += 1
            w.runtime_sum += end - start
            w.ran_any = True
            ran_a_task = True
            with self._log_lock:
                self._records.append(TaskRecord(task, i, start, end))
            self.done_counter.accumulate(1)
            self._update_info(i)
            self.info.communicate(i)  # line 13

    # ----------------------------------------------------------------- helpers
    def _update_info(self, i: int) -> None:
        """n_i = executed + queued; t_i = mean runtime, or elapsed wall time
        before the first task finishes (preemptive stealing, §2.2.1)."""
        w = self.workers[i]
        n_i = w.executed + len(w.deque)
        if w.executed > 0:
            t_i = w.runtime_sum / w.executed
        else:
            t_i = max(self.clock() - w.start_time, 1e-9)
        self.info.update_local(i, float(n_i), float(t_i))

    def _try_steal(self, i: int) -> bool:
        """Lines 4-8: steal_equation -> select_victim -> steal_task.

        Decisions use ONLY the thief's information vector (plus the elapsed
        wall time for preemptive estimates, §2.2.1) — never ground-truth reads
        of remote state.  Over/under-estimates are absorbed by the Fig. 3b
        atomic adjust-and-correct protocol, exactly as in the paper.
        """
        w = self.workers[i]
        n_view, t_view = self.info.view(i)
        now = self.clock()
        elapsed = max(now - w.start_time, 1e-9)
        window = self.info.window(i)
        queued = np.zeros(self.num_workers)
        for j in window:
            if j == i:
                queued[j] = len(w.deque)
                continue
            if self.dead[j]:
                # Tombstoned worker: its info cells are frozen garbage.  Its
                # RMA window (deque) is still readable — count the orphaned
                # tasks directly and report speed ~0 so the fair share never
                # assigns it anything.
                queued[j] = len(self.workers[j].deque)
                t_view[j] = 1e12
                n_view[j] = self.workers[j].executed + queued[j]
                continue
            if np.isnan(self.info.t[i, j]):
                # No report from j yet: preemptive wall-time estimate — j
                # looks like it has finished 0 tasks in `elapsed` seconds.
                t_view[j] = elapsed
            # Estimated executed count from speed; remaining = n_j - executed.
            done_est = min(elapsed / max(t_view[j], 1e-9), n_view[j])
            queued[j] = max(n_view[j] - done_est, 0.0)
        decision = plan_steal(
            w.rng, i, n_view, t_view, queued, self.radius,
            idle=len(w.deque) == 0,
        )
        if decision is None:
            return False
        victim = self.workers[decision.victim]
        result = victim.deque.steal(decision.amount)  # Fig. 3b protocol
        # The get-accumulate snapshot tells the thief the victim's exact
        # remaining queue; fold it into the information vector (Table 1).
        observed_left = max(result.observed_tail - result.observed_head, 0)
        victim_n_new = n_view[decision.victim] - len(result.tasks)
        if not result:
            self._failed_steals += 1
            # Table 1 row 3: thief marks the victim position dirty anyway —
            # with n_j corrected down to what the snapshot implies.
            exec_est = n_view[decision.victim] - observed_left
            self.info.record_remote(
                i, decision.victim, float(max(exec_est, 0.0)),
                self.info.t[i, decision.victim],
            )
            return False
        w.deque.push(result.tasks)
        with self._log_lock:
            self._steal_log.append(
                (self.clock(), i, decision.victim, len(result.tasks))
            )
        # Table 1 row 2: thief refreshes its own and the victim's cells.
        self._update_info(i)
        self.info.record_remote(
            i, decision.victim, float(victim_n_new),
            self.info.t[i, decision.victim],
        )
        return True

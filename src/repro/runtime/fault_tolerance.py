"""Fault-tolerant training driver: heartbeats, checkpoint/restart, elasticity.

Failure model (mapped from a real multi-host deployment to this container):

* worker failure mid-step  -> the A2WS runtime re-queues the dying worker's
  task and survivors steal the rest of its deque — the STEP still completes
  (no global restart for a single lost worker; this is the paper's
  decentralisation paying off as fault tolerance).
* persistent worker loss   -> the driver removes the worker between steps and
  rebuilds the task partition (elastic down-scale); a replacement can be
  added later (elastic up-scale) and preemptive stealing warms it up.
* process/job loss         -> periodic async checkpoints + restore-on-start;
  the synthetic data pipeline is step-indexed so resume is bit-exact.
"""

from __future__ import annotations

import time
from dataclasses import dataclass


from repro.checkpoint import store
from .het_dp import HetDPTrainer, WorkerFailed

__all__ = ["Heartbeat", "ResilientDriver"]


class Heartbeat:
    """Worker liveness tracking (timestamp board + stall detector)."""

    def __init__(self, num_workers: int, timeout: float = 5.0) -> None:
        self.last = [time.monotonic()] * num_workers
        self.timeout = timeout

    def beat(self, wid: int) -> None:
        self.last[wid] = time.monotonic()

    def stalled(self) -> list[int]:
        now = time.monotonic()
        return [i for i, t in enumerate(self.last) if now - t > self.timeout]


@dataclass
class DriverReport:
    steps_run: int
    restarts: int
    removed_workers: list[str]
    final_loss: float


class ResilientDriver:
    """Runs a HetDPTrainer for N steps with checkpoint/restart + elasticity."""

    def __init__(
        self,
        trainer: HetDPTrainer,
        make_microbatches,  # step -> list[dict]
        ckpt_dir: str,
        *,
        ckpt_every: int = 10,
    ) -> None:
        self.trainer = trainer
        self.make_microbatches = make_microbatches
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ckpt = store.AsyncCheckpointer(ckpt_dir)
        self.removed: list[str] = []
        self.restarts = 0

    def _maybe_restore(self) -> int:
        step = store.latest_step(self.ckpt_dir)
        if step is None:
            return 0
        tree = {"params": self.trainer.params, "opt": self.trainer.opt_state}
        restored, _ = store.restore(self.ckpt_dir, tree, step=step)
        self.trainer.params = restored["params"]
        self.trainer.opt_state = restored["opt"]
        self.trainer.step_count = step
        return step

    def run(self, total_steps: int, *, resume: bool = True) -> DriverReport:
        start = self._maybe_restore() if resume else 0
        step = start
        last_loss = float("nan")
        while step < total_steps:
            mbs = self.make_microbatches(step)
            try:
                metrics = self.trainer.step(mbs)
            except WorkerFailed as e:
                # Catastrophic (all workers died): restart from checkpoint
                # with the failed worker removed.
                self.restarts += 1
                if 0 <= e.worker < len(self.trainer.workers):
                    self.removed.append(self.trainer.workers[e.worker].name)
                    self.trainer.remove_worker(e.worker)
                if not self.trainer.workers:
                    raise
                self._maybe_restore()
                step = self.trainer.step_count
                continue
            # Partial failure: the step completed; drop dead workers so the
            # next partition excludes them (elastic down-scale).
            for wid in sorted(metrics["failed_workers"], reverse=True):
                self.removed.append(self.trainer.workers[wid].name)
                self.trainer.remove_worker(wid)
            last_loss = metrics["loss"]
            step += 1
            if step % self.ckpt_every == 0 or step == total_steps:
                self.ckpt.save(
                    step,
                    {"params": self.trainer.params, "opt": self.trainer.opt_state},
                )
        self.ckpt.wait()
        return DriverReport(
            steps_run=step - start,
            restarts=self.restarts,
            removed_workers=self.removed,
            final_loss=last_loss,
        )

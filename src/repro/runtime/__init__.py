from .compression import ErrorFeedback, dequantize, quantize
from .fault_tolerance import Heartbeat, ResilientDriver
from .het_dp import HetDPTrainer, WorkerFailed, WorkerSpec

__all__ = [
    "ErrorFeedback",
    "dequantize",
    "quantize",
    "Heartbeat",
    "ResilientDriver",
    "HetDPTrainer",
    "WorkerFailed",
    "WorkerSpec",
]

"""Heterogeneous data parallelism scheduled by A2WS — the paper's technique
as a first-class training feature.

The global batch of one optimizer step is split into T microbatch *tasks*.
Worker groups (device slices / pods; here threads driving jitted compute,
with configurable slowdown factors standing in for heterogeneous hardware or
stragglers) own A2WS deques of those tasks.  Fast groups finish their
microbatches and *steal* from slow ones — Algorithm 1 verbatim, payload =
microbatch index.  Because every microbatch is the same token count, the
combined gradient is the exact full-batch gradient regardless of who computed
what (asserted by tests), so A2WS changes step *latency*, never semantics.

Cross-group gradient combination optionally goes through int8+error-feedback
compression (``repro.runtime.compression``) — the slow-link trick for
cross-pod reduction.

Straggler mitigation and elasticity fall out of the scheduler: a slowed
worker's queue is drained by thieves (per-step), and workers can be added or
removed between steps (the task partition is rebuilt each step).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.a2ws import RunStats, WorkerPool
from repro.core.policy import SchedPolicy
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from .compression import ErrorFeedback

__all__ = ["WorkerSpec", "HetDPTrainer", "WorkerFailed"]


@dataclass
class WorkerSpec:
    name: str
    slow_factor: float = 1.0  # simulated heterogeneity (1.0 = full speed)
    fail_at_step: int | None = None  # fault-injection hook


class WorkerFailed(RuntimeError):
    def __init__(self, worker: int):
        super().__init__(f"worker {worker} failed")
        self.worker = worker


class HetDPTrainer:
    """A2WS-scheduled gradient-accumulation trainer over worker groups."""

    def __init__(
        self,
        loss_fn,  # loss_fn(params, microbatch) -> (loss, metrics)
        params,
        workers: list[WorkerSpec],
        opt_cfg: AdamWConfig = AdamWConfig(),
        *,
        radius: int | None = None,
        policy: str | SchedPolicy = "a2ws",
        compress: bool = False,
        base_task_time: float = 0.0,  # extra per-task sleep (demo pacing)
    ) -> None:
        """``policy``: scheduling policy for the per-step microbatch pool —
        "a2ws" (default), "ctws", "lw", "random", or a ``SchedPolicy``
        instance (reused across steps; name specs build one per step)."""
        self.params = params
        self.opt_cfg = opt_cfg
        self.opt_state = adamw_init(params, opt_cfg)
        self.workers = list(workers)
        self.radius = radius
        self.policy = policy
        self.compress = compress
        self.base_task_time = base_task_time
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
        self._ef = [ErrorFeedback() for _ in workers]
        self.step_count = 0
        self.history: list[RunStats] = []

    # ------------------------------------------------------------------ step
    def step(self, microbatches: list[dict], lr_scale: float = 1.0):
        """One optimizer step over T microbatch tasks."""
        nw = len(self.workers)
        grads = [None] * nw
        losses = [0.0] * nw
        counts = [0] * nw
        locks = [threading.Lock() for _ in range(nw)]
        params = self.params
        step_idx = self.step_count

        def task_fn(wid: int, task_idx):
            spec = self.workers[wid]
            if spec.fail_at_step is not None and step_idx >= spec.fail_at_step:
                raise WorkerFailed(wid)
            (loss, _), g = self._grad_fn(params, microbatches[int(task_idx)])
            jax.block_until_ready(loss)
            if spec.slow_factor > 1.0 or self.base_task_time:
                time.sleep(self.base_task_time * max(spec.slow_factor, 1.0))
            with locks[wid]:
                losses[wid] += float(loss)
                counts[wid] += 1
                if grads[wid] is None:
                    grads[wid] = jax.tree.map(np.asarray, g)
                else:
                    grads[wid] = jax.tree.map(
                        lambda a, b: a + np.asarray(b), grads[wid], g
                    )

        rt = WorkerPool(
            list(range(len(microbatches))),
            nw,
            task_fn,
            policy=self.policy,
            radius=self.radius,
            seed=self.step_count,
        )
        stats = rt.run()
        self.history.append(stats)

        # ----------------------------------------------- combine + update
        total = sum(counts)
        failed = sorted({wid for wid, _, _ in rt.errors})
        if total < len(microbatches):
            # Only possible if every worker died: surviving workers steal the
            # re-queued tasks of dead ones, so partial failure still finishes.
            raise WorkerFailed(failed[0] if failed else -1)
        combined = None
        for wid in range(nw):
            if grads[wid] is None:
                continue
            g = grads[wid]
            if self.compress:
                packed = self._ef[wid].compress(g)
                g = ErrorFeedback.decompress(packed)
            combined = g if combined is None else jax.tree.map(np.add, combined, g)
        combined = jax.tree.map(lambda x: jnp.asarray(x / total), combined)
        self.params, self.opt_state, om = adamw_update(
            combined, self.opt_state, self.params, self.opt_cfg, lr_scale
        )
        self.step_count += 1
        return {
            "loss": sum(losses) / max(total, 1),
            "tasks_per_worker": counts,
            "steals": len(stats.steals),
            "makespan": stats.makespan,
            "grad_norm": float(om["grad_norm"]),
            "failed_workers": failed,
        }

    # ------------------------------------------------------------- elasticity
    def remove_worker(self, wid: int) -> None:
        del self.workers[wid]
        del self._ef[wid]

    def add_worker(self, spec: WorkerSpec) -> None:
        self.workers.append(spec)
        self._ef.append(ErrorFeedback())

"""Gradient compression for cross-group (slow-link) reduction.

int8 per-tensor quantisation with **error feedback**: the residual of each
compression round is added back before the next one, so the bias vanishes and
SGD-style convergence is preserved (Karimireddy et al., 2019).  Used by the
heterogeneous-DP runtime when combining gradients across worker groups whose
interconnect is slow (cross-pod DCI), cutting gradient bytes 4x vs f32.
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = ["quantize", "dequantize", "ErrorFeedback", "compressed_bytes"]


def quantize(x: jax.Array) -> tuple[np.ndarray, float]:
    xf = np.asarray(x, dtype=np.float32)
    scale = float(np.max(np.abs(xf))) / 127.0 if xf.size else 0.0
    if scale == 0.0:
        return np.zeros(xf.shape, np.int8), 0.0
    q = np.clip(np.rint(xf / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize(q: np.ndarray, scale: float) -> np.ndarray:
    return q.astype(np.float32) * scale


def compressed_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) + 4 for x in jax.tree.leaves(tree))


class ErrorFeedback:
    """Per-link error-feedback compressor over a gradient pytree."""

    def __init__(self) -> None:
        self._residual = None

    def compress(self, grads):
        """Returns (quantised tree of (q, scale)), updating the residual."""
        if self._residual is None:
            self._residual = jax.tree.map(
                lambda g: np.zeros(g.shape, np.float32), grads
            )
        corrected = jax.tree.map(
            lambda g, r: np.asarray(g, np.float32) + r, grads, self._residual
        )
        packed = jax.tree.map(quantize, corrected)
        self._residual = _residual_update(corrected, packed)
        return packed

    @staticmethod
    def decompress(packed):
        return _tree_map_packed(lambda p: dequantize(*p), packed)


def _is_packed(x) -> bool:
    return (
        isinstance(x, tuple)
        and len(x) == 2
        and isinstance(x[0], np.ndarray)
        and x[0].dtype == np.int8
    )


def _tree_map_packed(fn, packed):
    return jax.tree.map(fn, packed, is_leaf=_is_packed)


def _residual_update(corrected, packed):
    flat_c, treedef = jax.tree_util.tree_flatten(corrected)
    flat_p = treedef.flatten_up_to(packed)
    return treedef.unflatten(
        [c - dequantize(*p) for c, p in zip(flat_c, flat_p)]
    )

"""Deterministic synthetic token pipeline with host sharding and prefetch.

Real text is unavailable offline, so the stream is a splittable counter-based
PRNG over token ids with a Zipf-ish marginal — deterministic per (seed, step,
shard), which makes multi-host loading, checkpoint-resume and elastic
re-sharding exact: a worker joining at step k produces the same global batch
content as the worker it replaced.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "Prefetcher"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard: int = 0


class SyntheticLM:
    """Deterministic synthetic LM batches (tokens, labels)."""

    def __init__(self, cfg: DataConfig) -> None:
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_shards

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        base_row = step * cfg.global_batch + self.local_batch * cfg.shard
        for r in range(self.local_batch):
            rng = np.random.Generator(
                np.random.Philox(key=cfg.seed, counter=[0, 0, step, base_row + r])
            )
            # Zipf-ish marginal over the vocab, cheap to sample:
            u = rng.random(cfg.seq_len + 1)
            toks = (cfg.vocab * u**3).astype(np.int32) % cfg.vocab
            rows.append(toks)
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator."""

    def __init__(self, it, depth: int = 2) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def run():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)

        self._th = threading.Thread(target=run, daemon=True)
        self._th.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass

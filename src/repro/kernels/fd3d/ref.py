"""Pure-jnp oracle for the 3-D acoustic FD time step (paper Eq. 12).

Second-order in time, 8th-order in space:

    u_next = 2 u - u_prev + (c dt)^2 * lap(u)

``lap`` is the 7-point-per-axis (radius-4) Laplacian.  This module is the
correctness reference for the Pallas kernel in ``fd3d.py``; it is also fast
enough on CPU for the small shots used in tests/examples.
"""

from __future__ import annotations

import jax.numpy as jnp

# 8th-order central second-derivative coefficients (Fornberg).
C0 = -205.0 / 72.0
COEF = (8.0 / 5.0, -1.0 / 5.0, 8.0 / 315.0, -1.0 / 560.0)
HALO = 4


def laplacian(u: jnp.ndarray, dx: float) -> jnp.ndarray:
    """Radius-4 Laplacian with zero (Dirichlet) boundaries, same shape."""
    up = jnp.pad(u, HALO)
    out = 3.0 * C0 * u
    for axis in range(3):
        for k, c in enumerate(COEF, start=1):
            lo = [slice(HALO, -HALO)] * 3
            hi = [slice(HALO, -HALO)] * 3
            lo[axis] = slice(HALO - k, up.shape[axis] - HALO - k)
            hi[axis] = slice(HALO + k, up.shape[axis] - HALO + k)
            out = out + c * (up[tuple(lo)] + up[tuple(hi)])
    return out / (dx * dx)


def fd3d_step(
    u: jnp.ndarray, u_prev: jnp.ndarray, c2dt2: jnp.ndarray, dx: float
) -> jnp.ndarray:
    """One leapfrog time step of Eq. 12 (without the source injection)."""
    return 2.0 * u - u_prev + c2dt2 * laplacian(u, dx)

"""Public op for the fused FD3D step: picks Pallas or the jnp oracle.

``fd3d_step(u, u_prev, c2dt2, dx)`` is what the seismic substrate calls.  On
CPU (this container) the Pallas kernel runs in interpret mode for correctness
validation but the jnp oracle is faster, so the default backend is "ref" on
CPU and "pallas" on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .fd3d import fd3d_pallas

__all__ = ["fd3d_step", "default_backend"]


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("dx", "backend", "bz"))
def fd3d_step(
    u: jax.Array,
    u_prev: jax.Array,
    c2dt2: jax.Array,
    *,
    dx: float,
    backend: str | None = None,
    bz: int = 8,
) -> jax.Array:
    backend = backend or default_backend()
    if backend == "ref":
        return ref.fd3d_step(u, u_prev, c2dt2, dx)
    if backend == "pallas":
        return fd3d_pallas(
            u, u_prev, c2dt2, dx=dx, bz=bz,
            interpret=jax.default_backend() != "tpu",
        )
    if backend == "pallas_interpret":
        return fd3d_pallas(u, u_prev, c2dt2, dx=dx, bz=bz, interpret=True)
    raise ValueError(f"unknown backend {backend!r}")

"""Pallas TPU kernel for the fused 3-D acoustic FD time step.

The seismic shot (the paper's task payload, §3) spends its time in the
wave-equation stencil, so this is the compute hot-spot that earns a kernel.

TPU adaptation (vs. the CUDA shared-memory tiling a GPU paper would use):

* Blocks tile the LEADING (z) axis only; each block carries the full padded
  XY plane.  XY halos live in the array padding, so in-block x/y shifts are
  static slices on VMEM-resident data — the VPU's native access pattern
  (8x128 vector registers want contiguous trailing dims; NX should be a
  multiple of 128 lanes for full utilisation).
* Z halos come from a **three-view trick**: the same padded array is passed
  three times with block index maps (i, i+1, i+2) over a z-padded buffer, so
  the kernel sees the previous/centre/next z-blocks without overlapping
  BlockSpecs (Pallas blocks must tile disjointly; shifted views sidestep
  that).  VMEM per step = 3 input z-blocks + u_prev + c^2dt^2 + out block:
      (3*(BZ, NYp, NXp) + 3*(BZ, NY, NX)) * 4 bytes
  with BZ=8, 512x512 planes: ~12.7 MiB — comfortably inside v5e VMEM.
* The stencil is VPU (element-wise) work, not MXU; arithmetic intensity is
  ~0.9 flop/byte so the kernel is HBM-bound and the win comes from fusing the
  whole leapfrog update (2u - u_prev + c2dt2 * lap) into ONE pass over HBM
  instead of the ~7 passes an unfused jnp implementation issues.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import C0, COEF, HALO

__all__ = ["fd3d_pallas"]


def _kernel(u_prevblk, u_mid, u_lo, u_hi, up_c, c2dt2, out, *, bz, dx):
    """out = 2u - u_prev + c2dt2 * lap(u) on one z-block.

    ``u_lo``/``u_mid``/``u_hi`` are the (i, i+1, i+2) views of the z-padded,
    xy-padded wavefield; the centre block's interior starts at z offset 0 of
    ``u_mid``.  ``up_c`` is the centre view again (alias of u_mid, kept for
    symmetry of the z-column assembly).
    """
    inv_dx2 = 1.0 / (dx * dx)
    # Assemble a (bz + 2*HALO) z-column around the centre block: the last
    # HALO planes of u_lo, all of u_mid, the first HALO planes of u_hi.
    col = jnp.concatenate(
        [u_lo[bz - HALO :, :, :], u_mid[:, :, :], u_hi[:HALO, :, :]], axis=0
    )
    # Centre region within the column / xy padding.
    c = col[HALO : HALO + bz, HALO:-HALO, HALO:-HALO]
    lap = 3.0 * C0 * c
    for k, w in enumerate(COEF, start=1):
        lap = lap + w * (
            col[HALO - k : HALO + bz - k, HALO:-HALO, HALO:-HALO]
            + col[HALO + k : HALO + bz + k, HALO:-HALO, HALO:-HALO]
        )
        lap = lap + w * (
            col[HALO : HALO + bz, HALO - k : col.shape[1] - HALO - k, HALO:-HALO]
            + col[HALO : HALO + bz, HALO + k : col.shape[1] - HALO + k, HALO:-HALO]
        )
        lap = lap + w * (
            col[HALO : HALO + bz, HALO:-HALO, HALO - k : col.shape[2] - HALO - k]
            + col[HALO : HALO + bz, HALO:-HALO, HALO + k : col.shape[2] - HALO + k]
        )
    out[...] = 2.0 * c - u_prevblk[...] + c2dt2[...] * (lap * inv_dx2)


@functools.partial(jax.jit, static_argnames=("dx", "bz", "interpret"))
def fd3d_pallas(
    u: jax.Array,
    u_prev: jax.Array,
    c2dt2: jax.Array,
    *,
    dx: float,
    bz: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Fused FD step via pallas_call.  Shapes (NZ, NY, NX); NZ % bz == 0.

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on a real TPU pass ``interpret=False``.
    """
    nz, ny, nx = u.shape
    if nz % bz != 0:
        raise ValueError(f"NZ={nz} must be a multiple of bz={bz}")
    if bz < HALO:
        raise ValueError(f"bz={bz} must be >= HALO={HALO}")
    # Pad: one full block of zeros on each z side (so the i/i+2 views always
    # index valid blocks) and HALO zeros on x/y (Dirichlet boundaries).
    up = jnp.pad(u, ((bz, bz), (HALO, HALO), (HALO, HALO)))
    nyp, nxp = ny + 2 * HALO, nx + 2 * HALO
    grid = (nz // bz,)

    padded_spec = lambda off: pl.BlockSpec(  # noqa: E731
        (bz, nyp, nxp), lambda i, o=off: (i + o, 0, 0)
    )
    plain_spec = pl.BlockSpec((bz, ny, nx), lambda i: (i, 0, 0))

    return pl.pallas_call(
        functools.partial(_kernel, bz=bz, dx=dx),
        grid=grid,
        in_specs=[
            plain_spec,        # u_prev block
            padded_spec(1),    # centre view
            padded_spec(0),    # lower (z-1) view
            padded_spec(2),    # upper (z+1) view
            padded_spec(1),    # centre view alias
            plain_spec,        # c2dt2 block
        ],
        out_specs=plain_spec,
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), u.dtype),
        interpret=interpret,
    )(u_prev, up, up, up, up, c2dt2)

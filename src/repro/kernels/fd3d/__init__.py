from .ops import fd3d_step, default_backend
from .fd3d import fd3d_pallas
from . import ref

__all__ = ["fd3d_step", "default_backend", "fd3d_pallas", "ref"]

"""Residual block assembly for every architecture family.

Block kinds
-----------
  attn        GQA self-attention (+ gated MLP)        dense transformers
  local       sliding-window GQA (+ gated MLP)        recurrentgemma / hybrids
  attn_dense  attention (GQA or MLA) + dense MLP      MoE models, first-k layers
  attn_moe    attention (GQA or MLA) + MoE            MoE models
  ssm         Mamba-2 SSD mixer (no MLP)              mamba2
  rglru       RG-LRU recurrence + gated MLP           recurrentgemma
  enc         bidirectional GQA + MLP                 seamless encoder
  xdec        causal self-attn + cross-attn + MLP     seamless decoder

Every apply returns ``(x, aux_loss, cache)`` so the scan bodies in ``lm.py``
stay uniform; decode returns ``(x, cache)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import ksplit, dense, mrope, param, rms_norm, rope

__all__ = [
    "block_params",
    "block_apply",
    "block_decode",
    "block_init_cache",
    "make_rope_fn",
]


# ------------------------------------------------------------------ MLP bits
def _mlp_params(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = ksplit(key, 3)
    if cfg.act == "plain":  # non-gated (seamless)
        return {
            "w_in": param(ks[0], (d, f), ("embed", "ffn")),
            "w_out": param(ks[1], (f, d), ("ffn", "embed")),
        }
    return {
        "w_gate": param(ks[0], (d, f), ("embed", "ffn")),
        "w_up": param(ks[1], (d, f), ("embed", "ffn")),
        "w_down": param(ks[2], (f, d), ("ffn", "embed")),
    }


def _mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_in" in p:
        return dense(jax.nn.relu(dense(x, p["w_in"])), p["w_out"])
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[
        cfg.act if cfg.act in ("silu", "gelu") else "silu"
    ]
    return dense(act(dense(x, p["w_gate"])) * dense(x, p["w_up"]), p["w_down"])


def make_rope_fn(cfg: ModelConfig, positions: jax.Array):
    """positions: [B,S] (standard) or [3,B,S] (M-RoPE)."""
    if cfg.mrope:
        return lambda x: mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return lambda x: rope(x, positions, cfg.rope_theta)


def _attn_params(key, cfg: ModelConfig):
    if cfg.mla is not None:
        return attn_mod.mla_params(key, cfg)
    return attn_mod.gqa_params(key, cfg)


# -------------------------------------------------------------------- params
def block_params(key, cfg: ModelConfig, kind: str) -> dict:
    ks = ksplit(key, 4)
    norm = lambda i: param(ks[i], (cfg.d_model,), ("embed",), init="zeros")  # noqa: E731
    if kind in ("attn", "local"):
        return {
            "norm1": norm(0),
            "attn": _attn_params(ks[1], cfg),
            "norm2": norm(2),
            "mlp": _mlp_params(ks[3], cfg),
        }
    if kind == "attn_dense":
        d_ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense) else cfg.d_ff
        return {
            "norm1": norm(0),
            "attn": _attn_params(ks[1], cfg),
            "norm2": norm(2),
            "mlp": _mlp_params(ks[3], cfg, d_ff),
        }
    if kind == "attn_moe":
        return {
            "norm1": norm(0),
            "attn": _attn_params(ks[1], cfg),
            "norm2": norm(2),
            "moe": moe_mod.moe_params(ks[3], cfg),
        }
    if kind == "ssm":
        return {"norm1": norm(0), "ssm": ssm_mod.ssm_params(ks[1], cfg)}
    if kind == "rglru":
        return {
            "norm1": norm(0),
            "rec": rglru_mod.rglru_params(ks[1], cfg),
            "norm2": norm(2),
            "mlp": _mlp_params(ks[3], cfg),
        }
    if kind == "enc":
        return {
            "norm1": norm(0),
            "attn": attn_mod.gqa_params(ks[1], cfg),
            "norm2": norm(2),
            "mlp": _mlp_params(ks[3], cfg),
        }
    if kind == "xdec":
        return {
            "norm1": norm(0),
            "attn": attn_mod.gqa_params(ks[1], cfg),
            "normx": norm(1),
            "xattn": attn_mod.gqa_params(ks[2], cfg),
            "norm2": norm(2),
            "mlp": _mlp_params(ks[3], cfg),
        }
    raise ValueError(f"unknown block kind {kind!r}")


# --------------------------------------------------------------------- apply
def _self_attn(p, x, cfg, aux, *, window=0, want_cache, bidirectional=False):
    rope_fn = make_rope_fn(cfg, aux["positions"])
    if cfg.mla is not None:
        if want_cache:
            return attn_mod.mla_attend(
                p, x, cfg, aux["positions"], chunk=aux["chunk"], return_cache=True
            )
        return attn_mod.mla_attend(p, x, cfg, aux["positions"], chunk=aux["chunk"]), None
    if bidirectional:
        q, k, v = attn_mod._qkv(p, x, cfg, rope_fn)
        o = attn_mod.flash_attention(
            q, k, v, causal=False, chunk=aux["chunk"]
        )
        y = dense(o.reshape(*x.shape[:2], -1), p["wo"])
        return (y, (k, v)) if want_cache else (y, None)
    if want_cache:
        return attn_mod.gqa_attend(
            p, x, cfg, rope_fn, window=window, chunk=aux["chunk"], return_cache=True
        )
    return (
        attn_mod.gqa_attend(p, x, cfg, rope_fn, window=window, chunk=aux["chunk"]),
        None,
    )


def _cross_attn(p, x, cfg, memory_kv):
    """Cross attention: q from x, cached (k, v) from encoder memory."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, h, hd)
    k, v = memory_kv
    o = attn_mod.flash_attention(q, k, v, causal=False, chunk=1024)
    return dense(o.reshape(b, s, -1), p["wo"])


def memory_kv(p_xattn, memory, cfg: ModelConfig):
    """Precompute encoder-memory K/V for one decoder layer."""
    b, s, _ = memory.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    k = dense(memory, p_xattn["wk"], p_xattn.get("bk")).reshape(b, s, hkv, hd)
    v = dense(memory, p_xattn["wv"], p_xattn.get("bv")).reshape(b, s, hkv, hd)
    return k, v


def block_apply(p, x, *, kind, cfg: ModelConfig, aux, want_cache=False):
    """Returns (x, aux_loss, cache)."""
    zero = jnp.float32(0.0)
    if kind in ("attn", "attn_dense", "local", "enc"):
        window = cfg.window if kind == "local" or (kind == "attn" and cfg.window) else 0
        y, cache = _self_attn(
            p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, aux,
            window=window, want_cache=want_cache, bidirectional=(kind == "enc"),
        )
        x = x + y
        x = x + _mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        if want_cache and kind == "local":
            cache = _ring_from_full(cache, cfg.window)
        return x, zero, cache
    if kind == "attn_moe":
        y, cache = _self_attn(
            p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, aux,
            want_cache=want_cache,
        )
        x = x + y
        xn = rms_norm(x, p["norm2"], cfg.norm_eps)
        top_i, top_w, probs = moe_mod.route(p["moe"]["router"], xn, cfg.moe)
        aux_l = moe_mod.aux_load_balance_loss(probs, top_i, cfg.moe)
        x = x + moe_mod.moe_apply(p["moe"], xn, top_i, top_w, cfg, aux.get("ctx"))
        return x, aux_l, cache
    if kind == "ssm":
        xn = rms_norm(x, p["norm1"], cfg.norm_eps)
        if want_cache:
            y, cache = ssm_mod.ssm_apply(p["ssm"], xn, cfg, return_cache=True)
        else:
            y, cache = ssm_mod.ssm_apply(p["ssm"], xn, cfg), None
        return x + y, zero, cache
    if kind == "rglru":
        xn = rms_norm(x, p["norm1"], cfg.norm_eps)
        if want_cache:
            y, cache = rglru_mod.rglru_apply(p["rec"], xn, cfg, return_cache=True)
        else:
            y, cache = rglru_mod.rglru_apply(p["rec"], xn, cfg), None
        x = x + y
        x = x + _mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, zero, cache
    if kind == "xdec":
        y, cache = _self_attn(
            p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, aux,
            want_cache=want_cache,
        )
        x = x + y
        mkv = aux.get("memory_kv")
        if mkv is None:
            mkv = memory_kv(p["xattn"], aux["memory"], cfg)
        x = x + _cross_attn(
            p["xattn"], rms_norm(x, p["normx"], cfg.norm_eps), cfg, mkv
        )
        x = x + _mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        if want_cache:
            cache = (cache, mkv)
        return x, zero, cache
    raise ValueError(f"unknown block kind {kind!r}")


def _ring_from_full(kv, window):
    """Re-index the last ``window`` positions into ring-buffer slots."""
    k, v = kv
    p0 = k.shape[1]
    w = min(window, p0)
    idx = (jnp.arange(p0 - w, p0)) % window
    shape = (k.shape[0], window, *k.shape[2:])
    rk = jnp.zeros(shape, k.dtype).at[:, idx].set(k[:, -w:])
    rv = jnp.zeros(shape, v.dtype).at[:, idx].set(v[:, -w:])
    return rk, rv


# -------------------------------------------------------------------- decode
def block_decode(p, x, *, kind, cfg: ModelConfig, aux, cache, pos):
    """Single-token step.  Returns (x, cache')."""
    if kind in ("attn", "attn_dense", "attn_moe", "local", "xdec"):
        xn = rms_norm(x, p["norm1"], cfg.norm_eps)
        if cfg.mla is not None:
            y, cache_sa = attn_mod.mla_decode(
                p["attn"], xn, cfg, cache if kind != "xdec" else cache[0], pos
            )
        else:
            rope_fn = make_rope_fn(cfg, aux["positions"])
            y, cache_sa = attn_mod.gqa_decode(
                p["attn"], xn, cfg, rope_fn,
                cache if kind != "xdec" else cache[0], pos,
                window=cfg.window if kind == "local" else 0,
            )
        x = x + y
        if kind == "xdec":
            mkv = cache[1]
            x = x + _cross_attn(
                p["xattn"], rms_norm(x, p["normx"], cfg.norm_eps), cfg, mkv
            )
            new_cache = (cache_sa, mkv)
        else:
            new_cache = cache_sa
        if kind == "attn_moe":
            xn2 = rms_norm(x, p["norm2"], cfg.norm_eps)
            top_i, top_w, _ = moe_mod.route(p["moe"]["router"], xn2, cfg.moe)
            x = x + moe_mod.moe_apply(
                p["moe"], xn2, top_i, top_w, cfg, aux.get("ctx")
            )
        elif "mlp" in p:
            x = x + _mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, new_cache
    if kind == "ssm":
        y, cache = ssm_mod.ssm_decode(
            p["ssm"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, cache
        )
        return x + y, cache
    if kind == "rglru":
        y, cache = rglru_mod.rglru_decode(
            p["rec"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, cache
        )
        x = x + y
        x = x + _mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, cache
    raise ValueError(f"unknown block kind {kind!r}")


# --------------------------------------------------------------------- cache
def block_init_cache(cfg: ModelConfig, kind: str, bsz: int, cache_len: int, dtype):
    h_kv, hd = cfg.n_kv_heads, cfg.head_dim_
    if kind in ("attn", "attn_dense", "attn_moe"):
        if cfg.mla is not None:
            m = cfg.mla
            return (
                jnp.zeros((bsz, cache_len, m.kv_lora_rank), dtype),
                jnp.zeros((bsz, cache_len, m.qk_rope_dim), dtype),
            )
        shape = (bsz, cache_len, h_kv, hd)
        return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
    if kind == "local":
        # ring buffer is ALWAYS window-sized (matches _ring_from_full and
        # stays correct when generation continues past a short prompt)
        w = cfg.window or cache_len
        shape = (bsz, w, h_kv, hd)
        return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
    if kind == "ssm":
        return ssm_mod.ssm_init_cache(cfg, bsz, dtype)
    if kind == "rglru":
        return rglru_mod.rglru_init_cache(cfg, bsz, dtype)
    raise ValueError(f"no cache for kind {kind!r}")

"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Temporal-mixing block: two branches from the (normed) input —
  gate branch:  linear -> GELU
  x branch:     linear -> causal conv1d(K=4) -> RG-LRU
merged multiplicatively, then projected back to d_model.

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_a x_t + b_a)            recurrence gate
  i_t = sigmoid(W_x x_t + b_x)            input gate
  log a_t = -c * softplus(Lambda) * r_t   (so a_t in (0,1))
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``lax.associative_scan`` (log-depth — the reason this
family handles the 500k-token shapes); decode is the O(1) single step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, RGLRUConfig
from .layers import ksplit, Leaf, dense, param

__all__ = [
    "rglru_params",
    "rglru_apply",
    "rglru_decode",
    "rglru_init_cache",
    "rglru_naive",
]


def rglru_params(key, cfg: ModelConfig) -> dict:
    r: RGLRUConfig = cfg.rglru
    d, w = cfg.d_model, r.lru_width
    ks = ksplit(key, 8)
    return {
        "in_x": param(ks[0], (d, w), ("embed", "ffn")),
        "in_gate": param(ks[1], (d, w), ("embed", "ffn")),
        "conv_w": param(ks[2], (r.d_conv, w), (None, "ffn"), scale=0.5),
        "conv_b": param(ks[3], (w,), ("ffn",), init="zeros"),
        "w_a": param(ks[4], (w, w), ("ffn", "ffn")),
        "b_a": param(ks[4], (w,), ("ffn",), init="zeros"),
        "w_i": param(ks[5], (w, w), ("ffn", "ffn")),
        "b_i": param(ks[5], (w,), ("ffn",), init="zeros"),
        "lam": Leaf(jnp.full((w,), 1.0, jnp.float32), ("ffn",)),
        "out": param(ks[6], (w, d), ("ffn", "embed")),
    }


def _conv1d(u, w, b):
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(up[:, i : i + u.shape[1], :] * w[i] for i in range(k)) + b


def _gates(p, x, c_exp):
    """log_a [B,S,W] and gated input, both f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_a = -c_exp * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, b


def rglru_apply(p: dict, xin: jax.Array, cfg: ModelConfig, return_cache=False):
    """Full-sequence RG-LRU block.  xin [B,S,d] (already normed)."""
    r: RGLRUConfig = cfg.rglru
    gate = jax.nn.gelu(dense(xin, p["in_gate"]))
    x = dense(xin, p["in_x"])
    x = _conv1d(x, p["conv_w"], p["conv_b"])
    a, b = _gates(p, x, r.c_exponent)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(xin.dtype)
    y = dense(h * gate, p["out"])
    if return_cache:
        conv_tail = dense(xin, p["in_x"])[:, -(r.d_conv - 1) :, :]
        return y, (h[:, -1].astype(jnp.float32), conv_tail)
    return y


def rglru_naive(p: dict, xin: jax.Array, cfg: ModelConfig):
    """Step-by-step oracle for tests."""
    bsz = xin.shape[0]
    cache = rglru_init_cache(cfg, bsz, dtype=xin.dtype)
    outs = []
    for t in range(xin.shape[1]):
        y, cache = rglru_decode(p, xin[:, t : t + 1], cfg, cache)
        outs.append(y)
    return jnp.concatenate(outs, 1)


def rglru_init_cache(cfg: ModelConfig, bsz: int, dtype=jnp.bfloat16):
    r: RGLRUConfig = cfg.rglru
    return (
        jnp.zeros((bsz, r.lru_width), jnp.float32),
        jnp.zeros((bsz, r.d_conv - 1, r.lru_width), dtype),
    )


def rglru_decode(p: dict, xin: jax.Array, cfg: ModelConfig, cache):
    """One-token step.  xin [B,1,d]; cache = (h, conv_tail)."""
    r: RGLRUConfig = cfg.rglru
    hprev, conv_tail = cache
    gate = jax.nn.gelu(dense(xin, p["in_gate"]))  # [B,1,W]
    xproj = dense(xin, p["in_x"])
    window = jnp.concatenate([conv_tail, xproj], 1)  # [B,K,W]
    x = (window * p["conv_w"][None]).sum(1, keepdims=True) + p["conv_b"]
    a, b = _gates(p, x, r.c_exponent)
    h = a[:, 0] * hprev + b[:, 0]
    y = dense((h[:, None, :]).astype(xin.dtype) * gate, p["out"])
    return y, (h, window[:, 1:, :])

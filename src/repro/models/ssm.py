"""Mamba-2 (SSD — state-space duality) mixing layer.

Chunked SSD algorithm (train/prefill): sequence is split into chunks of Q
tokens; within a chunk the quadratic "attention-like" form runs on the fly,
across chunks a linear recurrence carries the [H, P, N] state.  Equivalent to
the full recurrence (tested against ``ssd_naive``), cost O(S·Q + S·N·P).

Decode keeps the state explicitly — O(1) per token, which is what makes the
``long_500k`` shape feasible for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, SSMConfig
from .layers import ksplit, Leaf, param

__all__ = [
    "ssm_params",
    "ssm_apply",
    "ssm_decode",
    "ssd_naive",
    "ssm_init_cache",
]


def ssm_params(key, cfg: ModelConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    h = d_in // s.head_dim
    g = s.n_groups
    conv_ch = d_in + 2 * g * s.d_state
    ks = ksplit(key, 6)
    import numpy as np

    dt = np.exp(
        np.random.RandomState(0).uniform(
            np.log(s.dt_min), np.log(s.dt_max), size=(h,)
        )
    )
    dt_bias = dt + np.log(-np.expm1(-dt))  # inverse softplus
    return {
        # packed: [z (d_in), x (d_in), B (g*n), C (g*n), dt (h)]
        "in_proj": param(
            ks[0], (d, 2 * d_in + 2 * g * s.d_state + h), ("embed", "ffn")
        ),
        "conv_w": param(ks[1], (s.d_conv, conv_ch), (None, "ffn"), scale=0.5),
        "conv_b": param(ks[2], (conv_ch,), ("ffn",), init="zeros"),
        "a_log": Leaf(
            jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)), ("heads",)
        ),
        "dt_bias": Leaf(jnp.asarray(dt_bias, jnp.float32), ("heads",)),
        "d_skip": param(ks[3], (h,), ("heads",), init="ones"),
        "norm": param(ks[4], (d_in,), ("ffn",), init="zeros"),
        "out_proj": param(ks[5], (d_in, d), ("ffn", "embed")),
    }


def _conv1d(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv along S.  u [B,S,C], w [K,C]."""
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(up[:, i : i + u.shape[1], :] * w[i] for i in range(k))
    return out + b


def _split_proj(zxbcdt, d_in, g, n, h):
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in : 2 * d_in]
    b = zxbcdt[..., 2 * d_in : 2 * d_in + g * n]
    c = zxbcdt[..., 2 * d_in + g * n : 2 * d_in + 2 * g * n]
    dt = zxbcdt[..., 2 * d_in + 2 * g * n :]
    return z, x, b, c, dt


def _gated_norm(y, z, gamma, eps):
    dt = y.dtype
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * (1 + gamma.astype(jnp.float32))).astype(dt)


def ssm_apply(p: dict, xin: jax.Array, cfg: ModelConfig, return_cache=False):
    """Chunked SSD over the full sequence.  xin [B, S, d]."""
    s: SSMConfig = cfg.ssm
    bsz, slen, d = xin.shape
    d_in = s.expand * d
    h = d_in // s.head_dim
    g, n, pdim, q = s.n_groups, s.d_state, s.head_dim, s.chunk
    assert slen % q == 0, (slen, q)
    nc = slen // q

    zxbcdt = xin @ p["in_proj"]
    z, x, bmat, cmat, dt = _split_proj(zxbcdt, d_in, g, n, h)
    xbc_pre = jnp.concatenate([x, bmat, cmat], -1)  # pre-conv (cache tail)
    xbc = jax.nn.silu(_conv1d(xbc_pre, p["conv_w"], p["conv_b"]))
    x, bmat, cmat = (
        xbc[..., :d_in],
        xbc[..., d_in : d_in + g * n],
        xbc[..., d_in + g * n :],
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["a_log"])  # [H]

    xh = x.reshape(bsz, nc, q, h, pdim).astype(jnp.float32)
    bh = bmat.reshape(bsz, nc, q, g, n).astype(jnp.float32)
    ch = cmat.reshape(bsz, nc, q, g, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h)
    hpg = h // g

    da = dtc * a  # [B,NC,Q,H]
    cum = jnp.cumsum(da, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,NC,Q(t),Q(s),H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    ldecay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    cb = jnp.einsum("bcqgn,bcsgn->bcqsg", ch, bh)  # [B,NC,Q,Q,G]
    cb = jnp.repeat(cb, hpg, axis=-1)  # -> heads
    m = cb * ldecay * dtc[:, :, None, :, :]  # weight on x_s
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", m, xh)

    # chunk summary state: sum_s exp(cum_end - cum_s) dt_s B_s x_s^T
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,NC,Q,H]
    bh_h = jnp.repeat(bh, hpg, axis=3)  # [B,NC,Q,H,N] (group -> heads)
    bx = jnp.einsum("bcshn,bcshp,bcsh->bchpn", bh_h, xh, dec_end * dtc)

    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,NC,H]

    def scan_fn(hstate, inp):
        bx_c, dec_c = inp  # [B,H,P,N], [B,H]
        h_out = hstate
        hstate = hstate * dec_c[:, :, None, None] + bx_c
        return hstate, h_out  # h_out = state BEFORE this chunk

    h0 = jnp.zeros((bsz, h, pdim, n), jnp.float32)
    hstate, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(bx, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B,NC,H,P,N]

    ch_h = jnp.repeat(ch, hpg, axis=3)  # [B,NC,Q,H,N]
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", ch_h, h_prev) * jnp.exp(cum)[
        ..., None
    ]
    y = (y_intra + y_inter).reshape(bsz, slen, h, pdim)
    y = y + xh.reshape(bsz, slen, h, pdim) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, slen, d_in).astype(xin.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_cache:
        conv_tail = xbc_pre[:, -(s.d_conv - 1) :, :]
        return out, (hstate, conv_tail.astype(xin.dtype))
    return out


def ssd_naive(p: dict, xin: jax.Array, cfg: ModelConfig):
    """Token-by-token recurrence oracle (slow; tests only)."""
    s: SSMConfig = cfg.ssm
    bsz, slen, d = xin.shape
    cache = ssm_init_cache(cfg, bsz, dtype=xin.dtype)
    outs = []
    for t in range(slen):
        y, cache = ssm_decode(p, xin[:, t : t + 1], cfg, cache)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


def ssm_init_cache(cfg: ModelConfig, bsz: int, dtype=jnp.bfloat16):
    s: SSMConfig = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return (
        jnp.zeros((bsz, h, s.head_dim, s.d_state), jnp.float32),
        jnp.zeros((bsz, s.d_conv - 1, conv_ch), dtype),
    )


def ssm_decode(p: dict, xin: jax.Array, cfg: ModelConfig, cache):
    """One-token step.  xin [B, 1, d]; cache = (state, conv_tail)."""
    s: SSMConfig = cfg.ssm
    bsz, _, d = xin.shape
    d_in = s.expand * d
    h = d_in // s.head_dim
    g, n, pdim = s.n_groups, s.d_state, s.head_dim
    hpg = h // g
    state, conv_tail = cache

    zxbcdt = xin @ p["in_proj"]
    z, x, bmat, cmat, dt = _split_proj(zxbcdt, d_in, g, n, h)
    xbc = jnp.concatenate([x, bmat, cmat], -1)  # [B,1,C]
    window = jnp.concatenate([conv_tail, xbc], axis=1)  # [B,K,C]
    conv_out = (window * p["conv_w"][None]).sum(1, keepdims=True) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    x, bmat, cmat = (
        xbc[..., :d_in],
        xbc[..., d_in : d_in + g * n],
        xbc[..., d_in + g * n :],
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a)  # [B,H]
    xh = x.reshape(bsz, h, pdim).astype(jnp.float32)
    bh = jnp.repeat(bmat.reshape(bsz, g, n), hpg, axis=1)  # [B,H,N]
    ch = jnp.repeat(cmat.reshape(bsz, g, n), hpg, axis=1)
    state = state * dec[:, :, None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xh, bh, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, ch)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(xin.dtype)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_tail = window[:, 1:, :]
    return out, (state, new_tail)

"""Attention variants: GQA (full / sliding-window / cached) and MLA.

Train/prefill paths use a flash-style blocked softmax (``lax.scan`` over KV
chunks with running max/denominator) so the [S, S] score matrix is never
materialised — mandatory for the 32k-prefill shapes.  Decode paths attend a
query of length 1 against the cache directly.

MLA (DeepSeek-V3) implements both the *naive* expanded form (train/prefill)
and the *absorbed* form for decode, where the cache holds only the compressed
``c_kv`` (kv_lora_rank) plus the shared rope key — 576 floats/token/layer —
and the up-projections are folded into the query/output einsums.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import MLAConfig, ModelConfig
from .layers import ksplit, dense, param, rms_norm, rope

__all__ = [
    "gqa_params",
    "gqa_attend",
    "gqa_decode",
    "mla_params",
    "mla_attend",
    "mla_decode",
    "flash_attention",
]

_NEG = -2.0e38


def flash_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, Hkv, D]
    v: jax.Array,  # [B, Sk, Hkv, Dv]
    *,
    causal: bool,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    chunk: int = 1024,
    scale: float | None = None,
) -> jax.Array:
    """Blocked softmax attention (pure JAX flash).  GQA via head grouping.

    ``q_offset`` is the absolute position of q[0] (for cached prefill);
    ``window`` > 0 restricts attention to the last ``window`` keys.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, dv = v.shape
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    # Keep Q/K/V in their storage dtype (bf16 on the target) and accumulate
    # the dots in f32 via preferred_element_type — the MXU reads bf16
    # natively, so this halves the HBM traffic of every score/PV pass vs
    # materialising f32 copies.
    qf = (q * scale).astype(q.dtype).reshape(b, sq, hkv, g, d)
    nchunk = -(-sk // chunk)
    pad = nchunk * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(b, nchunk, chunk, hkv, d)
    vc = vp.reshape(b, nchunk, chunk, hkv, dv)
    qpos = jnp.arange(sq) + q_offset  # [Sq]

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, cidx = inp
        kpos = cidx * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", qf, kb,
            preferred_element_type=jnp.float32,
        )  # [B,Sq,Hkv,G,C] f32 accum
        mask = kpos[None, :] <= qpos[:, None] if causal else (kpos[None, :] >= 0) & (qpos[:, None] >= 0)
        if window:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        mask = mask & (kpos[None, :] < sk)
        s = jnp.where(mask[None, :, None, None, :], s, _NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckv->bqkgv", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, g), _NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.arange(nchunk),
        ),
    )
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ------------------------------------------------------------------------ GQA
def gqa_params(key, cfg: ModelConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = ksplit(key, 4)
    p = {
        "wq": param(ks[0], (d, h * hd), ("embed", "heads")),
        "wk": param(ks[1], (d, hkv * hd), ("embed", "kv")),
        "wv": param(ks[2], (d, hkv * hd), ("embed", "kv")),
        "wo": param(ks[3], (h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = param(ks[0], (h * hd,), ("heads",), init="zeros")
        p["bk"] = param(ks[1], (hkv * hd,), ("kv",), init="zeros")
        p["bv"] = param(ks[2], (hkv * hd,), ("kv",), init="zeros")
    return p


def _qkv(p, x, cfg: ModelConfig, rope_fn):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, h, hd)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, s, hkv, hd)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, s, hkv, hd)
    q = rope_fn(q)
    k = rope_fn(k)
    return q, k, v


def gqa_attend(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rope_fn,
    *,
    window: int = 0,
    chunk: int = 1024,
    return_cache: bool = False,
):
    """Full/windowed causal self-attention for train & prefill."""
    q, k, v = _qkv(p, x, cfg, rope_fn)
    o = flash_attention(q, k, v, causal=True, window=window, chunk=chunk)
    y = dense(o.reshape(*x.shape[:2], -1), p["wo"])
    if return_cache:
        return y, (k, v)
    return y


def gqa_decode(
    p: dict,
    x: jax.Array,  # [B, 1, d]
    cfg: ModelConfig,
    rope_fn,
    cache: tuple[jax.Array, jax.Array],  # k/v [B, S_cache, Hkv, hd]
    pos: jax.Array,  # scalar int — number of tokens already in cache
    *,
    window: int = 0,
):
    """Single-token decode.  ``window``>0 => ring-buffer cache of that size."""
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = dense(x, p["wq"], p.get("bq")).reshape(b, 1, h, hd)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, 1, hkv, hd)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, 1, hkv, hd)
    q = rope_fn(q)
    k = rope_fn(k)
    ck, cv = cache
    s_cache = ck.shape[1]
    slot = pos % s_cache if window else pos
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
    kpos = jnp.arange(s_cache)
    if window:
        # ring buffer: entry at slot j holds absolute position
        # pos - ((slot - j) mod S_cache)
        age = jnp.mod(slot - kpos, s_cache)
        abs_pos = pos - age
        valid = (abs_pos >= 0) & (abs_pos > pos - window)
    else:
        valid = kpos <= pos
    g = h // hkv
    qf = (q * (1.0 / math.sqrt(hd))).astype(ck.dtype).reshape(b, 1, hkv, g, hd)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qf, ck,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[None, None, None, None, :], s, _NEG)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckv->bqkgv", a.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    y = dense(o.reshape(b, 1, h * hd).astype(x.dtype), p["wo"])
    return y, (ck, cv)


# ------------------------------------------------------------------------ MLA
def mla_params(key, cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = ksplit(key, 8)
    return {
        "w_dq": param(ks[0], (d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": param(ks[1], (m.q_lora_rank,), ("lora",), init="zeros"),
        "w_uq": param(ks[2], (m.q_lora_rank, h * qk), ("lora", "heads")),
        "w_dkv": param(
            ks[3], (d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "lora")
        ),
        "kv_norm": param(ks[4], (m.kv_lora_rank,), ("lora",), init="zeros"),
        "w_uk": param(
            ks[5], (m.kv_lora_rank, h * m.qk_nope_dim), ("lora", "heads")
        ),
        "w_uv": param(ks[6], (m.kv_lora_rank, h * m.v_dim), ("lora", "heads")),
        "wo": param(ks[7], (h * m.v_dim, d), ("heads", "embed")),
    }


def _mla_q(p, x, cfg, positions):
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    q = dense(rms_norm(dense(x, p["w_dq"]), p["q_norm"], cfg.norm_eps), p["w_uq"])
    q = q.reshape(b, s, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, x, cfg, positions):
    m: MLAConfig = cfg.mla
    ckv = dense(x, p["w_dkv"])
    c, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, k_rope  # [B,S,kvr], [B,S,rope_d]


def mla_attend(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    chunk: int = 1024,
    return_cache: bool = False,
):
    """Naive (expanded) MLA for train/prefill."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c, k_rope = _mla_ckv(p, x, cfg, positions)
    k_nope = dense(c, p["w_uk"]).reshape(b, s, h, m.qk_nope_dim)
    v = dense(c, p["w_uv"]).reshape(b, s, h, m.v_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.qk_rope_dim))],
        -1,
    )
    o = flash_attention(q, k, v, causal=True, chunk=chunk)
    y = dense(o.reshape(b, s, -1), p["wo"])
    if return_cache:
        return y, (c, k_rope)
    return y


def mla_decode(
    p: dict,
    x: jax.Array,  # [B, 1, d]
    cfg: ModelConfig,
    cache: tuple[jax.Array, jax.Array],  # c [B,S,kvr], k_rope [B,S,rope_d]
    pos: jax.Array,
):
    """Absorbed-matrix MLA decode against the compressed cache."""
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    positions = jnp.broadcast_to(pos, (b, 1))
    q_nope, q_rope = _mla_q(p, x, cfg, positions)  # [B,1,H,*]
    c_new, kr_new = _mla_ckv(p, x, cfg, positions)
    cc, ckr = cache
    cc = jax.lax.dynamic_update_slice(cc, c_new.astype(cc.dtype), (0, pos, 0))
    ckr = jax.lax.dynamic_update_slice(ckr, kr_new.astype(ckr.dtype), (0, pos, 0))
    # Absorb W_uk into q: q_eff[b,h,r] = q_nope . W_uk[., h, .].  All dots
    # read the compressed cache / up-projections in their storage dtype and
    # accumulate f32 (bf16 is MXU-native; f32 casts would double the cache
    # read traffic — decode is memory-bound on exactly these reads).
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk,
                       preferred_element_type=jnp.float32)  # [B,1,H,kvr]
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s = (
        jnp.einsum("bqhr,bsr->bqhs", q_eff.astype(cc.dtype), cc,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,bsd->bqhs", q_rope.astype(ckr.dtype), ckr,
                     preferred_element_type=jnp.float32)
    ) * scale
    valid = jnp.arange(cc.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, _NEG)
    a = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bqhs,bsr->bqhr", a.astype(cc.dtype), cc,
                     preferred_element_type=jnp.float32)  # [B,1,H,kvr]
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_dim)
    o = jnp.einsum("bqhr,rhv->bqhv", o_c.astype(w_uv.dtype), w_uv,
                   preferred_element_type=jnp.float32)
    y = dense(o.reshape(b, 1, h * m.v_dim).astype(x.dtype), p["wo"])
    return y, (cc, ckr)

"""Mixture-of-Experts layer with expert parallelism over the 'model' axis.

Design (baseline, recorded as such in EXPERIMENTS.md §Perf):

* Routing (softmax top-k, optional normalisation) happens in the auto-sharded
  (pjit) world — logits are tiny.
* Expert compute runs inside ``shard_map``: activations are **replicated
  across the TP/EP ('model') axis** (exactly what Megatron-style TP leaves
  between blocks), so each EP rank simply *selects* the tokens routed to its
  local experts into a fixed-capacity buffer ``[E_loc, C, d]``, runs the gated
  MLP as one batched einsum, scatter-adds weighted outputs into a local
  [tokens, d] partial, and a single ``psum`` over 'model' combines partials —
  the same collective volume as one TP all-reduce.  (The all-to-all dispatch
  variant is the §Perf hillclimb.)
* Tokens beyond an expert's capacity ``C = ceil(tokens*top_k/E * cf)`` are
  dropped (standard GShard semantics); tests use cf large enough for zero
  drops when checking numerics against the dense oracle.
* The shared expert (DeepSeek) is a TP-sharded dense MLP folded into the SAME
  psum, costing no extra collective.

``moe_dense_ref`` is the all-experts-dense oracle used by unit tests.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import shard_map_compat

from .config import ModelConfig, MoEConfig
from .layers import ksplit, dense, param

__all__ = [
    "moe_params",
    "route",
    "moe_apply",
    "moe_dense_ref",
    "aux_load_balance_loss",
]


def moe_params(key, cfg: ModelConfig) -> dict:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    f = m.d_expert
    ks = ksplit(key, 6)
    p = {
        "router": param(ks[0], (d, m.num_experts), ("embed", None), dtype=jnp.float32),
        "w1": param(ks[1], (m.num_experts, d, f), ("experts", "embed", "ffn")),
        "w3": param(ks[2], (m.num_experts, d, f), ("experts", "embed", "ffn")),
        "w2": param(ks[3], (m.num_experts, f, d), ("experts", "ffn", "embed")),
    }
    if m.num_shared:
        fs = (m.d_shared or f) * m.num_shared
        p["ws1"] = param(ks[4], (d, fs), ("embed", "ffn"))
        p["ws3"] = param(ks[5], (d, fs), ("embed", "ffn"))
        p["ws2"] = param(ks[4], (fs, d), ("ffn", "embed"))
    return p


def route(router_w: jax.Array, x: jax.Array, m: MoEConfig):
    """Top-k routing.  Returns (top_idx [B,S,k], top_w [B,S,k], probs)."""
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_i, top_w.astype(x.dtype), probs


def aux_load_balance_loss(probs: jax.Array, top_i: jax.Array, m: MoEConfig):
    """Switch-style load-balance auxiliary loss."""
    e = m.num_experts
    counts = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = probs.mean(axis=(0, 1))
    return e * jnp.sum(frac_tokens * frac_probs) * m.aux_loss_coef


def _expert_compute(xbuf, w1, w3, w2, act):
    h = jnp.einsum("ecd,edf->ecf", xbuf, w1)
    u = jnp.einsum("ecd,edf->ecf", xbuf, w3)
    h = act(h) * u
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _dispatch_local(
    x2d: jax.Array,  # [T, d] local tokens (flattened b*s)
    top_i: jax.Array,  # [T, k]
    top_w: jax.Array,  # [T, k]
    w1, w3, w2,  # [E_loc, ...] local expert weights
    *,
    m: MoEConfig,
    rank: jax.Array,
    act,
) -> jax.Array:
    """Select->compute->scatter-add for this rank's experts.  [T, d] partial."""
    t, d_model = x2d.shape
    e_loc = w1.shape[0]
    cap = int(math.ceil(t * m.top_k / m.num_experts * m.capacity_factor))
    lo = rank * e_loc

    eid = top_i.reshape(-1)  # [T*k]
    wgt = top_w.reshape(-1)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), m.top_k)
    local_e = eid - lo
    mine = (local_e >= 0) & (local_e < e_loc)
    sort_key = jnp.where(mine, local_e, e_loc)  # strangers sort last
    order = jnp.argsort(sort_key, stable=True)
    key_sorted = sort_key[order]
    starts = jnp.searchsorted(key_sorted, jnp.arange(e_loc + 1))
    slot_sorted = jnp.arange(key_sorted.shape[0], dtype=jnp.int32) - starts[
        jnp.clip(key_sorted, 0, e_loc)
    ].astype(jnp.int32)
    ok = (key_sorted < e_loc) & (slot_sorted < cap)
    le_s = jnp.clip(key_sorted, 0, e_loc - 1)
    tok_s = tok[order]
    wgt_s = wgt[order]
    # gather tokens into the capacity buffer
    buf = jnp.zeros((e_loc, cap, d_model), x2d.dtype)
    buf = buf.at[
        jnp.where(ok, le_s, e_loc - 1),
        jnp.where(ok, slot_sorted, cap),  # cap -> dropped
    ].set(x2d[tok_s], mode="drop")
    ybuf = _expert_compute(buf, w1, w3, w2, act)
    # scatter-add weighted outputs back to token order
    out = jnp.zeros((t, d_model), x2d.dtype)
    vals = ybuf[le_s, jnp.clip(slot_sorted, 0, cap - 1)] * wgt_s[:, None]
    out = out.at[jnp.where(ok, tok_s, t)].add(vals, mode="drop")
    return out


def moe_apply(
    params: dict,
    x: jax.Array,  # [B, S, d]
    top_i: jax.Array,
    top_w: jax.Array,
    cfg: ModelConfig,
    ctx=None,  # ParallelContext | None
    act=jax.nn.silu,
) -> jax.Array:
    """Expert-parallel MoE forward (+ shared expert).

    Two device layouts, selected by ``ctx.ep_axes``:

    * ``("model",)`` (training): experts sharded over TP, activations
      replicated across 'model'; each rank selects its experts' tokens,
      computes, and one psum over 'model' combines — collective volume of a
      single TP all-reduce.  FSDP over 'data' happens OUTSIDE (weight specs).
    * full mesh (serving, ``serve_context``): every device owns E/P whole
      experts.  Decode batches are tiny, so the TOKENS are gathered across
      'data' (MBs) instead of gathering the WEIGHTS (GBs/layer, what the
      training layout would do at decode), and one global psum combines.
    """
    m = cfg.moe
    b, s, d = x.shape
    ep_axes = getattr(ctx, "ep_axes", ("model",)) if ctx is not None else ("model",)
    dp = ctx.dp_axes if ctx is not None else ("data",)
    tp = ctx.tp_axis if ctx is not None else None
    full_ep = ctx is not None and len(ep_axes) > 1

    def body(x_loc, ti_loc, tw_loc, w1, w3, w2, *shared):
        x2d = x_loc.reshape(-1, d)
        ti2 = ti_loc.reshape(-1, m.top_k)
        tw2 = tw_loc.reshape(-1, m.top_k)
        if ctx is None or ctx.mesh is None:
            rank = jnp.int32(0)
        elif full_ep:
            rank = jnp.int32(0)
            for ax in ep_axes:
                rank = rank * ctx.mesh.shape[ax] + lax.axis_index(ax)
        else:
            rank = lax.axis_index(tp)
        if full_ep:
            t_loc = x2d.shape[0]
            x2d = lax.all_gather(x2d, dp, axis=0, tiled=True)
            ti2 = lax.all_gather(ti2, dp, axis=0, tiled=True)
            tw2 = lax.all_gather(tw2, dp, axis=0, tiled=True)
        out = _dispatch_local(
            x2d, ti2, tw2, w1, w3, w2, m=m, rank=rank, act=act,
        )
        if shared:
            ws1, ws3, ws2 = shared
            h = act(x2d @ ws1) * (x2d @ ws3)
            sh = h @ ws2
            if full_ep:
                # shared weights are sharded over 'model' only, so every
                # 'data' rank computes the same partial: pre-scale so the
                # global psum does not multiply it by |data|.
                dp_n = 1
                for ax in dp:
                    dp_n *= ctx.mesh.shape[ax]
                sh = sh / dp_n
            out = out + sh
        if ctx is not None and ctx.mesh is not None:
            out = lax.psum(out, ep_axes if full_ep else tp)
            if full_ep:
                start = (lax.axis_index(dp[-1]) if len(dp) == 1 else (
                    lax.axis_index(dp[0]) * ctx.mesh.shape[dp[1]]
                    + lax.axis_index(dp[1])
                )) * t_loc
                out = lax.dynamic_slice_in_dim(out, start, t_loc, 0)
        return out.reshape(x_loc.shape)

    args = [x, top_i, top_w, params["w1"], params["w3"], params["w2"]]
    if m.num_shared:
        args += [params["ws1"], params["ws3"], params["ws2"]]

    if ctx is None or ctx.mesh is None:
        return body(*args)

    ep_spec = tuple(ep_axes) if full_ep else tp
    in_specs = [
        P(dp, None, None),  # x: replicated over model
        P(dp, None, None),  # top_i
        P(dp, None, None),  # top_w
        P(ep_spec, None, None),  # w1
        P(ep_spec, None, None),  # w3
        P(ep_spec, None, None),  # w2
    ]
    if m.num_shared:
        in_specs += [P(None, tp), P(None, tp), P(tp, None)]  # shared: TP
    return shard_map_compat(
        body,
        mesh=ctx.mesh,
        in_specs=tuple(in_specs),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(*args)


def moe_dense_ref(params, x, cfg: ModelConfig, act=jax.nn.silu):
    """Oracle: every expert computes every token; combine with top-k weights."""
    m = cfg.moe
    top_i, top_w, probs = route(params["router"], x, m)
    h = jnp.einsum("bsd,edf->bsef", x, params["w1"])
    u = jnp.einsum("bsd,edf->bsef", x, params["w3"])
    y_all = jnp.einsum("bsef,efd->bsed", act(h) * u, params["w2"])
    mask = jax.nn.one_hot(top_i, m.num_experts, dtype=x.dtype)  # [B,S,k,E]
    w_full = (mask * top_w[..., None]).sum(-2)  # [B,S,E]
    out = jnp.einsum("bsed,bse->bsd", y_all, w_full)
    if m.num_shared:
        h = act(x @ params["ws1"]) * (x @ params["ws3"])
        out = out + h @ params["ws2"]
    return out

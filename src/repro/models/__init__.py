from . import attention, blocks, layers, lm, moe, rglru, ssm
from .config import MLAConfig, ModelConfig, MoEConfig, RGLRUConfig, SSMConfig

__all__ = [
    "attention", "blocks", "layers", "lm", "moe", "rglru", "ssm",
    "MLAConfig", "ModelConfig", "MoEConfig", "RGLRUConfig", "SSMConfig",
]

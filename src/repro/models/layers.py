"""Shared building blocks: params-with-logical-axes, norms, MLPs, RoPE.

Parameters are plain nested dicts of jax.Arrays.  Every leaf is created via
``param(key, shape, axes, ...)`` which returns a ``Leaf`` carrying the array
together with its *logical axis names*; ``split(tree)`` separates the arrays
from the logical specs.  ``repro.parallel.sharding`` maps logical axes to mesh
axes (TP on 'model', FSDP on 'data', replication across 'pod').
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "Leaf",
    "param",
    "ksplit",
    "split",
    "rms_norm",
    "dense",
    "swiglu",
    "geglu_mlp",
    "rope",
    "mrope",
    "softcap",
]


@dataclasses.dataclass
class Leaf:
    value: Any  # jax.Array | ShapeDtypeStruct
    axes: tuple[str | None, ...]


def ksplit(key, n: int):
    """random.split that tolerates abstract (None) keys."""
    if key is None:
        return [None] * n
    return jax.random.split(key, n)


def param(
    key: jax.Array | None,
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    dtype=jnp.bfloat16,
    scale: float | str = "fan_in",
    init: str = "normal",
) -> Leaf:
    """Create one parameter Leaf.  ``axes`` names each dim logically.

    ``key=None`` produces an abstract Leaf (ShapeDtypeStruct) — used by the
    dry-run to build full-size parameter trees without allocating anything.
    """
    assert len(shape) == len(axes), (shape, axes)
    if key is None:
        return Leaf(jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)), axes)
    if init == "zeros":
        return Leaf(jnp.zeros(shape, dtype), axes)
    if init == "ones":
        return Leaf(jnp.ones(shape, dtype), axes)
    if scale == "fan_in":
        std = 1.0 / math.sqrt(shape[0] if len(shape) > 1 else 1.0)
    elif scale == "embed":
        std = 1.0
    else:
        std = float(scale)
    v = jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std
    return Leaf(v.astype(dtype), axes)


def split(tree) -> tuple[Any, Any]:
    """Split a Leaf-tree into (arrays, logical-axes) trees."""
    leaves_is = lambda x: isinstance(x, Leaf)  # noqa: E731
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=leaves_is)
    specs = jax.tree.map(lambda l: l.axes, tree, is_leaf=leaves_is)
    return params, specs


# ----------------------------------------------------------------- functional
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def swiglu(x, w_gate, w_up, w_down, act: str = "silu"):
    """Gated MLP: down( act(gate(x)) * up(x) )."""
    return dense(_act(act)(dense(x, w_gate)) * dense(x, w_up), w_down)


def geglu_mlp(x, w_in, w_down, act: str = "gelu"):
    """Fused-in gated MLP where w_in packs [gate; up] (seamless/simple MLP
    uses plain two-matrix form when gate dim == 0)."""
    h = dense(x, w_in)
    g, u = jnp.split(h, 2, axis=-1)
    return dense(_act(act)(g) * u, w_down)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ----------------------------------------------------------------------- RoPE
def _rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions [...,] -> angles [..., dim/2]."""
    freqs = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    return positions[..., None].astype(jnp.float32) * freqs


def _apply_angles(x: jax.Array, ang: jax.Array) -> jax.Array:
    """x [..., dim] rotated by angles [..., dim/2] (interleaved halves)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c, s = jnp.cos(ang), jnp.sin(ang)
    dt = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE.  x [B, S, H, D]; positions [B, S]."""
    ang = _rope_angles(positions, x.shape[-1], theta)  # [B, S, D/2]
    return _apply_angles(x, ang[:, :, None, :])


def mrope(
    x: jax.Array,
    positions: jax.Array,  # [3, B, S] (t, h, w) position ids
    theta: float,
    sections: tuple[int, int, int],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: frequency bands split across t/h/w ids.

    ``sections`` partitions the HALF-dim (D/2) frequency channels; text tokens
    have t==h==w so M-RoPE degenerates to standard RoPE for them.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)  # [D/2]
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=d // 2
    )  # [D/2] which of t/h/w drives this channel
    pos = positions.astype(jnp.float32)  # [3, B, S]
    pos_per_channel = pos[sec_id]  # [D/2, B, S]
    ang = jnp.moveaxis(pos_per_channel, 0, -1) * freqs  # [B, S, D/2]
    return _apply_angles(x, ang[:, :, None, :])

"""Model assembly: decoder-only LMs (+ encoder-decoder) with scan-over-layers.

The layer stack is grouped into runs of identical block kinds (see
``ModelConfig.scan_groups``); each run is one ``lax.scan`` over stacked
parameters, keeping the HLO size O(1) in depth — essential for compiling the
61-layer/671B dry-run cells in reasonable time.  Rematerialisation is applied
per scan body according to ``cfg.remat``.

Public entry points (all pure functions over (params, batch)):
  init(cfg, key)            -> (params, logical_specs)
  forward(params, batch)    -> logits [B, S, vocab] (f32)
  loss_fn(params, batch)    -> (scalar loss, metrics)
  prefill(params, batch)    -> (last-token logits, caches)
  decode_step(params, tok, caches, pos) -> (logits, caches)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from . import blocks as blk
from .config import ModelConfig
from .layers import Leaf, ksplit, param, rms_norm, softcap, split

__all__ = [
    "init",
    "init_shapes",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_caches",
    "param_count",
]


def _group_kinds(group_kind: str) -> list[str]:
    if group_kind.startswith("cycle:"):
        return group_kind[len("cycle:") :].split("|")
    return [group_kind]


def _group_params(key, cfg: ModelConfig, group_kind: str, count: int):
    kinds = _group_kinds(group_kind)
    is_leaf = lambda x: isinstance(x, Leaf)  # noqa: E731

    def one(k):
        ks = ksplit(k, len(kinds))
        return {
            f"b{i}": blk.block_params(ks[i], cfg, kind)
            for i, kind in enumerate(kinds)
        }

    if key is None:  # abstract: prepend the layer dim structurally
        proto = one(None)

        def stack_abs(l: Leaf) -> Leaf:
            v = l.value
            if isinstance(v, jax.ShapeDtypeStruct):
                v = jax.ShapeDtypeStruct((count, *v.shape), v.dtype)
            else:  # small concrete leaf (e.g. dt_bias): broadcast
                v = jax.ShapeDtypeStruct((count, *v.shape), v.dtype)
            return Leaf(v, ("layers", *l.axes))

        return jax.tree.map(stack_abs, proto, is_leaf=is_leaf)

    # Concrete: init each layer and stack (vmap would trace Leafs; loop is
    # simpler and init happens once).
    per_layer = [one(k) for k in jax.random.split(key, count)]

    def stack(*leaves: Leaf) -> Leaf:
        vals = [l.value for l in leaves]
        return Leaf(jnp.stack(vals), ("layers", *leaves[0].axes))

    return jax.tree.map(stack, *per_layer, is_leaf=is_leaf)


def _decoder_groups(cfg: ModelConfig):
    if cfg.enc_layers:
        return (("xdec", cfg.n_layers),)
    return cfg.scan_groups()


def _embed_scale(cfg: ModelConfig) -> float:
    return float(cfg.d_model) ** 0.5 if cfg.family == "hybrid" else 1.0


def init(cfg: ModelConfig, key) -> tuple[Any, Any]:
    """Returns (params, logical_axes) trees (same structure).

    ``key=None`` builds the tree abstractly (ShapeDtypeStruct leaves, nothing
    allocated) — the dry-run path for 671B-scale configs.
    """
    ks = ksplit(key, 8)
    tree: dict[str, Any] = {}
    tree["embed"] = param(
        ks[0], (cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), scale=0.02
    )
    groups = _decoder_groups(cfg)
    gkeys = ksplit(ks[1], len(groups))
    tree["groups"] = [
        _group_params(k, cfg, kind, count)
        for k, (kind, count) in zip(gkeys, groups)
    ]
    tree["final_norm"] = param(ks[2], (cfg.d_model,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        tree["head"] = param(
            ks[3], (cfg.d_model, cfg.vocab_padded), ("embed", "vocab"), scale=0.02
        )
    if cfg.enc_layers:
        tree["enc_groups"] = [_group_params(ks[4], cfg, "enc", cfg.enc_layers)]
        tree["enc_norm"] = param(ks[5], (cfg.d_model,), ("embed",), init="zeros")
    if cfg.mtp:  # DeepSeek-V3 multi-token prediction module (depth 1)
        mtp_kind = cfg.block_types()[-1]
        mks = ksplit(ks[6], 4)
        tree["mtp"] = {
            "norm_h": param(mks[0], (cfg.d_model,), ("embed",), init="zeros"),
            "norm_e": param(mks[1], (cfg.d_model,), ("embed",), init="zeros"),
            "proj": param(mks[2], (2 * cfg.d_model, cfg.d_model), (None, "embed")),
            "block": blk.block_params(mks[3], cfg, mtp_kind),
        }
    return split(tree)


def init_shapes(cfg: ModelConfig) -> tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical-axes tree) — used by the dry-run."""
    shapes, specs = init(cfg, None)

    def to_sds(v):
        if isinstance(v, jax.ShapeDtypeStruct):
            return v
        return jax.ShapeDtypeStruct(v.shape, v.dtype)

    return jax.tree.map(to_sds, shapes), specs


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _run_groups(params_groups, x, cfg: ModelConfig, aux, groups, want_cache=False):
    """Apply every scan group; returns (x, aux_loss_sum, caches|None)."""
    aux_total = jnp.float32(0.0)
    caches = []
    for gp, (kind, count) in zip(params_groups, groups):
        kinds = _group_kinds(kind)

        def body(carry, layer_p):
            h = constrain(carry, aux.get("ctx"), ("dp", None, None))
            a_sum = jnp.float32(0.0)
            cs = []
            for i, k in enumerate(kinds):
                h, a, c = blk.block_apply(
                    layer_p[f"b{i}"], h, kind=k, cfg=cfg, aux=aux,
                    want_cache=want_cache,
                )
                a_sum = a_sum + a
                cs.append(c)
            out = tuple(cs) if want_cache else None
            return h, (a_sum, out)

        body = _remat(body, cfg)
        x, (a_per_layer, cache_stack) = jax.lax.scan(body, x, gp)
        aux_total = aux_total + a_per_layer.sum()
        caches.append(cache_stack)
    return x, aux_total, (caches if want_cache else None)


def _embed_tokens(params, tokens, cfg: ModelConfig):
    x = params["embed"][tokens] * _embed_scale(cfg)
    return x.astype(jnp.dtype(cfg.dtype))


def _logits(params, x, cfg: ModelConfig, ctx=None):
    x = constrain(x, ctx, ("dp", None, None))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = constrain(logits, ctx, ("dp", None, "tp"))
    logits = softcap(logits.astype(jnp.float32), cfg.logits_softcap)
    if cfg.vocab_padded != cfg.vocab:  # mask the padded vocab columns
        keep = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(keep, logits, -2.0e38)
    return logits


def _encode(params, batch, cfg: ModelConfig, aux):
    """Encoder stack for enc-dec models (bidirectional)."""
    x = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
    enc_aux = dict(aux)
    enc_aux["positions"] = batch.get(
        "enc_positions",
        jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2]),
    )
    x = constrain(x, aux.get("ctx"), ("dp", None, None))
    x, _, _ = _run_groups(
        params["enc_groups"], x, cfg, enc_aux, (("enc", cfg.enc_layers),)
    )
    return constrain(rms_norm(x, params["enc_norm"], cfg.norm_eps),
                     aux.get("ctx"), ("dp", None, None))


def _make_aux(batch, cfg: ModelConfig, ctx, chunk=1024):
    if cfg.mrope:
        positions = batch["positions"]  # [3, B, S]
    else:
        tokens = batch.get("tokens")
        ref = tokens if tokens is not None else batch["embeds"][..., 0]
        positions = batch.get(
            "positions",
            jnp.broadcast_to(jnp.arange(ref.shape[1])[None], ref.shape[:2]),
        )
    return {"positions": positions, "ctx": ctx, "chunk": chunk, "memory": None}


def forward(params, batch, cfg: ModelConfig, ctx=None, chunk: int = 1024):
    """Training forward.  batch: tokens [B,S] (or embeds), positions, labels."""
    aux = _make_aux(batch, cfg, ctx, chunk)
    if cfg.enc_layers:
        aux["memory"] = _encode(params, batch, cfg, aux)
    if "embeds" in batch and not cfg.enc_layers:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = _embed_tokens(params, batch["tokens"], cfg)
    x = constrain(x, ctx, ("dp", None, None))
    x, aux_loss, _ = _run_groups(params["groups"], x, cfg, aux, _decoder_groups(cfg))
    return _logits(params, x, cfg, ctx), aux_loss


def _mtp_trunk(params, h, batch, cfg: ModelConfig, aux):
    """DeepSeek-V3 MTP (depth 1): predict token t+2 from (h_t, emb_{t+1}).

    ``h`` is the trunk output BEFORE the final norm, [B, S, d].  Returns the
    MTP hidden states [B, S-1, d] (logits via the shared streamed CE head).
    """
    p = params["mtp"]
    emb = _embed_tokens(params, batch["tokens"], cfg)  # [B,S,d]
    hh = rms_norm(h[:, :-1], p["norm_h"], cfg.norm_eps)
    ee = rms_norm(emb[:, 1:], p["norm_e"], cfg.norm_eps)
    x = jnp.concatenate([hh, ee], axis=-1) @ p["proj"].astype(hh.dtype)
    x = constrain(x, aux.get("ctx"), ("dp", None, None))
    aux_m = dict(aux)
    aux_m["positions"] = aux["positions"][..., :-1]
    kind = cfg.block_types()[-1]
    x, _, _ = blk.block_apply(p["block"], x, kind=kind, cfg=cfg, aux=aux_m)
    return x


def _ce(logits, labels, mask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _num_ce_chunks(cfg: ModelConfig, seq: int) -> int:
    """Resolved chunk count: a divisor of ``seq`` near the target."""
    want = cfg.ce_chunks
    if want == 0:  # auto: ~16M logits elements per chunk
        want = max(1, (seq * cfg.vocab_padded) // (1 << 24))
    want = min(want, seq)
    for nc in range(want, 0, -1):
        if seq % nc == 0:
            return nc
    return 1


def _ce_stream(params, h, labels, mask, cfg: ModelConfig, ctx):
    """Streaming cross-entropy over sequence chunks (§Perf, train cells).

    The head matmul + log-softmax + gather run one [B, S/nc] slab at a time
    inside a remat'd scan, so the [B, S, vocab] f32 logits never exist —
    peak loss-side activation drops by nc (32x for the 4k x 129k deepseek
    train cell).  Chunking the SEQUENCE keeps the vocab-sharded head matmul
    layout untouched (vocab chunking would slice the sharded dim).
    """
    nc = _num_ce_chunks(cfg, h.shape[1])
    if nc <= 1:
        return _ce(_logits(params, h, cfg, ctx), labels, mask)
    b, s, d = h.shape
    sc = s // nc
    hc = jnp.moveaxis(h.reshape(b, nc, sc, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, sc), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nc, sc), 1, 0)

    def body(carry, xs):
        nll, msum = carry
        h_c, l_c, m_c = xs
        logp = jax.nn.log_softmax(_logits(params, h_c, cfg, ctx), axis=-1)
        ll = jnp.take_along_axis(logp, l_c[..., None], axis=-1)[..., 0]
        return (nll - (ll * m_c).sum(), msum + m_c.sum()), None

    (nll, msum), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)),
        (hc, lc, mc),
    )
    return nll / jnp.maximum(msum, 1.0)


def loss_fn(params, batch, cfg: ModelConfig, ctx=None, chunk: int = 1024):
    aux = _make_aux(batch, cfg, ctx, chunk)
    if cfg.enc_layers:
        aux["memory"] = _encode(params, batch, cfg, aux)
    if "embeds" in batch and not cfg.enc_layers:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = _embed_tokens(params, batch["tokens"], cfg)
    x = constrain(x, ctx, ("dp", None, None))
    h, aux_loss, _ = _run_groups(params["groups"], x, cfg, aux, _decoder_groups(cfg))
    labels = batch["labels"]
    mask = batch.get("loss_mask", jnp.ones(labels.shape, jnp.float32))
    ce = _ce_stream(params, h, labels, mask, cfg, ctx)
    loss = ce + aux_loss
    metrics = {"ce": ce, "aux": aux_loss, "tokens": mask.sum()}
    if cfg.mtp and "tokens" in batch:
        h_mtp = _mtp_trunk(params, h, batch, cfg, aux)
        ce_mtp = _ce_stream(
            params, h_mtp, labels[:, 1:], mask[:, 1:], cfg, ctx
        )
        loss = loss + cfg.mtp_weight * ce_mtp
        metrics["ce_mtp"] = ce_mtp
    return loss, metrics


# ------------------------------------------------------------------- serving
def init_caches(cfg: ModelConfig, bsz: int, cache_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    groups = _decoder_groups(cfg)
    caches = []
    for kind, count in groups:
        kinds = _group_kinds(kind)
        per_layer = tuple(
            blk.block_init_cache(cfg, k, bsz, cache_len, dtype)
            if k not in ("xdec",)
            else (
                blk.block_init_cache(cfg, "attn", bsz, cache_len, dtype),
                None,  # memory kv filled at prefill
            )
            for k in kinds
        )
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count, *a.shape)).copy()
            if a is not None
            else None,
            per_layer,
        )
        caches.append(stacked)
    return caches


def prefill(params, batch, cfg: ModelConfig, ctx=None, chunk: int = 1024):
    """Run the prompt; returns (last-position logits, caches)."""
    aux = _make_aux(batch, cfg, ctx, chunk)
    if cfg.enc_layers:
        aux["memory"] = _encode(params, batch, cfg, aux)
        x = _embed_tokens(params, batch["tokens"], cfg)
    elif "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = _embed_tokens(params, batch["tokens"], cfg)
    x = constrain(x, ctx, ("dp", None, None))
    x, _, caches = _run_groups(
        params["groups"], x, cfg, aux, _decoder_groups(cfg), want_cache=True
    )
    logits = _logits(params, x[:, -1:, :], cfg, ctx)
    return logits, caches


def pad_caches(caches, cfg: ModelConfig, cache_len: int):
    """Grow prefill caches to ``cache_len`` so decoding can continue.

    Full-attention K/V (and MLA compressed) caches are padded along the
    sequence dim; ring-buffer (local), SSM and RG-LRU states are fixed-size;
    enc-dec memory K/V is never padded (padded zero-keys would corrupt the
    cross-attention softmax).
    """
    groups = _decoder_groups(cfg)
    out = []
    for cache, (kind, _count) in zip(caches, groups):
        kinds = _group_kinds(kind)
        new = []
        for i, k in enumerate(kinds):
            c = cache[i]
            if k in ("attn", "attn_dense", "attn_moe"):
                c = tuple(_pad_seq(x, cache_len) for x in c)
            elif k == "xdec":
                sa, mkv = c
                c = (tuple(_pad_seq(x, cache_len) for x in sa), mkv)
            new.append(c)
        out.append(tuple(new))
    return out


def _pad_seq(x, cache_len: int):
    cur = x.shape[2]  # [L, B, S, ...]
    if cur >= cache_len:
        return x
    pad = [(0, 0)] * x.ndim
    pad[2] = (0, cache_len - cur)
    return jnp.pad(x, pad)


def decode_step(params, tokens, caches, pos, cfg: ModelConfig, ctx=None):
    """One decode step.  tokens [B, 1]; pos scalar int32."""
    bsz = tokens.shape[0]
    if cfg.mrope:
        positions = jnp.broadcast_to(pos, (3, bsz, 1))
    else:
        positions = jnp.broadcast_to(pos, (bsz, 1))
    aux = {"positions": positions, "ctx": ctx, "chunk": 1024, "memory": None}
    x = constrain(_embed_tokens(params, tokens, cfg), ctx, ("dp", None, None))
    groups = _decoder_groups(cfg)
    new_caches = []
    for gp, cache, (kind, count) in zip(params["groups"], caches, groups):
        kinds = _group_kinds(kind)

        def body(carry, xs):
            h = carry
            layer_p, layer_cache = xs
            new_cs = []
            for i, k in enumerate(kinds):
                h, c = blk.block_decode(
                    layer_p[f"b{i}"], h, kind=k, cfg=cfg, aux=aux,
                    cache=layer_cache[i], pos=pos,
                )
                new_cs.append(c)
            return h, tuple(new_cs)

        x, new_cache = jax.lax.scan(body, x, (gp, cache))
        new_caches.append(new_cache)
    logits = _logits(params, x, cfg, ctx)
    return logits, new_caches


# ------------------------------------------------------------------ counting
def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape; ``active_only`` scales expert
    weights by top_k/num_experts (for 6*N_active*D model flops)."""
    shapes, _ = init_shapes(cfg)
    total = 0

    def visit(path, leaf):
        nonlocal total
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only and cfg.moe is not None and "moe" in str(path):
            pstr = str(path)
            if any(f"'{w}'" in pstr for w in ("w1", "w2", "w3")):
                n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    return int(total)

"""Model configuration for the assigned architecture zoo.

One frozen dataclass covers all ten families; family-specific sub-configs are
None when unused.  Instances are hashable (usable as jit static args).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Literal

__all__ = [
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "RGLRUConfig",
    "ModelConfig",
]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # expert hidden size
    num_shared: int = 0
    d_shared: int = 0  # shared-expert hidden size (0 -> same as d_expert)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    aux_loss_coef: float = 0.001
    # layers [0, first_k_dense) use a dense FFN instead of MoE (DeepSeek-V3
    # keeps the first 3 layers dense).
    first_k_dense: int = 0
    d_ff_dense: int = 0  # hidden of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block."""

    lru_width: int = 2560
    d_conv: int = 4
    window: int = 2048  # sliding window of the interleaved local attention
    c_exponent: float = 8.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention flavour
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    window: int = 0  # 0 -> full attention
    mrope: bool = False  # qwen2-vl multimodal rotary (t/h/w sections)
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # halves of head_dim
    # block pattern for hybrids: tuple of "attn" | "local" | "rglru" | "ssm"
    # cycled over n_layers; empty -> all "attn" (or "ssm" for family=ssm)
    pattern: tuple[str, ...] = ()
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    # encoder-decoder (seamless): encoder layer count (decoder = n_layers)
    enc_layers: int = 0
    # modality frontend stub: inputs arrive as embeddings, not token ids
    frontend: Literal["none", "audio", "vision"] = "none"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"
    # multi-token prediction (DeepSeek-V3 MTP, depth 1): one extra block that
    # predicts token t+2 from (h_t, emb(t+1)); adds mtp_weight * CE to loss.
    mtp: bool = False
    mtp_weight: float = 0.3
    # embedding/head vocab dim is padded up to a multiple of this so the
    # vocab axis shards evenly over 'model' (padded logits are masked).
    vocab_pad_to: int = 16
    # streaming cross-entropy: the loss is computed over sequence chunks
    # (remat'd scan) so the [B, S, vocab] f32 logits are never materialised.
    # 0 = auto (chunk count from S*vocab), 1 = unchunked.
    ce_chunks: int = 0
    # training/serving knobs
    max_seq: int = 8192
    dtype: str = "bfloat16"
    remat: str = "full"  # "none" | "full" | "dots"
    logits_softcap: float = 0.0

    # ------------------------------------------------------------------ utils
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = max(self.vocab_pad_to, 1)
        return -(-self.vocab // m) * m

    def block_types(self) -> tuple[str, ...]:
        """Resolved per-layer block type list of length n_layers."""
        if self.pattern:
            reps = -(-self.n_layers // len(self.pattern))
            return (self.pattern * reps)[: self.n_layers]
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        if self.moe is not None:
            kinds = []
            for i in range(self.n_layers):
                kinds.append("attn_dense" if i < self.moe.first_k_dense else "attn_moe")
            return tuple(kinds)
        return ("attn",) * self.n_layers

    def scan_groups(self) -> tuple[tuple[str, int], ...]:
        """Consecutive (block_type, count) runs — each becomes one lax.scan.

        For cyclic patterns (e.g. recurrentgemma's rglru/rglru/local) the unit
        is the full cycle so one scan covers all repetitions.
        """
        types = self.block_types()
        if self.pattern and len(set(self.pattern)) > 1:
            # scan over whole cycles; leftover layers become their own runs
            cyc = len(self.pattern)
            full = self.n_layers // cyc
            groups = [("cycle:" + "|".join(self.pattern), full)] if full else []
            for t in types[full * cyc :]:
                groups.append((t, 1))
            return tuple(_merge_runs(groups))
        runs: list[tuple[str, int]] = []
        for t in types:
            if runs and runs[-1][0] == t:
                runs[-1] = (t, runs[-1][1] + 1)
            else:
                runs.append((t, 1))
        return tuple(runs)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops)."""
        from . import lm  # lazy: avoid cycle

        return lm.param_count(self)

    def active_param_count(self) -> int:
        from . import lm

        return lm.param_count(self, active_only=True)


def _merge_runs(groups: list[tuple[str, int]]) -> list[tuple[str, int]]:
    out: list[tuple[str, int]] = []
    for t, c in groups:
        if out and out[-1][0] == t:
            out[-1] = (t, out[-1][1] + c)
        else:
            out.append((t, c))
    return out

"""Checkpointing: atomic save/restore with async writer and elastic reshard.

Format: one ``.npz`` per checkpoint step + a JSON manifest, written to a tmp
path and atomically renamed (crash-safe).  Restore accepts a *different* mesh
than the one that saved: arrays are loaded on host and ``device_put`` with the
new shardings — this is the elastic-scaling path (a 16-device pod restoring a
32-device checkpoint or vice versa just works, because the on-disk format is
the unsharded logical array).

For 1000+-node deployments the same interface backs onto per-host shard files
(see ``save_sharded``); here single-host .npz keeps tests hermetic.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16/f8): widen —
            arr = arr.astype(np.float32)   # lossless, and .npz-portable
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree: Any, *, metadata: dict | None = None) -> str:
    """Atomic checkpoint write.  Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat.keys()),
        **(metadata or {}),
    }
    mtmp = final + ".json.tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, final + ".json")
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("ckpt_") : -len(".npz")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore(directory: str, template: Any, *, step: int | None = None, shardings=None):
    """Load a checkpoint into the structure of ``template``.

    ``shardings``: optional matching tree of NamedSharding for the CURRENT
    mesh — this is where elastic re-sharding happens (device_put with the new
    sharding regardless of how the checkpoint was produced).
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat_t)
    )
    out = []
    for (pathk, leaf), sh in zip(flat_t, shard_leaves):
        key = "/".join(_path_str(p) for p in pathk)
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype))
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in out]), step


class AsyncCheckpointer:
    """Fire-and-forget background saver (one in flight at a time)."""

    def __init__(self, directory: str) -> None:
        self.directory = directory
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, tree: Any, metadata: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before mutation

        def run():
            save(self.directory, step, host_tree, metadata=metadata)
            self.last_saved = step

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

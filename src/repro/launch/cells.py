"""Dry-run cell builders: one lowerable program per (arch x shape x mesh).

``lower_cell`` returns a ``jax.stages.Lowered`` for the cell's step function
against ShapeDtypeStruct inputs — nothing is allocated, so the full-size
configs (incl. the 671B one) lower on this CPU container.  ``analyze`` turns
(lowered, compiled) into the roofline record: per-device FLOPs/bytes from
``cost_analysis``, per-device collective payloads parsed from the
post-partitioning HLO, memory footprint from ``memory_analysis``.
"""

from __future__ import annotations

import os
import re
import time

import jax

from repro.configs.base import Shape, input_specs
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import ParallelContext
from repro.serve.engine import abstract_caches, jit_decode_step, jit_prefill_step
from repro.train.step import abstract_train_state, jit_train_step

__all__ = ["lower_cell", "analyze", "collective_bytes", "HW", "roofline_terms"]

# TPU v5e-like hardware constants (per chip).
HW = {
    "peak_flops": 197e12,  # bf16 FLOP/s
    "hbm_bw": 819e9,  # bytes/s
    "link_bw": 50e9,  # bytes/s per ICI link
}


def lower_cell(cfg: ModelConfig, shape: Shape, ctx: ParallelContext):
    """Lower the cell's step.  Returns (lowered, meta)."""
    t0 = time.time()
    batch = input_specs(cfg, shape)
    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype="bfloat16")
        params_sds, opt_sds, _ = abstract_train_state(cfg, opt_cfg)
        fn = jit_train_step(cfg, ctx, opt_cfg, batch, donate=True)
        lowered = fn.lower(params_sds, opt_sds, batch)
    elif shape.kind == "prefill":
        params_sds, _ = lm.init_shapes(cfg)
        fn = jit_prefill_step(cfg, ctx, batch)
        lowered = fn.lower(params_sds, batch)
    elif shape.kind == "decode":
        params_sds, _ = lm.init_shapes(cfg)
        b, s = shape.global_batch, shape.seq_len
        caches = abstract_caches(cfg, b, s)
        serve_layout = os.environ.get("REPRO_SERVE_LAYOUT", "1") != "0"
        fn = jit_decode_step(cfg, ctx, b, s, donate=True,
                             serve_layout=serve_layout)
        lowered = fn.lower(params_sds, batch["tokens"], caches, batch["pos"])
    else:
        raise ValueError(shape.kind)
    return lowered, {"lower_s": round(time.time() - t0, 2)}


# ------------------------------------------------------------- HLO analysis
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[d0,d1]{...}' or a '(tuple, of, them)'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device payload bytes by collective kind (result-shape accounting).

    The compiled module is the per-device SPMD program, so result shapes are
    per-shard — summing them gives per-device bytes entering/leaving this
    chip's links.  all-reduce is counted twice (reduce-scatter + all-gather
    phases of a ring implementation).  ``*-done`` ops are skipped (their
    ``*-start`` already counted).
    """
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        factor = 2 if kind == "all-reduce" else 1
        out[kind] = out.get(kind, 0) + nbytes * factor
    return out


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float,
) -> dict[str, float]:
    """The three roofline terms in seconds (per the assignment formulas,
    evaluated per-chip: global/(chips*X) == per_device/X)."""
    return {
        "t_compute": flops_per_dev / HW["peak_flops"],
        "t_memory": bytes_per_dev / HW["hbm_bw"],
        "t_collective": coll_bytes_per_dev / HW["link_bw"],
    }


def analyze(lowered, compiled, cfg: ModelConfig, shape: Shape, chips: int) -> dict:
    """Full roofline record for one compiled cell.

    FLOPs/bytes/collective payloads come from the trip-count-aware HLO walk
    (``hlo_analysis``) — XLA's ``cost_analysis`` counts while bodies once, so
    a 61-layer scan and its in-loop FSDP all-gathers would be 61x under-
    counted.  The raw XLA numbers are kept in the record as ``xla_*``.
    """
    from repro.launch.hlo_analysis import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    costs = analyze_hlo(hlo)
    flops = costs.flops
    byts = costs.bytes
    coll = {k: int(v) for k, v in costs.coll.items()}
    coll_total = costs.coll_bytes
    terms = roofline_terms(flops, byts, coll_total)
    dom = max(terms, key=terms.get)

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_rec[attr] = int(v)
    live = (
        mem_rec.get("argument_size_in_bytes", 0)
        + mem_rec.get("output_size_in_bytes", 0)
        + mem_rec.get("temp_size_in_bytes", 0)
        - mem_rec.get("alias_size_in_bytes", 0)
    )

    # MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = trained tokens
    # for train cells, else fwd-only 2*N*D.
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops = 2 * n_active * tokens
    flops_global = flops * chips
    useful = model_flops / flops_global if flops_global else float("nan")

    return {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "chips": chips,
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "xla_flops_per_device": xla_flops,
        "xla_bytes_per_device": xla_bytes,
        **{k: v for k, v in terms.items()},
        "dominant": dom,
        "model_flops": float(model_flops),
        "useful_flops_ratio": useful,
        "memory": mem_rec,
        "live_bytes_per_device": int(live),
        "fits_hbm16g": bool(live <= 16 * 1024**3),
    }

"""Serving driver: batched greedy generation on a reduced config.

Closed batch (the original smoke driver):

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --requests 8 --prompt-len 32 --new-tokens 16

Open-arrival continuous batching (DESIGN.md §Open-arrival): requests arrive
as a Poisson stream into a live ``ServePool`` over heterogeneous replicas —
fast replicas steal queued requests from slow ones mid-flight, and the
driver reports per-request latency percentiles:

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --requests 24 --prompt-len 16 --new-tokens 8 \
        --open-arrival --rate 8 --replicas 2 --slow-factor 4

``--policy`` swaps the scheduling policy balancing the replica pool
(DESIGN.md §Policy layer): a2ws (default) vs the ctws / lw / random
baselines, head-to-head on the same Poisson trace and latency metric.

``--autoscale-max N`` makes the pool ELASTIC (DESIGN.md §Elasticity): a
threshold autoscaler boots surge replicas up to N while the backlog
exceeds its per-replica bound and drains them back once traffic quiets.

``--limp-slowdown F`` injects a STRAGGLER fault (DESIGN.md §Straggler
plane): ``--limp-replica`` limps to F× its normal service time
``--limp-after`` seconds into the run.  ``--limp-factor`` (default on)
arms the adaptive limp detector — the pool re-prices the limping
replica's queue so the others strip it, stops routing new requests to
it, and reports the detector's flag transitions:

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --requests 24 --prompt-len 16 --new-tokens 8 \
        --open-arrival --rate 8 --replicas 3 --slow-factor 1 \
        --limp-slowdown 16 --limp-after 0.5
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_smoke
from repro.core.limp import LimpConfig, SlowdownEvent, SlowdownSchedule
from repro.core.netfault import parse_netfaults
from repro.core.policy import POLICIES
from repro.core.topology import parse_topology
from repro.models import lm
from repro.serve.engine import AutoscaleConfig, Replica, ServePool


def make_decode(cfg):
    """One jitted decode step, reusable across requests/replicas (a fresh
    ``jax.jit`` per call would recompile every time)."""
    return jax.jit(
        lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg),
        donate_argnums=(2,),
    )


def generate(cfg, params, tokens: jnp.ndarray, new_tokens: int, decode=None):
    """Greedy generation for a [B, S] prompt batch (mesh-free path)."""
    b, s = tokens.shape
    cache_len = s + new_tokens
    caches = lm.init_caches(cfg, b, cache_len)
    # prefill re-runs through decode_step to keep the cache length fixed
    # (simple path for the smoke driver; the engine prefill is jitted).
    if decode is None:
        decode = make_decode(cfg)
    out = []
    tok = tokens[:, :1]
    logits = None
    for i in range(s + new_tokens - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(i))
        if i + 1 < s:
            tok = tokens[:, i + 1 : i + 2]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def _closed_main(cfg, params, args) -> None:
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.requests, args.prompt_len)), jnp.int32
    )
    t0 = time.time()
    out = generate(cfg, params, prompts, args.new_tokens)
    dt = time.time() - t0
    total = args.requests * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s); sample: {np.asarray(out[0])[:8]}")


def _open_main(cfg, params, args) -> None:
    """Continuous batching: Poisson arrivals into a live heterogeneous pool."""
    rng = np.random.default_rng(args.seed)

    # one shared jitted step: each request's caches are private (donation is
    # per-call, so concurrent replica threads don't interfere)
    decode = make_decode(cfg)

    def gen(request: dict) -> dict:
        out = generate(cfg, params, request["tokens"][None, :],
                       args.new_tokens, decode=decode)
        return {"completion": np.asarray(out[0]).tolist()}
    # one jit warm-up so compile time doesn't poison the latency stats
    gen({"tokens": jnp.zeros((args.prompt_len,), jnp.int32)})

    replicas = [Replica("replica0", gen)]
    for r in range(1, args.replicas):
        # replicas share the weights/compiled fn; heterogeneity is emulated
        # by slow_factor (on real hardware: different device slices)
        replicas.append(Replica(f"replica{r}", gen,
                                slow_factor=args.slow_factor))
    autoscale = None
    if args.autoscale_max > args.replicas:
        # Elastic pool (DESIGN.md §Elasticity): surge replicas boot at full
        # speed (fresh capacity) and drain back out once the backlog clears.
        autoscale = AutoscaleConfig(
            factory=lambda wid: Replica(f"surge{wid}", gen),
            min_replicas=args.replicas,
            max_replicas=args.autoscale_max,
        )
    slowdown = None
    limp = None
    if args.limp_slowdown > 1.0:
        # Straggler fault (DESIGN.md §Straggler plane): one replica limps
        # mid-run; the detector (unless disabled) re-prices its queue so
        # the healthy replicas strip it and new requests route around it.
        if not 0 <= args.limp_replica < args.replicas:
            raise SystemExit("--limp-replica must name a boot replica")
        slowdown = SlowdownSchedule((
            SlowdownEvent(args.limp_replica, args.limp_after,
                          args.limp_slowdown),
        ))
        if args.limp_factor > 1.0:
            limp = LimpConfig(limp_factor=args.limp_factor)
    netfaults = parse_netfaults(args.net_faults, args.replicas)
    pool = ServePool(replicas, seed=args.seed, policy=args.policy,
                     autoscale=autoscale, slowdown=slowdown, limp=limp,
                     topology=parse_topology(args.topology, args.replicas),
                     migration_cost=args.migration_cost,
                     netfaults=netfaults)
    pool.start()
    t0 = time.perf_counter()

    futs = []
    for _ in range(args.requests):
        time.sleep(float(rng.exponential(1.0 / args.rate)))
        req = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.prompt_len,)), jnp.int32)}
        futs.append(pool.submit(req))
    for f in futs:
        f.result(timeout=600)
    scale_outs = sum(1 for e in pool.scale_events if e[1] == "out")
    peak = pool.peak_live
    stats = pool.shutdown()
    pct = stats.latency_percentiles()
    per_rep = stats.per_worker_tasks
    print(f"served {len(futs)} streamed requests [{args.policy}]; "
          f"requests/replica={per_rep} steals={len(stats.steals)}")
    if autoscale is not None:
        print(f"autoscaler: peak {peak} replicas, {scale_outs} scale-outs")
    if slowdown is not None:
        flips = ", ".join(f"replica{w} {'limp' if f else 'recovered'}"
                          f" @{t - t0:.2f}s" for t, w, f in pool.limp_log)
        print(f"limp detector: {flips or 'no transitions'}")
    if netfaults is not None:
        print(f"fault fabric: {stats.net_failed} dropped steal requests, "
              f"{stats.lease_expired} leases expired")
    print("latency p50/p95/p99 = "
          + "/".join(f"{pct[q]*1e3:.0f}ms" for q in (50.0, 95.0, 99.0)))
    print(f"sample completion: {futs[0].result()['completion'][:8]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--open-arrival", action="store_true",
                    help="stream requests into a live ServePool")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate, requests/sec (open mode)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="model replicas in the pool (open mode)")
    ap.add_argument("--slow-factor", type=float, default=4.0,
                    help="slowdown of replicas 1.. vs replica 0 (open mode)")
    ap.add_argument("--policy", choices=POLICIES, default="a2ws",
                    help="scheduling policy for the replica pool (open mode)")
    ap.add_argument("--autoscale-max", type=int, default=0,
                    help="elastic pool: scale out to at most this many "
                         "replicas under backlog, drain back when idle "
                         "(0 = fixed pool; open mode)")
    ap.add_argument("--limp-slowdown", type=float, default=0.0,
                    help="straggler fault: limp one replica to this multiple "
                         "of its normal service time (0/1 = no fault; "
                         "open mode)")
    ap.add_argument("--limp-replica", type=int, default=0,
                    help="which boot replica the straggler fault hits")
    ap.add_argument("--limp-after", type=float, default=0.5,
                    help="seconds after start() the straggler fault begins")
    ap.add_argument("--topology", default="none",
                    help="network-cost model pricing steals between replicas "
                         "(DESIGN.md §Topology plane): none | "
                         "uniform:LAT:PER_TASK | two-level:K:INTRA:CROSS | "
                         "fat-tree:K:HOP (costs in seconds; open mode)")
    ap.add_argument("--net-faults", default="none",
                    help="network-fault plane on the replica steal fabric "
                         "(DESIGN.md §Fault fabric): none | drop:PROB | "
                         "delay:SEC | partition:START:DUR[:K] — combinable "
                         "with '+', e.g. drop:0.1+partition:5:30:2 "
                         "(open mode)")
    ap.add_argument("--migration-cost", type=float, default=0.0,
                    help="per-request warm-state cost of serving a stolen "
                         "request cold, folded into every remote link of "
                         "--topology (seconds; open mode)")
    ap.add_argument("--limp-factor", type=float, default=4.0,
                    help="limp detector threshold: flag a replica whose "
                         "recent service time exceeds its baseline by this "
                         "factor (<=1 disables detection — the count-based "
                         "ablation)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    if cfg.frontend != "none" or cfg.enc_layers:
        raise SystemExit("serve driver handles token-in archs")
    params, _ = lm.init(cfg, jax.random.key(args.seed))
    if args.open_arrival:
        _open_main(cfg, params, args)
    else:
        _closed_main(cfg, params, args)


if __name__ == "__main__":
    main()

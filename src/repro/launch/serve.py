"""Serving driver: batched greedy generation on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --requests 8 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_smoke
from repro.models import lm
from repro.parallel.sharding import make_context


def generate(cfg, params, tokens: jnp.ndarray, new_tokens: int):
    """Greedy generation for a [B, S] prompt batch (mesh-free path)."""
    b, s = tokens.shape
    cache_len = s + new_tokens
    caches = lm.init_caches(cfg, b, cache_len)
    # prefill re-runs through decode_step to keep the cache length fixed
    # (simple path for the smoke driver; the engine prefill is jitted).
    decode = jax.jit(
        lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg),
        donate_argnums=(2,),
    )
    out = []
    tok = tokens[:, :1]
    logits = None
    for i in range(s + new_tokens - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(i))
        if i + 1 < s:
            tok = tokens[:, i + 1 : i + 2]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    if cfg.frontend != "none" or cfg.enc_layers:
        raise SystemExit("serve driver handles token-in archs")
    params, _ = lm.init(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.requests, args.prompt_len)), jnp.int32
    )
    t0 = time.time()
    out = generate(cfg, params, prompts, args.new_tokens)
    dt = time.time() - t0
    total = args.requests * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s); sample: {np.asarray(out[0])[:8]}")


if __name__ == "__main__":
    main()

"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a 61-layer scan
body is counted as one layer, and the FSDP all-gathers inside it vanish from
the totals.  This module re-derives the three roofline inputs by walking the
call graph and scaling while-loop bodies by their trip count (XLA records
``known_trip_count`` in ``backend_config`` for scan-derived loops):

  flops  — 2 * prod(result dims) * prod(lhs contracting dims) per ``dot``
  bytes  — per instruction: result + operand bytes.  Post-fusion, every
           top-level instruction is one kernel, so its operands/results are
           HBM traffic (fusion-internal ops are skipped; free ops — tuple,
           gte, parameter, constant, bitcast — are skipped).
  coll   — collective payloads by kind (result-shape accounting, per-device;
           all-reduce counted 2x for its reduce-scatter + all-gather phases).

Shapes are per-shard in the partitioned module, so everything is per-device.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloCosts"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9a-z]*)\[([\d,]*)\]")
# %name = <type> opcode(operands...), attrs
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z][\w\[\],{}\/* ]*?))\s+"
    r"([a-z][\w\-]*)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_DOT_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _dims(shape_str):
        total += math.prod(dims) * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    rtype: str
    opcode: str
    rest: str  # operands + attrs (raw tail of the line)


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)

    def scaled(self, k: float) -> "HloCosts":
        return HloCosts(
            self.flops * k,
            self.bytes * k,
            {kk: v * k for kk, v in self.coll.items()},
        )

    def add(self, other: "HloCosts") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll.values()))


def _is_convert_only(instrs: list["_Instr"]) -> bool:
    """True for computations of shape (parameter* , convert ROOT)."""
    ops = [i.opcode for i in instrs]
    return (
        len(ops) >= 2
        and ops.count("convert") == 1
        and all(o in ("parameter", "convert") for o in ops)
    )


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    cur_name = None
    for line in text.splitlines():
        ls = line.rstrip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*(?:\(|\{)", ls)
            if m and ls.endswith("{"):
                cur_name = m.group(1)
                cur = []
            continue
        if ls == "}" or ls.startswith("} "):
            comps[cur_name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(ls)
        if m:
            cur.append(_Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    if cur is not None and cur_name is not None:
        comps[cur_name] = cur
    return comps


def analyze_hlo(text: str, entry_hint: str | None = None) -> HloCosts:
    comps = _parse_computations(text)
    entry = None
    m = re.search(r"^ENTRY\s+%([\w.\-]+)", text, re.M)
    if m:
        entry = m.group(1)
    if entry is None:
        entry = entry_hint or max(comps, key=lambda k: len(comps[k]))

    shape_of: dict[str, dict[str, str]] = {
        cname: {i.name: i.rtype for i in instrs}
        for cname, instrs in comps.items()
    }

    memo: dict[str, HloCosts] = {}

    def _sliced_bytes(ins: _Instr, shapes: dict, kind: str) -> float:
        """Traffic of slice-like ops: only the touched window moves.

        dynamic-slice / gather read+write the RESULT window (+indices);
        dynamic-update-slice / scatter read+write the UPDATE operand — the
        big aliased buffer itself is not streamed (in-place on hardware).
        """
        rbytes = _shape_bytes(ins.rtype)
        op_bytes = [
            _shape_bytes(shapes[on])
            for on in _OPERAND_RE.findall(ins.rest.split(", calls=")[0])
            if on in shapes
        ]
        if kind in ("dynamic-slice", "gather"):
            return 2.0 * rbytes
        # dus/scatter: everything except the aliased big buffer, twice
        small = sum(op_bytes) - (max(op_bytes) if op_bytes else 0)
        return 2.0 * small

    _SLICE_ROOTS = {"dynamic-slice", "gather", "dynamic-update-slice",
                    "scatter"}

    def comp_cost(cname: str) -> HloCosts:
        if cname in memo:
            return memo[cname]
        memo[cname] = HloCosts()  # cycle guard
        total = HloCosts()
        shapes = shape_of.get(cname, {})
        for ins in comps.get(cname, []):
            op = ins.opcode
            if op in _FREE_OPS:
                continue
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            if base in _COLLECTIVES:
                nb = _shape_bytes(ins.rtype)
                f = 2 if base == "all-reduce" else 1
                total.coll[base] = total.coll.get(base, 0.0) + nb * f
                total.bytes += _shape_bytes(ins.rtype) * 2  # read + write
                continue
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _CALL_RE.search(ins.rest)
                if bm:
                    total.add(comp_cost(bm.group(1)).scaled(trip))
                cm = _COND_RE.search(ins.rest)
                if cm:
                    total.add(comp_cost(cm.group(1)).scaled(trip))
                continue
            if op in ("fusion", "call", "conditional", "map", "custom-call"):
                # Skip convert-only kernels: the CPU backend materialises
                # bf16->f32 copies of dot inputs (no native bf16 matmul);
                # the TPU MXU reads bf16 directly, so these are not traffic
                # on the target hardware.
                inner_names = _CALL_RE.findall(ins.rest)
                inner = comps.get(inner_names[0], []) if inner_names else []
                if inner and _is_convert_only(inner):
                    continue
                root = inner[-1].opcode if inner else None
                if root in _SLICE_ROOTS:
                    total.bytes += _sliced_bytes(ins, shapes, root)
                    continue
                # memory at the kernel boundary; a fusion operand consumed
                # ONLY through dynamic-slice inside the kernel streams the
                # slice, not the full (e.g. layer-stacked) buffer
                total.bytes += _shape_bytes(ins.rtype)
                params = {}
                for i2 in inner:
                    if i2.opcode == "parameter":
                        m2 = re.match(r"\s*(\d+)", i2.rest)
                        if m2:
                            params[int(m2.group(1))] = i2.name
                operand_names = _OPERAND_RE.findall(
                    ins.rest.split(", calls=")[0]
                )
                for oi, on in enumerate(operand_names):
                    if on not in shapes:
                        continue
                    full = _shape_bytes(shapes[on])
                    pname = params.get(oi)
                    eff = full
                    if pname is not None and inner:
                        pat = re.compile(r"%" + re.escape(pname) + r"\b")
                        consumers = [
                            j for j in inner
                            if j.opcode != "parameter" and pat.search(j.rest)
                        ]
                        if consumers and all(
                            c.opcode == "dynamic-slice" for c in consumers
                        ):
                            eff = min(
                                full,
                                sum(_shape_bytes(c.rtype) for c in consumers),
                            )
                    total.bytes += eff
                # inner dots/collectives still counted (bytes of inner ops
                # are skipped below because inner comps are reached only via
                # this call edge — mark by scaling bytes to 0?  Simpler: the
                # CPU backend keeps dots un-fused, so inner comps here are
                # elementwise; count their flops (0) and skip their bytes.
                for cn in _CALL_RE.findall(ins.rest):
                    inner = comp_cost(cn)
                    total.flops += inner.flops
                    for k, v in inner.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                continue
            if op == "dot":
                rbytes = _shape_bytes(ins.rtype)
                total.bytes += rbytes
                for on in _OPERAND_RE.findall(ins.rest):
                    if on in shapes:
                        total.bytes += _shape_bytes(shapes[on])
                rd = _dims(ins.rtype)
                out_elems = math.prod(rd[0][1]) if rd else 0
                k_elems = 1
                cm = _DOT_LHS_C.search(ins.rest)
                ops = _OPERAND_RE.findall(ins.rest)
                if cm and ops:
                    lhs_shape = shapes.get(ops[0])
                    if lhs_shape:
                        ld = _dims(lhs_shape)
                        if ld:
                            for ci in (int(c) for c in cm.group(1).split(",") if c):
                                if ci < len(ld[0][1]):
                                    k_elems *= ld[0][1][ci]
                total.flops += 2.0 * out_elems * k_elems
                continue
            if op in _SLICE_ROOTS:
                total.bytes += _sliced_bytes(ins, shapes, op)
                continue
            # default: one kernel — result + operands are HBM traffic
            total.bytes += _shape_bytes(ins.rtype)
            for on in _OPERAND_RE.findall(ins.rest):
                if on in shapes:
                    total.bytes += _shape_bytes(shapes[on])
        memo[cname] = total
        return total

    return comp_cost(entry)

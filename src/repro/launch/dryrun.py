import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_FORCE_DEVICES", "512")
    + " " + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analysis.

The two lines ABOVE the docstring must run before any jax import — jax locks
the device count on first init.  Smoke tests and benches do NOT import this
module, so they see the single real CPU device.

Usage:
    python -m repro.launch.dryrun --arch mistral-nemo-12b --shape train_4k
    python -m repro.launch.dryrun --all                  # single-pod 16x16
    python -m repro.launch.dryrun --all --multi-pod      # 2x16x16
Records land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.cells import analyze, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import make_context

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    rec_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "skipped", "reason": reason}
        _write(rec_path, rec)
        print(f"[skip] {arch} x {shape_name} ({mesh_tag}): {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_context(mesh)
    chips = mesh.devices.size
    print(f"[cell] {arch} x {shape_name} on {mesh_tag} ({chips} chips)")
    try:
        with mesh:
            lowered, meta = lower_cell(cfg, shape, ctx)
            t0 = time.time()
            compiled = lowered.compile()
            meta["compile_s"] = round(time.time() - t0, 2)
            print(compiled.memory_analysis())   # proves it fits
            cost = compiled.cost_analysis()     # FLOPs/bytes for the roofline
            print({k: cost[k] for k in ("flops", "bytes accessed")
                   if k in cost})
            rec = analyze(lowered, compiled, cfg, shape, chips)
            rec.update({"mesh": mesh_tag, "status": "ok", **meta})
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        print(f"[FAIL] {arch} x {shape_name}: {e}")
    _write(rec_path, rec)
    return rec


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    pods = [args.multi_pod] if not args.both_meshes else [False, True]
    cells_ = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = 0
    for mp in pods:
        for arch, shape_name in cells_:
            tag = "2x16x16" if mp else "16x16"
            path = os.path.join(args.out, f"{arch}__{shape_name}__{tag}.json")
            if args.skip_existing and os.path.exists(path):
                rec = json.load(open(path))
                if rec.get("status") in ("ok", "skipped"):
                    continue
            rec = run_cell(arch, shape_name, mp, args.out)
            failures += rec.get("status") == "error"
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

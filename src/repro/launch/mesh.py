"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The production target is a TPU v5e pod of 16x16 =
256 chips ('data' x 'model'); the multi-pod mesh stacks 2 pods on a leading
'pod' axis (512 chips) whose cross-pod DCI links carry only batch-gradient
traffic (see ``repro.parallel.sharding``).
"""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "run under launch/dryrun.py (it forces 512 host devices) or on "
            "real hardware"
        )
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_debug_mesh(data: int, model: int, pod: int = 0):
    """Small mesh over however many host devices exist (tests)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    need = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])

"""Training driver: any assigned arch on whatever mesh exists.

On real hardware this runs the pjit train step over the production mesh; on
this CPU container use ``--smoke`` (reduced config, mesh-free) to run end to
end.  Fault tolerance: periodic async checkpoints, resume on start.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --smoke --steps 20 --batch 4 --seq 128 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import store
from repro.configs.base import ARCH_IDS, get_config, get_smoke
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_debug_mesh
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import make_context
from repro.train.step import jit_train_step, train_shardings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--mesh", default="", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend != "none" or cfg.enc_layers:
        raise SystemExit(
            "train driver feeds token batches; use examples/het_train.py for "
            "frontend-stubbed archs"
        )
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_debug_mesh(d, m)
    ctx = make_context(mesh)
    opt_cfg = AdamWConfig(lr=args.lr)

    params, specs = lm.init(cfg, jax.random.key(args.seed))
    opt_state = adamw_init(params, opt_cfg)
    if mesh is not None:
        param_sh, opt_sh = train_shardings(cfg, ctx, opt_cfg)
        params = jax.device_put(params, param_sh)
        opt_state = jax.device_put(opt_state, opt_sh)

    data = SyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                   seed=args.seed)
    )
    start = 0
    ckpt = store.AsyncCheckpointer(args.ckpt) if args.ckpt else None
    if args.ckpt and store.latest_step(args.ckpt) is not None:
        restored, start = store.restore(
            args.ckpt, {"params": params, "opt": opt_state}
        )
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from step {start}")

    batch0 = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in data.batch_at(0).items()}
    step_fn = jit_train_step(
        cfg, ctx, opt_cfg, batch0,
        schedule={"warmup": 10, "total": max(args.steps, 20)}, donate=True,
    )
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0:
            loss = float(metrics["loss"])
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"dt {time.time()-t0:6.2f}s")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()

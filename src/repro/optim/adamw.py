"""AdamW with global-norm clipping, cosine schedule and ZeRO-style sharding.

Pure-functional (no optax dependency).  Optimizer moments inherit the
parameter sharding (our FSDP rules shard weights over 'data' x 'model', so
m/v are automatically ZeRO-sharded — no replicated optimizer state anywhere).
``moment_dtype`` lets the 671B-class configs keep bf16 moments.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr", "global_norm"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def adamw_update(grads, opt_state, params, cfg: AdamWConfig, lr_scale=1.0):
    """One step.  Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    mdt = jnp.dtype(cfg.moment_dtype)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "count": count},
        {"grad_norm": gnorm, "clip_scale": scale},
    )


def cosine_lr(step, *, warmup: int, total: int, floor: float = 0.1):
    """Warmup + cosine decay multiplier in [floor, 1]."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return warm * (floor + (1.0 - floor) * cos)

"""seamless-m4t-medium — encoder-decoder multimodal [arXiv:2308.11596].

Pool spec: 12L (encoder) + 12L (decoder) d_model=1024 16H (kv=16) d_ff=4096
vocab=256206.  The audio frontend is a stub per the assignment:
``input_specs`` provides precomputed frame embeddings for the encoder.
Non-gated (plain ReLU) MLP as in the NLLB/seamless transformer.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256_206,
    head_dim=64,
    rope_theta=10_000.0,
    frontend="audio",
    act="plain",
    max_seq=32_768,
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="encdec",
    n_layers=2,
    enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    head_dim=16,
    frontend="audio",
    act="plain",
    max_seq=256,
    remat="none",
)

"""qwen1.5-32b [hf:Qwen family].

Pool spec: 64L d_model=5120 40H (GQA kv=40 — i.e. MHA) d_ff=27392
vocab=152064, QKV bias.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27_392,
    vocab=152_064,
    head_dim=128,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    max_seq=32_768,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab=256,
    head_dim=16,
    qkv_bias=True,
    max_seq=256,
    remat="none",
)

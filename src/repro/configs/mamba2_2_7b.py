"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

Pool spec: 64L d_model=2560 (attention-free) vocab=50280, ssm_state=128,
expand 2, head_dim 64.  Sub-quadratic: runs the long_500k shape.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50_280,
    head_dim=64,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    tie_embeddings=True,
    max_seq=524_288,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=256,
    head_dim=16,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    max_seq=256,
    remat="none",
)

from .base import (
    ARCH_IDS,
    SHAPES,
    Shape,
    cells,
    get_config,
    get_smoke,
    input_specs,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "Shape",
    "cells",
    "get_config",
    "get_smoke",
    "input_specs",
    "shape_applicable",
]

"""moonshot-v1-16b-a3b — Kimi/Moonlight-style MoE.

[hf:moonshotai/Moonlight-16B-A3B; pool spec]: 48L d_model=2048 16H (GQA
kv=16) d_ff=1408 (expert hidden) vocab=163840, MoE 64 experts top-6.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163_840,
    head_dim=128,
    rope_theta=50_000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408),
    max_seq=32_768,
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab=256,
    head_dim=16,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=96, capacity_factor=2.0),
    max_seq=256,
    remat="none",
)

"""Architecture registry + input-shape sets for the assigned pool.

Every assigned architecture has one module in this package exposing

    CONFIG : ModelConfig   -- the exact published configuration
    SMOKE  : ModelConfig   -- reduced same-family config for CPU smoke tests

and this module provides the registry (``get_config``/``get_smoke``), the four
assigned LM input shapes, applicability rules (long_500k needs sub-quadratic
mixing), and ``input_specs`` — ShapeDtypeStruct stand-ins for every model
input of a (config, shape) cell, exactly what the multi-pod dry-run lowers
against (weak-type-correct, shardable, no device allocation).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "Shape",
    "get_config",
    "get_smoke",
    "shape_applicable",
    "input_specs",
    "cells",
]

ARCH_IDS = (
    "moonshot-v1-16b-a3b",
    "deepseek-v3-671b",
    "qwen2-vl-2b",
    "mistral-nemo-12b",
    "minitron-4b",
    "qwen1.5-32b",
    "phi4-mini-3.8b",
    "recurrentgemma-2b",
    "mamba2-2.7b",
    "seamless-m4t-medium",
)


@dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    subquadratic_only: bool = False


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1, subquadratic_only=True),
}


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def shape_applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """(applicable, reason).  DESIGN.md §Arch-applicability."""
    if shape.subquadratic_only:
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        return False, (
            "full attention at 524k context is quadratic by construction; "
            "run only for SSM/hybrid families"
        )
    return True, ""


# --------------------------------------------------------------------- specs
def _i32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStruct stand-ins for the batch of one (arch, shape) cell.

    train:    full-sequence batch for ``train_step``.
    prefill:  prompt batch for ``prefill_step``.
    decode:   one new token against a ``shape.seq_len``-token KV cache
              (the cache itself is built by the serve engine, not here).
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch: dict = {}
        if cfg.frontend == "vision":
            # patch/frame embeddings from the stubbed frontend + M-RoPE ids
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            batch["positions"] = _i32(3, b, s)
            batch["labels"] = _i32(b, s)
        elif cfg.enc_layers:
            batch["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            batch["tokens"] = _i32(b, s)
            batch["labels"] = _i32(b, s)
        else:
            batch["tokens"] = _i32(b, s)
            batch["labels"] = _i32(b, s)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.frontend == "vision":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            batch["positions"] = _i32(3, b, s)
        elif cfg.enc_layers:
            batch["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            batch["tokens"] = _i32(b, s)
        else:
            batch["tokens"] = _i32(b, s)
        return batch
    if shape.kind == "decode":
        return {"tokens": _i32(b, 1), "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)


def cells(include_skipped: bool = False):
    """All (arch_id, shape) cells of the assignment (40 incl. skips)."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for sh in SHAPES.values():
            ok, reason = shape_applicable(cfg, sh)
            if ok or include_skipped:
                out.append((a, sh.name, ok, reason))
    return out

"""phi4-mini-3.8b [arXiv:2412.08905].

Pool spec: 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 — RoPE,
SwiGLU, GQA.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200_064,
    head_dim=128,
    rope_theta=10_000.0,
    max_seq=32_768,
)

SMOKE = ModelConfig(
    name="phi4-mini-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=6,
    n_kv_heads=2,
    d_ff=96,
    vocab=256,
    head_dim=8,
    max_seq=256,
    remat="none",
)

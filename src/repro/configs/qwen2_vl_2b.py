"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191].

Pool spec: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  The
vision frontend is a stub per the assignment: ``input_specs`` provides
precomputed patch embeddings plus (t, h, w) M-RoPE position ids.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151_936,
    head_dim=128,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),  # halves of head_dim: 16+24+24 = 64
    frontend="vision",
    max_seq=32_768,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(2, 3, 3),
    frontend="vision",
    max_seq=256,
    remat="none",
)

"""deepseek-v3-671b — MLA + fine-grained MoE + MTP [arXiv:2412.19437].

Pool spec: 61L d_model=7168 128H d_ff=2048 (routed-expert hidden)
vocab=129280, MoE 1 shared + 256 routed top-8, MLA, MTP.  First 3 layers use
a dense FFN of 18432 (paper §4.2); MLA ranks q=1536 / kv=512, head dims
128 nope + 64 rope, v 128.
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab=129_280,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_expert=2048,
        num_shared=1,
        d_shared=2048,
        first_k_dense=3,
        d_ff_dense=18_432,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_dim=128,
    ),
    mtp=True,
    max_seq=32_768,
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    n_layers=3,  # 1 dense + 2 MoE — exercises first_k_dense
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=256,
    head_dim=16,
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_expert=64,
        num_shared=1,
        d_shared=64,
        first_k_dense=1,
        d_ff_dense=128,
        capacity_factor=2.0,
    ),
    mla=MLAConfig(
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8, v_dim=16
    ),
    mtp=True,
    max_seq=256,
    remat="none",
)

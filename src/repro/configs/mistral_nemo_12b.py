"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407].

Pool spec: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, 128k
context (head_dim fixed at 128, rope theta 1M).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=131_072,
    head_dim=128,
    rope_theta=1_000_000.0,
    max_seq=131_072,
)

SMOKE = ModelConfig(
    name="mistral-nemo-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    max_seq=256,
    remat="none",
)

"""recurrentgemma-2b — Griffin: RG-LRU + local attention 2:1 [arXiv:2402.19427].

Pool spec: 26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, pattern
(rglru, rglru, local) cycled, sliding window 2048, lru_width 2560.
Sub-quadratic: runs the long_500k shape.
"""

from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256_000,
    head_dim=256,
    rope_theta=10_000.0,
    window=2048,
    pattern=("rglru", "rglru", "local"),
    rglru=RGLRUConfig(lru_width=2560, d_conv=4, window=2048),
    logits_softcap=30.0,
    tie_embeddings=True,
    max_seq=524_288,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=4,  # one full cycle + one leftover rglru
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=256,
    head_dim=16,
    window=32,
    pattern=("rglru", "rglru", "local"),
    rglru=RGLRUConfig(lru_width=64, d_conv=4, window=32),
    logits_softcap=30.0,
    max_seq=256,
    remat="none",
)

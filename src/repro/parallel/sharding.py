"""Logical-axis -> mesh-axis sharding rules (MaxText-style, simplified).

Weights carry *logical* axis names (see ``repro.models.layers.param``); this
module maps them onto the production mesh:

  'model' axis : tensor parallelism (attention heads, ffn, experts, vocab)
  'data'  axis : FSDP — the non-TP weight dim is sharded over 'data' so
                 per-device weight memory scales with the full pod; XLA
                 inserts the all-gather per scan step.
  'pod'   axis : pure data parallelism across pods (weights replicated,
                 gradients all-reduced) — cross-pod DCI links are slow, so
                 nothing weight-related crosses them.

Batch/activations: batch dim over ('pod', 'data').
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ParallelContext",
    "make_context",
    "shard_map_compat",
    "spec_for",
    "shardings_for",
]


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` across jax versions.

    Public ``jax.shard_map`` (with ``check_vma``) only exists in newer jax;
    on 0.4.x the same transform lives in ``jax.experimental.shard_map`` and
    the kwarg is spelled ``check_rep``.  Pass ``check_vma=None`` to take the
    version default.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
    )

# logical axis -> mesh axis (None = replicate)
DEFAULT_RULES: dict[str, str | None] = {
    "vocab": "model",
    "embed": "data",      # FSDP dim
    "ffn": "model",
    "heads": "model",
    "kv": "model",
    "experts": "model",
    "lora": None,
    "layers": None,
    "state": None,
    None: None,
}


@dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh | None
    dp_axes: tuple[str, ...] = ("data",)  # batch axes (('pod','data') multi-pod)
    tp_axis: str = "model"
    # mesh axes the EXPERT dim is sharded over.  Training: ("model",) — EP
    # folded into TP, weights additionally FSDP'd over 'data'.  Serving
    # (serve_context): ("data", "model") — full EP across the mesh, token
    # replication + global psum instead of per-layer weight gathers.
    ep_axes: tuple[str, ...] = ("model",)
    rules: tuple[tuple[str | None, str | None], ...] = tuple(
        DEFAULT_RULES.items()
    )

    def rule(self, logical: str | None) -> str | None:
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    @property
    def batch_spec(self) -> P:
        return P(self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0])


def make_context(
    mesh: Mesh | None, rules: dict[str, str | None] | None = None
) -> ParallelContext:
    if mesh is None:
        return ParallelContext(mesh=None)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    return ParallelContext(mesh=mesh, dp_axes=dp, rules=tuple(merged.items()))


def serve_context(mesh: Mesh | None, num_experts: int = 0) -> ParallelContext:
    """Inference parameter layout (§Perf hillclimb, deepseek decode cell).

    Training FSDP shards a weight dim over 'data', which forces an
    all-gather of the FULL parameter bank per layer per DECODE step — for
    deepseek-v3 that is ~167 GB/device/token of pure collective traffic.
    Serving instead:

      * dense weights: TP over 'model', REPLICATED over 'data' (params/16
        fits HBM for every assigned arch once experts are excluded);
      * expert weights: full EP over ('data' x 'model') when the expert
        count divides the mesh (256 experts / 256 chips for deepseek-v3);
        decode-token dispatch replicates the (tiny) token batch instead of
        gathering the (huge) weights.
    """
    if mesh is None:
        return ParallelContext(mesh=None)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    # Widest EP grid the expert count divides (experts may stay replicated
    # across 'pod' — 2 copies of the expert bank still fit).
    ep_axes = ("model",)
    for cand in ((*dp, "model"), ("data", "model")):
        size = 1
        for a in cand:
            if a not in mesh.axis_names:
                size = 0
                break
            size *= mesh.shape[a]
        if size and num_experts > 0 and num_experts % size == 0:
            ep_axes = cand
            break
    rules = dict(DEFAULT_RULES)
    rules["embed"] = None  # no FSDP dim at serving time
    if len(ep_axes) > 1:
        rules["experts"] = ep_axes
    return ParallelContext(
        mesh=mesh, dp_axes=dp, ep_axes=ep_axes, rules=tuple(rules.items())
    )


def spec_for(
    axes: tuple[str | None, ...],
    ctx: ParallelContext,
    shape: tuple[int, ...] | None = None,
) -> P:
    """PartitionSpec for one param from its logical axes.

    Guards against (a) using the same mesh axis twice (e.g. a [ffn, ffn]
    square weight — the second occurrence is replicated) and (b) dims not
    divisible by the mesh-axis size when ``shape`` is given (replicated
    instead of relying on GSPMD padding).
    """
    used: set[str] = set()
    out = []
    for i, a in enumerate(axes):
        m = ctx.rule(a)
        parts = (m,) if isinstance(m, str) else tuple(m or ())
        if parts and shape is not None and ctx.mesh is not None:
            size = 1
            for ax in parts:
                size *= ctx.mesh.shape[ax]
            if shape[i] % size != 0:
                parts = ()
        if not parts or any(ax in used for ax in parts):
            out.append(None)
        else:
            out.append(parts if len(parts) > 1 else parts[0])
            used.update(parts)
    return P(*out)


def shardings_for(spec_tree, ctx: ParallelContext, shapes=None):
    """Tree of logical-axes tuples -> tree of NamedSharding (or None mesh).

    ``shapes``: optional matching tree with ``.shape``-carrying leaves
    (arrays or ShapeDtypeStruct) enabling the divisibility guard.
    """
    if ctx.mesh is None:
        return jax.tree.map(
            lambda axes: None, spec_tree, is_leaf=_is_axes
        )
    if shapes is None:
        return jax.tree.map(
            lambda axes: NamedSharding(ctx.mesh, spec_for(axes, ctx)),
            spec_tree,
            is_leaf=_is_axes,
        )
    flat_a, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_axes)
    flat_s = treedef.flatten_up_to(shapes)
    return treedef.unflatten(
        [
            NamedSharding(ctx.mesh, spec_for(a, ctx, s.shape))
            for a, s in zip(flat_a, flat_s)
        ]
    )


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def constrain(x, ctx: ParallelContext | None, dims: tuple[str | None, ...]):
    """Activation sharding constraint.  ``dims``: per-dim 'dp' | 'tp' | None.

    Without explicit anchors XLA's sharding propagation can (and does) drop
    the batch sharding at the embedding/logits boundaries, materialising
    full-batch × full-vocab tensors.  This pins the canonical activation
    layout: batch over the DP axes, feature/vocab over 'model', replicated
    elsewhere.  Dims that don't divide evenly are left unconstrained.
    """
    if ctx is None or ctx.mesh is None:
        return x
    spec = []
    for i, d in enumerate(dims):
        if d == "dp":
            size = 1
            for a in ctx.dp_axes:
                size *= ctx.mesh.shape[a]
            if x.shape[i] % size == 0:
                spec.append(ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0])
            else:
                spec.append(None)
        elif d == "tp":
            tpn = ctx.mesh.shape[ctx.tp_axis]
            spec.append(ctx.tp_axis if x.shape[i] % tpn == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec))
    )

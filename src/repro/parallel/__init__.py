from .sharding import ParallelContext, make_context, shardings_for, spec_for

__all__ = ["ParallelContext", "make_context", "shardings_for", "spec_for"]

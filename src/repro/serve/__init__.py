from .engine import (
    abstract_caches,
    cache_pspecs,
    cache_shardings,
    jit_decode_step,
    jit_prefill_step,
    Replica,
    ServeFuture,
    ServePool,
)

__all__ = [
    "abstract_caches",
    "cache_pspecs",
    "cache_shardings",
    "jit_decode_step",
    "jit_prefill_step",
    "Replica",
    "ServeFuture",
    "ServePool",
]

"""Serving: jitted prefill/decode steps with cache sharding + host-side pool.

Device plane
------------
``jit_prefill_step``/``jit_decode_step`` wrap ``lm.prefill``/``lm.decode_step``
with explicit shardings.  KV-cache layout policy (per leaf):

  * batch dim        -> DP axes when divisible (decode_32k: 128 over 16/32)
  * KV heads         -> 'model' when divisible (TP-style head sharding)
  * else sequence    -> 'model' (flash-decode style: each rank holds a cache
    slice; XLA inserts the tiny cross-rank softmax reductions — this is what
    spreads the 32k-cache HBM traffic over the pod, the decode bottleneck)
  * SSM state heads / RG-LRU width / conv channels -> 'model'

Host plane
----------
``ServePool`` is a **continuous-batching server** on the open-arrival
``WorkerPool`` substrate (DESIGN.md §Open-arrival, §Policy layer): requests
stream in through ``submit()`` while the pool is live, each replica is a
worker whose deque holds queued requests, and the scheduling policy
(``policy=`` — A2WS by default, or CTWS/LW/random for head-to-head baseline
serving) moves queued requests between replicas mid-flight.  The pool never
tears down or re-partitions between request waves — workers idle (with
capped backoff) until the next submit wakes them, and quiescence detection
only fires at ``shutdown()``.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.a2ws import PoolCollapsed, RunStats, WorkerPool
from repro.core.deque import SLO_BATCH, SLO_LATENCY, SLO_NAMES
from repro.core.limp import LimpConfig, SlowdownSchedule
from repro.core.netfault import NetFaultSchedule
from repro.core.policy import SchedPolicy
from repro.core.topology import Topology
from repro.models import lm
from repro.models.config import ModelConfig
from repro.parallel.sharding import (
    ParallelContext,
    serve_context,
    shardings_for,
)
from repro.train.step import batch_shardings

__all__ = [
    "abstract_caches",
    "cache_pspecs",
    "cache_shardings",
    "jit_prefill_step",
    "jit_decode_step",
    "Replica",
    "ServeFuture",
    "ServePool",
    "AutoscaleConfig",
    "request_size",
    "shape_cost_classifier",
]


# ----------------------------------------------------------------- structure
def _group_kinds(kind: str) -> list[str]:
    if kind.startswith("cycle:"):
        return kind[len("cycle:") :].split("|")
    return [kind]


def _decoder_groups(cfg: ModelConfig):
    if cfg.enc_layers:
        return (("xdec", cfg.n_layers),)
    return cfg.scan_groups()


def abstract_caches(
    cfg: ModelConfig, bsz: int, cache_len: int, enc_len: int | None = None
):
    """ShapeDtypeStruct tree matching what ``lm.prefill`` returns as caches."""
    sds = jax.eval_shape(lambda: lm.init_caches(cfg, bsz, cache_len))
    if not cfg.enc_layers:
        return sds
    # enc-dec: fill the memory-KV slot (None in init_caches) with the
    # encoder-memory K/V the decode step cross-attends to.
    enc_len = enc_len or cache_len
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    mem = jax.ShapeDtypeStruct(
        (cfg.n_layers, bsz, enc_len, hkv, hd), jnp.dtype(cfg.dtype)
    )
    (group0,) = sds  # single xdec group
    (pair,) = group0  # kinds == ["xdec"]
    sa = pair[0] if isinstance(pair, tuple) and len(pair) == 2 else pair
    return [(((sa[0], sa[1]), (mem, mem)),)]


def _dp_or_none(ctx: ParallelContext, bsz: int):
    if ctx.mesh is None:
        return None
    size = 1
    for a in ctx.dp_axes:
        size *= ctx.mesh.shape[a]
    if bsz % size != 0:
        return None
    return ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]


def _kv_spec(cfg, ctx, dp, seq: int):
    """[L, B, S, Hkv, hd] — heads over 'model' if divisible, else sequence."""
    tp = ctx.tp_axis
    tpn = ctx.mesh.shape[tp]
    if cfg.n_kv_heads % tpn == 0:
        return P(None, dp, None, tp, None)
    if seq % tpn == 0:
        return P(None, dp, tp, None, None)
    return P(None, dp, None, None, None)


def cache_pspecs(cfg: ModelConfig, ctx: ParallelContext, bsz: int, cache_len: int):
    """PartitionSpec tree matching the prefill/decode cache structure."""
    assert ctx.mesh is not None
    tp = ctx.tp_axis
    tpn = ctx.mesh.shape[tp]
    dp = _dp_or_none(ctx, bsz)

    def div(n):  # 'model' only when divisible
        return tp if n % tpn == 0 else None

    def kind_spec(kind: str):
        if kind in ("attn", "attn_dense", "attn_moe"):
            if cfg.mla is not None:
                s = div(cache_len)
                return (P(None, dp, s, None), P(None, dp, s, None))
            kv = _kv_spec(cfg, ctx, dp, cache_len)
            return (kv, kv)
        if kind == "local":
            w = cfg.window or cache_len
            kv = _kv_spec(cfg, ctx, dp, w)
            return (kv, kv)
        if kind == "ssm":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            h = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.d_state
            return (
                P(None, dp, div(h), None, None),
                P(None, dp, None, div(conv_ch)),
            )
        if kind == "rglru":
            w = cfg.rglru.lru_width
            return (P(None, dp, div(w)), P(None, dp, None, div(w)))
        if kind == "xdec":
            kv = _kv_spec(cfg, ctx, dp, cache_len)
            return ((kv, kv), (kv, kv))
        raise ValueError(kind)

    out = []
    for kind, _count in _decoder_groups(cfg):
        out.append(tuple(kind_spec(k) for k in _group_kinds(kind)))
    return out


def cache_shardings(cfg, ctx, bsz, cache_len):
    specs = cache_pspecs(cfg, ctx, bsz, cache_len)
    return jax.tree.map(
        lambda p: NamedSharding(ctx.mesh, p),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ------------------------------------------------------------------ jit steps
def jit_prefill_step(cfg: ModelConfig, ctx: ParallelContext, batch_sds: dict):
    """jit(prefill) with explicit shardings; returns (logits, caches)."""

    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, ctx)

    if ctx.mesh is None:
        return jax.jit(prefill_step)
    params_sds, specs = lm.init_shapes(cfg)
    param_sh = shardings_for(specs, ctx, params_sds)
    b_sh = batch_shardings(batch_sds, ctx)
    ref = (
        batch_sds["tokens"]
        if "tokens" in batch_sds
        else batch_sds.get("embeds", batch_sds.get("enc_embeds"))
    )
    bsz, seq = ref.shape[0], ref.shape[1]
    cache_sh = cache_shardings(cfg, ctx, bsz, seq)
    return jax.jit(
        prefill_step,
        in_shardings=(param_sh, b_sh),
        out_shardings=(None, cache_sh),
    )


def jit_decode_step(
    cfg: ModelConfig,
    ctx: ParallelContext,
    bsz: int,
    cache_len: int,
    *,
    donate: bool = True,
    serve_layout: bool = True,
):
    """jit(decode_step) with explicit shardings; caches donated in-place.

    ``serve_layout``: use the inference parameter layout (``serve_context``)
    — dense weights TP-only (no per-step FSDP gathers), experts full-EP.
    Pass False to keep the training layout (the paper-faithful baseline in
    EXPERIMENTS.md §Perf).
    """
    if ctx.mesh is not None and serve_layout:
        ctx = serve_context(ctx.mesh, cfg.moe.num_experts if cfg.moe else 0)

    def decode(params, tokens, caches, pos):
        return lm.decode_step(params, tokens, caches, pos, cfg, ctx)

    if ctx.mesh is None:
        return jax.jit(decode, donate_argnums=(2,) if donate else ())
    params_sds, specs = lm.init_shapes(cfg)
    param_sh = shardings_for(specs, ctx, params_sds)
    cache_sh = cache_shardings(cfg, ctx, bsz, cache_len)
    dp = _dp_or_none(ctx, bsz)
    tok_sh = NamedSharding(ctx.mesh, P(dp, None))
    pos_sh = NamedSharding(ctx.mesh, P())
    return jax.jit(
        decode,
        in_shardings=(param_sh, tok_sh, cache_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,) if donate else (),
    )


# -------------------------------------------------------------- host serving
def request_size(request: dict) -> float:
    """Scalar work proxy read off a request's SHAPE (DESIGN.md
    §Work-weighted stealing).

    Checked in order: an explicit step/length scalar (``nt`` — seismic shot
    time steps, ``steps``, ``max_new_tokens``, ``new_tokens``), then the
    length of a sized payload (``tokens``, ``prompt``, ``inputs``,
    ``receivers``).  Unrecognisable requests size to 1.0, which lands them
    in the lowest cost class — never an error: sizing is an accounting hint,
    not validation.
    """
    for key in ("nt", "steps", "max_new_tokens", "new_tokens"):
        v = request.get(key)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            return float(v)
    for key in ("tokens", "prompt", "inputs", "receivers"):
        v = request.get(key)
        if v is not None and hasattr(v, "__len__"):
            return float(len(v))
    return 1.0


def shape_cost_classifier(bounds: Sequence[float]) -> Callable[[dict], int]:
    """Cost-class inference from request shape: class = number of ``bounds``
    the request's :func:`request_size` exceeds (so ``bounds=(100,)`` gives
    two classes: ≤100 → 0, >100 → 1).  This is what ``ServePool`` installs
    when given ``cost_class_bounds`` — replicas then publish per-class EWMA
    service times through the scheduler's information ring and queues are
    priced in estimated work-seconds rather than request counts."""
    edges = sorted(float(b) for b in bounds)

    def classify(request: dict) -> int:
        s = request_size(request)
        return sum(1 for e in edges if s > e)

    return classify


@dataclass
class Replica:
    """One model replica (device slice / pod) with a relative speed."""

    name: str
    generate: Callable[[dict], dict]  # request -> response
    slow_factor: float = 1.0


@dataclass
class AutoscaleConfig:
    """Autoscaler for an elastic ``ServePool`` (DESIGN.md §Elasticity,
    §SLO serving).

    A background watcher samples the pool every ``interval`` seconds and
    acts in one of two modes:

    ``mode="threshold"`` (the PR-3 reactive scaler):

    * **scale OUT** when the request backlog exceeds
      ``high_pending_per_replica`` × live replicas (queueing theory's "the
      pool is past saturation" signal — pending() counts queued + in-flight,
      so the bound is in units of requests-per-server) and the pool is below
      ``max_replicas``: ``factory(worker_id)`` builds the new replica.
    * **scale IN** when ``pending() == 0`` for ``idle_ticks_to_retire``
      consecutive samples and the pool is above ``min_replicas``: the
      highest-numbered live replica is drained back out (LIFO, so the boot
      replicas — typically the fast reserved capacity — stay).

    ``mode="predictive"``: Holt's double-exponential forecast of the
    ARRIVAL rate instead of the instantaneous backlog.  Each tick observes
    the submit rate since the last tick, updates level/trend EWMAs
    (``rate_alpha``/``trend_beta``), and provisions capacity against the
    forecast ``level + trend × horizon`` at ``target_util`` utilisation,
    where per-replica capacity is the observed mean service rate (served
    tasks / busy seconds, pool-wide).  The pool scales out while live <
    wanted and recedes (one per tick, only when the backlog is already
    small) when live > wanted — reserves come up BEFORE the backlog a
    threshold scaler needs as evidence, which is what rescues the latency
    tail on a diurnal ramp.  Until a service-time observation exists the
    predictive mode stands pat (no capacity estimate to provision against).

    **Straggler interaction** (DESIGN.md §Straggler plane): when the pool
    runs with limp detection (``ServePool(limp=...)``), a flagged replica is
    degraded capacity the backlog bound must not count on.  With
    ``limp_scale_out`` the scale-out test divides the backlog by HEALTHY
    replicas only (live minus limping), so a limping replica reads as load
    and triggers a surge replica early.  Once the scheduler has stripped a
    limping replica's deque (the re-pricing path), ``drain_limping_ticks``
    consecutive samples of flagged-and-empty drain it out of the pool like
    ``retire_replica(drain=True)`` — recorded as a ``"limp"`` scale event —
    guarded by ``min_replicas``.  Both knobs are inert when limp detection
    is off (nothing ever flags).
    """

    factory: Callable[[int], Replica]  # worker id -> new Replica
    min_replicas: int = 1
    max_replicas: int = 8
    high_pending_per_replica: float = 4.0
    idle_ticks_to_retire: int = 3
    interval: float = 0.02
    limp_scale_out: bool = True
    drain_limping_ticks: int = 3
    mode: str = "threshold"  # "threshold" | "predictive"
    rate_alpha: float = 0.3  # predictive: level EWMA weight
    trend_beta: float = 0.2  # predictive: trend EWMA weight
    horizon: float = 5.0  # predictive: forecast look-ahead, in ticks
    target_util: float = 0.75  # predictive: provisioned utilisation target

    def __post_init__(self) -> None:
        if self.mode not in ("threshold", "predictive"):
            raise ValueError(f"unknown autoscale mode {self.mode!r}")


class ServeFuture:
    """Handle for one in-flight request submitted to a live ``ServePool``.

    The scheduler moves the request between replica deques (steals) until a
    replica executes it; ``result()`` blocks until then.  Timing telemetry:
    ``submit_t`` (entered the pool), ``start_t``/``end_t`` (execution on the
    serving replica), ``latency`` = end - submit (the open-arrival sojourn
    time the §Open-arrival design optimises for).

    SLO attributes (DESIGN.md §SLO serving): ``slo_class`` (SLO_BATCH /
    SLO_LATENCY) and an ABSOLUTE ``deadline`` (pool-clock seconds; +inf =
    none).  These are what the scheduler's SLO-ordered owner pops and
    ``RunStats.slo_stats`` read off the future (the duck-typed face of
    ``core.deque.Task``, with ``submit_t`` as the arrival stamp).
    """

    __slots__ = (
        "request", "response", "error", "worker",
        "submit_t", "start_t", "end_t", "slo_class", "deadline", "_done",
    )

    def __init__(self, request: dict) -> None:
        self.request = request
        self.response: dict | None = None
        self.error: BaseException | None = None
        self.worker: int | None = None  # replica that ultimately served it
        self.submit_t: float = float("nan")
        self.start_t: float = float("nan")
        self.end_t: float = float("nan")
        self.slo_class: int = SLO_BATCH
        self.deadline: float = math.inf
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> dict:
        if not self._done.wait(timeout):
            raise TimeoutError("request not served in time")
        if self.error is not None:
            raise self.error
        assert self.response is not None
        return self.response

    @property
    def latency(self) -> float:
        return self.end_t - self.submit_t


class ServePool:
    """Continuous-batching A2WS request pool over heterogeneous replicas.

    Requests are the paper's tasks; each replica is a worker whose deque the
    others steal from (open-arrival mode, DESIGN.md §Open-arrival).  The
    pool boots ONCE (``start``), serves streamed requests (``submit``) for
    its whole lifetime — fast replicas steal queued requests from slow ones
    mid-flight, across wave boundaries, with no teardown or re-partitioning
    in between — and drains at ``shutdown``.

    ``submit_all`` is the closed-batch convenience wrapper: it submits a
    wave into the live pool and waits for exactly that wave.

    ``policy`` selects the scheduling policy balancing the replica deques —
    "a2ws" (default), "ctws", "lw", "random", or a ``SchedPolicy`` instance
    — so the paper's baselines are benchmarkable head-to-head on latency
    percentiles under identical serving traffic.

    **Work-weighted serving** (DESIGN.md §Work-weighted stealing): variable-
    cost requests (long vs short generations, deep vs shallow shots) break
    count-based balancing — a queue of 3 heavy requests is "shorter" than a
    queue of 4 light ones.  ``cost_class_bounds=(100,)`` infers a cost class
    from each request's shape (:func:`request_size` thresholds — here ≤100 →
    class 0, >100 → class 1) and the scheduler prices replica queues in
    estimated work-seconds from per-class EWMA service times.  For payloads
    the shape heuristic cannot size, pass an explicit ``cost_class_fn``
    (request dict -> class index) with ``num_classes``.  Neither given →
    count-based scheduling, bit-for-bit the old behaviour.

    **Migration cost** (DESIGN.md §Topology plane): stealing a queued
    request between replicas is not free — the thief replica serves it
    cold (prefix cache, paged KV, warm weights all live on the victim).
    ``migration_cost`` is the per-request warm-state price in seconds,
    folded into every remote link of ``topology`` (or onto a zero-cost
    uniform topology when none is given) via ``Topology.add_per_task`` —
    so victim selection discounts distant/cold steals, net-negative
    migrations are refused, and the thief pays the cost before the loot
    lands, through exactly the same pricing hook as the network.  Both
    default to off (``topology=None, migration_cost=0.0``) = bit-for-bit
    the unpriced pool.
    """

    def __init__(
        self,
        replicas: list[Replica],
        *,
        radius: int | None = None,
        seed: int = 0,
        policy: str | SchedPolicy = "a2ws",
        autoscale: AutoscaleConfig | None = None,
        cost_class_bounds: Sequence[float] | None = None,
        cost_class_fn: Callable[[dict], int] | None = None,
        num_classes: int | None = None,
        slowdown: SlowdownSchedule | None = None,
        limp: LimpConfig | None = None,
        topology: Topology | None = None,
        migration_cost: float = 0.0,
        netfaults: NetFaultSchedule | None = None,
        slo_order: bool = False,
        slo_aging: float = math.inf,
    ):
        self.replicas = replicas
        self.radius = radius
        self.seed = seed
        self.policy = policy
        self.autoscale = autoscale
        # SLO plane (DESIGN.md §SLO serving): slo_order=True makes every
        # replica pop its own deque SLO-first (latency jumps batch, EDF
        # within class, batch older than slo_aging promoted); thief-end
        # steals still strip the oldest tail, i.e. batch work.  Off by
        # default — bit-for-bit the PR-9 pop path.
        if not slo_aging > 0.0:  # also rejects NaN
            raise ValueError(f"slo_aging {slo_aging} must be > 0 (or inf)")
        self.slo_order = slo_order
        self.slo_aging = slo_aging
        if migration_cost < 0.0 or migration_cost != migration_cost:
            raise ValueError("migration_cost must be >= 0")
        # Per-request warm-state weight rides the same pricing hook as the
        # network: fold it into every remote per-task cost of the topology
        # (a zero-cost uniform base when no network model was given).
        if migration_cost > 0.0:
            base = topology if topology is not None else Topology.uniform()
            topology = base.add_per_task(migration_cost, name=f"{base.name}+migration")
        self.topology = topology
        self.migration_cost = migration_cost
        # Fault plane (DESIGN.md §Fault fabric): injected into the replica
        # runtime's steal fabric (leases, backoff, partition degradation),
        # and consulted by submit() for partition-aware front-end routing.
        self.netfaults = netfaults
        self._route_rr = 0  # round-robin cursor for partition routing
        # Straggler plane (DESIGN.md §Straggler plane): ``slowdown`` scripts
        # degraded-but-alive faults into the replica runtime; ``limp``
        # enables the owner-side detector that re-prices a limping replica's
        # queue, stops routing submits to it, and (with autoscale) drains it.
        self.slowdown = slowdown
        self.limp = limp
        #: (wall time, replica id, flagged) limp-detector transitions —
        #: live view while serving, snapshotted across shutdown().
        self.limp_log: list[tuple[float, int, bool]] = []
        if cost_class_bounds is not None and cost_class_fn is not None:
            raise ValueError(
                "cost_class_bounds and cost_class_fn are mutually exclusive"
            )
        if cost_class_bounds is not None:
            self.cost_class_fn: Callable[[dict], int] | None = (
                shape_cost_classifier(cost_class_bounds)
            )
            self.num_classes = len(cost_class_bounds) + 1
        elif cost_class_fn is not None:
            if num_classes is None or num_classes < 2:
                raise ValueError(
                    "an explicit cost_class_fn needs num_classes >= 2"
                )
            self.cost_class_fn = cost_class_fn
            self.num_classes = num_classes
        else:
            self.cost_class_fn = None
            self.num_classes = 1
        #: (wall time, "out" | "in" | "limp", worker id, pending at decision)
        self.scale_events: list[tuple[float, str, int, int]] = []
        self.peak_live = len(replicas)
        self._scale_lock = threading.Lock()
        self._scale_stop = threading.Event()
        self._scaler: threading.Thread | None = None
        self._runtime: WorkerPool | None = None

    # ------------------------------------------------------------- lifecycle
    @property
    def running(self) -> bool:
        return self._runtime is not None

    def start(self) -> None:
        """Boot the replica workers; idempotent."""
        if self._runtime is not None:
            return

        def task_fn(wid: int, fut: ServeFuture) -> None:
            # A generate() failure propagates into the runtime's
            # fault-tolerance path: the replica is tombstoned, the future is
            # re-queued, and a SURVIVING replica re-serves it (transparent
            # retry).  The future is only resolved on success — or at
            # shutdown, if no survivor ever picked it up.
            rep = self.replicas[wid]
            fut.worker = wid
            fut.start_t = time.perf_counter()
            out = rep.generate(fut.request)
            if rep.slow_factor > 1.0:
                time.sleep(
                    (time.perf_counter() - fut.start_t)
                    * (rep.slow_factor - 1.0)
                )
            fut.response = out
            fut.end_t = time.perf_counter()
            fut._done.set()

        # The pool's tasks are ServeFutures: classify through the wrapped
        # request so user classifiers keep their dict-in/int-out signature.
        classify = self.cost_class_fn
        rt = WorkerPool(
            [],
            len(self.replicas),
            task_fn,
            policy=self.policy,
            radius=self.radius,
            seed=self.seed,
            open_arrival=True,
            cost_class_fn=(
                None if classify is None
                else lambda fut: classify(fut.request)
            ),
            num_classes=self.num_classes,
            slowdown=self.slowdown,
            limp=self.limp,
            topology=self.topology,
            netfaults=self.netfaults,
            slo=self.slo_order,
            slo_aging=self.slo_aging,
        )
        # Share the runtime's transition log so limp telemetry stays
        # readable after shutdown() drops the runtime reference.
        self.limp_log = rt.limp_log
        # If the LAST replica dies, nothing will ever serve the queued
        # requests — fail their futures immediately instead of letting
        # result() (and submit_all) hang forever.
        rt.on_collapse = self._fail_unserved
        rt.start()
        self._runtime = rt
        if self.autoscale is not None:
            self._scale_stop.clear()
            self._scaler = threading.Thread(
                target=self._autoscale_loop, daemon=True
            )
            self._scaler.start()

    def _fail_unserved(self, stranded: list) -> None:
        err = RuntimeError("all replicas died; request not served")
        for fut in stranded:
            if isinstance(fut, ServeFuture) and not fut.done():
                fut.error = err
                fut.end_t = time.perf_counter()
                fut._done.set()

    # ------------------------------------------------------------- elasticity
    def live_replicas(self) -> list[int]:
        """Ids of replicas currently serving (not dead, not draining)."""
        rt = self._runtime
        if rt is None:
            return []
        return [
            i for i in range(rt.num_workers)
            if not rt.dead[i] and not rt.workers[i].retiring
        ]

    def limping_replicas(self) -> list[int]:
        """Ids of LIVE replicas the limp detector currently flags (always
        empty when the pool runs without ``limp=``)."""
        rt = self._runtime
        if rt is None:
            return []
        return [i for i in self.live_replicas() if rt.limping(i)]

    def set_replica_slowdown(self, replica: int, factor: float) -> None:
        """Inject a live slowdown multiplier on one replica (fault
        injection / chaos testing): every task it executes stalls by
        ``factor`` on top of any scripted schedule.  ``factor=1.0``
        restores full speed."""
        if self._runtime is None:
            raise RuntimeError("pool not started")
        self._runtime.set_worker_slowdown(replica, factor)

    def add_replica(
        self, replica: Replica | Callable[[int], Replica]
    ) -> int:
        """Scale out: boot one more worker of the LIVE pool.  Queued
        requests flow to it through the ordinary steal path — no
        rebalancing pass, no pause.  Returns the replica id — a recycled
        slot of a previously retired/dead replica when one is free (the
        pool's ring stays bounded across surge cycles), else a fresh one.

        ``replica`` may be a ready ``Replica`` or a factory called with the
        ACTUAL assigned id — a recycled slot's id is only known at
        assignment time, so id-keyed replica config (device slice, name,
        endpoint) must be built there, not guessed from the list length."""
        if self._runtime is None:
            raise RuntimeError("pool not started")

        def place(wid: int) -> None:
            # Runs before the worker thread boots: task_fn indexes
            # self.replicas[wid], so the entry must exist first.
            rep = replica(wid) if callable(replica) else replica
            if wid == len(self.replicas):
                self.replicas.append(rep)
            else:
                self.replicas[wid] = rep

        with self._scale_lock:
            wid = self._runtime.add_worker(on_assign=place)
        self.peak_live = max(self.peak_live, len(self.live_replicas()))
        return wid

    def retire_replica(self, replica: int, drain: bool = True) -> None:
        """Scale in / maintenance: gracefully drain one replica out of the
        live pool (its queued requests move to survivors first).  The
        ``Replica`` object keeps its slot so ids stay stable."""
        if self._runtime is None:
            raise RuntimeError("pool not started")
        self._runtime.retire_worker(replica, drain=drain)

    def _autoscale_loop(self) -> None:
        cfg = self.autoscale
        assert cfg is not None
        idle_ticks = 0
        limp_ticks: dict[int, int] = {}  # replica -> consecutive flagged+empty
        # Predictive state: Holt's level+trend over the observed submit rate.
        prev_submitted: int | None = None
        level = 0.0
        trend = 0.0
        level_init = False
        while not self._scale_stop.wait(cfg.interval):
            rt = self._runtime
            if rt is None:
                return
            live = self.live_replicas()
            self.peak_live = max(self.peak_live, len(live))
            pending = rt.pending()
            limping = [i for i in live if rt.limping(i)]
            # A limping replica that the scheduler has already stripped
            # (empty deque) is pure drag: drain it like retire_replica
            # once it stays flagged-and-empty long enough.  One drain per
            # sample keeps the pool's reaction conservative.
            limp_ticks = {
                i: (limp_ticks.get(i, 0) + 1
                    if len(rt.workers[i].deque) == 0 else 0)
                for i in limping
            }
            ripe = [
                i for i, t in limp_ticks.items()
                if t >= cfg.drain_limping_ticks
            ]
            if ripe and len(live) > cfg.min_replicas:
                victim = min(ripe)
                self.retire_replica(victim, drain=True)
                self.scale_events.append(
                    (time.perf_counter(), "limp", victim, pending)
                )
                del limp_ticks[victim]
                limping.remove(victim)
                live.remove(victim)  # retiring now — not capacity
            # Limping replicas are degraded capacity: with limp_scale_out
            # the saturation bound counts healthy replicas only, so a
            # straggler reads as backlog and pulls in a surge replica.
            healthy = (
                len(live) - len(limping) if cfg.limp_scale_out else len(live)
            )
            if cfg.mode == "predictive":
                submitted = rt.submitted.load()
                if prev_submitted is not None:
                    inst = (submitted - prev_submitted) / cfg.interval
                    if not level_init:
                        level_init = True
                        level = inst  # first observation seeds the level
                    else:
                        lvl_prev = level
                        level = cfg.rate_alpha * inst + (
                            1.0 - cfg.rate_alpha
                        ) * lvl_prev
                        trend = cfg.trend_beta * (level - lvl_prev) + (
                            1.0 - cfg.trend_beta
                        ) * trend
                prev_submitted = submitted
                # Per-replica capacity from OBSERVED service times (served
                # tasks / busy seconds, pool-wide mean); no observation yet
                # -> stand pat, there is nothing to provision against.
                served = sum(w.executed for w in rt.workers)
                busy_s = sum(w.runtime_sum for w in rt.workers)
                if served <= 0 or busy_s <= 0.0:
                    continue
                rate_per_replica = served / busy_s
                lam = max(level + trend * cfg.horizon, 0.0)
                want = math.ceil(
                    lam / (cfg.target_util * rate_per_replica)
                )
                want = min(max(want, cfg.min_replicas), cfg.max_replicas)
                if healthy < want and len(live) < cfg.max_replicas:
                    wid = self.add_replica(cfg.factory)
                    self.scale_events.append(
                        (time.perf_counter(), "out", wid, pending)
                    )
                elif (
                    len(live) > want
                    and len(live) > cfg.min_replicas
                    and pending <= len(live)
                ):
                    # Recede one per tick, only once the backlog is small —
                    # draining a replica re-sprays its queue.
                    victim = max(live)  # LIFO: boot replicas stay
                    self.retire_replica(victim, drain=True)
                    self.scale_events.append(
                        (time.perf_counter(), "in", victim, pending)
                    )
            elif (
                pending > cfg.high_pending_per_replica * max(healthy, 1)
                and len(live) < cfg.max_replicas
            ):
                # The factory receives the ACTUAL slot id (recycled slots
                # make it differ from the replica-list length).
                wid = self.add_replica(cfg.factory)
                self.scale_events.append(
                    (time.perf_counter(), "out", wid, pending)
                )
                idle_ticks = 0
            elif pending == 0 and len(live) > cfg.min_replicas:
                idle_ticks += 1
                if idle_ticks >= cfg.idle_ticks_to_retire:
                    victim = max(live)  # LIFO: boot replicas stay
                    self.retire_replica(victim, drain=True)
                    self.scale_events.append(
                        (time.perf_counter(), "in", victim, 0)
                    )
                    idle_ticks = 0
            else:
                idle_ticks = 0

    def shutdown(self) -> RunStats:
        """Drain (no more submits), wait for quiescence, return final stats."""
        if self._runtime is None:
            raise RuntimeError("pool not started")
        if self._scaler is not None:
            self._scale_stop.set()
            self._scaler.join()
            self._scaler = None
        rt = self._runtime
        rt.drain()
        stats = rt.join()
        # Every replica that could serve a re-queued request has now had
        # the chance.  Unresolved futures come in two flavours: the ones a
        # dying replica was executing (rt.errors) and the ones still queued
        # on deques no surviving worker ever popped — fail both so no
        # waiter outlives the pool.
        for _wid, fut, err in rt.errors:
            if isinstance(fut, ServeFuture) and not fut.done():
                fut.error = err
                fut.end_t = time.perf_counter()
                fut._done.set()
        self._fail_unserved(rt.drain_leftover_tasks())
        self._runtime = None
        return stats

    # -------------------------------------------------------------- requests
    def _partition_route(self) -> int | None:
        """Partition-aware front-end routing (DESIGN.md §Fault fabric).

        While a partition is active, the default round-robin would spray
        requests uniformly — those landing on the minority side cannot be
        stolen across the cut, so the majority's capacity sits idle while
        the minority drowns.  Instead, pick (round-robin) a live replica in
        the LARGEST reachable component; if every member of a component has
        died, retry with the next-largest one.  Returns ``None`` when no
        partition is active, every live replica sits in one component, or
        no component has a live member — the caller then falls back to the
        default router.
        """
        nf, rt = self.netfaults, self._runtime
        if nf is None or not nf.partitions or rt is None or rt._t0 is None:
            return None
        t = rt.clock() - rt._t0
        active = [p for p in nf.partitions if p.start <= t < p.end]
        if not active:
            return None
        groups: dict[tuple, list[int]] = {}
        for w in range(rt.num_workers):
            if rt.dead[w]:
                continue
            label = tuple(w in p._side_set for p in active)
            groups.setdefault(label, []).append(w)
        if len(groups) <= 1:
            return None
        # Only live replicas enter groups, so a fully-dead component is
        # skipped by construction — iterating largest-first IS the submit
        # retry across components.
        for members in sorted(groups.values(), key=lambda g: (-len(g), g[0])):
            if members:
                self._route_rr += 1
                return members[self._route_rr % len(members)]
        return None

    def submit(
        self,
        request: dict,
        *,
        replica: int | None = None,
        slo_class: int | str | None = None,
        deadline: float | None = None,
    ) -> ServeFuture:
        """Inject one request into the live pool (thread-safe); returns a
        ``ServeFuture``.  ``replica`` pins the initial deque (tests/traces);
        default routing round-robins and lets stealing do the balancing —
        except while a partition is active (``netfaults``), where the
        request routes into the largest reachable component instead
        (:meth:`_partition_route`).

        ``slo_class`` tags the request ``"latency"``/``"batch"`` (or the
        SLO_LATENCY/SLO_BATCH ints); ``deadline`` is a RELATIVE budget in
        seconds, resolved against the submit stamp into the absolute
        deadline the SLO-ordered pops and ``RunStats.slo_stats`` act on.
        Both default to the batch/no-deadline degenerate case."""
        if self._runtime is None:
            self.start()
        fut = ServeFuture(request)
        if slo_class is not None:
            if isinstance(slo_class, str):
                try:
                    slo_class = SLO_NAMES.index(slo_class)
                except ValueError:
                    raise ValueError(
                        f"slo_class {slo_class!r} not in {SLO_NAMES}"
                    ) from None
            if slo_class not in (SLO_BATCH, SLO_LATENCY):
                raise ValueError(f"slo_class {slo_class} must be 0 or 1")
            fut.slo_class = int(slo_class)
        fut.submit_t = time.perf_counter()
        if deadline is not None:
            if not deadline > 0.0:  # also rejects NaN
                raise ValueError(f"deadline budget {deadline} must be > 0")
            fut.deadline = fut.submit_t + deadline
        assert self._runtime is not None
        if replica is None:
            replica = self._partition_route()
        try:
            self._runtime.submit(fut, worker=replica)
        except PoolCollapsed:
            # Every replica is dead: fail THIS request immediately (the
            # runtime either never accepted it, or swept it into the
            # collapse hook — which already failed it, making this a no-op).
            self._fail_unserved([fut])
            return fut
        if self._runtime.alive.load() == 0:
            # Pool collapsed (all replicas dead).  Redundant safety net: the
            # runtime's post-push sweep already routed every stranded future
            # through the collapse hook (ServePool always installs it before
            # start), making this a no-op via the fut.done() guard — kept so
            # a waiter can never hang even if the collapse protocol shifts.
            # Never drain here: the runtime reconciles its quiescence
            # counters when IT sweeps.
            self._fail_unserved([fut])
        return fut

    def submit_wave(
        self,
        requests: Sequence[dict],
        *,
        replica: int | None = None,
        slo_class: int | str | None = None,
        deadline: float | None = None,
    ) -> list[ServeFuture]:
        return [
            self.submit(
                r, replica=replica, slo_class=slo_class, deadline=deadline
            )
            for r in requests
        ]

    def stats(self) -> RunStats:
        """Live scheduler stats snapshot (callable while serving)."""
        if self._runtime is None:
            raise RuntimeError("pool not started")
        return self._runtime.stats_snapshot()

    def pending(self) -> int:
        return self._runtime.pending() if self._runtime is not None else 0

    # ------------------------------------------------------ closed-batch API
    def submit_all(self, requests: list[dict], seed: int = 0):
        """Serve one wave to completion on the LIVE pool and return
        ``(responses, stats)`` — kept signature-compatible with the old
        closed-batch ServePool, but no longer tears the pool down: calling
        it repeatedly reuses the same workers and deques, and requests of a
        later wave can be stolen the moment they are submitted.  ``stats``
        is a pool-lifetime snapshot (per-wave deltas: diff two snapshots).
        """
        del seed  # scheduler seeding is fixed at pool construction now
        futs = self.submit_wave(requests)
        responses = [f.result() for f in futs]
        return responses, self.stats()

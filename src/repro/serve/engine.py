"""Serving: jitted prefill/decode steps with cache sharding + host-side pool.

Device plane
------------
``jit_prefill_step``/``jit_decode_step`` wrap ``lm.prefill``/``lm.decode_step``
with explicit shardings.  KV-cache layout policy (per leaf):

  * batch dim        -> DP axes when divisible (decode_32k: 128 over 16/32)
  * KV heads         -> 'model' when divisible (TP-style head sharding)
  * else sequence    -> 'model' (flash-decode style: each rank holds a cache
    slice; XLA inserts the tiny cross-rank softmax reductions — this is what
    spreads the 32k-cache HBM traffic over the pod, the decode bottleneck)
  * SSM state heads / RG-LRU width / conv channels -> 'model'

Host plane
----------
``ServePool`` runs batched requests across heterogeneous model replicas with
the paper's scheduler: requests are A2WS tasks, replicas are workers, so fast
replicas steal queued requests from slow ones (preemptively, per §2.2.1).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.a2ws import A2WSRuntime
from repro.models import lm
from repro.models.config import ModelConfig
from repro.parallel.sharding import (
    ParallelContext,
    serve_context,
    shardings_for,
)
from repro.train.step import batch_shardings

__all__ = [
    "abstract_caches",
    "cache_pspecs",
    "cache_shardings",
    "jit_prefill_step",
    "jit_decode_step",
    "Replica",
    "ServePool",
]


# ----------------------------------------------------------------- structure
def _group_kinds(kind: str) -> list[str]:
    if kind.startswith("cycle:"):
        return kind[len("cycle:") :].split("|")
    return [kind]


def _decoder_groups(cfg: ModelConfig):
    if cfg.enc_layers:
        return (("xdec", cfg.n_layers),)
    return cfg.scan_groups()


def abstract_caches(
    cfg: ModelConfig, bsz: int, cache_len: int, enc_len: int | None = None
):
    """ShapeDtypeStruct tree matching what ``lm.prefill`` returns as caches."""
    sds = jax.eval_shape(lambda: lm.init_caches(cfg, bsz, cache_len))
    if not cfg.enc_layers:
        return sds
    # enc-dec: fill the memory-KV slot (None in init_caches) with the
    # encoder-memory K/V the decode step cross-attends to.
    enc_len = enc_len or cache_len
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    mem = jax.ShapeDtypeStruct(
        (cfg.n_layers, bsz, enc_len, hkv, hd), jnp.dtype(cfg.dtype)
    )
    (group0,) = sds  # single xdec group
    (pair,) = group0  # kinds == ["xdec"]
    sa = pair[0] if isinstance(pair, tuple) and len(pair) == 2 else pair
    return [(((sa[0], sa[1]), (mem, mem)),)]


def _dp_or_none(ctx: ParallelContext, bsz: int):
    if ctx.mesh is None:
        return None
    size = 1
    for a in ctx.dp_axes:
        size *= ctx.mesh.shape[a]
    if bsz % size != 0:
        return None
    return ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]


def _kv_spec(cfg, ctx, dp, seq: int):
    """[L, B, S, Hkv, hd] — heads over 'model' if divisible, else sequence."""
    tp = ctx.tp_axis
    tpn = ctx.mesh.shape[tp]
    if cfg.n_kv_heads % tpn == 0:
        return P(None, dp, None, tp, None)
    if seq % tpn == 0:
        return P(None, dp, tp, None, None)
    return P(None, dp, None, None, None)


def cache_pspecs(cfg: ModelConfig, ctx: ParallelContext, bsz: int, cache_len: int):
    """PartitionSpec tree matching the prefill/decode cache structure."""
    assert ctx.mesh is not None
    tp = ctx.tp_axis
    tpn = ctx.mesh.shape[tp]
    dp = _dp_or_none(ctx, bsz)

    def div(n):  # 'model' only when divisible
        return tp if n % tpn == 0 else None

    def kind_spec(kind: str):
        if kind in ("attn", "attn_dense", "attn_moe"):
            if cfg.mla is not None:
                s = div(cache_len)
                return (P(None, dp, s, None), P(None, dp, s, None))
            kv = _kv_spec(cfg, ctx, dp, cache_len)
            return (kv, kv)
        if kind == "local":
            w = cfg.window or cache_len
            kv = _kv_spec(cfg, ctx, dp, w)
            return (kv, kv)
        if kind == "ssm":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            h = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.d_state
            return (
                P(None, dp, div(h), None, None),
                P(None, dp, None, div(conv_ch)),
            )
        if kind == "rglru":
            w = cfg.rglru.lru_width
            return (P(None, dp, div(w)), P(None, dp, None, div(w)))
        if kind == "xdec":
            kv = _kv_spec(cfg, ctx, dp, cache_len)
            return ((kv, kv), (kv, kv))
        raise ValueError(kind)

    out = []
    for kind, _count in _decoder_groups(cfg):
        out.append(tuple(kind_spec(k) for k in _group_kinds(kind)))
    return out


def cache_shardings(cfg, ctx, bsz, cache_len):
    specs = cache_pspecs(cfg, ctx, bsz, cache_len)
    return jax.tree.map(
        lambda p: NamedSharding(ctx.mesh, p),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ------------------------------------------------------------------ jit steps
def jit_prefill_step(cfg: ModelConfig, ctx: ParallelContext, batch_sds: dict):
    """jit(prefill) with explicit shardings; returns (logits, caches)."""

    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, ctx)

    if ctx.mesh is None:
        return jax.jit(prefill_step)
    params_sds, specs = lm.init_shapes(cfg)
    param_sh = shardings_for(specs, ctx, params_sds)
    b_sh = batch_shardings(batch_sds, ctx)
    ref = (
        batch_sds["tokens"]
        if "tokens" in batch_sds
        else batch_sds.get("embeds", batch_sds.get("enc_embeds"))
    )
    bsz, seq = ref.shape[0], ref.shape[1]
    cache_sh = cache_shardings(cfg, ctx, bsz, seq)
    return jax.jit(
        prefill_step,
        in_shardings=(param_sh, b_sh),
        out_shardings=(None, cache_sh),
    )


def jit_decode_step(
    cfg: ModelConfig,
    ctx: ParallelContext,
    bsz: int,
    cache_len: int,
    *,
    donate: bool = True,
    serve_layout: bool = True,
):
    """jit(decode_step) with explicit shardings; caches donated in-place.

    ``serve_layout``: use the inference parameter layout (``serve_context``)
    — dense weights TP-only (no per-step FSDP gathers), experts full-EP.
    Pass False to keep the training layout (the paper-faithful baseline in
    EXPERIMENTS.md §Perf).
    """
    if ctx.mesh is not None and serve_layout:
        ctx = serve_context(ctx.mesh, cfg.moe.num_experts if cfg.moe else 0)

    def decode(params, tokens, caches, pos):
        return lm.decode_step(params, tokens, caches, pos, cfg, ctx)

    if ctx.mesh is None:
        return jax.jit(decode, donate_argnums=(2,) if donate else ())
    params_sds, specs = lm.init_shapes(cfg)
    param_sh = shardings_for(specs, ctx, params_sds)
    cache_sh = cache_shardings(cfg, ctx, bsz, cache_len)
    dp = _dp_or_none(ctx, bsz)
    tok_sh = NamedSharding(ctx.mesh, P(dp, None))
    pos_sh = NamedSharding(ctx.mesh, P())
    return jax.jit(
        decode,
        in_shardings=(param_sh, tok_sh, cache_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,) if donate else (),
    )


# -------------------------------------------------------------- host serving
@dataclass
class Replica:
    """One model replica (device slice / pod) with a relative speed."""

    name: str
    generate: Callable[[dict], dict]  # request -> response
    slow_factor: float = 1.0


class ServePool:
    """A2WS-scheduled request pool over heterogeneous replicas.

    Requests are the paper's tasks; each replica is a worker whose deque the
    others can steal from.  ``submit_all`` runs one batch of requests to
    completion and returns (responses, RunStats).
    """

    def __init__(self, replicas: list[Replica], *, radius: int | None = None):
        self.replicas = replicas
        self.radius = radius

    def submit_all(self, requests: list[dict], seed: int = 0):
        import time as _time

        responses: dict[int, dict] = {}

        def task_fn(wid: int, idx):
            rep = self.replicas[wid]
            t0 = _time.perf_counter()
            out = rep.generate(requests[int(idx)])
            if rep.slow_factor > 1.0:
                _time.sleep((_time.perf_counter() - t0) * (rep.slow_factor - 1.0))
            responses[int(idx)] = out

        rt = A2WSRuntime(
            list(range(len(requests))),
            len(self.replicas),
            task_fn,
            radius=self.radius,
            seed=seed,
        )
        stats = rt.run()
        return [responses[i] for i in range(len(requests))], stats

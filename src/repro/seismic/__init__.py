from .model import (
    SeismicModel,
    Shot,
    make_demo_model,
    make_shot_grid,
    ricker,
    run_shot,
)

__all__ = [
    "SeismicModel",
    "Shot",
    "make_demo_model",
    "make_shot_grid",
    "ricker",
    "run_shot",
]

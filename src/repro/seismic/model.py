"""3-D acoustic seismic modeling — the paper's use case (§3).

A *shot* is one independent simulation: inject a Ricker source at a position
near the surface, propagate Eq. 12 for ``nt`` steps through the velocity
model, and record the pressure at receiver positions.  Shots are the
homogeneous tasks A2WS schedules.

The stencil is the FD3D kernel (``repro.kernels.fd3d``); boundaries use a
simple exponential sponge taper.  Everything is jittable; the shot loop is a
``lax.fori_loop`` so one shot is a single XLA program.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fd3d import fd3d_step

__all__ = ["Shot", "SeismicModel", "ricker", "run_shot", "make_demo_model"]


def ricker(f_peak: float, dt: float, nt: int) -> jnp.ndarray:
    """Ricker wavelet source time function."""
    t = jnp.arange(nt) * dt - 1.0 / f_peak
    a = (jnp.pi * f_peak * t) ** 2
    return (1.0 - 2.0 * a) * jnp.exp(-a)


@dataclass(frozen=True)
class Shot:
    """One seismic experiment: source position + receiver line."""

    src: tuple[int, int, int]
    receivers: tuple[tuple[int, int, int], ...]

    def rec_array(self) -> np.ndarray:
        return np.asarray(self.receivers, dtype=np.int32)


@dataclass(frozen=True)
class SeismicModel:
    """Discretised velocity model + solver settings."""

    velocity: jnp.ndarray  # (NZ, NY, NX) m/s
    dx: float = 10.0  # m
    dt: float = 1e-3  # s  (must satisfy CFL: dt < 0.4 dx / vmax)
    f_peak: float = 12.0  # Hz
    sponge: int = 8
    sponge_decay: float = 0.012

    def cfl_ok(self) -> bool:
        vmax = float(jnp.max(self.velocity))
        return self.dt <= 0.5 * self.dx / (vmax * np.sqrt(3.0) / 2.0)


def _sponge_mask(shape: tuple[int, int, int], width: int, decay: float) -> jnp.ndarray:
    """Exponential absorbing taper near five faces (z=0 is the free surface,
    where sources and receivers live)."""
    masks = []
    for axis, n in enumerate(shape):
        idx = jnp.arange(n)
        if axis == 0:  # free surface at z=0: only absorb at the bottom
            edge = n - 1 - idx
        else:
            edge = jnp.minimum(idx, n - 1 - idx)
        ramp = jnp.where(
            edge < width, jnp.exp(-decay * (width - edge) ** 2), 1.0
        )
        masks.append(ramp)
    mz, my, mx = masks
    return mz[:, None, None] * my[None, :, None] * mx[None, None, :]


@partial(jax.jit, static_argnames=("nt", "backend"))
def run_shot(
    model: SeismicModel,
    src: jnp.ndarray,  # (3,) int32
    receivers: jnp.ndarray,  # (n_rec, 3) int32
    nt: int,
    backend: str | None = None,
) -> jnp.ndarray:
    """Propagate one shot; returns the (nt, n_rec) seismogram."""
    vel = model.velocity
    c2dt2 = (vel * model.dt) ** 2
    mask = _sponge_mask(vel.shape, model.sponge, model.sponge_decay)
    wavelet = ricker(model.f_peak, model.dt, nt)
    u = jnp.zeros_like(vel)
    u_prev = jnp.zeros_like(vel)
    seis = jnp.zeros((nt, receivers.shape[0]), vel.dtype)

    def body(it, carry):
        u, u_prev, seis = carry
        u_next = fd3d_step(u, u_prev, c2dt2, dx=model.dx, backend=backend)
        u_next = u_next.at[src[0], src[1], src[2]].add(
            wavelet[it] * c2dt2[src[0], src[1], src[2]]
        )
        u_next = u_next * mask
        u_damped = u * mask
        rec = u_next[receivers[:, 0], receivers[:, 1], receivers[:, 2]]
        # carry stays (current, previous, seismogram)
        return u_next, u_damped, seis.at[it].set(rec)

    u, u_prev, seis = jax.lax.fori_loop(0, nt, body, (u, u_prev, seis))
    return seis


jax.tree_util.register_pytree_node(
    SeismicModel,
    lambda m: ((m.velocity,), (m.dx, m.dt, m.f_peak, m.sponge, m.sponge_decay)),
    lambda aux, kids: SeismicModel(kids[0], *aux),
)


def make_demo_model(
    n: int = 48, dx: float = 10.0, dt: float = 1e-3, layers: int = 3
) -> SeismicModel:
    """Small layered-earth model for tests/examples."""
    z = np.linspace(0, 1, n)[:, None, None]
    vel = 1500.0 + 1000.0 * np.floor(z * layers)
    vel = np.broadcast_to(vel, (n, n, n)).astype(np.float32)
    return SeismicModel(velocity=jnp.asarray(vel), dx=dx, dt=dt)


def make_shot_grid(
    model: SeismicModel, num_shots: int, depth: int = 2, n_rec: int = 8
) -> list[Shot]:
    """A line of shots across the surface with a fixed receiver line."""
    nz, ny, nx = model.velocity.shape
    xs = np.linspace(6, nx - 7, num_shots).astype(int)
    rec_y = ny // 2
    recs = tuple(
        (depth, rec_y, int(x)) for x in np.linspace(4, nx - 5, n_rec).astype(int)
    )
    return [Shot(src=(depth, rec_y, int(x)), receivers=recs) for x in xs]

"""End-to-end driver: LM training with A2WS-scheduled heterogeneous data
parallelism, fault injection, checkpoint/restart — the paper's technique as
a first-class training feature.

The global batch is cut into microbatch TASKS; worker groups (one fast, one
deliberately slow, one that dies mid-run) own A2WS deques of them.  Fast
workers steal microbatches from stragglers, the dying worker's tasks are
re-queued and finished by survivors, and the driver restarts from the last
checkpoint after removing it.  The combined gradient is exact regardless of
who computed what, so A2WS changes step latency, never semantics.

Defaults are container-sized (a ~1M-param model, 30 steps); scale with
    --arch phi4-mini-3.8b --steps 300 --d-model 512 ...
to the ~100M/few-hundred-step regime on real hardware.

    PYTHONPATH=src python examples/het_train.py
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import ResilientDriver
from repro.runtime.het_dp import HetDPTrainer, WorkerSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--mb-size", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--fail-step", type=int, default=12)
    ap.add_argument("--compress", action="store_true",
                    help="int8+error-feedback gradient compression")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params, _ = lm.init(cfg, jax.random.key(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch {cfg.name}: {n_params/1e6:.2f}M params, "
          f"{args.microbatches} microbatch tasks/step")

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq,
        global_batch=args.mb_size * args.microbatches, seed=0,
    ))

    def loss_fn(p, batch):
        return lm.loss_fn(p, batch, cfg)

    def make_microbatches(step):
        b = data.batch_at(step)
        return [
            {k: jax.numpy.asarray(v[i::args.microbatches]) for k, v in b.items()}
            for i in range(args.microbatches)
        ]

    workers = [
        WorkerSpec("fast-pod"),
        WorkerSpec("throttled-pod", slow_factor=5.0),
        WorkerSpec("flaky-pod", fail_at_step=args.fail_step),
    ]
    trainer = HetDPTrainer(
        loss_fn, params, workers,
        AdamWConfig(lr=args.lr, weight_decay=0.0),
        compress=args.compress, base_task_time=0.01,
    )
    ckpt_dir = tempfile.mkdtemp(prefix="het_train_ckpt_")
    driver = ResilientDriver(trainer, make_microbatches, ckpt_dir,
                             ckpt_every=5)
    report = driver.run(args.steps)

    print(f"steps run:        {report.steps_run}")
    print(f"restarts:         {report.restarts}")
    print(f"removed workers:  {report.removed_workers}")
    print(f"final loss:       {report.final_loss:.4f}")
    tot = [0] * 3
    for st in trainer.history:
        for i, c in enumerate(st.per_worker_tasks):
            if i < len(tot):
                tot[i] += c
    print(f"microbatches/worker (lifetime): {tot} — the straggler ran fewer, "
          "thanks to stealing")


if __name__ == "__main__":
    main()

"""The paper's use case end-to-end: 3-D acoustic seismic modeling with shots
scheduled by A2WS across heterogeneous workers (paper §3-4, miniaturised).

Each task = one shot: inject a Ricker wavelet, propagate the 8th-order FDM
stencil (`repro.kernels.fd3d`, the Pallas TPU kernel's jnp oracle on CPU),
record seismograms at the receiver line.  Workers are CPU threads with
synthetic slowdown factors standing in for 1..24-core nodes.

    PYTHONPATH=src python examples/seismic_shots.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.a2ws import A2WSRuntime
from repro.core.baselines import CTWSRuntime
from repro.seismic.model import make_demo_model, make_shot_grid, run_shot

N = 32          # model cube size
NT = 60         # time steps per shot
NUM_SHOTS = 12
SLOWDOWN = {0: 1.0, 1: 1.0, 2: 4.0}  # worker 2 is a "1-core node"


def main() -> None:
    model = make_demo_model(n=N)
    shots = make_shot_grid(model, NUM_SHOTS)
    print(f"velocity model {model.velocity.shape}, {NUM_SHOTS} shots x "
          f"{NT} steps, CFL ok: {model.cfl_ok()}")
    # warm up the jitted solver so the first scheduler's makespan does not
    # include XLA compilation
    run_shot(model, jnp.asarray(shots[0].src),
             jnp.asarray(shots[0].rec_array()), nt=NT).block_until_ready()

    seismograms = {}

    def task_fn(wid: int, shot):
        t0 = time.perf_counter()
        seis = run_shot(model, jnp.asarray(shot.src),
                        jnp.asarray(shot.rec_array()), nt=NT)
        seis.block_until_ready()
        extra = (time.perf_counter() - t0) * (SLOWDOWN[wid] - 1.0)
        if extra > 0:  # throttle: emulate a slow node
            end = time.perf_counter() + extra
            while time.perf_counter() < end:
                pass
        seismograms[shot.src] = np.asarray(seis)

    for name, cls in (("a2ws", A2WSRuntime), ("ctws", CTWSRuntime)):
        seismograms.clear()
        rt = cls(shots, len(SLOWDOWN), task_fn)
        stats = rt.run()
        peak = max(float(np.abs(s).max()) for s in seismograms.values())
        print(f"{name:5s}: makespan {stats.makespan:6.2f}s  "
              f"tasks/worker {stats.per_worker_tasks}  "
              f"steals {len(getattr(stats, 'steals', []) or [])}  "
              f"peak amplitude {peak:.3e}")
    print("slow worker (w2) should execute the fewest shots under a2ws.")


if __name__ == "__main__":
    main()

"""Quickstart: A2WS vs LW vs CTWS on a synthetic heterogeneous cluster.

Runs the paper's three schedulers twice:
  1. virtually (discrete-event simulator, paper §4 node configs) — exact,
     fast, shows the gain structure of Tables 3/4;
  2. for real (threaded runtime, CPU-throttled workers) — Algorithm 1
     executing with actual concurrency, packed head/tail deques and the
     bidirectional info ring.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.a2ws import A2WSRuntime
from repro.core.baselines import CTWSRuntime, LWRuntime
from repro.core.simulator import SimConfig, simulate, table2_speeds


def virtual_demo():
    print("=== virtual cluster (discrete-event, C4 = 64 nodes, 3840 shots) ===")
    speeds = table2_speeds("C4")
    cfg = SimConfig(speeds=speeds, num_tasks=3840, seed=0)
    for policy in ("a2ws", "ctws", "lw"):
        res = simulate(policy, cfg)
        print(f"  {policy:5s}: makespan {res.makespan:7.1f}s  "
              f"steals {res.steals:5d}  moved {res.moved_tasks}")
    a = simulate("a2ws", cfg).makespan
    for other in ("lw", "ctws"):
        o = simulate(other, cfg).makespan
        print(f"  gain vs {other}: {(1 - a / o) * 100:5.1f}%  (paper Eq. 13)")


def threaded_demo():
    print("=== threaded runtime (4 workers, one 6x slower, 120 tasks) ===")
    slow = {3}

    def task_fn(wid, task):
        # ~2ms of real work, 12ms on the throttled worker
        end = time.perf_counter() + (0.012 if wid in slow else 0.002)
        while time.perf_counter() < end:
            pass

    tasks = list(range(120))
    for name, cls in (("a2ws", A2WSRuntime), ("ctws", CTWSRuntime),
                      ("lw", LWRuntime)):
        stats = cls(tasks, 4, task_fn).run()
        print(f"  {name:5s}: makespan {stats.makespan*1e3:7.1f}ms  "
              f"tasks/worker {stats.per_worker_tasks}")


if __name__ == "__main__":
    virtual_demo()
    threaded_demo()

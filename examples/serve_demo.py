"""Continuous-batching serving with A2WS request scheduling across
heterogeneous model replicas: requests stream into a LIVE pool (open-arrival
mode, DESIGN.md §Open-arrival), replicas are workers, and fast replicas steal
queued requests from slow ones mid-flight — including requests submitted
after the pool started, across wave boundaries, with no teardown in between.

    PYTHONPATH=src python examples/serve_demo.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import lm
from repro.serve.engine import Replica, ServePool

ARCH = "mistral-nemo-12b"
NUM_REQUESTS = 16
PROMPT_LEN = 12
NEW_TOKENS = 6


def make_generate(cfg, params):
    cache_len = PROMPT_LEN + NEW_TOKENS

    @jax.jit
    def decode(p, tok, caches, pos):
        return lm.decode_step(p, tok, caches, pos, cfg)

    def generate(request: dict) -> dict:
        toks = request["tokens"][None, :]  # [1, S]
        caches = lm.init_caches(cfg, 1, cache_len)
        out = []
        tok = toks[:, :1]
        for i in range(cache_len - 1):
            logits, caches = decode(params, tok, caches, jnp.int32(i))
            if i + 1 < PROMPT_LEN:
                tok = toks[:, i + 1 : i + 2]
            else:
                tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
                out.append(int(tok[0, 0]))
        return {"completion": out}

    return generate


def main() -> None:
    cfg = get_smoke(ARCH)
    params, _ = lm.init(cfg, jax.random.key(0))
    gen = make_generate(cfg, params)
    rng = np.random.default_rng(0)
    requests = [
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, PROMPT_LEN),
                               jnp.int32)}
        for _ in range(NUM_REQUESTS)
    ]
    pool = ServePool([
        Replica("fast-replica", gen),
        Replica("slow-replica", gen, slow_factor=4.0),
    ])
    pool.start()  # boots once; lives across both waves below
    t0 = time.perf_counter()
    responses, stats = pool.submit_all(requests)
    dt = time.perf_counter() - t0
    print(f"wave 1: served {len(responses)} requests x {NEW_TOKENS} tokens "
          f"in {dt:.2f}s ({len(responses)*NEW_TOKENS/dt:.1f} tok/s)")
    print(f"  requests/replica: {stats.per_worker_tasks} "
          f"(steals: {len(stats.steals)}) — fast replica served more")
    print(f"  sample completion: {responses[0]['completion']}")

    # wave 2 streams into the SAME live pool — every request is pinned to the
    # slow replica at submit time, so each one served by the fast replica was
    # stolen mid-flight after injection.
    futs = [pool.submit(r, replica=1) for r in requests]
    for f in futs:
        f.result(timeout=300)
    stolen = sum(1 for f in futs if f.worker == 0)
    final = pool.shutdown()
    pct = final.latency_percentiles()
    print(f"wave 2 (streamed, all pinned to slow replica): "
          f"{stolen}/{len(futs)} rescued by the fast replica via steals")
    print("  pool-lifetime latency p50/p95/p99 = "
          + "/".join(f"{pct[q]*1e3:.0f}ms" for q in (50.0, 95.0, 99.0)))


if __name__ == "__main__":
    main()

"""Diff freshly generated BENCH_<name>.json perf records against the
committed ones at the repo root.

Timing leaves drift run-to-run (CI machines are noisy), so the check is
STRUCTURAL, not numeric: it fails only when

  * a benchmark named in ``--names`` produced no fresh record, or
  * a fresh record LOST keys the committed record has (a silently dropped
    metric is how a perf trajectory goes dark).

Numeric drift is printed as an informational summary — the committed
records themselves are refreshed by re-running
``python -m benchmarks.run --fast --out-dir .`` and committing the result.

    python scripts/bench_diff.py --fresh bench-results --names hierarchy,sched_micro
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def leaf_keys(obj, prefix: str = "") -> set[str]:
    """Dotted paths of every leaf in a nested dict."""
    if isinstance(obj, dict) and obj:
        out: set[str] = set()
        for k, v in obj.items():
            out |= leaf_keys(v, f"{prefix}{k}.")
        return out
    return {prefix.rstrip(".")}


def leaf_get(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, help="dir with the new records")
    ap.add_argument(
        "--committed", default=".", help="dir with the committed records"
    )
    ap.add_argument(
        "--names", default="",
        help="comma-separated benchmark names that MUST have fresh records",
    )
    args = ap.parse_args()
    fresh_dir = Path(args.fresh)
    committed_dir = Path(args.committed)
    names = [n for n in args.names.split(",") if n]

    failures: list[str] = []
    for name in names:
        fresh_path = fresh_dir / f"BENCH_{name}.json"
        committed_path = committed_dir / f"BENCH_{name}.json"
        if not fresh_path.exists():
            failures.append(f"{name}: no fresh record at {fresh_path}")
            continue
        fresh = json.loads(fresh_path.read_text())
        if not committed_path.exists():
            print(f"{name}: no committed baseline yet (first record) — OK")
            continue
        committed = json.loads(committed_path.read_text())
        lost = leaf_keys(committed) - leaf_keys(fresh)
        if lost:
            failures.append(
                f"{name}: fresh record lost keys: {sorted(lost)[:10]}"
            )
            continue
        drifts = []
        for path in sorted(leaf_keys(committed)):
            old, new = leaf_get(committed, path), leaf_get(fresh, path)
            if (
                isinstance(old, (int, float)) and isinstance(new, (int, float))
                and not isinstance(old, bool) and old
            ):
                rel = (new - old) / abs(old) * 100.0
                if abs(rel) >= 10.0:
                    drifts.append(f"  {path}: {old:.4g} -> {new:.4g} ({rel:+.0f}%)")
        print(f"{name}: OK ({len(leaf_keys(committed))} keys)"
              + (f", {len(drifts)} leaves drifted >=10%:" if drifts else ""))
        for line in drifts[:20]:
            print(line)

    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Opcode-level byte/collective breakdown for one dry-run cell (hillclimb
profiling tool — 'the profile is lowered.as_text() + cost_analysis')."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    os.environ.get("REPRO_FORCE_DEVICES", "512")

import collections
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES, get_config
from repro.launch.cells import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis as H
from repro.parallel.sharding import make_context

arch, shape_name = sys.argv[1], sys.argv[2]
cfg = get_config(arch)
mesh = make_production_mesh(multi_pod=False)
ctx = make_context(mesh)
with mesh:
    lowered, meta = lower_cell(cfg, SHAPES[shape_name], ctx)
    compiled = lowered.compile()
text = compiled.as_text()

comps = H._parse_computations(text)
shape_of = {c: {i.name: i.rtype for i in ins} for c, ins in comps.items()}
memo = {}

def cost(cname):
    if cname in memo:
        return memo[cname]
    memo[cname] = collections.Counter()
    tot = collections.Counter()
    shapes = shape_of.get(cname, {})
    for ins in comps.get(cname, []):
        op = ins.opcode
        if op in H._FREE_OPS or op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if op == "while":
            trip = 1
            m = H._TRIP_RE.search(ins.rest)
            if m:
                trip = int(m.group(1))
            b = H._CALL_RE.search(ins.rest)
            if b:
                for k, v in cost(b.group(1)).items():
                    tot[k] += v * trip
            continue
        if base in H._COLLECTIVES:
            nb = H._shape_bytes(ins.rtype) * (2 if base == "all-reduce" else 1)
            tot["COLL:" + base] += nb
            continue
        if op in ("fusion", "call", "conditional", "custom-call"):
            nb = H._shape_bytes(ins.rtype)
            for on in H._OPERAND_RE.findall(ins.rest.split(", calls=")[0]):
                if on in shapes:
                    nb += H._shape_bytes(shapes[on])
            # bucket fusions by their biggest tensor's metadata op_name hint
            m = re.search(r'op_name="([^"]+)"', ins.rest)
            tag = "fusion"
            if m:
                name = m.group(1)
                for key in ("attention", "moe", "softmax", "log_softmax",
                            "scan", "transpose", "while"):
                    if key in name:
                        tag = f"fusion[{key}]"
                        break
            tot[tag] += nb
            continue
        nb = H._shape_bytes(ins.rtype)
        for on in H._OPERAND_RE.findall(ins.rest):
            if on in shapes:
                nb += H._shape_bytes(shapes[on])
        tot[base] += nb
    memo[cname] = tot
    return tot

m = re.search(r"^ENTRY\s+%([\w.\-]+)", text, re.M)
tot = cost(m.group(1))
print(f"=== {arch} {shape_name}: bytes by opcode (GB, trip-scaled) ===")
for k, v in tot.most_common(20):
    print(f"{k:28s} {v/1e9:12.2f}")
print("TOTAL_GB", sum(v for k, v in tot.items() if not k.startswith('COLL'))/1e9)

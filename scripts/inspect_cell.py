import os, sys
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_FORCE_DEVICES", "512")
)
import re
import collections

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs.base import SHAPES, get_config
from repro.launch.cells import lower_cell, _shape_bytes
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import make_context

arch, shape_name = sys.argv[1], sys.argv[2]
cfg = get_config(arch)
mesh = make_production_mesh(multi_pod=False)
ctx = make_context(mesh)
with mesh:
    lowered, meta = lower_cell(cfg, SHAPES[shape_name], ctx)
    compiled = lowered.compile()
hlo = compiled.as_text()
open(f"/tmp/{arch}_{shape_name}.hlo", "w").write(hlo)

# entry params
for line in hlo.splitlines():
    if line.strip().startswith("ENTRY"):
        print(line[:400])
        break

pat = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start)?\("
)
sizes = collections.Counter()
tops = []
for m in pat.finditer(hlo):
    b = _shape_bytes(m.group(1))
    sizes[m.group(2)] += b
    tops.append((b, m.group(2), m.group(1)[:120]))
tops.sort(reverse=True)
print("per-kind result bytes:", {k: f"{v/1e9:.2f}GB" for k, v in sizes.items()})
print("top collectives:")
for b, kind, shp in tops[:15]:
    print(f"  {b/1e9:9.3f}GB {kind:20s} {shp}")
# biggest fusions/temps hint: largest shapes anywhere
shape_re = re.compile(r"([a-z]+\d+)\[([\d,]+)\]")
big = collections.Counter()
for m in shape_re.finditer(hlo):
    big[m.group(0)] = _shape_bytes(m.group(0))
print("largest tensor shapes in module:")
for s, b in sorted(big.items(), key=lambda kv: -kv[1])[:12]:
    print(f"  {b/1e9:9.3f}GB {s}")
print(meta)

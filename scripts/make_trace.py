#!/usr/bin/env python
"""Generate a seeded bursty diurnal arrival trace (DESIGN.md §SLO serving).

Writes the streaming trace format consumed by ``SimConfig.arrival_trace`` /
``slo_trace`` (and by ``benchmarks/slo_trace``): a compressed ``.npz`` with
aligned ``arrival`` (float64 seconds) and ``slo`` (int8, 0=batch 1=latency)
arrays.  Example:

    python scripts/make_trace.py --n 1000000 --mean-rate 150 \
        --period 1200 --out traces/diurnal_1m.npz
"""

from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core.trace import diurnal_trace, save_trace  # noqa: E402


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1_000_000,
                    help="number of requests")
    ap.add_argument("--mean-rate", type=float, default=100.0,
                    help="mean arrival rate, requests/s")
    ap.add_argument("--period", type=float, default=600.0,
                    help="diurnal period, seconds")
    ap.add_argument("--depth", type=float, default=0.8,
                    help="sinusoidal swing in [0, 1)")
    ap.add_argument("--spikes", type=int, default=3,
                    help="number of flash-crowd spikes")
    ap.add_argument("--spike-amp", type=float, default=4.0,
                    help="spike amplitude, multiples of the mean rate")
    ap.add_argument("--spike-width", type=float, default=None,
                    help="spike width, seconds (default period/40)")
    ap.add_argument("--latency-frac", type=float, default=0.25,
                    help="fraction of latency-class requests")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", required=True, help="output .npz path")
    args = ap.parse_args(argv)

    arrival, slo = diurnal_trace(
        args.n,
        mean_rate=args.mean_rate,
        period=args.period,
        depth=args.depth,
        spikes=args.spikes,
        spike_amp=args.spike_amp,
        spike_width=args.spike_width,
        latency_frac=args.latency_frac,
        seed=args.seed,
    )
    save_trace(args.out, arrival, slo)
    span = float(arrival[-1] - arrival[0])
    print(
        f"wrote {args.out}: {args.n} requests over {span:.1f}s "
        f"(mean {args.n / max(span, 1e-9):.1f}/s, "
        f"{int(slo.sum())} latency-class, seed {args.seed})"
    )
    # Peak-minute rate: the burstiness the autoscaler has to ride out.
    if span > 60.0:
        counts, _ = np.histogram(
            arrival, bins=np.arange(arrival[0], arrival[-1] + 60.0, 60.0)
        )
        print(f"peak minute: {counts.max() / 60.0:.1f}/s")


if __name__ == "__main__":
    main()

"""Dry-run + roofline record for the A2WS device scheduler itself — the cell
most representative of the paper's technique.

Lowers one jitted shard_map scheduler round (ring ppermutes + steal-rate +
request/grant all_to_all) for 256 workers on the production pod, records the
three roofline terms, and writes experiments/dryrun/a2ws-sched__round__16x16.json.

    REPRO_SCHED_VARIANT=baseline|packed python scripts/sched_cell.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"

import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import device_sched as ds
from repro.launch.cells import roofline_terms
from repro.launch.hlo_analysis import analyze_hlo

VARIANT = os.environ.get("REPRO_SCHED_VARIANT", "baseline")
P = 256
RADIUS = 51  # 20% of 256 (paper's operating point)
MAX_STEAL = 16
NUM_TASKS = 256 * 30


def main() -> None:
    mesh = jax.make_mesh((P,), ("workers",))
    speeds = jnp.concatenate(
        [jnp.full((P // 4,), s) for s in (24.0, 16.0, 4.0, 1.0)]
    )
    base, rem = divmod(NUM_TASKS, P)
    counts = jnp.array([base + (1 if i < rem else 0) for i in range(P)],
                       jnp.int32)
    state = ds.init_state(P, counts, speeds, RADIUS, capacity=NUM_TASKS)
    round_fn = ds.make_round_fn(mesh, "workers", RADIUS, MAX_STEAL,
                                packed=(VARIANT == "packed"))
    t0 = time.time()
    lowered = round_fn.lower(state)
    compiled = lowered.compile()
    dt = time.time() - t0
    costs = analyze_hlo(compiled.as_text())
    terms = roofline_terms(costs.flops, costs.bytes, costs.coll_bytes)
    mem = compiled.memory_analysis()
    rec = {
        "arch": "a2ws-sched",
        "shape": f"round_p{P}_r{RADIUS}",
        "kind": "sched",
        "variant": VARIANT,
        "chips": P,
        "mesh": "16x16",
        "status": "ok",
        "flops_per_device": costs.flops,
        "bytes_per_device": costs.bytes,
        "collective_bytes_per_device": costs.coll_bytes,
        "collectives": {k: int(v) for k, v in costs.coll.items()},
        **terms,
        "dominant": max(terms, key=terms.get),
        "live_bytes_per_device": int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ),
        "compile_s": round(dt, 2),
    }
    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun", f"a2ws-sched__round__16x16__{VARIANT}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    json.dump(rec, open(out, "w"), indent=1)
    print(json.dumps({k: rec[k] for k in (
        "variant", "t_compute", "t_memory", "t_collective", "dominant",
        "collective_bytes_per_device", "bytes_per_device", "compile_s")},
        indent=1))


if __name__ == "__main__":
    main()
